"""Paper Table 1: sumup on the clock-level machine, NO/FOR/SUMUP."""
import time

import numpy as np

from repro.core import TABLE1, alpha_eff, programs, run_program, timing

VEC = [0xD, 0xC0, 0xB00, 0xA000, 5, 7]


def run() -> list[str]:
    rows = ["table1.header,n,mode,clocks,clocks_paper,cores,cores_paper,"
            "speedup,s_over_k,alpha_eff,match"]
    for n, mode, t_exp, k_exp, s_exp, sk_exp, a_exp in TABLE1:
        t0 = time.perf_counter()
        r = run_program(programs.PROGRAMS[mode](n), programs.mem_image(VEC[:n]))
        us = (time.perf_counter() - t0) * 1e6
        s = timing.exec_clocks(n, "NO") / int(r.clocks)
        k = int(r.peak_cores)
        a = float(alpha_eff(k, s))
        match = int(r.clocks) == t_exp and k == k_exp
        rows.append(
            f"table1,{n},{mode},{int(r.clocks)},{t_exp},{k},{k_exp},"
            f"{s:.2f},{s / k:.2f},{a:.2f},{'OK' if match else 'FAIL'}")
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
