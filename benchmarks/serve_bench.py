"""Serving benchmark: device-resident continuous batching economics.

Measures the refactored engine on CPU-sized configs and writes
``BENCH_serve.json`` so the perf trajectory starts recording:

* ``tokens_per_s`` — end-to-end greedy decode throughput,
* ``device_ticks`` — decode iterations executed on device,
* ``host_syncs_per_100_tokens`` — actual blocking host round-trips,
* ``baseline_syncs_per_100_tokens`` — what the pre-refactor engine paid
  (one ``int(jnp.argmax(...))`` per slot per tick + one per admission),
  measured in the *same run* from the same token stream,
* ``sync_reduction_x`` — the ratio (acceptance floor: ≥ 5×).
"""
import json
import os
import time


def run_serve(out_path: str = None) -> list[str]:
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.configs import get_arch, reduced
    from repro.models import model as model_lib
    from repro.runtime.serve import Request, ServingEngine

    out_path = out_path or os.path.join(os.getcwd(), "BENCH_serve.json")
    cfg = reduced(get_arch("granite-3-2b"), n_layers=2, d_model=128,
                  vocab=512)
    params = model_lib.init(jax.random.PRNGKey(0), cfg, jnp.float32)
    chunk = 8
    eng = ServingEngine(params, cfg, n_slots=4, max_seq=96, chunk=chunk)

    rng = np.random.default_rng(0)
    reqs = [Request(i,
                    rng.integers(1, cfg.vocab, size=int(rng.integers(4, 16)),
                                 dtype=np.int64).astype(np.int32),
                    max_new=int(rng.integers(6, 20)))
            for i in range(16)]
    # warmup: compile the admit/decode programs outside the timed region
    warm = ServingEngine(params, cfg, n_slots=4, max_seq=96, chunk=chunk)
    warm.run_to_completion([Request(99, np.arange(1, 9, dtype=np.int32),
                                    max_new=4)])

    t0 = time.perf_counter()
    done, ticks = eng.run_to_completion(reqs)
    dt = time.perf_counter() - t0
    assert len(done) == len(reqs)

    total_tokens = sum(len(r.out) for r in done)
    stats = eng.sync_stats()
    record = {
        "suite": "serve",
        "config": {"arch": cfg.name, "n_slots": 4, "chunk": chunk,
                   "n_requests": len(reqs), "max_seq": 96},
        "tokens_per_s": total_tokens / dt,
        "total_tokens": total_tokens,
        "device_ticks": ticks,
        "wall_s": dt,
        **stats,
    }
    with open(out_path, "w") as f:
        json.dump(record, f, indent=2)

    rows = ["serve.header,name,metric,value,derived"]
    rows.append(f"serve,continuous_batching,tokens_per_s,"
                f"{record['tokens_per_s']:.0f},ticks={ticks}")
    rows.append(f"serve,host_sync_economy,syncs_per_100_tokens,"
                f"{stats['host_syncs_per_100_tokens']:.2f},"
                f"baseline={stats['baseline_syncs_per_100_tokens']:.2f};"
                f"reduction={stats['sync_reduction_x']:.1f}x")
    rows.append(f"serve,artifact,path,{out_path},")
    # acceptance floor: ≥ 5× fewer host syncs than per-slot-per-tick
    assert stats["sync_reduction_x"] >= 5.0, stats
    return rows


def run() -> list[str]:
    return run_serve()


if __name__ == "__main__":
    print("\n".join(run()))
