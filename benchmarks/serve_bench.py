"""Serving benchmark: device-resident continuous batching economics.

Measures the refactored engine on CPU-sized configs and writes
``BENCH_serve.json`` so the perf trajectory keeps recording:

* ``tokens_per_s`` — end-to-end greedy decode throughput,
* ``device_ticks`` — decode iterations executed on device,
* ``host_syncs_per_100_tokens`` — actual blocking host round-trips,
* ``baseline_syncs_per_100_tokens`` — what the pre-refactor engine paid
  (one ``int(jnp.argmax(...))`` per slot per tick + one per admission),
  measured in the *same run* from the same token stream,
* ``sync_reduction_x`` — the ratio (acceptance floor: ≥ 5×),
* ``kv`` — paged-vs-contiguous KV economics from the same request
  stream: allocated KV bytes per admitted token under each layout and
  the reduction ratio (acceptance floor: paged strictly smaller), plus
  shared-prefix block hits and peak block usage,
* ``ttft`` / ``inter_token_p50`` / ``inter_token_p99`` — head-of-line
  latency: a long prompt is admitted *mid-decode* and the active slots'
  token arrival gaps are measured under monolithic admission (the whole
  prompt prefills in one call, stalling every decoder) vs chunked
  prefill (one fragment per mixed tick).  Floors: chunked output is
  token-exact vs monolithic, and chunked p99 inter-token latency is no
  worse than a decode-only run's by more than one fragment tick's cost.
  ``ttft.long_chunked_idle_s`` is the cold-start case: with no decoder
  to protect, the solo tick packs fragments up to the per-tick budget
  through a single-row forward instead of paying the n_slots-row
  fragment tax — it must land within 2x of the monolithic prefill,
* ``spec`` — speculative decoding on a repetitive-suffix workload:
  ``tokens_per_forward`` (decode tokens per decoding slot per verify
  forward; the non-speculative engine is exactly 1.0),
  ``acceptance_rate``, ``spec_decode_tokens_per_s`` vs
  ``baseline_decode_tokens_per_s`` — decode tokens per second of
  serving-tick wall time (``ServingEngine.decode_wall_s``) on the same
  stream — plus the per-phase breakdown ``verify_forward_s`` /
  ``draft_s`` and ``spec_token_exact`` (greedy argmax verification is
  bit-exact — asserted on BOTH cache layouts).  Floors:
  ``tokens_per_forward > 1.3`` and, since the span-clamped
  chunk-attention kernels, ``spec_decode_tokens_per_s >=
  baseline_decode_tokens_per_s``,
* ``overcommit`` — preemptive over-commit on a deliberately undersized
  block pool: mean ``occupancy`` (running slots per tick) vs the
  reserved-admission engine on the same stream, ``preemptions`` /
  ``resumes`` / ``preempted_tokens_recomputed``, throughput vs
  reserved, and ``preempt_token_exact`` (eviction + recompute-based
  resume changes no token).  Floors: >= 1 preemption actually fired,
  token-exact, and occupancy strictly above the reserved baseline,
* ``scaling`` / ``sharded_token_exact`` — the mesh curve: a
  FleetSupervisor of one replica per device at 1/2/4/8 forced host
  devices (each device count in a subprocess — XLA reads the flag at
  import), tok/s + host syncs + routing balance per point, and the
  tensor-parallel (model=2) engine's byte-exactness vs the
  single-device oracle.  Floors: every point token-exact and every
  replica routed to; ``sharded_token_exact`` true.  Also appends the
  single-device baseline to ``benchmarks/artifacts/
  serve_trajectory.jsonl`` (the perf-trajectory anchor),
* ``fault_recovery`` — chaos: a seeded FaultPlan kills replica 0 of a
  2-replica fleet mid-run; the fleet quarantines it and migrates its
  in-flight requests to the survivor via token-exact replay.  Records
  ``requests_migrated`` / ``migrated_token_exact`` / ``dead_letter`` /
  ``recovery_overhead_x`` (fault-free tok/s over faulted tok/s).
  Floors: >= 1 migration, bit-exact vs the unfaulted single-engine
  oracle, zero dead letters,
* ``sla`` — priority tiers under a bursty open-loop trace: throughput
  requests arrive in bursts that saturate the slots, latency-tier
  requests arrive mid-run and displace throughput victims through the
  admission controller.  Per tier and per layout: ``ttft_p99`` /
  ``inter_token_p99`` (``TierAccounting``), ``displacements``, and
  ``tier_token_exact`` (the tiered run's outputs vs the same engine's
  untiered closed-loop oracle).  Floors: >= 1 displacement fired,
  token-exact on both layouts, and latency-tier p99 TTFT < 0.5x the
  throughput tier's.
"""
import json
import os
import sys
import time


def _phase_time(fn, *args, reps: int = 20) -> float:
    """Steady-state seconds per call of a jitted fn (compile excluded)."""
    import jax
    jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / reps


def _requests(cfg, np, Request, n=16):
    rng = np.random.default_rng(0)
    shared = rng.integers(1, cfg.vocab, size=16,
                          dtype=np.int64).astype(np.int32)
    reqs = []
    for i in range(n):
        if i % 2 == 0:   # half the stream shares a 16-token (1-block) prefix
            tail = rng.integers(1, cfg.vocab, size=int(rng.integers(2, 8)),
                                dtype=np.int64).astype(np.int32)
            prompt = np.concatenate([shared, tail])
        else:
            prompt = rng.integers(1, cfg.vocab,
                                  size=int(rng.integers(4, 16)),
                                  dtype=np.int64).astype(np.int32)
        reqs.append(Request(i, prompt, max_new=int(rng.integers(6, 20))))
    return reqs


def run_serve(out_path: str = None) -> list[str]:
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.configs import get_arch, reduced
    from repro.models import model as model_lib
    from repro.runtime.serve import Request, ServingEngine

    out_path = out_path or os.path.join(os.getcwd(), "BENCH_serve.json")
    cfg = reduced(get_arch("granite-3-2b"), n_layers=2, d_model=128,
                  vocab=512)
    params = model_lib.init(jax.random.PRNGKey(0), cfg, jnp.float32)
    chunk = 8

    def engine(paged: bool) -> ServingEngine:
        kw = dict(paged=True, block_size=16, n_blocks=20) if paged else {}
        return ServingEngine(params, cfg, n_slots=4, max_seq=96,
                             chunk=chunk, **kw)

    results = {}
    for paged in (False, True):
        eng = engine(paged)
        # warmup on the SAME engine (each engine owns its jitted
        # closures), then reset the counters for a clean measurement
        eng.run_to_completion([Request(99, np.arange(1, 9, dtype=np.int32),
                                       max_new=4)])
        eng.reset_stats()
        reqs = _requests(cfg, np, Request)
        t0 = time.perf_counter()
        done, ticks = eng.run_to_completion(reqs)
        dt = time.perf_counter() - t0
        assert len(done) == len(reqs)
        results[eng.kv_stats()["layout"]] = dict(
            engine=eng, done=done, ticks=ticks, dt=dt,
            outputs={r.rid: list(r.out) for r in done})
    # paged decode is token-exact vs the contiguous cache (same stream)
    token_exact = results["paged"]["outputs"] == results["contiguous"]["outputs"]
    assert token_exact, "paged decode diverged from the contiguous cache"

    eng = results["contiguous"]["engine"]
    dt, ticks = results["contiguous"]["dt"], results["contiguous"]["ticks"]
    total_tokens = sum(len(r.out) for r in results["contiguous"]["done"])
    stats = eng.sync_stats()
    kv_c = eng.kv_stats()
    kv_p = results["paged"]["engine"].kv_stats()
    kv_reduction = kv_c["kv_bytes_per_token"] / kv_p["kv_bytes_per_token"]
    record = {
        "suite": "serve",
        "config": {"arch": cfg.name, "n_slots": 4, "chunk": chunk,
                   "n_requests": len(results["contiguous"]["done"]),
                   "max_seq": 96, "block_size": 16, "n_blocks": 20},
        "tokens_per_s": total_tokens / dt,
        "total_tokens": total_tokens,
        "device_ticks": ticks,
        "wall_s": dt,
        **stats,
        "kv": {
            "contiguous_bytes_per_token": kv_c["kv_bytes_per_token"],
            "paged_bytes_per_token": kv_p["kv_bytes_per_token"],
            "kv_bytes_reduction_x": kv_reduction,
            "paged_token_exact": token_exact,
            "shared_block_hits": kv_p["shared_block_hits"],
            "peak_blocks": kv_p["peak_blocks"],
            "stalls": kv_p["stalls"],
        },
    }
    with open(out_path, "w") as f:
        json.dump(record, f, indent=2)

    rows = ["serve.header,name,metric,value,derived"]
    rows.append(f"serve,continuous_batching,tokens_per_s,"
                f"{record['tokens_per_s']:.0f},ticks={ticks}")
    rows.append(f"serve,host_sync_economy,syncs_per_100_tokens,"
                f"{stats['host_syncs_per_100_tokens']:.2f},"
                f"baseline={stats['baseline_syncs_per_100_tokens']:.2f};"
                f"reduction={stats['sync_reduction_x']:.1f}x")
    rows.append(f"serve,paged_kv_economy,kv_bytes_per_token,"
                f"{kv_p['kv_bytes_per_token']:.0f},"
                f"contiguous={kv_c['kv_bytes_per_token']:.0f};"
                f"reduction={kv_reduction:.2f}x;"
                f"shared_hits={kv_p['shared_block_hits']}")
    rows.append(f"serve,artifact,path,{out_path},")
    # acceptance floors: ≥ 5× fewer host syncs than per-slot-per-tick;
    # paged KV bytes per token strictly below contiguous, with no stalls
    assert stats["sync_reduction_x"] >= 5.0, stats
    assert kv_reduction > 1.0, record["kv"]
    assert kv_p["stalls"] == 0, record["kv"]
    return rows


# ---------------------------------------------------------------------------
# Head-of-line latency: monolithic admission vs chunked prefill
# ---------------------------------------------------------------------------

N_DECODERS = 3
LONG_LEN = 960          # long enough that a monolithic prefill (~0.3 s at
LATENCY_MAX_SEQ = 1024  # this size) dwarfs ambient scheduler noise
INJECT_AT = 2           # steps of pure decode before the long prompt lands
PREFILL_CHUNK = 32


def _latency_requests(np, Request):
    rng = np.random.default_rng(11)
    decoders = [Request(i, rng.integers(1, 500, size=8,
                                        dtype=np.int64).astype(np.int32),
                        max_new=60) for i in range(N_DECODERS)]
    long_req = Request(99, rng.integers(1, 500, size=LONG_LEN,
                                        dtype=np.int64).astype(np.int32),
                      max_new=4)
    return decoders, long_req


def _timed_run(eng, np, Request, inject_long: bool):
    """Drive the engine step by step, recording token-arrival times.

    Returns (outputs, arrivals {rid: [(t, n_new), ...]}, ttft_long,
    tick_times)."""
    decoders, long_req = _latency_requests(np, Request)
    reqs = decoders + ([long_req] if inject_long else [])
    assert eng.admit_many(decoders) == len(decoders)
    arrivals = {r.rid: [] for r in reqs}
    t_admit_long, pending_long = None, inject_long
    tick_times, steps = [], 0
    while eng.active or pending_long or eng._finished_instant:
        if pending_long and steps >= INJECT_AT:
            t_admit_long = time.perf_counter()
            assert eng.admit(long_req)
            pending_long = False
        before = {r.rid: len(r.out) for r in reqs}
        t0 = time.perf_counter()
        eng.step()
        t1 = time.perf_counter()
        tick_times.append(t1 - t0)
        for r in reqs:
            d = len(r.out) - before[r.rid]
            if d:
                arrivals[r.rid].append((t1, d))
        steps += 1
    ttft_long = arrivals[long_req.rid][0][0] - t_admit_long \
        if inject_long else None
    return {r.rid: list(r.out) for r in reqs}, arrivals, ttft_long, \
        tick_times


def _per_token_latencies(arrivals, rids):
    """Gap between consecutive deliveries, amortized over the tokens the
    later delivery carried (a `chunk`-token decode delivery is `chunk`
    tokens per sync, not one slow token)."""
    lats = []
    for rid in rids:
        ds = arrivals[rid]
        for (prev_t, _), (t, n) in zip(ds, ds[1:]):
            lats.extend([(t - prev_t) / n] * n)
    return lats


def run_latency(out_path: str = None) -> list[str]:
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.configs import get_arch, reduced
    from repro.models import model as model_lib
    from repro.runtime.serve import Request, ServingEngine

    out_path = out_path or os.path.join(os.getcwd(), "BENCH_serve.json")
    cfg = reduced(get_arch("granite-3-2b"), n_layers=4, d_model=256,
                  vocab=512)
    params = model_lib.init(jax.random.PRNGKey(0), cfg, jnp.float32)
    chunk = 4

    def engine(chunked: bool) -> ServingEngine:
        kw = dict(chunked_prefill=True,
                  prefill_chunk_tokens=PREFILL_CHUNK) if chunked else {}
        return ServingEngine(params, cfg, n_slots=4, max_seq=LATENCY_MAX_SEQ,
                             chunk=chunk, **kw)

    dec_rids = list(range(N_DECODERS))
    reps = 3              # best-of-N: a shared box injects ~30ms
    #                       scheduler hiccups at random ticks; the min-p99
    #                       pass is the engine's behavior, not the OS's
    runs, p = {}, {}
    for name, chunked, inject in (("decode_only", True, False),
                                  ("monolithic", False, True),
                                  ("chunked", True, True)):
        eng = engine(chunked)
        # warm every compile this workload touches on the SAME engine
        # (each engine owns its jitted closures), then measure
        _timed_run(eng, np, Request, inject_long=inject)
        best = None
        for _ in range(reps):
            eng.reset_stats()
            outputs, arrivals, ttft_long, ticks = _timed_run(
                eng, np, Request, inject_long=inject)
            lats = _per_token_latencies(arrivals, dec_rids)
            gaps = [t - pt for rid in dec_rids
                    for (pt, _), (t, _) in zip(arrivals[rid],
                                               arrivals[rid][1:])]
            stats = {"p50": float(np.percentile(lats, 50)),
                     "p99": float(np.percentile(lats, 99)),
                     "stall_max": float(max(gaps))}
            if best is None:
                best = (stats, dict(outputs=outputs, ttft_long=ttft_long,
                                    ticks=ticks))
            else:
                # min per metric across passes: a genuine engine stall
                # (the monolithic prefill) survives the min, a random
                # scheduler hiccup does not
                best[0].update({k: min(best[0][k], stats[k])
                                for k in stats})
                if ttft_long is not None:
                    best[1]["ttft_long"] = min(best[1]["ttft_long"],
                                               ttft_long)
        p[name], runs[name] = best

    # chunked prefill must not change a single token vs monolithic
    token_exact = runs["chunked"]["outputs"] == runs["monolithic"]["outputs"]
    assert token_exact, "chunked prefill diverged from monolithic admission"

    # one fragment tick's cost: the mixed ticks right after injection
    # (mean = typical; max = worst observed, which is the honest slack
    # for a p99 bound on a shared box)
    mixed = runs["chunked"]["ticks"][INJECT_AT:
                                     INJECT_AT + LONG_LEN // PREFILL_CHUNK]
    chunk_cost = float(np.mean(mixed))
    chunk_cost_max = float(np.max(mixed))

    record = json.load(open(out_path))
    record["latency_config"] = {
        "n_decoders": N_DECODERS, "long_len": LONG_LEN,
        "prefill_chunk_tokens": PREFILL_CHUNK, "decode_chunk": chunk,
        "inject_at_step": INJECT_AT, "max_seq": LATENCY_MAX_SEQ,
    }
    record["ttft"] = {
        "long_monolithic_s": runs["monolithic"]["ttft_long"],
        "long_chunked_s": runs["chunked"]["ttft_long"],
    }
    record["inter_token_p50"] = {k: v["p50"] for k, v in p.items()}
    record["inter_token_p99"] = {k: v["p99"] for k, v in p.items()}
    record["decode_stall_max_s"] = {k: v["stall_max"] for k, v in p.items()}
    record["fragment_tick_cost_s"] = chunk_cost
    record["fragment_tick_cost_max_s"] = chunk_cost_max
    record["chunked_token_exact"] = token_exact
    with open(out_path, "w") as f:
        json.dump(record, f, indent=2)

    # cold-start TTFT: the long prompt admitted on an idle engine — no
    # decoder to protect, so the solo tick packs fragments up to the
    # per-tick budget through a single-row forward (the fix for the
    # fragment-per-tick TTFT regression; ~n_slots x less compute than
    # fragment ticks and a fraction of the host round-trips)
    eng = engine(True)
    rng_idle = np.random.default_rng(11)

    def run_idle():
        req = Request(199, rng_idle.integers(
            1, 500, size=LONG_LEN, dtype=np.int64).astype(np.int32),
            max_new=4)
        t0 = time.perf_counter()
        assert eng.admit(req)
        while not req.out:
            eng.step()
        ttft = time.perf_counter() - t0
        while eng.active:
            eng.step()
        return ttft

    run_idle()                      # warm the solo-tick compile
    ttft_idle = min(run_idle() for _ in range(reps))
    record["ttft"]["long_chunked_idle_s"] = ttft_idle
    with open(out_path, "w") as f:
        json.dump(record, f, indent=2)

    rows = [
        f"serve,chunked_prefill,ttft_long_s,"
        f"{record['ttft']['long_chunked_s']:.4f},"
        f"monolithic={record['ttft']['long_monolithic_s']:.4f};"
        f"idle={ttft_idle:.4f}",
        f"serve,chunked_prefill,inter_token_p99_s,"
        f"{p['chunked']['p99']:.5f},"
        f"decode_only={p['decode_only']['p99']:.5f};"
        f"monolithic={p['monolithic']['p99']:.5f}",
        f"serve,chunked_prefill,decode_stall_max_s,"
        f"{p['chunked']['stall_max']:.5f},"
        f"monolithic={p['monolithic']['stall_max']:.5f};"
        f"fragment_tick={chunk_cost:.5f}",
    ]
    # acceptance floors: admitting a long prompt mid-decode may cost the
    # active decoders at most one fragment tick over a decode-only run.
    # The p99 bound uses the worst *observed* fragment tick (+20% timer
    # margin): on a shared box a single ~30ms scheduler hiccup is the
    # top percentile of a ~140-sample distribution, and that same hiccup
    # is part of "one chunk's cost" when it lands in a fragment tick.
    # The p50 bound is the noise-immune version of the same claim.
    slack = 1.2 * chunk_cost_max
    assert p["chunked"]["p99"] <= p["decode_only"]["p99"] + slack, \
        (p, chunk_cost_max)
    assert p["chunked"]["p50"] <= p["decode_only"]["p50"] + 1.2 * chunk_cost, \
        (p, chunk_cost)
    # cold-start floor: with nobody decoding, packed solo prefill must
    # land within 2x of one monolithic prefill (same compute, a few more
    # host round-trips) — the pre-fix fragment-per-tick path paid the
    # full n_slots-row tax and ~3x the monolithic latency
    assert ttft_idle <= 2.0 * record["ttft"]["long_monolithic_s"], record["ttft"]
    return rows


# ---------------------------------------------------------------------------
# Speculative decoding: drafter cores ahead, k tokens per verify forward
# ---------------------------------------------------------------------------

SPEC_K = 4
SPEC_MAX_SEQ = 128


def _spec_params(cfg):
    """Copy-model: every block's residual contribution is zeroed and the
    unembedding tied, so the forward copies its input token.  Greedy
    decode becomes perfectly repetitive — the regime repetitive/
    code-like serving traffic lives in, which the tiny *random* seed
    model cannot produce — while the verify pass stays a real
    transformer forward over real caches."""
    import jax
    import jax.numpy as jnp

    from repro.models import model as model_lib

    params = model_lib.init(jax.random.PRNGKey(0), cfg, jnp.float32)
    p = dict(params)
    p["layers"] = dict(p["layers"],
                       wo=jnp.zeros_like(p["layers"]["wo"]),
                       w_down=jnp.zeros_like(p["layers"]["w_down"]))
    if not cfg.tie_embeddings:
        p["unembed"] = p["embed"]["tok"]
    return p


def _spec_requests(np, Request, n=8):
    """Repetitive-suffix prompts: a random head, then a constant run the
    copy-model continues — prompt-lookup's home turf."""
    rng = np.random.default_rng(7)
    reqs = []
    for i in range(n):
        head = rng.integers(2, 500,
                            size=int(rng.integers(4, 10))).astype(np.int32)
        tail = np.full(int(rng.integers(6, 12)),
                       int(rng.integers(2, 500)), np.int32)
        reqs.append(Request(i, np.concatenate([head, tail]),
                            max_new=int(rng.integers(24, 48))))
    return reqs


def run_spec(out_path: str = None) -> list[str]:
    import numpy as np

    from repro.configs import get_arch, reduced
    from repro.runtime.serve import Request, ServingEngine

    out_path = out_path or os.path.join(os.getcwd(), "BENCH_serve.json")
    cfg = reduced(get_arch("granite-3-2b"), n_layers=2, d_model=128,
                  vocab=512)
    params = _spec_params(cfg)

    def engine(spec: bool, paged: bool) -> ServingEngine:
        kw = dict(paged=True, block_size=16, n_blocks=40) if paged else {}
        if spec:
            kw.update(speculative=True, spec_k=SPEC_K)
        return ServingEngine(params, cfg, n_slots=4, max_seq=SPEC_MAX_SEQ,
                             chunk=8, **kw)

    results = {}
    for spec in (False, True):
        for paged in (False, True):
            eng = engine(spec, paged)
            eng.run_to_completion([Request(99, np.arange(1, 9,
                                                         dtype=np.int32),
                                           max_new=6)])       # warm
            eng.reset_stats()
            reqs = _spec_requests(np, Request)
            t0 = time.perf_counter()
            done, _ = eng.run_to_completion(reqs)
            dt = time.perf_counter() - t0
            assert len(done) == len(reqs)
            results[(spec, paged)] = dict(
                engine=eng, dt=dt,
                outputs={r.rid: list(r.out) for r in done})

    # bit-exactness: speculative == non-speculative, on BOTH layouts
    token_exact = all(
        results[(True, paged)]["outputs"] == results[(False, paged)]["outputs"]
        for paged in (False, True))
    assert token_exact, "speculative decode diverged from greedy decode"

    st = results[(True, False)]["engine"].spec_stats()
    st_paged = results[(True, True)]["engine"].spec_stats()
    base_eng = results[(False, False)]["engine"]
    spec_eng = results[(True, False)]["engine"]
    # decode wall-clock: tokens per second of *serving-tick* time (the
    # engine's decode_wall_s — admission prefill excluded: identical
    # work in both configs and, on CPU, dominated by per-prompt-bucket
    # XLA compiles that drown the decode signal; the whole-run number
    # stays in the record as run_tokens_per_s).  With the span-clamped
    # verify forward (kernels/chunk_attention and the jnp ladder) a
    # verify tick emits ~k+1 tokens for well under (k+1)x a decode
    # step, so speculation now wins wall-clock, not just forward count.
    spec_tps = spec_eng.decode_tokens / max(spec_eng.decode_wall_s, 1e-9)
    base_tps = base_eng.decode_tokens / max(base_eng.decode_wall_s, 1e-9)

    # per-phase timing: one jitted verify forward (width k+1) and one
    # drafter proposal on the bench config — where a spec tick's time
    # actually goes
    import jax
    import jax.numpy as jnp

    from repro.models import model as model_lib
    from repro.runtime import draft as draft_lib
    cache = model_lib.init_cache(cfg, 4, SPEC_MAX_SEQ, dtype=jnp.float32)
    cache = dict(cache, pos=jnp.full((4,), 40, jnp.int32))
    w = SPEC_K + 1
    toks = jnp.full((4, w), 7, jnp.int32)
    lens = jnp.full((4,), w, jnp.int32)
    fwd_fn = jax.jit(lambda p, t, l, c: model_lib.prefill_chunk(
        p, t, l, c, cfg, all_logits=True)[0])
    verify_forward_s = _phase_time(fwd_fn, params, toks, lens, cache)
    dstate = draft_lib.DraftState(
        hist=jnp.full((4, 64), 7, jnp.int32),
        count=jnp.full((4,), 64, jnp.int32))
    draft_fn = jax.jit(lambda d, t: draft_lib.propose(d, t, SPEC_K))
    draft_s = _phase_time(draft_fn, dstate, jnp.full((4,), 7, jnp.int32))

    spec_record = {
        "spec_k": SPEC_K,
        "acceptance_rate": st["acceptance_rate"],
        "tokens_per_forward": st["tokens_per_forward"],
        "tokens_per_forward_paged": st_paged["tokens_per_forward"],
        "spec_decode_tokens_per_s": spec_tps,
        "baseline_decode_tokens_per_s": base_tps,
        "spec_run_tokens_per_s":
            spec_eng.decode_tokens / results[(True, False)]["dt"],
        "baseline_run_tokens_per_s":
            base_eng.decode_tokens / results[(False, False)]["dt"],
        "verify_forward_s": verify_forward_s,
        "draft_s": draft_s,
        "decode_forwards": int(spec_eng.device_ticks),
        "baseline_decode_forwards": int(base_eng.device_ticks),
        "forwards_reduction_x":
            base_eng.device_ticks / max(1, spec_eng.device_ticks),
        "host_sync_reduction_x":
            base_eng.host_syncs / max(1, spec_eng.host_syncs),
        "spec_token_exact": token_exact,
    }
    record = json.load(open(out_path))
    record["spec"] = spec_record
    with open(out_path, "w") as f:
        json.dump(record, f, indent=2)

    rows = [
        f"serve,spec_decode,tokens_per_forward,"
        f"{st['tokens_per_forward']:.2f},"
        f"acceptance={st['acceptance_rate']:.2f};"
        f"paged={st_paged['tokens_per_forward']:.2f}",
        f"serve,spec_decode,forwards_reduction,"
        f"{spec_record['forwards_reduction_x']:.2f}x,"
        f"spec={spec_record['decode_forwards']};"
        f"baseline={spec_record['baseline_decode_forwards']}",
        f"serve,spec_decode,decode_tokens_per_s,{spec_tps:.0f},"
        f"baseline={base_tps:.0f};"
        f"verify_forward_ms={verify_forward_s * 1e3:.2f};"
        f"draft_ms={draft_s * 1e3:.3f}",
    ]
    # acceptance floors: the drafter must actually multiply the decode
    # (> 1.3 tokens per slot-forward on this workload, both layouts,
    # proportionally fewer memory-bound decode forwards) and the
    # outputs must be bit-exact (asserted above).  Since PR 6 the
    # speculative path must also pay for itself in decode wall-clock —
    # the span-clamped verify forward makes a verify tick cheaper than
    # the k+1 decode steps it replaces.
    assert st["tokens_per_forward"] > 1.3, spec_record
    assert st_paged["tokens_per_forward"] > 1.3, spec_record
    assert spec_record["forwards_reduction_x"] > 1.3, spec_record
    assert spec_tps >= base_tps, spec_record
    return rows


# ---------------------------------------------------------------------------
# Preemptive over-commit: occupancy under KV pressure vs reserved admission
# ---------------------------------------------------------------------------

OC_N_SLOTS = 6
OC_BLOCKS = 14          # deliberately too small for every worst case:
#                         6 slots x up to 6 worst-case blocks >> 14
OC_BLOCK_SIZE = 8
OC_MAX_SEQ = 96


def _overcommit_requests(np, Request, n=16):
    """Medium prompts with real decode budgets: reserved admission can
    seat only a couple of worst cases at once, over-commit seats what
    the pool physically holds and claws back under pressure."""
    rng = np.random.default_rng(13)
    return [Request(i, rng.integers(1, 500, size=int(rng.integers(8, 20)),
                                    dtype=np.int64).astype(np.int32),
                    max_new=int(rng.integers(12, 24))) for i in range(n)]


def run_overcommit(out_path: str = None) -> list[str]:
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.configs import get_arch, reduced
    from repro.models import model as model_lib
    from repro.runtime.serve import Request, ServingEngine

    out_path = out_path or os.path.join(os.getcwd(), "BENCH_serve.json")
    cfg = reduced(get_arch("granite-3-2b"), n_layers=2, d_model=128,
                  vocab=512)
    params = model_lib.init(jax.random.PRNGKey(0), cfg, jnp.float32)

    def engine(overcommit: bool) -> ServingEngine:
        return ServingEngine(params, cfg, n_slots=OC_N_SLOTS,
                             max_seq=OC_MAX_SEQ, chunk=4, paged=True,
                             block_size=OC_BLOCK_SIZE, n_blocks=OC_BLOCKS,
                             chunked_prefill=True, prefill_chunk_tokens=8,
                             overcommit=overcommit)

    results = {}
    for overcommit in (False, True):
        eng = engine(overcommit)
        eng.run_to_completion([Request(99, np.arange(1, 9, dtype=np.int32),
                                       max_new=4)])            # warm
        eng.reset_stats()
        reqs = _overcommit_requests(np, Request)
        t0 = time.perf_counter()
        done, _ = eng.run_to_completion(reqs, max_ticks=50_000)
        dt = time.perf_counter() - t0
        assert len(done) == len(reqs)
        results[overcommit] = dict(
            engine=eng, dt=dt,
            tokens=sum(len(r.out) for r in done),
            outputs={r.rid: list(r.out) for r in done})

    eng_o = results[True]["engine"]
    eng_r = results[False]["engine"]
    occ = eng_o.occupancy_stats()
    occ_r = eng_r.occupancy_stats()
    # the exactness guarantee: eviction + recompute-based resume changed
    # no token vs the reserved (never-preempting) engine, and every
    # resume's replayed pending token matched what was delivered
    token_exact = results[True]["outputs"] == results[False]["outputs"] \
        and occ["preempt_replay_mismatches"] == 0
    assert token_exact, "preempted/resumed requests diverged"
    tps_o = results[True]["tokens"] / results[True]["dt"]
    tps_r = results[False]["tokens"] / results[False]["dt"]
    record = json.load(open(out_path))
    record["overcommit"] = {
        "n_slots": OC_N_SLOTS, "n_blocks": OC_BLOCKS,
        "block_size": OC_BLOCK_SIZE,
        "n_requests": len(results[True]["outputs"]),
        "occupancy": occ["occupancy"],
        "occupancy_reserved": occ_r["occupancy"],
        "preemptions": occ["preemptions"],
        "resumes": occ["resumes"],
        "preempted_tokens_recomputed": occ["preempted_tokens_recomputed"],
        "preempt_token_exact": token_exact,
        "tokens_per_s": tps_o,
        "reserved_tokens_per_s": tps_r,
        "throughput_vs_reserved_x": tps_o / tps_r,
        "stalls": int(eng_o.stalls),
    }
    with open(out_path, "w") as f:
        json.dump(record, f, indent=2)

    rows = [
        f"serve,overcommit,occupancy,{occ['occupancy']:.2f},"
        f"reserved={occ_r['occupancy']:.2f};"
        f"preemptions={occ['preemptions']};resumes={occ['resumes']}",
        f"serve,overcommit,tokens_per_s,{tps_o:.0f},"
        f"reserved={tps_r:.0f};"
        f"ratio={tps_o / tps_r:.2f}x;"
        f"recomputed={occ['preempted_tokens_recomputed']}",
    ]
    # acceptance floors: the pool really contended (>= 1 eviction), the
    # recompute replayed token-exactly, and over-commit admission ran
    # strictly more of the fleet than the worst-case reservation allowed
    assert occ["preemptions"] >= 1, record["overcommit"]
    assert occ["occupancy"] > occ_r["occupancy"], record["overcommit"]
    return rows


# ---------------------------------------------------------------------------
# Mesh scaling: fleet throughput vs device count + sharded token exactness
# ---------------------------------------------------------------------------
#
# Each device count runs in a SUBPROCESS: XLA reads
# ``--xla_force_host_platform_device_count`` once at import, so a fresh
# interpreter is the only way to vary it.  The child builds a
# FleetSupervisor of one replica per device, serves the same stream the
# single-engine oracle serves, and reports throughput + host syncs +
# token exactness; the 2-device child additionally runs a
# tensor-parallel (model=2) engine for the ``sharded_token_exact``
# acceptance bit.  Forced host devices share one physical CPU — the
# curve records the router's scaling behavior (per-replica jit caches,
# routing overhead, sync totals), not hardware speedup; on real
# accelerators the same code path is the one that scales.

SCALING_DEVICE_COUNTS = (1, 2, 4, 8)
SCALING_N_REQUESTS = 16


def _scaling_requests(np, Request, cfg, n=SCALING_N_REQUESTS):
    rng = np.random.default_rng(17)
    return [Request(i, rng.integers(1, cfg.vocab,
                                    size=int(rng.integers(6, 16)),
                                    dtype=np.int64).astype(np.int32),
                    max_new=int(rng.integers(8, 16))) for i in range(n)]


def _scaling_worker(n_devices: int) -> dict:
    """Child-process body (device count already forced via XLA_FLAGS)."""
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.configs import get_arch, reduced
    from repro.models import model as model_lib
    from repro.runtime.serve import Request, ServingEngine
    from repro.runtime.sharding import serve_mesh
    from repro.runtime.supervisor import FleetSupervisor

    assert jax.device_count() >= n_devices, (jax.device_count(), n_devices)
    cfg = reduced(get_arch("granite-3-2b"), n_layers=2, d_model=128,
                  vocab=512)
    params = model_lib.init(jax.random.PRNGKey(0), cfg, jnp.float32)
    kw = dict(n_slots=4, max_seq=96, chunk=8, paged=True, block_size=16,
              n_blocks=24)

    oracle = ServingEngine(params, cfg, **kw)
    done, _ = oracle.run_to_completion(_scaling_requests(np, Request, cfg))
    want = {r.rid: list(r.out) for r in done}

    fleet = FleetSupervisor(params, cfg, n_replicas=n_devices, model=1,
                            devices=jax.devices()[:n_devices], **kw)
    for eng in fleet.engines:       # warm each replica's jitted closures
        eng.run_to_completion([Request(99, np.arange(1, 9, dtype=np.int32),
                                       max_new=4)])
    fleet.reset_stats()
    reqs = _scaling_requests(np, Request, cfg)
    t0 = time.perf_counter()
    done, _ = fleet.run_to_completion(reqs)
    dt = time.perf_counter() - t0
    got = {r.rid: list(r.out) for r in done}
    sync = fleet.sync_stats()["fleet"]
    out = {
        "devices": n_devices,
        "tokens_per_s": sum(len(t) for t in got.values()) / dt,
        "wall_s": dt,
        "host_syncs": sync["host_syncs"],
        "device_ticks": sync["device_ticks"],
        "requests_per_replica": list(fleet.routed),
        "fleet_token_exact": got == want,
    }
    if n_devices == 2:
        # tensor-parallel exactness: heads + KV sharded over model=2,
        # same stream, must be bit-identical to the single-device oracle
        eng = ServingEngine(params, cfg, mesh=serve_mesh(2), **kw)
        done, _ = eng.run_to_completion(
            _scaling_requests(np, Request, cfg))
        ks = eng.kv_stats()
        out["sharded_token_exact"] = \
            {r.rid: list(r.out) for r in done} == want \
            and ks["model_shards"] == 2 and ks["kv_shard_fraction"] == 0.5
    return out


def run_scaling(out_path: str = None) -> list[str]:
    import subprocess
    import sys

    out_path = out_path or os.path.join(os.getcwd(), "BENCH_serve.json")
    points = []
    for d in SCALING_DEVICE_COUNTS:
        env = dict(
            os.environ,
            XLA_FLAGS=f"--xla_force_host_platform_device_count={d}")
        proc = subprocess.run(
            [sys.executable, os.path.abspath(__file__),
             "--scaling-worker", str(d)],
            env=env, capture_output=True, text=True, timeout=1200)
        if proc.returncode != 0:
            raise RuntimeError(
                f"scaling worker (devices={d}) failed:\n"
                f"{proc.stderr[-4000:]}")
        points.append(json.loads(proc.stdout.splitlines()[-1]))

    sharded_exact = next(p["sharded_token_exact"] for p in points
                         if "sharded_token_exact" in p)
    scaling = {
        "device_counts": [p["devices"] for p in points],
        "tokens_per_s": [p["tokens_per_s"] for p in points],
        "host_syncs": [p["host_syncs"] for p in points],
        "device_ticks": [p["device_ticks"] for p in points],
        "requests_per_replica": [p["requests_per_replica"] for p in points],
        "fleet_token_exact": all(p["fleet_token_exact"] for p in points),
        "note": "forced host devices share one physical CPU: the curve "
                "records the fleet router's behavior (balance, syncs, "
                "exactness), not hardware speedup",
    }
    record = json.load(open(out_path))
    record["scaling"] = scaling
    record["sharded_token_exact"] = sharded_exact
    with open(out_path, "w") as f:
        json.dump(record, f, indent=2)

    # the perf-trajectory file: one JSONL line per bench run, seeded with
    # the single-device baseline so device-count regressions have an
    # anchor to diff against
    traj_dir = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                            "artifacts")
    os.makedirs(traj_dir, exist_ok=True)
    with open(os.path.join(traj_dir, "serve_trajectory.jsonl"), "a") as f:
        f.write(json.dumps({
            "ts": time.time(),
            "suite": "serve_scaling",
            "single_device_tokens_per_s": points[0]["tokens_per_s"],
            "scaling": {k: scaling[k] for k in
                        ("device_counts", "tokens_per_s", "host_syncs")},
            "sharded_token_exact": sharded_exact,
        }) + "\n")

    rows = []
    for p in points:
        rows.append(f"serve,scaling,tokens_per_s@{p['devices']}dev,"
                    f"{p['tokens_per_s']:.0f},"
                    f"host_syncs={p['host_syncs']};"
                    f"routed={p['requests_per_replica']}")
    rows.append(f"serve,scaling,sharded_token_exact,{sharded_exact},"
                f"model_shards=2")
    # acceptance floors: every device count served the stream
    # byte-identically (fleet AND tensor-parallel), and the router used
    # every replica at each point
    assert scaling["fleet_token_exact"] is True, scaling
    assert sharded_exact is True, scaling
    for p in points:
        assert all(n > 0 for n in p["requests_per_replica"]), p
    return rows


# ---------------------------------------------------------------------------
# Chaos: fault injection, quarantine, and in-flight request migration
# ---------------------------------------------------------------------------
#
# A 2-replica fleet serves the same stream twice: once fault-free (the
# throughput baseline) and once with a seeded FaultPlan killing replica
# 0's tick mid-run.  The fleet quarantines the replica and migrates its
# in-flight requests to the survivor by replaying prompt +
# generated-so-far through chunked prefill — greedy determinism makes
# the replay token-exact, asserted against the unfaulted single-engine
# oracle.  ``fault_recovery`` records the cost of surviving: migrated
# request count, exactness, dead letters (must be zero — the fleet sheds
# throughput, never correctness), and throughput vs the fault-free run.

CHAOS_FAULT_KIND = "tick_exception"
CHAOS_FAULT_TICK = 4


def run_chaos(out_path: str = None) -> list[str]:
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.configs import get_arch, reduced
    from repro.models import model as model_lib
    from repro.runtime import faults
    from repro.runtime.serve import Request, ServingEngine
    from repro.runtime.supervisor import FleetSupervisor

    out_path = out_path or os.path.join(os.getcwd(), "BENCH_serve.json")
    cfg = reduced(get_arch("granite-3-2b"), n_layers=2, d_model=128,
                  vocab=512)
    params = model_lib.init(jax.random.PRNGKey(0), cfg, jnp.float32)
    kw = dict(n_slots=4, max_seq=96, chunk=8, paged=True, block_size=16,
              n_blocks=24, chunked_prefill=True, prefill_chunk_tokens=8)

    # the unfaulted single-replica oracle every survivor is held to
    oracle = ServingEngine(params, cfg, **kw)
    done, _ = oracle.run_to_completion(_scaling_requests(np, Request, cfg))
    want = {r.rid: list(r.out) for r in done}

    def fleet_run(plan):
        fleet = FleetSupervisor(params, cfg, n_replicas=2, model=1,
                                devices=jax.devices()[:1],
                                validate_outputs=True, **kw)
        for eng in fleet.engines:   # warm each replica's jitted closures
            eng.run_to_completion([Request(99,
                                           np.arange(1, 9, dtype=np.int32),
                                           max_new=4)])
            eng.reset_stats()
        if plan is not None:
            fleet.arm_faults(plan)
        reqs = _scaling_requests(np, Request, cfg)
        t0 = time.perf_counter()
        done, _ = fleet.run_to_completion(reqs, max_wall_s=600)
        dt = time.perf_counter() - t0
        got = {r.rid: list(r.out) for r in done}
        return got, sum(len(t) for t in got.values()) / dt, fleet

    got0, tps0, _ = fleet_run(None)
    assert got0 == want, "fault-free fleet diverged from the oracle"

    plan = faults.FaultPlan([faults.FaultEvent(
        kind=CHAOS_FAULT_KIND, tick=CHAOS_FAULT_TICK, replica=0)])
    got_f, tps_f, fleet = fleet_run(plan)
    fh = fleet.fleet_health()

    fault_recovery = {
        "fault_kind": CHAOS_FAULT_KIND,
        "fault_tick": CHAOS_FAULT_TICK,
        "requests_migrated": fh["migrations"],
        "migrated_token_exact": got_f == want,
        "migrate_replay_mismatches": fh["migrate_replay_mismatches"],
        "dead_letter": len(fh["dead_letters"]),
        "replicas_quarantined": len(fleet.engines) - fh["healthy"],
        "tokens_per_s": tps_f,
        "fault_free_tokens_per_s": tps0,
        "recovery_overhead_x": tps0 / tps_f,
    }
    record = json.load(open(out_path))
    record["fault_recovery"] = fault_recovery
    with open(out_path, "w") as f:
        json.dump(record, f, indent=2)

    rows = [
        f"serve,fault_recovery,requests_migrated,"
        f"{fault_recovery['requests_migrated']},"
        f"token_exact={fault_recovery['migrated_token_exact']};"
        f"dead_letter={fault_recovery['dead_letter']}",
        f"serve,fault_recovery,tokens_per_s,{tps_f:.0f},"
        f"fault_free={tps0:.0f};"
        f"overhead={fault_recovery['recovery_overhead_x']:.2f}x;"
        f"quarantined={fault_recovery['replicas_quarantined']}",
    ]
    # acceptance floors: work really migrated, every survivor bit-exact
    # vs the unfaulted oracle, and nothing was dead-lettered — losing a
    # replica mid-run costs throughput, never tokens
    assert fault_recovery["requests_migrated"] >= 1, fault_recovery
    assert fault_recovery["migrated_token_exact"] is True, fault_recovery
    assert fault_recovery["migrate_replay_mismatches"] == 0, fault_recovery
    assert fault_recovery["dead_letter"] == 0, fault_recovery
    return rows


# ---------------------------------------------------------------------------
# Priority/SLA tiers: per-tier p99 TTFT under a bursty open-loop trace
# ---------------------------------------------------------------------------

SLA_N_SLOTS = 4
SLA_BURSTS = (0, 2, 4, 6)        # step indices of the throughput bursts
SLA_BURST_SIZE = 8
SLA_LATENCY_ARRIVALS = (3, 7, 11, 15, 19, 23)


def _sla_trace(np, Request):
    """The bursty open-loop arrival trace: (step, request) pairs.
    Throughput bursts land early and saturate the slots; latency-tier
    requests arrive mid-run, one at a time, and must displace."""
    rng = np.random.default_rng(23)

    def prompt():
        return rng.integers(1, 500, size=int(rng.integers(8, 16)),
                            dtype=np.int64).astype(np.int32)

    arrivals, rid = [], 0
    for step in SLA_BURSTS:
        for _ in range(SLA_BURST_SIZE):
            # batch-class requests carry real decode budgets: the queue
            # the latency tier gets to jump is what the bench measures
            arrivals.append((step, Request(
                rid, prompt(), max_new=int(rng.integers(16, 28)),
                tier="throughput")))
            rid += 1
    for step in SLA_LATENCY_ARRIVALS:
        arrivals.append((step, Request(
            rid, prompt(), max_new=int(rng.integers(8, 16)),
            tier="latency")))
        rid += 1
    return arrivals


def _drive_sla_trace(eng, arrivals, max_steps=50_000):
    """Open-loop drive: submit at step indices, poll completions."""
    out, steps = {}, 0
    pending = sorted(arrivals, key=lambda kv: (kv[0], kv[1].rid))
    while pending or eng.has_work:
        while pending and pending[0][0] <= steps:
            eng.submit(pending.pop(0)[1])
        eng.step()
        for req in eng.poll():
            assert req.rid not in out, f"rid {req.rid} delivered twice"
            out[req.rid] = list(req.out)
        steps += 1
        assert steps < max_steps, "SLA trace did not converge"
    return out


def run_sla(out_path: str = None) -> list[str]:
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.configs import get_arch, reduced
    from repro.models import model as model_lib
    from repro.runtime.accounting import TierAccounting
    from repro.runtime.serve import Request, ServingEngine

    out_path = out_path or os.path.join(os.getcwd(), "BENCH_serve.json")
    cfg = reduced(get_arch("granite-3-2b"), n_layers=2, d_model=128,
                  vocab=512)
    params = model_lib.init(jax.random.PRNGKey(0), cfg, jnp.float32)

    layouts = {
        "contiguous": dict(),
        "paged": dict(paged=True, block_size=16, n_blocks=24,
                      overcommit=True),
    }
    sla: dict = {
        "trace": {
            "n_throughput": len(SLA_BURSTS) * SLA_BURST_SIZE,
            "n_latency": len(SLA_LATENCY_ARRIVALS),
            "burst_steps": list(SLA_BURSTS),
            "latency_arrival_steps": list(SLA_LATENCY_ARRIVALS),
        },
    }
    rows: list[str] = []
    for layout, extra in layouts.items():
        eng = ServingEngine(params, cfg, n_slots=SLA_N_SLOTS, max_seq=96,
                            chunk=4, chunked_prefill=True,
                            prefill_chunk_tokens=8, **extra)
        # warmup in two passes so TTFT measures scheduling, not XLA:
        # the untiered closed-loop run is the exactness oracle, and one
        # throwaway tiered pass compiles the displacement-path tick
        # shapes the oracle never reaches
        oracle_reqs = [Request(r.rid, r.prompt, max_new=r.max_new)
                       for _, r in _sla_trace(np, Request)]
        done, _ = eng.run_to_completion(oracle_reqs, max_ticks=50_000)
        want = {r.rid: list(r.out) for r in done}
        warm = _drive_sla_trace(eng, _sla_trace(np, Request))
        assert warm == want, f"{layout}: tiered warmup diverged"
        eng.reset_stats()
        eng.sla = TierAccounting()

        got = _drive_sla_trace(eng, _sla_trace(np, Request))
        token_exact = got == want
        assert token_exact, f"{layout}: tiered run diverged from oracle"
        rep = eng.sla.report()
        lat, thr = rep["latency"], rep["throughput"]
        assert eng.displacements >= 1, (layout, eng.displacements)
        assert lat["finished"] == len(SLA_LATENCY_ARRIVALS)
        # the point of the tier: arrivals that displace instead of
        # queueing see a fraction of the backlogged tier's p99 TTFT
        assert lat["ttft_p99"] < 0.5 * thr["ttft_p99"], (layout, rep)
        sla[layout] = {
            "latency": lat,
            "throughput": thr,
            "tier_token_exact": token_exact,
            "displacements": int(eng.displacements),
            "preempt_replay_mismatches":
                int(eng.preempt_replay_mismatches),
            "ttft_p99_vs_throughput_x": lat["ttft_p99"] / thr["ttft_p99"],
        }
        rows.append(
            f"serve,sla,{layout}_ttft_p99_s,{lat['ttft_p99']:.3f},"
            f"throughput_tier={thr['ttft_p99']:.3f};"
            f"ratio={lat['ttft_p99'] / thr['ttft_p99']:.2f}x;"
            f"displacements={eng.displacements};"
            f"token_exact={token_exact}")

    record = json.load(open(out_path))
    record["sla"] = sla
    with open(out_path, "w") as f:
        json.dump(record, f, indent=2)
    return rows


def run() -> list[str]:
    return run_serve() + run_latency() + run_spec() + run_overcommit() \
        + run_scaling() + run_chaos() + run_sla()


if __name__ == "__main__":
    if "--scaling-worker" in sys.argv:
        sys.path.insert(0, os.path.join(
            os.path.dirname(os.path.abspath(__file__)), "..", "src"))
        d = int(sys.argv[sys.argv.index("--scaling-worker") + 1])
        print(json.dumps(_scaling_worker(d)))
    else:
        print("\n".join(run()))
