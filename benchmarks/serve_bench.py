"""Serving benchmark: device-resident continuous batching economics.

Measures the refactored engine on CPU-sized configs and writes
``BENCH_serve.json`` so the perf trajectory keeps recording:

* ``tokens_per_s`` — end-to-end greedy decode throughput,
* ``device_ticks`` — decode iterations executed on device,
* ``host_syncs_per_100_tokens`` — actual blocking host round-trips,
* ``baseline_syncs_per_100_tokens`` — what the pre-refactor engine paid
  (one ``int(jnp.argmax(...))`` per slot per tick + one per admission),
  measured in the *same run* from the same token stream,
* ``sync_reduction_x`` — the ratio (acceptance floor: ≥ 5×),
* ``kv`` — paged-vs-contiguous KV economics from the same request
  stream: allocated KV bytes per admitted token under each layout and
  the reduction ratio (acceptance floor: paged strictly smaller), plus
  shared-prefix block hits and peak block usage.
"""
import json
import os
import time


def _requests(cfg, np, Request, n=16):
    rng = np.random.default_rng(0)
    shared = rng.integers(1, cfg.vocab, size=16,
                          dtype=np.int64).astype(np.int32)
    reqs = []
    for i in range(n):
        if i % 2 == 0:   # half the stream shares a 16-token (1-block) prefix
            tail = rng.integers(1, cfg.vocab, size=int(rng.integers(2, 8)),
                                dtype=np.int64).astype(np.int32)
            prompt = np.concatenate([shared, tail])
        else:
            prompt = rng.integers(1, cfg.vocab,
                                  size=int(rng.integers(4, 16)),
                                  dtype=np.int64).astype(np.int32)
        reqs.append(Request(i, prompt, max_new=int(rng.integers(6, 20))))
    return reqs


def run_serve(out_path: str = None) -> list[str]:
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.configs import get_arch, reduced
    from repro.models import model as model_lib
    from repro.runtime.serve import Request, ServingEngine

    out_path = out_path or os.path.join(os.getcwd(), "BENCH_serve.json")
    cfg = reduced(get_arch("granite-3-2b"), n_layers=2, d_model=128,
                  vocab=512)
    params = model_lib.init(jax.random.PRNGKey(0), cfg, jnp.float32)
    chunk = 8

    def engine(paged: bool) -> ServingEngine:
        kw = dict(paged=True, block_size=16, n_blocks=20) if paged else {}
        return ServingEngine(params, cfg, n_slots=4, max_seq=96,
                             chunk=chunk, **kw)

    results = {}
    for paged in (False, True):
        eng = engine(paged)
        # warmup on the SAME engine (each engine owns its jitted
        # closures), then reset the counters for a clean measurement
        eng.run_to_completion([Request(99, np.arange(1, 9, dtype=np.int32),
                                       max_new=4)])
        eng.reset_stats()
        reqs = _requests(cfg, np, Request)
        t0 = time.perf_counter()
        done, ticks = eng.run_to_completion(reqs)
        dt = time.perf_counter() - t0
        assert len(done) == len(reqs)
        results[eng.kv_stats()["layout"]] = dict(
            engine=eng, done=done, ticks=ticks, dt=dt,
            outputs={r.rid: list(r.out) for r in done})
    # paged decode is token-exact vs the contiguous cache (same stream)
    token_exact = results["paged"]["outputs"] == results["contiguous"]["outputs"]
    assert token_exact, "paged decode diverged from the contiguous cache"

    eng = results["contiguous"]["engine"]
    dt, ticks = results["contiguous"]["dt"], results["contiguous"]["ticks"]
    total_tokens = sum(len(r.out) for r in results["contiguous"]["done"])
    stats = eng.sync_stats()
    kv_c = eng.kv_stats()
    kv_p = results["paged"]["engine"].kv_stats()
    kv_reduction = kv_c["kv_bytes_per_token"] / kv_p["kv_bytes_per_token"]
    record = {
        "suite": "serve",
        "config": {"arch": cfg.name, "n_slots": 4, "chunk": chunk,
                   "n_requests": len(results["contiguous"]["done"]),
                   "max_seq": 96, "block_size": 16, "n_blocks": 20},
        "tokens_per_s": total_tokens / dt,
        "total_tokens": total_tokens,
        "device_ticks": ticks,
        "wall_s": dt,
        **stats,
        "kv": {
            "contiguous_bytes_per_token": kv_c["kv_bytes_per_token"],
            "paged_bytes_per_token": kv_p["kv_bytes_per_token"],
            "kv_bytes_reduction_x": kv_reduction,
            "paged_token_exact": token_exact,
            "shared_block_hits": kv_p["shared_block_hits"],
            "peak_blocks": kv_p["peak_blocks"],
            "stalls": kv_p["stalls"],
        },
    }
    with open(out_path, "w") as f:
        json.dump(record, f, indent=2)

    rows = ["serve.header,name,metric,value,derived"]
    rows.append(f"serve,continuous_batching,tokens_per_s,"
                f"{record['tokens_per_s']:.0f},ticks={ticks}")
    rows.append(f"serve,host_sync_economy,syncs_per_100_tokens,"
                f"{stats['host_syncs_per_100_tokens']:.2f},"
                f"baseline={stats['baseline_syncs_per_100_tokens']:.2f};"
                f"reduction={stats['sync_reduction_x']:.1f}x")
    rows.append(f"serve,paged_kv_economy,kv_bytes_per_token,"
                f"{kv_p['kv_bytes_per_token']:.0f},"
                f"contiguous={kv_c['kv_bytes_per_token']:.0f};"
                f"reduction={kv_reduction:.2f}x;"
                f"shared_hits={kv_p['shared_block_hits']}")
    rows.append(f"serve,artifact,path,{out_path},")
    # acceptance floors: ≥ 5× fewer host syncs than per-slot-per-tick;
    # paged KV bytes per token strictly below contiguous, with no stalls
    assert stats["sync_reduction_x"] >= 5.0, stats
    assert kv_reduction > 1.0, record["kv"]
    assert kv_p["stalls"] == 0, record["kv"]
    return rows


def run() -> list[str]:
    return run_serve()


if __name__ == "__main__":
    print("\n".join(run()))
