"""Perf hillclimbing harness: re-lower one cell with knobs, print terms.

Usage:
  PYTHONPATH=src python -m benchmarks.hillclimb --arch qwen3-moe-30b-a3b \
      --shape train_4k [--microbatch N] [--gather-once] [--top 10]

Each invocation is one hypothesis→change→measure cycle of EXPERIMENTS.md
§Perf: it prints the three roofline terms and the top collective
contributors (op, per-device bytes, trip multiplier) so the next
hypothesis is grounded in the lowered program, not guesswork.
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse
import json
import time

import jax

from repro.configs import SHAPES, get_arch
from repro.launch.mesh import make_production_mesh
from repro.runtime.accounting import hlo_collectives, jaxpr_cost
from repro.runtime.supervisor import ClusterSupervisor

PEAK, HBM, LINK = 197e12, 819e9, 50e9


def measure(arch, shape_name, *, multi_pod=False, top=10, **sup_kwargs):
    cfg = get_arch(arch)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    sup = ClusterSupervisor(mesh, cfg, shape, **sup_kwargs)
    plan = sup.plan()
    t0 = time.time()
    with mesh:
        lowered = jax.jit(plan.step_fn, in_shardings=plan.in_shardings,
                          out_shardings=plan.out_shardings,
                          donate_argnums=plan.donate_argnums) \
            .lower(*plan.abstract_args)
        compiled = lowered.compile()
        jcost = jaxpr_cost(plan.step_fn, *plan.abstract_args)
    coll = hlo_collectives(compiled.as_text())
    try:
        mem = compiled.memory_analysis()
        temp = int(mem.temp_size_in_bytes)
        args_b = int(mem.argument_size_in_bytes)
    except Exception:
        temp, args_b = -1, -1
    chips = mesh.devices.size
    terms = {
        "compute": jcost["flops"] / (chips * PEAK),
        "memory": jcost["bytes"] / (chips * HBM),
        "collective": coll["total_bytes"] / LINK,
    }
    dom = max(terms, key=terms.get)
    out = {
        "arch": arch, "shape": shape_name, "knobs": sup_kwargs,
        "terms": terms, "dominant": dom,
        "bound_s": max(terms.values()),
        "roofline_fraction": terms["compute"] / max(terms.values()),
        "flops": jcost["flops"], "coll_bytes": coll["total_bytes"],
        "mem_temp": temp, "mem_args": args_b,
        "compile_s": round(time.time() - t0, 1),
        "top_collectives": coll["top"][:top],
        "microbatches": sup.n_microbatch,
    }
    return out


def pretty(r):
    t = r["terms"]
    print(f"== {r['arch']} × {r['shape']}  knobs={r['knobs']} "
          f"(mb={r['microbatches']}) ==")
    print(f"  compute {t['compute']:9.3f}s | memory {t['memory']:9.3f}s | "
          f"collective {t['collective']:9.3f}s  -> dominant: {r['dominant']}"
          f"  roofline_frac={r['roofline_fraction']:.3f}")
    print(f"  temp/dev {r['mem_temp'] / 1e9:.2f} GB, args/dev "
          f"{r['mem_args'] / 1e9:.2f} GB, compile {r['compile_s']}s")
    for e in r["top_collectives"]:
        print(f"    {e['op']:<20} ×{e['mult']:<8.0f} "
              f"{e['bytes'] / 1e9:8.2f} GB  {e['shape']}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--microbatch", type=int, default=None)
    ap.add_argument("--gather-once", action="store_true")
    ap.add_argument("--remat",
                    choices=["full", "none", "moe_save", "block_save"],
                    default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--top", type=int, default=10)
    ap.add_argument("--json-out", default=None)
    args = ap.parse_args()

    kw = {}
    if args.microbatch is not None:
        kw["n_microbatch"] = args.microbatch
    if args.gather_once:
        kw["gather_once"] = True
    if args.remat is not None:
        kw["remat"] = {"full": True, "none": False, "moe_save": "moe_save",
                       "block_save": "block_save"}[args.remat]
    r = measure(args.arch, args.shape, multi_pod=args.multi_pod,
                top=args.top, **kw)
    pretty(r)
    if args.json_out:
        with open(args.json_out, "a") as f:
            f.write(json.dumps(r) + "\n")


if __name__ == "__main__":
    main()
