"""Kernel benches: interpret-mode timing + analytic intensity per kernel.

Wall time in interpret mode is a CPU emulation number (the TPU target is
validated structurally) — the derived column is the kernel's arithmetic
intensity (FLOPs/byte) against the v5e ridge point (197e12/819e9 ≈ 240),
which says whether the kernel is compute- or bandwidth-bound at spec.
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

RIDGE = 197e12 / 819e9


def _time(fn, *args, reps=3):
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else \
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / reps * 1e6


def run() -> list[str]:
    from repro.kernels.flash_attention import flash_attention
    from repro.kernels.massmap import massmap
    from repro.kernels.ssd_scan import ssd_chunked_kernel
    from repro.kernels.sumup import sumup

    rows = ["kernels.header,name,shape,us_per_call_interp,flops,bytes,"
            "intensity,bound_at_spec"]
    key = jax.random.PRNGKey(0)

    # sumup: N floats -> 1; intensity ~ 1/4 (stream-bound by design)
    x = jax.random.normal(key, (8, 8192), jnp.float32)
    us = _time(sumup, x)
    fl, by = 8 * 8192, 8 * 8192 * 4
    rows.append(f"kernels,sumup,(8×8192),{us:.0f},{fl},{by},"
                f"{fl / by:.3f},{'memory' if fl / by < RIDGE else 'compute'}")

    # massmap: fused scale-bias-act
    x = jax.random.normal(key, (256, 1024), jnp.float32)
    sc = jnp.ones((1024,))
    bi = jnp.zeros((1024,))
    us = _time(massmap, x, sc, bi)
    fl, by = 4 * 256 * 1024, 2 * 256 * 1024 * 4
    rows.append(f"kernels,massmap,(256×1024),{us:.0f},{fl},{by},"
                f"{fl / by:.3f},{'memory' if fl / by < RIDGE else 'compute'}")

    # flash attention: causal S=512 D=64
    b, h, s, d = 1, 4, 512, 64
    q = jax.random.normal(key, (b, h, s, d), jnp.bfloat16)
    k = jax.random.normal(key, (b, h, s, d), jnp.bfloat16)
    v = jax.random.normal(key, (b, h, s, d), jnp.bfloat16)
    us = _time(flash_attention, q, k, v)
    fl = 4 * b * h * s * s * d / 2
    by = 4 * b * h * s * d * 2
    rows.append(f"kernels,flash_attention,(1×4×512×64),{us:.0f},{fl:.0f},"
                f"{by},{fl / by:.1f},"
                f"{'memory' if fl / by < RIDGE else 'compute'}")

    # ssd_scan: chunked SSD
    bs, s, hh, p, n, g = 1, 256, 4, 64, 32, 1
    ks = jax.random.split(key, 6)
    xx = jax.random.normal(ks[0], (bs, s, hh, p), jnp.float32)
    dt = jax.random.normal(ks[1], (bs, s, hh)) * 0.3
    a_log = jax.random.normal(ks[2], (hh,)) * 0.3
    bm = jax.random.normal(ks[3], (bs, s, g, n)) * 0.5
    cm = jax.random.normal(ks[4], (bs, s, g, n)) * 0.5
    dsk = jax.random.normal(ks[5], (hh,))
    dtb = jnp.zeros((hh,))
    us = _time(lambda *a: ssd_chunked_kernel(*a, chunk=64),
               xx, dt, a_log, bm, cm, dsk, dtb)
    q_ = 64
    fl = 2 * bs * hh * s * q_ * (n + p) + 4 * bs * s * hh * p * n
    by = bs * s * hh * (p + 2 * n) * 4 * 2
    rows.append(f"kernels,ssd_scan,(1×256×4×64),{us:.0f},{fl:.0f},{by},"
                f"{fl / by:.1f},{'memory' if fl / by < RIDGE else 'compute'}")
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
