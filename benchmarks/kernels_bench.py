"""Kernel benches: interpret-mode timing + analytic intensity per kernel.

Wall time in interpret mode is a CPU emulation number (the TPU target is
validated structurally) — the derived column is the kernel's arithmetic
intensity (FLOPs/byte) against the v5e ridge point (197e12/819e9 ≈ 240),
which says whether the kernel is compute- or bandwidth-bound at spec.
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

RIDGE = 197e12 / 819e9


def _time(fn, *args, reps=3):
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else \
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / reps * 1e6


def run() -> list[str]:
    from repro.kernels.flash_attention import flash_attention
    from repro.kernels.massmap import massmap
    from repro.kernels.paged_attention import (paged_attention,
                                               paged_attention_ref)
    from repro.kernels.ssd_scan import ssd_chunked_kernel
    from repro.kernels.sumup import sumup

    rows = ["kernels.header,name,shape,us_per_call_interp,flops,bytes,"
            "intensity,bound_at_spec"]
    key = jax.random.PRNGKey(0)

    # paged attention: block-table decode (PR 2's kernel) vs the ref.py
    # oracle — GQA 4:1, 16-position blocks, random disjoint chains
    b, h, hkv, d, n_pages, bs, nb = 4, 8, 2, 64, 32, 16, 4
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (b, h, d), jnp.float32)
    kp = jax.random.normal(ks[1], (n_pages, bs, hkv, d), jnp.float32)
    vp = jax.random.normal(ks[2], (n_pages, bs, hkv, d), jnp.float32)
    rng = np.random.default_rng(0)
    lengths = jnp.asarray(rng.integers(bs, nb * bs + 1, size=b), jnp.int32)
    tables = np.full((b, nb), -1, np.int32)
    perm = rng.permutation(n_pages)
    i = 0
    for r in range(b):
        for j in range(-(-int(lengths[r]) // bs)):
            tables[r, j] = perm[i]
            i += 1
    tables = jnp.asarray(tables)
    got = paged_attention(q, kp, vp, tables, lengths)
    want = paged_attention_ref(q, kp, vp, tables, lengths)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)
    us = _time(paged_attention, q, kp, vp, tables, lengths)
    skv = int(jnp.sum(lengths))
    fl = 4.0 * h * d * skv                      # QK^T + PV over the chains
    by = 2.0 * skv * hkv * d * 4                # the K/V pages streamed in
    rows.append(f"kernels,paged_attention,({b}x{h}x{d};bs={bs}),{us:.0f},"
                f"{fl:.0f},{by:.0f},{fl / by:.2f},"
                f"{'memory' if fl / by < RIDGE else 'compute'}")

    # chunk attention: the shape-dispatched fragment kernels (PR 6) vs
    # the ref.py oracle.  Two problem shapes, two schedules (the
    # charm_u50 mm_large/mm_small split): a wide prefill fragment and
    # the narrow speculative verify fragment (n_slots, k+1).  FLOPs /
    # bytes count the *clamped* KV span — the rows quantify what the
    # clamp saves vs masking the whole max_seq cache.
    from repro.kernels.chunk_attention import (
        chunk_attention_kernel, chunk_attention_ref,
        paged_chunk_attention_kernel, paged_chunk_attention_ref)
    from repro.models.attention import attention_flops, span_ladder

    def _chunk_rows(name, c, b, h, hkv, d, smax, pos0_max):
        ks = jax.random.split(jax.random.PRNGKey(c), 3)
        q = jax.random.normal(ks[0], (b, c, h, d), jnp.float32)
        kc = jax.random.normal(ks[1], (b, smax, hkv, d), jnp.float32)
        vc = jax.random.normal(ks[2], (b, smax, hkv, d), jnp.float32)
        rng_ = np.random.default_rng(c)
        pos0 = jnp.asarray(rng_.integers(0, pos0_max + 1, size=b),
                           jnp.int32)
        q_pos = pos0[:, None] + jnp.arange(c)
        got = chunk_attention_kernel(q, kc, vc, q_pos)
        want = chunk_attention_ref(q, kc, vc, q_pos)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-5, atol=2e-5)
        us = _time(chunk_attention_kernel, q, kc, vc, q_pos)
        spans = span_ladder(smax)
        lim = int(jnp.max(q_pos)) + 1
        att = next(s for s in spans if s >= lim)
        fl = attention_flops(b, c, smax, h, d, False, attended=att)
        by = 2.0 * b * att * hkv * d * 4            # clamped K/V stream
        rows.append(f"kernels,{name},({b}x{c}x{h}x{d};s={smax};"
                    f"att={att}),{us:.0f},{fl:.0f},{by:.0f},"
                    f"{fl / by:.2f},"
                    f"{'memory' if fl / by < RIDGE else 'compute'}")

    # wide: a scheduler-chunk prefill fragment mid-sequence
    _chunk_rows("chunk_attention_wide", 16, 4, 8, 2, 64, 256, 96)
    # narrow: the spec verify shape (n_slots=4, k+1=5) over a long cache
    _chunk_rows("chunk_attention_narrow", 5, 4, 8, 2, 64, 256, 48)

    # paged twin on the narrow shape: block-table DMAs, same clamp
    b, c, h, hkv, d, bs_, nb = 4, 5, 8, 2, 64, 16, 8
    n_pages = b * nb + 2
    ks = jax.random.split(jax.random.PRNGKey(9), 3)
    q = jax.random.normal(ks[0], (b, c, h, d), jnp.float32)
    kp = jax.random.normal(ks[1], (n_pages, bs_, hkv, d), jnp.float32)
    vp = jax.random.normal(ks[2], (n_pages, bs_, hkv, d), jnp.float32)
    rng = np.random.default_rng(9)
    pos0 = rng.integers(8, 48, size=b)
    tables = np.full((b, nb), -1, np.int32)
    perm = rng.permutation(n_pages)
    i = 0
    for r in range(b):
        for j in range(-(-int(pos0[r] + c) // bs_)):
            tables[r, j] = perm[i]
            i += 1
    tables = jnp.asarray(tables)
    q_pos = jnp.asarray(pos0, jnp.int32)[:, None] + jnp.arange(c)
    got = paged_chunk_attention_kernel(q, kp, vp, tables, q_pos)
    want = paged_chunk_attention_ref(q, kp, vp, tables, q_pos)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)
    us = _time(paged_chunk_attention_kernel, q, kp, vp, tables, q_pos)
    lim = int(jnp.max(q_pos)) + 1
    att = min(-(-lim // bs_) * bs_, nb * bs_)       # blocks touched
    fl = attention_flops(b, c, nb * bs_, h, d, False, attended=att)
    by = 2.0 * b * att * hkv * d * 4
    rows.append(f"kernels,paged_chunk_attention,({b}x{c}x{h}x{d};"
                f"bs={bs_};att={att}),{us:.0f},{fl:.0f},{by:.0f},"
                f"{fl / by:.2f},"
                f"{'memory' if fl / by < RIDGE else 'compute'}")

    # sumup: N floats -> 1; intensity ~ 1/4 (stream-bound by design)
    x = jax.random.normal(key, (8, 8192), jnp.float32)
    us = _time(sumup, x)
    fl, by = 8 * 8192, 8 * 8192 * 4
    rows.append(f"kernels,sumup,(8×8192),{us:.0f},{fl},{by},"
                f"{fl / by:.3f},{'memory' if fl / by < RIDGE else 'compute'}")

    # massmap: fused scale-bias-act
    x = jax.random.normal(key, (256, 1024), jnp.float32)
    sc = jnp.ones((1024,))
    bi = jnp.zeros((1024,))
    us = _time(massmap, x, sc, bi)
    fl, by = 4 * 256 * 1024, 2 * 256 * 1024 * 4
    rows.append(f"kernels,massmap,(256×1024),{us:.0f},{fl},{by},"
                f"{fl / by:.3f},{'memory' if fl / by < RIDGE else 'compute'}")

    # flash attention: causal S=512 D=64
    b, h, s, d = 1, 4, 512, 64
    q = jax.random.normal(key, (b, h, s, d), jnp.bfloat16)
    k = jax.random.normal(key, (b, h, s, d), jnp.bfloat16)
    v = jax.random.normal(key, (b, h, s, d), jnp.bfloat16)
    us = _time(flash_attention, q, k, v)
    fl = 4 * b * h * s * s * d / 2
    by = 4 * b * h * s * d * 2
    rows.append(f"kernels,flash_attention,(1×4×512×64),{us:.0f},{fl:.0f},"
                f"{by},{fl / by:.1f},"
                f"{'memory' if fl / by < RIDGE else 'compute'}")

    # ssd_scan: chunked SSD
    bs, s, hh, p, n, g = 1, 256, 4, 64, 32, 1
    ks = jax.random.split(key, 6)
    xx = jax.random.normal(ks[0], (bs, s, hh, p), jnp.float32)
    dt = jax.random.normal(ks[1], (bs, s, hh)) * 0.3
    a_log = jax.random.normal(ks[2], (hh,)) * 0.3
    bm = jax.random.normal(ks[3], (bs, s, g, n)) * 0.5
    cm = jax.random.normal(ks[4], (bs, s, g, n)) * 0.5
    dsk = jax.random.normal(ks[5], (hh,))
    dtb = jnp.zeros((hh,))
    us = _time(lambda *a: ssd_chunked_kernel(*a, chunk=64),
               xx, dt, a_log, bm, cm, dsk, dtb)
    q_ = 64
    fl = 2 * bs * hh * s * q_ * (n + p) + 4 * bs * s * hh * p * n
    by = bs * s * hh * (p + 2 * n) * 4 * 2
    rows.append(f"kernels,ssd_scan,(1×256×4×64),{us:.0f},{fl:.0f},{by},"
                f"{fl / by:.1f},{'memory' if fl / by < RIDGE else 'compute'}")
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
