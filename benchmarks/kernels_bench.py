"""Kernel benches: interpret-mode timing + analytic intensity per kernel.

Wall time in interpret mode is a CPU emulation number (the TPU target is
validated structurally) — the derived column is the kernel's arithmetic
intensity (FLOPs/byte) against the v5e ridge point (197e12/819e9 ≈ 240),
which says whether the kernel is compute- or bandwidth-bound at spec.
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

RIDGE = 197e12 / 819e9


def _time(fn, *args, reps=3):
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else \
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / reps * 1e6


def run() -> list[str]:
    from repro.kernels.flash_attention import flash_attention
    from repro.kernels.massmap import massmap
    from repro.kernels.paged_attention import (paged_attention,
                                               paged_attention_ref)
    from repro.kernels.ssd_scan import ssd_chunked_kernel
    from repro.kernels.sumup import sumup

    rows = ["kernels.header,name,shape,us_per_call_interp,flops,bytes,"
            "intensity,bound_at_spec"]
    key = jax.random.PRNGKey(0)

    # paged attention: block-table decode (PR 2's kernel) vs the ref.py
    # oracle — GQA 4:1, 16-position blocks, random disjoint chains
    b, h, hkv, d, n_pages, bs, nb = 4, 8, 2, 64, 32, 16, 4
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (b, h, d), jnp.float32)
    kp = jax.random.normal(ks[1], (n_pages, bs, hkv, d), jnp.float32)
    vp = jax.random.normal(ks[2], (n_pages, bs, hkv, d), jnp.float32)
    rng = np.random.default_rng(0)
    lengths = jnp.asarray(rng.integers(bs, nb * bs + 1, size=b), jnp.int32)
    tables = np.full((b, nb), -1, np.int32)
    perm = rng.permutation(n_pages)
    i = 0
    for r in range(b):
        for j in range(-(-int(lengths[r]) // bs)):
            tables[r, j] = perm[i]
            i += 1
    tables = jnp.asarray(tables)
    got = paged_attention(q, kp, vp, tables, lengths)
    want = paged_attention_ref(q, kp, vp, tables, lengths)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)
    us = _time(paged_attention, q, kp, vp, tables, lengths)
    skv = int(jnp.sum(lengths))
    fl = 4.0 * h * d * skv                      # QK^T + PV over the chains
    by = 2.0 * skv * hkv * d * 4                # the K/V pages streamed in
    rows.append(f"kernels,paged_attention,({b}x{h}x{d};bs={bs}),{us:.0f},"
                f"{fl:.0f},{by:.0f},{fl / by:.2f},"
                f"{'memory' if fl / by < RIDGE else 'compute'}")

    # sumup: N floats -> 1; intensity ~ 1/4 (stream-bound by design)
    x = jax.random.normal(key, (8, 8192), jnp.float32)
    us = _time(sumup, x)
    fl, by = 8 * 8192, 8 * 8192 * 4
    rows.append(f"kernels,sumup,(8×8192),{us:.0f},{fl},{by},"
                f"{fl / by:.3f},{'memory' if fl / by < RIDGE else 'compute'}")

    # massmap: fused scale-bias-act
    x = jax.random.normal(key, (256, 1024), jnp.float32)
    sc = jnp.ones((1024,))
    bi = jnp.zeros((1024,))
    us = _time(massmap, x, sc, bi)
    fl, by = 4 * 256 * 1024, 2 * 256 * 1024 * 4
    rows.append(f"kernels,massmap,(256×1024),{us:.0f},{fl},{by},"
                f"{fl / by:.3f},{'memory' if fl / by < RIDGE else 'compute'}")

    # flash attention: causal S=512 D=64
    b, h, s, d = 1, 4, 512, 64
    q = jax.random.normal(key, (b, h, s, d), jnp.bfloat16)
    k = jax.random.normal(key, (b, h, s, d), jnp.bfloat16)
    v = jax.random.normal(key, (b, h, s, d), jnp.bfloat16)
    us = _time(flash_attention, q, k, v)
    fl = 4 * b * h * s * s * d / 2
    by = 4 * b * h * s * d * 2
    rows.append(f"kernels,flash_attention,(1×4×512×64),{us:.0f},{fl:.0f},"
                f"{by},{fl / by:.1f},"
                f"{'memory' if fl / by < RIDGE else 'compute'}")

    # ssd_scan: chunked SSD
    bs, s, hh, p, n, g = 1, 256, 4, 64, 32, 1
    ks = jax.random.split(key, 6)
    xx = jax.random.normal(ks[0], (bs, s, hh, p), jnp.float32)
    dt = jax.random.normal(ks[1], (bs, s, hh)) * 0.3
    a_log = jax.random.normal(ks[2], (hh,)) * 0.3
    bm = jax.random.normal(ks[3], (bs, s, g, n)) * 0.5
    cm = jax.random.normal(ks[4], (bs, s, g, n)) * 0.5
    dsk = jax.random.normal(ks[5], (hh,))
    dtb = jnp.zeros((hh,))
    us = _time(lambda *a: ssd_chunked_kernel(*a, chunk=64),
               xx, dt, a_log, bm, cm, dsk, dtb)
    q_ = 64
    fl = 2 * bs * hh * s * q_ * (n + p) + 4 * bs * s * hh * p * n
    by = bs * s * hh * (p + 2 * n) * 4 * 2
    rows.append(f"kernels,ssd_scan,(1×256×4×64),{us:.0f},{fl:.0f},{by},"
                f"{fl / by:.1f},{'memory' if fl / by < RIDGE else 'compute'}")
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
