"""Roofline analysis from the dry-run artifacts (EXPERIMENTS.md §Roofline).

Per (arch × shape) on the single-pod mesh:
  compute term    = global_flops            / (chips × 197 TF/s bf16)
  memory term     = global_bytes_prefusion  / (chips × 819 GB/s HBM)
  collective term = coll_bytes_per_device   /          (50 GB/s link)
plus the dominant term, MODEL_FLOPS = 6·N·D (dense) / 6·N_active·D (MoE),
the MODEL_FLOPS / HLO_FLOPs usefulness ratio, and a one-line lever note.

Caveats recorded with the numbers: FLOPs are a loop-aware jaxpr count
(global, exact for matmuls); bytes are the pre-fusion jaxpr estimate (an
upper bound on HBM traffic — XLA fusion reduces it); collective bytes are
per-device HLO result sizes with while-loop multipliers.
"""
from __future__ import annotations

import json
import os

from repro.configs import SHAPES, get_arch
from repro.models import model as model_lib

PEAK_FLOPS = 197e12        # bf16 per chip (TPU v5e)
HBM_BW = 819e9             # B/s per chip
LINK_BW = 50e9             # B/s per ICI link

ARTIFACT = os.path.join(os.path.dirname(__file__), "artifacts", "dryrun.json")


def _lever(dom: str, kind: str, cell: dict) -> str:
    if dom == "collective":
        return ("cut FSDP re-gathers (remat policy / weight-stationary "
                "microbatching) and overlap the EP all-to-all"
                if kind == "train" else
                "shrink per-step resharding: keep KV/state sharded in place")
    if dom == "memory":
        return ("raise arithmetic intensity: fuse elementwise chains, "
                "widen microbatches" if kind == "train" else
                "decode is bandwidth-bound by design: batch more requests "
                "per step to amortize the weight sweep")
    return ("good place to be: push MXU utilization via larger per-device "
            "tiles (fewer, bigger matmuls)")


def analyze(cells: list[dict]) -> list[dict]:
    out = []
    for c in cells:
        if c.get("status") != "ok" or c.get("mesh") != "pod16x16":
            continue
        cfg = get_arch(c["arch"])
        shape = SHAPES[c["shape"]]
        chips = c["n_devices"]
        tokens = shape.global_batch * shape.seq_len \
            if shape.kind != "decode" else shape.global_batch
        t_compute = c["global_flops"] / (chips * PEAK_FLOPS)
        t_memory = c["global_bytes_prefusion"] / (chips * HBM_BW)
        t_coll = c["collectives"]["total_bytes"] / LINK_BW
        terms = {"compute": t_compute, "memory": t_memory,
                 "collective": t_coll}
        dom = max(terms, key=terms.get)
        mf = model_lib.model_flops(cfg, tokens, shape.kind)
        bound = max(terms.values())
        out.append({
            "arch": c["arch"], "shape": c["shape"], "kind": shape.kind,
            "chips": chips,
            "t_compute_s": t_compute, "t_memory_s": t_memory,
            "t_collective_s": t_coll, "dominant": dom,
            "model_flops": mf,
            "useful_ratio": mf / max(c["global_flops"], 1.0),
            # roofline fraction: achievable-compute share of the bound
            "roofline_fraction": t_compute / bound if bound else 0.0,
            "lever": _lever(dom, shape.kind, c),
        })
    return out


def run() -> list[str]:
    if not os.path.exists(ARTIFACT):
        return [f"roofline,SKIPPED,no artifact at {ARTIFACT} "
                "(run python -m repro.launch.dryrun --all first)"]
    cells = json.load(open(ARTIFACT))
    rows = ["roofline.header,arch,shape,kind,chips,t_compute_s,t_memory_s,"
            "t_collective_s,dominant,model_flops,useful_ratio,"
            "roofline_fraction,lever"]
    for r in analyze(cells):
        rows.append(
            f"roofline,{r['arch']},{r['shape']},{r['kind']},{r['chips']},"
            f"{r['t_compute_s']:.4g},{r['t_memory_s']:.4g},"
            f"{r['t_collective_s']:.4g},{r['dominant']},"
            f"{r['model_flops']:.4g},{r['useful_ratio']:.3f},"
            f"{r['roofline_fraction']:.3f},\"{r['lever']}\"")
    return rows


def markdown_table(path_out: str | None = None) -> str:
    cells = json.load(open(ARTIFACT))
    rs = analyze(cells)
    lines = ["| arch | shape | compute s | memory s | collective s | "
             "dominant | MODEL/HLO | roofline frac | lever |",
             "|---|---|---|---|---|---|---|---|---|"]
    for r in rs:
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['t_compute_s']:.3g} | "
            f"{r['t_memory_s']:.3g} | {r['t_collective_s']:.3g} | "
            f"**{r['dominant']}** | {r['useful_ratio']:.2f} | "
            f"{r['roofline_fraction']:.2f} | {r['lever']} |")
    md = "\n".join(lines)
    if path_out:
        with open(path_out, "w") as f:
            f.write(md + "\n")
    return md


if __name__ == "__main__":
    print("\n".join(run()))
