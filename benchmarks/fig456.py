"""Paper Figs 4-6: speedup, S/k and α_eff vs vector length.

Machine-measured points for n ≤ 128 (validated == analytic), analytic
curve beyond — exactly the paper's saturation story: S_FOR → 30/11,
S_SUMUP → 30, α_eff → 1 while S/k turns around at 31 cores (Fig 6).
"""
import numpy as np

from repro.core import programs, run_program, timing

MACHINE_NS = [1, 2, 4, 6, 12, 24, 48, 96]
ANALYTIC_NS = [200, 1000, 10_000, 100_000]


def run() -> list[str]:
    rows = ["fig4_6.header,n,mode,source,clocks,speedup,s_over_k,alpha_eff"]
    for n in MACHINE_NS:
        vec = np.arange(1, n + 1, dtype=np.int32)
        for mode in ("NO", "FOR", "SUMUP"):
            r = run_program(programs.PROGRAMS[mode](n),
                            programs.mem_image(vec))
            assert int(r.clocks) == int(timing.exec_clocks(n, mode)), \
                (n, mode, int(r.clocks))
            s = float(timing.exec_clocks(n, "NO")) / int(r.clocks)
            k = int(r.peak_cores)
            a = float(timing.alpha_eff(k, s))
            rows.append(f"fig4_6,{n},{mode},machine,{int(r.clocks)},"
                        f"{s:.3f},{s / k:.3f},{a:.3f}")
    for n in ANALYTIC_NS:
        for mode in ("FOR", "SUMUP"):
            s = float(timing.speedup(n, mode))
            k = int(timing.cores_used(n, mode))
            a = float(timing.alpha_eff(k, s))
            rows.append(f"fig4_6,{n},{mode},analytic,"
                        f"{int(timing.exec_clocks(n, mode))},"
                        f"{s:.3f},{s / k:.3f},{a:.3f}")
    # saturation assertions (paper §6.1)
    assert abs(timing.speedup(10**7, 'FOR') - 30 / 11) < 1e-3
    assert abs(timing.speedup(10**7, 'SUMUP') - 30) < 1e-2
    rows.append("fig4_6.saturation,inf,FOR,analytic,,2.727,,")
    rows.append("fig4_6.saturation,inf,SUMUP,analytic,,30.000,,")
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
