"""Benchmark harness — one module per paper table/figure + system benches.

  table1    — paper Table 1 (clock-exact reproduction)
  fig456    — paper Figs 4/5/6 (speedup, S/k, α_eff vs vector length)
  roofline  — §Roofline terms per (arch × shape) from the dry-run artifact
  kernels   — per-kernel timing + arithmetic intensity vs the v5e ridge
  e2e       — tiny end-to-end train throughput + slot-pool serving
  serve     — device-resident continuous batching; writes BENCH_serve.json

Prints ``name,...`` CSV.  ``python -m benchmarks.run [section ...]`` or
``python -m benchmarks.run --suite serve``.
"""
import argparse
import traceback


def main() -> None:
    from benchmarks import (e2e_bench, fig456, kernels_bench, roofline,
                            serve_bench, table1)
    sections = {
        "table1": table1.run,
        "fig456": fig456.run,
        "roofline": roofline.run,
        "kernels": kernels_bench.run,
        "e2e": e2e_bench.run,
        "serve": serve_bench.run,
    }
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("sections", nargs="*", choices=[[]] + list(sections),
                    help="sections to run (default: all)")
    ap.add_argument("--suite", action="append", choices=list(sections),
                    help="section to run (repeatable; alias for positional)")
    args = ap.parse_args()
    want = list(args.sections) + list(args.suite or [])
    want = want or list(sections)
    failures = 0
    for name in want:
        try:
            for line in sections[name]():
                print(line)
        except Exception:
            failures += 1
            print(f"{name},ERROR")
            traceback.print_exc()
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
