"""End-to-end CPU benches: tiny train throughput + serving engine ticks."""
import time

import jax
import numpy as np

from repro.configs import ShapeConfig, get_arch, reduced


def run() -> list[str]:
    rows = ["e2e.header,name,metric,value,derived"]

    # train throughput (reduced granite, CPU)
    from repro.launch.train import train_loop
    cfg = reduced(get_arch("granite-3-2b"), n_layers=2, d_model=128,
                  vocab=512)
    shape = ShapeConfig("bench", 128, 4, "train")
    t0 = time.perf_counter()
    out = train_loop(cfg, shape, steps=6, log_every=0)
    dt = time.perf_counter() - t0
    tok_s = 6 * shape.global_batch * shape.seq_len / dt
    loss_drop = out.losses[0][1] - out.losses[-1][1]
    rows.append(f"e2e,train_tiny,tokens_per_s,{tok_s:.0f},"
                f"loss_drop={loss_drop:.3f}")

    # serving engine: device-resident continuous batching over the slot pool
    from repro.models import model as model_lib
    from repro.runtime.serve import Request, ServingEngine
    import jax.numpy as jnp
    params = model_lib.init(jax.random.PRNGKey(0), cfg, jnp.float32)
    eng = ServingEngine(params, cfg, n_slots=4, max_seq=64)
    reqs = [Request(i, np.arange(1, 9, dtype=np.int32) + i, max_new=6)
            for i in range(8)]
    t0 = time.perf_counter()
    done, ticks = eng.run_to_completion(reqs)
    dt = time.perf_counter() - t0
    stats = eng.sync_stats()
    rows.append(f"e2e,serve_slot_pool,requests_done,{len(done)},"
                f"ticks={ticks};rented={eng.pool.created_total};"
                f"tok_per_s={sum(len(r.out) for r in done) / dt:.0f};"
                f"host_syncs={stats['host_syncs']};"
                f"sync_reduction={stats['sync_reduction_x']:.1f}x")
    assert len(done) == 8
    assert eng.pool.created_total >= 8      # every request rented a slot
    assert eng.pool.used == 0               # and returned it (§4.3)
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
