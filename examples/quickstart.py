"""Quickstart: train a tiny LM for a few steps, then decode from it.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ShapeConfig, get_arch, reduced
from repro.launch.train import train_loop
from repro.models import model


def main():
    # a reduced granite-3-2b: same family, CPU-sized
    cfg = reduced(get_arch("granite-3-2b"), n_layers=2, d_model=128,
                  vocab=512)
    shape = ShapeConfig("quickstart", seq_len=128, global_batch=8,
                        kind="train")

    print(f"== training {cfg.name} (reduced, "
          f"{cfg.param_count():,} params) ==")
    run = train_loop(cfg, shape, steps=30, log_every=5, keep_state=True)
    first, last = run.losses[0][1], run.losses[-1][1]
    print(f"loss: {first:.3f} -> {last:.3f}")
    assert last < first, "training should reduce loss"

    print("== greedy decoding 12 tokens ==")
    params = run.final_state["params"]
    batch = {"tokens": jnp.array(np.arange(1, 17)[None], jnp.int32)}
    logits, cache = model.prefill(params, batch, cfg, max_seq=64)
    toks = []
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    for _ in range(12):
        toks.append(int(tok[0]))
        logits, cache = model.decode_step(params, tok, cache, cfg)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
    print("generated:", toks)
    print("OK")


if __name__ == "__main__":
    main()
