"""The paper, live: run Listing 1 in NO / FOR / SUMUP on the EMPA machine.

    PYTHONPATH=src python examples/empa_sim_demo.py
"""
import numpy as np

from repro.core import alpha_eff, programs, run_program, timing


def main():
    vec = [0xD, 0xC0, 0xB00, 0xA000]
    print("vector:", [hex(v) for v in vec], "sum:", hex(sum(vec)))
    print(f"{'mode':>6} {'clocks':>7} {'cores':>6} {'speedup':>8} "
          f"{'S/k':>6} {'alpha_eff':>9}")
    base = None
    for mode in ("NO", "FOR", "SUMUP"):
        r = run_program(programs.PROGRAMS[mode](len(vec)),
                        programs.mem_image(vec))
        assert int(r.result) == sum(vec)
        clocks, k = int(r.clocks), int(r.peak_cores)
        base = base or clocks
        s = base / clocks
        print(f"{mode:>6} {clocks:>7} {k:>6} {s:>8.2f} {s / k:>6.2f} "
              f"{float(alpha_eff(k, s)):>9.2f}")

    print("\nsaturation (paper §6.1): S_FOR -> 30/11 = "
          f"{timing.speedup(10**6, 'FOR'):.3f}, "
          f"S_SUMUP -> {timing.speedup(10**6, 'SUMUP'):.1f}")

    print("\nnested QTs (§3): 3-level fork tree, fanout 2")
    r = run_program(programs.qt_tree(3, 2), ())
    print(f"  leaves counted: {int(r.result)} (expect 8); "
          f"QTs created: {int(r.created_total)}; "
          f"peak cores: {int(r.peak_cores)}")


if __name__ == "__main__":
    main()
