"""Serving example: batched requests over the EMPA slot pool.

Requests are QTs, KV-cache slots are cores: rented on admission, returned
at EOS; more requests than slots exercises queueing (pool exhaustion =
"SV out of cores", §3.3).

    PYTHONPATH=src python examples/serve.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch, reduced
from repro.models import model
from repro.runtime.serve import Request, ServingEngine


def main():
    cfg = reduced(get_arch("granite-3-2b"), n_layers=2, d_model=128,
                  vocab=512)
    params = model.init(jax.random.PRNGKey(0), cfg, jnp.float32)
    engine = ServingEngine(params, cfg, n_slots=4, max_seq=96)

    rng = np.random.default_rng(0)
    requests = [
        Request(rid=i,
                prompt=rng.integers(1, cfg.vocab, size=rng.integers(4, 12),
                                    dtype=np.int64).astype(np.int32),
                max_new=int(rng.integers(4, 10)))
        for i in range(10)
    ]
    print(f"serving {len(requests)} requests over "
          f"{engine.pool.n} slots (continuous batching)")
    done, ticks = engine.run_to_completion(requests)
    for r in sorted(done, key=lambda r: r.rid):
        print(f"  req {r.rid}: prompt[{len(r.prompt)}] -> {r.out}")
    print(f"done in {ticks} decode ticks; slots rented "
          f"{engine.pool.created_total} times; pool back to "
          f"{engine.pool.available}/{engine.pool.n} free")
    assert len(done) == len(requests)
    assert engine.pool.used == 0


if __name__ == "__main__":
    main()
