"""Serving example: device-resident continuous batching over the EMPA pool.

Requests are QTs, KV-cache slots are cores: rented on admission, returned
at EOS; more requests than slots exercises queueing (pool exhaustion =
"SV out of cores", §3.3).  The slot supervisor — active mask, greedy
argmax, EOS/budget retirement — runs inside one jitted decode chunk, so
the host syncs once per `chunk` generated tokens instead of once per slot
per tick.

    PYTHONPATH=src python examples/serve.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch, reduced
from repro.models import model
from repro.runtime.serve import Request, ServingEngine


def main():
    cfg = reduced(get_arch("granite-3-2b"), n_layers=2, d_model=128,
                  vocab=512)
    params = model.init(jax.random.PRNGKey(0), cfg, jnp.float32)
    engine = ServingEngine(params, cfg, n_slots=4, max_seq=96, chunk=8)

    rng = np.random.default_rng(0)
    requests = [
        Request(rid=i,
                prompt=rng.integers(1, cfg.vocab, size=rng.integers(4, 12),
                                    dtype=np.int64).astype(np.int32),
                max_new=int(rng.integers(4, 10)))
        for i in range(10)
    ]
    print(f"serving {len(requests)} requests over "
          f"{engine.pool.n} slots (device-resident continuous batching)")
    t0 = time.perf_counter()
    done, ticks = engine.run_to_completion(requests)
    dt = time.perf_counter() - t0
    for r in sorted(done, key=lambda r: r.rid):
        print(f"  req {r.rid}: prompt[{len(r.prompt)}] -> {r.out}")
    total = sum(len(r.out) for r in done)
    stats = engine.sync_stats()
    print(f"done in {ticks} on-device decode ticks; slots rented "
          f"{engine.pool.created_total} times; pool back to "
          f"{engine.pool.available}/{engine.pool.n} free")
    print(f"{total} tokens in {dt:.2f}s = {total / dt:.0f} tok/s; "
          f"{stats['host_syncs']} host syncs "
          f"({stats['host_syncs_per_100_tokens']:.1f}/100tok, baseline "
          f"{stats['baseline_syncs_per_100_tokens']:.1f}/100tok -> "
          f"{stats['sync_reduction_x']:.1f}x fewer)")
    assert len(done) == len(requests)
    assert engine.pool.used == 0


if __name__ == "__main__":
    main()
