"""Serving example: device-resident continuous batching over the EMPA pool.

Requests are QTs, KV-cache slots are cores: rented on admission, returned
at EOS; more requests than slots exercises queueing (pool exhaustion =
"SV out of cores", §3.3).  The slot supervisor — active mask, greedy
argmax, EOS/budget retirement — runs inside one jitted decode chunk, so
the host syncs once per `chunk` generated tokens instead of once per slot
per tick.

The same run then repeats with ``paged=True``: the rented resource drops
from a whole `max_seq` slot to a fixed-size KV *block* (runtime/paging),
identical prompt prefixes share blocks, and the outputs stay token-exact
while the allocated KV bytes per token shrink.  The final section turns
on ``overcommit=True`` against a pool too small for every worst case:
the supervisor evicts and resumes requests under KV pressure and the
streams still match the reserved run token for token.

With ``--devices N`` the run finishes one level up the hierarchy: a
``FleetSupervisor`` owns N serving replicas (one per device; replicas
share devices when the host has fewer) and routes the same stream
least-loaded-by-blocks across them — engines are cores to the fleet
exactly as slots are cores to an engine, and the tokens still match.

    PYTHONPATH=src python examples/serve.py
    XLA_FLAGS=--xla_force_host_platform_device_count=4 \\
        PYTHONPATH=src python examples/serve.py --devices 4
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch, reduced
from repro.models import model
from repro.runtime.serve import Request, ServingEngine
from repro.runtime.supervisor import FleetSupervisor


def make_requests(cfg, n=10):
    rng = np.random.default_rng(0)
    shared_prefix = rng.integers(1, cfg.vocab, size=16,
                                 dtype=np.int64).astype(np.int32)
    reqs = []
    for i in range(n):
        tail = rng.integers(1, cfg.vocab, size=rng.integers(2, 8),
                            dtype=np.int64).astype(np.int32)
        # half the stream shares a 16-token prefix (one full block)
        prompt = np.concatenate([shared_prefix, tail]) if i % 2 == 0 \
            else rng.integers(1, cfg.vocab, size=rng.integers(4, 12),
                              dtype=np.int64).astype(np.int32)
        reqs.append(Request(rid=i, prompt=prompt,
                            max_new=int(rng.integers(4, 10))))
    return reqs


def run(engine, requests, label):
    print(f"-- {label}: serving {len(requests)} requests over "
          f"{engine.pool.n} slots")
    t0 = time.perf_counter()
    done, ticks = engine.run_to_completion(requests)
    dt = time.perf_counter() - t0
    total = sum(len(r.out) for r in done)
    stats = engine.sync_stats()
    kv = engine.kv_stats()
    print(f"   done in {ticks} on-device decode ticks; slots rented "
          f"{engine.pool.created_total} times; pool back to "
          f"{engine.pool.available}/{engine.pool.n} free")
    print(f"   {total} tokens in {dt:.2f}s = {total / dt:.0f} tok/s; "
          f"{stats['host_syncs']} host syncs "
          f"({stats['host_syncs_per_100_tokens']:.1f}/100tok, baseline "
          f"{stats['baseline_syncs_per_100_tokens']:.1f}/100tok -> "
          f"{stats['sync_reduction_x']:.1f}x fewer)")
    print(f"   KV allocated: {kv['kv_bytes_allocated']} B over "
          f"{kv['tokens_finished']} tokens = "
          f"{kv['kv_bytes_per_token']:.0f} B/token"
          + (f"; {kv['shared_block_hits']} shared-block hits, peak "
             f"{kv['peak_blocks']}/{kv['n_blocks']} blocks"
             if engine.layout else ""))
    assert len(done) == len(requests)
    assert engine.pool.used == 0
    return {r.rid: r.out for r in done}, kv


def run_fleet(params, cfg, requests, want, n_replicas):
    print(f"-- fleet: {n_replicas} serving replicas over "
          f"{jax.device_count()} devices")
    fleet = FleetSupervisor(params, cfg, n_replicas=n_replicas, model=1,
                            n_slots=4, max_seq=96, chunk=8,
                            paged=True, block_size=16, n_blocks=16)
    t0 = time.perf_counter()
    done, ticks = fleet.run_to_completion(requests)
    dt = time.perf_counter() - t0
    got = {r.rid: r.out for r in done}
    assert got == want, "fleet routing must not change a token"
    total = sum(len(t) for t in got.values())
    ks = fleet.kv_stats()["fleet"]
    sync = fleet.sync_stats()["fleet"]
    print(f"   {total} tokens in {dt:.2f}s = {total / dt:.0f} tok/s over "
          f"{ticks} summed ticks; requests per replica {fleet.routed}")
    print(f"   fleet pool: {ks['slot_pool']['n_units']} slots / "
          f"{ks['n_blocks']} blocks across {ks['n_replicas']} replicas; "
          f"{sync['host_syncs']} host syncs fleet-wide")
    print("token-exact across the fleet: which replica serves a request "
          "cannot matter")


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--devices", type=int, default=1,
                    help="fleet replicas (one per device; replicas share "
                         "devices when the host has fewer — set "
                         "XLA_FLAGS=--xla_force_host_platform_device_count"
                         "=N for a real N-device CPU mesh)")
    args = ap.parse_args()
    cfg = reduced(get_arch("granite-3-2b"), n_layers=2, d_model=128,
                  vocab=512)
    params = model.init(jax.random.PRNGKey(0), cfg, jnp.float32)

    out_c, kv_c = run(
        ServingEngine(params, cfg, n_slots=4, max_seq=96, chunk=8),
        make_requests(cfg), "contiguous slots")
    out_p, kv_p = run(
        ServingEngine(params, cfg, n_slots=4, max_seq=96, chunk=8,
                      paged=True, block_size=16, n_blocks=16),
        make_requests(cfg), "paged blocks")
    assert out_c == out_p, "paged decode must be token-exact"
    print(f"token-exact across layouts; paged KV bytes/token "
          f"{kv_p['kv_bytes_per_token']:.0f} vs contiguous "
          f"{kv_c['kv_bytes_per_token']:.0f} "
          f"({kv_c['kv_bytes_per_token'] / kv_p['kv_bytes_per_token']:.1f}x"
          f" smaller)")

    # chunked prefill: prompts are outsourced fragment by fragment (the
    # paper's cores never hand over a whole job), so a long prompt can't
    # head-of-line-block the decoders — and tokens stay exact
    out_f, _ = run(
        ServingEngine(params, cfg, n_slots=4, max_seq=96, chunk=8,
                      paged=True, block_size=16, n_blocks=16,
                      chunked_prefill=True, prefill_chunk_tokens=16),
        make_requests(cfg), "paged blocks + chunked prefill")
    assert out_f == out_c, "chunked prefill must be token-exact"
    print("token-exact with chunked prefill (fragments of 16)")

    # speculative decoding: a drafter core (n-gram prompt lookup) runs
    # ahead, one verify forward accepts up to spec_k+1 tokens per slot —
    # greedy argmax verification keeps the output bit-exact, so the only
    # possible outcome is fewer memory-bound decode forwards
    spec_eng = ServingEngine(params, cfg, n_slots=4, max_seq=96, chunk=8,
                             paged=True, block_size=16, n_blocks=16,
                             speculative=True, spec_k=4)
    out_s, _ = run(spec_eng, make_requests(cfg),
                   "paged blocks + speculative decode")
    assert out_s == out_c, "speculative decode must be token-exact"
    st = spec_eng.spec_stats()
    print(f"token-exact with speculative decode (spec_k=4): "
          f"{st['tokens_per_forward']:.2f} tokens/forward at "
          f"{st['acceptance_rate']:.2f} draft acceptance")

    # preemptive over-commit: admission takes only what a request needs
    # *now* (no §5.1 worst-case reservation), and when decode growth
    # runs the deliberately undersized pool dry the supervisor evicts a
    # victim — its chain is clawed back, its request parks with its
    # token history and resumes later by replaying that history through
    # chunked prefill.  Greedy determinism keeps the stream token-exact.
    reqs = make_requests(cfg, n=12)
    for r in reqs:
        r.max_new = max(r.max_new, 28)        # real decode budgets:
        #                                       worst case ~3 blocks each
    base = ServingEngine(params, cfg, n_slots=4, max_seq=96, chunk=4,
                         paged=True, block_size=16, n_blocks=9,
                         chunked_prefill=True, prefill_chunk_tokens=16)
    out_r, _ = run(base, [Request(r.rid, r.prompt, max_new=r.max_new)
                          for r in reqs], "small pool, reserved admission")
    oc_eng = ServingEngine(params, cfg, n_slots=4, max_seq=96, chunk=4,
                           paged=True, block_size=16, n_blocks=9,
                           chunked_prefill=True, prefill_chunk_tokens=16,
                           overcommit=True)
    out_o, _ = run(oc_eng, [Request(r.rid, r.prompt, max_new=r.max_new)
                            for r in reqs], "small pool, over-commit")
    assert out_o == out_r, "preempted/resumed requests must be token-exact"
    occ = oc_eng.occupancy_stats()
    occ_r = base.occupancy_stats()
    print(f"token-exact under over-commit: occupancy "
          f"{occ['occupancy']:.2f} vs {occ_r['occupancy']:.2f} reserved, "
          f"{occ['preemptions']} preemptions / {occ['resumes']} resumes, "
          f"{occ['preempted_tokens_recomputed']} tokens recomputed")

    # the fleet: one supervisor up — N engines as the rented cores,
    # requests routed least-loaded-by-blocks, preemption-aware
    if args.devices > 1:
        run_fleet(params, cfg, make_requests(cfg), out_c, args.devices)


if __name__ == "__main__":
    main()
