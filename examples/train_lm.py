"""End-to-end driver: train a ~100M-parameter LM for a few hundred steps.

Exercises the full substrate: synthetic pipeline with prefetch, FOR-mode
microbatching, AdamW + cosine schedule, remat, async checkpointing with
auto-resume (kill it mid-run and restart — it continues).

    PYTHONPATH=src python examples/train_lm.py --steps 300
"""
import argparse
import dataclasses

from repro.configs import ShapeConfig, get_arch
from repro.launch.train import train_loop
from repro.optim import adamw


def make_100m():
    """granite-family config at ~100M parameters."""
    cfg = get_arch("granite-3-2b")
    cfg = dataclasses.replace(
        cfg, n_layers=10, d_model=640, n_heads=10, n_kv_heads=2,
        head_dim=64, d_ff=2560, vocab=32768, max_position=65536)
    return cfg


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--microbatch", type=int, default=2)
    ap.add_argument("--ckpt", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    cfg = make_100m()
    print(f"model: {cfg.param_count():,} params "
          f"({cfg.n_layers}L d{cfg.d_model} v{cfg.vocab})")
    shape = ShapeConfig("train_lm", args.seq, args.batch, "train")
    opt = adamw.AdamWConfig(lr=6e-4, warmup_steps=30,
                            total_steps=args.steps)
    run = train_loop(cfg, shape, steps=args.steps, ckpt_dir=args.ckpt,
                     ckpt_every=50, opt_cfg=opt,
                     n_microbatch=args.microbatch, log_every=10)
    if run.resumed_from is not None:
        print(f"(resumed from checkpoint at step {run.resumed_from})")
    losses = [l for _, l in run.losses]
    print(f"steps {len(losses)}; loss {losses[0]:.3f} -> {losses[-1]:.3f}")


if __name__ == "__main__":
    main()
