"""AdamW with decoupled weight decay + global-norm clipping.

Pure-pytree implementation (no optax dependency): moments in f32, master
behaviour configurable.  The update itself is elementwise — perfectly
sharded by whatever PartitionSpecs the parameters carry.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class AdamWConfig(NamedTuple):
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


def schedule(cfg: AdamWConfig, step):
    """Linear warmup + cosine decay to min_lr_ratio."""
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                    0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * (cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos)


def init(params):
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "m": jax.tree_util.tree_map(zeros, params),
        "v": jax.tree_util.tree_map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree):
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree_util.tree_leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(grads, max_norm):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree_util.tree_map(
        lambda g: (g.astype(jnp.float32) * scale), grads), norm


def update(grads, state, params, cfg: AdamWConfig):
    """Returns (new_params, new_state, metrics)."""
    grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    step = state["step"] + 1
    lr = schedule(cfg, step)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
        mhat = m / b1c
        vhat = v / b2c
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) \
            + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = jax.tree_util.tree_leaves(grads)
    flat_m = jax.tree_util.tree_leaves(state["m"])
    flat_v = jax.tree_util.tree_leaves(state["v"])
    new = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree_util.tree_unflatten(treedef, [t[0] for t in new])
    new_m = jax.tree_util.tree_unflatten(treedef, [t[1] for t in new])
    new_v = jax.tree_util.tree_unflatten(treedef, [t[2] for t in new])
    return new_p, {"m": new_m, "v": new_v, "step": step}, \
        {"grad_norm": gnorm, "lr": lr}
