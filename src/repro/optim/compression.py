"""Error-feedback int8 gradient compression (1-bit-Adam/EF-SGD family).

EMPA mapping: "a limited amount of glue can be returned in a synchronized
way when a QT is finished" (§3.2) — the clone-back is narrow by design.
Cross-pod gradient reduction is the cluster-scale clone-back, and the
inter-pod links are the scarce resource (data-center ICI ≪ in-pod ICI),
so the returned glue is quantized to int8 with per-tensor scales and the
quantization error is fed back into the next step (error feedback keeps
SGD/Adam convergence — Karimireddy et al., 2019).

Integration levels:
* numerics (here, tested): quantize→(sum)→dequantize with persistent
  error-feedback state, applied to the gradient tree before the optimizer
  — exactly what each pod would send/receive.
* wire (future work): the actual int8 all-reduce over the "pod" axis
  needs the step's gradient computation wrapped in a shard_map over
  ("pod",) with auto data/model axes so the per-pod partial gradients are
  manually reachable; the GSPMD-auto path fuses the pod reduction into
  one bf16/f32 all-reduce that cannot be intercepted (see DESIGN.md §5).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def init_error_state(params):
    return jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)


def quantize(g, err):
    """g + err -> (int8 codes, scale, new_err).  Per-tensor symmetric."""
    v = g.astype(jnp.float32) + err
    scale = jnp.maximum(jnp.max(jnp.abs(v)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(v / scale), -127, 127).astype(jnp.int8)
    deq = q.astype(jnp.float32) * scale
    return q, scale, v - deq


def dequantize(q, scale):
    return q.astype(jnp.float32) * scale


def compress_grads(grads, err_state, *, reduce_fn=None):
    """Quantize the gradient tree with error feedback.

    `reduce_fn(q_int8, scale)` is the hook where a manual cross-pod
    reduction would run (int8 on the wire); default is identity —
    quantize/dequantize numerics only.  Returns (grads, new_err_state,
    metrics) with metrics reporting the achieved compression ratio.
    """
    leaves, treedef = jax.tree_util.tree_flatten(grads)
    errs = jax.tree_util.tree_leaves(err_state)
    out, new_errs = [], []
    raw_bytes = comp_bytes = 0.0
    for g, e in zip(leaves, errs):
        q, scale, new_e = quantize(g, e)
        if reduce_fn is not None:
            q = reduce_fn(q, scale)
        out.append(dequantize(q, scale))
        new_errs.append(new_e)
        raw_bytes += g.size * 4.0
        comp_bytes += g.size * 1.0 + 4.0
    return (jax.tree_util.tree_unflatten(treedef, out),
            jax.tree_util.tree_unflatten(treedef, new_errs),
            {"compression_ratio": raw_bytes / comp_bytes})
