"""Production meshes.

Defined as FUNCTIONS (not module-level constants) so importing this module
never touches jax device state — required because the dry-run overrides the
platform device count and the smoke tests must keep seeing 1 device.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 = 256 chips per pod; 2 pods = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_degraded_mesh(level: int = 0, *, multi_pod: bool = True):
    """Elastic ladder (runtime/elastic.py): each level is a pre-validated
    fallback mesh after capacity loss — EMPA's shrinking core pool."""
    ladder = [
        ((2, 16, 16), ("pod", "data", "model")),   # full fleet
        ((1, 16, 16), ("pod", "data", "model")),   # one pod lost
        ((16, 16), ("data", "model")),             # single-pod operation
        ((8, 16), ("data", "model")),              # half-pod (8 hosts lost)
        ((4, 16), ("data", "model")),              # quarter-pod
    ]
    shape, axes = ladder[level]
    return jax.make_mesh(shape, axes)


def make_host_mesh(n: int | None = None, model_axis: int = 1):
    """Small mesh over the actually-present devices (tests, examples)."""
    n = n or len(jax.devices())
    assert n % model_axis == 0
    return jax.make_mesh((n // model_axis, model_axis), ("data", "model"))
