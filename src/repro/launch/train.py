"""End-to-end training driver: data → step → checkpoint → auto-resume.

Runs on whatever devices exist (CPU smoke / TPU pod): the mesh, sharding
rules, microbatching, prefetch and checkpointing all come from the same
framework pieces the dry-run validates at 512 chips.

CLI:
  PYTHONPATH=src python -m repro.launch.train --arch granite-3-2b \
      --reduced --steps 50 --batch 8 --seq 128 --ckpt /tmp/ckpt
"""
from __future__ import annotations

import argparse
import dataclasses
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.configs import SHAPES, ShapeConfig, get_arch, reduced
from repro.data.pipeline import DataConfig, Prefetcher
from repro.launch.mesh import make_host_mesh
from repro.optim import adamw
from repro.runtime import train as train_lib
from repro.runtime.sharding import ShardingRules


@dataclasses.dataclass
class TrainRun:
    losses: list
    steps_run: int
    resumed_from: Optional[int]
    final_state: object = None


def fingerprint(cfg) -> str:
    return f"{cfg.name}-L{cfg.n_layers}-d{cfg.d_model}-v{cfg.vocab}"


def train_loop(cfg, shape: ShapeConfig, *, steps: int,
               ckpt_dir: Optional[str] = None, ckpt_every: int = 20,
               resume: bool = True, seed: int = 0,
               opt_cfg: Optional[adamw.AdamWConfig] = None,
               n_microbatch: int = 1, dtype=jnp.float32,
               log_every: int = 10, fail_at: Optional[int] = None,
               keep_state: bool = False) -> TrainRun:
    """`fail_at` injects a crash after that step (fault-tolerance tests)."""
    mesh = make_host_mesh()
    rules = ShardingRules(mesh)
    opt_cfg = opt_cfg or adamw.AdamWConfig(lr=1e-3, warmup_steps=10,
                                           total_steps=max(steps, 1))
    step_fn = train_lib.jit_train_step(cfg, opt_cfg, mesh, rules,
                                       n_microbatch=n_microbatch)

    state = train_lib.init_state(jax.random.PRNGKey(seed), cfg, dtype)
    start = 0
    resumed = None
    mgr = None
    if ckpt_dir:
        mgr = CheckpointManager(ckpt_dir, fingerprint=fingerprint(cfg))
        if resume and mgr.latest_step() is not None:
            state, start = mgr.restore(state)
            resumed = start

    data = Prefetcher(cfg, shape, DataConfig(seed=seed), start_step=start)
    losses = []
    t0 = time.time()
    try:
        with mesh:
            for step, batch in data:
                if step >= steps:
                    break
                jb = jax.tree_util.tree_map(jnp.asarray, batch)
                state, metrics = step_fn(state, jb)
                loss = float(metrics["loss"])
                losses.append((step, loss))
                if log_every and step % log_every == 0:
                    tok_s = shape.global_batch * shape.seq_len * \
                        (len(losses)) / max(time.time() - t0, 1e-9)
                    print(f"step {step:5d} loss {loss:.4f} "
                          f"gnorm {float(metrics['grad_norm']):.3f} "
                          f"lr {float(metrics['lr']):.2e} "
                          f"({tok_s:,.0f} tok/s)")
                if mgr and (step + 1) % ckpt_every == 0:
                    mgr.save(step + 1, state)
                if fail_at is not None and step + 1 >= fail_at:
                    raise RuntimeError(f"injected failure at step {step + 1}")
    finally:
        data.close()
        if mgr:
            mgr.wait()
    return TrainRun(losses=losses, steps_run=len(losses),
                    resumed_from=resumed,
                    final_state=state if keep_state else None)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-2b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--microbatch", type=int, default=1)
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES))
    args = ap.parse_args()

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    shape = SHAPES[args.shape] if args.shape else \
        ShapeConfig("cli", args.seq, args.batch, "train")
    run = train_loop(cfg, shape, steps=args.steps, ckpt_dir=args.ckpt,
                     n_microbatch=args.microbatch)
    print(f"done: {run.steps_run} steps, final loss "
          f"{run.losses[-1][1]:.4f}" if run.losses else "no steps run")


if __name__ == "__main__":
    main()
