"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each cell this proves the distribution config is coherent without real
hardware: sharding propagates, the collectives exist, memory fits.  The
compiled artifact's cost analysis + HLO collective inventory are dumped as
JSON for EXPERIMENTS.md §Dry-run and the §Roofline analysis.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch granite-8b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod/--single-pod]
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST precede every other import (jax locks the device
# count at first initialization).

import argparse
import json
import time
import traceback

import jax

from repro.configs import ARCH_IDS, SHAPES, get_arch, shape_applicable
from repro.launch.mesh import make_production_mesh
from repro.runtime.accounting import hlo_collectives, jaxpr_cost
from repro.runtime.supervisor import ClusterSupervisor


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             verbose: bool = True) -> dict:
    cfg = get_arch(arch)
    shape = SHAPES[shape_name]
    ok, why = shape_applicable(cfg, shape)
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    cell = {"arch": arch, "shape": shape_name, "mesh": mesh_name}
    if not ok:
        cell["status"] = "skipped"
        cell["reason"] = why
        if verbose:
            print(f"[dryrun] {arch} × {shape_name} × {mesh_name}: SKIP ({why})")
        return cell

    t0 = time.time()
    mesh = make_production_mesh(multi_pod=multi_pod)
    sup = ClusterSupervisor(mesh, cfg, shape)
    plan = sup.plan()
    with mesh:
        lowered = jax.jit(plan.step_fn,
                          in_shardings=plan.in_shardings,
                          out_shardings=plan.out_shardings,
                          donate_argnums=plan.donate_argnums) \
            .lower(*plan.abstract_args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    cost = compiled.cost_analysis() or {}
    if isinstance(cost, (list, tuple)):   # older jax: one dict per device
        cost = cost[0] if cost else {}
    try:
        mem = compiled.memory_analysis()
        mem_stats = {k: int(getattr(mem, k)) for k in
                     ("argument_size_in_bytes", "output_size_in_bytes",
                      "temp_size_in_bytes", "generated_code_size_in_bytes")
                     if hasattr(mem, k)}
    except Exception as e:  # pragma: no cover - backend dependent
        mem_stats = {"error": str(e)}

    # loop-aware accounting (see runtime/accounting.py): jaxpr cost is
    # GLOBAL; HLO collectives are PER-DEVICE wire bytes
    t1 = time.time()
    with mesh:
        jcost = jaxpr_cost(plan.step_fn, *plan.abstract_args)
    coll = hlo_collectives(compiled.as_text())
    t_account = time.time() - t1

    n_dev = mesh.devices.size
    cell.update(
        status="ok",
        n_devices=int(n_dev),
        kind=shape.kind,
        microbatches=sup.n_microbatch,
        lower_s=round(t_lower, 1),
        compile_s=round(t_compile, 1),
        account_s=round(t_account, 1),
        # global, loop-aware (jaxpr walk)
        global_flops=jcost["flops"],
        global_matmul_flops=jcost["matmul_flops"],
        global_bytes_prefusion=jcost["bytes"],
        # raw XLA numbers (loop bodies counted once — kept for reference)
        xla_flops_per_device_bodyonce=float(cost.get("flops", -1.0)),
        xla_bytes_per_device_bodyonce=float(cost.get("bytes accessed", -1.0)),
        memory=mem_stats,
        collectives=coll,
        sharding_fallbacks=plan.rules.report(),
        notes=plan.notes,
    )
    if verbose:
        print(f"[dryrun] {arch} × {shape_name} × {mesh_name}: OK "
              f"(lower {t_lower:.0f}s compile {t_compile:.0f}s, "
              f"{jcost['flops']:.3g} global flops, "
              f"{coll['total_bytes']:.3g} coll B/dev)")
        print(f"  memory_analysis: {mem_stats}")
        print(f"  cost_analysis(body-once): flops={cost.get('flops')} "
              f"bytes={cost.get('bytes accessed')}")
    return cell


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--single-pod", action="store_true")
    ap.add_argument("--out", default="benchmarks/artifacts/dryrun.json")
    ap.add_argument("--append", action="store_true")
    args = ap.parse_args()

    meshes = []
    if args.multi_pod or not args.single_pod:
        meshes.append(True)
    if args.single_pod or not args.multi_pod:
        meshes.insert(0, False)

    cells = []
    archs = ARCH_IDS if (args.all or not args.arch) else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                try:
                    cells.append(run_cell(arch, shape, mp))
                except Exception:
                    traceback.print_exc()
                    cells.append({"arch": arch, "shape": shape,
                                  "mesh": "pod2x16x16" if mp else "pod16x16",
                                  "status": "error",
                                  "error": traceback.format_exc()[-2000:]})
                # persist incrementally — a crash keeps prior results
                os.makedirs(os.path.dirname(args.out), exist_ok=True)
                prior = []
                if args.append and os.path.exists(args.out):
                    with open(args.out) as f:
                        prior = json.load(f)
                    args.append = False
                with open(args.out, "w") as f:
                    json.dump(prior + cells, f, indent=1)
                if prior:
                    cells = prior + cells

    n_ok = sum(1 for c in cells if c.get("status") == "ok")
    n_skip = sum(1 for c in cells if c.get("status") == "skipped")
    n_err = sum(1 for c in cells if c.get("status") == "error")
    print(f"[dryrun] done: {n_ok} ok, {n_skip} skipped, {n_err} errors "
          f"-> {args.out}")
    return 1 if n_err else 0


if __name__ == "__main__":
    raise SystemExit(main())
