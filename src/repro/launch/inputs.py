"""ShapeDtypeStruct stand-ins for every model input (dry-run, no allocation).

Weak-type-correct, shardable.  [audio]/[vlm] archs get precomputed
frame/patch embeddings (the modality frontend is a stub per assignment).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, ShapeConfig
from repro.models import model as model_lib


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def batch_inputs(cfg: ArchConfig, shape: ShapeConfig, *, with_labels: bool,
                 dtype=jnp.bfloat16) -> dict:
    """Inputs for train (with labels) / prefill (without)."""
    b, s = shape.global_batch, shape.seq_len
    batch = {}
    s_txt = s
    if cfg.frontend == "vision":
        nv = cfg.n_frontend_tokens
        batch["vision_embeds"] = _sds((b, nv, cfg.frontend_dim), dtype)
        s_txt = s - nv
    if cfg.family == "encdec":
        batch["enc_embeds"] = _sds((b, s, cfg.frontend_dim), dtype)
    batch["tokens"] = _sds((b, s_txt), jnp.int32)
    if with_labels:
        batch["labels"] = _sds((b, s_txt), jnp.int32)
    return batch


def batch_axes(cfg: ArchConfig, shape: ShapeConfig, *, with_labels: bool) -> dict:
    ax = {}
    if cfg.frontend == "vision":
        ax["vision_embeds"] = ("batch", None, None)
    if cfg.family == "encdec":
        ax["enc_embeds"] = ("batch", None, None)
    ax["tokens"] = ("batch", None)
    if with_labels:
        ax["labels"] = ("batch", None)
    return ax


def decode_inputs(cfg: ArchConfig, shape: ShapeConfig, dtype=jnp.bfloat16):
    """(token, cache) stand-ins for a decode step with a seq_len cache."""
    b, s = shape.global_batch, shape.seq_len
    token = _sds((b,), jnp.int32)
    cache = model_lib.init_cache(cfg, b, s, dtype=dtype, abstract_only=True)
    return token, cache


def cache_axes(cfg: ArchConfig, paged: bool = False) -> dict:
    """Logical axes for each cache leaf (family-dependent).

    Paged K/V pages are `(layers, n_blocks, block_size, hkv, dh)`: any
    slot's chain may live on any block, so the block axes replicate
    across the data axis and TP stays on the head/head-dim axes; the
    per-slot block tables shard with the slot batch."""
    kv = ("layers", None, None, "cache_kv_heads", "cache_head_dim") \
        if paged else \
        ("layers", "cache_batch", None, "cache_kv_heads", "cache_head_dim")
    ax = {"pos": ("cache_batch",)}
    fam = cfg.family
    if fam in ("dense", "moe", "vlm", "encdec"):
        ax.update(k=kv, v=kv)
        if paged:
            ax["block_tables"] = ("cache_batch", None)
        if fam == "encdec":
            ax.update(xk=kv, xv=kv)
    if fam in ("ssm", "hybrid"):
        ax.update(conv=("layers", "cache_batch", None, "conv_dim"),
                  state=("layers", "cache_batch", "ssm_heads", None,
                         "ssm_state"))
    if fam == "hybrid":
        ax.update(k=kv, v=kv)
    return ax
