"""Sharded synthetic LM data pipeline with host-side prefetch.

The prefetch thread is EMPA's dedicated service core (§3.6: a core
"prepared ... and waiting", so the payload cores never stall on input):
batches are produced ahead of time into a bounded queue off the training
thread's critical path.

Determinism & sharding: batch contents are a pure function of
(seed, step, host_id), so every host generates exactly its own rows, any
step can be regenerated after restart, and elastic re-sharding (different
n_hosts) keeps the global batch identical.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Iterator, Optional

import numpy as np

from repro.configs.base import ArchConfig, ShapeConfig


@dataclasses.dataclass(frozen=True)
class DataConfig:
    seed: int = 0
    host_id: int = 0
    n_hosts: int = 1
    prefetch: int = 2
    # synthetic-corpus knobs: a mixture of Zipfian unigrams and short
    # copy/induction motifs so the loss has learnable structure
    zipf_a: float = 1.2
    motif_len: int = 8
    motif_prob: float = 0.3


def _rng_for(cfg: DataConfig, step: int) -> np.random.Generator:
    return np.random.default_rng(
        np.random.SeedSequence([cfg.seed, step, cfg.host_id]))


def synth_batch(arch: ArchConfig, shape: ShapeConfig, cfg: DataConfig,
                step: int) -> dict:
    """One host-local batch for `step` (pure function — restart-safe)."""
    assert shape.global_batch % cfg.n_hosts == 0
    b = shape.global_batch // cfg.n_hosts
    s = shape.seq_len
    rng = _rng_for(cfg, step)

    s_txt = s
    batch: dict = {}
    if arch.frontend == "vision":
        nv = arch.n_frontend_tokens
        batch["vision_embeds"] = rng.standard_normal(
            (b, nv, arch.frontend_dim), dtype=np.float32)
        s_txt = s - nv
    if arch.family == "encdec":
        batch["enc_embeds"] = rng.standard_normal(
            (b, s, arch.frontend_dim), dtype=np.float32)

    # Zipfian unigram stream
    v = arch.vocab
    toks = rng.zipf(cfg.zipf_a, size=(b, s_txt)).astype(np.int64)
    toks = np.clip(toks, 1, v - 1).astype(np.int32)
    # inject copy motifs: tokens[i..i+L] = tokens[i-L..i] (induction heads)
    n_motifs = int(cfg.motif_prob * s_txt / max(cfg.motif_len, 1))
    for row in range(b):
        starts = rng.integers(cfg.motif_len, max(s_txt - cfg.motif_len,
                                                 cfg.motif_len + 1),
                              size=n_motifs)
        for st in starts:
            seg = toks[row, st - cfg.motif_len:st]
            toks[row, st:st + cfg.motif_len] = seg[:max(
                0, min(cfg.motif_len, s_txt - st))]
    batch["tokens"] = toks
    batch["labels"] = np.concatenate(
        [toks[:, 1:], np.full((b, 1), -1, np.int32)], axis=1)
    return batch


class Prefetcher:
    """Bounded-queue background producer (the EMPA 'service core')."""

    def __init__(self, arch: ArchConfig, shape: ShapeConfig,
                 cfg: Optional[DataConfig] = None, start_step: int = 0):
        self.arch, self.shape = arch, shape
        self.cfg = cfg or DataConfig()
        self._q: queue.Queue = queue.Queue(maxsize=self.cfg.prefetch)
        self._step = start_step
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _worker(self):
        step = self._step
        while not self._stop.is_set():
            batch = synth_batch(self.arch, self.shape, self.cfg, step)
            while not self._stop.is_set():
                try:
                    self._q.put((step, batch), timeout=0.1)
                    break
                except queue.Full:
                    continue
            step += 1

    def __iter__(self) -> Iterator[tuple[int, dict]]:
        return self

    def __next__(self):
        return self._q.get()

    def close(self):
        self._stop.set()
        self._thread.join(timeout=2)
