"""Mixture-of-Experts with sort-based fixed-capacity dispatch.

EMPA mapping: routing a token to an expert is *outsourcing a QT* — the
router is compile-time parallelization metadata, the expert pool is the
core pool (experts are rented per token, capacity = pool size), and the
weighted combine is a SUMUP-mode reduction (per-token partial results
stream back and are combined without materializing the dispatch tensor).

Implementation notes (TPU-native):
* group-local dispatch — tokens are processed in groups (the leading axis,
  sharded over the data axes), so argsort/gather/scatter stay shard-local;
  the expert einsums contract against expert-sharded weights, which GSPMD
  turns into the EP all-to-all pair.
* fixed capacity ``C = ceil(T·k/E · capacity_factor)`` per group; overflow
  tokens are dropped (standard Switch/GShard semantics; the capacity
  factor is configurable per arch).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax.ad_checkpoint import checkpoint_name

from repro.models import layers


def capacity(tokens_per_group: int, top_k: int, n_experts: int,
             factor: float) -> int:
    c = int(math.ceil(tokens_per_group * top_k * factor / n_experts))
    c = max(c, 1)
    if c >= 8:  # MXU-friendly
        c = (c + 7) // 8 * 8
    return c


def route(x, router_w, top_k: int):
    """x: (G, T, d); router_w: (d, E) -> (gates, idx, probs)."""
    logits = jnp.einsum("gtd,de->gte", x, router_w).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, idx = jax.lax.top_k(probs, top_k)           # (G, T, k)
    gates = gates / jnp.maximum(jnp.sum(gates, -1, keepdims=True), 1e-9)
    return gates, idx, probs


def load_balancing_loss(probs, idx, n_experts: int):
    """Switch-style aux loss: E * Σ_e f_e · P_e."""
    g, t, k = idx.shape
    sel = jax.nn.one_hot(idx, n_experts, dtype=jnp.float32)   # (G,T,k,E)
    f = jnp.mean(jnp.sum(sel, axis=2), axis=(0, 1))           # fraction routed
    p = jnp.mean(probs, axis=(0, 1))                          # mean router prob
    return n_experts * jnp.sum(f * p) / k


def dispatch_tables(idx, gates, n_experts: int, cap: int):
    """Sort-based dispatch: (G,T,k) assignments -> (G,E,C) token/gate tables.

    Shard-local per group: argsort + searchsorted give each assignment its
    rank within its expert; ranks >= capacity are dropped.
    Returns (buf_tok, buf_gate); buf_tok == T marks an empty slot.
    """
    g, t, k = idx.shape
    flat = idx.reshape(g, t * k)
    gflat = gates.reshape(g, t * k)
    order = jnp.argsort(flat, axis=-1, stable=True)           # (G, T*k)
    sorted_eid = jnp.take_along_axis(flat, order, axis=-1)
    sorted_gate = jnp.take_along_axis(gflat, order, axis=-1)
    # rank within expert group = position - first occurrence of the expert
    first = jax.vmap(lambda se: jnp.searchsorted(se, se, side="left"))(sorted_eid)
    rank = jnp.arange(t * k)[None, :] - first
    keep = rank < cap
    tok_of = order // k
    gi = jnp.arange(g)[:, None]
    # scatter into (G, E, C+1); dropped slots land in the trash column C
    buf_tok = jnp.full((g, n_experts, cap + 1), t, jnp.int32)
    buf_gate = jnp.zeros((g, n_experts, cap + 1), jnp.float32)
    col = jnp.where(keep, rank, cap)
    buf_tok = buf_tok.at[gi, sorted_eid, col].set(
        jnp.where(keep, tok_of, t).astype(jnp.int32))
    buf_gate = buf_gate.at[gi, sorted_eid, col].set(
        jnp.where(keep, sorted_gate, 0.0))
    return buf_tok[:, :, :cap], buf_gate[:, :, :cap]


def moe_ffn(x, p, cfg, act: str = "silu"):
    """x: (G, T, d) -> (y, aux_loss).

    p: router (d, E); w_gate/w_up (E, d, f); w_down (E, f, d);
       optional shared expert: sh_gate/sh_up (d, f·n_sh), sh_down (f·n_sh, d).
    """
    gdim, t, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    cap = capacity(t, k, e, cfg.capacity_factor)

    gates, idx, probs = route(x, p["router"], k)
    aux = load_balancing_loss(probs, idx, e)
    buf_tok, buf_gate = dispatch_tables(idx, gates, e, cap)

    # gather: (G, E, C, d); row T is a zero pad
    x_pad = jnp.concatenate([x, jnp.zeros((gdim, 1, d), x.dtype)], axis=1)
    gi = jnp.arange(gdim)[:, None, None]
    xe = x_pad[gi, buf_tok]                                    # (G, E, C, d)
    xe = _shard(xe, ("batch", "experts", None, None))

    # expert computation (E contracted against expert-sharded weights -> EP)
    a = layers.act_fn(act)
    h = a(jnp.einsum("gecd,edf->gecf", xe, p["w_gate"])) * \
        jnp.einsum("gecd,edf->gecf", xe, p["w_up"])
    ye = jnp.einsum("gecf,efd->gecd", h, p["w_down"])
    ye = _shard(ye, ("batch", "experts", None, None))

    # combine: weighted scatter-add back to tokens (SUMUP-style reduce).
    # Accumulate in the activation dtype: the cross-expert-shard psum of
    # this tensor dominates the MoE's collective bytes, and bf16 halves it
    # (§Perf E1); top-k gates are normalized, so the sum has ≤ k addends.
    y = jnp.zeros((gdim, t + 1, d), x.dtype)
    y = y.at[gi, buf_tok].add((ye.astype(jnp.float32)
                               * buf_gate[..., None]).astype(x.dtype))
    y = y[:, :t]
    # name the combined output so the remat policy can SAVE it: recomputing
    # the MoE block in backward would replay its collectives (§Perf E2)
    y = checkpoint_name(y, "moe_out")

    if "sh_up" in p:  # always-on shared experts (Moonlight/DeepSeek style)
        y = y + layers.mlp(x, {"w_gate": p["sh_gate"], "w_up": p["sh_up"],
                               "w_down": p["sh_down"]}, act)
    return y, aux


def _shard(x, axes):
    from repro.runtime.sharding import shard
    return shard(x, axes)


# ---------------------------------------------------------------------------
# shard_map EP path (§Perf E2): explicit locality
# ---------------------------------------------------------------------------
# GSPMD cannot prove the dispatch gather / combine scatter are batched-local
# per data shard, so the pjit path all-gathers the full (G, T+1, d) hidden
# over the data axis per MoE layer (measured: the dominant collective term
# for both MoE archs).  The shard_map path makes the EMPA structure
# explicit: routing and dispatch are LOCAL to the data shard (a parent
# keeps its own QTs), each model shard computes its expert slice, and ONE
# psum over "model" combines the partial outputs (the latched clone-back).

def moe_ffn_sharded(x, p, cfg, act: str, mesh):
    """x: (G, T, d).  Requires G divisible by the data axes product."""
    from jax.sharding import PartitionSpec as P
    from jax.experimental.shard_map import shard_map

    e, k = cfg.n_experts, cfg.top_k
    gdim, t, d = x.shape
    cap = capacity(t, k, e, cfg.capacity_factor)
    dp = tuple(a for a in ("pod", "data") if a in mesh.shape)
    n_model = mesh.shape["model"]
    e_loc = e // n_model

    def body(x_loc, router_w, wg, wu, wd):
        # FSDP: clone the glue on rent — gather the weight shards once
        wg = jax.lax.all_gather(wg, "data", axis=1, tiled=True)
        wu = jax.lax.all_gather(wu, "data", axis=1, tiled=True)
        wd = jax.lax.all_gather(wd, "data", axis=2, tiled=True)

        gates, idx, probs = route(x_loc, router_w, k)
        aux = load_balancing_loss(probs, idx, e)
        for ax in dp:
            aux = jax.lax.pmean(aux, ax)
        buf_tok, buf_gate = dispatch_tables(idx, gates, e, cap)
        # this model shard serves experts [e0, e0 + e_loc)
        e0 = jax.lax.axis_index("model") * e_loc
        tok_loc = jax.lax.dynamic_slice_in_dim(buf_tok, e0, e_loc, axis=1)
        gate_loc = jax.lax.dynamic_slice_in_dim(buf_gate, e0, e_loc, axis=1)

        g_loc = x_loc.shape[0]
        x_pad = jnp.concatenate(
            [x_loc, jnp.zeros((g_loc, 1, d), x_loc.dtype)], axis=1)
        gi = jnp.arange(g_loc)[:, None, None]
        xe = x_pad[gi, tok_loc]                       # local gather
        a = layers.act_fn(act)
        h = a(jnp.einsum("gecd,edf->gecf", xe, wg)) * \
            jnp.einsum("gecd,edf->gecf", xe, wu)
        ye = jnp.einsum("gecf,efd->gecd", h, wd)
        y = jnp.zeros((g_loc, t + 1, d), x_loc.dtype)
        y = y.at[gi, tok_loc].add(
            (ye.astype(jnp.float32) * gate_loc[..., None]).astype(x_loc.dtype))
        # the ONE combine collective: partial expert outputs -> tokens
        y = jax.lax.psum(y[:, :t], "model")
        return y, aux

    y, aux = shard_map(
        body, mesh=mesh,
        in_specs=(P(dp, None, None), P(None, None),
                  P("model", "data", None), P("model", "data", None),
                  P("model", None, "data")),
        out_specs=(P(dp, None, None), P()),
        check_rep=False,
    )(x, p["router"], p["w_gate"], p["w_up"], p["w_down"])
    y = checkpoint_name(y, "moe_out")

    if "sh_up" in p:   # always-on shared experts: plain dense MLP (GSPMD)
        y = y + layers.mlp(x, {"w_gate": p["sh_gate"], "w_up": p["sh_up"],
                               "w_down": p["sh_down"]}, act)
    return y, aux


def moe_flops(tokens: int, d: int, f: int, top_k: int, n_shared: int) -> float:
    return 2.0 * tokens * d * f * 3 * (top_k + n_shared)
