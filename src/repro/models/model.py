"""Unified LM covering all assigned architecture families.

One parameter-definition table (`param_defs`) drives initialization,
abstract (dry-run) parameters and partition specs.  One set of step
functions (`loss_fn`, `prefill`, `decode_step`) covers:

* dense / MoE / VLM decoder-only transformers (GQA + RoPE + SwiGLU),
* Mamba2 SSD stacks (attention-free),
* zamba2-style hybrids (SSD stack + ONE shared attention block applied
  every k layers — the shared block is a rented core: one weight set,
  many QTs),
* whisper-style encoder-decoder (stub audio frontend per assignment).

Layers are stacked (leading L axis) and scanned — the FOR-mode discipline:
the loop lives in one compiled `lax.scan`, layer weights are all-gathered
(FSDP) right before use, exactly EMPA's clone-the-glue-on-rent.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.ad_checkpoint import checkpoint_name

from repro.configs.base import ArchConfig
from repro.models import attention as attn_lib
from repro.models import layers, moe, ssm
from repro.models.params import ParamDef, abstract_params, axes_tree, init_params

BLOCKWISE_THRESHOLD = 2048   # use online-softmax attention above this S
AUX_LOSS_WEIGHT = 0.01
LOSS_CHUNK = 1024            # FOR-mode chunked CE (never materialize B,S,V)


# ===========================================================================
# Parameter definitions
# ===========================================================================

def _attn_defs(prefix, cfg: ArchConfig, n_layers: Optional[int],
               cross: bool = False) -> list[ParamDef]:
    d, h, hkv, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    lead = () if n_layers is None else (n_layers,)
    la = () if n_layers is None else ("layers",)
    sfx = "x" if cross else ""
    return [
        ParamDef(prefix + (f"w{sfx}q",), lead + (d, h, dh),
                 la + ("w_embed", "heads", None)),
        ParamDef(prefix + (f"w{sfx}k",), lead + (d, hkv, dh),
                 la + ("w_embed", "kv_heads", None)),
        ParamDef(prefix + (f"w{sfx}v",), lead + (d, hkv, dh),
                 la + ("w_embed", "kv_heads", None)),
        ParamDef(prefix + (f"w{sfx}o",), lead + (h, dh, d),
                 la + ("heads", None, "w_embed"),
                 scale=1.0 / (h * dh) ** 0.5),
    ]


def _mlp_defs(prefix, cfg: ArchConfig, n_layers: Optional[int]) -> list[ParamDef]:
    d, f = cfg.d_model, cfg.d_ff
    lead = () if n_layers is None else (n_layers,)
    la = () if n_layers is None else ("layers",)
    out = []
    if cfg.act == "silu":
        out.append(ParamDef(prefix + ("w_gate",), lead + (d, f),
                            la + ("w_embed", "ffn")))
    out += [
        ParamDef(prefix + ("w_up",), lead + (d, f), la + ("w_embed", "ffn")),
        ParamDef(prefix + ("w_down",), lead + (f, d), la + ("ffn", "w_embed")),
    ]
    return out


def _moe_defs(prefix, cfg: ArchConfig, n_layers: int) -> list[ParamDef]:
    d, fe, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    ll, la = (n_layers,), ("layers",)
    out = [
        ParamDef(prefix + ("router",), ll + (d, e), la + (None, None)),
        ParamDef(prefix + ("w_gate",), ll + (e, d, fe),
                 la + ("experts", "w_embed", "ffn")),
        ParamDef(prefix + ("w_up",), ll + (e, d, fe),
                 la + ("experts", "w_embed", "ffn")),
        ParamDef(prefix + ("w_down",), ll + (e, fe, d),
                 la + ("experts", "ffn", "w_embed")),
    ]
    if cfg.n_shared_experts:
        fs = fe * cfg.n_shared_experts
        out += [
            ParamDef(prefix + ("sh_gate",), ll + (d, fs), la + ("w_embed", "ffn")),
            ParamDef(prefix + ("sh_up",), ll + (d, fs), la + ("w_embed", "ffn")),
            ParamDef(prefix + ("sh_down",), ll + (fs, d), la + ("ffn", "w_embed")),
        ]
    return out


def _mamba_defs(prefix, cfg: ArchConfig, n_layers: int) -> list[ParamDef]:
    d, di = cfg.d_model, cfg.d_inner
    k, cdim, h = ssm.proj_dim(cfg), ssm.conv_dim(cfg), cfg.ssm_nheads
    ll, la = (n_layers,), ("layers",)
    return [
        ParamDef(prefix + ("ln",), ll + (d,), la + (None,), init="ones"),
        ParamDef(prefix + ("w_in",), ll + (d, k), la + ("w_embed", "conv_dim")),
        ParamDef(prefix + ("conv_w",), ll + (cfg.ssm_conv, cdim),
                 la + (None, "conv_dim"), scale=0.1),
        ParamDef(prefix + ("conv_b",), ll + (cdim,), la + ("conv_dim",),
                 init="zeros"),
        ParamDef(prefix + ("a_log",), ll + (h,), la + ("ssm_heads",),
                 init="zeros"),
        ParamDef(prefix + ("d_skip",), ll + (h,), la + ("ssm_heads",),
                 init="ones"),
        ParamDef(prefix + ("dt_bias",), ll + (h,), la + ("ssm_heads",),
                 init="zeros"),
        ParamDef(prefix + ("norm_w",), ll + (di,), la + ("conv_dim",),
                 init="ones"),
        ParamDef(prefix + ("w_out",), ll + (di, d), la + ("conv_dim", "w_embed"),
                 scale=1.0 / di**0.5),
    ]


def _norm(prefix, cfg, n_layers, name) -> ParamDef:
    lead = () if n_layers is None else (n_layers,)
    la = () if n_layers is None else ("layers",)
    return ParamDef(prefix + (name,), lead + (cfg.d_model,), la + (None,),
                    init="ones")


def param_defs(cfg: ArchConfig) -> list[ParamDef]:
    # embedding tables use the TP-padded vocab; logits beyond cfg.vocab are
    # masked at the loss/decode boundary (layers.unembed_logits)
    d, v = cfg.d_model, cfg.vocab_padded
    defs: list[ParamDef] = [
        ParamDef(("embed", "tok"), (v, d), ("vocab", "w_embed"), init="embed"),
        ParamDef(("final_norm",), (d,), (None,), init="ones"),
    ]
    if not cfg.tie_embeddings:
        defs.append(ParamDef(("unembed",), (v, d), ("vocab", "w_embed"),
                             init="embed"))
    if cfg.pos_embed == "learned":
        defs.append(ParamDef(("embed", "pos"), (cfg.max_position, d),
                             (None, "w_embed"), init="embed"))
    if cfg.frontend:
        defs.append(ParamDef(("frontend", "proj"), (cfg.frontend_dim, d),
                             (None, "w_embed")))

    fam = cfg.family
    if fam in ("dense", "moe", "vlm"):
        L = cfg.n_layers
        defs += [_norm(("layers",), cfg, L, "ln1"), _norm(("layers",), cfg, L, "ln2")]
        defs += _attn_defs(("layers",), cfg, L)
        if cfg.is_moe:
            defs += _moe_defs(("layers",), cfg, L)
        else:
            defs += _mlp_defs(("layers",), cfg, L)
    elif fam == "ssm":
        defs += _mamba_defs(("layers",), cfg, cfg.n_layers)
    elif fam == "hybrid":
        defs += _mamba_defs(("layers",), cfg, cfg.n_layers)
        defs += [_norm(("shared",), cfg, None, "ln1"),
                 _norm(("shared",), cfg, None, "ln2")]
        defs += _attn_defs(("shared",), cfg, None)
        defs += _mlp_defs(("shared",), cfg, None)
    elif fam == "encdec":
        Le, Ld = cfg.enc_layers, cfg.dec_layers
        defs += [_norm(("encoder",), cfg, Le, "ln1"),
                 _norm(("encoder",), cfg, Le, "ln2")]
        defs += _attn_defs(("encoder",), cfg, Le)
        defs += _mlp_defs(("encoder",), cfg, Le)
        defs.append(ParamDef(("enc_norm",), (d,), (None,), init="ones"))
        defs += [_norm(("decoder",), cfg, Ld, "ln1"),
                 _norm(("decoder",), cfg, Ld, "lnx"),
                 _norm(("decoder",), cfg, Ld, "ln2")]
        defs += _attn_defs(("decoder",), cfg, Ld)
        defs += _attn_defs(("decoder",), cfg, Ld, cross=True)
        defs += _mlp_defs(("decoder",), cfg, Ld)
    else:
        raise ValueError(fam)
    return defs


def init(key, cfg: ArchConfig, dtype=jnp.bfloat16):
    return init_params(param_defs(cfg), key, dtype)


def abstract(cfg: ArchConfig, dtype=jnp.bfloat16):
    return abstract_params(param_defs(cfg), dtype)


def logical_axes(cfg: ArchConfig):
    return axes_tree(param_defs(cfg))


# ===========================================================================
# Blocks
# ===========================================================================

def _sh(x, axes):
    from repro.runtime.sharding import shard
    return shard(x, axes)


def _attention(x_q, x_kv, p, cfg: ArchConfig, q_pos, kv_pos, *, causal,
               sfx="", cache_kv=None, cache_len=None):
    """Projections + RoPE + attention.  Returns (out, (k, v))."""
    q = jnp.einsum("bsd,dhk->bshk", x_q, p[f"w{sfx}q"])
    k = jnp.einsum("bsd,dhk->bshk", x_kv, p[f"w{sfx}k"])
    v = jnp.einsum("bsd,dhk->bshk", x_kv, p[f"w{sfx}v"])
    if cfg.pos_embed == "rope":
        q = layers.apply_rope(q, q_pos, cfg.rope_theta)
        k = layers.apply_rope(k, kv_pos, cfg.rope_theta)
    q = _sh(q, ("batch", None, "heads_act", None))
    if cache_kv is not None:
        # decode: attend over the cache (k/v already written by caller)
        ck, cv = cache_kv
        o = attn_lib.decode_attention(q, ck, cv, cache_len)
    elif x_q.shape[1] > BLOCKWISE_THRESHOLD:
        o = attn_lib.blockwise_attention(q, k, v, causal=causal)
    else:
        o = attn_lib.full_attention(q, k, v, causal=causal)
    out = jnp.einsum("bshk,hkd->bsd", o, p[f"w{sfx}o"])
    return out, (k, v)


def _ffn(x, p, cfg: ArchConfig):
    """MLP or MoE.  Returns (y, aux_loss)."""
    if cfg.is_moe:
        from repro.runtime.sharding import current_rules
        rules = current_rules()
        if rules is not None and _moe_shardable(x, cfg, rules.mesh):
            return moe.moe_ffn_sharded(x, p, cfg, cfg.act, rules.mesh)
        return moe.moe_ffn(x, p, cfg, cfg.act)
    return layers.mlp(x, p, cfg.act), jnp.float32(0.0)


def _moe_shardable(x, cfg, mesh) -> bool:
    """The explicit-locality EP path needs clean divisibility (see moe.py)."""
    if "model" not in mesh.shape or "data" not in mesh.shape:
        return False
    dp = 1
    for a in ("pod", "data"):
        dp *= mesh.shape.get(a, 1)
    return (cfg.n_experts % mesh.shape["model"] == 0
            and cfg.d_model % mesh.shape["data"] == 0
            and x.shape[0] % dp == 0)


def _decoder_layer(x, lp, cfg: ArchConfig, positions):
    # NOTE (§Perf, granite-8b E3 — REFUTED): constraining the residual to
    # S-sharded-over-model here (Megatron sequence parallelism) made the
    # collective term 4× WORSE under GSPMD: the blockwise-attention KV
    # chunk path hits involuntary remat and the per-microbatch weight
    # grads get all-reduced over data.  Proper SP needs a manual
    # shard_map attention block; left as future work.
    h, _ = _attention(layers.rms_norm(x, lp["ln1"], cfg.norm_eps),
                      layers.rms_norm(x, lp["ln1"], cfg.norm_eps),
                      lp, cfg, positions, positions, causal=True)
    # named so the "block_save" remat policy can keep the TP-psum'd block
    # outputs: backward then never replays the psums (§Perf E4)
    x = x + checkpoint_name(h, "attn_out")
    y, aux = _ffn(layers.rms_norm(x, lp["ln2"], cfg.norm_eps), lp, cfg)
    return x + checkpoint_name(y, "mlp_out"), aux


def _mamba_layer(x, lp, cfg: ArchConfig):
    h, _ = ssm.mamba2_block(layers.rms_norm(x, lp["ln"], cfg.norm_eps), lp, cfg)
    return x + h


def _shared_attn_block(x, sp, cfg: ArchConfig, positions):
    h, kv = _attention(layers.rms_norm(x, sp["ln1"], cfg.norm_eps),
                       layers.rms_norm(x, sp["ln1"], cfg.norm_eps),
                       sp, cfg, positions, positions, causal=True)
    x = x + h
    y = layers.mlp(layers.rms_norm(x, sp["ln2"], cfg.norm_eps), sp, cfg.act)
    return x + y, kv


# ===========================================================================
# Forward (training / full-sequence)
# ===========================================================================

def _embed_inputs(params, batch, cfg: ArchConfig):
    """Token (+frontend) embedding.  Returns (x (B,S,d), positions (S,))."""
    tok = batch["tokens"]
    x = layers.embed(params["embed"]["tok"], tok)
    if cfg.frontend == "vision":
        vis = jnp.einsum("bnf,fd->bnd",
                         batch["vision_embeds"].astype(x.dtype),
                         params["frontend"]["proj"])
        x = jnp.concatenate([vis, x], axis=1)
    positions = jnp.arange(x.shape[1], dtype=jnp.int32)
    if cfg.pos_embed == "learned":
        x = x + layers.learned_pos_embed(params["embed"]["pos"], positions)
    return _sh(x, ("batch", None, None)), positions


def _maybe_remat(body, remat, policy):
    if not remat:
        return body
    return jax.checkpoint(body, policy=policy)


def _run_stack(params, x, cfg: ArchConfig, positions, *, remat,
               remat_policy=None):
    """Scan the decoder stack. Returns (x, aux_loss)."""
    fam = cfg.family

    if fam in ("dense", "moe", "vlm"):
        def body(carry, lp):
            y, aux = _decoder_layer(carry, lp, cfg, positions)
            return y, aux
        f = _maybe_remat(body, remat, remat_policy)
        x, auxs = jax.lax.scan(f, x, params["layers"])
        return x, jnp.sum(auxs)

    if fam == "ssm":
        def body(carry, lp):
            return _mamba_layer(carry, lp, cfg), jnp.float32(0.0)
        f = _maybe_remat(body, remat, remat_policy)
        x, _ = jax.lax.scan(f, x, params["layers"])
        return x, jnp.float32(0.0)

    if fam == "hybrid":
        every = cfg.shared_attn_every
        sp = params["shared"]

        def body(carry, inp):
            lp, idx = inp
            y = _mamba_layer(carry, lp, cfg)
            y = jax.lax.cond(
                (idx % every) == every - 1,
                lambda z: _shared_attn_block(z, sp, cfg, positions)[0],
                lambda z: z, y)
            return y, jnp.float32(0.0)
        f = _maybe_remat(body, remat, remat_policy)
        x, _ = jax.lax.scan(f, x, (params["layers"],
                                   jnp.arange(cfg.n_layers)))
        return x, jnp.float32(0.0)

    raise ValueError(fam)


def _encoder(params, batch, cfg: ArchConfig):
    """Whisper-style encoder over precomputed frame embeddings (stub)."""
    frames = batch["enc_embeds"]
    x = jnp.einsum("bsf,fd->bsd",
                   frames.astype(params["frontend"]["proj"].dtype),
                   params["frontend"]["proj"])
    pos = jnp.arange(x.shape[1], dtype=jnp.int32)
    if cfg.pos_embed == "learned":
        x = x + layers.learned_pos_embed(params["embed"]["pos"], pos)

    def body(carry, lp):
        h, _ = _attention(layers.rms_norm(carry, lp["ln1"], cfg.norm_eps),
                          layers.rms_norm(carry, lp["ln1"], cfg.norm_eps),
                          lp, cfg, pos, pos, causal=False)
        y = carry + h
        m = layers.mlp(layers.rms_norm(y, lp["ln2"], cfg.norm_eps), lp, cfg.act)
        return y + m, None
    x, _ = jax.lax.scan(body, x, params["encoder"])
    return layers.rms_norm(x, params["enc_norm"], cfg.norm_eps)


def _decoder_encdec(params, x, enc_out, cfg: ArchConfig, positions,
                    remat, remat_policy=None):
    enc_pos = jnp.arange(enc_out.shape[1], dtype=jnp.int32)

    def body(carry, lp):
        h, _ = _attention(layers.rms_norm(carry, lp["ln1"], cfg.norm_eps),
                          layers.rms_norm(carry, lp["ln1"], cfg.norm_eps),
                          lp, cfg, positions, positions, causal=True)
        y = carry + h
        hx, _ = _attention(layers.rms_norm(y, lp["lnx"], cfg.norm_eps),
                           enc_out, lp, cfg, positions, enc_pos,
                           causal=False, sfx="x")
        y = y + hx
        m = layers.mlp(layers.rms_norm(y, lp["ln2"], cfg.norm_eps), lp, cfg.act)
        return y + m, None
    f = _maybe_remat(body, remat, remat_policy)
    x, _ = jax.lax.scan(f, x, params["decoder"])
    return x


def forward(params, batch, cfg: ArchConfig, *, remat=False,
            remat_policy=None):
    """Full-sequence forward.  Returns (hidden (B,S,d), aux_loss)."""
    if cfg.family == "encdec":
        enc_out = _encoder(params, batch, cfg)
        x = layers.embed(params["embed"]["tok"], batch["tokens"])
        positions = jnp.arange(x.shape[1], dtype=jnp.int32)
        if cfg.pos_embed == "learned":
            x = x + layers.learned_pos_embed(params["embed"]["pos"], positions)
        x = _decoder_encdec(params, x, enc_out, cfg, positions, remat,
                            remat_policy)
        aux = jnp.float32(0.0)
    else:
        x, positions = _embed_inputs(params, batch, cfg)
        x, aux = _run_stack(params, x, cfg, positions, remat=remat,
                            remat_policy=remat_policy)
    return layers.rms_norm(x, params["final_norm"], cfg.norm_eps), aux


def _unembed_table(params, cfg: ArchConfig):
    return params["embed"]["tok"] if cfg.tie_embeddings else params["unembed"]


def _logits(x, params, cfg: ArchConfig):
    return layers.unembed_logits(x, _unembed_table(params, cfg),
                                 true_vocab=cfg.vocab)


def chunked_loss(x, table, labels, chunk: int = LOSS_CHUNK,
                 true_vocab=None):
    """FOR-mode CE: scan over sequence chunks; (B,S,V) never materializes."""
    b, s, _ = x.shape
    chunk = min(chunk, s)
    if s % chunk:
        chunk = s  # odd smoke-test sizes: single chunk
    nc = s // chunk
    xc = x.reshape(b, nc, chunk, -1).swapaxes(0, 1)
    lc = labels.reshape(b, nc, chunk).swapaxes(0, 1)

    def body(carry, inp):
        xb, lb = inp
        logits = layers.unembed_logits(xb, table, true_vocab=true_vocab)
        logits = _sh(logits, ("batch", None, "vocab_act"))
        n = jnp.sum((lb >= 0).astype(jnp.float32))
        return (carry[0] + layers.cross_entropy(logits, lb) * n,
                carry[1] + n), None
    (tot, n), _ = jax.lax.scan(body, (jnp.float32(0.0), jnp.float32(0.0)),
                               (xc, lc))
    return tot / jnp.maximum(n, 1.0)


def loss_fn(params, batch, cfg: ArchConfig, *, remat=True, remat_policy=None):
    """Mean next-token CE (+ MoE aux).  Returns (loss, metrics)."""
    x, aux = forward(params, batch, cfg, remat=remat,
                     remat_policy=remat_policy)
    labels = batch["labels"]
    if cfg.frontend == "vision":
        x = x[:, -labels.shape[1]:]   # loss over the text tail only
    ce = chunked_loss(x, _unembed_table(params, cfg), labels,
                      true_vocab=cfg.vocab)
    loss = ce + AUX_LOSS_WEIGHT * aux
    return loss, {"ce": ce, "aux": aux}


# ===========================================================================
# Serving: prefill + decode with caches
# ===========================================================================

def _kv_cache_axes():
    return ("cache_batch", None, "cache_kv_heads", None)


@dataclasses.dataclass(frozen=True)
class PagedLayout:
    """Paged KV layout: the cache is a pool of `n_blocks` blocks of
    `block_size` positions, addressed per slot through a block table
    (runtime/paging.py owns the rent/release discipline over them).

    Only causal attention-cache families (dense/moe/vlm) page; recurrent
    state (ssm/hybrid) is O(1) per slot and has nothing to page.
    """

    block_size: int
    n_blocks: int

    def max_blocks(self, max_seq: int) -> int:
        return -(-max_seq // self.block_size)


PAGED_FAMILIES = ("dense", "moe", "vlm")


def init_cache(cfg: ArchConfig, batch: int, max_seq: int, dtype=jnp.bfloat16,
               abstract_only: bool = False,
               layout: Optional[PagedLayout] = None):
    """Cache pytree for `decode_step` (shapes depend on the family).

    With `layout` given, attention K/V live in `(L, n_blocks, block_size,
    hkv, dh)` pages plus a per-slot `block_tables` leaf (-1 = end of
    chain); without it, the contiguous `(L, batch, max_seq, hkv, dh)`
    allocation.  Both shapes go through the same `decode_step`.
    """
    mk = (lambda s, dt: jax.ShapeDtypeStruct(s, dt)) if abstract_only else \
         (lambda s, dt: jnp.zeros(s, dt))

    def kv(n_layers: int, *names: str) -> dict:
        hkv, dh = cfg.n_kv_heads, cfg.head_dim
        shape = (n_layers, batch, max_seq, hkv, dh) if layout is None else \
            (n_layers, layout.n_blocks, layout.block_size, hkv, dh)
        return {name: mk(shape, dtype) for name in names}

    def recurrent() -> dict:
        return {
            "conv": mk((cfg.n_layers, batch, cfg.ssm_conv - 1,
                        ssm.conv_dim(cfg)), dtype),
            "state": mk((cfg.n_layers, batch, cfg.ssm_nheads,
                         cfg.ssm_headdim, cfg.ssm_state), jnp.float32),
        }

    fam = cfg.family
    if layout is not None and fam not in PAGED_FAMILIES:
        raise ValueError(
            f"paged KV cache supports {PAGED_FAMILIES}, not {fam!r}: "
            "recurrent/cross-attention state is not paged")
    cache = {"pos": mk((batch,), jnp.int32)}
    if fam in ("dense", "moe", "vlm"):
        cache.update(kv(cfg.n_layers, "k", "v"))
        if layout is not None:
            nb = layout.max_blocks(max_seq)
            cache["block_tables"] = mk((batch, nb), jnp.int32) \
                if abstract_only else jnp.full((batch, nb), -1, jnp.int32)
    elif fam == "ssm":
        cache.update(recurrent())
    elif fam == "hybrid":
        cache.update(recurrent())
        cache.update(kv(cfg.n_layers // cfg.shared_attn_every, "k", "v"))
    elif fam == "encdec":
        cache.update(kv(cfg.dec_layers, "k", "v", "xk", "xv"))
    return cache


def _prefill_paged(params, batch, cfg: ArchConfig, max_seq: int,
                   layout: PagedLayout, lengths=None):
    """Paged prefill: contiguous prefill over the (block-rounded) prompt
    span, then scatter the K/V blocks into pages with full identity
    chains (row i owns blocks ``i*nb_full .. (i+1)*nb_full - 1``, so
    decode up to ``max_seq`` never needs growth).  The serving engine
    instead scatters into *rented* blocks and grows chains on demand
    (runtime/paging.py); this path is the standalone cache API (plans,
    parity tests, single-shot generation)."""
    if cfg.family not in PAGED_FAMILIES:    # fail before the inner prefill
        raise ValueError(
            f"paged KV cache supports {PAGED_FAMILIES}, not {cfg.family!r}")
    bsz = batch["tokens"].shape[0]
    bs = layout.block_size
    span = batch["tokens"].shape[1]
    if cfg.frontend == "vision":
        span += cfg.n_frontend_tokens
    span_pad = -(-span // bs) * bs
    nb = span_pad // bs
    nb_full = layout.max_blocks(max_seq)
    if bsz * nb_full > layout.n_blocks:
        raise ValueError(f"static paged prefill needs {bsz * nb_full} "
                         f"blocks, pool has {layout.n_blocks}")
    logits, cc = prefill(params, batch, cfg, span_pad, lengths=lengths)
    cache = init_cache(cfg, bsz, max_seq, dtype=cc["k"].dtype, layout=layout)
    n_layers = cc["k"].shape[0]
    hkv, dh = cfg.n_kv_heads, cfg.head_dim
    chains = jnp.arange(bsz * nb_full, dtype=jnp.int32).reshape(bsz, nb_full)
    for name in ("k", "v"):
        blocks = cc[name].reshape(n_layers, bsz, nb, bs, hkv, dh)
        cache[name] = cache[name].at[:, chains[:, :nb]].set(blocks)
    cache["block_tables"] = chains
    cache["pos"] = cc["pos"]
    return logits, cache


def prefill(params, batch, cfg: ArchConfig, max_seq: int, lengths=None,
            layout: Optional[PagedLayout] = None):
    """Run the prompt; return (last-token logits (B, V), filled cache).

    With ``lengths`` (B,) given, rows are right-padded prompts: logits are
    gathered at each row's last *valid* position and ``cache["pos"]`` is
    set per row, so one batched call prefills many admitted requests at
    once (continuous-batching packed prefill).  Causal attention keeps the
    valid prefix exact under right-padding, and the pad tail of the KV
    cache is masked at decode by ``pos``.  For recurrent families
    (ssm/hybrid) the state would absorb pad tokens — callers must pass
    exact-length rows (or ``lengths=None``) there.

    With ``layout`` given the returned cache is paged (see
    :class:`PagedLayout`); ``decode_step`` accepts either.
    """
    if layout is not None:
        return _prefill_paged(params, batch, cfg, max_seq, layout,
                              lengths=lengths)
    fam = cfg.family
    bsz = batch["tokens"].shape[0]
    # cache precision follows the parameters (bf16 in production, f32 in
    # the CPU consistency tests)
    cache = init_cache(cfg, bsz, max_seq, dtype=params["embed"]["tok"].dtype)

    if fam in ("dense", "moe", "vlm"):
        x, positions = _embed_inputs(params, batch, cfg)
        s = x.shape[1]

        def body(carry, lp):
            h_in = layers.rms_norm(carry, lp["ln1"], cfg.norm_eps)
            h, (k, v) = _attention(h_in, h_in, lp, cfg, positions, positions,
                                   causal=True)
            y = carry + h
            f, _ = _ffn(layers.rms_norm(y, lp["ln2"], cfg.norm_eps), lp, cfg)
            return y + f, (k, v)
        x, (ks, vs) = jax.lax.scan(body, x, params["layers"])
        cache["k"] = cache["k"].at[:, :, :s].set(ks.astype(cache["k"].dtype))
        cache["v"] = cache["v"].at[:, :, :s].set(vs.astype(cache["v"].dtype))
        cache["pos"] = jnp.full((bsz,), s, jnp.int32)

    elif fam == "ssm":
        x, _ = _embed_inputs(params, batch, cfg)
        s = x.shape[1]

        def body(carry, lp):
            h_in = layers.rms_norm(carry, lp["ln"], cfg.norm_eps)
            h, state = ssm.mamba2_block(h_in, lp, cfg)
            # conv tail for seamless decode continuation
            zxbcdt = jnp.einsum("bsd,dk->bsk", h_in[:, -cfg.ssm_conv + 1:],
                                lp["w_in"])
            conv_tail = zxbcdt[..., cfg.d_inner:
                               2 * cfg.d_inner + 2 * cfg.ssm_ngroups * cfg.ssm_state]
            return carry + h, (state, conv_tail)
        x, (states, tails) = jax.lax.scan(body, x, params["layers"])
        cache["state"] = states
        cache["conv"] = tails.astype(cache["conv"].dtype)
        cache["pos"] = jnp.full((bsz,), s, jnp.int32)

    elif fam == "hybrid":
        x, positions = _embed_inputs(params, batch, cfg)
        s = x.shape[1]
        every = cfg.shared_attn_every
        sp = params["shared"]
        shk, shv = cache["k"], cache["v"]

        def body(carry, inp):
            lp, idx = inp
            x_c, shk_c, shv_c = carry
            h_in = layers.rms_norm(x_c, lp["ln"], cfg.norm_eps)
            h, state = ssm.mamba2_block(h_in, lp, cfg)
            zxbcdt = jnp.einsum("bsd,dk->bsk", h_in[:, -cfg.ssm_conv + 1:],
                                lp["w_in"])
            conv_tail = zxbcdt[..., cfg.d_inner:
                               2 * cfg.d_inner + 2 * cfg.ssm_ngroups * cfg.ssm_state]
            y = x_c + h
            app = idx // every

            def apply_shared(args):
                z, shk_i, shv_i = args
                out, (k, v) = _shared_attn_block(z, sp, cfg, positions)
                shk_i = jax.lax.dynamic_update_slice(
                    shk_i, k[None, :, :, :, :].astype(shk_i.dtype),
                    (app, 0, 0, 0, 0))
                shv_i = jax.lax.dynamic_update_slice(
                    shv_i, v[None].astype(shv_i.dtype), (app, 0, 0, 0, 0))
                return out, shk_i, shv_i

            y, shk_c, shv_c = jax.lax.cond(
                (idx % every) == every - 1, apply_shared,
                lambda args: args, (y, shk_c, shv_c))
            return (y, shk_c, shv_c), (state, conv_tail)
        (x, shk, shv), (states, tails) = jax.lax.scan(
            body, (x, shk[:, :, :s], shv[:, :, :s]),
            (params["layers"], jnp.arange(cfg.n_layers)))
        cache["k"] = cache["k"].at[:, :, :s].set(shk.astype(cache["k"].dtype))
        cache["v"] = cache["v"].at[:, :, :s].set(shv.astype(cache["v"].dtype))
        cache["state"] = states
        cache["conv"] = tails.astype(cache["conv"].dtype)
        cache["pos"] = jnp.full((bsz,), s, jnp.int32)

    elif fam == "encdec":
        enc_out = _encoder(params, batch, cfg)
        se = enc_out.shape[1]
        x = layers.embed(params["embed"]["tok"], batch["tokens"])
        positions = jnp.arange(x.shape[1], dtype=jnp.int32)
        if cfg.pos_embed == "learned":
            x = x + layers.learned_pos_embed(params["embed"]["pos"], positions)
        enc_pos = jnp.arange(se, dtype=jnp.int32)
        s = x.shape[1]

        def body(carry, lp):
            h_in = layers.rms_norm(carry, lp["ln1"], cfg.norm_eps)
            h, (k, v) = _attention(h_in, h_in, lp, cfg, positions, positions,
                                   causal=True)
            y = carry + h
            hx, (xk, xv) = _attention(
                layers.rms_norm(y, lp["lnx"], cfg.norm_eps), enc_out, lp, cfg,
                positions, enc_pos, causal=False, sfx="x")
            y = y + hx
            m = layers.mlp(layers.rms_norm(y, lp["ln2"], cfg.norm_eps), lp,
                           cfg.act)
            return y + m, (k, v, xk, xv)
        x, (ks, vs, xks, xvs) = jax.lax.scan(body, x, params["decoder"])
        cache["k"] = cache["k"].at[:, :, :s].set(ks.astype(cache["k"].dtype))
        cache["v"] = cache["v"].at[:, :, :s].set(vs.astype(cache["v"].dtype))
        cache["xk"] = cache["xk"].at[:, :, :se].set(xks.astype(cache["xk"].dtype))
        cache["xv"] = cache["xv"].at[:, :, :se].set(xvs.astype(cache["xv"].dtype))
        cache["pos"] = jnp.full((bsz,), s, jnp.int32)
    else:
        raise ValueError(fam)

    x = layers.rms_norm(x, params["final_norm"], cfg.norm_eps)
    if lengths is None:
        x_last = x[:, -1]
    else:
        lengths = jnp.asarray(lengths, jnp.int32)
        # vision frontends prepend stub tokens: offset the text positions
        offset = x.shape[1] - batch["tokens"].shape[1]
        x_last = x[jnp.arange(bsz), offset + lengths - 1]
        cache["pos"] = (offset + lengths).astype(jnp.int32)
    logits = _logits(x_last, params, cfg)
    return logits, cache


def _decode_attn_layer(x1, lp, cfg, k_l, v_l, pos, sfx=""):
    """One-token attention against a cache layer; writes K/V at `pos`."""
    bsz = x1.shape[0]
    q_pos = pos[:, None] if pos.ndim == 1 else pos
    q = jnp.einsum("bsd,dhk->bshk", x1, lp[f"w{sfx}q"])
    k = jnp.einsum("bsd,dhk->bshk", x1, lp[f"w{sfx}k"])
    v = jnp.einsum("bsd,dhk->bshk", x1, lp[f"w{sfx}v"])
    if cfg.pos_embed == "rope":
        q = layers.apply_rope(q, q_pos, cfg.rope_theta)
        k = layers.apply_rope(k, q_pos, cfg.rope_theta)
    # write the new K/V at each row's position
    bidx = jnp.arange(bsz)
    k_l = k_l.at[bidx, pos].set(k[:, 0].astype(k_l.dtype))
    v_l = v_l.at[bidx, pos].set(v[:, 0].astype(v_l.dtype))
    o = attn_lib.decode_attention(q, k_l, v_l, pos + 1)
    out = jnp.einsum("bshk,hkd->bsd", o, lp[f"w{sfx}o"])
    return out, k_l, v_l


def _decode_attn_layer_paged(x1, lp, cfg, k_l, v_l, pos, blk, off, tables,
                             sfx=""):
    """One-token attention against a paged cache layer: write the new
    K/V into (block, offset) of each row's chain, then attend through
    the block table.  Rows with no valid block (retired / released
    chains, `blk` < 0) drop the write — they can never corrupt a live
    chain's pages."""
    n_pages = k_l.shape[0]
    q_pos = pos[:, None]
    q = jnp.einsum("bsd,dhk->bshk", x1, lp[f"w{sfx}q"])
    k = jnp.einsum("bsd,dhk->bshk", x1, lp[f"w{sfx}k"])
    v = jnp.einsum("bsd,dhk->bshk", x1, lp[f"w{sfx}v"])
    if cfg.pos_embed == "rope":
        q = layers.apply_rope(q, q_pos, cfg.rope_theta)
        k = layers.apply_rope(k, q_pos, cfg.rope_theta)
    wblk = jnp.where(blk >= 0, blk, n_pages)   # out of range -> dropped
    k_l = k_l.at[wblk, off].set(k[:, 0].astype(k_l.dtype), mode="drop")
    v_l = v_l.at[wblk, off].set(v[:, 0].astype(v_l.dtype), mode="drop")
    o = attn_lib.paged_decode_attention(q, k_l, v_l, tables, pos + 1)
    out = jnp.einsum("bshk,hkd->bsd", o, lp[f"w{sfx}o"])
    return out, k_l, v_l


def decode_step(params, token, cache, cfg: ArchConfig):
    """One decode step.  token: (B,) int32.  Returns (logits (B,V), cache).

    Accepts either cache layout from :func:`init_cache`: the presence of
    ``block_tables`` selects the paged write/attend path.
    """
    bsz = token.shape[0]
    pos = cache["pos"]
    x = layers.embed(params["embed"]["tok"], token)[:, None]   # (B,1,d)
    if cfg.pos_embed == "learned":
        x = x + layers.learned_pos_embed(params["embed"]["pos"],
                                         pos[:, None])
    fam = cfg.family

    if fam in PAGED_FAMILIES and "block_tables" in cache:
        tables = cache["block_tables"]
        blk_size = cache["k"].shape[2]
        nb = tables.shape[1]
        blk_idx = pos // blk_size
        blk = jnp.take_along_axis(
            tables, jnp.clip(blk_idx, 0, nb - 1)[:, None], axis=1)[:, 0]
        # beyond-capacity rows (frozen retired slots at pos == max_seq)
        # must not clamp into a live block
        blk = jnp.where(blk_idx < nb, blk, -1)
        off = pos % blk_size

        def body(carry, inp):
            lp, k_l, v_l = inp
            h_in = layers.rms_norm(carry, lp["ln1"], cfg.norm_eps)
            h, k_l, v_l = _decode_attn_layer_paged(h_in, lp, cfg, k_l, v_l,
                                                   pos, blk, off, tables)
            y = carry + h
            f, _ = _ffn(layers.rms_norm(y, lp["ln2"], cfg.norm_eps), lp, cfg)
            return y + f, (k_l, v_l)
        x, (ks, vs) = jax.lax.scan(body, x, (params["layers"], cache["k"],
                                             cache["v"]))
        cache = dict(cache, k=ks, v=vs)

    elif fam in ("dense", "moe", "vlm"):
        def body(carry, inp):
            lp, k_l, v_l = inp
            h_in = layers.rms_norm(carry, lp["ln1"], cfg.norm_eps)
            h, k_l, v_l = _decode_attn_layer(h_in, lp, cfg, k_l, v_l, pos)
            y = carry + h
            f, _ = _ffn(layers.rms_norm(y, lp["ln2"], cfg.norm_eps), lp, cfg)
            return y + f, (k_l, v_l)
        x, (ks, vs) = jax.lax.scan(body, x, (params["layers"], cache["k"],
                                             cache["v"]))
        cache = dict(cache, k=ks, v=vs)

    elif fam == "ssm":
        def body(carry, inp):
            lp, conv_l, state_l = inp
            h_in = layers.rms_norm(carry[:, 0], lp["ln"], cfg.norm_eps)
            h, conv_l, state_l = ssm.mamba2_decode(h_in, lp, cfg, conv_l,
                                                   state_l)
            return carry + h[:, None], (conv_l, state_l)
        x, (convs, states) = jax.lax.scan(
            body, x, (params["layers"], cache["conv"], cache["state"]))
        cache = dict(cache, conv=convs, state=states)

    elif fam == "hybrid":
        every = cfg.shared_attn_every
        sp = params["shared"]

        def body(carry, inp):
            lp, conv_l, state_l, idx = inp
            x_c, shk, shv = carry
            h_in = layers.rms_norm(x_c[:, 0], lp["ln"], cfg.norm_eps)
            h, conv_l, state_l = ssm.mamba2_decode(h_in, lp, cfg, conv_l,
                                                   state_l)
            y = x_c + h[:, None]
            app = idx // every

            def apply_shared(args):
                z, shk_i, shv_i = args
                k_l = jax.lax.dynamic_slice_in_dim(shk_i, app, 1, 0)[0]
                v_l = jax.lax.dynamic_slice_in_dim(shv_i, app, 1, 0)[0]
                h_a, k_l, v_l = _decode_attn_layer(
                    layers.rms_norm(z, sp["ln1"], cfg.norm_eps), sp, cfg,
                    k_l, v_l, pos)
                z2 = z + h_a
                m = layers.mlp(layers.rms_norm(z2, sp["ln2"], cfg.norm_eps),
                               sp, cfg.act)
                shk_i = jax.lax.dynamic_update_slice_in_dim(
                    shk_i, k_l[None], app, 0)
                shv_i = jax.lax.dynamic_update_slice_in_dim(
                    shv_i, v_l[None], app, 0)
                return z2 + m, shk_i, shv_i

            y, shk, shv = jax.lax.cond((idx % every) == every - 1,
                                       apply_shared, lambda a: a,
                                       (y, shk, shv))
            return (y, shk, shv), (conv_l, state_l)
        (x, shk, shv), (convs, states) = jax.lax.scan(
            body, (x, cache["k"], cache["v"]),
            (params["layers"], cache["conv"], cache["state"],
             jnp.arange(cfg.n_layers)))
        cache = dict(cache, k=shk, v=shv, conv=convs, state=states)

    elif fam == "encdec":
        enc_len = cache["pos"] * 0 + cache["xk"].shape[2]  # full cross memory

        def body(carry, inp):
            lp, k_l, v_l, xk_l, xv_l = inp
            h_in = layers.rms_norm(carry, lp["ln1"], cfg.norm_eps)
            h, k_l, v_l = _decode_attn_layer(h_in, lp, cfg, k_l, v_l, pos)
            y = carry + h
            qx = jnp.einsum("bsd,dhk->bshk",
                            layers.rms_norm(y, lp["lnx"], cfg.norm_eps),
                            lp["wxq"])
            ox = attn_lib.decode_attention(qx, xk_l, xv_l, enc_len)
            y = y + jnp.einsum("bshk,hkd->bsd", ox, lp["wxo"])
            m = layers.mlp(layers.rms_norm(y, lp["ln2"], cfg.norm_eps), lp,
                           cfg.act)
            return y + m, (k_l, v_l)
        x, (ks, vs) = jax.lax.scan(
            body, x, (params["decoder"], cache["k"], cache["v"],
                      cache["xk"], cache["xv"]))
        cache = dict(cache, k=ks, v=vs)
    else:
        raise ValueError(fam)

    x = layers.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = _logits(x[:, 0], params, cfg)
    cache["pos"] = pos + 1
    return logits, cache


def prefill_chunk(params, tokens, lengths, cache, cfg: ArchConfig,
                  skip_until=None, all_logits: bool = False):
    """Prefill *continuation*: consume one left-aligned prompt fragment
    per row against an existing cache, at each row's position offset.

    The paper's cores outsource fragments, not whole jobs — this is that
    discipline for prompts: instead of one monolithic prefill, the prompt
    is fed in ``(B, C)`` chunks, each writing K/V at positions
    ``cache["pos"] .. cache["pos"] + length - 1`` and attending causally
    through the position-offset mask (:func:`attention.chunk_attention`).

    * ``tokens`` (B, C) int32, ``lengths`` (B,) int32 — rows with length
      0 are untouched (no writes, ``pos`` unchanged, logits garbage);
      a length-1 row is exactly a decode step, so one call advances a
      mix of prefilling and decoding rows (the serving engine's unified
      tick).
    * ``skip_until`` (B,) int32 — optional write fence: positions below
      it are *not* stored (they live in shared prefix blocks an earlier
      chain already wrote); attention still reads them from the cache.
    * Works on both cache layouts from :func:`init_cache` (contiguous
      and paged).  Causal-attention families only (dense/moe): recurrent
      state absorbs tokens sequentially and a frontend's prepended
      embeddings are not in token space — both keep the monolithic path.

    Returns ``(logits (B, V) at each row's last valid column, advanced
    cache)``.  With ``all_logits=True`` the logits are returned for
    *every* fragment column — ``(B, C, V)`` — which is what the
    speculative verify tick needs: one forward scores all k+1 candidate
    positions at once (columns past a row's length carry garbage; the
    caller masks by length).
    """
    if cfg.family not in PAGED_FAMILIES or cfg.frontend:
        raise ValueError(
            f"chunked prefill supports causal attention caches "
            f"{PAGED_FAMILIES} without a frontend, not "
            f"{cfg.family!r} (frontend={cfg.frontend!r})")
    bsz, span = tokens.shape
    lengths = jnp.asarray(lengths, jnp.int32)
    pos0 = cache["pos"]
    cols = jnp.arange(span, dtype=jnp.int32)
    q_pos = pos0[:, None] + cols[None, :]           # (B, C) absolute
    valid = cols[None, :] < lengths[:, None]
    if skip_until is not None:
        valid = valid & (q_pos >= jnp.asarray(skip_until,
                                              jnp.int32)[:, None])
    x = layers.embed(params["embed"]["tok"], tokens)
    if cfg.pos_embed == "learned":
        x = x + layers.learned_pos_embed(params["embed"]["pos"], q_pos)

    paged = "block_tables" in cache
    if paged:
        tables = cache["block_tables"]
        n_pages, blk_size = cache["k"].shape[1], cache["k"].shape[2]
        nb = tables.shape[1]
        # attended-span rung for the whole tick: every layer clamps its
        # KV work to the same pow2 slice (hoisted out of the scan)
        span_idx = attn_lib.attended_span(q_pos, nb * blk_size)
        blk_idx = q_pos // blk_size
        blk = jnp.take_along_axis(tables, jnp.clip(blk_idx, 0, nb - 1),
                                  axis=1)
        blk = jnp.where(blk_idx < nb, blk, -1)
        # invalid columns (and chain holes) -> out of range -> dropped
        wblk = jnp.where(valid & (blk >= 0), blk, n_pages)
        off = q_pos % blk_size
    else:
        smax = cache["k"].shape[2]
        wpos = jnp.where(valid, q_pos, smax)
        bidx = jnp.arange(bsz)[:, None]
        span_idx = attn_lib.attended_span(q_pos, smax)

    def body(carry, inp):
        lp, k_l, v_l = inp
        h_in = layers.rms_norm(carry, lp["ln1"], cfg.norm_eps)
        q = jnp.einsum("bsd,dhk->bshk", h_in, lp["wq"])
        k = jnp.einsum("bsd,dhk->bshk", h_in, lp["wk"])
        v = jnp.einsum("bsd,dhk->bshk", h_in, lp["wv"])
        if cfg.pos_embed == "rope":
            q = layers.apply_rope(q, q_pos, cfg.rope_theta)
            k = layers.apply_rope(k, q_pos, cfg.rope_theta)
        # write-then-attend: the fragment's own K/V are in the cache
        # before the position-offset causal mask reads them
        if paged:
            k_l = k_l.at[wblk, off].set(k.astype(k_l.dtype), mode="drop")
            v_l = v_l.at[wblk, off].set(v.astype(v_l.dtype), mode="drop")
            o = attn_lib.paged_chunk_attention(q, k_l, v_l, tables, q_pos,
                                               span_idx=span_idx)
        else:
            k_l = k_l.at[bidx, wpos].set(k.astype(k_l.dtype), mode="drop")
            v_l = v_l.at[bidx, wpos].set(v.astype(v_l.dtype), mode="drop")
            o = attn_lib.chunk_attention(q, k_l, v_l, q_pos,
                                         span_idx=span_idx)
        h = jnp.einsum("bshk,hkd->bsd", o, lp["wo"])
        y = carry + h
        f, _ = _ffn(layers.rms_norm(y, lp["ln2"], cfg.norm_eps), lp, cfg)
        return y + f, (k_l, v_l)

    x, (ks, vs) = jax.lax.scan(body, x, (params["layers"], cache["k"],
                                         cache["v"]))
    x = layers.rms_norm(x, params["final_norm"], cfg.norm_eps)
    if all_logits:
        logits = _logits(x, params, cfg)                   # (B, C, V)
    else:
        x_last = x[jnp.arange(bsz), jnp.clip(lengths - 1, 0, span - 1)]
        logits = _logits(x_last, params, cfg)
    cache = dict(cache, k=ks, v=vs, pos=pos0 + lengths)
    return logits, cache


# ===========================================================================
# Accounting (roofline's MODEL_FLOPS)
# ===========================================================================

def model_flops(cfg: ArchConfig, tokens: int, kind: str = "train") -> float:
    """MODEL_FLOPS = 6·N·D (dense) / 6·N_active·D (MoE); 2·N·D for fwd-only."""
    n = cfg.active_param_count() if cfg.is_moe else cfg.param_count()
    mult = 6.0 if kind == "train" else 2.0
    return mult * n * tokens
