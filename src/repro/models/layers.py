"""Shared neural-net layers (pure functions over param subtrees)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def rms_norm(x, weight, eps: float = 1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * weight.astype(jnp.float32)).astype(dt)


def gated_rms_norm(x, gate, weight, eps: float = 1e-5):
    """Mamba2's RMSNorm(x * silu(z)) fused gate-norm."""
    return rms_norm(x * jax.nn.silu(gate.astype(jnp.float32)).astype(x.dtype),
                    weight, eps)


def act_fn(name: str):
    return {"silu": jax.nn.silu, "gelu": jax.nn.gelu, "relu": jax.nn.relu}[name]


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------

def rope_frequencies(head_dim: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x, positions, theta: float = 10_000.0):
    """x: (..., S, H, D); positions: broadcastable to (..., S)."""
    d = x.shape[-1]
    freqs = rope_frequencies(d, theta)                        # (D/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, D/2)
    cos = jnp.cos(angles)[..., None, :]                        # (..., S, 1, D/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def learned_pos_embed(table, positions):
    return jnp.take(table, jnp.clip(positions, 0, table.shape[0] - 1), axis=0)


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------

def mlp(x, p, act: str = "silu"):
    """SwiGLU (silu) or plain two-layer (gelu/relu) MLP.

    p: {"w_gate": (d, f)?, "w_up": (d, f), "w_down": (f, d)}
    """
    a = act_fn(act)
    up = jnp.einsum("...d,df->...f", x, p["w_up"])
    if "w_gate" in p:
        up = a(jnp.einsum("...d,df->...f", x, p["w_gate"])) * up
    else:
        up = a(up)
    return jnp.einsum("...f,fd->...d", up, p["w_down"])


def mlp_flops(tokens: int, d: int, f: int, gated: bool) -> float:
    n_mats = 3 if gated else 2
    return 2.0 * tokens * d * f * n_mats


# ---------------------------------------------------------------------------
# Embedding / unembedding
# ---------------------------------------------------------------------------

def embed(table, tokens):
    return jnp.take(table, tokens, axis=0)


def unembed_logits(x, table, true_vocab=None):
    """x: (..., d); table: (Vp, d) -> logits (..., Vp).

    With `true_vocab` < Vp (TP-padded tables), pad logits are masked to
    -inf so softmax/argmax never select them."""
    logits = jnp.einsum("...d,vd->...v", x, table)
    vp = table.shape[0]
    if true_vocab is not None and true_vocab < vp:
        mask = jnp.arange(vp) < true_vocab
        logits = jnp.where(mask, logits, jnp.asarray(-1e30, logits.dtype))
    return logits


def cross_entropy(logits, labels, z_loss: float = 0.0):
    """Mean next-token CE in f32.  labels < 0 are masked out."""
    logits = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(
        logits, jnp.clip(labels, 0, logits.shape[-1] - 1)[..., None], axis=-1
    )[..., 0]
    nll = lse - gold
    if z_loss:
        nll = nll + z_loss * lse**2
    mask = (labels >= 0).astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
