"""Mamba2 / SSD (state-space duality) blocks — arXiv:2405.21060.

EMPA mapping of the chunked SSD algorithm: sequence chunks are child QTs —
each computes its chunk-local output and a chunk summary state in
parallel; the parent carries the inter-chunk recurrence (an associative
scan — the latched parent-child chain of §3.5), and children's
contributions stream into the output without materializing the full
(S × S) semiseparable matrix (SUMUP: "eliminate obsolete read/write-back
stages").  The O(1)-state decode step is what makes the 524k-token
`long_500k` shape runnable at all.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import layers


def _to_heads(bc, nheads: int):
    """(B, S, G, N) group tensor -> broadcast to (B, S, H, N)."""
    b, s, g, n = bc.shape
    rep = nheads // g
    return jnp.broadcast_to(bc[:, :, :, None, :], (b, s, g, rep, n)) \
              .reshape(b, s, nheads, n)


def ssd_chunked(x, dt, a_log, b_mat, c_mat, d_skip, dt_bias,
                chunk: int = 64, init_state=None):
    """Chunked SSD scan.

    x: (B, S, H, P); dt: (B, S, H); a_log: (H,); b_mat/c_mat: (B, S, G, N);
    d_skip: (H,); dt_bias: (H,).  Returns (y (B,S,H,P), state (B,H,P,N)).
    """
    bsz, s, h, p = x.shape
    n = b_mat.shape[-1]
    chunk = min(chunk, s)
    assert s % chunk == 0, (s, chunk)
    nc = s // chunk

    f32 = jnp.float32
    dt = jax.nn.softplus(dt.astype(f32) + dt_bias.astype(f32))        # (B,S,H)
    a = -jnp.exp(a_log.astype(f32))                                    # (H,)
    da = dt * a                                                        # (B,S,H)
    bh = _to_heads(b_mat, h).astype(f32)
    ch = _to_heads(c_mat, h).astype(f32)
    xdt = x.astype(f32) * dt[..., None]                                # (B,S,H,P)

    # chunk views
    da_c = da.reshape(bsz, nc, chunk, h)
    cum = jnp.cumsum(da_c, axis=2)                                     # (B,C,Q,H)
    cum_last = cum[:, :, -1, :]                                        # (B,C,H)
    b_c = bh.reshape(bsz, nc, chunk, h, n)
    c_c = ch.reshape(bsz, nc, chunk, h, n)
    x_c = xdt.reshape(bsz, nc, chunk, h, p)

    # ---- intra-chunk (children's local work) -------------------------
    # decay L[q, t] = exp(cum_q - cum_t) for t <= q
    seg = cum[:, :, :, None, :] - cum[:, :, None, :, :]                # (B,C,Q,Q,H)
    q_idx = jnp.arange(chunk)
    mask = (q_idx[:, None] >= q_idx[None, :])[None, None, :, :, None]
    l_mat = jnp.where(mask, jnp.exp(seg), 0.0)
    cb = jnp.einsum("bcqhn,bcthn->bcqth", c_c, b_c)                    # (B,C,Q,Q,H)
    y_intra = jnp.einsum("bcqth,bcqth,bcthp->bcqhp", cb, l_mat, x_c)

    # ---- chunk summary states (children's clone-back) ----------------
    decay_to_end = jnp.exp(cum_last[:, :, None, :] - cum)              # (B,C,Q,H)
    states = jnp.einsum("bcqhn,bcqh,bcqhp->bchpn", b_c, decay_to_end, x_c)

    # ---- inter-chunk recurrence (the parent's latched chain) ---------
    chunk_decay = jnp.exp(cum_last)                                    # (B,C,H)

    def combine(left, right):
        d1, s1 = left
        d2, s2 = right
        return d1 * d2, s2 + d2[..., None, None] * s1

    dec_scan, st_scan = jax.lax.associative_scan(
        combine, (chunk_decay, states), axis=1)
    # state *before* each chunk: S_before[c] = st_scan[c-1] +
    # (Π decay of chunks 0..c-1) · init_state   (zero-shift the scan)
    if init_state is None:
        init_state = jnp.zeros((bsz, h, p, n), f32)
    else:
        init_state = init_state.astype(f32)
    carry_in = jnp.concatenate(
        [jnp.ones((bsz, 1, h), f32), dec_scan[:, :-1]], axis=1)
    prev = jnp.concatenate([jnp.zeros_like(st_scan[:, :1]),
                            st_scan[:, :-1]], axis=1) \
        + carry_in[..., None, None] * init_state[:, None]

    y_inter = jnp.einsum("bcqhn,bcqh,bchpn->bcqhp",
                         c_c, jnp.exp(cum), prev)

    y = (y_intra + y_inter).reshape(bsz, s, h, p)
    y = y + x.astype(f32) * d_skip.astype(f32)[None, None, :, None]
    final_state = st_scan[:, -1] + dec_scan[:, -1, :, None, None] * init_state
    return y.astype(x.dtype), final_state


def ssd_decode_step(x, dt, a_log, b_vec, c_vec, d_skip, dt_bias, state):
    """O(1) single-token step.

    x: (B, H, P); dt: (B, H); b_vec/c_vec: (B, G, N); state: (B, H, P, N).
    """
    f32 = jnp.float32
    h = x.shape[1]
    dt = jax.nn.softplus(dt.astype(f32) + dt_bias.astype(f32))          # (B,H)
    da = jnp.exp(dt * (-jnp.exp(a_log.astype(f32))))                    # (B,H)
    bh = _to_heads(b_vec[:, None], h)[:, 0].astype(f32)                 # (B,H,N)
    ch = _to_heads(c_vec[:, None], h)[:, 0].astype(f32)
    xdt = x.astype(f32) * dt[..., None]                                 # (B,H,P)
    state = state.astype(f32) * da[..., None, None] \
        + jnp.einsum("bhp,bhn->bhpn", xdt, bh)
    y = jnp.einsum("bhn,bhpn->bhp", ch, state)
    y = y + x.astype(f32) * d_skip.astype(f32)[None, :, None]
    return y.astype(x.dtype), state


# ---------------------------------------------------------------------------
# Causal depthwise conv (the Mamba2 local mixer)
# ---------------------------------------------------------------------------

def causal_conv(x, w, b, width: int):
    """x: (B, S, C); w: (width, C); b: (C,). Causal depthwise conv."""
    pad = jnp.pad(x, ((0, 0), (width - 1, 0), (0, 0)))
    out = jnp.zeros_like(x, dtype=jnp.float32)
    for i in range(width):
        out = out + pad[:, i:i + x.shape[1]].astype(jnp.float32) \
            * w[i].astype(jnp.float32)
    return (out + b.astype(jnp.float32)).astype(x.dtype)


def causal_conv_step(x, conv_state, w, b):
    """x: (B, C); conv_state: (B, width-1, C) -> (y (B,C), new_state)."""
    width = w.shape[0]
    window = jnp.concatenate([conv_state, x[:, None]], axis=1)  # (B,width,C)
    y = jnp.einsum("bwc,wc->bc", window.astype(jnp.float32),
                   w.astype(jnp.float32)) + b.astype(jnp.float32)
    return y.astype(x.dtype), window[:, 1:]


# ---------------------------------------------------------------------------
# Full Mamba2 block
# ---------------------------------------------------------------------------

def _split_proj(zxbcdt, cfg):
    """Split the fused in-projection into (z gate, conv channels, dt)."""
    di = cfg.d_inner
    gn = cfg.ssm_ngroups * cfg.ssm_state
    z = zxbcdt[..., :di]
    xbc = zxbcdt[..., di:2 * di + 2 * gn]
    dt = zxbcdt[..., 2 * di + 2 * gn:]
    return z, xbc, dt


def mamba2_block(x, p, cfg, ssd_fn=ssd_chunked):
    """x: (B, S, d_model) -> (B, S, d_model). Training/prefill path."""
    bsz, s, _ = x.shape
    di, n, g = cfg.d_inner, cfg.ssm_state, cfg.ssm_ngroups
    h, pdim = cfg.ssm_nheads, cfg.ssm_headdim

    zxbcdt = jnp.einsum("bsd,dk->bsk", x, p["w_in"])
    z, xbc, dt = _split_proj(zxbcdt, cfg)
    xbc = jax.nn.silu(causal_conv(xbc, p["conv_w"], p["conv_b"], cfg.ssm_conv))
    xs = xbc[..., :di].reshape(bsz, s, h, pdim)
    b_mat = xbc[..., di:di + g * n].reshape(bsz, s, g, n)
    c_mat = xbc[..., di + g * n:].reshape(bsz, s, g, n)

    y, state = ssd_fn(xs, dt, p["a_log"], b_mat, c_mat, p["d_skip"],
                      p["dt_bias"])
    y = y.reshape(bsz, s, di)
    y = layers.gated_rms_norm(y, z, p["norm_w"])
    return jnp.einsum("bsk,kd->bsd", y, p["w_out"]), state


def mamba2_decode(x, p, cfg, conv_state, ssm_state):
    """x: (B, d_model) single token -> (y, conv_state, ssm_state)."""
    bsz = x.shape[0]
    di, n, g = cfg.d_inner, cfg.ssm_state, cfg.ssm_ngroups
    h, pdim = cfg.ssm_nheads, cfg.ssm_headdim

    zxbcdt = jnp.einsum("bd,dk->bk", x, p["w_in"])
    z = zxbcdt[..., :di]
    xbc = zxbcdt[..., di:2 * di + 2 * g * n]
    dt = zxbcdt[..., 2 * di + 2 * g * n:]
    xbc, conv_state = causal_conv_step(xbc, conv_state, p["conv_w"], p["conv_b"])
    xbc = jax.nn.silu(xbc)
    xs = xbc[..., :di].reshape(bsz, h, pdim)
    b_vec = xbc[..., di:di + g * n].reshape(bsz, g, n)
    c_vec = xbc[..., di + g * n:].reshape(bsz, g, n)
    y, ssm_state = ssd_decode_step(xs, dt, p["a_log"], b_vec, c_vec,
                                   p["d_skip"], p["dt_bias"], ssm_state)
    y = y.reshape(bsz, di)
    y = layers.gated_rms_norm(y, z, p["norm_w"])
    return jnp.einsum("bk,kd->bd", y, p["w_out"]), conv_state, ssm_state


def conv_dim(cfg) -> int:
    return cfg.d_inner + 2 * cfg.ssm_ngroups * cfg.ssm_state


def proj_dim(cfg) -> int:
    return 2 * cfg.d_inner + 2 * cfg.ssm_ngroups * cfg.ssm_state + cfg.ssm_nheads


def ssd_flops(batch, seq, cfg, chunk: int = 64) -> float:
    h, p, n = cfg.ssm_nheads, cfg.ssm_headdim, cfg.ssm_state
    nc = seq // chunk
    intra = 2.0 * batch * nc * chunk * chunk * h * (n + p)
    states = 4.0 * batch * seq * h * p * n
    return intra + states
