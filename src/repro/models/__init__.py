from repro.models import attention, layers, model, moe, params, ssm  # noqa: F401
