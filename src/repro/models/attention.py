"""Attention: GQA with RoPE; full / blockwise (online-softmax) / decode.

The blockwise path is the SUMUP-mode adaptation at the XLA level: the
(S × S) score matrix is never materialized — a ``lax.scan`` over KV chunks
streams partial scores into running (max, denominator, accumulator) state,
exactly the paper's "children stream summands into a parent-side adder;
the partial sum is never written back" (§5.2), applied to softmax
normalization.  The Pallas kernel (kernels/flash_attention) is the VMEM
realization of the same schedule.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def _sh(x, axes):
    from repro.runtime.sharding import shard
    return shard(x, axes)


def _head_shard_mesh(h: int, hkv: int):
    """The active rules' mesh iff its "model" axis (size > 1) divides
    both head counts — the condition for handing the Pallas kernels a
    local head slice via ``shard_map`` (GSPMD cannot partition a
    ``pallas_call``; without this the kernel path would all-gather the
    sharded KV cache onto every shard).  Mirrors the divisibility
    fallback in runtime/sharding.py: non-divisible head counts take the
    unsharded kernel, they don't crash."""
    from repro.runtime.sharding import current_rules
    rules = current_rules()
    if rules is None:
        return None
    m = dict(rules.mesh.shape).get("model", 1)
    if m <= 1 or h % m or hkv % m:
        return None
    return rules.mesh


def _repeat_kv(k, n_rep: int):
    """(B, S, Hkv, D) -> (B, S, Hkv * n_rep, D)."""
    if n_rep == 1:
        return k
    b, s, h, d = k.shape
    return jnp.broadcast_to(k[:, :, :, None, :], (b, s, h, n_rep, d)) \
              .reshape(b, s, h * n_rep, d)


def full_attention(q, k, v, *, causal: bool, q_offset=0):
    """q: (B, Sq, H, D); k,v: (B, Skv, Hkv, D).  Returns (B, Sq, H, D).

    Reference path (materializes scores) — used for short sequences and as
    the oracle for the blockwise path and the Pallas kernel.
    """
    b, sq, h, d = q.shape
    hkv = k.shape[2]
    k = _repeat_kv(k, h // hkv)
    v = _repeat_kv(v, h // hkv)
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32)
    scores = scores / jnp.sqrt(jnp.float32(d))
    if causal:
        qpos = jnp.arange(sq)[:, None] + q_offset
        kpos = jnp.arange(k.shape[1])[None, :]
        scores = jnp.where(kpos <= qpos, scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", probs.astype(v.dtype), v)


def blockwise_attention(q, k, v, *, causal: bool, chunk: int = 1024,
                        q_offset=0):
    """Online-softmax attention, O(S·chunk) memory (SUMUP-mode schedule).

    Scans over KV chunks carrying (acc, running max m, denominator l).
    """
    b, sq, h, d = q.shape
    hkv = k.shape[2]
    skv = k.shape[1]
    n_rep = h // hkv
    chunk = min(chunk, skv)
    assert skv % chunk == 0, (skv, chunk)
    nkc = skv // chunk

    # carry sharding: heads over "model" when divisible, else sequence
    # parallelism over Sq ("attn_sq") — without a stable constraint the
    # f32 carry bounces between layouts on every KV chunk, which showed up
    # as the dominant collective term for the 36/24/12-head archs (§Perf).
    # Always on: it looks like a collective regression for starcoder2 at
    # train length (bound 5.3 -> 9.0 s) — but the unconstrained layout
    # needs 23.9 GB/dev of transients (whisper: 56 GB), i.e. it does not
    # fit v5e HBM at all.  The constrained layout is the deployable one
    # (§Perf notes).
    _c = _sh
    CARRY4 = ("batch", "heads_act", "attn_sq", None)
    CARRY3 = ("batch", "heads_act", "attn_sq")

    qf = (q.astype(jnp.float32) / jnp.sqrt(jnp.float32(d)))
    qf = _c(qf, ("batch", "attn_sq", "heads_act", None))
    kc = k.reshape(b, nkc, chunk, hkv, d)
    vc = v.reshape(b, nkc, chunk, hkv, d)
    qpos = jnp.arange(sq) + q_offset

    def step(carry, inputs):
        acc, m, l = carry
        kb, vb, ci = inputs
        kb = _repeat_kv(kb, n_rep)          # (B, chunk, H, D)
        vb = _repeat_kv(vb, n_rep)
        s = jnp.einsum("bqhd,bkhd->bhqk", qf, kb.astype(jnp.float32))
        if causal:
            kpos = ci * chunk + jnp.arange(chunk)
            s = jnp.where(kpos[None, :] <= qpos[:, None], s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        # renormalize the running accumulator; stream in this chunk
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[..., None])
        l_new = l * alpha + jnp.sum(p, axis=-1)
        acc_new = acc * alpha[..., None] + jnp.einsum(
            "bhqk,bkhd->bhqd", p, vb.astype(jnp.float32))
        return (_c(acc_new, CARRY4), _c(m_new, CARRY3),
                _c(l_new, CARRY3)), None

    acc0 = _c(jnp.zeros((b, h, sq, d), jnp.float32), CARRY4)
    m0 = _c(jnp.full((b, h, sq), NEG_INF, jnp.float32), CARRY3)
    l0 = _c(jnp.zeros((b, h, sq), jnp.float32), CARRY3)
    (acc, m, l), _ = jax.lax.scan(
        step, (acc0, m0, l0),
        (jnp.moveaxis(kc, 1, 0), jnp.moveaxis(vc, 1, 0), jnp.arange(nkc)))
    out = acc / jnp.maximum(l[..., None], 1e-30)
    out = jnp.moveaxis(out, 1, 2).astype(q.dtype)      # (B, Sq, H, D)
    # one-time reshard OUT of the carry layout: without this the Sq shard
    # leaks into the residual stream and the loss contracts against
    # d-partial activations (measured: a (B, chunk, V) f32 all-reduce per
    # loss chunk on whisper — §Perf notes)
    return _c(out, ("batch", None, "heads_act", None))


def decode_attention(q, k_cache, v_cache, cache_len):
    """Single-token decode: q (B, 1, H, D) against a (B, Smax, Hkv, D) cache.

    ``cache_len`` masks the still-empty tail of the cache.
    """
    b, _, h, d = q.shape
    hkv = k_cache.shape[2]
    k = _repeat_kv(k_cache, h // hkv)
    v = _repeat_kv(v_cache, h // hkv)
    s = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32)
    s = s / jnp.sqrt(jnp.float32(d))
    kpos = jnp.arange(k.shape[1])
    s = jnp.where(kpos[None, None, None, :] < cache_len[:, None, None, None],
                  s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", p.astype(v.dtype), v)


# -- clamped-span machinery (chunked prefill / speculative verify) ----------
#
# A fragment at positions ``q_pos`` only ever attends to cache rows
# ``[0, max(q_pos) + 1)``; everything past that is masked to exact zeros.
# Computing (and masking) scores over the whole ``max_seq`` cache wastes
# FLOPs on dead rows — the dominant cost of the speculative verify
# forward, whose fragment is ``spec_k + 1`` wide but whose cache is
# ``max_seq`` long.  The jnp path clamps by slicing the cache to the
# smallest power-of-two rung >= the attended limit (``lax.switch`` over a
# short static ladder): slicing at a power-of-two boundary keeps the XLA
# CPU reductions bit-identical to the full-length softmax (the masked
# tail contributes exact zeros and the contraction blocking is
# unchanged — the same append-zeros invariance the monolithic-vs-chunked
# parity already relies on; asserted by tests/kernels/
# test_chunk_attention.py).  The TPU path dispatches to the Pallas
# kernels (kernels/chunk_attention), which clamp by skipping KV blocks
# past the limit inside the grid.

SPAN_MIN = 16      # smallest ladder rung (and the bit-exactness floor)
SPAN_RUNGS = 4     # ladder length cap: bounds per-tick compile cost


def span_ladder(smax: int) -> list[int]:
    """Static KV-span buckets for a ``smax``-row cache: the top rung is
    the full cache, lower rungs halve down to ``SPAN_MIN`` (at most
    ``SPAN_RUNGS`` rungs; all non-top rungs are powers of two)."""
    spans = [smax]
    if smax <= SPAN_MIN:
        return spans
    rung = 1 << ((smax - 1).bit_length() - 1)   # largest pow2 < smax
    while rung >= SPAN_MIN and len(spans) < SPAN_RUNGS:
        spans.insert(0, rung)
        rung //= 2
    return spans


def attended_span(q_pos, smax: int):
    """Index into :func:`span_ladder` of the smallest rung covering the
    attended limit ``max(q_pos) + 1`` (dynamic scalar; clamped to the top
    rung by ``lax.switch`` when garbage rows point past ``smax``)."""
    spans = jnp.asarray(span_ladder(smax), jnp.int32)
    return jnp.sum(spans < jnp.max(q_pos) + 1).astype(jnp.int32)


def offset_causal_mask(scores, q_pos):
    """Position-offset causal mask: key position ``kpos`` is visible to
    query column j iff ``kpos <= q_pos[:, j]``.

    One mask, three consumers: prefill-continuation fragments
    (:func:`chunk_attention`), their paged twin
    (:func:`paged_chunk_attention`), and the **speculative verify
    forward** — a draft fragment scored through this mask sees, at
    column j, exactly the keys a sequential decode step at position
    ``q_pos[:, j]`` would see, which is what makes greedy verification
    bit-exact on both cache layouts.  ``scores`` is (B, H, C, Skv),
    ``q_pos`` (B, C) absolute.
    """
    kpos = jnp.arange(scores.shape[-1])
    return jnp.where(kpos[None, None, None, :] <= q_pos[:, None, :, None],
                     scores, NEG_INF)


def _chunk_attend(q, k, v, q_pos):
    """The chunk-attention math itself, over an already-clamped cache
    slice: scores + position-offset causal mask + softmax + PV."""
    b, c, h, d = q.shape
    hkv = k.shape[2]
    k = _repeat_kv(k, h // hkv)
    v = _repeat_kv(v, h // hkv)
    s = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32)
    s = offset_causal_mask(s / jnp.sqrt(jnp.float32(d)), q_pos)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", p.astype(v.dtype), v)


def chunk_attention(q, k_cache, v_cache, q_pos, span_idx=None,
                    use_kernel=None):
    """Prefill-continuation attention: q (B, C, H, D) at absolute positions
    ``q_pos`` (B, C) against a (B, Smax, Hkv, D) cache whose rows already
    hold the chunk's own K/V (write-then-attend, like decode).

    Causal through :func:`offset_causal_mask` — the mask that makes an
    incrementally outsourced prompt fragment (or a speculative draft
    fragment under verification) exact against the cache built by
    earlier fragments.  ``decode_attention`` is the C == 1 special case
    (``q_pos = cache_len - 1``); the masked tail contributes exact zeros
    to the softmax, so chunked prefill reproduces the monolithic prefill
    bit for bit (same reduction argument as the paged/contiguous
    parity).

    Thin dispatcher (the ``paged_decode_attention`` pattern): on TPU the
    Pallas chunk-attention kernel (wide or narrow by fragment width —
    kernels/chunk_attention); on CPU the jnp path, KV reads clamped to
    the :func:`span_ladder` rung covering ``max(q_pos) + 1`` instead of
    masking the whole cache.  ``span_idx`` (optional) is the precomputed
    :func:`attended_span` — `model.prefill_chunk` hoists it out of the
    layer scan so the ladder search runs once per fragment, not once per
    layer.
    """
    if use_kernel is None:
        use_kernel = jax.default_backend() == "tpu"
    if use_kernel:
        from repro.kernels.chunk_attention import (
            chunk_attention_kernel, chunk_attention_kernel_sharded)
        mesh = _head_shard_mesh(q.shape[2], k_cache.shape[2])
        if mesh is not None:
            return chunk_attention_kernel_sharded(q, k_cache, v_cache,
                                                  q_pos, mesh=mesh)
        return chunk_attention_kernel(q, k_cache, v_cache, q_pos)
    smax = k_cache.shape[1]
    spans = span_ladder(smax)
    if len(spans) == 1:
        return _chunk_attend(q, k_cache, v_cache, q_pos)
    if span_idx is None:
        span_idx = attended_span(q_pos, smax)
    branches = [
        (lambda s: lambda q_, k_, v_, p_: _chunk_attend(
            q_, k_[:, :s], v_[:, :s], p_))(s)
        for s in spans]
    return jax.lax.switch(span_idx, branches, q, k_cache, v_cache, q_pos)


def paged_chunk_attention(q, k_pages, v_pages, block_tables, q_pos,
                          span_idx=None, use_kernel=None,
                          return_blocks=False):
    """:func:`chunk_attention` over a paged cache: gather each row's chain
    back into the contiguous layout (element order identical to the
    contiguous cache, so parity is exact) and apply the position-offset
    causal mask.

    Same dispatcher shape as the contiguous path: the TPU kernel aims KV
    DMAs through the scalar-prefetched block table, and the jnp path
    gathers **only the blocks that intersect the attended span** — a
    long chain behind a short fragment stays in HBM instead of being
    materialized whole.  With ``return_blocks`` the jnp path also
    returns the per-rung gathered-block count (the regression
    observable: blocks touched, not chain length)."""
    n_pages, bs, hkv, d = k_pages.shape
    b, nb = block_tables.shape
    if use_kernel is None:
        use_kernel = jax.default_backend() == "tpu"
    if use_kernel and not return_blocks:
        from repro.kernels.chunk_attention import (
            paged_chunk_attention_kernel,
            paged_chunk_attention_kernel_sharded)
        mesh = _head_shard_mesh(q.shape[2], k_pages.shape[2])
        if mesh is not None:
            return paged_chunk_attention_kernel_sharded(
                q, k_pages, v_pages, block_tables, q_pos, mesh=mesh)
        return paged_chunk_attention_kernel(q, k_pages, v_pages,
                                            block_tables, q_pos)
    smax = nb * bs
    spans = span_ladder(smax)
    if span_idx is None:
        span_idx = attended_span(q_pos, smax)
    rung_blocks = [min(nb, -(-s // bs)) for s in spans]

    def branch(nb_used):
        def f(q_, kp, vp, tables, p_):
            t = jnp.clip(tables[:, :nb_used], 0, n_pages - 1)
            k = kp[t].reshape(b, nb_used * bs, hkv, d)
            v = vp[t].reshape(b, nb_used * bs, hkv, d)
            return _chunk_attend(q_, k, v, p_)
        return f

    if len(spans) == 1:
        out = branch(nb)(q, k_pages, v_pages, block_tables, q_pos)
    else:
        out = jax.lax.switch(span_idx, [branch(n) for n in rung_blocks],
                             q, k_pages, v_pages, block_tables, q_pos)
    if return_blocks:
        idx = jnp.clip(span_idx, 0, len(spans) - 1)
        return out, jnp.asarray(rung_blocks, jnp.int32)[idx]
    return out


def paged_decode_attention(q, k_pages, v_pages, block_tables, cache_len,
                           use_kernel=None):
    """Single-token decode over a paged cache: q (B, 1, H, D) against
    (P, bs, Hkv, D) pages addressed by (B, NB) block tables.

    The pure-jnp path gathers the chain back into the contiguous layout
    and reuses :func:`decode_attention` — element order matches the
    contiguous cache exactly, so paged decode is bit-identical to
    contiguous decode on the same tokens (the parity the serving tests
    assert).  On TPU the Pallas kernel (kernels/paged_attention) computes
    the same schedule without materializing the gather.
    """
    if use_kernel is None:
        use_kernel = jax.default_backend() == "tpu"
    if use_kernel:
        from repro.kernels.paged_attention import (
            paged_attention, paged_attention_sharded)
        mesh = _head_shard_mesh(q.shape[2], k_pages.shape[2])
        if mesh is not None:
            o = paged_attention_sharded(q[:, 0], k_pages, v_pages,
                                        block_tables, cache_len, mesh=mesh)
        else:
            o = paged_attention(q[:, 0], k_pages, v_pages, block_tables,
                                cache_len)
        return o[:, None]
    n_pages, bs, _, d = k_pages.shape
    b, nb = block_tables.shape
    t = jnp.clip(block_tables, 0, n_pages - 1)
    k = k_pages[t].reshape(b, nb * bs, k_pages.shape[2], d)
    v = v_pages[t].reshape(b, nb * bs, v_pages.shape[2], d)
    return decode_attention(q, k, v, cache_len)


def attention_flops(batch: int, sq: int, skv: int, heads: int, head_dim: int,
                    causal: bool, attended: int = None) -> float:
    """QK^T + PV FLOPs.  ``attended`` is the clamped KV span actually
    computed (chunked prefill / speculative verify: the
    :func:`span_ladder` rung, not the full cache) — without it the count
    assumes the whole ``skv`` is touched."""
    span = skv if attended is None else min(skv, attended)
    f = 4.0 * batch * heads * sq * span * head_dim  # QK^T + PV
    return f / 2 if causal and sq == span else f
