"""Single-source parameter definitions.

Every model declares its parameters once as a list of :class:`ParamDef`
(path, shape, logical axes, init).  From that single table derive:

* ``init_params``      — materialized weights (smoke tests, examples),
* ``abstract_params``  — ShapeDtypeStructs (dry-run: no allocation),
* partition specs      — via runtime/sharding.py's logical-axis rules
                         (the framework's "compile-time metainstructions").
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ParamDef:
    path: tuple[str, ...]
    shape: tuple[int, ...]
    axes: tuple[Optional[str], ...]
    init: str = "normal"          # normal | zeros | ones | embed
    scale: Optional[float] = None  # std for normal; default fan-in

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), \
            f"{self.path}: axes/shape rank mismatch"


def _set(tree: dict, path: tuple[str, ...], value) -> None:
    node = tree
    for p in path[:-1]:
        node = node.setdefault(p, {})
    node[path[-1]] = value


def _init_one(d: ParamDef, key, dtype):
    if d.init == "zeros":
        return jnp.zeros(d.shape, dtype)
    if d.init == "ones":
        return jnp.ones(d.shape, dtype)
    if d.init == "embed":
        std = d.scale if d.scale is not None else 0.02
        return (std * jax.random.normal(key, d.shape, jnp.float32)).astype(dtype)
    if d.init == "normal":
        fan_in = d.shape[-2] if len(d.shape) >= 2 else d.shape[-1]
        std = d.scale if d.scale is not None else 1.0 / math.sqrt(max(fan_in, 1))
        return (std * jax.random.normal(key, d.shape, jnp.float32)).astype(dtype)
    raise ValueError(d.init)


def init_params(defs: list[ParamDef], key, dtype=jnp.bfloat16) -> dict:
    tree: dict = {}
    keys = jax.random.split(key, max(len(defs), 1))
    for d, k in zip(defs, keys):
        _set(tree, d.path, _init_one(d, k, dtype))
    return tree


def abstract_params(defs: list[ParamDef], dtype=jnp.bfloat16) -> dict:
    tree: dict = {}
    for d in defs:
        _set(tree, d.path, jax.ShapeDtypeStruct(d.shape, dtype))
    return tree


def axes_tree(defs: list[ParamDef]) -> dict:
    """Pytree (same structure as params) of logical-axis tuples."""
    tree: dict = {}
    for d in defs:
        _set(tree, d.path, d.axes)
    return tree


def param_bytes(defs: list[ParamDef], bytes_per_el: int = 2) -> int:
    return sum(math.prod(d.shape) * bytes_per_el for d in defs)
