"""Y86 + EMPA metainstruction ISA.

The paper (§5) writes its workloads in Y86 assembly "extended with EMPA
metainstructions".  We keep the Y86 register model and mnemonics but use a
fixed-width structured encoding (op, a, b, imm, imm2) instead of the
variable-length byte encoding — the simulator is clock-level, not
byte-level, and the paper's own timing is per-instruction.

Normal instructions execute on a core and cost ``COST[op]`` supervisor
clocks.  Metainstructions are *detected at pre-fetch* and executed by the
supervisor (paper §4.5): they cost the issuing core ``META_COST[op]``
clocks (0 for QTERM — the 'Meta' signal is raised during pre-fetch and the
SV handles termination while the core's last payload clock completes).
"""
from __future__ import annotations

import enum
from typing import NamedTuple, Sequence

import numpy as np

NREGS = 8
# Y86 register file order.
EAX, ECX, EDX, EBX, ESP, EBP, ESI, EDI = range(8)
REG_NAMES = ["%eax", "%ecx", "%edx", "%ebx", "%esp", "%ebp", "%esi", "%edi"]

NO_REG = 0xF


class Op(enum.IntEnum):
    # --- normal Y86 subset (executed by a core) ---
    HALT = 0
    NOP = 1
    IRMOVL = 2      # imm -> rb
    RRMOVL = 3      # ra -> rb
    MRMOVL = 4      # mem[rb + imm] -> ra
    RMMOVL = 5      # ra -> mem[rb + imm]
    ADDL = 6        # rb = rb OP ra ; sets ZF/SF
    SUBL = 7
    ANDL = 8
    XORL = 9
    JMP = 10        # pc = imm
    JLE = 11
    JL = 12
    JE = 13
    JNE = 14
    JGE = 15
    JG = 16
    # --- EMPA metainstructions (executed by the supervisor) ---
    QPREALLOC = 17  # imm = number of cores to preallocate for this core
    QCREATE = 18    # imm = QT address; rent a core, clone glue, child runs
    QTERM = 19      # terminate this QT; latch link register (%eax) for parent
    QWAIT = 20      # block until all children terminated; read back latch
    QFOR = 21       # a=count_reg b=addr_reg imm=payload_addr imm2=stride
    QSUMUP = 22     # a=addr_reg b=count_reg imm=stride imm2=alu_op
    # pseudo-register write (child -> ForParent latch), used in SUMUP payloads
    PADDL = 23      # ra -> ForParent latch, combining with configured ALU op

    @property
    def is_meta(self) -> bool:
        # PADDL is a normal (pseudo-register) instruction, not a meta.
        return Op.QPREALLOC <= self <= Op.QSUMUP


# ALU op selectors for QSUMUP's parent-side adder (imm2 field).
ALU_ADD, ALU_AND, ALU_XOR = 0, 1, 2

# Per-instruction costs in SV clocks.  "The simulator uses arbitrary, but
# reasonable execution times" (paper §6).  This table is the unique fit that
# reproduces every row of Table 1 (see core/timing.py and DESIGN.md §7):
#   NO-mode loop body mrmovl+addl+irmovl+addl+irmovl+addl+jne = 30 clocks,
#   setup irmovl+irmovl+xorl+andl+je = 20, halt = 2  =>  T_NO = 22 + 30 n.
COST = {
    Op.HALT: 2,
    Op.NOP: 1,
    Op.IRMOVL: 4,
    Op.RRMOVL: 4,
    Op.MRMOVL: 6,
    Op.RMMOVL: 6,
    Op.ADDL: 4,
    Op.SUBL: 4,
    Op.ANDL: 4,
    Op.XORL: 4,
    Op.JMP: 4,
    Op.JLE: 4,
    Op.JL: 4,
    Op.JE: 4,
    Op.JNE: 4,
    Op.JGE: 4,
    Op.JG: 4,
    # metas: cost charged to the *issuing core* while the SV acts.
    Op.QPREALLOC: 1,
    Op.QCREATE: 1,
    Op.QTERM: 0,     # absorbed: Meta signal raised at pre-fetch (§4.5)
    Op.QWAIT: 0,     # waiting consumes no clocks ("no time is used when
                     #  there is no need to wait", §3.4); unblock latch
                     #  transfer is charged by the engine.
    Op.QFOR: 1,      # mode-enter handshake with the SV
    Op.QSUMUP: 1,
    Op.PADDL: 4,     # writes the ForParent pseudo-register (register-speed)
}

MAX_OP = int(max(Op)) + 1


def cost_table() -> np.ndarray:
    t = np.zeros(MAX_OP, dtype=np.int32)
    for op, c in COST.items():
        t[int(op)] = c
    return t


class Instr(NamedTuple):
    op: int
    a: int = NO_REG
    b: int = NO_REG
    imm: int = 0
    imm2: int = 0
    imm3: int = 0


# ---------------------------------------------------------------------------
# Tiny assembler: list of (mnemonic, operands...) or ("label", name) entries.
# ---------------------------------------------------------------------------

_REG_IDX = {name: i for i, name in enumerate(REG_NAMES)}


def _reg(r) -> int:
    if isinstance(r, str):
        return _REG_IDX[r]
    return int(r)


def assemble(source: Sequence[tuple]) -> np.ndarray:
    """Assemble to an (P, 5) int32 program image.

    ``source`` entries::

        ("label", "Loop")
        ("irmovl", imm_or_label, "%edx")
        ("mrmovl", offset, "%ecx", "%esi")     # mem[%ecx+offset] -> %esi
        ("rmmovl", "%esi", offset, "%ecx")     # %esi -> mem[%ecx+offset]
        ("addl", "%esi", "%eax")               # %eax += %esi
        ("jne", "Loop")
        ("qfor", count_reg, addr_reg, payload_label, stride)
        ("qsumup", addr_reg, count_reg, payload_label, stride, alu_op)
        ...

    Labels may be used wherever an immediate address is expected; they
    resolve to instruction indices (the machine is word-addressed at the
    instruction level).
    """
    # pass 1: labels
    labels: dict[str, int] = {}
    pc = 0
    for entry in source:
        if entry[0] == "label":
            labels[entry[1]] = pc
        else:
            pc += 1

    def imm_of(v) -> int:
        if isinstance(v, str):
            return labels[v]
        return int(v)

    out: list[Instr] = []
    for entry in source:
        m, *ops = entry
        if m == "label":
            continue
        if m == "halt":
            out.append(Instr(Op.HALT))
        elif m == "nop":
            out.append(Instr(Op.NOP))
        elif m == "irmovl":
            out.append(Instr(Op.IRMOVL, b=_reg(ops[1]), imm=imm_of(ops[0])))
        elif m == "rrmovl":
            out.append(Instr(Op.RRMOVL, a=_reg(ops[0]), b=_reg(ops[1])))
        elif m == "mrmovl":
            out.append(Instr(Op.MRMOVL, a=_reg(ops[2]), b=_reg(ops[1]), imm=imm_of(ops[0])))
        elif m == "rmmovl":
            out.append(Instr(Op.RMMOVL, a=_reg(ops[0]), b=_reg(ops[2]), imm=imm_of(ops[1])))
        elif m in ("addl", "subl", "andl", "xorl"):
            op = {"addl": Op.ADDL, "subl": Op.SUBL, "andl": Op.ANDL, "xorl": Op.XORL}[m]
            out.append(Instr(op, a=_reg(ops[0]), b=_reg(ops[1])))
        elif m in ("jmp", "jle", "jl", "je", "jne", "jge", "jg"):
            op = {"jmp": Op.JMP, "jle": Op.JLE, "jl": Op.JL, "je": Op.JE,
                  "jne": Op.JNE, "jge": Op.JGE, "jg": Op.JG}[m]
            out.append(Instr(op, imm=imm_of(ops[0])))
        elif m == "qprealloc":
            out.append(Instr(Op.QPREALLOC, imm=imm_of(ops[0])))
        elif m == "qcreate":
            out.append(Instr(Op.QCREATE, imm=imm_of(ops[0])))
        elif m == "qterm":
            out.append(Instr(Op.QTERM))
        elif m == "qwait":
            out.append(Instr(Op.QWAIT))
        elif m == "qfor":
            out.append(Instr(Op.QFOR, a=_reg(ops[0]), b=_reg(ops[1]),
                             imm=imm_of(ops[2]), imm2=imm_of(ops[3])))
        elif m == "qsumup":
            out.append(Instr(Op.QSUMUP, a=_reg(ops[0]), b=_reg(ops[1]),
                             imm=imm_of(ops[2]), imm2=imm_of(ops[3]),
                             imm3=imm_of(ops[4])))
        elif m == "paddl":
            out.append(Instr(Op.PADDL, a=_reg(ops[0])))
        else:
            raise ValueError(f"unknown mnemonic {m!r}")
    arr = np.array([[i.op, i.a, i.b, i.imm, i.imm2, i.imm3] for i in out],
                   dtype=np.int32)
    return arr
