"""Clock-level, jittable EMPA machine: a pool of Y86 cores under a supervisor.

Faithful model of the paper's architecture (§3–§5):

* A **pool of uniform cores** (``MAX_CORES``), each a small Y86 machine
  (register file, PC, ZF/SF flags) — "the cores are mostly similar to the
  present single-core processor, with some extra functionality" (§4.1.2).
* A **supervisor (SV)** above the cores that owns every shared resource:
  rent/return of cores, parent/children bookkeeping, latched data transfer,
  mass-processing engines.  The SV dispatches **one core-visible action per
  clock** (§4.1.3: "it can only be used in a sequential way, one operation
  at a time"); its internal bookkeeping (address advance, counter
  decrement) is free — it "can be operated at a frequency ... much higher
  than the clock frequency needed for the cores".
* **Metainstructions** are detected at pre-fetch and executed at the SV
  level (§4.5).  ``QTERM`` is fully absorbed into the final payload clock
  (the 'Meta' signal is raised while the last instruction completes).
* **Latched transfers**: a child's result is latched at termination,
  transferred to the parent's ``FromChild`` latch on the next clock, and
  consumed by the parent the clock after — the two-stage latched protocol
  of §3.5/§4.4.
* **Mass-processing engines** (§5.1, §5.2):
  - ``QFOR``  — the SV runs the loop: it re-creates the (preallocated)
    child once per iteration with the SV-advanced address and the chained
    partial result; control instructions vanish from the instruction
    stream.
  - ``QSUMUP`` — the SV staggers one child creation per clock; children
    stream their loads through the ForParent latch into a parent-side
    combining unit (add/and/xor).  The partial sum is never written back
    to an architectural register: one element per clock at steady state.
    A child core's full turnaround (rent → payload → terminate → pool
    maintenance → rentable) is ``SUMUP_TURNAROUND`` = 30 clocks, so at
    most 30 children + 1 parent are ever in use (§6.2), yet creation
    never stalls: by the time the 31st child is needed, the 1st core is
    back in the pool.

With the per-instruction costs in ``isa.COST`` this machine reproduces
**every row of Table 1 exactly** (see tests/core/test_table1.py).
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import isa
from repro.core.isa import Op

MAX_CORES = 32
MEM_WORDS = 4096
RING = 32               # SUMUP inbox ring (>= max in-flight children)
SUMUP_TURNAROUND = 30   # clocks from rent until the core is rentable again
LINK_REG = isa.EAX      # the link register cloned back at termination (§5.1)

# Core status codes.
POOL, RUN, ENGINE, WAITQ, HALTWAIT, HALTED = range(6)


class MachineState(NamedTuple):
    # memory + per-core architectural state
    mem: jnp.ndarray            # (MEM_WORDS,) i32
    regs: jnp.ndarray           # (C, 8) i32
    pc: jnp.ndarray             # (C,) i32
    zf: jnp.ndarray             # (C,) i32
    sf: jnp.ndarray             # (C,) i32
    # supervisor-visible core state
    status: jnp.ndarray         # (C,) i32
    busy: jnp.ndarray           # (C,) i32  remaining clocks of current instr
    parent: jnp.ndarray         # (C,) i32  parent core id (-1)
    children: jnp.ndarray       # (C,) i32  live child count
    childmask: jnp.ndarray      # (C,) u32  'Children' bitmask (§4.1.2)
    prealloc: jnp.ndarray       # (C,) i32  cores preallocated for this core
    pool_release: jnp.ndarray   # (C,) i32  clock at which core is rentable
    rent_clock: jnp.ndarray     # (C,) i32  clock at which core was rented
    # latched transfer paths (§4.6)
    latch_fromchild: jnp.ndarray    # (C,) i32  parent-side FromChild latch
    latch_valid: jnp.ndarray        # (C,) i32
    latch_forparent: jnp.ndarray    # (C,) i32  child-side ForParent latch
    unblock_after: jnp.ndarray      # (C,) i32  earliest unblock clock (QWAIT)
    # mass-processing engine state (per core, in role 'parent')
    mode: jnp.ndarray           # (C,) i32  0 none / 1 FOR / 2 SUMUP
    e_remaining: jnp.ndarray    # (C,) i32  creations left
    e_total: jnp.ndarray        # (C,) i32  total iterations
    e_consumed: jnp.ndarray     # (C,) i32  SUMUP: elements combined
    e_inflight: jnp.ndarray     # (C,) i32  live engine children
    e_addr: jnp.ndarray         # (C,) i32  SV-maintained address
    e_stride: jnp.ndarray       # (C,) i32
    e_payload: jnp.ndarray      # (C,) i32  payload QT address
    e_addr_reg: jnp.ndarray     # (C,) i32
    e_count_reg: jnp.ndarray    # (C,) i32
    e_aluop: jnp.ndarray        # (C,) i32  SUMUP combiner op
    e_acc: jnp.ndarray          # (C,) i32  FOR chained value / SUMUP adder
    e_exit_at: jnp.ndarray      # (C,) i32  engine exit clock (0 = not set)
    # SUMUP inbox: two-stage latched stream child -> parent
    inbox_val: jnp.ndarray      # (C, RING) i32
    inbox_tick: jnp.ndarray     # (C, RING) i32  QTERM clock of each entry
    inbox_head: jnp.ndarray     # (C,) i32  consumed count
    inbox_tail: jnp.ndarray     # (C,) i32  arrived count
    # transient (within-tick) requests from the exec phase to the SV phase
    term_req: jnp.ndarray       # (C,) i32
    meta_op: jnp.ndarray        # (C,) i32  0 = none
    meta_a: jnp.ndarray         # (C,) i32
    meta_b: jnp.ndarray
    meta_imm: jnp.ndarray
    meta_imm2: jnp.ndarray
    meta_imm3: jnp.ndarray
    # global
    clock: jnp.ndarray          # () i32
    peak_used: jnp.ndarray      # () i32
    created_total: jnp.ndarray  # () i32


class MachineResult(NamedTuple):
    clocks: jnp.ndarray         # () i32   total execution time
    result: jnp.ndarray         # () i32   %eax of core 0 at halt
    regs0: jnp.ndarray          # (8,) i32
    mem: jnp.ndarray            # (MEM_WORDS,) i32
    peak_cores: jnp.ndarray     # () i32   max cores simultaneously in use
    created_total: jnp.ndarray  # () i32   total QT creations
    halted: jnp.ndarray         # () bool  clean halt (not clock-limit)


def _u32bit(i):
    return jnp.left_shift(jnp.uint32(1), jnp.asarray(i).astype(jnp.uint32))


def init_state(mem_init: np.ndarray | jnp.ndarray) -> MachineState:
    C = MAX_CORES
    mem = jnp.zeros((MEM_WORDS,), jnp.int32)
    mem_init = jnp.asarray(mem_init, jnp.int32)
    mem = mem.at[: mem_init.shape[0]].set(mem_init)
    z = lambda *s: jnp.zeros(s, jnp.int32)
    status = z(C).at[0].set(RUN)   # SV "creates" the cores, enables core 0 (§4.5)
    return MachineState(
        mem=mem, regs=z(C, isa.NREGS), pc=z(C), zf=z(C), sf=z(C),
        status=status, busy=z(C), parent=z(C) - 1, children=z(C),
        childmask=jnp.zeros((C,), jnp.uint32), prealloc=z(C),
        pool_release=z(C), rent_clock=z(C),
        latch_fromchild=z(C), latch_valid=z(C), latch_forparent=z(C),
        unblock_after=z(C),
        mode=z(C), e_remaining=z(C), e_total=z(C), e_consumed=z(C),
        e_inflight=z(C), e_addr=z(C), e_stride=z(C), e_payload=z(C),
        e_addr_reg=z(C), e_count_reg=z(C), e_aluop=z(C), e_acc=z(C),
        e_exit_at=z(C),
        inbox_val=z(C, RING), inbox_tick=z(C, RING),
        inbox_head=z(C), inbox_tail=z(C),
        term_req=z(C), meta_op=z(C), meta_a=z(C), meta_b=z(C),
        meta_imm=z(C), meta_imm2=z(C), meta_imm3=z(C),
        clock=jnp.int32(0), peak_used=jnp.int32(0),
        created_total=jnp.int32(0),
    )


# ---------------------------------------------------------------------------
# Phase 1+2: vectorized core execution (fetch/execute/complete).
# ---------------------------------------------------------------------------

def _exec_phase(s: MachineState, prog: jnp.ndarray, cost: jnp.ndarray) -> MachineState:
    C = MAX_CORES
    run = s.status == RUN

    # Stage A: cores mid-instruction burn one clock.
    burning = run & (s.busy > 0)
    busy = jnp.where(burning, s.busy - 1, s.busy)
    completed_a = burning & (busy == 0)

    # Stage B: cores with busy==0 fetch and execute.  A core with a pending
    # (retrying) metainstruction is blocked until the SV satisfies it.
    fetch = run & (s.busy == 0) & (s.meta_op == 0)
    pcs = jnp.clip(s.pc, 0, prog.shape[0] - 1)
    op = prog[pcs, 0]
    a = prog[pcs, 1]
    b = prog[pcs, 2]
    imm = prog[pcs, 3]
    imm2 = prog[pcs, 4]
    imm3 = prog[pcs, 5]

    rows = jnp.arange(C)
    aval = s.regs[rows, jnp.clip(a, 0, isa.NREGS - 1)]
    bval = s.regs[rows, jnp.clip(b, 0, isa.NREGS - 1)]

    regs, mem, pc, zf, sf, status = s.regs, s.mem, s.pc, s.zf, s.sf, s.status
    latch_forparent = s.latch_forparent

    def owrite(dst_reg, val, m):
        # masked register write
        cur = regs[rows, jnp.clip(dst_reg, 0, isa.NREGS - 1)]
        new = jnp.where(m, val, cur)
        return regs.at[rows, jnp.clip(dst_reg, 0, isa.NREGS - 1)].set(new)

    # IRMOVL / RRMOVL
    m = fetch & (op == Op.IRMOVL)
    regs = owrite(b, imm, m)
    m = fetch & (op == Op.RRMOVL)
    regs = owrite(b, aval, m)
    # MRMOVL: regs[a] = mem[(bval+imm)>>2]
    m = fetch & (op == Op.MRMOVL)
    addr_w = jnp.clip((bval + imm) >> 2, 0, MEM_WORDS - 1)
    regs = owrite(a, mem[addr_w], m)
    # RMMOVL: mem[(bval+imm)>>2] = aval   (EMPA coordination excludes
    # simultaneous conflicting access, §4.1.4 — last writer wins here)
    # (word MEM_WORDS-1 is a reserved scratch word: masked-off lanes land
    # there so duplicate-index scatter never clobbers live data)
    m = fetch & (op == Op.RMMOVL)
    mem = mem.at[jnp.where(m, addr_w, MEM_WORDS - 1)].set(
        jnp.where(m, aval, mem[MEM_WORDS - 1]))
    # ALU ops
    is_alu = (op == Op.ADDL) | (op == Op.SUBL) | (op == Op.ANDL) | (op == Op.XORL)
    res = jnp.where(op == Op.ADDL, bval + aval,
          jnp.where(op == Op.SUBL, bval - aval,
          jnp.where(op == Op.ANDL, bval & aval, bval ^ aval)))
    m = fetch & is_alu
    regs = owrite(b, res, m)
    zf = jnp.where(m, (res == 0).astype(jnp.int32), zf)
    sf = jnp.where(m, (res < 0).astype(jnp.int32), sf)
    # PADDL: write the ForParent latch (child-side pseudo-register, §4.6)
    m = fetch & (op == Op.PADDL)
    latch_forparent = jnp.where(m, aval, latch_forparent)

    # Jumps
    is_jmp = (op >= Op.JMP) & (op <= Op.JG)
    taken = jnp.where(op == Op.JMP, True,
            jnp.where(op == Op.JLE, (sf == 1) | (zf == 1),
            jnp.where(op == Op.JL, sf == 1,
            jnp.where(op == Op.JE, zf == 1,
            jnp.where(op == Op.JNE, zf == 0,
            jnp.where(op == Op.JGE, sf == 0,
                      (sf == 0) & (zf == 0)))))))
    new_pc = jnp.where(fetch & is_jmp & taken, imm, pc + 1)
    pc = jnp.where(fetch, new_pc, pc)

    # HALT: request SV attention (handled like a termination of core 0 /
    # any core running plain code).
    halt_req = fetch & (op == Op.HALT)

    # Meta fetched directly (cost table; QTERM cost 0 handled as term req).
    # PADDL is NOT a meta: it is a normal instruction that writes the
    # ForParent pseudo-register (§4.6) at register speed.
    is_meta = (op >= Op.QPREALLOC) & (op <= Op.QSUMUP)
    meta_fetch = fetch & is_meta & (op != Op.QTERM)
    term_fetch = fetch & (op == Op.QTERM)

    # busy bookkeeping for fetched instructions
    op_cost = cost[jnp.clip(op, 0, isa.MAX_OP - 1)]
    busy = jnp.where(fetch, jnp.maximum(op_cost - 1, 0), busy)
    completed_b = fetch & (busy == 0) & ~is_meta & ~halt_req
    completed = completed_a | completed_b

    # QTERM absorption: completed instructions pre-fetch; if the next op is
    # QTERM the SV handles termination in this same clock (§4.5).
    pcs2 = jnp.clip(pc, 0, prog.shape[0] - 1)
    peek = prog[pcs2, 0]
    term_peek = completed & (peek == Op.QTERM)
    pc = jnp.where(term_peek, pc + 1, pc)

    term_req = (term_fetch | term_peek).astype(jnp.int32)
    # halts: mark HALTWAIT; SV phase finalizes (blocks on live children §4.3)
    status = jnp.where(halt_req, HALTWAIT, status)
    # halt occupies the core for its cost
    busy = jnp.where(halt_req, jnp.maximum(cost[int(Op.HALT)] - 1, 0), busy)

    # preserve pending (retrying) meta requests from earlier clocks
    meta_op = jnp.where(meta_fetch, op, s.meta_op)
    return s._replace(
        mem=mem, regs=regs, pc=pc, zf=zf, sf=sf, status=status, busy=busy,
        latch_forparent=latch_forparent, term_req=term_req,
        meta_op=meta_op,
        meta_a=jnp.where(meta_fetch, a, s.meta_a),
        meta_b=jnp.where(meta_fetch, b, s.meta_b),
        meta_imm=jnp.where(meta_fetch, imm, s.meta_imm),
        meta_imm2=jnp.where(meta_fetch, imm2, s.meta_imm2),
        meta_imm3=jnp.where(meta_fetch, imm3, s.meta_imm3),
    )


# ---------------------------------------------------------------------------
# Phase 3: supervisor — sequential over cores ("one operation at a time").
# ---------------------------------------------------------------------------

def _rent_core(s: MachineState):
    """Index of the first rentable core, or -1."""
    free = (s.status == POOL) & (s.pool_release <= s.clock)
    idx = jnp.argmax(free)
    return jnp.where(jnp.any(free), idx.astype(jnp.int32), jnp.int32(-1))


def _clone_to(s: MachineState, parent_i, child_i, qt_addr,
              override_reg, override_val, override2_reg, override2_val,
              is_engine_child):
    """Rent ``child_i`` for ``parent_i``: clone the glue, set the QT address.

    The SV "clones the complete internal state (including the register file
    and the PC) of the parent to the new child" (§4.6); engine children get
    the SV-maintained address / chained value written over the clone.
    """
    base = s.regs[parent_i]
    r1 = jnp.clip(override_reg, 0, isa.NREGS - 1)
    base = base.at[r1].set(jnp.where(override_reg >= 0, override_val, base[r1]))
    r2 = jnp.clip(override2_reg, 0, isa.NREGS - 1)
    base = base.at[r2].set(jnp.where(override2_reg >= 0, override2_val, base[r2]))
    regs = s.regs.at[child_i].set(base)
    return s._replace(
        regs=regs,
        pc=s.pc.at[child_i].set(qt_addr),
        zf=s.zf.at[child_i].set(s.zf[parent_i]),
        sf=s.sf.at[child_i].set(s.sf[parent_i]),
        status=s.status.at[child_i].set(RUN),
        busy=s.busy.at[child_i].set(0),
        parent=s.parent.at[child_i].set(parent_i),
        children=s.children.at[parent_i].add(1),
        childmask=s.childmask.at[parent_i].set(
            s.childmask[parent_i] | _u32bit(child_i)),
        rent_clock=s.rent_clock.at[child_i].set(s.clock),
        # fresh life: no transient requests carry over from a prior QT
        meta_op=s.meta_op.at[child_i].set(0),
        term_req=s.term_req.at[child_i].set(0),
        e_inflight=jnp.where(is_engine_child,
                             s.e_inflight.at[parent_i].add(1), s.e_inflight),
        created_total=s.created_total + 1,
    )


def _sv_handle_term(s: MachineState, i) -> MachineState:
    """Child core ``i`` raised its Meta/termination signal this clock."""
    p = s.parent[i]
    has_parent = p >= 0
    pm = jnp.maximum(p, 0)
    pmode = jnp.where(has_parent, s.mode[pm], 0)

    # FOR engine: clone back the link register into the SV-chained value.
    e_acc = jnp.where(has_parent & (pmode == 1),
                      s.e_acc.at[pm].set(s.regs[i, LINK_REG]), s.e_acc)
    # SUMUP engine: enqueue the ForParent latch into the parent's inbox.
    slot = s.inbox_tail[pm] % RING
    do_inbox = has_parent & (pmode == 2)
    inbox_val = jnp.where(do_inbox,
                          s.inbox_val.at[pm, slot].set(s.latch_forparent[i]),
                          s.inbox_val)
    inbox_tick = jnp.where(do_inbox,
                           s.inbox_tick.at[pm, slot].set(s.clock),
                           s.inbox_tick)
    inbox_tail = jnp.where(do_inbox, s.inbox_tail.at[pm].add(1), s.inbox_tail)
    # plain QT: latch the link register for the parent (two-stage transfer)
    plain = has_parent & (pmode == 0)
    latch_fromchild = jnp.where(plain,
                                s.latch_fromchild.at[pm].set(s.regs[i, LINK_REG]),
                                s.latch_fromchild)
    latch_valid = jnp.where(plain, s.latch_valid.at[pm].set(1), s.latch_valid)
    unblock_after = jnp.where(has_parent,
                              s.unblock_after.at[pm].set(s.clock + 1),
                              s.unblock_after)

    # core returns to the pool; SUMUP turnaround holds it out for 30 clocks
    release = jnp.where(pmode == 2, s.rent_clock[i] + SUMUP_TURNAROUND,
                        s.clock + 1)
    return s._replace(
        status=s.status.at[i].set(POOL),
        busy=s.busy.at[i].set(0),
        pool_release=s.pool_release.at[i].set(release),
        parent=s.parent.at[i].set(-1),
        children=jnp.where(has_parent, s.children.at[pm].add(-1), s.children),
        childmask=jnp.where(has_parent,
                            s.childmask.at[pm].set(
                                s.childmask[pm] & ~_u32bit(i)),
                            s.childmask),
        e_inflight=jnp.where(has_parent & (pmode > 0),
                             s.e_inflight.at[pm].add(-1), s.e_inflight),
        e_acc=e_acc, inbox_val=inbox_val, inbox_tick=inbox_tick,
        inbox_tail=inbox_tail, latch_fromchild=latch_fromchild,
        latch_valid=latch_valid, unblock_after=unblock_after,
        term_req=s.term_req.at[i].set(0),
    )


def _sv_handle_meta(s: MachineState, i) -> MachineState:
    """Execute core ``i``'s fetched metainstruction at the SV level."""
    mop = s.meta_op[i]

    # QPREALLOC: reserve capacity (bookkeeping only; guarantees §5.1)
    s = s._replace(prealloc=jnp.where(mop == Op.QPREALLOC,
                                      s.prealloc.at[i].set(s.meta_imm[i]),
                                      s.prealloc))

    # QCREATE: rent + clone; child begins next clock.
    def do_create(st):
        c = _rent_core(st)
        ok = c >= 0
        cm = jnp.maximum(c, 0)
        st2 = _clone_to(st, i, cm, st.meta_imm[i],
                        jnp.int32(-1), jnp.int32(0), jnp.int32(-1), jnp.int32(0),
                        jnp.bool_(False))
        st2 = jax.tree_util.tree_map(lambda a, b: jnp.where(ok, a, b), st2, st)
        # out of cores: the issuing core blocks until one frees (§4.5);
        # model: retry by not advancing (keep meta pending)
        st2 = st2._replace(meta_op=st2.meta_op.at[i].set(
            jnp.where(ok, 0, Op.QCREATE)))
        return st2

    s = jax.lax.cond(mop == Op.QCREATE, do_create, lambda st: st, s)

    # QWAIT: block until children==0 (unblock handled in engine phase)
    s = s._replace(status=jnp.where(mop == Op.QWAIT,
                                    s.status.at[i].set(WAITQ), s.status),
                   meta_op=jnp.where(mop == Op.QWAIT,
                                     s.meta_op.at[i].set(0), s.meta_op))

    # QFOR / QSUMUP: configure and arm the engine; parent blocks.
    def arm(st, which):
        is_for = which == 1
        addr_reg = jnp.where(is_for, st.meta_b[i], st.meta_a[i])
        count_reg = jnp.where(is_for, st.meta_a[i], st.meta_b[i])
        count = st.regs[i, count_reg]
        return st._replace(
            status=st.status.at[i].set(ENGINE),
            mode=st.mode.at[i].set(which),
            e_remaining=st.e_remaining.at[i].set(count),
            e_total=st.e_total.at[i].set(count),
            e_consumed=st.e_consumed.at[i].set(0),
            e_inflight=st.e_inflight.at[i].set(0),
            e_addr=st.e_addr.at[i].set(st.regs[i, addr_reg]),
            e_stride=st.e_stride.at[i].set(st.meta_imm2[i]),
            e_payload=st.e_payload.at[i].set(st.meta_imm[i]),
            e_addr_reg=st.e_addr_reg.at[i].set(addr_reg),
            e_count_reg=st.e_count_reg.at[i].set(count_reg),
            e_aluop=st.e_aluop.at[i].set(st.meta_imm3[i]),
            # FOR chains the parent's link register through the children;
            # SUMUP's combining unit starts from it (cleared by the code).
            e_acc=st.e_acc.at[i].set(st.regs[i, LINK_REG]),
            e_exit_at=st.e_exit_at.at[i].set(0),
            inbox_head=st.inbox_head.at[i].set(0),
            inbox_tail=st.inbox_tail.at[i].set(0),
            meta_op=st.meta_op.at[i].set(0),
        )

    s = jax.lax.cond(mop == Op.QFOR, lambda st: arm(st, jnp.int32(1)),
                     lambda st: st, s)
    s = jax.lax.cond(mop == Op.QSUMUP, lambda st: arm(st, jnp.int32(2)),
                     lambda st: st, s)
    s = s._replace(meta_op=jnp.where(mop == Op.QPREALLOC,
                                     s.meta_op.at[i].set(0), s.meta_op))
    return s


def _sv_engine_step(s: MachineState, i) -> MachineState:
    """Advance core ``i``'s mass-processing engine by one SV clock."""
    mode = s.mode[i]

    # ---- FOR: one child at a time; re-create one clock after termination.
    def for_step(st):
        can_create = (st.e_remaining[i] > 0) & (st.e_inflight[i] == 0) & \
                     (st.unblock_after[i] <= st.clock)
        def create(st2):
            c = _rent_core(st2)
            ok = c >= 0
            cm = jnp.maximum(c, 0)
            st3 = _clone_to(st2, jnp.int32(i), cm, st2.e_payload[i],
                            st2.e_addr_reg[i], st2.e_addr[i],
                            jnp.int32(LINK_REG), st2.e_acc[i],
                            jnp.bool_(True))
            st3 = st3._replace(
                e_remaining=st3.e_remaining.at[i].add(-1),
                e_addr=st3.e_addr.at[i].add(st3.e_stride[i]),
            )
            return jax.tree_util.tree_map(
                lambda a_, b_: jnp.where(ok, a_, b_), st3, st2)
        st = jax.lax.cond(can_create, create, lambda x: x, st)
        # completion: all created and none in flight -> exit transfer one
        # clock after the last SV action (the final child's termination)
        done = (st.e_remaining[i] == 0) & (st.e_inflight[i] == 0)
        st = st._replace(e_exit_at=jnp.where(
            done & (st.e_exit_at[i] == 0),
            st.e_exit_at.at[i].set(jnp.maximum(st.clock, st.unblock_after[i])),
            st.e_exit_at))
        def exit_(st2):
            # SV transfers the final chained value into the parent's link
            # register and unblocks it (one clock: the exit transfer).
            regs = st2.regs.at[i, LINK_REG].set(st2.e_acc[i])
            regs = regs.at[i, st2.e_addr_reg[i]].set(st2.e_addr[i])
            regs = regs.at[i, st2.e_count_reg[i]].set(0)
            return st2._replace(
                regs=regs,
                zf=st2.zf.at[i].set(1),  # count reached zero
                status=st2.status.at[i].set(RUN),
                mode=st2.mode.at[i].set(0),
                e_exit_at=st2.e_exit_at.at[i].set(0))
        do_exit = (st.e_exit_at[i] > 0) & (st.clock >= st.e_exit_at[i])
        return jax.lax.cond(do_exit, exit_, lambda x: x, st)

    # ---- SUMUP: stagger one creation per clock; combine one value per clock.
    def sumup_step(st):
        # 1 creation per SV clock while elements remain and a core is free
        def create(st2):
            c = _rent_core(st2)
            ok = c >= 0
            cm = jnp.maximum(c, 0)
            st3 = _clone_to(st2, jnp.int32(i), cm, st2.e_payload[i],
                            st2.e_addr_reg[i], st2.e_addr[i],
                            jnp.int32(-1), jnp.int32(0), jnp.bool_(True))
            st3 = st3._replace(
                e_remaining=st3.e_remaining.at[i].add(-1),
                e_addr=st3.e_addr.at[i].add(st3.e_stride[i]),
            )
            return jax.tree_util.tree_map(
                lambda a_, b_: jnp.where(ok, a_, b_), st3, st2)
        st = jax.lax.cond(st.e_remaining[i] > 0, create, lambda x: x, st)

        # parent-side combining unit: consume one latched value per clock,
        # two clocks after the child's termination (two-stage transfer).
        def consume(st2):
            slot = st2.inbox_head[i] % RING
            v = st2.inbox_val[i, slot]
            acc = st2.e_acc[i]
            aluop = st2.e_aluop[i]
            acc = jnp.where(aluop == isa.ALU_ADD, acc + v,
                  jnp.where(aluop == isa.ALU_AND, acc & v, acc ^ v))
            return st2._replace(e_acc=st2.e_acc.at[i].set(acc),
                                inbox_head=st2.inbox_head.at[i].add(1),
                                e_consumed=st2.e_consumed.at[i].add(1),
                                unblock_after=st2.unblock_after.at[i].set(
                                    st2.clock + 1))
        slot = st.inbox_head[i] % RING
        can_consume = (st.inbox_tail[i] > st.inbox_head[i]) & \
                      (st.clock >= st.inbox_tick[i, slot] + 2)
        st = jax.lax.cond(can_consume, consume, lambda x: x, st)

        # completion: everything combined -> readout one clock after the
        # last combine (the final latch -> link-register transfer)
        done = (st.e_consumed[i] == st.e_total[i]) & (st.e_remaining[i] == 0)
        st = st._replace(e_exit_at=jnp.where(
            done & (st.e_exit_at[i] == 0),
            st.e_exit_at.at[i].set(jnp.maximum(st.clock, st.unblock_after[i])),
            st.e_exit_at))
        def exit_(st2):
            regs = st2.regs.at[i, LINK_REG].set(st2.e_acc[i])
            regs = regs.at[i, st2.e_addr_reg[i]].set(st2.e_addr[i])
            return st2._replace(
                regs=regs,
                status=st2.status.at[i].set(RUN),
                mode=st2.mode.at[i].set(0),
                e_exit_at=st2.e_exit_at.at[i].set(0))
        do_exit = (st.e_exit_at[i] > 0) & (st.clock >= st.e_exit_at[i])
        return jax.lax.cond(do_exit, exit_, lambda x: x, st)

    s = jax.lax.cond((s.status[i] == ENGINE) & (mode == 1), for_step,
                     lambda x: x, s)
    s = jax.lax.cond((s.status[i] == ENGINE) & (mode == 2), sumup_step,
                     lambda x: x, s)

    # QWAIT unblock: children gone, latch transferred (one clock after the
    # last termination), latched value written back on request (§4.6).
    def unwait(st):
        regs = jnp.where(st.latch_valid[i] == 1,
                         st.regs.at[i, LINK_REG].set(st.latch_fromchild[i]),
                         st.regs)
        return st._replace(regs=regs,
                           latch_valid=st.latch_valid.at[i].set(0),
                           status=st.status.at[i].set(RUN))
    can_unwait = (s.status[i] == WAITQ) & (s.children[i] == 0) & \
                 (s.clock >= s.unblock_after[i])
    s = jax.lax.cond(can_unwait, unwait, lambda x: x, s)

    # HALTWAIT -> HALTED once children cleared (§4.3: SV blocks termination
    # of a parent until its children mask gets cleared).
    can_halt = (s.status[i] == HALTWAIT) & (s.children[i] == 0) & \
               (s.busy[i] == 0)
    s = s._replace(status=jnp.where(can_halt, s.status.at[i].set(HALTED),
                                    s.status))
    return s


def _tick(s: MachineState, prog: jnp.ndarray, cost: jnp.ndarray) -> MachineState:
    s = s._replace(clock=s.clock + 1)
    s = _exec_phase(s, prog, cost)

    # SV phase — strictly sequential over cores (§4.1.3).
    def body(i, st):
        st = jax.lax.cond(st.term_req[i] == 1,
                          lambda x: _sv_handle_term(x, i), lambda x: x, st)
        st = jax.lax.cond(st.meta_op[i] > 0,
                          lambda x: _sv_handle_meta(x, i), lambda x: x, st)
        st = _sv_engine_step(st, i)
        return st
    s = jax.lax.fori_loop(0, MAX_CORES, body, s)

    # HALT burns its cost like any instruction
    s = s._replace(busy=jnp.where((s.status == HALTWAIT) & (s.busy > 0),
                                  s.busy - 1, s.busy))

    used = jnp.sum(((s.status != POOL) | (s.pool_release > s.clock)).astype(jnp.int32))
    return s._replace(peak_used=jnp.maximum(s.peak_used, used))


def _all_done(s: MachineState) -> jnp.ndarray:
    idle = (s.status == POOL) | (s.status == HALTED)
    return jnp.all(idle) & (s.status[0] == HALTED)


@functools.partial(jax.jit, static_argnames=("max_clocks",))
def _run(prog: jnp.ndarray, mem_init: jnp.ndarray, max_clocks: int) -> MachineResult:
    cost = jnp.asarray(isa.cost_table())
    s0 = init_state(mem_init)

    def cond(s):
        return (~_all_done(s)) & (s.clock < max_clocks)

    def step(s):
        return _tick(s, prog, cost)

    s = jax.lax.while_loop(cond, step, s0)
    return MachineResult(
        clocks=s.clock, result=s.regs[0, LINK_REG], regs0=s.regs[0],
        mem=s.mem, peak_cores=s.peak_used, created_total=s.created_total,
        halted=_all_done(s))


def run_program(prog: np.ndarray, mem_init=(), max_clocks: int = 100_000) -> MachineResult:
    """Assemble-and-run entry point.  ``prog`` is an (P, 6) int32 image."""
    prog = np.asarray(prog, np.int32)
    if prog.shape[1] == 5:  # pad legacy 5-field encodings
        prog = np.concatenate([prog, np.zeros((prog.shape[0], 1), np.int32)], 1)
    mem = np.zeros((MEM_WORDS,), np.int32)
    mem_init = np.asarray(list(mem_init) + [0], np.int32)
    mem[: mem_init.shape[0]] = mem_init
    return _run(jnp.asarray(prog), jnp.asarray(mem), max_clocks)
