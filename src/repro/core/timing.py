"""Analytic timing model of the EMPA processor + the paper's figure of merit.

Table 1 of the paper implies (and the clock-level machine reproduces) the
exact execution-time model::

    T_NO(n)    = 22 + 30 n      k = 1
    T_FOR(n)   = 20 + 11 n      k = 2
    T_SUMUP(n) = 32 +  1 n      k = min(n, 30) + 1

Derivation (isa.COST): the conventional loop body is
mrmovl(6)+addl(4)+irmovl(4)+addl(4)+irmovl(4)+addl(4)+jne(4) = 30 clocks and
setup+halt = 22.  FOR replaces the computed control instructions by SV
functionality: one create clock + the 10-clock payload per iteration, with a
2-clock prologue difference (no 'je' guard; +prealloc +mode-enter +exit
transfer -wait elision) — net 20 + 11 n.  SUMUP staggers one child per clock
into a parent-side combining unit: after a 12-clock pipeline fill, one
element per clock, +readout: 32 + n.  Speedups saturate at 30/11 and 30
(paper §6.1), and at most 31 cores are ever in use because a child core's
full turnaround is 30 clocks (§6.2).
"""
from __future__ import annotations

from typing import Literal

import numpy as np

Mode = Literal["NO", "FOR", "SUMUP"]

# EMPA child-core turnaround in SUMUP mode (rent -> ... -> rentable), clocks.
SUMUP_TURNAROUND = 30
MAX_SUMUP_CORES = SUMUP_TURNAROUND + 1  # 30 children + 1 parent (§6.2)


def exec_clocks(n, mode: Mode):
    """Execution time of the `sumup` workload on an n-element vector."""
    n = np.asarray(n)
    if mode == "NO":
        return 22 + 30 * n
    if mode == "FOR":
        return 20 + 11 * n
    if mode == "SUMUP":
        return 32 + n
    raise ValueError(mode)


def cores_used(n, mode: Mode):
    n = np.asarray(n)
    if mode == "NO":
        return np.ones_like(n)
    if mode == "FOR":
        return np.full_like(n, 2)
    if mode == "SUMUP":
        return np.minimum(n, SUMUP_TURNAROUND) + 1
    raise ValueError(mode)


def speedup(n, mode: Mode):
    return exec_clocks(n, "NO") / exec_clocks(n, mode)


def s_over_k(n, mode: Mode):
    """The traditional merit S/k (paper Fig. 5/6)."""
    return speedup(n, mode) / cores_used(n, mode)


def alpha_eff(k, s):
    """Effective parallelization, Eq. (1):  α_eff = k/(k−1) · (S−1)/S.

    For k == 1 the merit is defined as 1 (perfectly 'parallelized' single
    core, matching Table 1's NO rows).
    """
    k = np.asarray(k, dtype=np.float64)
    s = np.asarray(s, dtype=np.float64)
    with np.errstate(divide="ignore", invalid="ignore"):
        a = k / (k - 1.0) * (s - 1.0) / s
    return np.where(k <= 1, 1.0, a)


def alpha_eff_mode(n, mode: Mode):
    """α_eff for the sumup workload; uses k_eff = min(n,30)+1 per §6.2."""
    return alpha_eff(cores_used(n, mode), speedup(n, mode))


def saturation_speedup(mode: Mode) -> float:
    """lim n→∞ of the speedup (paper §6.1: 30/11 and 30)."""
    if mode == "NO":
        return 1.0
    if mode == "FOR":
        return 30.0 / 11.0
    if mode == "SUMUP":
        return 30.0
    raise ValueError(mode)


# Table 1 of the paper, verbatim (vector length, mode, clocks, cores,
# speedup, S/k, alpha_eff) — the oracle for tests and benchmarks.
TABLE1 = [
    (1, "NO", 52, 1, 1.0, 1.0, 1.0),
    (1, "FOR", 31, 2, 1.68, 0.84, 0.81),
    (1, "SUMUP", 33, 2, 1.58, 0.79, 0.73),
    (2, "NO", 82, 1, 1.0, 1.0, 1.0),
    (2, "FOR", 42, 2, 1.95, 0.98, 0.97),
    (2, "SUMUP", 34, 3, 2.41, 0.80, 0.87),
    (4, "NO", 142, 1, 1.0, 1.0, 1.0),
    (4, "FOR", 64, 2, 2.22, 1.11, 1.10),
    (4, "SUMUP", 36, 5, 3.94, 0.79, 0.93),
    (6, "NO", 202, 1, 1.0, 1.0, 1.0),
    (6, "FOR", 86, 2, 2.34, 1.17, 1.15),
    (6, "SUMUP", 38, 7, 5.31, 0.76, 0.95),
]
