# The paper's primary contribution: the EMPA model.
#   isa        — Y86 + EMPA metainstruction encoding
#   machine    — clock-level jittable multi-core machine + supervisor
#   supervisor — reusable SV pool semantics (serving slots, elastic pool)
#   qt         — Quasi-Thread graphs (compile-time parallelization metadata)
#   timing     — analytic timing model + alpha_eff (Eq. 1)
#   programs   — the paper's workloads (Listing 1 in NO / FOR / SUMUP)
from repro.core import isa, machine, programs, qt, supervisor, timing  # noqa: F401
from repro.core.machine import MachineResult, run_program  # noqa: F401
from repro.core.supervisor import CorePool  # noqa: F401
from repro.core.timing import (  # noqa: F401
    TABLE1, alpha_eff, alpha_eff_mode, cores_used, exec_clocks, s_over_k,
    speedup)
