"""Quasi-Thread graphs: compile-time parallelization metadata.

"The information about the possible outsourcing must be prepared at compile
time rather than at runtime, the code must be cut to optimally sized, partly
independent QTs, the processor must be notified about the pre-calculated
parallelization possibilities" (§3).

At cluster scale the "code" is a training/serving step and the "cores" are
mesh devices.  A :class:`QTGraph` records the step's fragments (QTs), their
parent-child ("glue"/clone) edges with byte sizes, and the mass-processing
mode each fragment uses.  The cluster supervisor (`runtime/supervisor.py`)
maps the graph onto mesh axes and plans the collective schedule — the
cluster-level analogue of the SV translating compile-time QT addresses to
runtime physical core numbers (§3.3).
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Optional


class MassMode(enum.Enum):
    NONE = "NO"        # plain sequential fragment
    FOR = "FOR"        # SV-owned loop: lax.scan / Pallas grid owns control
    SUMUP = "SUMUP"    # fused streaming reduction: no partial writeback


@dataclasses.dataclass(frozen=True)
class QT:
    """One compile-time fragment of the step."""
    name: str
    flops: float = 0.0           # payload compute
    param_bytes: float = 0.0     # weights touched ("glue" cloned in)
    act_bytes: float = 0.0       # activations produced ("glue" cloned back)
    mode: MassMode = MassMode.NONE
    # preferred partitioning of the fragment's parallel dimension
    shard_axis: Optional[str] = None


@dataclasses.dataclass
class QTGraph:
    qts: list[QT] = dataclasses.field(default_factory=list)
    edges: list[tuple[str, str, float]] = dataclasses.field(default_factory=list)

    def add(self, qt: QT, parent: Optional[str] = None,
            glue_bytes: float = 0.0) -> QT:
        if any(q.name == qt.name for q in self.qts):
            raise ValueError(f"duplicate QT {qt.name}")
        self.qts.append(qt)
        if parent is not None:
            if not any(q.name == parent for q in self.qts):
                raise ValueError(f"unknown parent {parent}")
            self.edges.append((parent, qt.name, glue_bytes))
        return qt

    def get(self, name: str) -> QT:
        for q in self.qts:
            if q.name == name:
                return q
        raise KeyError(name)

    def children(self, name: str) -> list[str]:
        return [c for p, c, _ in self.edges if p == name]

    def parent(self, name: str) -> Optional[str]:
        ps = [p for p, c, _ in self.edges if c == name]
        if len(ps) > 1:
            raise ValueError(f"QT {name} has multiple parents")  # §4.2
        return ps[0] if ps else None

    def roots(self) -> list[str]:
        have_parent = {c for _, c, _ in self.edges}
        return [q.name for q in self.qts if q.name not in have_parent]

    # -- aggregate accounting (drives the roofline napkin math) -----------
    def total_flops(self) -> float:
        return sum(q.flops for q in self.qts)

    def total_glue_bytes(self) -> float:
        return sum(b for _, _, b in self.edges)

    def check_invariants(self) -> None:
        names = [q.name for q in self.qts]
        assert len(set(names)) == len(names)
        for p, c, b in self.edges:
            assert p in names and c in names and b >= 0
            assert p != c
        # acyclic (it's a fork tree: every QT has ≤1 parent)
        for q in self.qts:
            seen = set()
            cur: Optional[str] = q.name
            while cur is not None:
                assert cur not in seen, "cycle in QT graph"
                seen.add(cur)
                cur = self.parent(cur)
