"""The paper's workloads: `sumup` (Listing 1) in NO / FOR / SUMUP coding.

The NO-mode program is the paper's Listing 1 verbatim (modulo the structured
encoding).  The FOR and SUMUP variants follow §5.1 / §5.2: the payload QT is
``mrmovl (%ecx),%esi ; addl %esi,%eax ; qterm`` — the two payload lines of
the loop kernel — while loop organization moves to the supervisor.
"""
from __future__ import annotations

import numpy as np

from repro.core import isa

ARRAY_BASE = 0x100  # byte address of the vector in simulator memory


def mem_image(vector) -> np.ndarray:
    """Memory image with the vector at ARRAY_BASE (word-addressed image)."""
    v = np.asarray(vector, np.int32)
    mem = np.zeros(ARRAY_BASE // 4 + len(v), np.int32)
    mem[ARRAY_BASE // 4:] = v
    return mem


def sumup_no(n: int) -> np.ndarray:
    """Listing 1: conventional coding.  T = 22 + 30 n."""
    return isa.assemble([
        ("irmovl", n, "%edx"),              # No of items to sum
        ("irmovl", ARRAY_BASE, "%ecx"),     # Array address
        ("xorl", "%eax", "%eax"),           # sum = 0
        ("andl", "%edx", "%edx"),           # Set condition codes
        ("je", "End"),
        ("label", "Loop"),
        ("mrmovl", 0, "%ecx", "%esi"),      # get *Start
        ("addl", "%esi", "%eax"),           # add to sum
        ("irmovl", 4, "%ebx"),
        ("addl", "%ebx", "%ecx"),           # Start++
        ("irmovl", -1, "%ebx"),
        ("addl", "%ebx", "%edx"),           # Count--
        ("jne", "Loop"),                    # Stop when 0
        ("label", "End"),
        ("halt",),
    ])


def sumup_for(n: int) -> np.ndarray:
    """§5.1: SV takes over loop organization.  T = 20 + 11 n, k = 2."""
    return isa.assemble([
        ("irmovl", n, "%edx"),
        ("irmovl", ARRAY_BASE, "%ecx"),
        ("xorl", "%eax", "%eax"),
        ("andl", "%edx", "%edx"),
        ("qprealloc", 1),                   # guarantee a core for the loop
        ("qfor", "%edx", "%ecx", "Payload", 4),
        ("halt",),
        ("label", "Payload"),               # the QT: payload lines 9-10
        ("mrmovl", 0, "%ecx", "%esi"),
        ("addl", "%esi", "%eax"),           # partial sum chained via %eax
        ("qterm",),
    ])


def sumup_sumup(n: int) -> np.ndarray:
    """§5.2: eliminate obsolete stages.  T = 32 + n, k = min(n,30) + 1."""
    return isa.assemble([
        ("irmovl", n, "%edx"),
        ("irmovl", ARRAY_BASE, "%ecx"),
        ("xorl", "%eax", "%eax"),
        ("andl", "%edx", "%edx"),
        ("qprealloc", 30),                  # preallocate the helper pool
        ("qsumup", "%ecx", "%edx", "Payload", 4, isa.ALU_ADD),
        ("halt",),
        ("label", "Payload"),               # child: load, stream to parent
        ("mrmovl", 0, "%ecx", "%esi"),
        ("paddl", "%esi"),                  # write ForParent pseudo-register
        ("qterm",),
    ])


PROGRAMS = {"NO": sumup_no, "FOR": sumup_for, "SUMUP": sumup_sumup}


def qt_tree(depth: int, fanout: int) -> np.ndarray:
    """A nested-QT test program: each QT spawns `fanout` children down to
    `depth`, each leaf contributes 1; result = number of leaves.

    Exercises generic QCREATE/QWAIT/QTERM (embedded QTs, §3: "QTs can be
    embedded into each other").  Built iteratively — each level's QT code
    is laid out after its parent's.
    """
    src: list[tuple] = []
    # level 0 (root) runs like a parent QT and halts
    for lvl in range(depth + 1):
        src.append(("label", f"L{lvl}"))
        if lvl == depth:
            src.append(("irmovl", 1, "%eax"))
        else:
            src.append(("xorl", "%ebx", "%ebx"))
            for _ in range(fanout):
                src.append(("qcreate", f"L{lvl + 1}"))
                src.append(("qwait",))
                # accumulate the child's clone-back (%eax latch) into %ebx
                src.append(("addl", "%eax", "%ebx"))
            src.append(("rrmovl", "%ebx", "%eax"))
        if lvl == 0:
            src.append(("halt",))
        else:
            src.append(("qterm",))
    return isa.assemble(src)
