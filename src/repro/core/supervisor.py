"""Supervisor resource semantics, reusable outside the clock-level machine.

The paper's SV "handles all resources of the processor" (§3.5) through
simple bitmask state: a pool of uniform units, rent/return, preallocation,
parent/children masks.  The clock-level machine (machine.py) embeds these
semantics; the *pure, jittable* transition functions live in
``repro.runtime.pool`` (SlotPoolState) so the same pool discipline can run
inside a compiled device program.  This module keeps the host-level
wrapper, :class:`CorePool`, whose API predates the refactor, so the
*same* property-tested transitions drive:

* the serving slot pool (`runtime/serve.py`: KV-cache slots are cores,
  requests are QTs — rent on admission, return on EOS), which runs the
  transitions *on device* via SlotPoolState,
* the elastic device-pool manager (`runtime/elastic.py`: pods/hosts are
  cores; a failed host is a core "disabled for some reason (like
  overheating)" §4.1.2 — the pool shrinks, work continues),
* property tests of the invariants the paper relies on (a core has at most
  one parent; children masks are consistent; pool conservation).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.runtime import pool as pool_lib
from repro.runtime.pool import SlotPoolState


@dataclasses.dataclass
class CorePool:
    """Host wrapper over the jittable EMPA pool transitions.

    Thin by construction: every transition is one `runtime.pool` step
    plus host-side error raising — the device-resident serving
    supervisor and this host pool can never drift apart.

    The ledger itself lives on the host: each transition's result is
    pulled back with one *explicit* ``jax.device_get``, so ``state``
    holds numpy leaves and every query (``available`` inside the
    admission loop, ``phase_of`` / the rented check in ``set_phase``)
    is a free host read instead of an implicit device->host sync.  The
    static auditor's transfer harness runs engine ticks under
    ``jax.transfer_guard_device_to_host("disallow")``, which lets these
    explicit ledger pulls through and catches any implicit ``int()`` /
    ``bool()`` on a device array creeping back in — the pre-audit
    wrapper performed one such hidden sync per rent/release/set_phase
    call, several per retirement inside the serving tick.
    """

    n: int
    state: SlotPoolState = dataclasses.field(init=False)

    def __post_init__(self):
        self.state = jax.device_get(pool_lib.init_pool(self.n))

    # -- queries (host reads over the numpy mirror) -------------------------
    @property
    def available(self) -> int:
        return int(np.sum(self.state.free & ~self.state.disabled))

    @property
    def used(self) -> int:
        return int(np.sum(~self.state.free))

    @property
    def created_total(self) -> int:
        return int(self.state.created_total)

    @property
    def peak_used(self) -> int:
        return int(self.state.peak_used)

    def children_of(self, unit: int) -> list[int]:
        mask = (self.state.parent == unit) & ~self.state.free
        return [int(i) for i in np.flatnonzero(mask)]

    def parent_of(self, unit: int) -> int:
        return int(self.state.parent[unit])

    def phase_of(self, unit: int) -> int:
        """Lifecycle phase of a unit: PHASE_IDLE / PHASE_PREFILL /
        PHASE_DECODE / PHASE_PREEMPTED (a QT is fed fragments before it
        runs, and may be parked mid-flight when the supervisor claws
        its lent resources back under pressure)."""
        self._check_unit(unit)
        return int(self.state.phase[unit])

    def ready(self) -> bool:
        """The SV's 'ALU avail' signal: ready while ≥1 core is free (§3.1)."""
        return self.available > 0

    # -- transitions -------------------------------------------------------
    def _check_unit(self, unit: int) -> None:
        if not 0 <= unit < self.n:
            raise IndexError(f"unit {unit} out of range for pool({self.n})")

    def rent(self, parent: Optional[int] = None,
             prefer_preallocated: bool = True) -> Optional[int]:
        """Rent the first available unit; administer parent/child masks."""
        if parent is not None:
            self._check_unit(parent)
        state, unit = pool_lib.rent(
            self.state, pool_lib.NO_PARENT if parent is None else parent,
            prefer_preallocated=prefer_preallocated)
        self.state, unit = jax.device_get((state, unit))
        unit = int(unit)
        return None if unit < 0 else unit

    def rent_many(self, k: int) -> list[int]:
        """Rent up to `k` units in one vectorized transition (same grant
        order as `k` sequential rents).  Returns the granted unit ids."""
        state, units = pool_lib.rent_many(self.state, jnp.ones((k,), bool))
        self.state, units = jax.device_get((state, units))
        return [int(u) for u in units if int(u) >= 0]

    def preallocate(self, parent: int, k: int) -> list[int]:
        """Mark k free units as preallocated for `parent` (§5.1: guarantees
        a core is always available for the iterations)."""
        self._check_unit(parent)
        state, granted = pool_lib.preallocate(self.state, parent, k)
        self.state, granted = jax.device_get((state, granted))
        return [int(i) for i in np.flatnonzero(granted)]

    def release(self, unit: int) -> None:
        """Terminate the QT on `unit`: clear masks, return to pool (§4.3)."""
        new_state, status = jax.device_get(pool_lib.release(self.state, unit))
        status = int(status)
        if status == pool_lib.ERR_NOT_RENTED:
            raise ValueError(f"unit {unit} is not rented")
        if status == pool_lib.ERR_LIVE_CHILDREN:
            # §4.3: the SV blocks termination of a parent until its
            # children mask gets cleared.
            raise RuntimeError(
                f"unit {unit} has live children; termination blocked")
        if status == pool_lib.ERR_BAD_UNIT:
            raise IndexError(f"unit {unit} out of range for pool({self.n})")
        self.state = new_state

    def set_phase(self, unit: int, phase: int) -> None:
        """Move a rented unit between lifecycle phases (PREFILL while its
        prompt is outsourced fragment by fragment, DECODE once it runs)."""
        self._check_unit(unit)
        if bool(self.state.free[unit]):
            raise ValueError(f"unit {unit} is not rented")
        self.state = jax.device_get(
            pool_lib.set_phase(self.state, unit, phase))

    def disable(self, unit: int) -> None:
        """A unit becomes unavailable ('overheating' / failed host)."""
        self._check_unit(unit)
        self.state = jax.device_get(pool_lib.disable(self.state, unit))

    def enable(self, unit: int) -> None:
        self._check_unit(unit)
        self.state = jax.device_get(pool_lib.enable(self.state, unit))

    # -- invariants (property-tested) --------------------------------------
    def check_invariants(self) -> None:
        pool_lib.check_invariants(self.state)
