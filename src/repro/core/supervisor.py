"""Supervisor resource semantics, reusable outside the clock-level machine.

The paper's SV "handles all resources of the processor" (§3.5) through
simple bitmask state: a pool of uniform units, rent/return, preallocation,
parent/children masks.  The clock-level machine (machine.py) embeds these
semantics; this module exposes them as a small, pure, framework-level
component so the *same* pool discipline drives:

* the serving slot pool (`runtime/serve.py`: KV-cache slots are cores,
  requests are QTs — rent on admission, return on EOS),
* the elastic device-pool manager (`runtime/elastic.py`: pods/hosts are
  cores; a failed host is a core "disabled for some reason (like
  overheating)" §4.1.2 — the pool shrinks, work continues),
* property tests of the invariants the paper relies on (a core has at most
  one parent; children masks are consistent; pool conservation).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np


@dataclasses.dataclass
class CorePool:
    """Bitmask pool of uniform units with EMPA rent/return semantics."""

    n: int
    # status per unit: True = in pool (available)
    _free: np.ndarray = dataclasses.field(init=False)
    _parent: np.ndarray = dataclasses.field(init=False)
    # bitmasks per unit as Python ints — arbitrary pool sizes (a cluster
    # fleet has many more units than the paper's 32 cores)
    _children: list = dataclasses.field(init=False)
    _prealloc: list = dataclasses.field(init=False)
    _disabled: np.ndarray = dataclasses.field(init=False)
    created_total: int = dataclasses.field(init=False, default=0)
    peak_used: int = dataclasses.field(init=False, default=0)

    def __post_init__(self):
        self._free = np.ones(self.n, bool)
        self._parent = np.full(self.n, -1, np.int64)
        self._children = [0] * self.n
        self._prealloc = [0] * self.n
        self._disabled = np.zeros(self.n, bool)

    # -- queries ----------------------------------------------------------
    @property
    def available(self) -> int:
        return int(np.sum(self._free & ~self._disabled))

    @property
    def used(self) -> int:
        return int(np.sum(~self._free))

    def children_of(self, unit: int) -> list[int]:
        mask = self._children[unit]
        return [i for i in range(self.n) if mask >> i & 1]

    def parent_of(self, unit: int) -> int:
        return int(self._parent[unit])

    def ready(self) -> bool:
        """The SV's 'ALU avail' signal: ready while ≥1 core is free (§3.1)."""
        return self.available > 0

    # -- transitions -------------------------------------------------------
    def rent(self, parent: Optional[int] = None,
             prefer_preallocated: bool = True) -> Optional[int]:
        """Rent the first available unit; administer parent/child masks."""
        cand = self._free & ~self._disabled
        if parent is not None and prefer_preallocated:
            pre = np.array([bool(self._prealloc[parent] >> i & 1)
                            for i in range(self.n)])
            if np.any(cand & pre):
                cand = cand & pre
        idx = np.flatnonzero(cand)
        if idx.size == 0:
            return None
        u = int(idx[0])
        self._free[u] = False
        if parent is not None:
            self._parent[u] = parent
            self._children[parent] |= 1 << u
        self.created_total += 1
        self.peak_used = max(self.peak_used, self.used)
        return u

    def preallocate(self, parent: int, k: int) -> list[int]:
        """Mark k free units as preallocated for `parent` (§5.1: guarantees
        a core is always available for the iterations)."""
        got = []
        for u in np.flatnonzero(self._free & ~self._disabled)[:k]:
            self._prealloc[parent] |= 1 << int(u)
            got.append(int(u))
        return got

    def release(self, unit: int) -> None:
        """Terminate the QT on `unit`: clear masks, return to pool (§4.3)."""
        if self._free[unit]:
            raise ValueError(f"unit {unit} is not rented")
        if self._children[unit] != 0:
            # §4.3: the SV blocks termination of a parent until its
            # children mask gets cleared.
            raise RuntimeError(
                f"unit {unit} has live children; termination blocked")
        p = int(self._parent[unit])
        if p >= 0:
            self._children[p] &= ~(1 << unit)
        self._parent[unit] = -1
        # clear any prealloc claims on this unit
        for i in range(self.n):
            self._prealloc[i] &= ~(1 << unit)
        self._free[unit] = True

    def disable(self, unit: int) -> None:
        """A unit becomes unavailable ('overheating' / failed host)."""
        self._disabled[unit] = True

    def enable(self, unit: int) -> None:
        self._disabled[unit] = False

    # -- invariants (property-tested) --------------------------------------
    def check_invariants(self) -> None:
        assert self._parent.shape == (self.n,)
        for u in range(self.n):
            p = int(self._parent[u])
            if p >= 0:
                assert not self._free[u], f"{u} has parent but is free"
                assert (self._children[p] >> u) & 1, \
                    f"{u}'s parent {p} does not list it"
        for p in range(self.n):
            for c in self.children_of(p):
                assert int(self._parent[c]) == p
        # pool conservation
        assert self.used + self.available + int(
            np.sum(self._disabled & self._free)) == self.n
