"""Fault-tolerant checkpointing: atomic, async, sharded, auto-resume.

EMPA mapping (§3.6): checkpointing runs on a dedicated "interrupt-service
core" — a background thread with a snapshot of the state — so the payload
step never stalls; no context change, no state save/restore on the
training path.  Durability discipline:

* writes go to ``step_N.tmp/`` and are fsync'd, then atomically renamed to
  ``step_N/`` — a crash mid-write can never corrupt the latest checkpoint;
* a msgpack manifest records the tree structure, shapes, dtypes and a
  config fingerprint, validated on restore;
* ``keep_n`` old checkpoints are garbage-collected only after the new one
  is durable;
* ``latest_step``/``restore`` make restart a one-liner — the launcher
  auto-resumes (tests inject a failure and prove bitwise-identical
  continuation).

Multi-host: each host writes its own ``host<k>`` shard file of its
addressable arrays; here (single-process) host 0 owns everything, but the
format and the manifest already carry the host dimension.
"""
from __future__ import annotations

import concurrent.futures
import os
import shutil
import threading
from typing import Any, Optional

import jax
import msgpack
import numpy as np


def _flatten(tree: Any, prefix: str = "") -> dict[str, np.ndarray]:
    flat = {}
    leaves, treedef = jax.tree_util.tree_flatten_with_path(tree)
    for path, leaf in leaves:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def _paths(tree: Any) -> list:
    leaves, treedef = jax.tree_util.tree_flatten_with_path(tree)
    return [p for p, _ in leaves], treedef


class CheckpointManager:
    def __init__(self, directory: str, *, keep_n: int = 3, host_id: int = 0,
                 async_save: bool = True, fingerprint: str = ""):
        self.dir = directory
        self.keep_n = keep_n
        self.host_id = host_id
        self.fingerprint = fingerprint
        os.makedirs(directory, exist_ok=True)
        self._pool = concurrent.futures.ThreadPoolExecutor(max_workers=1) \
            if async_save else None
        self._pending: Optional[concurrent.futures.Future] = None
        self._lock = threading.Lock()

    # -- paths ----------------------------------------------------------
    def _step_dir(self, step: int) -> str:
        return os.path.join(self.dir, f"step_{step:08d}")

    def steps(self) -> list[int]:
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and not name.endswith(".tmp") and \
                    os.path.isdir(os.path.join(self.dir, name)):
                try:
                    out.append(int(name.split("_")[1]))
                except ValueError:
                    pass
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        s = self.steps()
        return s[-1] if s else None

    # -- save -----------------------------------------------------------
    def save(self, step: int, state: Any, *, block: bool = False) -> None:
        # snapshot on the caller's thread (device->host copy), then hand
        # off to the service thread
        flat = _flatten(state)
        if self._pool is None or block:
            self._write(step, flat)
        else:
            self.wait()     # one in flight at a time
            self._pending = self._pool.submit(self._write, step, flat)

    def wait(self) -> None:
        if self._pending is not None:
            self._pending.result()
            self._pending = None

    def _write(self, step: int, flat: dict[str, np.ndarray]) -> None:
        final = self._step_dir(step)
        tmp = final + ".tmp"
        with self._lock:
            shutil.rmtree(tmp, ignore_errors=True)
            os.makedirs(tmp)
            np.savez(os.path.join(tmp, f"host{self.host_id}.npz"), **flat)
            manifest = {
                "step": step,
                "fingerprint": self.fingerprint,
                "host_id": self.host_id,
                "keys": {k: [list(v.shape), str(v.dtype)]
                         for k, v in flat.items()},
            }
            with open(os.path.join(tmp, "manifest.msgpack"), "wb") as f:
                f.write(msgpack.packb(manifest))
                f.flush()
                os.fsync(f.fileno())
            os.rename(tmp, final)          # atomic publish
            self._gc()

    def _gc(self) -> None:
        steps = self.steps()
        for s in steps[:-self.keep_n] if self.keep_n else []:
            shutil.rmtree(self._step_dir(s), ignore_errors=True)

    # -- restore ---------------------------------------------------------
    def restore(self, like: Any, step: Optional[int] = None) -> tuple[Any, int]:
        """Restore into the structure of `like`.  Returns (state, step)."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {self.dir}")
        d = self._step_dir(step)
        with open(os.path.join(d, "manifest.msgpack"), "rb") as f:
            manifest = msgpack.unpackb(f.read())
        if self.fingerprint and manifest["fingerprint"] and \
                manifest["fingerprint"] != self.fingerprint:
            raise ValueError(
                f"checkpoint fingerprint {manifest['fingerprint']!r} != "
                f"runtime {self.fingerprint!r} — refusing to restore")
        data = np.load(os.path.join(d, f"host{self.host_id}.npz"))
        paths, treedef = _paths(like)
        leaves = []
        like_leaves = jax.tree_util.tree_leaves(like)
        for path, ref in zip(paths, like_leaves):
            key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                           for p in path)
            if key not in data:
                raise KeyError(f"checkpoint missing leaf {key}")
            arr = data[key]
            want = tuple(getattr(ref, "shape", ()) or ())
            if tuple(arr.shape) != want:
                raise ValueError(f"{key}: shape {arr.shape} != {want}")
            dt = getattr(ref, "dtype", arr.dtype)
            leaves.append(arr.astype(dt))
        return jax.tree_util.tree_unflatten(treedef, leaves), step
