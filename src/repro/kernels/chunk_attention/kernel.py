"""Chunk attention — write-then-attend over an existing KV cache.

Two schedules for the same math, dispatched by fragment width (the
charm_u50 ``mm_large`` / ``mm_small`` pattern — one fabric
configuration per problem shape):

* **wide** — grid ``(batch, kv_heads, kv_blocks)``, one GQA group per
  tile, (C·group, kv_block) score panels.  Serves chunked prefill and
  monolithic resume replay, where the fragment is the scheduler chunk
  (8–64 tokens) and the MXU wants tall panels.
* **narrow** — grid ``(batch, kv_blocks)``, *all* heads in one tile as
  a (Hkv, C·group, kv_block) batched contraction.  Serves the
  speculative verify fragment ``(n_slots, k+1)``, where per-head tiles
  would be a few rows each and the grid overhead dominates.

Both clamp KV work to the attended span: the per-row fragment start
rides in as a **scalar-prefetch** operand and ``@pl.when(j·bs < pos0 +
width)`` skips every KV block past the last query position — the cache
tail beyond ``pos + fragment`` is never read, instead of being
gathered and masked to -inf like the old jnp path.  The paged twins
aim each KV DMA through the scalar-prefetched block table exactly like
``paged_attention``.

Fragment positions are assumed contiguous per row (``q_pos[b, c] ==
q_pos[b, 0] + c``), which is what ``prefill_chunk`` produces; the mask
is rebuilt in-register from the prefetched row start.  Online softmax
(running max / denominator / accumulator scratch in VMEM) keeps the
accumulation exact across the sequential last grid dimension.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


# ---------------------------------------------------------------- wide

def _wide_body(qpos_ref, q_ref, k_ref, v_ref, o_ref, acc, m, l, *,
               kv_block: int, width: int, group: int, sm_scale: float):
    b = pl.program_id(0)
    j = pl.program_id(2)
    nkb = pl.num_programs(2)

    @pl.when(j == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc)
        m[...] = jnp.full_like(m, NEG_INF)
        l[...] = jnp.zeros_like(l)

    pos0 = qpos_ref[b, 0]

    # the KV clamp: blocks past the last query position (j·bs >= pos0 +
    # width) are dead under the offset-causal mask — skip the DMA'd
    # tile's compute entirely instead of masking it to -inf
    @pl.when(j * kv_block < pos0 + width)
    def _compute():
        q = q_ref[0, :, 0].astype(jnp.float32)     # (C, group, D)
        c, g, d = q.shape
        q2 = q.reshape(c * g, d)
        k = k_ref[0, :, 0].astype(jnp.float32)     # (bs, D)
        v = v_ref[0, :, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q2, k,
                                (((1,), (1,)), ((), ()))) * sm_scale
        kpos = j * kv_block + jax.lax.broadcasted_iota(
            jnp.int32, (c * g, kv_block), 1)
        qp = pos0 + jax.lax.broadcasted_iota(
            jnp.int32, (c * g, kv_block), 0) // g
        s = jnp.where(kpos <= qp, s, NEG_INF)      # (C·group, bs)
        m_prev = m[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)
        l[...] = l[...] * alpha + jnp.sum(p, axis=-1, keepdims=True)
        acc[...] = acc[...] * alpha + jax.lax.dot(p, v)
        m[...] = m_new

    @pl.when(j == nkb - 1)
    def _readout():
        c = o_ref.shape[1]
        d = o_ref.shape[-1]
        out = acc[...] / jnp.maximum(l[...], 1e-30)
        o_ref[0, :, 0] = out.reshape(c, group, d).astype(o_ref.dtype)


def _paged_wide_body(tables_ref, qpos_ref, *rest, **kw):
    _wide_body(qpos_ref, *rest, **kw)


# -------------------------------------------------------------- narrow

def _narrow_body(qpos_ref, q_ref, k_ref, v_ref, o_ref, acc, m, l, *,
                 kv_block: int, width: int, group: int, sm_scale: float):
    b = pl.program_id(0)
    j = pl.program_id(1)
    nkb = pl.num_programs(1)

    @pl.when(j == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc)
        m[...] = jnp.full_like(m, NEG_INF)
        l[...] = jnp.zeros_like(l)

    pos0 = qpos_ref[b, 0]

    @pl.when(j * kv_block < pos0 + width)
    def _compute():
        q = q_ref[0].astype(jnp.float32)           # (Hkv, C·group, D)
        hkv, cg, d = q.shape
        k = k_ref[0].astype(jnp.float32)           # (bs, Hkv, D)
        v = v_ref[0].astype(jnp.float32)
        # batch over kv heads without transposing the KV tile: contract
        # D, batch Hkv (dim 0 of q, dim 1 of k)
        s = jax.lax.dot_general(
            q, k, (((2,), (2,)), ((0,), (1,))),
            preferred_element_type=jnp.float32) * sm_scale
        kpos = j * kv_block + jax.lax.broadcasted_iota(
            jnp.int32, (1, cg, kv_block), 2)
        qp = pos0 + jax.lax.broadcasted_iota(
            jnp.int32, (1, cg, kv_block), 1) // group
        s = jnp.where(kpos <= qp, s, NEG_INF)      # (Hkv, C·group, bs)
        m_prev = m[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)
        l[...] = l[...] * alpha + jnp.sum(p, axis=-1, keepdims=True)
        acc[...] = acc[...] * alpha + jax.lax.dot_general(
            p, v, (((2,), (0,)), ((0,), (1,))),
            preferred_element_type=jnp.float32)
        m[...] = m_new

    @pl.when(j == nkb - 1)
    def _readout():
        out = acc[...] / jnp.maximum(l[...], 1e-30)
        o_ref[0] = out.astype(o_ref.dtype)


def _paged_narrow_body(tables_ref, qpos_ref, *rest, **kw):
    _narrow_body(qpos_ref, *rest, **kw)


# ------------------------------------------------------------- helpers

def _kv_block(smax: int, cap: int = 128) -> int:
    """Largest power of two <= cap that divides the cache length."""
    bs = 1
    while bs < cap and smax % (bs * 2) == 0:
        bs *= 2
    return bs


def _narrow_layout(q, hkv: int):
    """(B, C, H, D) -> (B, Hkv, C·group, D): batch dim first so the
    kernel's contraction needs no in-tile transpose."""
    b, c, h, d = q.shape
    group = h // hkv
    return (q.reshape(b, c, hkv, group, d)
             .transpose(0, 2, 1, 3, 4)
             .reshape(b, hkv, c * group, d))


def _narrow_unlayout(o, c: int, group: int):
    b, hkv, cg, d = o.shape
    return (o.reshape(b, hkv, c, group, d)
             .transpose(0, 2, 1, 3, 4)
             .reshape(b, c, hkv * group, d))


# ------------------------------------------------------ contiguous API

def chunk_attention_wide_call(q, k_cache, v_cache, q_pos, *,
                              interpret: bool = True):
    """q: (B, C, H, D) at contiguous positions q_pos (B, C);
    k/v_cache: (B, Smax, Hkv, D).  -> (B, C, H, D)."""
    b, c, h, d = q.shape
    smax, hkv = k_cache.shape[1], k_cache.shape[2]
    assert h % hkv == 0
    group = h // hkv
    kvb = _kv_block(smax)
    nkb = smax // kvb
    sm_scale = 1.0 / (d ** 0.5)
    q_r = q.reshape(b, c, hkv, group, d)

    def q_map(ib, ih, j, qpos):
        return (ib, 0, ih, 0, 0)

    def kv_map(ib, ih, j, qpos):
        return (ib, j, ih, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(b, hkv, nkb),
        in_specs=[
            pl.BlockSpec((1, c, 1, group, d), q_map),
            pl.BlockSpec((1, kvb, 1, d), kv_map),
            pl.BlockSpec((1, kvb, 1, d), kv_map),
        ],
        out_specs=pl.BlockSpec((1, c, 1, group, d), q_map),
        scratch_shapes=[
            pltpu.VMEM((c * group, d), jnp.float32),   # acc
            pltpu.VMEM((c * group, 1), jnp.float32),   # running max
            pltpu.VMEM((c * group, 1), jnp.float32),   # denominator
        ],
    )
    out = pl.pallas_call(
        functools.partial(_wide_body, kv_block=kvb, width=c,
                          group=group, sm_scale=sm_scale),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, c, hkv, group, d), q.dtype),
        interpret=interpret,
    )(q_pos.astype(jnp.int32), q_r, k_cache, v_cache)
    return out.reshape(b, c, h, d)


def chunk_attention_narrow_call(q, k_cache, v_cache, q_pos, *,
                                interpret: bool = True):
    b, c, h, d = q.shape
    smax, hkv = k_cache.shape[1], k_cache.shape[2]
    assert h % hkv == 0
    group = h // hkv
    kvb = _kv_block(smax)
    nkb = smax // kvb
    sm_scale = 1.0 / (d ** 0.5)
    q_r = _narrow_layout(q, hkv)

    def q_map(ib, j, qpos):
        return (ib, 0, 0, 0)

    def kv_map(ib, j, qpos):
        return (ib, j, 0, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(b, nkb),
        in_specs=[
            pl.BlockSpec((1, hkv, c * group, d), q_map),
            pl.BlockSpec((1, kvb, hkv, d), kv_map),
            pl.BlockSpec((1, kvb, hkv, d), kv_map),
        ],
        out_specs=pl.BlockSpec((1, hkv, c * group, d), q_map),
        scratch_shapes=[
            pltpu.VMEM((hkv, c * group, d), jnp.float32),
            pltpu.VMEM((hkv, c * group, 1), jnp.float32),
            pltpu.VMEM((hkv, c * group, 1), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        functools.partial(_narrow_body, kv_block=kvb, width=c,
                          group=group, sm_scale=sm_scale),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, hkv, c * group, d), q.dtype),
        interpret=interpret,
    )(q_pos.astype(jnp.int32), q_r, k_cache, v_cache)
    return _narrow_unlayout(out, c, group)


# ----------------------------------------------------------- paged API

def paged_chunk_attention_wide_call(q, k_pages, v_pages, block_tables,
                                    q_pos, *, interpret: bool = True):
    """q: (B, C, H, D); k/v_pages: (P, bs, Hkv, D); block_tables:
    (B, NB) int32 (-1 = end of chain).  -> (B, C, H, D)."""
    b, c, h, d = q.shape
    n_pages, bs, hkv, _ = k_pages.shape
    assert h % hkv == 0
    group = h // hkv
    nb = block_tables.shape[1]
    sm_scale = 1.0 / (d ** 0.5)
    q_r = q.reshape(b, c, hkv, group, d)

    def q_map(ib, ih, j, tables, qpos):
        return (ib, 0, ih, 0, 0)

    def kv_map(ib, ih, j, tables, qpos):
        # address indirection: table entry -> physical block (blocks
        # past the clamp are skipped by the body, so the clamped-to-0
        # NO_BLOCK entries are never *used*, only harmlessly fetched)
        return (jnp.maximum(tables[ib, j], 0), 0, ih, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, hkv, nb),
        in_specs=[
            pl.BlockSpec((1, c, 1, group, d), q_map),
            pl.BlockSpec((1, bs, 1, d), kv_map),
            pl.BlockSpec((1, bs, 1, d), kv_map),
        ],
        out_specs=pl.BlockSpec((1, c, 1, group, d), q_map),
        scratch_shapes=[
            pltpu.VMEM((c * group, d), jnp.float32),
            pltpu.VMEM((c * group, 1), jnp.float32),
            pltpu.VMEM((c * group, 1), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        functools.partial(_paged_wide_body, kv_block=bs, width=c,
                          group=group, sm_scale=sm_scale),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, c, hkv, group, d), q.dtype),
        interpret=interpret,
    )(block_tables.astype(jnp.int32), q_pos.astype(jnp.int32),
      q_r, k_pages, v_pages)
    return out.reshape(b, c, h, d)


def paged_chunk_attention_narrow_call(q, k_pages, v_pages, block_tables,
                                      q_pos, *, interpret: bool = True):
    b, c, h, d = q.shape
    n_pages, bs, hkv, _ = k_pages.shape
    assert h % hkv == 0
    group = h // hkv
    nb = block_tables.shape[1]
    sm_scale = 1.0 / (d ** 0.5)
    q_r = _narrow_layout(q, hkv)

    def q_map(ib, j, tables, qpos):
        return (ib, 0, 0, 0)

    def kv_map(ib, j, tables, qpos):
        return (jnp.maximum(tables[ib, j], 0), 0, 0, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, nb),
        in_specs=[
            pl.BlockSpec((1, hkv, c * group, d), q_map),
            pl.BlockSpec((1, bs, hkv, d), kv_map),
            pl.BlockSpec((1, bs, hkv, d), kv_map),
        ],
        out_specs=pl.BlockSpec((1, hkv, c * group, d), q_map),
        scratch_shapes=[
            pltpu.VMEM((hkv, c * group, d), jnp.float32),
            pltpu.VMEM((hkv, c * group, 1), jnp.float32),
            pltpu.VMEM((hkv, c * group, 1), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        functools.partial(_paged_narrow_body, kv_block=bs, width=c,
                          group=group, sm_scale=sm_scale),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, hkv, c * group, d), q.dtype),
        interpret=interpret,
    )(block_tables.astype(jnp.int32), q_pos.astype(jnp.int32),
      q_r, k_pages, v_pages)
    return _narrow_unlayout(out, c, group)
