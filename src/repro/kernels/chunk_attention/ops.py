"""Public jit'd wrappers for chunk attention — shape dispatch lives here.

The caller (``models/attention.py``) hands every fragment to one entry
point per layout; the width of the fragment picks the schedule, the
charm_u50 way (``mm_large`` / ``mm_small`` chosen by the supervisor to
match the fabric configuration to the job):

* width <= ``NARROW_MAX_WIDTH``  ->  narrow kernel (all heads per
  tile; the speculative verify fragment ``(n_slots, k+1)`` and other
  skinny resumes)
* wider fragments                ->  wide kernel (one GQA group per
  tile; scheduler-chunk prefill)

Width is a static shape, so the dispatch is resolved at trace time —
each (width, layout) pair jits once and the tick graph contains only
the matching ``pallas_call``.
"""
from __future__ import annotations

import jax
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from repro.kernels.chunk_attention.kernel import (
    chunk_attention_narrow_call,
    chunk_attention_wide_call,
    paged_chunk_attention_narrow_call,
    paged_chunk_attention_wide_call,
)

# Fragments at or below this width take the narrow (all-heads) kernel.
# The speculative verify width is k+1 (k in 2..6 across the configs
# here); the scheduler chunk is 8+.
NARROW_MAX_WIDTH = 8


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


@jax.jit
def chunk_attention_kernel(q, k_cache, v_cache, q_pos):
    """Fragment attention against a contiguous cache.  q (B,C,H,D) at
    contiguous positions q_pos (B,C) vs (B,Smax,Hkv,D); KV reads are
    clamped to pos + fragment."""
    call = (chunk_attention_narrow_call
            if q.shape[1] <= NARROW_MAX_WIDTH else
            chunk_attention_wide_call)
    return call(q, k_cache, v_cache, q_pos, interpret=_interpret())


@jax.jit
def paged_chunk_attention_kernel(q, k_pages, v_pages, block_tables,
                                 q_pos):
    """Fragment attention through the block table.  q (B,C,H,D) vs
    (P,bs,Hkv,D) pages addressed by (B,NB) tables; KV blocks past
    pos + fragment are never touched."""
    call = (paged_chunk_attention_narrow_call
            if q.shape[1] <= NARROW_MAX_WIDTH else
            paged_chunk_attention_wide_call)
    return call(q, k_pages, v_pages, block_tables, q_pos,
                interpret=_interpret())


# -- head-sharded entries (tensor-parallel serving) --------------------------
#
# GSPMD cannot partition a ``pallas_call``: under a head-sharded mesh the
# jit'd wrappers above would force an all-gather of the KV cache onto
# every shard.  These entries instead run the SAME shape dispatch
# per-shard on the local head slice via ``shard_map`` — heads are
# embarrassingly parallel in attention (GQA groups never mix), so the
# width-picks-the-schedule contract is untouched: the fragment axis is
# unsharded and each shard sees the global width.  Callers guard on
# divisibility (``model`` must divide H and Hkv — the sharding-rules
# fallback) before routing here; these functions are not jit'd at this
# level because mesh/axis are part of the closure — the serving tick
# that traces them holds the jit.

def chunk_attention_kernel_sharded(q, k_cache, v_cache, q_pos, *,
                                   mesh: Mesh, axis: str = "model"):
    """:func:`chunk_attention_kernel` with q/K/V head-sharded over
    ``axis``; q_pos replicated.  Per-shard GQA ratio equals the global
    one, so narrow/wide tile shapes are valid on the slice."""
    hs = P(None, None, axis, None)
    f = shard_map(chunk_attention_kernel, mesh=mesh,
                  in_specs=(hs, hs, hs, P(None, None)), out_specs=hs,
                  check_rep=False)
    return f(q, k_cache, v_cache, q_pos)


def paged_chunk_attention_kernel_sharded(q, k_pages, v_pages, block_tables,
                                         q_pos, *, mesh: Mesh,
                                         axis: str = "model"):
    """:func:`paged_chunk_attention_kernel` with pages head-sharded over
    ``axis``; block tables and positions replicated — every shard walks
    the same chain, reads its own head slice of each block."""
    f = shard_map(paged_chunk_attention_kernel, mesh=mesh,
                  in_specs=(P(None, None, axis, None),
                            P(None, None, axis, None),
                            P(None, None, axis, None),
                            P(None, None), P(None, None)),
                  out_specs=P(None, None, axis, None),
                  check_rep=False)
    return f(q, k_pages, v_pages, block_tables, q_pos)
