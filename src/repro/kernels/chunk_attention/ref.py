"""Pure-jnp oracle for chunk attention: full-cache mask, no clamping.

Deliberately the *naive* schedule — materialize scores against every
cache row (contiguous) or gather the whole chain (paged), then apply the
position-offset causal mask.  The kernels and the dispatcher's clamped
jnp path are both checked against this; `full_attention` over the
logical prefix is the independent second oracle
(tests/kernels/test_chunk_attention.py).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def chunk_attention_ref(q, k_cache, v_cache, q_pos):
    """q: (B, C, H, D) at absolute positions q_pos (B, C); k/v_cache:
    (B, Smax, Hkv, D).  Returns (B, C, H, D)."""
    b, c, h, d = q.shape
    hkv = k_cache.shape[2]
    rep = h // hkv
    k = jnp.repeat(k_cache, rep, axis=2)
    v = jnp.repeat(v_cache, rep, axis=2)
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / jnp.sqrt(jnp.float32(d))
    kpos = jnp.arange(k.shape[1])
    s = jnp.where(kpos[None, None, None, :] <= q_pos[:, None, :, None],
                  s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", p,
                      v.astype(jnp.float32)).astype(q.dtype)


def paged_chunk_attention_ref(q, k_pages, v_pages, block_tables, q_pos):
    """q: (B, C, H, D); k/v_pages: (P, bs, Hkv, D); block_tables: (B, NB)
    int32 (-1 = end of chain); q_pos: (B, C).  Returns (B, C, H, D)."""
    n_pages, bs, hkv, d = k_pages.shape
    b, nb = block_tables.shape
    t = jnp.clip(block_tables, 0, n_pages - 1)
    k = k_pages[t].reshape(b, nb * bs, hkv, d)
    v = v_pages[t].reshape(b, nb * bs, hkv, d)
    return chunk_attention_ref(q, k, v, q_pos)
