from repro.kernels.chunk_attention.ops import (  # noqa: F401
    NARROW_MAX_WIDTH,
    chunk_attention_kernel,
    chunk_attention_kernel_sharded,
    paged_chunk_attention_kernel,
    paged_chunk_attention_kernel_sharded,
)
from repro.kernels.chunk_attention.kernel import (  # noqa: F401
    chunk_attention_narrow_call,
    chunk_attention_wide_call,
    paged_chunk_attention_narrow_call,
    paged_chunk_attention_wide_call,
)
from repro.kernels.chunk_attention.ref import (  # noqa: F401
    chunk_attention_ref,
    paged_chunk_attention_ref,
)
