from repro.kernels.massmap.ops import massmap  # noqa: F401
from repro.kernels.massmap.ref import massmap_ref  # noqa: F401
