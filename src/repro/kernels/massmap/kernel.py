"""FOR mass-processing mode as a TPU kernel.

Paper §5.1: the loop's control instructions (counter advance, address
generation, branch) are "obsolete" — the supervisor runs them.  TPU
adaptation: the Pallas grid + BlockSpec index maps ARE the supervisor —
they own iteration and addressing; the kernel body executes only payload
(here a fused scale-bias-activation, the payload of a norm-affine + act
epilogue).  One HBM read + one write per element; zero control overhead in
the instruction stream.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


_ACTS = {
    "silu": lambda x: x * jax.nn.sigmoid(x),
    "gelu": jax.nn.gelu,
    "relu": lambda x: jnp.maximum(x, 0.0),
    "none": lambda x: x,
}


def _massmap_kernel(x_ref, scale_ref, bias_ref, o_ref, *, act: str):
    # payload only: y = act(x * scale + bias)
    x = x_ref[...].astype(jnp.float32)
    y = _ACTS[act](x * scale_ref[...].astype(jnp.float32)
                   + bias_ref[...].astype(jnp.float32))
    o_ref[...] = y.astype(o_ref.dtype)


def massmap_call(x, scale, bias, *, act: str = "silu",
                 block_m: int = 256, block_n: int = 512,
                 interpret: bool = True):
    """x: (M, N); scale/bias: (N,) broadcast per column.  Returns (M, N)."""
    m, n = x.shape
    block_m = min(block_m, m)
    block_n = min(block_n, n)
    assert m % block_m == 0 and n % block_n == 0, (m, n, block_m, block_n)
    kern = functools.partial(_massmap_kernel, act=act)
    return pl.pallas_call(
        kern,
        grid=(m // block_m, n // block_n),   # the SV owns the loop nest
        in_specs=[
            pl.BlockSpec((block_m, block_n), lambda i, j: (i, j)),
            pl.BlockSpec((1, block_n), lambda i, j: (0, j)),
            pl.BlockSpec((1, block_n), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        interpret=interpret,
    )(x, scale[None], bias[None])
