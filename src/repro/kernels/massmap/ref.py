"""Pure-jnp oracle for the massmap kernel."""
import jax
import jax.numpy as jnp

_ACTS = {
    "silu": lambda x: x * jax.nn.sigmoid(x),
    "gelu": jax.nn.gelu,
    "relu": lambda x: jnp.maximum(x, 0.0),
    "none": lambda x: x,
}


def massmap_ref(x, scale, bias, act: str = "silu"):
    y = _ACTS[act](x.astype(jnp.float32) * scale.astype(jnp.float32)
                   + bias.astype(jnp.float32))
    return y.astype(x.dtype)
