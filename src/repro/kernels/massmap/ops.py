"""Public jit'd wrapper for the massmap kernel."""
from __future__ import annotations

import functools

import jax

from repro.kernels.massmap.kernel import massmap_call


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("act", "block_m", "block_n"))
def massmap(x, scale, bias, act: str = "silu", block_m: int = 256,
            block_n: int = 512):
    """Fused scale-bias-activation: act(x * scale + bias), columnwise."""
    return massmap_call(x, scale, bias, act=act, block_m=block_m,
                        block_n=block_n, interpret=_interpret())
