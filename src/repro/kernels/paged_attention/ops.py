"""Public jit'd wrapper for paged decode attention."""
from __future__ import annotations

import jax
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from repro.kernels.paged_attention.kernel import paged_attention_call


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


@jax.jit
def paged_attention(q, k_pages, v_pages, block_tables, lengths):
    """Block-table decode attention.  q (B,H,D) against (P,bs,Hkv,D)
    pages addressed by (B,NB) tables, masked by (B,) lengths."""
    return paged_attention_call(q, k_pages, v_pages, block_tables, lengths,
                                interpret=_interpret())


def paged_attention_sharded(q, k_pages, v_pages, block_tables, lengths, *,
                            mesh: Mesh, axis: str = "model"):
    """:func:`paged_attention` under a head-sharded mesh: GSPMD cannot
    partition a ``pallas_call``, so each ``axis`` shard runs the kernel
    on its local head slice via ``shard_map`` (heads never mix in
    attention — no collective).  Block tables and lengths are
    replicated: every shard walks the same chain, reads its own head
    slice of each block.  Callers guard divisibility (``axis`` must
    divide H and Hkv) before routing here."""
    f = shard_map(paged_attention, mesh=mesh,
                  in_specs=(P(None, axis, None),
                            P(None, None, axis, None),
                            P(None, None, axis, None),
                            P(None, None), P(None)),
                  out_specs=P(None, axis, None),
                  check_rep=False)
    return f(q, k_pages, v_pages, block_tables, lengths)
