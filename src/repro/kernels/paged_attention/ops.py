"""Public jit'd wrapper for paged decode attention."""
from __future__ import annotations

import jax

from repro.kernels.paged_attention.kernel import paged_attention_call


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


@jax.jit
def paged_attention(q, k_pages, v_pages, block_tables, lengths):
    """Block-table decode attention.  q (B,H,D) against (P,bs,Hkv,D)
    pages addressed by (B,NB) tables, masked by (B,) lengths."""
    return paged_attention_call(q, k_pages, v_pages, block_tables, lengths,
                                interpret=_interpret())
