from repro.kernels.paged_attention.ops import (  # noqa: F401
    paged_attention,
    paged_attention_sharded,
)
from repro.kernels.paged_attention.ref import paged_attention_ref  # noqa: F401
