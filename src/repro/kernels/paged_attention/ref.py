"""Pure-jnp oracle: materialize the block-table gather, then softmax."""
import jax
import jax.numpy as jnp


def paged_attention_ref(q, k_pages, v_pages, block_tables, lengths):
    """q: (B, H, D); k/v_pages: (P, bs, Hkv, D); block_tables: (B, NB);
    lengths: (B,).  Returns (B, H, D)."""
    b, h, d = q.shape
    n_pages, bs, hkv, _ = k_pages.shape
    nb = block_tables.shape[1]
    t = jnp.clip(block_tables, 0, n_pages - 1)
    k = k_pages[t].reshape(b, nb * bs, hkv, d)
    v = v_pages[t].reshape(b, nb * bs, hkv, d)
    rep = h // hkv
    k = jnp.repeat(k, rep, axis=2)
    v = jnp.repeat(v, rep, axis=2)
    s = jnp.einsum("bhd,bkhd->bhk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / jnp.sqrt(jnp.float32(d))
    pos = jnp.arange(nb * bs)
    s = jnp.where(pos[None, None, :] < lengths[:, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhk,bkhd->bhd", p,
                      v.astype(jnp.float32)).astype(q.dtype)
