"""Paged decode attention — gather K/V through the block table in VMEM.

The serving cache stores K/V in fixed-size blocks rented from the block
pool (runtime/paging.py); a slot's sequence is a *chain* of blocks named
by its block-table row.  This kernel is the SUMUP-mode schedule of
``flash_attention`` applied to that layout: the (1 × Skv) score row is
the §5.2 partial sum — children (KV blocks) stream their scores into the
parent's running (max m, denominator l, accumulator acc) scratch, and
HBM never sees a gathered contiguous copy of the sequence.

The block table and per-slot lengths ride in as **scalar-prefetch**
operands (``pltpu.PrefetchScalarGridSpec``): the BlockSpec index map
reads ``tables[b, j]`` to aim each KV DMA at the right physical block —
the address indirection is resolved by the supervisor-owned table, not
by materializing the gather.

Grid: (batch, kv_heads, blocks); the block dimension iterates
sequentially on TPU, which makes the scratch carry legal.  All q heads
of one GQA group are processed together (block shape (1, 1, group, D)).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _paged_kernel(tables_ref, lens_ref, q_ref, k_ref, v_ref, o_ref,
                  acc, m, l, *, block_size: int, sm_scale: float):
    b = pl.program_id(0)
    j = pl.program_id(2)
    nb = pl.num_programs(2)

    @pl.when(j == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc)
        m[...] = jnp.full_like(m, NEG_INF)
        l[...] = jnp.zeros_like(l)

    length = lens_ref[b]

    # blocks past the chain (j·bs >= length) contribute nothing: skip the
    # compute entirely — their table entries are NO_BLOCK (clamped to 0 by
    # the index map) and their data is whatever the pool left there
    @pl.when(j * block_size < length)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)        # (group, D)
        k = k_ref[0, :, 0].astype(jnp.float32)     # (bs, D)
        v = v_ref[0, :, 0].astype(jnp.float32)     # (bs, D)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * sm_scale
        pos = j * block_size + jax.lax.broadcasted_iota(
            jnp.int32, (1, block_size), 1)
        s = jnp.where(pos < length, s, NEG_INF)    # (group, bs)
        m_prev = m[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)
        l[...] = l[...] * alpha + jnp.sum(p, axis=-1, keepdims=True)
        acc[...] = acc[...] * alpha + jax.lax.dot(p, v)
        m[...] = m_new

    @pl.when(j == nb - 1)
    def _readout():
        o_ref[0, 0] = (acc[...] /
                       jnp.maximum(l[...], 1e-30)).astype(o_ref.dtype)


def paged_attention_call(q, k_pages, v_pages, block_tables, lengths, *,
                         interpret: bool = True):
    """q: (B, H, D); k/v_pages: (P, bs, Hkv, D); block_tables: (B, NB)
    int32 (-1 = end of chain); lengths: (B,) valid tokens.  -> (B, H, D).
    """
    b, h, d = q.shape
    n_pages, block_size, hkv, _ = k_pages.shape
    assert h % hkv == 0
    group = h // hkv
    nb = block_tables.shape[1]
    sm_scale = 1.0 / (d ** 0.5)
    q_r = q.reshape(b, hkv, group, d)

    def q_map(ib, ih, j, tables, lens):
        return (ib, ih, 0, 0)

    def kv_map(ib, ih, j, tables, lens):
        # the address indirection: table entry -> physical block
        return (jnp.maximum(tables[ib, j], 0), 0, ih, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, hkv, nb),
        in_specs=[
            pl.BlockSpec((1, 1, group, d), q_map),
            pl.BlockSpec((1, block_size, 1, d), kv_map),
            pl.BlockSpec((1, block_size, 1, d), kv_map),
        ],
        out_specs=pl.BlockSpec((1, 1, group, d), q_map),
        scratch_shapes=[
            pltpu.VMEM((group, d), jnp.float32),   # acc
            pltpu.VMEM((group, 1), jnp.float32),   # running max
            pltpu.VMEM((group, 1), jnp.float32),   # denominator
        ],
    )
    out = pl.pallas_call(
        functools.partial(_paged_kernel, block_size=block_size,
                          sm_scale=sm_scale),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, hkv, group, d), q.dtype),
        interpret=interpret,
    )(block_tables.astype(jnp.int32), lengths.astype(jnp.int32),
      q_r, k_pages, v_pages)
    return out.reshape(b, h, d)
