"""Mamba2 SSD chunk scan — parent-child QT chain as a TPU kernel.

Each sequence chunk is a child QT: it computes its intra-chunk
(quadratic, MXU-friendly) contribution locally.  The (P × N) SSM state is
the parent's latched register: carried in VMEM scratch across the
sequential chunk grid dimension, updated once per chunk (the clone-back),
never written to HBM until the final read-out.  This is the §5.2 insight
— eliminate the obsolete state write-back between iterations — applied to
the SSD recurrence.

Grid: (batch, heads, n_chunks); last dim sequential.  ops.py does the
cheap elementwise prep (dt softplus, cumsum, head broadcast) in jnp and
calls this kernel for the O(S·Q·(N+P)) heavy part.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(xdt_ref, cum_ref, b_ref, c_ref, y_ref, state_out_ref, state):
    ic = pl.program_id(2)

    @pl.when(ic == 0)
    def _init():
        state[...] = jnp.zeros_like(state)      # fresh parent latch

    xdt = xdt_ref[0, 0, 0].astype(jnp.float32)   # (Q, P)
    cum = cum_ref[0, 0, 0].astype(jnp.float32)   # (Q, 1) within-chunk cumsum
    bmat = b_ref[0, 0, 0].astype(jnp.float32)    # (Q, N)
    cmat = c_ref[0, 0, 0].astype(jnp.float32)    # (Q, N)

    # --- child's local work: intra-chunk (semiseparable) product ---
    seg = cum - cum.T                           # (Q, Q) cum_q - cum_t
    q = cum.shape[0]
    tri = jax.lax.broadcasted_iota(jnp.int32, (q, q), 0) >= \
        jax.lax.broadcasted_iota(jnp.int32, (q, q), 1)
    l_mat = jnp.where(tri, jnp.exp(seg), 0.0)
    cb = jax.lax.dot_general(cmat, bmat, (((1,), (1,)), ((), ())))  # (Q, Q)
    y = jax.lax.dot(cb * l_mat, xdt)            # (Q, P)

    # --- parent contribution: state from previous chunks ---
    y += jnp.exp(cum) * jax.lax.dot(cmat, state[...])          # (Q,N)@(N,P)

    # --- clone-back: update the latched state for the next child ---
    cum_last = cum[-1:, :]                       # (1, 1)
    decay_to_end = jnp.exp(cum_last - cum)       # (Q, 1)
    state[...] = jnp.exp(cum_last) * state[...] + \
        jax.lax.dot_general(bmat * decay_to_end, xdt,
                            (((0,), (0,)), ((), ())))          # (N, P)

    y_ref[0, 0, 0] = y.astype(y_ref.dtype)

    @pl.when(ic == pl.num_programs(2) - 1)
    def _readout():
        state_out_ref[0, 0] = state[...].astype(state_out_ref.dtype)


def ssd_scan_call(xdt, cum, b_mat, c_mat, *, interpret: bool = True):
    """Chunked SSD core.

    xdt:   (B, H, NC, Q, P)  x · dt, f32
    cum:   (B, H, NC, Q, 1)  within-chunk cumsum of dt·A
    b_mat: (B, H, NC, Q, N)
    c_mat: (B, H, NC, Q, N)
    Returns (y (B, H, NC, Q, P), final_state (B, H, N, P)).
    """
    bsz, h, nc, q, p = xdt.shape
    n = b_mat.shape[-1]
    grid = (bsz, h, nc)
    kern = _ssd_kernel
    y, state = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, 1, q, p), lambda ib, ih, ic: (ib, ih, ic, 0, 0)),
            pl.BlockSpec((1, 1, 1, q, 1), lambda ib, ih, ic: (ib, ih, ic, 0, 0)),
            pl.BlockSpec((1, 1, 1, q, n), lambda ib, ih, ic: (ib, ih, ic, 0, 0)),
            pl.BlockSpec((1, 1, 1, q, n), lambda ib, ih, ic: (ib, ih, ic, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, 1, q, p), lambda ib, ih, ic: (ib, ih, ic, 0, 0)),
            pl.BlockSpec((1, 1, n, p), lambda ib, ih, ic: (ib, ih, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bsz, h, nc, q, p), xdt.dtype),
            jax.ShapeDtypeStruct((bsz, h, n, p), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((n, p), jnp.float32)],
        interpret=interpret,
    )(xdt, cum, b_mat, c_mat)
    return y, state
