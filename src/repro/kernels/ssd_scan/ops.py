"""Public wrapper: full Mamba2-SSD signature around the chunk-scan kernel.

Accepts the same arguments as models/ssm.ssd_chunked and returns the same
(y, final_state) pair, so the kernel can swap in for the jnp path.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.ssd_scan.kernel import ssd_scan_call


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def _to_heads(bc, nheads: int):
    b, s, g, n = bc.shape
    rep = nheads // g
    return jnp.broadcast_to(bc[:, :, :, None, :], (b, s, g, rep, n)) \
              .reshape(b, s, nheads, n)


@functools.partial(jax.jit, static_argnames=("chunk",))
def ssd_chunked_kernel(x, dt, a_log, b_mat, c_mat, d_skip, dt_bias,
                       chunk: int = 64, init_state=None):
    """Kernel-backed drop-in for models/ssm.ssd_chunked (init_state=None)."""
    assert init_state is None, "kernel path starts from a fresh state"
    bsz, s, h, p = x.shape
    n = b_mat.shape[-1]
    chunk = min(chunk, s)
    assert s % chunk == 0
    nc = s // chunk
    f32 = jnp.float32

    dt = jax.nn.softplus(dt.astype(f32) + dt_bias.astype(f32))       # (B,S,H)
    a = -jnp.exp(a_log.astype(f32))
    da = dt * a
    xdt = x.astype(f32) * dt[..., None]                               # (B,S,H,P)
    bh = _to_heads(b_mat, h).astype(f32)
    ch = _to_heads(c_mat, h).astype(f32)

    def chunked(t, feat):                       # (B,S,H,F) -> (B,H,NC,Q,F)
        return t.transpose(0, 2, 1, 3).reshape(bsz, h, nc, chunk, feat)

    cum = jnp.cumsum(da.reshape(bsz, nc, chunk, h), axis=2) \
             .reshape(bsz, s, h)
    y, state = ssd_scan_call(
        chunked(xdt, p),
        chunked(cum[..., None].reshape(bsz, s, h, 1), 1),
        chunked(bh, n), chunked(ch, n),
        interpret=_interpret())

    y = y.reshape(bsz, h, s, p).transpose(0, 2, 1, 3)                # (B,S,H,P)
    y = y + x.astype(f32) * d_skip.astype(f32)[None, None, :, None]
    return y.astype(x.dtype), state.transpose(0, 1, 3, 2)            # (B,H,P,N)
