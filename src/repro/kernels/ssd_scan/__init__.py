from repro.kernels.ssd_scan.ops import ssd_chunked_kernel  # noqa: F401
from repro.kernels.ssd_scan.ref import ssd_scan_ref  # noqa: F401
