"""Pure-jnp oracle for the SSD chunk-scan kernel (kernel-layout inputs)."""
import jax
import jax.numpy as jnp


def ssd_scan_ref(xdt, cum, b_mat, c_mat):
    """Same contract as kernel.ssd_scan_call, sequential-scan reference."""
    bsz, h, nc, q, p = xdt.shape
    n = b_mat.shape[-1]
    f32 = jnp.float32
    xdt, cum = xdt.astype(f32), cum.astype(f32)
    b_mat, c_mat = b_mat.astype(f32), c_mat.astype(f32)

    tri = jnp.tril(jnp.ones((q, q), f32))

    def chunk(state, inp):
        xd, cm, bm, cmt = inp                     # (Q,P),(Q,1),(Q,N),(Q,N)
        seg = cm - cm.T
        l_mat = jnp.where(tri > 0, jnp.exp(seg), 0.0)
        y = ((cmt @ bm.T) * l_mat) @ xd
        y = y + jnp.exp(cm) * (cmt @ state)
        state = jnp.exp(cm[-1:]) * state + (bm * jnp.exp(cm[-1:] - cm)).T @ xd
        return state, y

    def per_bh(args):
        xd, cm, bm, cmt = args
        state0 = jnp.zeros((n, p), f32)
        state, ys = jax.lax.scan(chunk, state0, (xd, cm, bm, cmt))
        return ys, state

    flat = (xdt.reshape(bsz * h, nc, q, p), cum.reshape(bsz * h, nc, q, 1),
            b_mat.reshape(bsz * h, nc, q, n), c_mat.reshape(bsz * h, nc, q, n))
    ys, states = jax.vmap(per_bh)((flat))
    return (ys.reshape(bsz, h, nc, q, p).astype(xdt.dtype),
            states.reshape(bsz, h, n, p))
