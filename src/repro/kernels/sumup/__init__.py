from repro.kernels.sumup.ops import sumup  # noqa: F401
from repro.kernels.sumup.ref import sumup_ref  # noqa: F401
