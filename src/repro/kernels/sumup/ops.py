"""Public jit'd wrapper for the sumup kernel."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.sumup.kernel import sumup_call


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("block", "op"))
def sumup(x, block: int = 2048, op: str = "sum"):
    """Streaming reduction over the last axis of (rows, N) -> (rows, 1)."""
    if x.ndim == 1:
        x = x[None]
    return sumup_call(x, block=block, op=op, interpret=_interpret())
