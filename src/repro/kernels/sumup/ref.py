"""Pure-jnp oracle for the sumup kernel."""
import jax.numpy as jnp


def sumup_ref(x, op: str = "sum"):
    x = x.astype(jnp.float32)
    if op == "max":
        return jnp.max(x, axis=-1, keepdims=True)
    return jnp.sum(x, axis=-1, keepdims=True)
