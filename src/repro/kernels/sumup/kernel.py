"""SUMUP mass-processing mode as a TPU kernel.

Paper §5.2: the partial sum "is never used, we are only interested in the
final sum" — so the read-out/write-back stages of the accumulator are
obsolete.  TPU adaptation: the running sum lives in a VMEM scratch
accumulator across sequential grid steps; only the final value is written
to HBM.  The Pallas grid machinery is the supervisor: it streams one
`block`-wide stripe per step (the staggered children), the f32 accumulator
is the parent-side adder.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _sumup_kernel(x_ref, o_ref, acc, *, op: str):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        if op == "max":
            acc[...] = jnp.full_like(acc, -jnp.inf)
        else:
            acc[...] = jnp.zeros_like(acc)

    x = x_ref[...].astype(jnp.float32)
    part = jnp.max(x, axis=-1, keepdims=True) if op == "max" \
        else jnp.sum(x, axis=-1, keepdims=True)
    if op == "max":
        acc[...] = jnp.maximum(acc[...], part)
    else:
        acc[...] += part                      # parent adder, stays in VMEM

    @pl.when(i == pl.num_programs(0) - 1)
    def _readout():                           # the single read-out clock
        o_ref[...] = acc[...]


def sumup_call(x, *, block: int = 2048, op: str = "sum",
               interpret: bool = True):
    """x: (rows, N) -> (rows, 1) f32 reduction along the last axis."""
    rows, n = x.shape
    block = min(block, n)
    assert n % block == 0, (n, block)
    kern = functools.partial(_sumup_kernel, op=op)
    return pl.pallas_call(
        kern,
        grid=(n // block,),
        in_specs=[pl.BlockSpec((rows, block), lambda i: (0, i))],
        out_specs=pl.BlockSpec((rows, 1), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, 1), jnp.float32),
        scratch_shapes=[pltpu.VMEM((rows, 1), jnp.float32)],
        interpret=interpret,
    )(x)
