# EMPA-adapted TPU kernels (Pallas).  Each subpackage:
#   kernel.py — pl.pallas_call + explicit BlockSpec VMEM tiling
#   ops.py    — jit'd public wrapper (interpret=True off-TPU)
#   ref.py    — pure-jnp oracle used by the allclose tests
#
#   sumup           — SUMUP mass mode: streaming reduction, partials never
#                     leave VMEM (no read/write-back of the running sum)
#   massmap         — FOR mass mode: the grid owns loop control/addressing,
#                     the body is pure payload
#   flash_attention — SUMUP applied to softmax: online (m, l, acc) stream
#   ssd_scan        — Mamba2 SSD: chunk children + sequential-grid parent
#                     state carry (the latched parent-child chain)
#   paged_attention — SUMUP decode attention over the paged KV cache:
#                     scalar-prefetched block tables aim each KV DMA at
#                     the supervisor-rented physical block
#   chunk_attention — span-clamped fragment attention for the serving
#                     tick (contiguous and paged variants)

# Oracle/test pairing manifest: every kernel package must name the
# interpret-mode test file (under tests/kernels/) that asserts it
# allclose against its ref.py.  `python -m repro.analysis.lint`
# cross-checks this map against the package tree — an unlisted package,
# a missing ref.py, or a dead test path fails CI.
KERNEL_TESTS = {
    "sumup": "test_kernels.py",
    "massmap": "test_kernels.py",
    "flash_attention": "test_kernels.py",
    "ssd_scan": "test_kernels.py",
    "paged_attention": "test_paged_attention.py",
    "chunk_attention": "test_chunk_attention.py",
}
