"""Pure-jnp oracle: materialized-scores attention in (B, H, S, D) layout."""
import jax
import jax.numpy as jnp


def flash_attention_ref(q, k, v, causal: bool = True):
    b, h, sq, d = q.shape
    hkv, skv = k.shape[1], k.shape[2]
    rep = h // hkv
    k = jnp.repeat(k, rep, axis=1)
    v = jnp.repeat(v, rep, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / jnp.sqrt(jnp.float32(d))
    if causal:
        mask = jnp.arange(skv)[None, :] <= jnp.arange(sq)[:, None]
        s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p,
                      v.astype(jnp.float32)).astype(q.dtype)
