"""Flash attention (causal GQA) — SUMUP mode applied to softmax.

The (Sq × Skv) score matrix is the "partial sum" of §5.2: it is never
needed as a whole, only the normalized PV product is.  So the running
(max m, denominator l, accumulator acc) live in VMEM scratch across the
sequential KV grid dimension — children (KV tiles) stream their scores
into the parent's combining unit, and HBM sees only the final output.

Grid: (batch, q_heads, q_blocks, kv_blocks); the last dimension iterates
sequentially on TPU, which is what makes the scratch carry legal.
BlockSpec index maps give GQA for free: the KV block index maps head h to
kv-head h // group.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc, m, l, *,
                  sm_scale: float, causal: bool, block_q: int, block_k: int):
    iq = pl.program_id(2)
    ik = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ik == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc)
        m[...] = jnp.full_like(m, NEG_INF)
        l[...] = jnp.zeros_like(l)

    q = q_ref[0, 0].astype(jnp.float32)            # (bq, D)
    k = k_ref[0, 0].astype(jnp.float32)            # (bk, D)
    v = v_ref[0, 0].astype(jnp.float32)            # (bk, D)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * sm_scale
    if causal:
        qpos = iq * block_q + jax.lax.broadcasted_iota(jnp.int32,
                                                       (block_q, block_k), 0)
        kpos = ik * block_k + jax.lax.broadcasted_iota(jnp.int32,
                                                       (block_q, block_k), 1)
        s = jnp.where(kpos <= qpos, s, NEG_INF)

    m_prev = m[...]                                 # (bq, 1)
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    alpha = jnp.exp(m_prev - m_new)                 # renormalize the parent
    p = jnp.exp(s - m_new)                          # (bq, bk)
    l[...] = l[...] * alpha + jnp.sum(p, axis=-1, keepdims=True)
    acc[...] = acc[...] * alpha + jax.lax.dot(p, v)
    m[...] = m_new

    @pl.when(ik == nk - 1)
    def _readout():
        o_ref[0, 0] = (acc[...] /
                       jnp.maximum(l[...], 1e-30)).astype(o_ref.dtype)


def flash_attention_call(q, k, v, *, causal: bool = True,
                         block_q: int = 128, block_k: int = 128,
                         interpret: bool = True):
    """q: (B, H, Sq, D); k, v: (B, Hkv, Skv, D) -> (B, H, Sq, D)."""
    b, h, sq, d = q.shape
    hkv, skv = k.shape[1], k.shape[2]
    assert h % hkv == 0
    group = h // hkv
    block_q = min(block_q, sq)
    block_k = min(block_k, skv)
    assert sq % block_q == 0 and skv % block_k == 0
    sm_scale = 1.0 / (d ** 0.5)

    kern = functools.partial(_flash_kernel, sm_scale=sm_scale, causal=causal,
                             block_q=block_q, block_k=block_k)
    grid = (b, h, sq // block_q, skv // block_k)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, d),
                         lambda ib, ih, iq, ik: (ib, ih, iq, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda ib, ih, iq, ik: (ib, ih // group, ik, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda ib, ih, iq, ik: (ib, ih // group, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, d),
                               lambda ib, ih, iq, ik: (ib, ih, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, d), jnp.float32),   # acc
            pltpu.VMEM((block_q, 1), jnp.float32),   # running max
            pltpu.VMEM((block_q, 1), jnp.float32),   # denominator
        ],
        interpret=interpret,
    )(q, k, v)
