"""Public jit'd wrapper for flash attention."""
from __future__ import annotations

import functools

import jax

from repro.kernels.flash_attention.kernel import flash_attention_call


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("causal", "block_q", "block_k"))
def flash_attention(q, k, v, causal: bool = True, block_q: int = 128,
                    block_k: int = 128):
    """Causal GQA flash attention.  (B,H,Sq,D) × (B,Hkv,Skv,D) layout."""
    return flash_attention_call(q, k, v, causal=causal, block_q=block_q,
                                block_k=block_k, interpret=_interpret())
