"""Logical-axis sharding rules — the framework's 'metainstructions'.

EMPA prepares parallelization information at compile time and lets the
supervisor bind it to physical cores at run time (§3.3: compile-time QT
addresses -> runtime core numbers).  Here: model code annotates tensors
with *logical* axis names; :class:`ShardingRules` binds them to *physical*
mesh axes at trace time, with **divisibility fallback** — each logical axis
lists candidate mesh axes in preference order and the first one that
divides the dimension (and is not already taken by another dimension of
the same tensor) wins; otherwise the dimension is replicated.  All
non-divisible cases (starcoder2's 36/24 heads, whisper's 12, odd vocabs)
degrade gracefully and are *reported*, not crashed on.
"""
from __future__ import annotations

import contextlib
import dataclasses
import threading
from typing import Optional, Sequence, Union

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

AxisCand = Union[str, tuple[str, ...]]  # one candidate: mesh axis or product


# Default rule table.  Keys are logical axis names used by the models;
# values are candidate mesh axes in preference order.
DEFAULT_RULES: dict[str, tuple[AxisCand, ...]] = {
    # -- activations --
    "batch": (("pod", "data"), "data", "pod"),
    "seq": ("data",),                      # sequence parallelism (long ctx)
    "heads_act": ("model",),
    # sequence parallelism INSIDE attention: when the head count doesn't
    # divide the model axis (starcoder2 36/24H, whisper 12H), the online-
    # softmax carry shards over Sq instead — otherwise it bounces between
    # replicated and sharded every KV chunk (§Perf, starcoder2 prefill)
    "attn_sq": ("model",),
    "vocab_act": ("model",),
    "experts_act": ("model",),
    # Megatron-style sequence-parallel residual stream: between TP blocks
    # the residual is S-sharded over "model", so GSPMD lowers the TP
    # combine as reduce-scatter (+ all-gather at the next block input)
    # instead of a full all-reduce — half the wire bytes, and norms run on
    # 1/16th of the tokens (§Perf, granite-8b E3)
    "res_seq": ("model",),
    # -- weights --
    "w_embed": ("data",),                  # FSDP storage shard
    "ffn": ("model",),
    "heads": ("model",),
    "kv_heads": ("model",),
    "qkv": ("model",),
    "vocab": ("model",),
    "experts": ("model",),
    "ssm_heads": ("model",),
    "ssm_state": ("model",),
    "conv_dim": ("model",),
    # -- caches / states --
    # batch must span the SAME axes as activations (("pod","data")) or the
    # prefill cache scatter forces involuntary replication on multi-pod
    "cache_batch": (("pod", "data"), "data", "pod"),
    "cache_kv_heads": ("model",),
    # fallback TP for archs whose kv_heads don't divide the model axis
    # (whisper 12, qwen3 4, starcoder2 4/2): shard head_dim instead — the
    # QK/PV contractions then psum over "model", which GSPMD handles.
    "cache_head_dim": ("model",),
    "cache_seq": ("data",),
    "layers": (),                          # scanned; never sharded
}


# Cross-dimension assignment priority (lower = assigned first).  With
# purely positional assignment a fallback axis early in the shape would
# steal the mesh axis from the preferred one later in the shape.
_PRIORITY = {
    "heads_act": 10, "vocab_act": 10, "experts_act": 10,
    "cache_kv_heads": 10, "ssm_heads": 10,
    "batch": 20, "cache_batch": 20,
    "attn_sq": 30, "cache_head_dim": 30, "ssm_state": 30,
}


@dataclasses.dataclass
class ShardingRules:
    mesh: Mesh
    rules: dict[str, tuple[AxisCand, ...]] = dataclasses.field(
        default_factory=lambda: dict(DEFAULT_RULES))
    # log of fallback decisions: (axes, shape, spec)
    decisions: list = dataclasses.field(default_factory=list)

    def _axis_size(self, cand: AxisCand) -> int:
        if isinstance(cand, tuple):
            out = 1
            for a in cand:
                out *= self.mesh.shape[a]
            return out
        return self.mesh.shape[cand]

    def _cand_axes(self, cand: AxisCand) -> tuple[str, ...]:
        return cand if isinstance(cand, tuple) else (cand,)

    def spec(self, axes: Sequence[Optional[str]],
             shape: Optional[Sequence[int]] = None) -> P:
        """PartitionSpec for logical `axes` (len == rank).

        With `shape` given, candidates that do not divide the dimension are
        skipped (divisibility fallback).  Mesh axes are never used twice in
        one spec.
        """
        used: set[str] = set()
        entries: list = [None] * len(axes)
        order = sorted(range(len(axes)),
                       key=lambda i: (_PRIORITY.get(axes[i], 25), i))
        for i in order:
            name = axes[i]
            if name is None:
                continue
            for cand in self.rules.get(name, ()):
                cax = self._cand_axes(cand)
                if any(a not in self.mesh.shape for a in cax):
                    continue
                if any(a in used for a in cax):
                    continue
                if shape is not None and \
                        shape[i] % self._axis_size(cand) != 0:
                    continue
                entries[i] = cand
                used.update(cax)
                break
        spec = P(*entries)
        self.decisions.append((tuple(axes), tuple(shape) if shape else None,
                               spec))
        return spec

    def sharding(self, axes, shape=None) -> NamedSharding:
        return NamedSharding(self.mesh, self.spec(axes, shape))

    def report(self) -> str:
        """Human-readable fallback report (printed by the dry-run)."""
        lines = []
        for axes, shape, spec in self.decisions:
            degraded = [a for a, e in zip(axes, spec)
                        if a is not None and e is None]
            if degraded and shape is not None:
                lines.append(f"  replicated {degraded} for axes={axes} "
                             f"shape={shape}")
        uniq = sorted(set(lines))
        return "\n".join(uniq) if uniq else "  (no fallbacks)"


# ---------------------------------------------------------------------------
# Serve meshes: the (data, model) grid the serving stack shards over.
# The fleet layer owns the data axis (one ServingEngine replica per data
# row); each replica's tick shards heads/KV over its model columns.
# ---------------------------------------------------------------------------

def serve_mesh(model: int = 1, *, data: int = 1,
               devices: Optional[Sequence] = None) -> Mesh:
    """A ``(data, model)`` mesh over ``data * model`` devices.

    Defaults to the first ``data * model`` of :func:`jax.devices` — on a
    CPU host forced to N devices (``xla_force_host_platform_device_count``)
    this is the mesh the multi-device conformance cells and the scaling
    bench run on."""
    need = data * model
    if devices is None:
        devices = jax.devices()
    if len(devices) < need:
        raise ValueError(
            f"serve_mesh(data={data}, model={model}) needs {need} devices, "
            f"have {len(devices)} (set "
            f"XLA_FLAGS=--xla_force_host_platform_device_count=N on CPU)")
    devs = np.asarray(list(devices)[:need], dtype=object).reshape(data, model)
    return Mesh(devs, ("data", "model"))


def fleet_submeshes(mesh: Mesh) -> list[Mesh]:
    """Split a ``(data, model)`` mesh into one ``(1, model)`` submesh per
    data row — the per-replica meshes a ``FleetSupervisor`` hands its
    ``ServingEngine``s.  Each replica shards tensor-parallel state over
    its own model columns; the data axis is realized as N independent
    engines, not as a collective."""
    devs = np.asarray(mesh.devices)
    if devs.ndim != 2:
        raise ValueError(f"expected a 2-d (data, model) mesh, got shape "
                         f"{devs.shape} with axes {mesh.axis_names}")
    return [Mesh(devs[i:i + 1], mesh.axis_names)
            for i in range(devs.shape[0])]


# ---------------------------------------------------------------------------
# Trace-time context: model code calls shard(x, axes); inside `use_rules`
# this becomes with_sharding_constraint, otherwise a no-op (CPU tests).
# ---------------------------------------------------------------------------

_ctx = threading.local()


@contextlib.contextmanager
def use_rules(rules: Optional[ShardingRules]):
    prev = getattr(_ctx, "rules", None)
    _ctx.rules = rules
    try:
        yield rules
    finally:
        _ctx.rules = prev


def current_rules() -> Optional[ShardingRules]:
    return getattr(_ctx, "rules", None)


def shard(x, axes: Sequence[Optional[str]]):
    """Constrain `x`'s sharding per the active rules (no-op without rules)."""
    rules = current_rules()
    if rules is None:
        return x
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(rules.mesh, rules.spec(axes, x.shape)))
