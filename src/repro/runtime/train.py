"""Training step builder: FOR-mode microbatching + AdamW + remat.

EMPA mapping: the microbatch loop is FOR-mode — the 'supervisor' (one
compiled ``lax.scan``) owns loop control and gradient accumulation streams
into an f32 accumulator (SUMUP: the partial sum never round-trips through
'architectural' HBM state between iterations at the JAX level).  One
optimizer step per scan; gradients sync exactly once per step.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import model as model_lib
from repro.optim import adamw
from repro.runtime.sharding import ShardingRules, shard, use_rules


def init_state(key, cfg: ArchConfig, dtype=jnp.bfloat16):
    params = model_lib.init(key, cfg, dtype)
    return {"params": params, "opt": adamw.init(params)}


def abstract_state(cfg: ArchConfig, dtype=jnp.bfloat16):
    params = model_lib.abstract(cfg, dtype)
    f32 = jax.tree_util.tree_map(
        lambda a: jax.ShapeDtypeStruct(a.shape, jnp.float32), params)
    return {"params": params,
            "opt": {"m": f32, "v": f32,
                    "step": jax.ShapeDtypeStruct((), jnp.int32)}}


def state_specs(cfg: ArchConfig, rules: ShardingRules):
    """PartitionSpec tree for the train state (FSDP+TP per the rules)."""
    defs = model_lib.param_defs(cfg)
    pspecs: dict = {}
    from repro.models.params import _set
    for d in defs:
        _set(pspecs, d.path, rules.spec(d.axes, d.shape))
    from jax.sharding import PartitionSpec as P
    return {"params": pspecs,
            "opt": {"m": pspecs, "v": pspecs, "step": P()}}


def _microbatches(batch: dict, n_mb: int) -> dict:
    def split(x):
        b = x.shape[0]
        assert b % n_mb == 0, (b, n_mb)
        return x.reshape(n_mb, b // n_mb, *x.shape[1:])
    return jax.tree_util.tree_map(split, batch)


def compute_specs(cfg: ArchConfig, rules: ShardingRules):
    """Param specs with the FSDP (data) axis dropped — the layout weights
    are gathered INTO for compute when `gather_once` hoists the all-gather
    out of the microbatch loop (ZeRO-2-style weight-stationary step)."""
    import dataclasses as _dc
    no_fsdp = _dc.replace(rules, rules={**rules.rules, "w_embed": ()})
    defs = model_lib.param_defs(cfg)
    out: dict = {}
    from repro.models.params import _set
    for d in defs:
        _set(out, d.path, no_fsdp.spec(d.axes, d.shape))
    return out


def build_train_step(cfg: ArchConfig, opt_cfg: adamw.AdamWConfig,
                     *, n_microbatch: int = 1,
                     rules: Optional[ShardingRules] = None,
                     gather_once: bool = False,
                     remat: bool | str = True):
    """Returns train_step(state, batch) -> (state, metrics).

    gather_once — hoist the FSDP all-gather of the weights out of the
        microbatch loop: ×n_microbatch fewer weight-gather bytes at the
        cost of holding the gathered (still TP-sharded) bf16 weights for
        the whole step (§Perf E3, EMPA: clone the glue ONCE per rent).
    remat — True: full per-layer remat; "moe_save": remat but SAVE tensors
        named 'moe_out' so backward never replays the MoE combine's
        collectives (§Perf E2); False: no remat.
    """
    policy = None
    if remat == "moe_save":
        policy = jax.checkpoint_policies.save_only_these_names("moe_out")
    elif remat == "block_save":
        # save the TP-psum'd block outputs: backward reuses them instead of
        # replaying the collectives (costs ~2 bf16 activations per layer)
        policy = jax.checkpoint_policies.save_only_these_names(
            "attn_out", "mlp_out", "moe_out")

    def train_step(state, batch):
        with use_rules(rules):
            params = state["params"]
            if gather_once and rules is not None:
                from jax.sharding import NamedSharding, PartitionSpec
                shardings = jax.tree_util.tree_map(
                    lambda s: NamedSharding(rules.mesh, s),
                    compute_specs(cfg, rules),
                    is_leaf=lambda x: isinstance(x, PartitionSpec))
                params = jax.tree_util.tree_map(
                    jax.lax.with_sharding_constraint, params, shardings)

            def mb_loss(p, mb):
                return model_lib.loss_fn(p, mb, cfg, remat=remat,
                                         remat_policy=policy)

            grad_fn = jax.value_and_grad(mb_loss, has_aux=True)

            if n_microbatch == 1:
                (loss, metrics), grads = grad_fn(params, batch)
                grads = jax.tree_util.tree_map(
                    lambda g: g.astype(jnp.float32), grads)
            else:
                mbs = _microbatches(batch, n_microbatch)
                g0 = jax.tree_util.tree_map(
                    lambda p: jnp.zeros(p.shape, jnp.float32), params)

                def body(carry, mb):
                    loss_acc, g_acc = carry
                    (loss, _), g = grad_fn(params, mb)
                    g_acc = jax.tree_util.tree_map(
                        lambda a, b: a + b.astype(jnp.float32), g_acc, g)
                    return (loss_acc + loss, g_acc), None

                (loss, grads), _ = jax.lax.scan(
                    body, (jnp.float32(0.0), g0), mbs)
                loss = loss / n_microbatch
                grads = jax.tree_util.tree_map(
                    lambda g: g / n_microbatch, grads)
                metrics = {}

            new_params, new_opt, om = adamw.update(
                grads, state["opt"], params, opt_cfg)
            out_metrics = {"loss": loss, **om}
        return {"params": new_params, "opt": new_opt}, out_metrics

    return train_step


def jit_train_step(cfg, opt_cfg, mesh, rules, *, n_microbatch=1,
                   batch_specs=None):
    """pjit-compiled step with explicit in/out shardings + donation."""
    from jax.sharding import NamedSharding
    step = build_train_step(cfg, opt_cfg, n_microbatch=n_microbatch,
                            rules=rules)
    sspec = state_specs(cfg, rules)
    to_sh = lambda tree: jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), tree,
        is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec))
    in_sh = (to_sh(sspec), to_sh(batch_specs) if batch_specs else None)
    out_sh = (to_sh(sspec), None)
    return jax.jit(step, in_shardings=in_sh, out_shardings=out_sh,
                   donate_argnums=(0,))
