# Cluster-scale EMPA runtime.  Import submodules explicitly (kept lazy to
# avoid pulling jax mesh machinery into simulator-only use).
