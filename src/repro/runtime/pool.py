"""Jittable EMPA pool discipline: the SV's rent/return state as arrays.

The paper's supervisor "handles all resources of the processor" (§3.5)
through bitmask state over a pool of uniform units.  This module is that
state as a :class:`SlotPoolState` NamedTuple of jax arrays plus *pure*
transition functions (``rent`` / ``release`` / ``disable`` / ``enable`` /
``preallocate``) that can live inside a jitted program — so the serving
engine's slot supervisor runs on the device, not in host Python.

One implementation, three consumers:

* ``core/supervisor.CorePool`` — the host-level wrapper (raises on misuse,
  keeps the exact pre-refactor API) used by the property tests and the
  elastic fleet manager;
* ``runtime/serve.ServingEngine`` — KV-cache slots are cores, requests
  are QTs (§4.3 rent/terminate);
* ``runtime/elastic.ElasticManager`` — hosts are cores, a failed host is
  a core "disabled for some reason (like overheating)" (§4.1.2).

Transitions never raise: they are total functions returning a status code
(jit-compatible).  The host wrapper turns non-``OK`` codes into the
exceptions the old numpy implementation raised.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional, Union

import jax
import jax.numpy as jnp
import numpy as np

NO_PARENT = -1

# status codes returned by `release`
OK = 0
ERR_NOT_RENTED = 1          # ValueError on the host wrapper
ERR_LIVE_CHILDREN = 2       # RuntimeError: §4.3 blocks parent termination
ERR_BAD_UNIT = 3

# lifecycle phase of a rented unit: the paper's QT does not receive its
# whole job at once — it is fed *fragments* (the companion EMPA paper's
# quasi-thread discipline), so a unit is either still being loaded
# (PREFILL: consuming prompt fragments), running (DECODE), or parked
# (PREEMPTED: the supervisor clawed its lent resources back under
# pressure — §4.3's rent/terminate cycle applied mid-flight — and the
# QT waits, with its full history, for re-admission).  Free units are
# IDLE by invariant.
PHASE_IDLE = 0
PHASE_PREFILL = 1
PHASE_DECODE = 2
PHASE_PREEMPTED = 3

IntLike = Union[int, jax.Array]


class SlotPoolState(NamedTuple):
    """Pool of `n` uniform units; every field is a fixed-shape array."""

    free: jax.Array           # (n,) bool — True = in pool (available)
    parent: jax.Array         # (n,) int32 — parent unit or NO_PARENT
    prealloc: jax.Array       # (n, n) bool — [parent, unit] claims (§5.1)
    disabled: jax.Array       # (n,) bool — 'overheated' units (§4.1.2)
    created_total: jax.Array  # () int32 — rents ever granted
    peak_used: jax.Array      # () int32 — high-water mark
    phase: jax.Array          # (n,) int32 — PHASE_* of each rented unit

    @property
    def n(self) -> int:
        return self.free.shape[0]


def init_pool(n: int) -> SlotPoolState:
    return SlotPoolState(
        free=jnp.ones((n,), bool),
        parent=jnp.full((n,), NO_PARENT, jnp.int32),
        prealloc=jnp.zeros((n, n), bool),
        disabled=jnp.zeros((n,), bool),
        created_total=jnp.int32(0),
        peak_used=jnp.int32(0),
        phase=jnp.zeros((n,), jnp.int32),
    )


# -- queries (all jittable) --------------------------------------------------

def available(state: SlotPoolState) -> jax.Array:
    return jnp.sum(state.free & ~state.disabled).astype(jnp.int32)


def used(state: SlotPoolState) -> jax.Array:
    return jnp.sum(~state.free).astype(jnp.int32)


def children_mask(state: SlotPoolState, unit: IntLike) -> jax.Array:
    """Live children of `unit` (a free unit never has a parent)."""
    return (state.parent == jnp.asarray(unit, jnp.int32)) & ~state.free


# -- transitions -------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("prefer_preallocated",))
def rent(state: SlotPoolState, parent: IntLike = NO_PARENT,
         prefer_preallocated: bool = True):
    """Rent the first available unit.  Returns (state, unit) — unit == -1
    when the pool is exhausted (the SV's 'no ALU avail', §3.1)."""
    parent = jnp.asarray(parent, jnp.int32)
    # transitions are total: an out-of-range parent degrades to "no
    # parent" rather than corrupting state (the host wrapper raises)
    has_parent = (parent >= 0) & (parent < state.n)
    p = jnp.clip(parent, 0, state.n - 1)
    cand = state.free & ~state.disabled
    pre = state.prealloc[p] & cand
    if prefer_preallocated:
        cand = jnp.where(has_parent & jnp.any(pre), pre, cand)
    ok = jnp.any(cand)
    unit = jnp.where(ok, jnp.argmax(cand), NO_PARENT).astype(jnp.int32)
    u = jnp.maximum(unit, 0)
    free = jnp.where(ok, state.free.at[u].set(False), state.free)
    par = jnp.where(ok & has_parent, state.parent.at[u].set(parent),
                    state.parent)
    created = state.created_total + ok.astype(jnp.int32)
    peak = jnp.maximum(state.peak_used, jnp.sum(~free).astype(jnp.int32))
    return state._replace(free=free, parent=par, created_total=created,
                          peak_used=peak), unit


@jax.jit
def release(state: SlotPoolState, unit: IntLike):
    """Terminate the QT on `unit` (§4.3).  Returns (state, status); on a
    non-OK status the state is unchanged."""
    unit = jnp.asarray(unit, jnp.int32)
    valid = (unit >= 0) & (unit < state.n)
    u = jnp.clip(unit, 0, state.n - 1)
    status = jnp.where(
        ~valid, ERR_BAD_UNIT,
        jnp.where(state.free[u], ERR_NOT_RENTED,
                  jnp.where(jnp.any(children_mask(state, unit)),
                            ERR_LIVE_CHILDREN, OK))).astype(jnp.int32)
    ok = status == OK
    par = jnp.where(ok, state.parent.at[u].set(NO_PARENT), state.parent)
    # clear any prealloc claims on this unit
    pre = jnp.where(ok, state.prealloc.at[:, u].set(False), state.prealloc)
    free = jnp.where(ok, state.free.at[u].set(True), state.free)
    phase = jnp.where(ok, state.phase.at[u].set(PHASE_IDLE), state.phase)
    return state._replace(free=free, parent=par, prealloc=pre,
                          phase=phase), status


@jax.jit
def rent_many(state: SlotPoolState, need: jax.Array):
    """Vectorized rent: grant one unit per ``True`` row of ``need``.

    The generalization that lets the same discipline govern pools of
    *arbitrary* resource counts (KV-cache blocks, not just slots): the
    serving decode chunk asks for one block per slot crossing a block
    boundary in a single pure transition — no host round-trip, no Python
    loop over rows.  Returns ``(state, units)`` where ``units`` has the
    shape of ``need``: the granted unit id per row, or -1 where the row
    didn't ask or the pool ran dry (grants are first-come first-served in
    row order, lowest-index units first — the same order a loop of
    ``rent`` calls would produce)."""
    need = jnp.asarray(need, bool)
    avail = state.free & ~state.disabled
    n_avail = jnp.sum(avail).astype(jnp.int32)
    # available unit ids first, ascending (stable sort keeps index order)
    order = jnp.argsort(~avail, stable=True).astype(jnp.int32)
    rank = jnp.cumsum(need.astype(jnp.int32)) - 1
    ok = need & (rank < n_avail)
    u = order[jnp.clip(rank, 0, state.n - 1)]
    units = jnp.where(ok, u, NO_PARENT).astype(jnp.int32)
    # scatter with an out-of-range sentinel for ungranted rows ("drop")
    free = state.free.at[jnp.where(ok, u, state.n)].set(False, mode="drop")
    created = state.created_total + jnp.sum(ok).astype(jnp.int32)
    peak = jnp.maximum(state.peak_used, jnp.sum(~free).astype(jnp.int32))
    return state._replace(free=free, created_total=created,
                          peak_used=peak), units


@jax.jit
def release_many(state: SlotPoolState, mask: jax.Array) -> SlotPoolState:
    """Vectorized release of every rented unit in ``mask`` (n,) bool.

    Rows that are already free are ignored; a unit whose live children are
    not all being released in the same call is kept rented (the §4.3
    parent-termination block, applied set-wise).  Total function — never
    raises — so it can run inside the jitted serving chunk when a whole
    block chain retires at once."""
    mask = jnp.asarray(mask, bool)
    alive_after = ~state.free & ~mask
    has_child = jnp.any(
        (state.parent[None, :] == jnp.arange(state.n)[:, None])
        & alive_after[None, :], axis=1)
    rel = mask & ~state.free & ~has_child
    free = state.free | rel
    parent = jnp.where(rel, NO_PARENT, state.parent)
    prealloc = state.prealloc & ~rel[None, :]
    phase = jnp.where(rel, PHASE_IDLE, state.phase)
    return state._replace(free=free, parent=parent, prealloc=prealloc,
                          phase=phase)


@jax.jit
def preallocate(state: SlotPoolState, parent: IntLike, k: IntLike):
    """Claim up to `k` free units for `parent` (§5.1: guarantees a core is
    always available for the iterations).  Returns (state, granted_mask).

    Claims are exclusive: a unit already claimed by another parent is
    skipped, so ``prealloc`` stays one-hot per unit column.  An
    out-of-range parent grants nothing (the host wrapper raises)."""
    parent = jnp.asarray(parent, jnp.int32)
    valid = (parent >= 0) & (parent < state.n)
    p = jnp.clip(parent, 0, state.n - 1)
    cand = state.free & ~state.disabled & ~jnp.any(state.prealloc, axis=0)
    take = valid & cand & (jnp.cumsum(cand) <= jnp.asarray(k, jnp.int32))
    pre = state.prealloc.at[p].set(state.prealloc[p] | take)
    return state._replace(prealloc=pre), take


@jax.jit
def set_phase(state: SlotPoolState, unit: IntLike,
              phase: IntLike) -> SlotPoolState:
    """Record the lifecycle phase of a rented unit (PREFILL while its QT
    is still being fed prompt fragments, DECODE once it runs).  Total
    function: an out-of-range or free unit leaves the state unchanged
    (the host wrapper raises)."""
    unit = jnp.asarray(unit, jnp.int32)
    u = jnp.clip(unit, 0, state.n - 1)
    valid = (unit >= 0) & (unit < state.n) & ~state.free[u]
    new = state.phase.at[u].set(jnp.asarray(phase, jnp.int32))
    return state._replace(phase=jnp.where(valid, new, state.phase))


@jax.jit
def disable(state: SlotPoolState, unit: IntLike) -> SlotPoolState:
    """A unit becomes unavailable ('overheating' / failed host, §4.1.2)."""
    return state._replace(
        disabled=state.disabled.at[jnp.asarray(unit, jnp.int32)].set(True))


@jax.jit
def enable(state: SlotPoolState, unit: IntLike) -> SlotPoolState:
    return state._replace(
        disabled=state.disabled.at[jnp.asarray(unit, jnp.int32)].set(False))


# -- fleet aggregation --------------------------------------------------------

def merge_stats(states) -> dict:
    """Fleet-wide ledger over per-replica (per-shard) slot pools.

    A fleet of supervisors holds one independent pool per replica; the
    fleet-level numbers are plain sums — the pools are disjoint, so
    used/peak/created add, and the per-pool monotonicity invariant
    (``used <= peak_used <= created_total``) carries over to the sums.
    This is the accounting `FleetSupervisor.occupancy_stats` reports so
    per-shard pools never masquerade as one global pool.
    """
    totals = {"n_units": 0, "used": 0, "available": 0,
              "peak_used": 0, "created_total": 0}
    for s in states:
        totals["n_units"] += int(s.n)
        totals["used"] += int(used(s))
        totals["available"] += int(available(s))
        totals["peak_used"] += int(s.peak_used)
        totals["created_total"] += int(s.created_total)
    assert 0 <= totals["used"] <= totals["peak_used"] \
        <= totals["created_total"] or totals["created_total"] == 0
    return totals


# -- invariants (host-side; property-tested) ---------------------------------

def invariant_violation(state: SlotPoolState) -> Optional[str]:
    """`check_invariants` as a health probe: the failure *reason* instead
    of an AssertionError.  This is what the serving fleet's per-tick
    ledger sampling reads — a forged free bit (faults.corrupt_pool_ledger
    is the chaos twin) comes back as a quarantine reason, not a crash in
    the supervisor loop."""
    try:
        check_invariants(state)
    except AssertionError as exc:
        return str(exc)
    return None


def check_invariants(state: SlotPoolState) -> None:
    free = np.asarray(state.free)
    parent = np.asarray(state.parent)
    prealloc = np.asarray(state.prealloc)
    disabled = np.asarray(state.disabled)
    phase = np.asarray(state.phase)
    n = free.shape[0]
    assert parent.shape == (n,) and prealloc.shape == (n, n)
    assert np.all((phase >= PHASE_IDLE) & (phase <= PHASE_PREEMPTED)), \
        "phase outside the QT lifecycle"
    assert np.all(phase[free] == PHASE_IDLE), "free unit with a phase"
    for u in range(n):
        p = int(parent[u])
        assert -1 <= p < n
        if p >= 0:
            assert not free[u], f"{u} has parent but is free"
    # prealloc claims are exclusive: one parent per unit
    assert np.all(prealloc.sum(axis=0) <= 1), "unit preallocated twice"
    # pool conservation: rented + available + disabled-but-free == n
    n_used = int(np.sum(~free))
    n_avail = int(np.sum(free & ~disabled))
    assert n_used + n_avail + int(np.sum(disabled & free)) == n
    # counter monotonicity: the high-water mark bounds current usage and
    # never exceeds the rents ever granted.  Rollback-relevant: a
    # speculative rewind releases nothing (rejected blocks stay rented
    # until the chain retires), so `used` may only shrink through
    # release transitions — these bounds catch a rewind that forged a
    # free bit without going through one.
    assert 0 <= n_used <= int(state.peak_used) <= int(state.created_total)
