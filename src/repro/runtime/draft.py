"""Device-resident n-gram drafter: the cheap core that runs ahead.

The paper's central pattern is a core outsourcing part of its job to a
neighbour and reconciling the result through the supervisor (PAPER.md
§§4-5).  Speculative decoding is that pattern on the decode hot path:
this module is the *drafter core* — it proposes up to ``spec_k``
candidate continuation tokens per decoding slot by prompt-lookup
(n-gram matching against the slot's own recent token stream), and the
verify forward (`serve.build_spec_tick`) is the supervisor-coordinated
reconciliation that accepts the longest correct prefix.

The drafter is deliberately model-free: a bigram match over a per-slot
ring of recent tokens costs a few vectorized compares — nothing next to
one transformer forward — and greedy-argmax verification makes the
scheme *bit-exact*: a wrong draft costs speculated work, never a wrong
token.  The fallback when no n-gram matches is an empty draft, which
degrades the spec tick to exactly the status-quo single greedy step.

State discipline mirrors the serving supervisor: every field is a
fixed-shape device array, every transition is pure and jittable, and
the invariant is

    ``hist[slot]`` holds the slot's consumed token stream (prompt +
    emitted tokens), newest last, EXCLUDING the pending input token
    ``DecodeState.tokens[slot]`` — so the match context is the bigram
    ``(hist[:, -1], tokens)`` and a proposed continuation starts right
    after an earlier occurrence of that bigram.

``count`` tracks how many trailing positions of each row are valid;
a freshly rented slot resets to 0, which disables matching entirely.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np


class DraftState(NamedTuple):
    """Per-slot drafter state; fixed shapes, device-resident."""

    hist: jax.Array    # (n_slots, H) int32 — token stream, newest at end
    count: jax.Array   # (n_slots,) int32 — valid trailing positions (<= H)

    @property
    def hist_len(self) -> int:
        return self.hist.shape[1]


def init_draft_state(n_slots: int, hist_len: int) -> DraftState:
    return DraftState(hist=jnp.zeros((n_slots, hist_len), jnp.int32),
                      count=jnp.zeros((n_slots,), jnp.int32))


def abstract_draft_state(n_slots: int, hist_len: int) -> DraftState:
    return jax.tree_util.tree_map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
        init_draft_state(n_slots, hist_len))


def push_tokens(state: DraftState, tokens: jax.Array,
                counts: jax.Array) -> DraftState:
    """Append ``counts[i]`` leading tokens of ``tokens[i]`` to row i.

    ``tokens`` is (n_slots, W) left-aligned (the tick's consumed
    fragment); rows with ``counts == 0`` are untouched.  The append is a
    shift-free gather: concatenate and take the last H of the stream.
    """
    n, h = state.hist.shape
    w = tokens.shape[1]
    c = jnp.clip(jnp.asarray(counts, jnp.int32), 0, w)
    merged = jnp.concatenate([state.hist, jnp.asarray(tokens, jnp.int32)],
                             axis=1)                       # (n, H + W)
    # the valid stream of row i ends at column H + c[i] - 1; keep its
    # trailing H positions: columns c[i] .. c[i] + H - 1
    cols = c[:, None] + jnp.arange(h, dtype=jnp.int32)[None, :]
    hist = jnp.take_along_axis(merged, cols, axis=1)
    return DraftState(hist=hist, count=jnp.minimum(state.count + c, h))


def propose(state: DraftState, tokens: jax.Array, spec_k: int):
    """Draft up to ``spec_k`` continuation tokens per slot.

    ``tokens`` (n_slots,) is each slot's pending input token.  The match
    context is the bigram ``(hist[:, -1], tokens)``; the draft is the
    ``spec_k`` tokens that followed its *latest* earlier occurrence in
    the history.  Returns ``(draft (n, spec_k) int32, draft_len (n,)
    int32)`` — ``draft_len == 0`` (no match / too little history) is the
    single-greedy-step fallback, so acceptance can never fall below the
    non-speculative status quo.
    """
    hist, count = state.hist, state.count
    n, h = hist.shape
    tokens = jnp.asarray(tokens, jnp.int32)
    # candidate positions j: bigram (hist[j], hist[j+1]) == (hist[-1],
    # tokens), both inside the valid window, with at least one
    # continuation token available inside hist (j + 2 <= H - 1)
    j = jnp.arange(h - 2, dtype=jnp.int32)                 # (H-2,)
    valid_from = h - count                                  # (n,)
    match = (hist[:, :-2] == hist[:, -1:]) \
        & (hist[:, 1:-1] == tokens[:, None]) \
        & (j[None, :] >= valid_from[:, None]) \
        & (count[:, None] >= 3)       # need context + >=1 continuation
    # among matches, prefer the one with the longest usable continuation
    # (a constant run's *latest* occurrence sits at the history edge
    # with almost nothing after it), breaking ties toward recency
    len_j = jnp.minimum(h - 2 - j, spec_k)                  # (H-2,)
    score = jnp.where(match, len_j[None, :] * h + j[None, :], -1)
    pick = jnp.argmax(score, axis=1).astype(jnp.int32)      # (n,)
    have = jnp.max(score, axis=1) >= 0
    best = jnp.where(have, pick, 0)
    # continuation tokens hist[best+2 .. ]; clamp gathers for no-match rows
    cols = best[:, None] + 2 + jnp.arange(spec_k, dtype=jnp.int32)[None, :]
    draft = jnp.take_along_axis(hist, jnp.clip(cols, 0, h - 1), axis=1)
    avail = h - best - 2                                    # tokens in hist
    draft_len = jnp.where(have, jnp.minimum(avail, spec_k), 0) \
        .astype(jnp.int32)
    return draft, draft_len


def push_and_propose(state: DraftState, tokens: jax.Array,
                     counts: jax.Array, pending: jax.Array, spec_k: int):
    """Fused accept/re-propose transition: :func:`push_tokens` the
    fragment a verify tick just consumed, then :func:`propose` against
    the updated history in the same jitted graph.

    This is the drafter half of the on-device accept/rewind core — the
    spec-chunk loop body calls it once per iteration, so the drafter
    never round-trips through the host between the verify gather and the
    next proposal.  ``pending`` (n_slots,) is each slot's next input
    token (the last accepted/corrected emission).  Returns ``(state',
    draft, draft_len)``; the budget clamp is the *caller's* job, applied
    at consumption time against the then-current ``DecodeState``.
    """
    state = push_tokens(state, tokens, counts)
    draft, draft_len = propose(state, pending, spec_k)
    return state, draft, draft_len


# -- host-side admission helpers ---------------------------------------------

def reset_slot(state: DraftState, slot: int) -> DraftState:
    """A freshly rented slot starts with no history (matching disabled
    until fragments/tokens are pushed)."""
    return state._replace(count=state.count.at[slot].set(0))


def evict_slot(state: DraftState, slot: int) -> DraftState:
    """Preemption: the drafter's match window dies with the slot's KV.
    The parked request replays its history through chunked prefill at
    re-admission and re-seeds via :func:`seed_slot` at the PREFILL ->
    DECODE transition, so matching stays disabled in between (a stale
    window must never draft for the slot's next tenant either)."""
    return reset_slot(state, slot)


def seed_slot(state: DraftState, slot: int, prompt) -> DraftState:
    """Monolithic admission: the whole prompt was consumed by one
    prefill call, so the slot's history is the prompt tail (the pending
    input token — the prefill argmax — stays out, per the invariant).

    The row is padded to ``hist_len`` on the host so the device update
    is shape-stable: a variable-length ``.at[slot, h-len:].set`` traces
    one scatter per distinct prompt length, which showed up as tens of
    ms of XLA compiles *per admission* in the serve bench."""
    h = state.hist_len
    tail = np.asarray(prompt, np.int32)[-h:]
    row = np.zeros(h, np.int32)
    row[h - len(tail):] = tail
    hist = state.hist.at[slot].set(jnp.asarray(row))
    return DraftState(hist=hist,
                      count=state.count.at[slot].set(len(tail)))
