"""Paged KV-cache block pool under the SV's rent/release discipline.

PR 1 made KV-cache *slots* the rented resource; this module makes the
rented resource a fixed-size KV **block** (vLLM-style paging), which is
the paper's discipline applied one level down: the supervisor "handles
all resources of the processor" (§3.5) one action per clock — here the
resources are cache blocks, the actions are the same pure transitions
(`runtime/pool.rent_many` / `release_many`) the slot pool already runs.

State:

* :class:`BlockPoolState` — a :class:`SlotPoolState` over ``n_blocks``
  plus per-block **refcounts** (shared prompt-prefix blocks are rented
  once and referenced by many chains);
* per-slot **block tables** ``(n_slots, max_blocks)`` int32 (-1 = end of
  chain) — these live in the serving cache pytree so the jitted decode
  step can translate ``pos`` -> ``(block, offset)`` without host help.

Transitions (all pure, all jit-compatible):

* :func:`admit_chains` — admission rents the blocks a prompt needs and
  takes a reference on every block of the chain (shared prefix blocks
  are referenced, not re-rented);
* :func:`grow_for_decode` — inside the jitted decode chunk: every active
  slot whose ``pos`` crossed a block boundary rents one more block in a
  single vectorized ``rent_many`` (no host sync);
* :func:`release_chain` — retirement drops the chain's references and
  returns refcount-zero blocks to the pool (§4.3 rent/terminate).
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.runtime import pool as pool_lib
from repro.runtime.pool import SlotPoolState

NO_BLOCK = -1


class BlockPoolState(NamedTuple):
    """The rented resource is a KV block; every field is fixed-shape."""

    pool: SlotPoolState       # free/disabled/created/peak over n_blocks
    refcount: jax.Array       # (n_blocks,) int32 — chains referencing

    @property
    def n_blocks(self) -> int:
        return self.pool.n


def init_blocks(n_blocks: int) -> BlockPoolState:
    return BlockPoolState(pool=pool_lib.init_pool(n_blocks),
                          refcount=jnp.zeros((n_blocks,), jnp.int32))


def init_block_tables(n_slots: int, max_blocks: int) -> jax.Array:
    return jnp.full((n_slots, max_blocks), NO_BLOCK, jnp.int32)


def abstract_blocks(n_blocks: int) -> BlockPoolState:
    return jax.tree_util.tree_map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
        init_blocks(n_blocks))


def _sanitize(idx: jax.Array, n: int) -> jax.Array:
    """Map NO_BLOCK entries to an out-of-range sentinel so scatters with
    ``mode="drop"`` skip them (negative indices would wrap)."""
    return jnp.where(idx >= 0, idx, n).astype(jnp.int32)


def admit_chains(state: BlockPoolState, chain_blocks: jax.Array,
                 new_blocks: jax.Array) -> BlockPoolState:
    """Admission: rent `new_blocks`, reference every block in
    `chain_blocks` (both flat int32 arrays, NO_BLOCK-padded).

    The host supervisor picked the block ids (it owns the admission-time
    free list and the prefix-hash map); this transition commits them to
    the device state: shared prefix blocks appear in `chain_blocks` only
    (refcount + 1), newly stored blocks appear in both (rented AND
    referenced).
    """
    n = state.n_blocks
    new_s = _sanitize(jnp.asarray(new_blocks, jnp.int32), n)
    chain_s = _sanitize(jnp.asarray(chain_blocks, jnp.int32), n)
    n_new = jnp.sum(new_s < n).astype(jnp.int32)
    pool = state.pool
    free = pool.free.at[new_s].set(False, mode="drop")
    created = pool.created_total + n_new
    peak = jnp.maximum(pool.peak_used, jnp.sum(~free).astype(jnp.int32))
    refcount = state.refcount.at[chain_s].add(1, mode="drop")
    return BlockPoolState(
        pool=pool._replace(free=free, created_total=created, peak_used=peak),
        refcount=refcount)


def extend_chains(state: BlockPoolState, tables: jax.Array,
                  cols: jax.Array, blocks: jax.Array):
    """Chunk-granular rent: commit one prefill *fragment's* blocks per
    slot — rent each host-picked block, take its chain reference, and
    append it to the slot's table at the given column, all in one pure
    transition inside the mixed tick.

    ``blocks`` / ``cols`` are (n_slots, K) int32, NO_BLOCK-padded.  The
    host supervisor picked the ids from its free-list mirror and its
    §5.1 worst-case reservation guarantees they are grantable, so unlike
    :func:`grow_for_decode` this commit cannot stall.  This is what
    replaces whole-chain-at-admission renting: a chain grows as its
    prompt fragments are outsourced, never faster.

    Returns ``(state, tables)``.
    """
    blk = jnp.asarray(blocks, jnp.int32)
    rows = jnp.arange(tables.shape[0])[:, None]
    c = jnp.where(blk >= 0, jnp.asarray(cols, jnp.int32), tables.shape[1])
    tables = tables.at[rows, c].set(blk, mode="drop")
    flat = blk.reshape(-1)
    return admit_chains(state, flat, flat), tables


def grow_to_cover(state: BlockPoolState, tables: jax.Array,
                  last_pos: jax.Array, active: jax.Array, *,
                  block_size: int, max_rounds: int = 1):
    """Rent blocks until each active chain covers write position
    ``last_pos`` (inclusive), fully on device.

    One decode step needs at most one new block per tick
    (:func:`grow_for_decode` is the ``max_rounds=1`` special case), but
    a **speculative verify fragment** writes up to ``spec_k + 1``
    positions at once and may cross several block boundaries — hence
    the static loop of vectorized :func:`pool.rent_many` rounds, each
    granting one block per still-deficient chain and appending it at
    the chain's current end.  Rollback safety: a rewound (rejected)
    draft leaves its blocks rented — they sit inside the admission-time
    §5.1 worst-case reservation, are overwritten by the next fragment's
    write-then-attend, and are released with the chain at retirement,
    so speculation introduces no new stall mode.

    Returns ``(state, tables, stalled)`` where ``stalled`` marks slots
    whose target is still uncovered after ``max_rounds`` (unreachable
    under the reservation; the safety valve, not the plan — a stalled
    slot must not be written).
    """
    n_slots, max_blocks = tables.shape
    need_blocks = (jnp.asarray(last_pos, jnp.int32) // block_size + 1)
    active = jnp.asarray(active, bool)
    row = jnp.arange(n_slots)
    refcount = state.refcount
    pool = state.pool
    for _ in range(max_rounds):
        have = jnp.sum(tables >= 0, axis=1).astype(jnp.int32)
        need = active & (need_blocks > have)
        pool, units = pool_lib.rent_many(pool, need)
        granted = units >= 0
        col = jnp.where(granted, jnp.clip(have, 0, max_blocks - 1),
                        max_blocks)
        tables = tables.at[row, col].set(units, mode="drop")
        refcount = refcount.at[
            jnp.where(granted, units, state.n_blocks)].set(1, mode="drop")
    have = jnp.sum(tables >= 0, axis=1).astype(jnp.int32)
    stalled = active & (need_blocks > have)
    return BlockPoolState(pool=pool, refcount=refcount), tables, stalled


def grow_for_decode(state: BlockPoolState, tables: jax.Array,
                    pos: jax.Array, active: jax.Array, *, block_size: int):
    """One decode tick's block growth: every active slot whose next
    write position ``pos`` falls in a block its chain doesn't cover yet
    rents exactly one block via a single vectorized
    :func:`pool.rent_many` (the ``max_rounds=1`` case of
    :func:`grow_to_cover`)."""
    return grow_to_cover(state, tables, pos, active,
                         block_size=block_size, max_rounds=1)


def _drop_chain(state: BlockPoolState, tables: jax.Array, slot):
    """The shared chain-drop core: one reference dropped per chain
    entry, refcount-zero blocks returned to the pool, the slot's table
    row cleared.  A block another chain still references (a shared
    prompt prefix) keeps its rent — dropping a chain can never free a
    neighbour's storage.  Returns ``(state, tables, n_freed)``."""
    n = state.n_blocks
    chain = _sanitize(tables[jnp.asarray(slot, jnp.int32)], n)
    refcount = state.refcount.at[chain].add(-1, mode="drop")
    newly_free = (refcount <= 0) & ~state.pool.free
    pool = pool_lib.release_many(state.pool, newly_free)
    tables = tables.at[jnp.asarray(slot, jnp.int32)].set(NO_BLOCK)
    n_freed = jnp.sum(newly_free).astype(jnp.int32)
    return BlockPoolState(pool=pool, refcount=refcount), tables, n_freed


@jax.jit
def release_chain(state: BlockPoolState, tables: jax.Array, slot):
    """Retire `slot` (§4.3 terminate): drop one reference per chain
    block, return refcount-zero blocks to the pool, clear the row."""
    state, tables, _ = _drop_chain(state, tables, slot)
    return state, tables


@jax.jit
def evict_chain(state: BlockPoolState, tables: jax.Array, slot):
    """Preempt `slot`: the supervisor claws a *live* chain back under
    KV pressure (the paper's rent/terminate cycle applied mid-flight —
    cheap enough to do while the QT still wants the resources).

    Reference discipline is identical to :func:`release_chain` —
    refcount-aware, so shared prefix blocks another chain references
    survive the eviction — but the transition returns ``(state, tables,
    n_freed)`` so the host loop can tell whether the eviction actually
    relieved pressure (a fully-shared chain frees nothing).  The evicted
    request's tokens are *not* lost: the serving engine parks them and
    replays prompt + generated history through chunked prefill at
    re-admission, which reconstructs the chain token-exactly."""
    return _drop_chain(state, tables, slot)


# -- queries / invariants ----------------------------------------------------

def blocks_in_use(state: BlockPoolState) -> jax.Array:
    return jnp.sum(~state.pool.free).astype(jnp.int32)


def free_blocks(state: BlockPoolState) -> jax.Array:
    """Rentable blocks right now (jittable) — the fleet router's
    least-loaded signal."""
    return pool_lib.available(state.pool)


def merge_block_stats(states) -> dict:
    """Fleet-wide block ledger over per-replica pools: disjoint pools,
    so capacity/usage/peaks are plain sums (see `pool.merge_stats` for
    the invariant argument).  One replica's pool may itself be sharded
    over the model axis — the ledger is replicated-with-local-rent
    there, so each replica still contributes exactly one pool here."""
    out = {"n_blocks": 0, "in_use": 0, "free": 0, "peak_used": 0}
    for s in states:
        out["n_blocks"] += int(s.pool.n)
        out["in_use"] += int(blocks_in_use(s))
        out["free"] += int(free_blocks(s))
        out["peak_used"] += int(s.pool.peak_used)
    return out


def invariant_violation(state: BlockPoolState, tables=None) -> Optional[str]:
    """`check_invariants` as a health probe (reason string, not a raise)
    — the block-ledger twin of ``pool.invariant_violation``.  The fleet
    supervisor reads it as a *diagnostic* on an already-quarantined
    replica: it materializes device state, so it stays off the serving
    hot path."""
    try:
        check_invariants(state, tables)
    except AssertionError as exc:
        return str(exc)
    return None


def check_invariants(state: BlockPoolState, tables=None) -> None:
    """Host-side: refcounts and the free mask must agree; with `tables`
    given, refcounts must equal the number of chains referencing."""
    pool_lib.check_invariants(state.pool)
    free = np.asarray(state.pool.free)
    ref = np.asarray(state.refcount)
    assert np.all(ref >= 0), "negative refcount"
    assert np.all(ref[free] == 0), "free block still referenced"
    assert np.all(ref[~free] >= 1), "rented block with no reference"
    if tables is not None:
        t = np.asarray(tables)
        counts = np.zeros_like(ref)
        for row in t:
            for b in row[row >= 0]:
                counts[b] += 1
        assert np.array_equal(counts, ref), (counts, ref)
