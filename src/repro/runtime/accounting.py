"""Cost accounting for the roofline: jaxpr FLOPs/bytes + HLO collectives.

XLA's ``compiled.cost_analysis()`` counts a while-loop body ONCE — for
scan-over-layers models it under-reports by the trip product (verified
empirically; see EXPERIMENTS.md §Dry-run).  This module provides loop-aware
accounting:

* :func:`jaxpr_cost` — recursive walk of the step's jaxpr.  ``scan`` trip
  counts are explicit there, so matmul FLOPs (dot_general), elementwise
  FLOPs and pre-fusion tensor traffic are counted exactly, including the
  remat recompute that autodiff inserts.  Numbers are GLOBAL (pre-SPMD).
* :func:`hlo_collectives` — walk of the partitioned HLO: per-computation
  collective result bytes, with while-body contributions multiplied by the
  trip count parsed from the loop condition.  Numbers are PER-DEVICE wire
  bytes (the module is post-partitioning).  ``conditional`` branches take
  the max (conservative for zamba2's every-6th shared block).
* :class:`TierAccounting` — per-tier latency SLO accounting for the async
  request frontier (``ServingEngine.submit``/``poll``): TTFT from submit
  to first emitted token and inter-token gaps per request, aggregated
  into per-tier p50/p99.  Entirely host-side — it watches ``len(req.out)``
  transitions at the per-chunk sync the engine already pays for, so the
  SLO ledger adds zero device syncs.
"""
from __future__ import annotations

import collections
import dataclasses
import re
import time
from typing import Any, Dict, List, Optional

import jax
import numpy as np

# ---------------------------------------------------------------------------
# jaxpr-level FLOPs / bytes
# ---------------------------------------------------------------------------

_ELEMENTWISE = {
    "add", "sub", "mul", "div", "max", "min", "neg", "abs", "sign",
    "exp", "log", "log1p", "expm1", "tanh", "logistic", "erf", "rsqrt",
    "sqrt", "pow", "integer_pow", "cos", "sin", "floor", "ceil", "round",
    "and", "or", "xor", "not", "select_n", "clamp", "nextafter",
}

_SUBJAXPR_PARAMS = ("jaxpr", "call_jaxpr", "fun_jaxpr", "cond_jaxpr")


@dataclasses.dataclass
class Cost:
    matmul_flops: float = 0.0
    elementwise_flops: float = 0.0
    bytes: float = 0.0          # pre-fusion tensor traffic (upper bound)

    @property
    def flops(self) -> float:
        return self.matmul_flops + self.elementwise_flops

    def add(self, other: "Cost", mult: float = 1.0) -> None:
        self.matmul_flops += other.matmul_flops * mult
        self.elementwise_flops += other.elementwise_flops * mult
        self.bytes += other.bytes * mult

    def as_dict(self) -> dict:
        return {"matmul_flops": self.matmul_flops,
                "elementwise_flops": self.elementwise_flops,
                "flops": self.flops, "bytes": self.bytes}


def _aval_bytes(v) -> float:
    aval = v.aval
    if not hasattr(aval, "shape"):
        return 0.0
    return float(np.prod(aval.shape, dtype=np.float64) or 1.0) * \
        np.dtype(aval.dtype).itemsize


def _out_elems(eqn) -> float:
    return float(np.prod(eqn.outvars[0].aval.shape, dtype=np.float64) or 1.0)


def _count_jaxpr(jaxpr, cost: Cost, mult: float) -> None:
    for eqn in jaxpr.eqns:
        name = eqn.primitive.name
        if name == "dot_general":
            (lc, _), _ = eqn.params["dimension_numbers"]
            lhs = eqn.invars[0].aval.shape
            k = 1.0
            for d in lc:
                k *= lhs[d]
            cost.matmul_flops += 2.0 * _out_elems(eqn) * k * mult
            cost.bytes += sum(map(_aval_bytes, (*eqn.invars, *eqn.outvars))) * mult
        elif name == "scan":
            inner = Cost()
            _count_jaxpr(eqn.params["jaxpr"].jaxpr, inner, 1.0)
            cost.add(inner, mult * eqn.params["length"])
        elif name == "while":
            inner = Cost()
            _count_jaxpr(eqn.params["body_jaxpr"].jaxpr, inner, 1.0)
            cost.add(inner, mult)  # trip count unknown at jaxpr level
        elif name == "cond":
            branches = [Cost() for _ in eqn.params["branches"]]
            for br, c in zip(eqn.params["branches"], branches):
                _count_jaxpr(br.jaxpr, c, 1.0)
            worst = max(branches, key=lambda c: c.flops + c.bytes)
            cost.add(worst, mult)
        elif any(p in eqn.params for p in _SUBJAXPR_PARAMS):
            for p in _SUBJAXPR_PARAMS:
                if p in eqn.params:
                    sub = eqn.params[p]
                    sub = sub.jaxpr if hasattr(sub, "jaxpr") else sub
                    _count_jaxpr(sub, cost, mult)
                    break
        elif name in _ELEMENTWISE:
            cost.elementwise_flops += _out_elems(eqn) * mult
            cost.bytes += sum(map(_aval_bytes, (*eqn.invars, *eqn.outvars))) * mult
        else:
            # data movement primitives: count traffic only
            cost.bytes += sum(map(_aval_bytes, eqn.outvars)) * mult


def jaxpr_cost(fn, *abstract_args) -> dict:
    """Trace ``fn`` and count global FLOPs/bytes with scan multipliers."""
    closed = jax.make_jaxpr(fn)(*abstract_args)
    cost = Cost()
    _count_jaxpr(closed.jaxpr, cost, 1.0)
    return cost.as_dict()


# ---------------------------------------------------------------------------
# HLO-level collectives with while trip counts
# ---------------------------------------------------------------------------

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1, "c64": 8, "c128": 16}

COLLECTIVE_OPS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                  "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COMP_HDR = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->.*\{")
_WHILE_RE = re.compile(
    r"while\(.*?\), condition=%?([\w.\-]+), body=%?([\w.\-]+)")
_CALLS_RE = re.compile(r"calls=%?([\w.\-]+)")
_BRANCHES_RE = re.compile(
    r"(?:branch_computations|true_computation|false_computation)="
    r"\{?%?([\w.\-, %]+)\}?")
_CONST_RE = re.compile(r"constant\((\d+)\)")
_COLL_RE = re.compile(
    r"= (.+?) (" + "|".join(COLLECTIVE_OPS) + r")(-start|-done)?\(")


def _shape_bytes(type_str: str) -> float:
    total = 0.0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1.0
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _split_computations(hlo: str) -> dict[str, list[str]]:
    comps: dict[str, list[str]] = {}
    cur = None
    for line in hlo.splitlines():
        m = _COMP_HDR.match(line.strip())
        if m:
            cur = m.group(2)
            comps[cur] = []
            if m.group(1):
                comps["__entry__"] = comps[cur]
        elif cur is not None:
            if line.strip() == "}":
                cur = None
            else:
                comps[cur].append(line.strip())
    return comps


def _trip_count(cond_lines: list[str]) -> int:
    """Loop bound from the condition computation (max compared constant)."""
    consts = []
    for line in cond_lines:
        consts += [int(x) for x in _CONST_RE.findall(line)]
    return max(consts) if consts else 1


def hlo_collectives(hlo_text: str) -> dict:
    """Per-device collective bytes with while-loop multipliers."""
    comps = _split_computations(hlo_text)

    entries: list = []

    def walk(name: str, mult: float, acc, counts, seen: tuple) -> None:
        if name not in comps or name in seen:
            return
        seen = seen + (name,)
        for line in comps[name]:
            cm = _COLL_RE.search(line)
            if cm and cm.group(3) != "-done":
                nbytes = _shape_bytes(cm.group(1)) * mult
                acc[cm.group(2)] += nbytes
                counts[cm.group(2)] += mult
                entries.append({"op": cm.group(2), "shape": cm.group(1)[:120],
                                "mult": mult, "bytes": nbytes})
            wm = _WHILE_RE.search(line)
            if wm:
                cond, body = wm.group(1), wm.group(2)
                trips = _trip_count(comps.get(cond, []))
                walk(body, mult * trips, acc, counts, seen)
                continue
            bm = _BRANCHES_RE.search(line)
            if bm:
                # conservative: every listed branch at full multiplicity is
                # too much; take the heaviest branch
                branch_names = [b.strip().lstrip("%")
                                for b in bm.group(1).split(",")]
                best: Any = None
                for b in branch_names:
                    a2 = collections.defaultdict(float)
                    c2 = collections.defaultdict(float)
                    walk(b, mult, a2, c2, seen)
                    if best is None or sum(a2.values()) > sum(best[0].values()):
                        best = (a2, c2)
                if best:
                    for k, v in best[0].items():
                        acc[k] += v
                    for k, v in best[1].items():
                        counts[k] += v
                continue
            fm = _CALLS_RE.search(line)
            if fm:
                walk(fm.group(1), mult, acc, counts, seen)

    acc: Any = collections.defaultdict(float)
    counts: Any = collections.defaultdict(float)
    walk("__entry__", 1.0, acc, counts, seen=())
    entries.sort(key=lambda e: -e["bytes"])
    return {"bytes": dict(acc), "counts": dict(counts),
            "total_bytes": float(sum(acc.values())),
            "top": entries[:20]}


# ---------------------------------------------------------------------------
# per-tier TTFT / inter-token SLO accounting (async request frontier)
# ---------------------------------------------------------------------------

TIERS = ("latency", "throughput")


@dataclasses.dataclass
class _RequestClock:
    """One request's latency ledger on the frontier."""

    tier: str
    submit_t: float
    ttft_s: Optional[float] = None     # submit -> first emitted token
    last_t: Optional[float] = None     # last time the output grew
    n_out: int = 0
    gaps: List[float] = dataclasses.field(default_factory=list)
    done: bool = False


class TierAccounting:
    """Per-tier TTFT and inter-token SLOs over the async frontier.

    ``arrive`` stamps a request's submit time; ``observe`` is called at
    every host sync with the request's current output length — the first
    growth records TTFT, and a growth of ``k`` tokens after a gap of
    ``dt`` records ``k`` inter-token intervals of ``dt / k`` (a chunked
    tick delivers several tokens per sync; attributing the whole gap to
    the last one would overstate p99 by the chunk width).  All clocks are
    host wall time; pass ``now`` explicitly for deterministic tests.

    The tier is pure host-side scheduling metadata (``Request.tier``):
    nothing here ever reaches a traced tick, which is what keeps the
    tiered engine token-exact vs the untiered oracle by construction.
    """

    def __init__(self):
        self._clocks: Dict[int, _RequestClock] = {}

    def __contains__(self, rid: int) -> bool:
        return rid in self._clocks

    def __len__(self) -> int:
        return len(self._clocks)

    def arrive(self, rid: int, tier: str,
               now: Optional[float] = None) -> None:
        if tier not in TIERS:
            raise ValueError(f"request {rid}: unknown tier {tier!r} "
                             f"(expected one of {TIERS})")
        self._clocks[rid] = _RequestClock(
            tier=tier, submit_t=time.perf_counter() if now is None else now)

    def observe(self, rid: int, n_out: int,
                now: Optional[float] = None) -> None:
        clk = self._clocks.get(rid)
        if clk is None or clk.done:
            return
        k = n_out - clk.n_out
        if k <= 0:
            return
        t = time.perf_counter() if now is None else now
        if clk.ttft_s is None:
            clk.ttft_s = t - clk.submit_t
            k -= 1                      # the first token is TTFT, not a gap
            clk.last_t = t              # same-sync siblings get zero gaps
        if k > 0 and clk.last_t is not None:
            clk.gaps.extend([(t - clk.last_t) / k] * k)
        clk.last_t = t
        clk.n_out = n_out

    def finish(self, rid: int) -> None:
        clk = self._clocks.get(rid)
        if clk is not None:
            clk.done = True

    @staticmethod
    def _pct(xs: List[float], q: float) -> Optional[float]:
        return float(np.percentile(np.asarray(xs), q)) if xs else None

    def report(self) -> dict:
        """Per-tier SLO summary over every tracked request (in-flight
        requests contribute what they have measured so far)."""
        out: dict = {}
        for tier in TIERS:
            clocks = [c for c in self._clocks.values() if c.tier == tier]
            ttfts = [c.ttft_s for c in clocks if c.ttft_s is not None]
            gaps = [g for c in clocks for g in c.gaps]
            out[tier] = {
                "n": len(clocks),
                "finished": sum(c.done for c in clocks),
                "ttft_p50": self._pct(ttfts, 50),
                "ttft_p99": self._pct(ttfts, 99),
                "inter_token_p50": self._pct(gaps, 50),
                "inter_token_p99": self._pct(gaps, 99),
            }
        return out
