"""Deterministic fault injection: chaos as a first-class supervisor input.

The paper's supervisor exists so a core that "overheats" can be withdrawn
and its job reassigned without the caller noticing (§4.1.2, preallocated
spares §5.1); the follow-up EMPA paper (2006.00532) makes that
supervisor-mediated reassignment the defining operation of the model.
To *test* that story end to end the fleet needs faults on demand — this
module is the chaos counterpart of the static auditor's known-bad
fixtures (PR 8): a seeded, replayable :class:`FaultPlan` that injects

* ``tick_exception``  — the serving tick raises mid-run,
* ``nan_poison``      — the replica's KV cache floats are NaN'd and the
  corruption surfaces at the next host sync (see below),
* ``hang``            — the tick sleeps past the fleet's deadline clock,
* ``ledger_corruption`` — a forged bit in the host slot-pool ledger (the
  exact class of corruption ``pool.check_invariants`` exists to catch),

into a chosen replica at a chosen tick.  Every event is host-side: the
hooks run between jitted ticks, never inside one — a compiled tick must
not branch on "is a fault armed" (the lint rule ``lint/fault-hook``
enforces exactly that, the L3 tracer-branch discipline extended to the
fault layer).

**How NaN poisoning surfaces.**  The serving engine's one budgeted host
sync per tick carries int32 token buffers, so a float NaN in the cache
reaches the host as *wrong tokens*, not as a NaN bit pattern.  The
injector therefore does both halves of the real failure: it NaNs every
float leaf of the device cache (any path reading the cache is genuinely
corrupted from that tick on) and marks the next synced emitted row with
:data:`POISON_TOKEN` — the out-of-range bit pattern a corrupted forward
presents at an integer boundary — which the engine's
``validate_outputs`` tripwire catches with slot/tick attribution, with
no device sync added.  Migration then replays from the *host-side* token
history, which the poison never touched.
"""
from __future__ import annotations

import dataclasses
from typing import Iterable, Optional, Sequence

import jax
import numpy as np

from repro.runtime import pool as pool_lib

KINDS = ("tick_exception", "nan_poison", "hang", "ledger_corruption")

# the "NaN at an int32 boundary" sentinel: far outside any vocabulary,
# so the range tripwire cannot mistake it for a real token
POISON_TOKEN = int(np.iinfo(np.int32).min)


class InjectedFault(RuntimeError):
    """Raised by an armed ``tick_exception`` event (and nothing else):
    chaos tests can tell an injected crash from a genuine engine bug."""


@dataclasses.dataclass(frozen=True)
class FaultEvent:
    """One fault, aimed at one replica's tick clock.

    ``tick`` counts the target replica's *host steps* (serving ticks)
    since the plan was armed — deterministic under greedy decoding, so
    the same plan replays the same failure every run.
    """
    kind: str
    tick: int
    replica: int = 0
    hang_s: float = 0.0      # only meaningful for kind == "hang"

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(
                f"unknown fault kind {self.kind!r}; known: {KINDS}")
        if self.tick < 0:
            raise ValueError(f"fault tick must be >= 0, got {self.tick}")
        if self.kind == "hang" and self.hang_s <= 0:
            raise ValueError("hang events need hang_s > 0")


class ReplicaFaults:
    """The slice of a plan aimed at one replica: what an engine arms.

    ``due(step)`` pops (fire-once) every event scheduled at or before
    ``step`` — a replica that ticks past a scheduled point (it was idle
    when the tick number came up) still fires the fault on its next
    real tick, keeping schedules robust to routing choices.
    """

    def __init__(self, events: Sequence[FaultEvent]):
        self._events = sorted(events, key=lambda e: e.tick)

    def __bool__(self) -> bool:
        return bool(self._events)

    def __len__(self) -> int:
        return len(self._events)

    def due(self, step: int) -> list[FaultEvent]:
        fired = [e for e in self._events if e.tick <= step]
        if fired:
            self._events = [e for e in self._events if e.tick > step]
        return fired


class FaultPlan:
    """A deterministic, replayable fault schedule for a serving fleet."""

    def __init__(self, events: Iterable[FaultEvent] = ()):
        self.events = tuple(events)
        for e in self.events:
            if not isinstance(e, FaultEvent):
                raise TypeError(f"FaultPlan takes FaultEvents, got {e!r}")

    @classmethod
    def seeded(cls, seed: int, *, n_replicas: int, max_tick: int,
               kinds: Sequence[str] = KINDS, n_events: int = 1,
               hang_s: float = 0.25) -> "FaultPlan":
        """Sample a schedule from a seed: same seed, same chaos."""
        rng = np.random.default_rng(seed)
        events = []
        for _ in range(n_events):
            kind = str(rng.choice(list(kinds)))
            events.append(FaultEvent(
                kind=kind,
                tick=int(rng.integers(0, max(1, max_tick))),
                replica=int(rng.integers(0, max(1, n_replicas))),
                hang_s=hang_s if kind == "hang" else 0.0))
        return cls(events)

    def for_replica(self, replica: int) -> ReplicaFaults:
        return ReplicaFaults(
            [e for e in self.events if e.replica == replica])


# -- the injectors (host-side effectors the engine hook applies) -------------

def poison_cache(cache: dict) -> dict:
    """NaN every float leaf of a serving cache (k/v pages or slots);
    integer bookkeeping (``pos``, block tables) is left intact so the
    corruption is *silent* — exactly the failure shape that makes NaN
    faults dangerous."""
    def nan_like(leaf):
        if hasattr(leaf, "dtype") and np.issubdtype(leaf.dtype, np.floating):
            return (leaf * np.nan).astype(leaf.dtype)
        return leaf
    return jax.tree_util.tree_map(nan_like, cache)


def corrupt_pool_ledger(pool) -> str:
    """Forge one bit in a host `CorePool` ledger so that
    ``pool.check_invariants`` (the health probe) catches it: a rented,
    phased unit is marked free — the "free unit with a phase" violation
    the §4.3 rent/terminate discipline forbids.  Falls back to phasing a
    free unit when nothing is rented.  Returns a description of the
    forgery (for the chaos log)."""
    state = pool.state
    free = np.asarray(state.free).copy()
    phase = np.asarray(state.phase).copy()
    target = np.flatnonzero(~free & (phase != pool_lib.PHASE_IDLE))
    if target.size:
        unit = int(target[0])
        free[unit] = True
        pool.state = state._replace(free=free)
        return f"forged free bit on rented unit {unit}"
    unit = int(np.flatnonzero(free)[0]) if np.any(free) else 0
    phase[unit] = pool_lib.PHASE_DECODE
    pool.state = state._replace(phase=phase)
    return f"forged phase on free unit {unit}"
