"""Cluster supervisor: maps a step's QT graph onto the device mesh.

The runtime-level twin of the paper's SV (§4.1.3): it owns the resources
(the mesh = core pool), binds compile-time parallelization metadata
(logical-axis rules = metainstructions) to physical axes, and plans the
collective schedule (latched parent-child transfers = FSDP all-gathers,
gradient reductions, EP all-to-alls).  Everything it decides is data — the
dry-run prints it, the roofline reads it.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig, ShapeConfig
from repro.core.qt import QT, MassMode, QTGraph
from repro.launch import inputs as inputs_lib
from repro.models import model as model_lib
from repro.optim import adamw
from repro.runtime import serve as serve_lib
from repro.runtime import train as train_lib
from repro.runtime.sharding import ShardingRules


@dataclasses.dataclass
class Plan:
    name: str
    kind: str                    # train | prefill | decode
    step_fn: Callable
    abstract_args: tuple         # ShapeDtypeStruct pytrees
    in_shardings: tuple
    out_shardings: Any
    donate_argnums: tuple
    rules: ShardingRules
    qt_graph: QTGraph
    notes: list[str]


class ClusterSupervisor:
    def __init__(self, mesh: Mesh, cfg: ArchConfig, shape: ShapeConfig, *,
                 n_microbatch: Optional[int] = None,
                 opt_cfg: Optional[adamw.AdamWConfig] = None,
                 dtype=jnp.bfloat16,
                 rules: Optional[ShardingRules] = None,
                 gather_once: bool = False,
                 remat: bool | str = True):
        self.mesh, self.cfg, self.shape = mesh, cfg, shape
        self.dtype = dtype
        self.rules = rules or ShardingRules(mesh)
        self.opt_cfg = opt_cfg or adamw.AdamWConfig()
        self.gather_once = gather_once
        self.remat = remat
        if n_microbatch is None:
            # FOR-mode default: keep per-microbatch global batch at 32 rows.
            # Archs whose head count doesn't divide the model axis carry
            # replicated attention activations — halve the microbatch so the
            # per-device transients fit v5e HBM (measured: starcoder2-7b
            # needs 16 microbatches to stay under 16 GB; §Perf notes).
            rows = 32
            n_microbatch = max(1, shape.global_batch // rows) \
                if shape.kind == "train" else 1
        self.n_microbatch = n_microbatch

    # -- helpers -----------------------------------------------------------
    def _sh(self, spec_tree):
        return jax.tree_util.tree_map(
            lambda s: NamedSharding(self.mesh, s), spec_tree,
            is_leaf=lambda x: isinstance(x, P))

    def _batch_specs(self, with_labels: bool):
        ax = inputs_lib.batch_axes(self.cfg, self.shape,
                                   with_labels=with_labels)
        batch = inputs_lib.batch_inputs(self.cfg, self.shape,
                                        with_labels=with_labels,
                                        dtype=self.dtype)
        return {k: self.rules.spec(ax[k], batch[k].shape) for k in batch}, batch

    def _cache_specs(self, cache, paged: bool = False):
        ax = inputs_lib.cache_axes(self.cfg, paged=paged)
        return jax.tree_util.tree_map(
            lambda leaf_ax, leaf: self.rules.spec(leaf_ax, leaf.shape),
            ax, {k: cache[k] for k in ax},
            is_leaf=lambda x: isinstance(x, tuple))

    # -- plans ---------------------------------------------------------------
    def plan(self) -> Plan:
        return {"train": self.plan_train,
                "prefill": self.plan_prefill,
                "decode": self.plan_decode,
                "serve": self.plan_serve}[self.shape.kind]()

    def plan_train(self) -> Plan:
        cfg, shape = self.cfg, self.shape
        step = train_lib.build_train_step(
            cfg, self.opt_cfg, n_microbatch=self.n_microbatch,
            rules=self.rules, gather_once=self.gather_once,
            remat=self.remat)
        state = train_lib.abstract_state(cfg, self.dtype)
        sspec = train_lib.state_specs(cfg, self.rules)
        bspec, batch = self._batch_specs(with_labels=True)
        metrics_spec = {"loss": P(), "grad_norm": P(), "lr": P()}
        return Plan(
            name=f"{cfg.name}/{shape.name}", kind="train", step_fn=step,
            abstract_args=(state, batch),
            in_shardings=(self._sh(sspec), self._sh(bspec)),
            out_shardings=(self._sh(sspec), self._sh(metrics_spec)),
            donate_argnums=(0,), rules=self.rules,
            qt_graph=self.qt_graph(), notes=self._notes())

    def plan_prefill(self) -> Plan:
        cfg, shape = self.cfg, self.shape
        step = serve_lib.build_prefill_step(cfg, shape.seq_len, self.rules)
        params = model_lib.abstract(cfg, self.dtype)
        pspec = train_lib.state_specs(cfg, self.rules)["params"]
        bspec, batch = self._batch_specs(with_labels=False)
        _, cache = inputs_lib.decode_inputs(cfg, shape, self.dtype)
        cspec = self._cache_specs(cache)
        logits_spec = self.rules.spec(("batch", "vocab_act"),
                                      (shape.global_batch, cfg.vocab))
        return Plan(
            name=f"{cfg.name}/{shape.name}", kind="prefill", step_fn=step,
            abstract_args=(params, batch),
            in_shardings=(self._sh(pspec), self._sh(bspec)),
            out_shardings=(self._sh(logits_spec), self._sh(cspec)),
            donate_argnums=(), rules=self.rules,
            qt_graph=self.qt_graph(), notes=self._notes())

    def plan_decode(self) -> Plan:
        cfg, shape = self.cfg, self.shape
        step = serve_lib.build_decode_step(cfg, self.rules)
        params = model_lib.abstract(cfg, self.dtype)
        pspec = train_lib.state_specs(cfg, self.rules)["params"]
        token, cache = inputs_lib.decode_inputs(cfg, shape, self.dtype)
        cspec = self._cache_specs(cache)
        tspec = self.rules.spec(("cache_batch",), (shape.global_batch,))
        logits_spec = self.rules.spec(("cache_batch", "vocab_act"),
                                      (shape.global_batch, cfg.vocab))
        return Plan(
            name=f"{cfg.name}/{shape.name}", kind="decode", step_fn=step,
            abstract_args=(params, token, cache),
            in_shardings=(self._sh(pspec), self._sh(tspec), self._sh(cspec)),
            out_shardings=(self._sh(logits_spec), self._sh(cspec)),
            donate_argnums=(2,),   # the cache is updated in place
            rules=self.rules, qt_graph=self.qt_graph(), notes=self._notes())

    def plan_serve(self, *, chunk: int = 8, eos_id: int = 1,
                   paged: Optional[model_lib.PagedLayout] = None,
                   speculative: Optional[int] = None,
                   spec_hist: int = 64,
                   overcommit: Optional[int] = None) -> Plan:
        """The device-resident continuous-batching tick (serve_lib): one
        jitted chunk advances every slot up to `chunk` tokens with the
        supervisor state (active mask, budgets) resident on device.  The
        cache is donated — decode streams in place.

        With ``paged`` given, the tick also carries the donated block
        pool state and grows block chains on device: the step signature
        becomes (params, state, cache, bstate) and the cache holds pages
        plus per-slot block tables (see `_cache_specs(paged=True)`).

        With ``speculative`` given (the draft length ``spec_k``), the
        lowered step is the **speculative verify tick**
        (`serve_lib.build_spec_tick`): drafter state rides along
        (donated, per-slot sharded like the decode state) and the step
        consumes per-slot fragment inputs, emitting up to ``spec_k + 1``
        tokens per slot per forward.

        With ``overcommit`` given (the fragment width, tokens), the
        lowered step is the **eviction-aware unified prefill/decode
        tick** (`serve_lib.build_mixed_tick`) the over-commit engine
        drives between evictions and resumes: every slot advances one
        fragment or one token per call, and the parked-request replay
        rides the same fragment inputs.  Speculative takes precedence —
        the spec tick already composes with fragments."""
        cfg, shape = self.cfg, self.shape
        n_slots = shape.global_batch
        if speculative is not None:
            return self._plan_serve_spec(spec_k=speculative,
                                         spec_hist=spec_hist,
                                         eos_id=eos_id, paged=paged)
        if overcommit is not None:
            return self._plan_serve_overcommit(chunk_tokens=overcommit,
                                               eos_id=eos_id, paged=paged)
        step = serve_lib.build_decode_chunk(
            cfg, chunk=chunk, eos_id=eos_id, rules=self.rules, jit=False,
            paged=paged)
        params = model_lib.abstract(cfg, self.dtype)
        pspec = train_lib.state_specs(cfg, self.rules)["params"]
        state = serve_lib.abstract_decode_state(n_slots)
        slot_spec = self.rules.spec(("cache_batch",), (n_slots,))
        sspec = serve_lib.DecodeState(*([slot_spec] * len(state)))
        cache = model_lib.init_cache(cfg, n_slots, shape.seq_len,
                                     dtype=self.dtype, abstract_only=True,
                                     layout=paged)
        cspec = self._cache_specs(cache, paged=paged is not None)
        emitted_spec = self.rules.spec(("cache_batch", None),
                                       (n_slots, chunk))
        abstract_args = [params, state, cache]
        in_sh = [self._sh(pspec), self._sh(sspec), self._sh(cspec)]
        out_sh = [self._sh(sspec), self._sh(cspec)]
        donate = (2,)                   # decode streams the cache in place
        if paged is not None:
            from repro.runtime import paging
            bstate = paging.abstract_blocks(paged.n_blocks)
            # block pool state is supervisor bookkeeping: replicated
            bspec = jax.tree_util.tree_map(lambda _: P(), bstate)
            abstract_args.append(bstate)
            in_sh.append(self._sh(bspec))
            out_sh.append(self._sh(bspec))
            donate = (2, 3)             # ... and the block pool with it
        out_sh += [self._sh(emitted_spec), self._sh(P())]
        if paged is not None:
            out_sh.append(self._sh(P()))     # stall counter
        return Plan(
            name=f"{cfg.name}/{shape.name}", kind="serve", step_fn=step,
            abstract_args=tuple(abstract_args),
            in_shardings=tuple(in_sh),
            out_shardings=tuple(out_sh),
            donate_argnums=donate,
            rules=self.rules, qt_graph=self.qt_graph(), notes=self._notes())

    def _plan_serve_overcommit(self, *, chunk_tokens: int, eos_id: int,
                               paged: Optional[model_lib.PagedLayout]
                               ) -> Plan:
        """Lower the eviction-aware mixed tick with explicit shardings:
        per-slot fragment inputs (sharded like the decode state), the
        cache — and, paged, the block pool plus the chunk-granular rent
        commits — donated.  Eviction and resume themselves are host
        supervisor actions between ticks (`ServingEngine.preempt` /
        `_resume_parked`); the device step they bracket is this one."""
        cfg, shape = self.cfg, self.shape
        n_slots = shape.global_batch
        c = chunk_tokens
        step = serve_lib.build_mixed_tick(
            cfg, chunk_tokens=c, eos_id=eos_id, rules=self.rules,
            jit=False, paged=paged)
        params = model_lib.abstract(cfg, self.dtype)
        pspec = train_lib.state_specs(cfg, self.rules)["params"]
        state = serve_lib.abstract_decode_state(n_slots)
        slot_spec = self.rules.spec(("cache_batch",), (n_slots,))
        sspec = serve_lib.DecodeState(*([slot_spec] * len(state)))
        cache = model_lib.init_cache(cfg, n_slots, shape.seq_len,
                                     dtype=self.dtype, abstract_only=True,
                                     layout=paged)
        cspec = self._cache_specs(cache, paged=paged is not None)
        row_spec = self.rules.spec(("cache_batch", None), (n_slots, c))
        i32 = lambda s: jax.ShapeDtypeStruct(s, jnp.int32)  # noqa: E731
        frag = [i32((n_slots, c)), i32((n_slots,)),
                jax.ShapeDtypeStruct((n_slots,), jnp.bool_),
                i32((n_slots,))]
        frag_sh = [row_spec, slot_spec, slot_spec, slot_spec]
        abstract_args = [params, state, cache]
        in_sh = [self._sh(pspec), self._sh(sspec), self._sh(cspec)]
        out_sh = [self._sh(sspec), self._sh(cspec)]
        donate = (2,)                   # the cache ticks in place
        if paged is not None:
            from repro.runtime import paging
            bstate = paging.abstract_blocks(paged.n_blocks)
            bspec = jax.tree_util.tree_map(lambda _: P(), bstate)
            abstract_args.append(bstate)
            in_sh.append(self._sh(bspec))
            out_sh.append(self._sh(bspec))
            donate = (2, 3)             # ... and the block pool with it
            k = c // paged.block_size + 2
            rowk = self.rules.spec(("cache_batch", None), (n_slots, k))
            frag += [i32((n_slots,)), i32((n_slots, k)), i32((n_slots, k))]
            frag_sh += [slot_spec, rowk, rowk]
        abstract_args += frag
        in_sh += [self._sh(s) for s in frag_sh]
        emitted_spec = self.rules.spec(("cache_batch", None), (n_slots, 1))
        out_sh.append(self._sh(emitted_spec))
        if paged is not None:
            out_sh.append(self._sh(P()))     # stall counter
        return Plan(
            name=f"{cfg.name}/{shape.name}", kind="serve", step_fn=step,
            abstract_args=tuple(abstract_args),
            in_shardings=tuple(in_sh),
            out_shardings=tuple(out_sh),
            donate_argnums=donate,
            rules=self.rules, qt_graph=self.qt_graph(), notes=self._notes())

    def _plan_serve_spec(self, *, spec_k: int, spec_hist: int, eos_id: int,
                         paged: Optional[model_lib.PagedLayout]) -> Plan:
        """Lower the speculative verify tick with explicit shardings:
        drafter history is per-slot state (sharded like the decode
        state), the cache — and, paged, the block pool — is donated."""
        from repro.runtime import draft as draft_lib

        cfg, shape = self.cfg, self.shape
        n_slots = shape.global_batch
        w = spec_k + 1
        step = serve_lib.build_spec_tick(
            cfg, spec_k=spec_k, chunk_tokens=w, eos_id=eos_id,
            rules=self.rules, jit=False, paged=paged)
        params = model_lib.abstract(cfg, self.dtype)
        pspec = train_lib.state_specs(cfg, self.rules)["params"]
        state = serve_lib.abstract_decode_state(n_slots)
        slot_spec = self.rules.spec(("cache_batch",), (n_slots,))
        sspec = serve_lib.DecodeState(*([slot_spec] * len(state)))
        dstate = draft_lib.abstract_draft_state(n_slots, spec_hist)
        dspec = draft_lib.DraftState(
            hist=self.rules.spec(("cache_batch", None),
                                 (n_slots, spec_hist)),
            count=slot_spec)
        cache = model_lib.init_cache(cfg, n_slots, shape.seq_len,
                                     dtype=self.dtype, abstract_only=True,
                                     layout=paged)
        cspec = self._cache_specs(cache, paged=paged is not None)
        row_spec = self.rules.spec(("cache_batch", None), (n_slots, w))
        i32 = lambda s: jax.ShapeDtypeStruct(s, jnp.int32)  # noqa: E731
        frag = [i32((n_slots, w)), i32((n_slots,)),
                jax.ShapeDtypeStruct((n_slots,), jnp.bool_),
                i32((n_slots,))]
        frag_sh = [row_spec, slot_spec, slot_spec, slot_spec]
        abstract_args = [params, state, dstate, cache]
        in_sh = [self._sh(pspec), self._sh(sspec), self._sh(dspec),
                 self._sh(cspec)]
        out_sh = [self._sh(sspec), self._sh(dspec), self._sh(cspec)]
        donate = (2, 3)      # drafter state + cache stream in place
        if paged is not None:
            from repro.runtime import paging
            bstate = paging.abstract_blocks(paged.n_blocks)
            bspec = jax.tree_util.tree_map(lambda _: P(), bstate)
            abstract_args.append(bstate)
            in_sh.append(self._sh(bspec))
            out_sh.append(self._sh(bspec))
            donate = (2, 3, 4)
            row1 = self.rules.spec(("cache_batch", None), (n_slots, 1))
            frag += [i32((n_slots,)), i32((n_slots, 1)), i32((n_slots, 1))]
            frag_sh += [slot_spec, row1, row1]
        abstract_args += frag
        in_sh += [self._sh(s) for s in frag_sh]
        out_sh += [self._sh(row_spec), self._sh(P()), self._sh(P())]
        if paged is not None:
            out_sh.append(self._sh(P()))     # stall counter
        # shape-dispatch metadata: which attention configuration the
        # fabric runs for this tick's fragment width (the dry-run's
        # answer to "which kernel serves the verify forward?")
        from repro.kernels.chunk_attention import NARROW_MAX_WIDTH
        from repro.models import attention as attn_lib
        sched = "narrow" if w <= NARROW_MAX_WIDTH else "wide"
        ladder = attn_lib.span_ladder(shape.seq_len)
        notes = self._notes() + [
            f"verify_width={w} -> chunk-attention[{sched}] (TPU) / "
            f"span ladder {ladder} (CPU)"]
        return Plan(
            name=f"{cfg.name}/{shape.name}", kind="serve", step_fn=step,
            abstract_args=tuple(abstract_args),
            in_shardings=tuple(in_sh),
            out_shardings=tuple(out_sh),
            donate_argnums=donate,
            rules=self.rules, qt_graph=self.qt_graph(), notes=notes)

    # -- compile-time metadata ------------------------------------------------
    def qt_graph(self) -> QTGraph:
        cfg, shape = self.cfg, self.shape
        tokens = shape.global_batch * shape.seq_len
        n_active = cfg.active_param_count()
        g = QTGraph()
        g.add(QT(f"{shape.kind}_step",
                 flops=model_lib.model_flops(
                     cfg, tokens if shape.kind not in ("decode", "serve")
                     else shape.global_batch, shape.kind)))
        g.add(QT("embed", shard_axis="data",
                 param_bytes=2.0 * cfg.vocab * cfg.d_model),
              parent=f"{shape.kind}_step",
              glue_bytes=2.0 * tokens * cfg.d_model)
        g.add(QT("stack", mode=MassMode.FOR, shard_axis="model",
                 flops=6.0 * n_active * tokens,
                 param_bytes=2.0 * n_active),
              parent=f"{shape.kind}_step",
              glue_bytes=2.0 * tokens * cfg.d_model)
        g.add(QT("head_loss", mode=MassMode.SUMUP, shard_axis="model"),
              parent=f"{shape.kind}_step",
              glue_bytes=2.0 * tokens * cfg.d_model)
        if shape.kind == "train":
            g.add(QT("grad_reduce", mode=MassMode.SUMUP, shard_axis="data",
                     act_bytes=4.0 * n_active),
                  parent=f"{shape.kind}_step", glue_bytes=4.0 * n_active)
            g.add(QT("adamw", shard_axis="data"), parent=f"{shape.kind}_step")
        g.check_invariants()
        return g

    def _notes(self) -> list[str]:
        notes = [f"mesh={dict(self.mesh.shape)}",
                 f"microbatches={self.n_microbatch}",
                 f"gather_once={self.gather_once}", f"remat={self.remat}"]
        return notes
