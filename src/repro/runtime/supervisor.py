"""Cluster supervisor: maps a step's QT graph onto the device mesh.

The runtime-level twin of the paper's SV (§4.1.3): it owns the resources
(the mesh = core pool), binds compile-time parallelization metadata
(logical-axis rules = metainstructions) to physical axes, and plans the
collective schedule (latched parent-child transfers = FSDP all-gathers,
gradient reductions, EP all-to-alls).  Everything it decides is data — the
dry-run prints it, the roofline reads it.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig, ShapeConfig
from repro.core.qt import QT, MassMode, QTGraph
from repro.core.supervisor import CorePool
from repro.launch import inputs as inputs_lib
from repro.models import model as model_lib
from repro.optim import adamw
from repro.runtime import pool as pool_lib
from repro.runtime import serve as serve_lib
from repro.runtime import train as train_lib
from repro.runtime.elastic import Event
from repro.runtime.sharding import ShardingRules, fleet_submeshes, serve_mesh


@dataclasses.dataclass
class Plan:
    name: str
    kind: str                    # train | prefill | decode
    step_fn: Callable
    abstract_args: tuple         # ShapeDtypeStruct pytrees
    in_shardings: tuple
    out_shardings: Any
    donate_argnums: tuple
    rules: ShardingRules
    qt_graph: QTGraph
    notes: list[str]


class ClusterSupervisor:
    def __init__(self, mesh: Mesh, cfg: ArchConfig, shape: ShapeConfig, *,
                 n_microbatch: Optional[int] = None,
                 opt_cfg: Optional[adamw.AdamWConfig] = None,
                 dtype=jnp.bfloat16,
                 rules: Optional[ShardingRules] = None,
                 gather_once: bool = False,
                 remat: bool | str = True):
        self.mesh, self.cfg, self.shape = mesh, cfg, shape
        self.dtype = dtype
        self.rules = rules or ShardingRules(mesh)
        self.opt_cfg = opt_cfg or adamw.AdamWConfig()
        self.gather_once = gather_once
        self.remat = remat
        if n_microbatch is None:
            # FOR-mode default: keep per-microbatch global batch at 32 rows.
            # Archs whose head count doesn't divide the model axis carry
            # replicated attention activations — halve the microbatch so the
            # per-device transients fit v5e HBM (measured: starcoder2-7b
            # needs 16 microbatches to stay under 16 GB; §Perf notes).
            rows = 32
            n_microbatch = max(1, shape.global_batch // rows) \
                if shape.kind == "train" else 1
        self.n_microbatch = n_microbatch

    # -- helpers -----------------------------------------------------------
    def _sh(self, spec_tree):
        return jax.tree_util.tree_map(
            lambda s: NamedSharding(self.mesh, s), spec_tree,
            is_leaf=lambda x: isinstance(x, P))

    def _batch_specs(self, with_labels: bool):
        ax = inputs_lib.batch_axes(self.cfg, self.shape,
                                   with_labels=with_labels)
        batch = inputs_lib.batch_inputs(self.cfg, self.shape,
                                        with_labels=with_labels,
                                        dtype=self.dtype)
        return {k: self.rules.spec(ax[k], batch[k].shape) for k in batch}, batch

    def _cache_specs(self, cache, paged: bool = False):
        ax = inputs_lib.cache_axes(self.cfg, paged=paged)
        return jax.tree_util.tree_map(
            lambda leaf_ax, leaf: self.rules.spec(leaf_ax, leaf.shape),
            ax, {k: cache[k] for k in ax},
            is_leaf=lambda x: isinstance(x, tuple))

    # -- plans ---------------------------------------------------------------
    def plan(self) -> Plan:
        return {"train": self.plan_train,
                "prefill": self.plan_prefill,
                "decode": self.plan_decode,
                "serve": self.plan_serve}[self.shape.kind]()

    def plan_train(self) -> Plan:
        cfg, shape = self.cfg, self.shape
        step = train_lib.build_train_step(
            cfg, self.opt_cfg, n_microbatch=self.n_microbatch,
            rules=self.rules, gather_once=self.gather_once,
            remat=self.remat)
        state = train_lib.abstract_state(cfg, self.dtype)
        sspec = train_lib.state_specs(cfg, self.rules)
        bspec, batch = self._batch_specs(with_labels=True)
        metrics_spec = {"loss": P(), "grad_norm": P(), "lr": P()}
        return Plan(
            name=f"{cfg.name}/{shape.name}", kind="train", step_fn=step,
            abstract_args=(state, batch),
            in_shardings=(self._sh(sspec), self._sh(bspec)),
            out_shardings=(self._sh(sspec), self._sh(metrics_spec)),
            donate_argnums=(0,), rules=self.rules,
            qt_graph=self.qt_graph(), notes=self._notes())

    def plan_prefill(self) -> Plan:
        cfg, shape = self.cfg, self.shape
        step = serve_lib.build_prefill_step(cfg, shape.seq_len, self.rules)
        params = model_lib.abstract(cfg, self.dtype)
        pspec = train_lib.state_specs(cfg, self.rules)["params"]
        bspec, batch = self._batch_specs(with_labels=False)
        _, cache = inputs_lib.decode_inputs(cfg, shape, self.dtype)
        cspec = self._cache_specs(cache)
        logits_spec = self.rules.spec(("batch", "vocab_act"),
                                      (shape.global_batch, cfg.vocab))
        return Plan(
            name=f"{cfg.name}/{shape.name}", kind="prefill", step_fn=step,
            abstract_args=(params, batch),
            in_shardings=(self._sh(pspec), self._sh(bspec)),
            out_shardings=(self._sh(logits_spec), self._sh(cspec)),
            donate_argnums=(), rules=self.rules,
            qt_graph=self.qt_graph(), notes=self._notes())

    def plan_decode(self) -> Plan:
        cfg, shape = self.cfg, self.shape
        step = serve_lib.build_decode_step(cfg, self.rules)
        params = model_lib.abstract(cfg, self.dtype)
        pspec = train_lib.state_specs(cfg, self.rules)["params"]
        token, cache = inputs_lib.decode_inputs(cfg, shape, self.dtype)
        cspec = self._cache_specs(cache)
        tspec = self.rules.spec(("cache_batch",), (shape.global_batch,))
        logits_spec = self.rules.spec(("cache_batch", "vocab_act"),
                                      (shape.global_batch, cfg.vocab))
        return Plan(
            name=f"{cfg.name}/{shape.name}", kind="decode", step_fn=step,
            abstract_args=(params, token, cache),
            in_shardings=(self._sh(pspec), self._sh(tspec), self._sh(cspec)),
            out_shardings=(self._sh(logits_spec), self._sh(cspec)),
            donate_argnums=(2,),   # the cache is updated in place
            rules=self.rules, qt_graph=self.qt_graph(), notes=self._notes())

    def plan_serve(self, *, chunk: int = 8, eos_id: int = 1,
                   paged: Optional[model_lib.PagedLayout] = None,
                   speculative: Optional[int] = None,
                   spec_hist: int = 64,
                   overcommit: Optional[int] = None,
                   chunked: Optional[int] = None,
                   solo_prefill: Optional[int] = None,
                   mesh: Optional[Mesh] = None) -> Plan:
        """The device-resident continuous-batching tick (serve_lib): one
        jitted chunk advances every slot up to `chunk` tokens with the
        supervisor state (active mask, budgets) resident on device.  The
        cache is donated — decode streams in place.

        With ``paged`` given, the tick also carries the donated block
        pool state and grows block chains on device: the step signature
        becomes (params, state, cache, bstate) and the cache holds pages
        plus per-slot block tables (see `_cache_specs(paged=True)`).

        With ``speculative`` given (the draft length ``spec_k``), the
        lowered step is the **speculative verify tick**
        (`serve_lib.build_spec_tick`): drafter state rides along
        (donated, per-slot sharded like the decode state) and the step
        consumes per-slot fragment inputs, emitting up to ``spec_k + 1``
        tokens per slot per forward.

        With ``overcommit`` given (the fragment width, tokens), the
        lowered step is the **eviction-aware unified prefill/decode
        tick** (`serve_lib.build_mixed_tick`) the over-commit engine
        drives between evictions and resumes: every slot advances one
        fragment or one token per call, and the parked-request replay
        rides the same fragment inputs.  Speculative takes precedence —
        the spec tick already composes with fragments.

        ``chunked`` (the fragment width) lowers the same mixed tick for
        the chunked-prefill family *without* over-commit — the device
        step is identical, only the host admission policy differs — and
        ``solo_prefill`` (the packed fragment width) lowers the
        cold-start **solo prefill tick** (`build_solo_prefill_tick`), so
        all five tick families lower through one entry point.

        With ``mesh`` given, the plan lowers for that mesh instead of the
        supervisor's own: fresh `ShardingRules` bind the logical axes to
        it (divisibility fallback per dimension), and every sharding in
        the plan — donated caches included — names the new mesh.  This is
        how a serve tick planned on one device re-plans for a (data,
        model) grid."""
        if mesh is not None and mesh is not self.mesh:
            sub = ClusterSupervisor(mesh, self.cfg, self.shape,
                                    n_microbatch=self.n_microbatch,
                                    opt_cfg=self.opt_cfg, dtype=self.dtype,
                                    gather_once=self.gather_once,
                                    remat=self.remat)
            return sub.plan_serve(chunk=chunk, eos_id=eos_id, paged=paged,
                                  speculative=speculative,
                                  spec_hist=spec_hist, overcommit=overcommit,
                                  chunked=chunked, solo_prefill=solo_prefill)
        cfg, shape = self.cfg, self.shape
        n_slots = shape.global_batch
        if speculative is not None:
            return self._plan_serve_spec(spec_k=speculative,
                                         spec_hist=spec_hist,
                                         eos_id=eos_id, paged=paged)
        if overcommit is not None or chunked is not None:
            return self._plan_serve_mixed(
                chunk_tokens=overcommit if overcommit is not None
                else chunked, eos_id=eos_id, paged=paged)
        if solo_prefill is not None:
            return self._plan_serve_solo(chunk_tokens=solo_prefill,
                                         paged=paged)
        step = serve_lib.build_decode_chunk(
            cfg, chunk=chunk, eos_id=eos_id, rules=self.rules, jit=False,
            paged=paged)
        params = model_lib.abstract(cfg, self.dtype)
        pspec = train_lib.state_specs(cfg, self.rules)["params"]
        state = serve_lib.abstract_decode_state(n_slots)
        slot_spec = self.rules.spec(("cache_batch",), (n_slots,))
        sspec = serve_lib.DecodeState(*([slot_spec] * len(state)))
        cache = model_lib.init_cache(cfg, n_slots, shape.seq_len,
                                     dtype=self.dtype, abstract_only=True,
                                     layout=paged)
        cspec = self._cache_specs(cache, paged=paged is not None)
        emitted_spec = self.rules.spec(("cache_batch", None),
                                       (n_slots, chunk))
        abstract_args = [params, state, cache]
        in_sh = [self._sh(pspec), self._sh(sspec), self._sh(cspec)]
        out_sh = [self._sh(sspec), self._sh(cspec)]
        donate = (2,)                   # decode streams the cache in place
        if paged is not None:
            from repro.runtime import paging
            bstate = paging.abstract_blocks(paged.n_blocks)
            # block pool state is supervisor bookkeeping: replicated
            bspec = jax.tree_util.tree_map(lambda _: P(), bstate)
            abstract_args.append(bstate)
            in_sh.append(self._sh(bspec))
            out_sh.append(self._sh(bspec))
            donate = (2, 3)             # ... and the block pool with it
        out_sh += [self._sh(emitted_spec), self._sh(P())]
        if paged is not None:
            out_sh.append(self._sh(P()))     # stall counter
        return Plan(
            name=f"{cfg.name}/{shape.name}", kind="serve", step_fn=step,
            abstract_args=tuple(abstract_args),
            in_shardings=tuple(in_sh),
            out_shardings=tuple(out_sh),
            donate_argnums=donate,
            rules=self.rules, qt_graph=self.qt_graph(), notes=self._notes())

    def plan_serve_families(self, *, paged: Optional[model_lib.PagedLayout]
                            = None, chunk: int = 8, fragment: int = 8,
                            spec_k: int = 3, eos_id: int = 1,
                            mesh: Optional[Mesh] = None) -> dict:
        """Every serve tick family the repo can build, keyed by name —
        the static auditor's enumeration hook (`repro.analysis.families`
        turns these into lowerable specs and proves donation coverage,
        transfer freedom, bounded retrace keys and constant hygiene on
        each).  The chunked-prefill and over-commit families lower the
        same device step; they are listed separately because their
        donation contracts must hold under *both* host policies and the
        audit report names them the way the engines do."""
        kw = dict(paged=paged, eos_id=eos_id, mesh=mesh)
        return {
            "decode": self.plan_serve(chunk=chunk, **kw),
            "chunked_prefill": self.plan_serve(chunked=fragment, **kw),
            "solo_prefill": self.plan_serve(solo_prefill=fragment, **kw),
            "speculative": self.plan_serve(speculative=spec_k, **kw),
            "overcommit_resume": self.plan_serve(overcommit=fragment, **kw),
        }

    def _plan_serve_mixed(self, *, chunk_tokens: int, eos_id: int,
                          paged: Optional[model_lib.PagedLayout]
                          ) -> Plan:
        """Lower the unified prefill/decode (mixed) tick with explicit
        shardings: per-slot fragment inputs (sharded like the decode
        state), the cache — and, paged, the block pool plus the
        chunk-granular rent commits — donated.  One lowering serves two
        families: chunked prefill and over-commit run the identical
        device step — eviction and resume are host supervisor actions
        between ticks (`ServingEngine.preempt` / `_resume_parked`); the
        device step they bracket is this one."""
        cfg, shape = self.cfg, self.shape
        n_slots = shape.global_batch
        c = chunk_tokens
        step = serve_lib.build_mixed_tick(
            cfg, chunk_tokens=c, eos_id=eos_id, rules=self.rules,
            jit=False, paged=paged)
        params = model_lib.abstract(cfg, self.dtype)
        pspec = train_lib.state_specs(cfg, self.rules)["params"]
        state = serve_lib.abstract_decode_state(n_slots)
        slot_spec = self.rules.spec(("cache_batch",), (n_slots,))
        sspec = serve_lib.DecodeState(*([slot_spec] * len(state)))
        cache = model_lib.init_cache(cfg, n_slots, shape.seq_len,
                                     dtype=self.dtype, abstract_only=True,
                                     layout=paged)
        cspec = self._cache_specs(cache, paged=paged is not None)
        row_spec = self.rules.spec(("cache_batch", None), (n_slots, c))
        i32 = lambda s: jax.ShapeDtypeStruct(s, jnp.int32)  # noqa: E731
        frag = [i32((n_slots, c)), i32((n_slots,)),
                jax.ShapeDtypeStruct((n_slots,), jnp.bool_),
                i32((n_slots,))]
        frag_sh = [row_spec, slot_spec, slot_spec, slot_spec]
        abstract_args = [params, state, cache]
        in_sh = [self._sh(pspec), self._sh(sspec), self._sh(cspec)]
        out_sh = [self._sh(sspec), self._sh(cspec)]
        donate = (2,)                   # the cache ticks in place
        if paged is not None:
            from repro.runtime import paging
            bstate = paging.abstract_blocks(paged.n_blocks)
            bspec = jax.tree_util.tree_map(lambda _: P(), bstate)
            abstract_args.append(bstate)
            in_sh.append(self._sh(bspec))
            out_sh.append(self._sh(bspec))
            donate = (2, 3)             # ... and the block pool with it
            k = c // paged.block_size + 2
            rowk = self.rules.spec(("cache_batch", None), (n_slots, k))
            frag += [i32((n_slots,)), i32((n_slots, k)), i32((n_slots, k))]
            frag_sh += [slot_spec, rowk, rowk]
        abstract_args += frag
        in_sh += [self._sh(s) for s in frag_sh]
        emitted_spec = self.rules.spec(("cache_batch", None), (n_slots, 1))
        out_sh.append(self._sh(emitted_spec))
        if paged is not None:
            out_sh.append(self._sh(P()))     # stall counter
        return Plan(
            name=f"{cfg.name}/{shape.name}", kind="serve", step_fn=step,
            abstract_args=tuple(abstract_args),
            in_shardings=tuple(in_sh),
            out_shardings=tuple(out_sh),
            donate_argnums=donate,
            rules=self.rules, qt_graph=self.qt_graph(), notes=self._notes())

    def _plan_serve_solo(self, *, chunk_tokens: int,
                         paged: Optional[model_lib.PagedLayout]) -> Plan:
        """Lower the cold-start solo prefill tick with explicit
        shardings: ONE job's packed fragments run through a single-row
        `prefill_chunk` against that slot's cache view.  The fragment row
        is replicated (one row cannot shard over data), the cache keeps
        its head-sharded layout — the single-row forward still runs
        tensor-parallel over "model" — and ``slot`` is a traced scalar,
        so one compile covers every slot."""
        cfg, shape = self.cfg, self.shape
        n_slots = shape.global_batch
        W = chunk_tokens
        step = serve_lib.build_solo_prefill_tick(
            cfg, chunk_tokens=W, rules=self.rules, jit=False, paged=paged)
        params = model_lib.abstract(cfg, self.dtype)
        pspec = train_lib.state_specs(cfg, self.rules)["params"]
        state = serve_lib.abstract_decode_state(n_slots)
        slot_spec = self.rules.spec(("cache_batch",), (n_slots,))
        sspec = serve_lib.DecodeState(*([slot_spec] * len(state)))
        cache = model_lib.init_cache(cfg, n_slots, shape.seq_len,
                                     dtype=self.dtype, abstract_only=True,
                                     layout=paged)
        cspec = self._cache_specs(cache, paged=paged is not None)
        i32 = lambda s: jax.ShapeDtypeStruct(s, jnp.int32)  # noqa: E731
        row1 = [i32((1, W)), i32((1,)),
                jax.ShapeDtypeStruct((1,), jnp.bool_), i32((1,))]
        abstract_args = [params, state, cache]
        in_sh = [self._sh(pspec), self._sh(sspec), self._sh(cspec)]
        out_sh = [self._sh(sspec), self._sh(cspec)]
        donate = (2,)
        if paged is not None:
            from repro.runtime import paging
            bstate = paging.abstract_blocks(paged.n_blocks)
            bspec = jax.tree_util.tree_map(lambda _: P(), bstate)
            abstract_args.append(bstate)
            in_sh.append(self._sh(bspec))
            out_sh.append(self._sh(bspec))
            donate = (2, 3)
        abstract_args.append(i32(()))              # slot (traced scalar)
        in_sh.append(self._sh(P()))
        abstract_args += row1
        in_sh += [self._sh(P()) for _ in row1]
        if paged is not None:
            k = W // paged.block_size + 2
            rowk = self.rules.spec(("cache_batch", None), (n_slots, k))
            abstract_args += [i32((1,)), i32((n_slots, k)),
                              i32((n_slots, k))]
            in_sh += [self._sh(P()), self._sh(rowk), self._sh(rowk)]
        out_sh.append(self._sh(P()))               # emitted (1,)
        return Plan(
            name=f"{cfg.name}/{shape.name}", kind="serve", step_fn=step,
            abstract_args=tuple(abstract_args),
            in_shardings=tuple(in_sh),
            out_shardings=tuple(out_sh),
            donate_argnums=donate,
            rules=self.rules, qt_graph=self.qt_graph(), notes=self._notes())

    def _plan_serve_spec(self, *, spec_k: int, spec_hist: int, eos_id: int,
                         paged: Optional[model_lib.PagedLayout]) -> Plan:
        """Lower the speculative verify tick with explicit shardings:
        drafter history is per-slot state (sharded like the decode
        state), the cache — and, paged, the block pool — is donated."""
        from repro.runtime import draft as draft_lib

        cfg, shape = self.cfg, self.shape
        n_slots = shape.global_batch
        w = spec_k + 1
        step = serve_lib.build_spec_tick(
            cfg, spec_k=spec_k, chunk_tokens=w, eos_id=eos_id,
            rules=self.rules, jit=False, paged=paged)
        params = model_lib.abstract(cfg, self.dtype)
        pspec = train_lib.state_specs(cfg, self.rules)["params"]
        state = serve_lib.abstract_decode_state(n_slots)
        slot_spec = self.rules.spec(("cache_batch",), (n_slots,))
        sspec = serve_lib.DecodeState(*([slot_spec] * len(state)))
        dstate = draft_lib.abstract_draft_state(n_slots, spec_hist)
        dspec = draft_lib.DraftState(
            hist=self.rules.spec(("cache_batch", None),
                                 (n_slots, spec_hist)),
            count=slot_spec)
        cache = model_lib.init_cache(cfg, n_slots, shape.seq_len,
                                     dtype=self.dtype, abstract_only=True,
                                     layout=paged)
        cspec = self._cache_specs(cache, paged=paged is not None)
        row_spec = self.rules.spec(("cache_batch", None), (n_slots, w))
        i32 = lambda s: jax.ShapeDtypeStruct(s, jnp.int32)  # noqa: E731
        frag = [i32((n_slots, w)), i32((n_slots,)),
                jax.ShapeDtypeStruct((n_slots,), jnp.bool_),
                i32((n_slots,))]
        frag_sh = [row_spec, slot_spec, slot_spec, slot_spec]
        abstract_args = [params, state, dstate, cache]
        in_sh = [self._sh(pspec), self._sh(sspec), self._sh(dspec),
                 self._sh(cspec)]
        out_sh = [self._sh(sspec), self._sh(dspec), self._sh(cspec)]
        donate = (2, 3)      # drafter state + cache stream in place
        if paged is not None:
            from repro.runtime import paging
            bstate = paging.abstract_blocks(paged.n_blocks)
            bspec = jax.tree_util.tree_map(lambda _: P(), bstate)
            abstract_args.append(bstate)
            in_sh.append(self._sh(bspec))
            out_sh.append(self._sh(bspec))
            donate = (2, 3, 4)
            row1 = self.rules.spec(("cache_batch", None), (n_slots, 1))
            frag += [i32((n_slots,)), i32((n_slots, 1)), i32((n_slots, 1))]
            frag_sh += [slot_spec, row1, row1]
        abstract_args += frag
        in_sh += [self._sh(s) for s in frag_sh]
        out_sh += [self._sh(row_spec), self._sh(P()), self._sh(P())]
        if paged is not None:
            out_sh.append(self._sh(P()))     # stall counter
        # shape-dispatch metadata: which attention configuration the
        # fabric runs for this tick's fragment width (the dry-run's
        # answer to "which kernel serves the verify forward?")
        from repro.kernels.chunk_attention import NARROW_MAX_WIDTH
        from repro.models import attention as attn_lib
        sched = "narrow" if w <= NARROW_MAX_WIDTH else "wide"
        ladder = attn_lib.span_ladder(shape.seq_len)
        notes = self._notes() + [
            f"verify_width={w} -> chunk-attention[{sched}] (TPU) / "
            f"span ladder {ladder} (CPU)"]
        return Plan(
            name=f"{cfg.name}/{shape.name}", kind="serve", step_fn=step,
            abstract_args=tuple(abstract_args),
            in_shardings=tuple(in_sh),
            out_shardings=tuple(out_sh),
            donate_argnums=donate,
            rules=self.rules, qt_graph=self.qt_graph(), notes=notes)

    # -- compile-time metadata ------------------------------------------------
    def qt_graph(self) -> QTGraph:
        cfg, shape = self.cfg, self.shape
        tokens = shape.global_batch * shape.seq_len
        n_active = cfg.active_param_count()
        g = QTGraph()
        g.add(QT(f"{shape.kind}_step",
                 flops=model_lib.model_flops(
                     cfg, tokens if shape.kind not in ("decode", "serve")
                     else shape.global_batch, shape.kind)))
        g.add(QT("embed", shard_axis="data",
                 param_bytes=2.0 * cfg.vocab * cfg.d_model),
              parent=f"{shape.kind}_step",
              glue_bytes=2.0 * tokens * cfg.d_model)
        g.add(QT("stack", mode=MassMode.FOR, shard_axis="model",
                 flops=6.0 * n_active * tokens,
                 param_bytes=2.0 * n_active),
              parent=f"{shape.kind}_step",
              glue_bytes=2.0 * tokens * cfg.d_model)
        g.add(QT("head_loss", mode=MassMode.SUMUP, shard_axis="model"),
              parent=f"{shape.kind}_step",
              glue_bytes=2.0 * tokens * cfg.d_model)
        if shape.kind == "train":
            g.add(QT("grad_reduce", mode=MassMode.SUMUP, shard_axis="data",
                     act_bytes=4.0 * n_active),
                  parent=f"{shape.kind}_step", glue_bytes=4.0 * n_active)
            g.add(QT("adamw", shard_axis="data"), parent=f"{shape.kind}_step")
        g.check_invariants()
        return g

    def _notes(self) -> list[str]:
        notes = [f"mesh={dict(self.mesh.shape)}",
                 f"microbatches={self.n_microbatch}",
                 f"gather_once={self.gather_once}", f"remat={self.remat}"]
        return notes


class FleetSupervisor:
    """Data-parallel fleet of serving supervisors — the paper's hierarchy
    one level up (cores -> SV -> cluster, §4.1): each `ServingEngine` is
    a supervisor over its slot/block cores on one ``(1, model)`` submesh;
    this layer owns the ``data`` axis of the serve mesh and routes
    incoming requests across the replicas.

    **Routing** is least-loaded-by-blocks and preemption-aware: a request
    goes to the replica with the most rentable KV blocks (free slots, for
    contiguous engines), except that replicas holding parked (preempted)
    requests or flagged under pool pressure lose priority — new work
    there would compete with the re-admission queue's claim on blocks the
    ledger calls free.  Ties break toward the replica routed least (round
    robin).  Routing reads only host mirrors; it never syncs a device.

    **Accounting**: per-shard pools never masquerade as one global pool —
    `kv_stats` / `occupancy_stats` / `sync_stats` / `spec_stats` return
    ``{"fleet": <sums>, "per_replica": [...]}``, with the slot/block
    ledger sums delegated to :func:`repro.runtime.pool.merge_stats` and
    :func:`repro.runtime.paging.merge_block_stats` (disjoint pools: used,
    peaks and capacities add).

    **Fault tolerance**: each replica is a rentable core of a fleet-level
    `CorePool` (the paper's SV discipline one level up, same as
    `runtime/elastic.ElasticManager` over training hosts).  Every fleet
    step watches each replica three ways — a raised tick (exceptions
    propagate out of ``engine.step()``), a wall-clock deadline
    (``tick_deadline_s``), and a sampled slot-pool ledger invariant check
    — and a failed check **quarantines** the replica: its pool unit is
    disabled, its in-flight requests are drained into a migration queue
    and **replayed token-exactly** (prompt + generated-so-far through the
    chunked-prefill resume path, cross-checked like preemption resume)
    on healthy replicas, with exponential backoff, dead-lettering after
    ``max_migration_attempts`` failures, and re-admission on
    :meth:`recover`.  Degradation is graceful: a fleet that loses
    replicas sheds throughput, never correctness.
    """

    def __init__(self, params, cfg: ArchConfig, *,
                 n_replicas: Optional[int] = None, model: int = 1,
                 devices: Optional[list] = None,
                 mesh: Optional[Mesh] = None,
                 tick_deadline_s: Optional[float] = None,
                 ledger_check_every: int = 1,
                 max_migration_attempts: int = 3,
                 migration_backoff_steps: int = 2, **engine_kw):
        """``mesh`` (a (data, model) grid) or ``n_replicas``/``model``
        pick the fleet shape; without either, one replica per available
        device.  ``engine_kw`` is forwarded to every `ServingEngine`
        (n_slots, max_seq, paged, speculative, overcommit, ...).

        ``tick_deadline_s`` arms the per-tick watchdog (leave ``None``
        until every replica's tick families are compiled — a first-call
        jit compile takes seconds and would trip it).
        ``ledger_check_every`` samples `ServingEngine.health_check` every
        N fleet steps; migration retries back off exponentially from
        ``migration_backoff_steps`` fleet steps."""
        if mesh is not None:
            self.meshes = fleet_submeshes(mesh)
        else:
            devices = list(devices) if devices is not None \
                else list(jax.devices())
            if n_replicas is None:
                n_replicas = max(1, len(devices) // model)
            need = n_replicas * model
            if len(devices) < need:
                if model > 1:
                    raise ValueError(
                        f"fleet of {n_replicas} x {model}-way replicas "
                        f"needs {need} devices, have {len(devices)}")
                # model == 1: replicas may share a device — a 1-device
                # host still gets a functional (if serialized) fleet
                devices = [devices[i % len(devices)] for i in range(need)]
            self.meshes = [
                serve_mesh(model, devices=devices[i * model:(i + 1) * model])
                for i in range(n_replicas)]
        self.engines = [
            serve_lib.ServingEngine(params, cfg, mesh=m, **engine_kw)
            for m in self.meshes]
        self.routed = [0] * len(self.engines)
        # replica health: the fleet's own rent/disable ledger (a replica
        # is a core), plus the human-readable state the router reads
        self._params, self._cfg = params, cfg
        self._engine_kw = dict(engine_kw)
        self.tick_deadline_s = tick_deadline_s
        self.ledger_check_every = max(1, int(ledger_check_every))
        self.max_migration_attempts = int(max_migration_attempts)
        self.migration_backoff_steps = int(migration_backoff_steps)
        self.replica_pool = CorePool(len(self.engines))
        self._replica_units = self.replica_pool.rent_many(len(self.engines))
        self.health = [{"state": "healthy", "reason": None}
                       for _ in self.engines]
        self.health_events: list[Event] = []
        self._migration_queue: list[dict] = []
        self.dead_letters: list[serve_lib.Request] = []
        self.migrations = 0
        self._fleet_steps = 0
        self._retired_ticks = 0
        self._finished_rescued: list[serve_lib.Request] = []

    @property
    def n_replicas(self) -> int:
        return len(self.engines)

    # -- routing -----------------------------------------------------------
    def _busy(self, e: serve_lib.ServingEngine) -> bool:
        return bool(e.active or e._parked or e._displaced
                    or e._finished_instant)

    def healthy(self, i: int) -> bool:
        return self.health[i]["state"] == "healthy"

    def route_order(self, tier: str = "throughput",
                    loads: Optional[list] = None) -> list[int]:
        """Replica indices in routing-preference order (see class doc);
        quarantined replicas are not candidates.  ``loads`` (one
        ``ServingEngine.load()`` entry per replica) lets an admit drain
        reuse a single ledger sweep across many admissions instead of
        re-reading every replica per request.  Latency-tier routing
        drops the parked/pressure penalty: a latency arrival displaces
        its way in, so a pressured replica with capacity is still a
        fine target — what matters is free slots and blocks."""
        if loads is None:
            loads = [e.load() for e in self.engines]

        def key(i):
            ld = loads[i]
            blocks = ld["free_blocks"] if ld["free_blocks"] is not None \
                else ld["free_slots"]
            if tier == "latency":
                return (True, ld["free_slots"] > 0, blocks,
                        -self.routed[i])
            penalized = ld["parked"] > 0 or ld["pressure"]
            return (not penalized, ld["free_slots"] > 0, blocks,
                    -self.routed[i])

        return sorted((i for i in range(len(self.engines))
                       if self.healthy(i)), key=key, reverse=True)

    def admit_many(self, pending: list[serve_lib.Request]) -> int:
        """Route-and-admit queued requests, head of queue first, until no
        replica takes the head — except latency-tier requests, which skip
        the queue-order admit barrier and may displace throughput-tier
        victims (``ServingEngine.admit_displacing``).  The ledgers are
        swept once per drain (``loads``) and only the chosen replica's
        entry is refreshed per admission.  Returns the number admitted;
        admitted requests are compacted to the queue's prefix first, so
        the caller's ``del pending[:n]`` contract still holds."""
        if not pending:
            return 0
        loads = [e.load() for e in self.engines]

        def try_admit(req: serve_lib.Request, displacing: bool) -> bool:
            for i in self.route_order(tier=req.tier, loads=loads):
                e = self.engines[i]
                if displacing and req.tier == "latency" and e._can_preempt:
                    ok = e.admit_displacing(req)
                else:
                    ok = e.admit(req)
                if ok:
                    self.routed[i] += 1
                    loads[i] = e.load()
                    return True
            return False

        admitted: list[int] = []
        barrier = False
        for k, req in enumerate(pending):
            if not barrier:
                if try_admit(req, displacing=req.tier == "latency"):
                    admitted.append(k)
                else:
                    barrier = True       # FIFO holds for throughput tier
            elif req.tier == "latency" and try_admit(req, displacing=True):
                admitted.append(k)       # latency heads jump the barrier
        if admitted and barrier:
            taken = set(admitted)
            rest = [r for k, r in enumerate(pending) if k not in taken]
            pending[:] = [pending[k] for k in admitted] + rest
        return len(admitted)

    # -- chaos & health ----------------------------------------------------
    def arm_faults(self, plan) -> None:
        """Arm a :class:`runtime.faults.FaultPlan` across the fleet: each
        replica gets its slice of the schedule (engines with no events
        stay entirely fault-free — their hooks remain dead code)."""
        for i, e in enumerate(self.engines):
            rf = plan.for_replica(i)
            if rf:
                e.arm_faults(rf)

    def _quarantine(self, i: int, reason: str) -> None:
        """Withdraw replica `i` (§4.1.2 'overheating'): disable its fleet
        pool unit, drain its in-flight requests into the migration queue
        (their host-side token histories are intact — the output tripwire
        fires *before* a bad row can reach ``req.out``), and rescue any
        finished-but-unreported requests.  The device state is abandoned;
        :meth:`recover` rebuilds the engine from scratch."""
        e = self.engines[i]
        detail = reason
        if e.layout is not None:
            # post-quarantine diagnostic (materializes device state —
            # fine here, the replica is already off the hot path)
            from repro.runtime import paging
            try:
                block_reason = paging.invariant_violation(
                    jax.device_get(e.bstate))
            except Exception:
                block_reason = None
            if block_reason is not None:
                detail += f"; block ledger: {block_reason}"
        self.health[i] = {"state": "quarantined", "reason": detail,
                          "since_step": self._fleet_steps}
        self.replica_pool.disable(self._replica_units[i])
        self.health_events.append(Event("quarantine", i, detail))
        drained = list(e.active.values()) \
            + [e._parked[s] for s in e._park_order] + list(e._displaced)
        for req in drained:
            req.slot = None
            self._migration_queue.append(
                {"req": req, "attempts": 0, "due": self._fleet_steps})
        self._finished_rescued += e._finished_instant
        e._finished_instant = []
        e.active.clear()
        e._jobs.clear()
        e._parked.clear()
        e._park_order.clear()
        e._displaced.clear()
        e._need_first.clear()

    def _drain_migrations(self) -> None:
        """Adopt due queue entries on healthy replicas (routing order);
        a failed attempt backs off exponentially, and after
        ``max_migration_attempts`` the request is dead-lettered."""
        if not self._migration_queue:
            return
        still: list[dict] = []
        for item in self._migration_queue:
            if item["due"] > self._fleet_steps:
                still.append(item)
                continue
            req = item["req"]
            adopted = False
            had_capacity = False
            for i in self.route_order(tier=req.tier):
                e2 = self.engines[i]
                if not e2._can_preempt:
                    continue   # no resume path lowered: not a candidate
                had_capacity = had_capacity or e2.pool.available > 0
                try:
                    adopted = e2.adopt(req)
                except Exception as exc:  # adopting replica is sick too
                    self._quarantine(i, f"adopt failed: {exc}")
                    adopted = False
                if adopted:
                    self.routed[i] += 1
                    self.migrations += 1
                    self.health_events.append(Event(
                        "migrate", i,
                        f"rid {req.rid} (+{len(req.out)} tokens replayed)"))
                    break
            if not adopted:
                if not had_capacity:
                    # every healthy replica is simply full: wait for a
                    # slot to drain — transient fullness is not a failed
                    # migration, so it never burns an attempt (the run
                    # loop's max_ticks / max_wall_s bound the wait)
                    item["due"] = self._fleet_steps + 1
                    still.append(item)
                    continue
                item["attempts"] += 1
                if item["attempts"] >= self.max_migration_attempts:
                    self.dead_letters.append(req)
                    self.health_events.append(Event(
                        "dead_letter", -1,
                        f"rid {req.rid} after {item['attempts']} "
                        f"failed migrations"))
                else:
                    item["due"] = self._fleet_steps \
                        + self.migration_backoff_steps \
                        * 2 ** (item["attempts"] - 1)
                    still.append(item)
        self._migration_queue = still

    def recover(self, i: int) -> None:
        """Re-admit a healed replica: re-enable its fleet pool unit and
        rebuild its engine from scratch on the same submesh (the
        quarantined device state is untrusted by construction).  The
        router sees it immediately."""
        if self.healthy(i):
            return
        self._retired_ticks += self.engines[i].device_ticks
        self.replica_pool.enable(self._replica_units[i])
        self.engines[i] = serve_lib.ServingEngine(
            self._params, self._cfg, mesh=self.meshes[i], **self._engine_kw)
        self.health[i] = {"state": "healthy", "reason": None}
        self.health_events.append(Event("readmit", i,
                                        "rebuilt and re-admitted"))

    def fleet_health(self) -> dict:
        """The fleet's health ledger, summarized for benches and tests."""
        return {
            "replicas": [dict(h) for h in self.health],
            "healthy": sum(self.healthy(i)
                           for i in range(len(self.engines))),
            "migrations": int(self.migrations),
            "migration_queue": len(self._migration_queue),
            "dead_letters": sorted(r.rid for r in self.dead_letters),
            "migrate_replay_mismatches":
                sum(e.migrate_replay_mismatches for e in self.engines),
            "events": [(ev.kind, ev.host, ev.detail)
                       for ev in self.health_events],
        }

    # -- driving -----------------------------------------------------------
    def step(self) -> list[serve_lib.Request]:
        """One tick on every healthy busy replica — each tick bracketed
        by the watchdog (exception / deadline / sampled ledger check) —
        then one migration-queue drain.  Returns finished requests."""
        self._fleet_steps += 1
        done: list[serve_lib.Request] = []
        for i, e in enumerate(self.engines):
            if not self.healthy(i) or not self._busy(e):
                continue
            t0 = time.perf_counter()
            try:
                done += e.step()
            except Exception as exc:
                self._quarantine(i, f"tick raised: {exc}")
                continue
            if self.tick_deadline_s is not None \
                    and time.perf_counter() - t0 > self.tick_deadline_s:
                self._quarantine(
                    i, f"tick deadline exceeded "
                       f"({time.perf_counter() - t0:.3f}s "
                       f"> {self.tick_deadline_s}s)")
                continue
            if self._fleet_steps % self.ledger_check_every == 0:
                reason = e.health_check()
                if reason is not None:
                    self._quarantine(i, reason)
        self._drain_migrations()
        if self._finished_rescued:
            done += self._finished_rescued
            self._finished_rescued = []
        return done

    def _fleet_device_ticks(self) -> int:
        return self._retired_ticks + sum(e.device_ticks
                                         for e in self.engines)

    def run_to_completion(self, requests: list[serve_lib.Request],
                          max_ticks: int = 10_000,
                          max_wall_s: Optional[float] = None):
        """Continuous batching across the fleet: route/admit whenever any
        replica has capacity, tick every busy replica.  Returns (done,
        total device ticks) like `ServingEngine.run_to_completion`.
        ``max_wall_s`` bounds host wall clock (hung replicas burn no
        device ticks).  With every replica quarantined, queued migrations
        are dead-lettered rather than spun on forever — the fleet sheds
        throughput, never correctness."""
        pending = list(requests)
        done: list[serve_lib.Request] = []
        start = self._fleet_device_ticks()
        t_start = time.perf_counter()

        def ticks():
            return self._fleet_device_ticks() - start

        def busy_healthy():
            return any(self.healthy(i) and self._busy(e)
                       for i, e in enumerate(self.engines))

        while pending or self._migration_queue or self._finished_rescued \
                or busy_healthy():
            n = self.admit_many(pending)
            del pending[:n]
            if self._migration_queue \
                    and not any(self.healthy(i)
                                for i in range(len(self.engines))):
                for item in self._migration_queue:
                    self.dead_letters.append(item["req"])
                    self.health_events.append(Event(
                        "dead_letter", -1,
                        f"rid {item['req'].rid}: no healthy replica"))
                self._migration_queue = []
                continue
            if not busy_healthy() and not self._migration_queue \
                    and not self._finished_rescued:
                if pending:
                    raise RuntimeError(self._stuck_report(pending))
                break
            done += self.step()
            if ticks() > max_ticks:
                n_parked = sum(len(e._parked) + len(e._displaced)
                               for e in self.engines)
                raise RuntimeError(
                    f"max_ticks={max_ticks} exhausted with "
                    f"{sum(len(e.active) for e in self.engines)} active, "
                    f"{n_parked} preempted and {len(pending)} pending "
                    f"requests undrained\n"
                    + self._stuck_report(pending))
            if max_wall_s is not None \
                    and time.perf_counter() - t_start > max_wall_s:
                raise RuntimeError(
                    f"max_wall_s={max_wall_s} exceeded\n"
                    + self._stuck_report(pending))
        for e in self.engines:
            if e._finished_instant:
                done += e._finished_instant
                e._finished_instant = []
        return done, ticks()

    def _stuck_report(self, pending: list[serve_lib.Request]) -> str:
        """Fleet-level diagnosis: per-replica health + load, the
        migration queue and the dead-letter ledger."""
        lines = [f"{len(pending)} requests stuck: no healthy replica can "
                 f"admit and none is draining"]
        for i, e in enumerate(self.engines):
            h = self.health[i]
            state = h["state"] + (f" ({h['reason']})" if h["reason"]
                                  else "")
            lines.append(f"  replica {i}: {state}; load {e.load()}")
            parked = [e._parked[s].rid for s in e._park_order] \
                + [r.rid for r in e._displaced]
            if parked:
                lines.append(f"    preempted rids {parked}")
        if self._migration_queue:
            rids = [item["req"].rid for item in self._migration_queue]
            lines.append(f"  migration queue: rids {rids}")
        if self.dead_letters:
            lines.append(
                f"  dead letters: rids "
                f"{sorted(r.rid for r in self.dead_letters)}")
        return "\n".join(lines)

    # -- accounting --------------------------------------------------------
    def reset_stats(self) -> None:
        for e in self.engines:
            e.reset_stats()

    def kv_stats(self) -> dict:
        """Fleet-wide KV economics + the per-replica ledgers.  Sums are
        across replicas; each replica's bytes are already summed over its
        model shards (see `ServingEngine.kv_stats`)."""
        per = [e.kv_stats() for e in self.engines]
        fleet = {
            "n_replicas": len(per),
            "kv_bytes_allocated": sum(p["kv_bytes_allocated"] for p in per),
            "tokens_finished": sum(p["tokens_finished"] for p in per),
        }
        fleet["kv_bytes_per_token"] = fleet["kv_bytes_allocated"] \
            / max(1, fleet["tokens_finished"])
        if all(e.layout is not None for e in self.engines):
            from repro.runtime import paging
            fleet.update(paging.merge_block_stats(
                [e.bstate for e in self.engines]))
            fleet["stalls"] = sum(p["stalls"] for p in per)
            fleet["shared_block_hits"] = \
                sum(p["shared_block_hits"] for p in per)
        fleet["slot_pool"] = pool_lib.merge_stats(
            [e.pool.state for e in self.engines])
        return {"fleet": fleet, "per_replica": per}

    def occupancy_stats(self) -> dict:
        """Fleet occupancy is slot-tick weighted across replicas (NOT a
        mean of per-replica ratios — replicas tick different amounts):
        sum(running slot-ticks) / sum(ticks x slots)."""
        per = [e.occupancy_stats() for e in self.engines]
        denom = sum(p["ticks"] * p["n_slots"] for p in per)
        fleet = {
            "occupancy": sum(p["slot_ticks"] for p in per) / max(1, denom),
            "ticks": sum(p["ticks"] for p in per),
            "preemptions": sum(p["preemptions"] for p in per),
            "resumes": sum(p["resumes"] for p in per),
            "preempted_tokens_recomputed":
                sum(p["preempted_tokens_recomputed"] for p in per),
            "preempt_replay_mismatches":
                sum(p["preempt_replay_mismatches"] for p in per),
            "migrations_in": sum(p["migrations_in"] for p in per),
            "migrate_replay_mismatches":
                sum(p["migrate_replay_mismatches"] for p in per),
        }
        return {"fleet": fleet, "per_replica": per}

    def sync_stats(self) -> dict:
        per = [e.sync_stats() for e in self.engines]
        fleet = {k: sum(p[k] for p in per)
                 for k in ("host_syncs", "baseline_syncs", "device_ticks",
                           "decode_tokens")}
        fleet["sync_reduction_x"] = fleet["baseline_syncs"] \
            / max(1, fleet["host_syncs"])
        return {"fleet": fleet, "per_replica": per}

    def spec_stats(self) -> dict:
        per = [e.spec_stats() for e in self.engines]
        fleet = {k: sum(p[k] for p in per)
                 for k in ("spec_forwards", "spec_slot_forwards",
                           "spec_decode_tokens", "drafted", "accepted")}
        fleet["tokens_per_forward"] = fleet["spec_decode_tokens"] \
            / max(1, fleet["spec_slot_forwards"])
        fleet["acceptance_rate"] = fleet["accepted"] \
            / max(1, fleet["drafted"])
        return {"fleet": fleet, "per_replica": per}
