"""Serving runtime: device-resident continuous batching over the EMPA pool.

The KV-cache slot pool *is* the paper's core pool: a request is a QT, a
cache slot is a core — rented on admission, returned at EOS (§4.3's
rent/terminate cycle), preallocation reserves slots for a stream of
requests (§5.1).  The refactor pushed the supervisor onto the device:

* per-slot decode state (last token, emitted count, budget, active mask)
  lives on device as a :class:`DecodeState`;
* one jitted **decode chunk** (`build_decode_chunk`) advances every active
  slot up to ``chunk`` tokens inside a single ``lax.while_loop`` — greedy
  argmax, EOS/max-new retirement and the active mask are all computed on
  device, so the host syncs once per chunk instead of once per slot per
  tick;
* admission packs every rentable pending prompt into one right-padded
  batched prefill (`build_admit_step`) that scatters prompt caches into
  the rented slots — one compiled call per admission round, not one per
  request.

**Paged mode** (``ServingEngine(paged=True)``) applies the same rent /
release discipline one level down: the rented resource is a fixed-size
KV *block* (runtime/paging.py), so a slot's cache cost is proportional
to its actual sequence, not to ``max_seq``:

* admission rents ``ceil(len/block)`` blocks and *reserves* (the paper's
  §5.1 preallocation, as host accounting) the worst-case remainder, so
  decode growth can never starve mid-flight;
* identical prompt-prefix blocks are shared through a host-side hash
  map with device refcounts — rented once, referenced by many chains;
* inside the jitted chunk, slots crossing a block boundary rent one
  block each through a single vectorized ``pool.rent_many`` — no host
  sync;
* retirement releases the whole chain; refcount-zero blocks return to
  the pool.

**Chunked prefill** (``ServingEngine(chunked_prefill=True)``) applies the
paper's *fragment outsourcing* to prompts: a core never receives its
whole job at once — the supervisor feeds it fragments as capacity
appears (the companion EMPA paper's quasi-thread discipline).  Instead
of one monolithic admission prefill (which stalls every active decode
slot behind the longest prompt and compiles one variant per pow2 length
bucket), an admitted slot enters ``PHASE_PREFILL`` and the **unified
mixed tick** (`build_mixed_tick`) advances all slots together:

* a PREFILLING slot consumes one prompt fragment (≤ ``prefill_chunk_
  tokens``), written into its cache at its position offset;
* a DECODING slot advances one token — the *same* ``model.prefill_
  chunk`` forward treats it as a length-1 fragment;
* paged chains rent blocks chunk-granularly as fragments land
  (`paging.extend_chains`), never faster — the §5.1 worst-case
  reservation is still taken at admission, so lazy growth cannot
  starve; a fully-written shared prefix is skipped, not recomputed;
* one compile total, one host sync per tick, per-tick latency bounded
  by one fragment — no head-of-line blocking, and the outputs stay
  token-exact vs monolithic admission.

**Speculative decoding** (``ServingEngine(speculative=True)``) applies
the paper's outsourcing pattern to the decode hot path itself: decode
is memory-bound at one token per forward, so a cheap *drafter core*
(`runtime/draft.py` — a device-resident n-gram matcher over each slot's
recent token stream) runs ahead and proposes up to ``spec_k`` candidate
tokens per DECODING slot, and the supervisor-coordinated **verify
forward** (`build_spec_tick`) scores all slots' draft fragments in one
``model.prefill_chunk`` call through the same position-offset causal
mask chunked prefill uses — on both cache layouts:

* acceptance takes the longest prefix where draft == argmax, plus the
  bonus token the forward produced anyway: 1..``spec_k + 1`` tokens per
  slot per forward, **bit-exact** vs non-speculative greedy decode (a
  wrong draft costs speculated work, never a wrong token);
* ``cache["pos"]`` rewinds past rejected drafts; the speculatively
  written KV rows/pages are left dead — overwritten by the next
  fragment's write-then-attend before the mask can read them, and paged
  chains stay inside the admission-time §5.1 worst-case reservation, so
  speculation adds no stall mode;
* PREFILLING slots keep consuming prompt fragments in the same tick —
  speculation composes with chunked prefill.

**Preemptive over-commit** (``ServingEngine(overcommit=True)``) is the
supervisor's rent/release discipline under pressure: instead of taking
the §5.1 worst-case block reservation at admission (which caps
occupancy at what the pool could serve if *every* slot grew to its full
budget), admission asks only for what the request needs *now* and the
supervisor claws blocks back mid-flight when growth runs the pool dry:

* when ``extend_chains`` / ``grow_to_cover`` would stall a tick, the
  host loop picks a **victim** — the slot with the fewest generated
  tokens, ties broken toward the latest admission — and evicts it:
  ``paging.evict_chain`` drops the chain (refcount-aware: shared prefix
  blocks another chain references survive), the drafter window resets,
  and the request parks in ``PHASE_PREEMPTED`` with its full token
  history (prompt + everything generated so far);
* a parked request **resumes** through the existing chunked-prefill
  path: its replay stream (prompt + generated-so-far) is outsourced
  fragment by fragment, and greedy determinism makes the recompute
  replay the stream token-exactly — the final fragment's argmax *is*
  the token the request was about to decode, so resumption re-emits
  nothing and continues bit-exact on both cache layouts, greedy and
  speculative alike;
* progress is guaranteed: the last non-preempted slot is never evicted
  and admission rejects requests whose worst-case chain exceeds the
  whole pool, so the maximal-progress request always runs to
  retirement and frees its chain.

Host Python keeps only what must be host-side: the rent/return ledger
(`core/supervisor.CorePool`, itself a thin wrapper over the same jittable
`runtime/pool` transitions), the prefix-hash map, the per-slot fragment
cursors, the re-admission queue, and the request queue.
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.analysis import manifest as audit_manifest
from repro.configs.base import ArchConfig
from repro.core.supervisor import CorePool
from repro.models import model as model_lib
from repro.models.model import PagedLayout
from repro.runtime import draft as draft_lib
from repro.runtime import faults as faults_lib
from repro.runtime import paging
from repro.runtime import pool as pool_lib
from repro.runtime.accounting import TierAccounting
from repro.runtime.sharding import ShardingRules, use_rules

NO_TOKEN = -1          # emitted-buffer sentinel: slot idle this iteration

# families whose prefill is exact under right-padding (causal attention);
# recurrent state (ssm/hybrid) would absorb pad tokens, so those admit
# one exact-length prompt per prefill call instead of a padded pack
PACKED_PREFILL_FAMILIES = ("dense", "moe", "vlm")


def build_prefill_step(cfg: ArchConfig, max_seq: int,
                       rules: Optional[ShardingRules] = None):
    def prefill_step(params, batch):
        with use_rules(rules):
            return model_lib.prefill(params, batch, cfg, max_seq)
    return prefill_step


def build_decode_step(cfg: ArchConfig,
                      rules: Optional[ShardingRules] = None):
    def decode_step(params, token, cache):
        with use_rules(rules):
            return model_lib.decode_step(params, token, cache, cfg)
    return decode_step


def _register_jit_site(fn, *, family: str, jit: bool,
                       paged: Optional[PagedLayout],
                       donate_state: dict, static_keys=()):
    """Single finishing step for every tick builder: register the site
    with the static auditor's manifest, then jit with the donation list.

    The contiguous/paged wrapper pairs that used to close each builder
    (``if not jit: return fn`` / ``return jax.jit(fn, donate_argnums=
    ...)``) collapse here: the two variants differ only in which
    argnums carry donated persistent state, and that mapping
    (``donate_state``: argnum -> buffer name) is exactly the meta-info
    ``python -m repro.analysis.audit`` needs to prove donation coverage
    and enumerate the retrace-key space — so declaring it IS publishing
    it.  Registration happens even for ``jit=False`` builds (the
    cluster supervisor re-jits with explicit shardings but the donation
    contract is the same).
    """
    layout = "contiguous" if paged is None else "paged"
    donate = tuple(sorted(donate_state))
    audit_manifest.register_site(audit_manifest.JitSite(
        name=f"{family}/{layout}", family=family, layout=layout,
        donate_argnums=donate, state_args=dict(donate_state),
        static_keys=tuple(static_keys)))
    if not jit:
        return fn
    return jax.jit(fn, donate_argnums=donate)


# ---------------------------------------------------------------------------
# Device-resident decode state + jitted transitions
# ---------------------------------------------------------------------------

class DecodeState(NamedTuple):
    """Per-slot decode supervisor state; every field is (n_slots,)."""

    tokens: jax.Array    # int32 — last emitted token (decode input)
    n_out: jax.Array     # int32 — tokens emitted so far (incl. prefill's)
    max_new: jax.Array   # int32 — per-request budget
    active: jax.Array    # bool — slot is decoding


def init_decode_state(n_slots: int) -> DecodeState:
    return DecodeState(tokens=jnp.zeros((n_slots,), jnp.int32),
                       n_out=jnp.zeros((n_slots,), jnp.int32),
                       max_new=jnp.zeros((n_slots,), jnp.int32),
                       active=jnp.zeros((n_slots,), bool))


def abstract_decode_state(n_slots: int) -> DecodeState:
    return jax.tree_util.tree_map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
        init_decode_state(n_slots))


def _merge_rows(new, old, keep_new):
    """Per-slot select between two cache leaves (batch axis 0 for `pos`,
    axis 1 for layer-stacked leaves — same convention as init_cache)."""
    if new.ndim == 1:
        return jnp.where(keep_new, new, old)
    shape = [1] * new.ndim
    shape[1] = -1
    return jnp.where(keep_new.reshape(shape), new, old)


def build_decode_chunk(cfg: ArchConfig, *, chunk: int, eos_id: int,
                       rules: Optional[ShardingRules] = None,
                       decode_fn: Optional[Callable] = None,
                       jit: bool = True,
                       paged: Optional[PagedLayout] = None):
    """Jitted multi-token decode tick: one host round-trip per `chunk`.

    Contiguous: fn(params, state, cache) -> (state, cache, emitted,
    iters).  Paged: fn(params, state, cache, bstate) -> (state, cache,
    bstate, emitted, iters, stalls) — each loop iteration first grows
    block chains on device (`paging.grow_for_decode`), then decodes.
    `emitted` is (n_slots, chunk) int32 (NO_TOKEN for idle cells),
    `iters` counts executed loop iterations (early exit when every slot
    retires) and `stalls` counts slot-iterations that could not advance
    because the block pool ran dry — zero under the engine's
    admission-time reservation, and the pressure signal the over-commit
    supervisor evicts on (a stalled slot stays active and resumes once
    a chain is clawed back).
    The cache (and block state) is donated: the engine decodes in place.
    """
    decode = decode_fn or build_decode_step(cfg, rules)

    def advance(params, st: DecodeState, cache, active, i, emitted):
        """One decode step over every row + retirement bookkeeping."""
        pos0 = cache["pos"]
        logits, new_cache = decode(params, st.tokens, cache)
        nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        # a retired slot keeps its last token and frozen cache rows /
        # pages: it can never perturb an active one
        tok = jnp.where(active, nxt, st.tokens)
        n_out = st.n_out + active.astype(jnp.int32)
        if paged is None:
            cache = jax.tree_util.tree_map(
                lambda a, b: _merge_rows(a, b, active), new_cache, cache)
        else:
            # pages are disjoint per chain: an inactive row's write is
            # either dropped (released chain) or rewrites its own cell
            # with the identical value — only per-row leaves need merge
            cache = dict(new_cache,
                         pos=jnp.where(active, new_cache["pos"], pos0))
        emitted = emitted.at[:, i].set(jnp.where(active, tok, NO_TOKEN))
        retire = active & ((tok == eos_id) | (n_out >= st.max_new))
        # a slot excluded from `active` by a block-pool stall stays in
        # st.active: it simply didn't advance this iteration, and the
        # over-commit supervisor relieves the pressure at the next sync
        # (eviction) — deactivating it here would silently truncate it
        return DecodeState(tok, n_out, st.max_new, st.active & ~retire), \
            cache, emitted

    if paged is None:
        def chunk_fn(params, state: DecodeState, cache):
            n = state.tokens.shape[0]
            emitted0 = jnp.full((n, chunk), NO_TOKEN, jnp.int32)

            def cond(carry):
                i, st, _, _ = carry
                return (i < chunk) & jnp.any(st.active)

            def body(carry):
                i, st, cache, emitted = carry
                st, cache, emitted = advance(params, st, cache, st.active,
                                             i, emitted)
                return i + jnp.int32(1), st, cache, emitted

            iters, state, cache, emitted = jax.lax.while_loop(
                cond, body, (jnp.int32(0), state, cache, emitted0))
            return state, cache, emitted, iters

        return _register_jit_site(
            chunk_fn, family="decode_chunk", jit=jit, paged=paged,
            donate_state={2: "cache"}, static_keys=(("chunk", chunk),))

    def chunk_fn_paged(params, state: DecodeState, cache, bstate):
        n = state.tokens.shape[0]
        emitted0 = jnp.full((n, chunk), NO_TOKEN, jnp.int32)

        def cond(carry):
            i, st, _, _, _, _ = carry
            return (i < chunk) & jnp.any(st.active)

        def body(carry):
            i, st, cache, bstate, emitted, stalls = carry
            # rent one block per slot crossing a block boundary — the
            # supervisor action happens on device, no host round-trip
            bstate, tables, stalled = paging.grow_for_decode(
                bstate, cache["block_tables"], cache["pos"], st.active,
                block_size=paged.block_size)
            active = st.active & ~stalled
            stalls = stalls + jnp.sum(stalled).astype(jnp.int32)
            cache = dict(cache, block_tables=tables)
            st, cache, emitted = advance(params, st, cache, active, i,
                                         emitted)
            return i + jnp.int32(1), st, cache, bstate, emitted, stalls

        iters, state, cache, bstate, emitted, stalls = jax.lax.while_loop(
            cond, body,
            (jnp.int32(0), state, cache, bstate, emitted0, jnp.int32(0)))
        return state, cache, bstate, emitted, iters, stalls

    return _register_jit_site(
        chunk_fn_paged, family="decode_chunk", jit=jit, paged=paged,
        donate_state={2: "cache", 3: "bstate"},
        static_keys=(("chunk", chunk),))


def build_mixed_tick(cfg: ArchConfig, *, chunk_tokens: int, eos_id: int,
                     rules: Optional[ShardingRules] = None,
                     jit: bool = True,
                     paged: Optional[PagedLayout] = None):
    """Jitted unified prefill/decode tick (the fragment-outsourcing step).

    One call advances *every* rented slot exactly one quantum: a slot in
    ``PHASE_PREFILL`` consumes its next prompt fragment (up to
    ``chunk_tokens`` tokens, written into the cache at its position
    offset), a slot in ``PHASE_DECODE`` advances one token — both through
    the same ``model.prefill_chunk`` forward, where a decode step is just
    a length-1 fragment.  One compile (no per-prompt-length buckets), one
    host sync per tick, per-tick latency bounded by one fragment's cost.

    Contiguous: ``fn(params, state, cache, frag_tokens (n, C), frag_len
    (n,), frag_last (n,), frag_max_new (n,)) -> (state, cache, emitted
    (n, 1))``.  ``emitted`` carries the decode token per active slot and
    the *first* token for rows whose final fragment just ran (the prefill
    argmax), ``NO_TOKEN`` elsewhere.

    Paged: ``fn(params, state, cache, bstate, frag_tokens, frag_len,
    frag_last, frag_max_new, frag_skip, frag_cols, frag_rent) -> (state,
    cache, bstate, emitted, stalls)``.  ``frag_rent``/``frag_cols``
    commit this tick's chunk-granular block rents
    (:func:`paging.extend_chains` — host-picked, reservation-backed),
    ``frag_skip`` fences writes below it (shared prefix blocks an
    earlier chain already stored), and decode rows still grow their
    chains on device via :func:`paging.grow_for_decode`.

    The cache (and block state) is donated: the engine ticks in place.
    """

    def run(params, state: DecodeState, cache, decode_rows, frag_tokens,
            frag_len, frag_last, frag_max_new, frag_skip):
        """Shared tail: one prefill_chunk forward + QT bookkeeping."""
        # trace-time check: the compiled width IS the fragment width
        assert frag_tokens.shape[1] == chunk_tokens, \
            (frag_tokens.shape, chunk_tokens)
        # a decoding slot is a length-1 fragment whose token lives in
        # device state; a prefilling slot's fragment comes from the host
        first_col = jnp.where(decode_rows, state.tokens, frag_tokens[:, 0])
        tokens = jnp.concatenate([first_col[:, None], frag_tokens[:, 1:]],
                                 axis=1)
        lengths = jnp.where(decode_rows, 1, frag_len)
        with use_rules(rules):
            logits, cache = model_lib.prefill_chunk(
                params, tokens, lengths, cache, cfg, skip_until=frag_skip)
        nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        prefill_rows = frag_len > 0
        done_pref = prefill_rows & frag_last
        emit = decode_rows | done_pref
        tok = jnp.where(emit, nxt, state.tokens)
        n_out = jnp.where(done_pref, 1,
                          state.n_out + decode_rows.astype(jnp.int32))
        max_new = jnp.where(done_pref, frag_max_new, state.max_new)
        # same retirement rule as the decode chunk; like monolithic
        # admission, the first token is emitted without an EOS check and
        # a budget of 1 is already spent by it.  A stalled decode row
        # (in state.active but not decode_rows) stays active — it didn't
        # advance, and deactivating it would silently truncate it.
        retire = decode_rows & ((tok == eos_id) | (n_out >= max_new))
        active = (state.active & ~retire) | (done_pref & (max_new > 1))
        emitted = jnp.where(emit, tok, NO_TOKEN)[:, None]
        return DecodeState(tok, n_out, max_new, active), cache, emitted

    if paged is None:
        def tick(params, state: DecodeState, cache, frag_tokens, frag_len,
                 frag_last, frag_max_new):
            frag_skip = jnp.zeros_like(frag_len)
            return run(params, state, cache, state.active, frag_tokens,
                       frag_len, frag_last, frag_max_new, frag_skip)

        return _register_jit_site(
            tick, family="mixed_tick", jit=jit, paged=paged,
            donate_state={2: "cache"},
            static_keys=(("chunk_tokens", chunk_tokens),))

    def tick_paged(params, state: DecodeState, cache, bstate, frag_tokens,
                   frag_len, frag_last, frag_max_new, frag_skip, frag_cols,
                   frag_rent):
        # 1. commit this tick's fragment blocks (host-picked, cannot
        #    stall under the §5.1 reservation)
        bstate, tables = paging.extend_chains(
            bstate, cache["block_tables"], frag_cols, frag_rent)
        # 2. decode rows crossing a block boundary rent on device
        bstate, tables, stalled = paging.grow_for_decode(
            bstate, tables, cache["pos"], state.active,
            block_size=paged.block_size)
        decode_rows = state.active & ~stalled
        stalls = jnp.sum(stalled).astype(jnp.int32)
        cache = dict(cache, block_tables=tables)
        state, cache, emitted = run(params, state, cache, decode_rows,
                                    frag_tokens, frag_len, frag_last,
                                    frag_max_new, frag_skip)
        return state, cache, bstate, emitted, stalls

    return _register_jit_site(
        tick_paged, family="mixed_tick", jit=jit, paged=paged,
        donate_state={2: "cache", 3: "bstate"},
        static_keys=(("chunk_tokens", chunk_tokens),))


def build_spec_tick(cfg: ArchConfig, *, spec_k: int, chunk_tokens: int,
                    eos_id: int, hist_len: int = 64,
                    rules: Optional[ShardingRules] = None,
                    jit: bool = True,
                    paged: Optional[PagedLayout] = None):
    """Jitted speculative decode tick: drafter cores run ahead, one
    verify forward accepts k tokens per slot.

    The paper's outsourcing pattern on the decode hot path: a cheap
    device-resident n-gram drafter (`runtime/draft.py`) proposes up to
    ``spec_k`` continuation tokens per DECODING slot, and a single
    ``model.prefill_chunk`` forward over the ``(n_slots, W)`` draft
    fragments (``W = chunk_tokens >= spec_k + 1``) scores every slot's
    candidates at once through the same position-offset causal mask the
    chunked-prefill machinery already uses — on both cache layouts.
    Acceptance takes the longest prefix where draft == argmax plus the
    one *bonus* token the verify forward produced anyway, so each
    forward emits between 1 (drafter whiffed — the status quo) and
    ``spec_k + 1`` tokens, and greedy argmax verification makes the
    output **bit-exact** vs non-speculative decode.

    Rollback: the fragment wrote K/V at ``pos0 .. pos0 + dlen``;
    ``cache["pos"]`` rewinds to ``pos0 + n_emit`` and the rows past it
    are left dead — the next fragment's write-then-attend overwrites
    them before the mask can read them, and (paged) the chain stays
    within the admission-time §5.1 worst-case reservation, so no new
    stall mode appears.

    Speculation composes with chunked prefill: PREFILLING slots keep
    consuming host-scheduled prompt fragments in the same tick, exactly
    as in :func:`build_mixed_tick`.

    Contiguous: ``fn(params, state, dstate, cache, frag_tokens (n, W),
    frag_len, frag_last, frag_max_new) -> (state, dstate, cache,
    emitted (n, W), drafted, accepted)``.  Paged adds ``bstate`` after
    ``cache`` plus ``frag_skip/frag_cols/frag_rent`` and returns a
    ``stalls`` scalar.  ``drafted``/``accepted`` are per-tick totals of
    proposed and accepted draft tokens (the acceptance-rate numerator /
    denominator).  The cache (and block state) is donated.
    """
    assert chunk_tokens >= spec_k + 1, (chunk_tokens, spec_k)
    W = chunk_tokens
    propose, _clamp, run = _spec_core(cfg, spec_k=spec_k, width=W,
                                      eos_id=eos_id, rules=rules)

    if paged is None:
        def tick(params, state: DecodeState, dstate, cache, frag_tokens,
                 frag_len, frag_last, frag_max_new):
            decode_rows = state.active
            draft, dlen = propose(state, dstate, decode_rows)
            frag_skip = jnp.zeros_like(frag_len)
            return run(params, state, dstate, cache, decode_rows, draft,
                       dlen, frag_tokens, frag_len, frag_last, frag_max_new,
                       frag_skip)[:6]

        return _register_jit_site(
            tick, family="spec_tick", jit=jit, paged=paged,
            donate_state={2: "dstate", 3: "cache"},
            static_keys=(("spec_k", spec_k), ("chunk_tokens", W)))

    def tick_paged(params, state: DecodeState, dstate, cache, bstate,
                   frag_tokens, frag_len, frag_last, frag_max_new,
                   frag_skip, frag_cols, frag_rent):
        # 1. commit this tick's prompt-fragment blocks (host-picked)
        bstate, tables = paging.extend_chains(
            bstate, cache["block_tables"], frag_cols, frag_rent)
        # 2. drafter proposal, then cover the whole verify fragment's
        #    write span — it may cross several block boundaries
        draft, dlen = propose(state, dstate, state.active)
        bstate, tables, stalled = paging.grow_to_cover(
            bstate, tables, cache["pos"] + dlen, state.active,
            block_size=paged.block_size,
            max_rounds=spec_k // paged.block_size + 1)
        decode_rows = state.active & ~stalled
        dlen = jnp.where(decode_rows, dlen, 0)
        stalls = jnp.sum(stalled).astype(jnp.int32)
        cache = dict(cache, block_tables=tables)
        state, dstate, cache, emitted, drafted, accepted = run(
            params, state, dstate, cache, decode_rows, draft, dlen,
            frag_tokens, frag_len, frag_last, frag_max_new, frag_skip)[:6]
        return state, dstate, cache, bstate, emitted, drafted, accepted, \
            stalls

    return _register_jit_site(
        tick_paged, family="spec_tick", jit=jit, paged=paged,
        donate_state={2: "dstate", 3: "cache", 4: "bstate"},
        static_keys=(("spec_k", spec_k), ("chunk_tokens", W)))


def _spec_core(cfg: ArchConfig, *, spec_k: int, width: int, eos_id: int,
               rules: Optional[ShardingRules]):
    """The draft/verify/accept core shared by the single spec tick
    (:func:`build_spec_tick`, which composes with prompt fragments) and
    the multi-iteration spec chunk (:func:`build_spec_chunk`).  Returns
    ``(propose, run)`` closures; ``run`` also hands back the *next*
    iteration's proposal (fused ``draft_lib.push_and_propose`` — the
    accept/rewind/re-propose cycle never leaves the device), which the
    spec-chunk loop carries and the single tick drops (XLA dead-codes
    the unused branch)."""
    W = width

    def propose(state: DecodeState, dstate: draft_lib.DraftState,
                decode_rows):
        draft, dlen = draft_lib.propose(dstate, state.tokens, spec_k)
        return draft, clamp(state, dlen, decode_rows)

    def clamp(state: DecodeState, dlen, decode_rows):
        # budget clamp: emitting dlen + 1 tokens must stay within
        # max_new, so the fragment's writes stay inside the §5.1
        # reservation (and max_seq) the engine took at admission.
        # Applied at *consumption* time against the then-current state —
        # a fused proposal carried from the previous iteration sees the
        # same cap the unfused re-proposal would have computed.
        cap = jnp.maximum(state.max_new - state.n_out - 1, 0)
        return jnp.where(decode_rows, jnp.minimum(dlen, cap), 0)

    def run(params, state: DecodeState, dstate, cache, decode_rows, draft,
            dlen, frag_tokens, frag_len, frag_last, frag_max_new,
            frag_skip):
        assert frag_tokens.shape[1] == W, (frag_tokens.shape, W)
        pos0 = cache["pos"]
        # fragment assembly: a decoding slot runs [pending token,
        # draft_1 .. draft_dlen]; a prefilling slot runs its
        # host-scheduled prompt fragment
        first_col = jnp.where(decode_rows, state.tokens, frag_tokens[:, 0])
        dec_tail = jnp.pad(draft, ((0, 0), (0, W - 1 - spec_k)))
        tail = jnp.where(decode_rows[:, None], dec_tail, frag_tokens[:, 1:])
        tokens = jnp.concatenate([first_col[:, None], tail], axis=1)
        lengths = jnp.where(decode_rows, 1 + dlen, frag_len)
        with use_rules(rules):
            logits, cache = model_lib.prefill_chunk(
                params, tokens, lengths, cache, cfg, skip_until=frag_skip,
                all_logits=True)
        greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)   # (n, W)

        # -- verify: longest accepted prefix + bonus token ----------------
        jcol = jnp.arange(spec_k, dtype=jnp.int32)
        ok = (draft == greedy[:, :spec_k]) & (jcol[None, :] < dlen[:, None])
        acc = jnp.sum(jnp.cumprod(ok.astype(jnp.int32), axis=1), axis=1)
        wcol = jnp.arange(W, dtype=jnp.int32)
        # sequential greedy stops at the first EOS: truncate there
        cand = wcol[None, :] <= acc[:, None]
        is_eos = (greedy == eos_id) & cand
        first_eos = jnp.min(jnp.where(is_eos, wcol[None, :], W), axis=1)
        m = jnp.minimum(acc, first_eos)          # accepted draft tokens
        n_emit = jnp.where(decode_rows, m + 1, 0)
        emit_mask = decode_rows[:, None] & (wcol[None, :] < n_emit[:, None])
        last_tok = jnp.take_along_axis(
            greedy, jnp.clip(n_emit - 1, 0, W - 1)[:, None], axis=1)[:, 0]

        # -- prefill rows: same bookkeeping as the mixed tick -------------
        prefill_rows = ~decode_rows & (frag_len > 0)
        done_pref = prefill_rows & frag_last
        pref_tok = jnp.take_along_axis(
            greedy, jnp.clip(frag_len - 1, 0, W - 1)[:, None], axis=1)[:, 0]
        tok = jnp.where(decode_rows, last_tok,
                        jnp.where(done_pref, pref_tok, state.tokens))
        n_out = jnp.where(done_pref, 1,
                          state.n_out + jnp.where(decode_rows, n_emit, 0))
        max_new = jnp.where(done_pref, frag_max_new, state.max_new)
        retire = decode_rows & ((tok == eos_id) | (n_out >= max_new))
        # stalled rows (state.active but not decode_rows) stay active
        active = (state.active & ~retire) | (done_pref & (max_new > 1))

        emitted = jnp.where(
            emit_mask, greedy,
            jnp.where(done_pref[:, None] & (wcol[None, :] == 0),
                      tok[:, None], NO_TOKEN))
        # rewind: prefill_chunk advanced decode rows by 1 + dlen; the
        # true position is pos0 + n_emit (rows past it are dead — the
        # next fragment overwrites before the mask can read them)
        cache = dict(cache, pos=jnp.where(decode_rows, pos0 + n_emit,
                                          cache["pos"]))
        # history: push the consumed inputs (pending token + accepted
        # drafts) — the new pending token `tok` stays out, per the
        # drafter's invariant.  Prompt history is seeded host-side at
        # the PREFILL -> DECODE transition, so prefill rows push 0.
        # Fused with the *next* proposal against the updated history
        # (the spec-chunk loop consumes it; budget-clamp there).
        dstate, nxt_draft, nxt_dlen = draft_lib.push_and_propose(
            dstate, tokens, jnp.where(decode_rows, n_emit, 0), tok,
            spec_k)
        drafted = jnp.sum(jnp.where(decode_rows, dlen, 0))
        accepted = jnp.sum(jnp.where(decode_rows, m, 0))
        return (DecodeState(tok, n_out, max_new, active), dstate, cache,
                emitted, drafted, accepted, nxt_draft, nxt_dlen)

    return propose, clamp, run


def build_spec_chunk(cfg: ArchConfig, *, spec_k: int, eos_id: int,
                     iters: int,
                     rules: Optional[ShardingRules] = None,
                     jit: bool = True,
                     paged: Optional[PagedLayout] = None):
    """Multi-iteration speculative decode chunk: up to ``iters`` verify
    forwards per host sync — PR 1's sync economy composed with the
    drafter, for the pure-decode phase (no prompt fragments pending).

    Every loop iteration is one draft → verify → accept/rewind cycle
    over all active slots (the :func:`_spec_core` the single tick also
    runs); the loop exits early when every slot retires.  Contiguous:
    ``fn(params, state, dstate, cache) -> (state, dstate, cache,
    emitted (n, iters*(spec_k+1)), fwd, slot_fwd, drafted, accepted)``
    where ``fwd`` counts executed verify forwards and ``slot_fwd`` the
    decoding-slot forwards (the tokens-per-forward denominator).  Paged
    adds the donated ``bstate`` and a ``stalls`` scalar.  The cache
    (and block state) is donated.

    The loop carries the drafter's *fused* proposal: iteration i's
    ``run`` pushes the consumed fragment and re-proposes against the
    updated history in the same graph (``draft_lib.push_and_propose``),
    so iteration i+1 only applies the budget clamp against its
    then-current state — the accept/rewind/re-propose cycle never
    leaves the device between verify forwards.
    """
    W = spec_k + 1
    propose, clamp, run = _spec_core(cfg, spec_k=spec_k, width=W,
                                     eos_id=eos_id, rules=rules)

    def zero_frags(n):
        return (jnp.zeros((n, W), jnp.int32), jnp.zeros((n,), jnp.int32),
                jnp.zeros((n,), bool), jnp.zeros((n,), jnp.int32))

    def iteration(params, st, ds, cache, bstate, decode_rows, draft, dlen):
        ft, fl, flast, fmax = zero_frags(st.tokens.shape[0])
        st, ds, cache, em, d_i, a_i, nd, nl = run(
            params, st, ds, cache, decode_rows, draft, dlen, ft, fl,
            flast, fmax, fl)        # frag_skip == zeros == fl
        return st, ds, cache, em, d_i, a_i, nd, nl

    if paged is None:
        def chunk_fn(params, state: DecodeState, dstate, cache):
            n = state.tokens.shape[0]
            emitted0 = jnp.full((n, iters * W), NO_TOKEN, jnp.int32)
            zeros = jnp.int32(0)
            draft0, dlen0 = draft_lib.propose(dstate, state.tokens, spec_k)

            def cond(carry):
                i, st = carry[0], carry[1]
                return (i < iters) & jnp.any(st.active)

            def body(carry):
                i, st, ds, cache, draft, dlen, emitted, sf, dr, ac = carry
                decode_rows = st.active
                dlen = clamp(st, dlen, decode_rows)
                st, ds, cache, em, d_i, a_i, draft, dlen = iteration(
                    params, st, ds, cache, None, decode_rows, draft, dlen)
                emitted = jax.lax.dynamic_update_slice(emitted, em,
                                                       (0, i * W))
                sf = sf + jnp.sum(decode_rows).astype(jnp.int32)
                return (i + jnp.int32(1), st, ds, cache, draft, dlen,
                        emitted, sf, dr + d_i, ac + a_i)

            (fwd, state, dstate, cache, _, _, emitted, slot_fwd, drafted,
             accepted) = jax.lax.while_loop(
                cond, body, (zeros, state, dstate, cache, draft0, dlen0,
                             emitted0, zeros, zeros, zeros))
            return (state, dstate, cache, emitted, fwd, slot_fwd, drafted,
                    accepted)

        return _register_jit_site(
            chunk_fn, family="spec_chunk", jit=jit, paged=paged,
            donate_state={2: "dstate", 3: "cache"},
            static_keys=(("spec_k", spec_k), ("iters", iters)))

    def chunk_fn_paged(params, state: DecodeState, dstate, cache, bstate):
        n = state.tokens.shape[0]
        emitted0 = jnp.full((n, iters * W), NO_TOKEN, jnp.int32)
        zeros = jnp.int32(0)
        draft0, dlen0 = draft_lib.propose(dstate, state.tokens, spec_k)

        def cond(carry):
            i, st = carry[0], carry[1]
            return (i < iters) & jnp.any(st.active)

        def body(carry):
            (i, st, ds, cache, bstate, draft, dlen, emitted, sf, dr, ac,
             stalls) = carry
            dlen = clamp(st, dlen, st.active)
            bstate, tables, stalled = paging.grow_to_cover(
                bstate, cache["block_tables"], cache["pos"] + dlen,
                st.active, block_size=paged.block_size,
                max_rounds=spec_k // paged.block_size + 1)
            decode_rows = st.active & ~stalled
            dlen = jnp.where(decode_rows, dlen, 0)
            stalls = stalls + jnp.sum(stalled).astype(jnp.int32)
            cache = dict(cache, block_tables=tables)
            st, ds, cache, em, d_i, a_i, draft, dlen = iteration(
                params, st, ds, cache, bstate, decode_rows, draft, dlen)
            emitted = jax.lax.dynamic_update_slice(emitted, em, (0, i * W))
            sf = sf + jnp.sum(decode_rows).astype(jnp.int32)
            return (i + jnp.int32(1), st, ds, cache, bstate, draft, dlen,
                    emitted, sf, dr + d_i, ac + a_i, stalls)

        (fwd, state, dstate, cache, bstate, _, _, emitted, slot_fwd,
         drafted, accepted, stalls) = jax.lax.while_loop(
            cond, body, (zeros, state, dstate, cache, bstate, draft0,
                         dlen0, emitted0, zeros, zeros, zeros, zeros))
        return (state, dstate, cache, bstate, emitted, fwd, slot_fwd,
                drafted, accepted, stalls)

    return _register_jit_site(
        chunk_fn_paged, family="spec_chunk", jit=jit, paged=paged,
        donate_state={2: "dstate", 3: "cache", 4: "bstate"},
        static_keys=(("spec_k", spec_k), ("iters", iters)))


def build_solo_prefill_tick(cfg: ArchConfig, *, chunk_tokens: int,
                            rules: Optional[ShardingRules] = None,
                            jit: bool = True,
                            paged: Optional[PagedLayout] = None):
    """Cold-start fast path: with *no* slot decoding there is nobody to
    protect from head-of-line blocking, so instead of a full-batch
    fragment tick (which pays ``n_slots`` rows of compute for one
    prefilling job) the engine packs up to ``chunk_tokens`` prompt
    tokens for ONE job and runs them through a single-row
    ``prefill_chunk`` against that slot's cache view.

    Contiguous: ``fn(params, state, cache, slot, frag_tokens (1, Wp),
    frag_len (1,), frag_last (1,), frag_max_new (1,)) -> (state, cache,
    emitted (1,))`` — ``emitted`` carries the first token when the
    packed chunk finished the prompt, else ``NO_TOKEN``.  Paged adds
    ``bstate`` plus ``frag_skip/frag_cols/frag_rent`` (the cols/rent
    arrays are full ``(n_slots, K)`` with only ``slot``'s row set, so
    :func:`paging.extend_chains` is reused verbatim).  ``slot`` is a
    traced scalar: one compile covers every slot.
    """
    W = chunk_tokens

    def finish(state: DecodeState, slot, logits, frag_len, frag_last,
               frag_max_new):
        ftok = jnp.argmax(logits, axis=-1).astype(jnp.int32)[0]
        done = frag_last[0]
        mnew = frag_max_new[0]
        state = DecodeState(
            tokens=jnp.where(done, state.tokens.at[slot].set(ftok),
                             state.tokens),
            n_out=jnp.where(done, state.n_out.at[slot].set(1), state.n_out),
            max_new=jnp.where(done, state.max_new.at[slot].set(mnew),
                              state.max_new),
            active=jnp.where(done, state.active.at[slot].set(mnew > 1),
                             state.active))
        emitted = jnp.where(done, ftok, NO_TOKEN)[None]
        return state, emitted

    if paged is None:
        def tick(params, state: DecodeState, cache, slot, frag_tokens,
                 frag_len, frag_last, frag_max_new):
            assert frag_tokens.shape == (1, W), frag_tokens.shape
            sub = {
                "k": jax.lax.dynamic_slice_in_dim(cache["k"], slot, 1, 1),
                "v": jax.lax.dynamic_slice_in_dim(cache["v"], slot, 1, 1),
                "pos": jax.lax.dynamic_slice_in_dim(cache["pos"], slot, 1,
                                                    0),
            }
            with use_rules(rules):
                logits, sub = model_lib.prefill_chunk(
                    params, frag_tokens, frag_len, sub, cfg)
            cache = dict(
                cache,
                k=jax.lax.dynamic_update_slice_in_dim(cache["k"], sub["k"],
                                                      slot, 1),
                v=jax.lax.dynamic_update_slice_in_dim(cache["v"], sub["v"],
                                                      slot, 1),
                pos=jax.lax.dynamic_update_slice_in_dim(
                    cache["pos"], sub["pos"], slot, 0))
            state, emitted = finish(state, slot, logits, frag_len,
                                    frag_last, frag_max_new)
            return state, cache, emitted

        return _register_jit_site(
            tick, family="solo_prefill", jit=jit, paged=paged,
            donate_state={2: "cache"},
            static_keys=(("chunk_tokens", W),))

    def tick_paged(params, state: DecodeState, cache, bstate, slot,
                   frag_tokens, frag_len, frag_last, frag_max_new,
                   frag_skip, frag_cols, frag_rent):
        assert frag_tokens.shape == (1, W), frag_tokens.shape
        bstate, tables = paging.extend_chains(
            bstate, cache["block_tables"], frag_cols, frag_rent)
        # pages are global — only the bookkeeping rows need slicing
        sub = {
            "k": cache["k"], "v": cache["v"],
            "pos": jax.lax.dynamic_slice_in_dim(cache["pos"], slot, 1, 0),
            "block_tables": jax.lax.dynamic_slice_in_dim(tables, slot, 1,
                                                         0),
        }
        with use_rules(rules):
            logits, sub = model_lib.prefill_chunk(
                params, frag_tokens, frag_len, sub, cfg,
                skip_until=frag_skip)
        cache = dict(cache, k=sub["k"], v=sub["v"], block_tables=tables,
                     pos=jax.lax.dynamic_update_slice_in_dim(
                         cache["pos"], sub["pos"], slot, 0))
        state, emitted = finish(state, slot, logits, frag_len, frag_last,
                                frag_max_new)
        return state, cache, bstate, emitted

    return _register_jit_site(
        tick_paged, family="solo_prefill", jit=jit, paged=paged,
        donate_state={2: "cache", 3: "bstate"},
        static_keys=(("chunk_tokens", W),))


def build_admit_step(cfg: ArchConfig, max_seq: int,
                     rules: Optional[ShardingRules] = None):
    """Jitted packed admission: batched prefill + scatter into rented slots.

    fn(params, tokens (G,Sp), lengths (G,), max_new (G,), slots (G,),
       state, cache, first) -> (state, cache, first).

    Rows whose slot is out of range (the G-padding rows) are dropped by
    the scatter (`mode="drop"`), so the call compiles once per Sp bucket.
    A ``max_new`` of 1 admits inactive: the prefill argmax already is the
    whole budget, so the slot retires without a decode step.
    """

    def admit_fn(params, tokens, lengths, max_new, slots, state, cache,
                 first):
        logits, cache_g = _group_prefill(params, tokens, lengths, cfg,
                                         max_seq, rules)
        ftok = jnp.argmax(logits, axis=-1).astype(jnp.int32)

        def put(big, small):
            if big.ndim == 1:                  # pos: (n_slots,)
                return big.at[slots].set(small, mode="drop")
            return big.at[:, slots].set(
                small.astype(big.dtype), mode="drop")
        cache = jax.tree_util.tree_map(put, cache, cache_g)
        state = _admit_state(state, slots, ftok, max_new)
        first = first.at[slots].set(ftok, mode="drop")
        return state, cache, first

    return _register_jit_site(
        admit_fn, family="admit_step", jit=True, paged=None,
        donate_state={6: "cache"}, static_keys=(("max_seq", max_seq),))


def _group_prefill(params, tokens, lengths, cfg, span, rules):
    """The shared packed-prefill call (span = group cache length)."""
    g = tokens.shape[0]
    batch = {"tokens": tokens}
    if cfg.frontend == "vision":
        batch["vision_embeds"] = jnp.zeros(
            (g, cfg.n_frontend_tokens, cfg.frontend_dim), jnp.float32)
    if cfg.family == "encdec":
        batch["enc_embeds"] = jnp.zeros(
            (g, tokens.shape[1], cfg.frontend_dim), jnp.float32)
    with use_rules(rules):
        return model_lib.prefill(params, batch, cfg, span, lengths=lengths)


def _admit_state(state: DecodeState, slots, ftok, max_new) -> DecodeState:
    return DecodeState(
        tokens=state.tokens.at[slots].set(ftok, mode="drop"),
        n_out=state.n_out.at[slots].set(1, mode="drop"),
        max_new=state.max_new.at[slots].set(max_new, mode="drop"),
        # budget 1 is already spent by the prefill argmax
        active=state.active.at[slots].set(max_new > 1, mode="drop"))


def build_admit_step_paged(cfg: ArchConfig, max_seq: int,
                           layout: PagedLayout,
                           rules: Optional[ShardingRules] = None):
    """Paged packed admission: prefill the group over its (block-rounded)
    span, then scatter K/V *blocks* into host-rented pages.

    fn(params, tokens (G,Sp), lengths, max_new, slots (G,),
       gtables (G,NB), wtargets (G,nb_span), state, cache, bstate, first)
    -> (state, cache, bstate, first).

    ``gtables`` rows are the full chains committed to the slots' block
    tables; ``wtargets`` names the physical block each span-block of the
    group prefill is stored into — shared prefix blocks carry the
    out-of-range sentinel (already stored by an earlier chain; the
    scatter drops them).  ``paging.admit_chains`` rents the written
    blocks and takes one reference per chain entry.
    """
    bs = layout.block_size

    def admit_fn(params, tokens, lengths, max_new, slots, gtables,
                 wtargets, state, cache, bstate, first):
        g = tokens.shape[0]
        span_total = tokens.shape[1] + \
            (cfg.n_frontend_tokens if cfg.frontend == "vision" else 0)
        logits, cache_g = _group_prefill(params, tokens, lengths, cfg,
                                         span_total, rules)
        ftok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        nb_span = span_total // bs
        wflat = wtargets.reshape(g * nb_span)
        for name in ("k", "v"):
            n_layers = cache_g[name].shape[0]
            blocks = cache_g[name].reshape(
                n_layers, g * nb_span, bs, *cache_g[name].shape[3:])
            cache[name] = cache[name].at[:, wflat].set(
                blocks.astype(cache[name].dtype), mode="drop")
        cache = dict(
            cache,
            pos=cache["pos"].at[slots].set(cache_g["pos"], mode="drop"),
            block_tables=cache["block_tables"].at[slots].set(
                gtables, mode="drop"))
        bstate = paging.admit_chains(bstate, gtables.reshape(-1), wflat)
        state = _admit_state(state, slots, ftok, max_new)
        first = first.at[slots].set(ftok, mode="drop")
        return state, cache, bstate, first

    return _register_jit_site(
        admit_fn, family="admit_step", jit=True, paged=layout,
        donate_state={8: "cache", 9: "bstate"},
        static_keys=(("max_seq", max_seq),
                     ("block_size", layout.block_size)))


# ---------------------------------------------------------------------------
# Continuous-batching engine
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray            # (S,) int32
    max_new: int = 16
    out: list = dataclasses.field(default_factory=list)
    slot: Optional[int] = None
    # scheduling class — host-side metadata ONLY (the lint/tier-host-side
    # rule proves no traced tick ever reads it, which is what keeps the
    # tiered engine token-exact vs the untiered oracle by construction):
    # "latency" admits ahead of queue order and may displace
    # throughput-tier victims; "throughput" is the default class
    tier: str = "throughput"


def _pow2_bucket(n: int, cap: int) -> int:
    """Next power of two ≥ n, clamped to cap — bounds recompiles.

    Over-cap lengths clamp to `cap` (admission rejects them before any
    compile); the pre-fix behavior returned raw `n`, which compiled a
    fresh prefill for every distinct over-cap prompt length.
    """
    b = 1
    while b < n:
        b <<= 1
    return min(b, cap)


def admit_span_buckets(max_seq: int, *, block_size: Optional[int] = None,
                       offset: int = 0, packed: bool = True,
                       _bucket: Callable[[int, int], int] = None) -> list:
    """Reachable compiled *span* buckets of the packed admission prefill.

    Derived by evaluating the engine's actual bucketing over every
    admissible prompt length — not a parallel hand-kept list, so if the
    bucketing in :meth:`ServingEngine._prefill_group` rots (PR 6's
    ``seed_slot`` lesson: a raw length reaching a jit boundary compiles
    once per distinct length), the enumerated space explodes and the
    retrace audit fails instead of the fleet silently recompiling.
    ``_bucket`` exists for the auditor's known-bad fixtures."""
    bucket = _bucket or _pow2_bucket
    spans = set()
    for maxlen in range(1, max_seq + 1):
        span = bucket(maxlen, max_seq) if packed else maxlen
        if block_size is not None:
            span += (-(span + offset)) % block_size
        spans.add(span)
    return sorted(spans)


def admit_group_buckets(n_slots: int, *, packed: bool = True,
                        _bucket: Callable[[int, int], int] = None) -> list:
    """Reachable compiled group-row buckets (same derivation rule)."""
    bucket = _bucket or _pow2_bucket
    return sorted({bucket(g, n_slots) if packed else g
                   for g in range(1, n_slots + 1)})


def retrace_key_spaces(*, max_seq: int, n_slots: int,
                       block_size: Optional[int] = None, offset: int = 0,
                       packed: bool = True) -> dict:
    """Static-argument key space per jit-site family, for the retrace
    audit: family name -> list of reachable static keys (one compile
    each), or ``None`` for an unbounded site (always a violation).

    The admission site is the only one whose key space depends on
    runtime data (prompt length, group size); every tick family's keys
    are fixed at engine construction and published through the
    manifest's ``static_keys``, so their space is the singleton the
    manifest already records."""
    spans = admit_span_buckets(max_seq, block_size=block_size,
                               offset=offset, packed=packed)
    gpads = admit_group_buckets(n_slots, packed=packed)
    spaces = {"admit_step": [(s, g) for s in spans for g in gpads]}
    for name, site in audit_manifest.sites().items():
        if site.family == "admit_step":
            continue
        spaces[name] = [site.static_keys]
    return spaces


@dataclasses.dataclass
class _ChainPlan:
    """Host-side admission plan for one request's block chain."""

    chain: list            # block ids covering the prompt (shared + new)
    new_blocks: list       # subset actually stored by this admission
    n_shared: int
    worst_total: int       # §5.1 reservation: blocks the chain may reach


@dataclasses.dataclass
class _PrefillJob:
    """Host cursor for one slot's incrementally outsourced prompt.

    ``stream`` is the token stream actually fed to the mixed tick —
    the request's prompt for a fresh admission, or prompt + generated
    history for a preempted request being resumed (the recompute
    replay).  ``cursor`` counts consumed tokens, ``registered`` the
    prefix-map blocks published so far (a block becomes shareable only
    once the fragment that writes it has been dispatched — a later
    chain must never attend to an unwritten shared block).  With
    ``drop_first`` the final fragment's argmax is a *replayed* token
    the request already emitted before eviction: it seeds the decode
    state but is not re-delivered."""

    req: Request
    max_new_eff: int
    stream: np.ndarray
    cursor: int = 0
    registered: int = 0
    drop_first: bool = False
    # a fleet-migrated request's replay (ServingEngine.adopt): the
    # drop_first cross-check books its mismatches separately so a
    # migration that silently diverged is distinguishable from a local
    # preemption-resume bug
    migrated: bool = False


class OutputValidationError(RuntimeError):
    """The host-side output tripwire (``validate_outputs=True``) caught a
    non-finite or out-of-vocabulary value in a synced emitted buffer —
    NaN/garbage logits upstream.  Carries slot/tick attribution in the
    message; the fleet supervisor treats it as a replica health failure."""


class ServingEngine:
    """Batched greedy decoding with rent/return slot semantics.

    The host owns the pool ledger and the queue; everything per-tick —
    argmax, EOS / max-new retirement, the active mask, cache advancement,
    and (paged) block-chain growth — runs inside one jitted decode chunk
    with a donated cache.  The host syncs once per chunk (and reads
    nothing at admission), which is what turns sequential per-slot
    coordination into streaming throughput.

    With ``paged=True`` the KV cache is a pool of ``n_blocks`` blocks of
    ``block_size`` positions governed by the same rent/release discipline
    (runtime/paging.py): admission rents exactly what the prompt needs
    (sharing identical prefix blocks), reserves the worst-case decode
    remainder so growth can't starve, and retirement returns the chain.

    With ``overcommit=True`` the §5.1 worst-case reservation is *not*
    taken: admission asks only for the blocks a request needs now, so
    occupancy rises to what the pool can physically hold, and when
    growth runs the pool dry mid-flight the supervisor evicts a victim
    (``preempt``) — its chain is clawed back refcount-aware, its request
    parks in ``PHASE_PREEMPTED`` with its full token history, and it
    resumes later by replaying that history through the chunked-prefill
    path, token-exactly (greedy determinism; the engine cross-checks the
    replayed pending token).  ``preempt(slot)`` is also callable
    directly — forced eviction is the mechanism priority scheduling and
    SLA tiers will drive.
    """

    def __init__(self, params, cfg: ArchConfig, *, n_slots: int,
                 max_seq: int, eos_id: int = 1,
                 decode_fn: Optional[Callable] = None,
                 chunk: int = 8,
                 rules: Optional[ShardingRules] = None,
                 mesh: Optional[Mesh] = None,
                 paged: bool = False, block_size: int = 16,
                 n_blocks: Optional[int] = None,
                 prefix_sharing: bool = True,
                 chunked_prefill: bool = False,
                 prefill_chunk_tokens: int = 16,
                 max_prefill_tokens_per_tick: Optional[int] = None,
                 speculative: bool = False, spec_k: int = 4,
                 spec_hist: int = 64,
                 overcommit: bool = False,
                 debug_transfers: bool = False,
                 validate_outputs: bool = False):
        # tensor-parallel tick: with a (data, model) mesh the engine
        # shards attention heads / KV along "model" per the logical-axis
        # rules (divisibility fallback included) and places params, cache
        # and supervisor state accordingly — every tick then lowers with
        # sharded donated caches.  Token-exact vs the single-device
        # engine: attention has no cross-head reduction, the sharded
        # contractions psum disjoint partial sums, and the conformance
        # matrix asserts bit-identical emitted tokens on a >=2-device
        # mesh (CI runs it under 8 forced host devices).
        if mesh is not None and rules is None:
            rules = ShardingRules(mesh)
        self.mesh, self.rules = mesh, rules
        self.params, self.cfg = params, cfg
        self.debug_transfers = debug_transfers
        # health surface (chaos tentpole): the output tripwire validates
        # every synced emitted row on the host (no device sync added),
        # the bound being the padded vocab (padded unembed columns are
        # legal argmax winners on some configs); the fault hook is dead
        # code until `arm_faults` installs a plan (lint-enforced); the
        # per-tick wall clock feeds the fleet's deadline watchdog
        self.validate_outputs = validate_outputs
        self._vocab_bound = int(getattr(cfg, "vocab_padded", cfg.vocab))
        self._faults: Optional[faults_lib.ReplicaFaults] = None
        self._fault_step = 0
        self._poison_pending = False
        self.last_tick_wall_s = 0.0
        self.migrations_in = 0
        self.migrate_replay_mismatches = 0
        self._admit_wall: dict[int, float] = {}   # rid -> admission time
        self.max_seq, self.eos_id, self.chunk = max_seq, eos_id, chunk
        self.pool = CorePool(n_slots)
        self.active: dict[int, Request] = {}
        self._offset = cfg.n_frontend_tokens if cfg.frontend == "vision" \
            else 0
        dtype = jax.tree_util.tree_leaves(params)[0].dtype
        self.layout: Optional[PagedLayout] = None
        if paged:
            if cfg.family not in model_lib.PAGED_FAMILIES:
                raise ValueError(
                    f"paged serving supports {model_lib.PAGED_FAMILIES}, "
                    f"not {cfg.family!r}")
            nb_full = -(-max_seq // block_size)
            if n_blocks is None:       # capacity-equivalent default
                n_blocks = n_slots * nb_full
            self.layout = PagedLayout(block_size, n_blocks)
        self.cache = model_lib.init_cache(cfg, n_slots, max_seq,
                                          dtype=dtype, layout=self.layout)
        self.dstate = init_decode_state(n_slots)
        self._first = jnp.zeros((n_slots,), jnp.int32)
        self._need_first: set[int] = set()
        self._chunk_fn = build_decode_chunk(cfg, chunk=chunk, eos_id=eos_id,
                                            rules=rules, decode_fn=decode_fn,
                                            paged=self.layout)
        if self.layout is None:
            self._admit_fn = build_admit_step(cfg, max_seq, rules=rules)
        else:
            self._admit_fn = build_admit_step_paged(cfg, max_seq,
                                                    self.layout, rules=rules)
            self.bstate = paging.init_blocks(n_blocks)
            self._prefix_sharing = prefix_sharing
            # host mirrors of the device block state (refreshed at every
            # chunk sync — admission never blocks on the device)
            self._ref_host = np.zeros((n_blocks,), np.int32)
            self._tables_host = np.full(
                (n_slots, self.layout.max_blocks(max_seq)), -1, np.int32)
            self._prefix_map: dict = {}      # prefix key -> block id
            self._block_hash: dict = {}      # block id -> prefix key
            self._plans: dict[int, _ChainPlan] = {}   # slot -> plan
        self._packed = cfg.family in PACKED_PREFILL_FAMILIES
        self.chunked = chunked_prefill
        self.overcommit = overcommit
        # preemption rides the fragment machinery (resume = replay the
        # parked history through chunked prefill), so any causal-cache
        # family gets it — chunked admission and over-commit merely
        # require it up front
        self._can_preempt = cfg.family in model_lib.PAGED_FAMILIES \
            and not cfg.frontend
        if chunked_prefill and not self._can_preempt:
            raise ValueError(
                f"chunked prefill supports causal attention caches "
                f"{model_lib.PAGED_FAMILIES} without a frontend, not "
                f"{cfg.family!r} (frontend={cfg.frontend!r})")
        if overcommit and not self._can_preempt:
            raise ValueError(
                f"over-commit serving resumes preempted requests through "
                f"the chunked-prefill path: causal attention caches "
                f"{model_lib.PAGED_FAMILIES} without a frontend only, not "
                f"{cfg.family!r} (frontend={cfg.frontend!r})")
        self._jobs: dict[int, _PrefillJob] = {}
        if self._can_preempt:
            if prefill_chunk_tokens < 1:
                raise ValueError("prefill_chunk_tokens must be >= 1")
            if max_prefill_tokens_per_tick is not None \
                    and max_prefill_tokens_per_tick < 1:
                raise ValueError(
                    "max_prefill_tokens_per_tick must be >= 1")
            pchunk = int(prefill_chunk_tokens)
            if speculative and not chunked_prefill:
                # resume fragments ride the spec tick, whose verify
                # width is spec_k + 1 — match it instead of widening
                # every verify forward to the prefill fragment size
                pchunk = max(2, int(spec_k) + 1)
            self._pchunk = pchunk
            self._tick_budget = max_prefill_tokens_per_tick
            self._mixed_fn = build_mixed_tick(
                cfg, chunk_tokens=self._pchunk, eos_id=eos_id, rules=rules,
                paged=self.layout)
            # cold-start fast path: when no slot is decoding there is no
            # fairness to protect, so ONE job gets its fragments packed
            # up to the per-tick token budget through a single-row tick
            # instead of paying n_slots rows per fragment
            budget_eff = self._tick_budget if self._tick_budget is not None \
                else self._pchunk * n_slots
            self._solo_width = max(self._pchunk, min(budget_eff, max_seq))
            self._solo_fn = build_solo_prefill_tick(
                cfg, chunk_tokens=self._solo_width, rules=rules,
                paged=self.layout)
        self.spec = speculative
        if speculative:
            if cfg.family not in model_lib.PAGED_FAMILIES or cfg.frontend:
                raise ValueError(
                    f"speculative decoding rides the chunked-prefill "
                    f"forward: causal attention caches "
                    f"{model_lib.PAGED_FAMILIES} without a frontend only, "
                    f"not {cfg.family!r} (frontend={cfg.frontend!r})")
            if spec_k < 1:
                raise ValueError("spec_k must be >= 1")
            if spec_hist < 4:
                raise ValueError("spec_hist must be >= 4 (bigram context "
                                 "+ at least one continuation token)")
            self._spec_k = int(spec_k)
            self._spec_width = max(spec_k + 1,
                                   self._pchunk if self._can_preempt else 0)
            self.draft_state = draft_lib.init_draft_state(n_slots,
                                                          int(spec_hist))
            # the single tick composes with prompt fragments; the chunk
            # runs up to `chunk` verify forwards per host sync once the
            # engine is in the pure-decode phase (PR 1's sync economy)
            self._spec_fn = build_spec_tick(
                cfg, spec_k=self._spec_k, chunk_tokens=self._spec_width,
                eos_id=eos_id, rules=rules, paged=self.layout)
            self._spec_chunk_fn = build_spec_chunk(
                cfg, spec_k=self._spec_k, eos_id=eos_id, iters=chunk,
                rules=rules, paged=self.layout)
        self._finished_instant: list[Request] = []
        # preemption: parked requests keep their slot (PHASE_PREEMPTED)
        # but hold no KV; the re-admission queue resumes them oldest
        # eviction first.  _slot_seq orders admissions for the victim
        # policy's tie-break; _pressure flags a host-side scheduling
        # shortfall (the device-side signal is the stall counter).
        self._parked: dict[int, Request] = {}
        self._park_order: list[int] = []
        self._admit_seq = 0
        self._slot_seq: dict[int, int] = {}
        self._pressure = False
        self._evicted_recently = False
        # async request frontier (priority/SLA tiers): submit() enqueues
        # arrivals without blocking, _admit_frontier() drains them
        # tier-aware between ticks (latency-tier heads jump the queue and
        # may displace throughput-tier victims through preempt()), and
        # poll() surfaces completions.  Displaced victims queue here for
        # replay re-admission over the fleet-migration resume path.
        self._frontier: list[Request] = []
        self._displaced: list[Request] = []
        self._completed: list[Request] = []
        self._frontier_rids: set[int] = set()
        self.displacements = 0
        self.sla = TierAccounting()
        self.preemptions = 0
        self.resumes = 0
        self.preempted_tokens = 0
        self.preempt_replay_mismatches = 0
        # occupancy: running (non-parked) slots per tick, the over-commit
        # bench's numerator/denominator
        self.occ_ticks = 0
        self.occ_slot_ticks = 0
        # accounting: host round-trips vs the one-sync-per-slot-per-tick
        # baseline an un-refactored engine would have paid
        self.host_syncs = 0
        self.baseline_syncs = 0
        self.device_ticks = 0
        self.decode_tokens = 0
        self.decode_wall_s = 0.0   # wall time inside serving ticks
        self.stalls = 0
        self.shared_block_hits = 0
        self.kv_bytes_allocated = 0
        self.tokens_finished = 0
        # speculative decode economics: verify forwards that had >= 1
        # decoding slot, the decode tokens they emitted, and the
        # drafted/accepted token totals (acceptance rate)
        self.spec_forwards = 0
        self.spec_slot_forwards = 0
        self.spec_decode_tokens = 0
        self.spec_drafted = 0
        self.spec_accepted = 0
        # per-slot / per-block KV footprint (all cache leaves that scale
        # with the slot or block count; `pos`/tables bookkeeping excluded)
        if self.layout is None:
            self._slot_bytes = sum(
                leaf.nbytes // n_slots for key, leaf in self.cache.items()
                if key != "pos")
        else:
            self._block_bytes = sum(
                self.cache[k].nbytes // n_blocks for k in ("k", "v"))
        # per-shard KV accounting: the fraction of a KV leaf's bytes one
        # model shard actually holds (1.0 single-device, 1/m head-sharded,
        # 1.0 again when divisibility fell back to replication)
        self._kv_shard_frac = 1.0
        self.model_shards = 1
        if mesh is not None:
            self._place_on_mesh()

    def _place_on_mesh(self) -> None:
        """Place params, cache and supervisor state on the engine mesh.

        Cache leaves follow the logical cache axes (kv heads over "model"
        when divisible, head_dim fallback otherwise); params follow the
        same rule table the cluster supervisor plans with.  Per-slot
        decode/drafter state and the block-pool ledger are *replicated*:
        the pool's bookkeeping is global — every shard rents the same
        block id for its local head slice (replicated-with-local-rent) —
        so rent/release stay one transition, while the pages' bytes split
        across shards (`kv_stats` reports both views).
        """
        from repro.launch import inputs as inputs_lib
        from repro.models.params import _set
        mesh, rules = self.mesh, self.rules
        repl = NamedSharding(mesh, P())
        pspecs: dict = {}
        for d in model_lib.param_defs(self.cfg):
            _set(pspecs, d.path, rules.spec(d.axes, d.shape))
        psh = jax.tree_util.tree_map(
            lambda s: NamedSharding(mesh, s), pspecs,
            is_leaf=lambda x: isinstance(x, P))
        self.params = jax.device_put(self.params, psh)
        ax = inputs_lib.cache_axes(self.cfg, paged=self.layout is not None)
        csh = {k: NamedSharding(mesh, rules.spec(ax[k], v.shape))
               if k in ax else repl for k, v in self.cache.items()}
        self.cache = jax.device_put(self.cache, csh)
        self.dstate = jax.device_put(self.dstate, repl)
        self._first = jax.device_put(self._first, repl)
        if self.layout is not None:
            self.bstate = jax.device_put(self.bstate, repl)
        if self.spec:
            self.draft_state = jax.device_put(self.draft_state, repl)
        k = self.cache["k"]
        local = int(np.prod(k.sharding.shard_shape(k.shape)))
        self._kv_shard_frac = local / k.size
        self.model_shards = int(dict(mesh.shape).get("model", 1))

    # -- admission ---------------------------------------------------------
    def admit(self, req: Request) -> bool:
        return self.admit_many([req]) == 1

    def admit_many(self, requests: list[Request]) -> int:
        """Rent slots (and, paged, blocks) and prefill as many of
        `requests` as the pools allow; returns how many were consumed
        from the front of the list.

        Packed admission: one batched padded prefill per call (causal
        families); recurrent families fall back to one exact-length
        prefill per request through the same jitted path.

        With ``chunked_prefill`` the prompt is *not* prefilled at
        admission at all: the slot enters ``PHASE_PREFILL`` and the mixed
        tick feeds it one fragment per tick (paged blocks are rented
        chunk-granularly as fragments land, under the same §5.1
        worst-case reservation taken here).

        Edge cases (all host-side, before any compile):
        * an empty prompt raises ``ValueError`` (a packed prefill row of
          length 0 would gather its "last token" from row -1 — garbage
          as the first token);
        * a prompt longer than ``max_seq`` raises ``ValueError``;
        * a prompt of exactly ``max_seq`` is admitted with an effective
          budget of 1 (the prefill argmax) — no decode write can land
          past the cache;
        * ``max_new <= 0`` completes immediately with empty output.
        """
        # validate the whole batch before renting anything: a rejection
        # must never leave earlier requests granted-but-unprefilled
        for req in requests:
            if len(req.prompt) == 0:
                raise ValueError(
                    f"request {req.rid}: empty prompt; there is no last "
                    f"prompt token to gather first-token logits from — "
                    f"reject upstream")
            if len(req.prompt) + self._offset > self.max_seq:
                raise ValueError(
                    f"request {req.rid}: prompt length {len(req.prompt)}"
                    f"{f' (+{self._offset} frontend tokens)' if self._offset else ''}"
                    f" does not fit max_seq={self.max_seq}; reject or "
                    f"truncate upstream")
        granted: list[Request] = []
        consumed = 0
        for req in requests:
            plen = len(req.prompt) + self._offset
            if req.max_new <= 0:
                req.out = []
                self._finished_instant.append(req)
                consumed += 1
                continue
            slot = self.pool.rent()
            if slot is None:
                break                     # pool exhausted: queue upstream
            if self.layout is not None:
                plan = self._plan_chain(req.prompt, plen,
                                        self._max_new_eff(req, plen),
                                        rent_now=not self.chunked)
                if plan is None:          # block pool exhausted
                    self.pool.release(slot)
                    break
                if self.chunked:
                    self._commit_plan_chunked(slot, plan)
                else:
                    self._commit_plan(slot, plan, req.prompt)
            req.slot = slot
            self._admit_seq += 1
            self._slot_seq[slot] = self._admit_seq
            self._admit_wall[req.rid] = time.perf_counter()
            granted.append(req)
            consumed += 1
        if not granted:
            return consumed
        if self.chunked:
            # no device prefill here: the slot's QT starts in the
            # fragment-feeding phase and the mixed tick does the rest
            for req in granted:
                slot, plen = req.slot, len(req.prompt)
                job = _PrefillJob(
                    req=req, max_new_eff=self._max_new_eff(req, plen),
                    stream=np.asarray(req.prompt, np.int32))
                if self.layout is not None:
                    plan = self._plans[slot]
                    # a fully-shared prefix needs no recompute: fast-
                    # forward past it (but keep >= 1 token so the final
                    # fragment has a last position to take logits from)
                    job.cursor = min(plan.n_shared * self.layout.block_size,
                                     plen - 1)
                    job.registered = plan.n_shared
                self.cache["pos"] = self.cache["pos"].at[slot].set(
                    job.cursor)
                self.active[slot] = req
                self._jobs[slot] = job
                self.pool.set_phase(slot, pool_lib.PHASE_PREFILL)
            return consumed
        groups = [granted] if self._packed else [[r] for r in granted]
        for group in groups:
            self._prefill_group(group)
        for req in granted:
            self.active[req.slot] = req
            self._need_first.add(req.slot)
            self.pool.set_phase(req.slot, pool_lib.PHASE_DECODE)
            if self.spec:
                # the drafter's match window is the consumed stream;
                # the pending first token (device-side argmax) stays out
                self.draft_state = draft_lib.seed_slot(
                    self.draft_state, req.slot, req.prompt)
        return consumed

    def _max_new_eff(self, req: Request, plen: int) -> int:
        """Budget clamp: emitted tokens 2..max_new write at positions
        plen..plen+max_new-2, which must stay inside max_seq."""
        return min(req.max_new, self.max_seq - plen + 1)

    def _worst_blocks(self, plen: int, max_new_eff: int) -> int:
        """The §5.1 worst-case chain: blocks the stream may reach if it
        spends its whole budget (the last token is emitted, not
        written)."""
        return -(-(plen + max_new_eff - 1) // self.layout.block_size)

    def _reserved_blocks(self) -> int:
        """Blocks promised to in-flight chains beyond what they hold now
        (reserved admission's un-rented remainder; 0 under over-commit,
        which takes no reservations)."""
        return sum(
            max(0, p.worst_total - int(np.sum(self._tables_host[s] >= 0)))
            for s, p in self._plans.items())

    def _plan_chain(self, prompt, plen: int, max_new_eff: int,
                    rent_now: bool = True) -> Optional[_ChainPlan]:
        """Pick a token stream's blocks from the host mirror: reuse
        shared prefix blocks, rent new ones, and check the admission
        budget against the pool.  ``prompt`` is the stream actually
        prefilled — the request's prompt, or the replay stream (prompt +
        generated history) when a preempted request resumes.

        Reserved admission checks the §5.1 worst-case chain against the
        unreserved pool, so decode growth can never starve.  With
        ``self.overcommit`` admission asks only for what the stream
        needs *now* — the worst case is checked against the pool's total
        capacity only (a request that couldn't complete even alone is
        deferred, and `run_to_completion` reports its demand), and
        mid-flight shortfalls are the preemption path's job.

        With ``rent_now=False`` (chunked prefill) no new blocks are
        picked — the chain holds only the shared prefix and grows
        chunk-granularly as fragments are outsourced."""
        lo = self.layout
        bs = lo.block_size
        n_full = plen // bs
        shared: list[int] = []
        if self._prefix_sharing:
            for j in range(n_full):
                blk = self._prefix_map.get(self._prefix_key(prompt, j))
                if blk is None:
                    break
                shared.append(blk)
        total_now = -(-plen // bs)
        worst_total = self._worst_blocks(plen, max_new_eff)
        used = int(np.sum(self._ref_host > 0))
        if self.overcommit:
            if worst_total > lo.n_blocks:
                return None     # cannot complete even on an empty pool
            need_now = (total_now if rent_now else len(shared)) \
                - len(shared)
            if need_now > lo.n_blocks - used:
                return None
        else:
            budget = lo.n_blocks - used - self._reserved_blocks()
            if worst_total - len(shared) > budget:
                return None
        if not rent_now:
            return _ChainPlan(chain=list(shared), new_blocks=[],
                              n_shared=len(shared),
                              worst_total=worst_total)
        free_ids = np.flatnonzero(self._ref_host == 0)
        new_blocks = [int(b) for b in free_ids[:total_now - len(shared)]]
        return _ChainPlan(chain=shared + new_blocks, new_blocks=new_blocks,
                          n_shared=len(shared), worst_total=worst_total)

    def _commit_plan(self, slot: int, plan: _ChainPlan, prompt) -> None:
        """Host-mirror bookkeeping for a granted chain.  Prefix keys are
        registered here, *before* the group prefill, so later requests
        in the same admission round already share them (the group
        scatter stores each block exactly once)."""
        self._plans[slot] = plan
        self.shared_block_hits += plan.n_shared
        for b in plan.chain:
            self._ref_host[b] += 1
        row = self._tables_host[slot]
        row[:] = -1
        row[:len(plan.chain)] = plan.chain
        self._register_prefixes(prompt, plan)

    def _commit_plan_chunked(self, slot: int, plan: _ChainPlan) -> None:
        """Chunked admission commits only the *shared prefix*: reference
        it on the device immediately (a retiring source chain must never
        free blocks this request still needs) and seed the slot's block
        table with it; everything else is rented fragment by fragment
        inside the mixed tick (`paging.extend_chains`)."""
        self._plans[slot] = plan
        self.shared_block_hits += plan.n_shared
        row = self._tables_host[slot]
        row[:] = -1
        for b in plan.chain:
            self._ref_host[b] += 1
        row[:len(plan.chain)] = plan.chain
        if plan.chain:
            shared = jnp.asarray(plan.chain, jnp.int32)
            self.bstate = paging.admit_chains(
                self.bstate, shared, jnp.zeros((0,), jnp.int32))
            self.cache["block_tables"] = self.cache["block_tables"] \
                .at[slot, :len(plan.chain)].set(shared)

    def _prefix_key(self, prompt: np.ndarray, j: int):
        """Key for chain block j: its content is a pure function of the
        token prefix it covers (frontend stub tokens are constant)."""
        end = (j + 1) * self.layout.block_size - self._offset
        return (j, np.asarray(prompt[:max(0, end)], np.int32).tobytes())

    def _register_prefixes(self, prompt, plan: _ChainPlan) -> None:
        if not self._prefix_sharing:
            return
        plen = len(prompt) + self._offset
        n_full = plen // self.layout.block_size
        for j in range(plan.n_shared, n_full):
            key = self._prefix_key(prompt, j)
            blk = plan.chain[j]
            self._prefix_map[key] = blk
            self._block_hash[blk] = key

    def _prefill_group(self, group: list[Request]) -> None:
        g = len(group)
        n = self.pool.n
        maxlen = max(len(r.prompt) for r in group)
        span = _pow2_bucket(maxlen, self.max_seq) if self._packed else maxlen
        if self.layout is not None:
            # the paged scatter stores whole blocks: pad the span so the
            # group cache divides into block_size rows
            bs = self.layout.block_size
            span += (-(span + self._offset)) % bs
        # pad the group to a pow2 row count: compiles stay bounded to
        # log2(n_slots) variants per span bucket, while a single trickle
        # admission doesn't pay a full n_slots-row prefill
        gpad = _pow2_bucket(g, n) if self._packed else g
        tokens = np.zeros((gpad, span), np.int32)
        lengths = np.ones((gpad,), np.int32)
        max_new = np.zeros((gpad,), np.int32)
        slots = np.full((gpad,), n, np.int32)   # n = out of range -> dropped
        for i, r in enumerate(group):
            tokens[i, :len(r.prompt)] = r.prompt
            lengths[i] = len(r.prompt)
            max_new[i] = self._max_new_eff(r, len(r.prompt) + self._offset)
            slots[i] = r.slot
        if self.layout is None:
            self.dstate, self.cache, self._first = self._admit_fn(
                self.params, jnp.asarray(tokens), jnp.asarray(lengths),
                jnp.asarray(max_new), jnp.asarray(slots), self.dstate,
                self.cache, self._first)
        else:
            lo = self.layout
            nb_span = (span + self._offset) // lo.block_size
            gtables = np.full((gpad, lo.max_blocks(self.max_seq)), -1,
                              np.int32)
            wtargets = np.full((gpad, nb_span), lo.n_blocks, np.int32)
            for i, r in enumerate(group):
                plan = self._plans[r.slot]
                gtables[i, :len(plan.chain)] = plan.chain
                for j, blk in enumerate(plan.chain):
                    if j >= plan.n_shared:
                        wtargets[i, j] = blk
            (self.dstate, self.cache, self.bstate,
             self._first) = self._admit_fn(
                self.params, jnp.asarray(tokens), jnp.asarray(lengths),
                jnp.asarray(max_new), jnp.asarray(slots),
                jnp.asarray(gtables), jnp.asarray(wtargets), self.dstate,
                self.cache, self.bstate, self._first)
        # un-refactored baseline: one argmax sync per admitted request
        self.baseline_syncs += g

    # -- chunked prefill: fragment scheduler + unified tick ------------------
    def _schedule_fragments(self, width: Optional[int] = None,
                            only_slot: Optional[int] = None):
        """Pick this tick's prompt fragments (host side): one fragment of
        up to ``width`` (default ``prefill_chunk_tokens``) per PREFILLING
        slot, oldest job first, bounded by the per-tick token budget.
        With ``only_slot`` given, only that job is scheduled (the
        cold-start solo path packs one job up to the tick budget).
        Paged jobs also get their fragment's blocks picked from the free
        mirror here — the §5.1 reservation taken at admission guarantees
        the pick succeeds, and the ids are committed on device by the
        tick itself (`paging.extend_chains`), so host and device free
        lists cannot race."""
        n = self.pool.n
        C = self._pchunk if width is None else int(width)
        ft = np.zeros((n, C), np.int32)
        fl = np.zeros((n,), np.int32)
        flast = np.zeros((n,), bool)
        fmax = np.zeros((n,), np.int32)
        fskip = np.zeros((n,), np.int32)
        paged = self.layout is not None
        if paged:
            bs = self.layout.block_size
            frent = np.full((n, C // bs + 2), -1, np.int32)
            fcols = np.zeros((n, C // bs + 2), np.int32)
        budget = self._tick_budget if self._tick_budget is not None \
            else C * n
        finishing: list[int] = []
        for slot, job in list(self._jobs.items()):
            if only_slot is not None and slot != only_slot:
                continue
            if budget <= 0:
                break                 # token budget spent: rest wait a tick
            prompt = job.stream
            plen = len(prompt)
            take = min(C, plen - job.cursor, budget)
            if take <= 0:
                continue
            if paged and self.overcommit:
                # admit on current need: the fragment may only write
                # positions the free pool can cover — a shortfall clamps
                # the fragment (the job waits) and flags pressure so the
                # host loop evicts a victim at the sync
                plan = self._plans[slot]
                need = (job.cursor + take - 1) // bs + 1
                if need > len(plan.chain):
                    free_now = int(np.sum(self._ref_host == 0))
                    cover = (len(plan.chain) + free_now) * bs - job.cursor
                    if cover < take:
                        self._pressure = True
                        take = cover
                        if take <= 0:
                            continue
            ft[slot, :take] = prompt[job.cursor:job.cursor + take]
            fl[slot] = take
            fmax[slot] = job.max_new_eff
            last = job.cursor + take >= plen
            flast[slot] = last
            if paged:
                plan = self._plans[slot]
                fskip[slot] = plan.n_shared * bs
                need = (job.cursor + take - 1) // bs + 1
                k_i = 0
                while len(plan.chain) < need:
                    blk = int(np.flatnonzero(self._ref_host == 0)[0])
                    col = len(plan.chain)
                    self._ref_host[blk] += 1
                    self._tables_host[slot, col] = blk
                    frent[slot, k_i] = blk
                    fcols[slot, k_i] = col
                    plan.chain.append(blk)
                    k_i += 1
                if self._prefix_sharing:
                    # publish prefix-map entries for the full blocks this
                    # fragment completes: a block becomes shareable only
                    # once its writing tick is dispatched
                    done_full = min((job.cursor + take) // bs, plen // bs)
                    for j in range(job.registered, done_full):
                        key = self._prefix_key(prompt, j)
                        self._prefix_map[key] = plan.chain[j]
                        self._block_hash[plan.chain[j]] = key
                    job.registered = max(job.registered, done_full)
            job.cursor += take
            budget -= take
            if last:
                finishing.append(slot)
        out = (ft, fl, flast, fmax, fskip)
        if paged:
            out = out + (fcols, frent)
        return out, finishing

    def _refresh_block_mirrors(self, tables_d, ref_d) -> None:
        """Host mirrors of the device block state, refreshed at every
        paged tick sync — admission never blocks on the device."""
        self._tables_host = np.asarray(tables_d).copy()
        self._ref_host = np.asarray(ref_d).copy()

    def _decoding_slots(self) -> list[int]:
        """Active slots currently in the decode phase (not mid-prefill)."""
        if not self._jobs:
            return list(self.active)
        return [s for s in self.active if s not in self._jobs]

    def _finish_jobs(self, finishing: list[int]) -> dict[int, _PrefillJob]:
        """PREFILL -> DECODE transitions for slots whose final fragment
        just ran; returns {slot: job} so the emission loop can apply the
        resume replay-token bookkeeping (``drop_first``)."""
        fin: dict[int, _PrefillJob] = {}
        for slot in finishing:
            job = self._jobs.pop(slot)
            fin[slot] = job
            self.pool.set_phase(slot, pool_lib.PHASE_DECODE)
            self.baseline_syncs += 1
            if self.spec:
                # the drafter's match window is the consumed stream —
                # for a resumed request that is prompt + replayed
                # history, exactly what it held before eviction
                self.draft_state = draft_lib.seed_slot(
                    self.draft_state, slot, job.stream)
        return fin

    def _checked_row(self, req: Request, slot: int, row):
        """Host-side output tripwire over one *already-synced* emitted
        row: NaN/inf for float buffers, vocab-range for the int32 token
        buffers the ticks actually emit.  Raises
        :class:`OutputValidationError` with slot/tick attribution —
        before the row can reach ``req.out``, so a poisoned replica's
        host-side token history stays clean for migration replay.  Reads
        only host memory: no device sync is added (the PR 8 transfer
        audit stays clean)."""
        if self._poison_pending:
            # an armed NaN fault poisoned the device cache; at the int32
            # token boundary the corruption surfaces as an out-of-range
            # bit pattern in the next synced row (see runtime/faults.py)
            row = np.array(row, copy=True)
            if row.size:
                row[0] = faults_lib.POISON_TOKEN
            self._poison_pending = False
        if not self.validate_outputs:
            return row
        arr = np.asarray(row)
        if np.issubdtype(arr.dtype, np.floating):
            if not np.all(np.isfinite(arr)):
                raise OutputValidationError(
                    f"non-finite emitted value for slot {slot} (rid "
                    f"{req.rid}) at device tick {self.device_ticks}")
        else:
            bad = arr[(arr != NO_TOKEN)
                      & ((arr < 0) | (arr >= self._vocab_bound))]
            if bad.size:
                raise OutputValidationError(
                    f"invalid token {int(bad[0])} emitted for slot {slot} "
                    f"(rid {req.rid}) at device tick {self.device_ticks}: "
                    f"outside [0, {self._vocab_bound}) — NaN/garbage "
                    f"logits upstream")
        return row

    def _emit_row(self, req: Request, slot: int, row,
                  fin: dict[int, _PrefillJob]) -> int:
        """Deliver one emitted row to `req`; returns how many *decode*
        tokens it carried (a finishing fragment's first token is prefill
        output, and a resumed job's replayed token is dropped — already
        delivered before eviction — after an exactness check)."""
        row = self._checked_row(req, slot, row)
        new_toks = [int(t) for t in row if t != NO_TOKEN]
        job = fin.get(slot)
        if job is not None and job.drop_first and new_toks:
            replay = new_toks.pop(0)
            if not req.out or replay != req.out[-1]:
                if job.migrated:
                    self.migrate_replay_mismatches += 1
                else:
                    self.preempt_replay_mismatches += 1
        req.out.extend(new_toks)
        return 0 if slot in fin else len(new_toks)

    def _solo_step(self) -> list[Request]:
        """Cold-start packed prefill: no slot is decoding, so one job's
        fragments are packed up to the per-tick budget and run through a
        single-row tick — no fairness to protect, no n_slots-row tax."""
        slot = next(iter(self._jobs))          # oldest job first
        sched, finishing = self._schedule_fragments(
            width=self._solo_width, only_slot=slot)
        s1 = slice(slot, slot + 1)
        if self.layout is None:
            ft, fl, flast, fmax, _ = sched
            self.dstate, self.cache, emitted = self._solo_fn(
                self.params, self.dstate, self.cache, jnp.int32(slot),
                jnp.asarray(ft[s1]), jnp.asarray(fl[s1]),
                jnp.asarray(flast[s1]), jnp.asarray(fmax[s1]))
            em, active_mask = jax.device_get((emitted, self.dstate.active))
        else:
            ft, fl, flast, fmax, fskip, fcols, frent = sched
            (self.dstate, self.cache, self.bstate,
             emitted) = self._solo_fn(
                self.params, self.dstate, self.cache, self.bstate,
                jnp.int32(slot), jnp.asarray(ft[s1]), jnp.asarray(fl[s1]),
                jnp.asarray(flast[s1]), jnp.asarray(fmax[s1]),
                jnp.asarray(fskip[s1]), jnp.asarray(fcols),
                jnp.asarray(frent))
            em, active_mask, tables_d, ref_d = jax.device_get(
                (emitted, self.dstate.active, self.cache["block_tables"],
                 self.bstate.refcount))
            self._refresh_block_mirrors(tables_d, ref_d)
        self.host_syncs += 1
        self.device_ticks += 1
        fin = self._finish_jobs(finishing)
        finished: list[Request] = []
        for s in finishing:                    # at most [slot]
            req = self.active[s]
            self._emit_row(req, s, em, fin)
            if not active_mask[s]:             # max_new == 1 retires now
                finished.append(req)
                del self.active[s]
                self._retire_slot(s, req)
        return finished

    def _spec_chunk_step(self) -> list[Request]:
        """Pure-decode speculation: up to ``chunk`` draft/verify/accept
        cycles inside one jitted loop — one host sync."""
        if self.layout is None:
            (self.dstate, self.draft_state, self.cache, emitted, fwd,
             slot_fwd, drafted, accepted) = self._spec_chunk_fn(
                self.params, self.dstate, self.draft_state, self.cache)
            (em, active_mask, first, fwd, slot_fwd, drafted,
             accepted) = jax.device_get(
                (emitted, self.dstate.active, self._first, fwd, slot_fwd,
                 drafted, accepted))
        else:
            (self.dstate, self.draft_state, self.cache, self.bstate,
             emitted, fwd, slot_fwd, drafted, accepted,
             stalls) = self._spec_chunk_fn(
                self.params, self.dstate, self.draft_state, self.cache,
                self.bstate)
            (em, active_mask, first, fwd, slot_fwd, drafted, accepted,
             stalls, tables_d, ref_d) = jax.device_get(
                (emitted, self.dstate.active, self._first, fwd, slot_fwd,
                 drafted, accepted, stalls, self.cache["block_tables"],
                 self.bstate.refcount))
            self._refresh_block_mirrors(tables_d, ref_d)
            self.stalls += int(stalls)
        self.host_syncs += 1
        self.device_ticks += int(fwd)
        self.spec_forwards += int(fwd)
        self.spec_slot_forwards += int(slot_fwd)
        self.spec_drafted += int(drafted)
        self.spec_accepted += int(accepted)
        finished: list[Request] = []
        for slot, req in list(self.active.items()):
            if slot in self._need_first:
                req.out.append(int(first[slot]))
                self._need_first.discard(slot)
            row = self._checked_row(req, slot, em[slot])
            new_toks = [int(t) for t in row if t != NO_TOKEN]
            req.out.extend(new_toks)
            self.decode_tokens += len(new_toks)
            self.spec_decode_tokens += len(new_toks)
            self.baseline_syncs += len(new_toks)
            if not active_mask[slot]:
                # hand off through _finished_instant and retire BEFORE
                # dropping from `active`: if a corrupt ledger makes the
                # release raise mid-loop, every request finished this
                # tick is still reachable — rescued or drained by the
                # fleet's quarantine, whose replay re-derives any tokens
                # the raise discarded
                self._finished_instant.append(req)
                self._retire_slot(slot, req)
                del self.active[slot]
        finished += self._finished_instant
        self._finished_instant = []
        return finished

    def _spec_step(self) -> list[Request]:
        """One speculative tick: every DECODING slot drafts ahead and
        gets up to ``spec_k + 1`` tokens verified in the shared forward;
        PREFILLING slots keep consuming prompt fragments; one host
        sync."""
        # pure decode goes through _spec_chunk_step; this tick only runs
        # while prompt fragments (admission or resume) are outsourced
        assert self._jobs
        W = self._spec_width
        decoding = self._decoding_slots()
        sched, finishing = self._schedule_fragments()
        if self.layout is None:
            ft, fl, flast, fmax, _ = sched
        else:
            ft, fl, flast, fmax, fskip, fcols, frent = sched
        if W > self._pchunk:
            ft = np.pad(ft, ((0, 0), (0, W - self._pchunk)))
        if self.layout is None:
            (self.dstate, self.draft_state, self.cache, emitted, drafted,
             accepted) = self._spec_fn(
                self.params, self.dstate, self.draft_state, self.cache,
                jnp.asarray(ft), jnp.asarray(fl), jnp.asarray(flast),
                jnp.asarray(fmax))
            em, active_mask, first, drafted, accepted = jax.device_get(
                (emitted, self.dstate.active, self._first, drafted,
                 accepted))
        else:
            (self.dstate, self.draft_state, self.cache, self.bstate,
             emitted, drafted, accepted, stalls) = self._spec_fn(
                self.params, self.dstate, self.draft_state, self.cache,
                self.bstate, jnp.asarray(ft), jnp.asarray(fl),
                jnp.asarray(flast), jnp.asarray(fmax), jnp.asarray(fskip),
                jnp.asarray(fcols), jnp.asarray(frent))
            (em, active_mask, first, drafted, accepted, stalls, tables_d,
             ref_d) = jax.device_get(
                (emitted, self.dstate.active, self._first, drafted,
                 accepted, stalls, self.cache["block_tables"],
                 self.bstate.refcount))
            self._refresh_block_mirrors(tables_d, ref_d)
            self.stalls += int(stalls)
        self.host_syncs += 1
        self.device_ticks += 1
        if decoding:
            self.spec_forwards += 1
            self.spec_slot_forwards += len(decoding)
            self.spec_drafted += int(drafted)
            self.spec_accepted += int(accepted)
        fin = self._finish_jobs(finishing)
        finished: list[Request] = []
        for slot, req in list(self.active.items()):
            if slot in self._jobs:
                continue               # mid-prefill: nothing emitted yet
            if slot in self._need_first:
                req.out.append(int(first[slot]))
                self._need_first.discard(slot)
            n_dec = self._emit_row(req, slot, em[slot], fin)
            self.decode_tokens += n_dec
            self.spec_decode_tokens += n_dec
            self.baseline_syncs += n_dec
            if not active_mask[slot]:
                # hand off through _finished_instant and retire BEFORE
                # dropping from `active`: if a corrupt ledger makes the
                # release raise mid-loop, every request finished this
                # tick is still reachable — rescued or drained by the
                # fleet's quarantine, whose replay re-derives any tokens
                # the raise discarded
                self._finished_instant.append(req)
                self._retire_slot(slot, req)
                del self.active[slot]
        finished += self._finished_instant
        self._finished_instant = []
        return finished

    def _mixed_step(self) -> list[Request]:
        """One unified prefill/decode tick: every PREFILLING slot eats a
        fragment, every DECODING slot one token; one host sync."""
        sched, finishing = self._schedule_fragments()
        if self.layout is None:
            ft, fl, flast, fmax, _ = sched
            self.dstate, self.cache, emitted = self._mixed_fn(
                self.params, self.dstate, self.cache, jnp.asarray(ft),
                jnp.asarray(fl), jnp.asarray(flast), jnp.asarray(fmax))
            em, active_mask, first = jax.device_get(
                (emitted, self.dstate.active, self._first))
        else:
            ft, fl, flast, fmax, fskip, fcols, frent = sched
            (self.dstate, self.cache, self.bstate, emitted,
             stalls) = self._mixed_fn(
                self.params, self.dstate, self.cache, self.bstate,
                jnp.asarray(ft), jnp.asarray(fl), jnp.asarray(flast),
                jnp.asarray(fmax), jnp.asarray(fskip), jnp.asarray(fcols),
                jnp.asarray(frent))
            em, active_mask, first, stalls, tables_d, ref_d = jax.device_get(
                (emitted, self.dstate.active, self._first, stalls,
                 self.cache["block_tables"], self.bstate.refcount))
            self._refresh_block_mirrors(tables_d, ref_d)
            self.stalls += int(stalls)
        self.host_syncs += 1
        self.device_ticks += 1
        # PREFILL -> DECODE for finishing slots: the final fragment's
        # argmax is the first token (what monolithic admission paid one
        # sync for) — or, resuming, the replayed token dropped below
        fin = self._finish_jobs(finishing)
        finished: list[Request] = []
        for slot, req in list(self.active.items()):
            if slot in self._jobs:
                continue               # mid-prefill: nothing emitted yet
            if slot in self._need_first:
                # a monolithically admitted slot decoding through the
                # mixed tick (resume jobs share it) delivers its
                # admission-prefill first token here, in order
                req.out.append(int(first[slot]))
                self._need_first.discard(slot)
            n_dec = self._emit_row(req, slot, em[slot], fin)
            self.decode_tokens += n_dec
            self.baseline_syncs += n_dec
            if not active_mask[slot]:
                # hand off through _finished_instant and retire BEFORE
                # dropping from `active`: if a corrupt ledger makes the
                # release raise mid-loop, every request finished this
                # tick is still reachable — rescued or drained by the
                # fleet's quarantine, whose replay re-derives any tokens
                # the raise discarded
                self._finished_instant.append(req)
                self._retire_slot(slot, req)
                del self.active[slot]
        finished += self._finished_instant
        self._finished_instant = []
        return finished

    # -- one decode chunk over all active slots -----------------------------
    def step(self) -> list[Request]:
        """Advance every active slot up to `chunk` tokens; one host sync.

        With ``debug_transfers=True`` the whole tick runs under
        ``jax.transfer_guard_device_to_host("disallow")``: the budgeted
        per-tick sync is an *explicit* ``jax.device_get`` (as is every
        pool-ledger read), so it passes, while any stray implicit
        device->host transfer smuggled into the serving path — an
        ``int()``/``bool()``/``np.asarray`` on a device array — raises
        instead of silently serializing the dispatch stream.  The
        static auditor's transfer harness runs engines in this mode."""
        if not self.debug_transfers:
            return self._step()
        with jax.transfer_guard_device_to_host("disallow"):
            return self._step()

    def _step(self) -> list[Request]:
        """Advance every active slot up to `chunk` tokens; one host sync.

        With chunked prefill, while any slot is still consuming prompt
        fragments (admission *or* a preempted request's resume replay)
        the engine ticks the unified prefill/decode step instead (one
        token per decoding slot, one fragment per prefilling slot,
        bounded latency); once every prompt is absorbed it returns to
        multi-token decode chunks.

        Over-commit supervision brackets the tick: parked requests are
        re-admitted up front when the pool can take them back, and a
        tick that ran the pool dry (device stall or host scheduling
        shortfall) evicts one victim at the sync."""
        finished: list[Request] = []
        if self._finished_instant:
            # drained optimistically; a raise below restores them so the
            # fleet's quarantine rescue still delivers them exactly once
            finished, self._finished_instant = self._finished_instant, []
        try:
            finished = finished + self._tick()
        except BaseException:
            self._finished_instant = finished + self._finished_instant
            raise
        if self._frontier_rids:
            self._frontier_epilogue(finished)
        return finished

    def _tick(self) -> list[Request]:
        """One supervised tick: frontier admission, parked resume, the
        jitted device step, then over-commit pressure relief."""
        finished: list[Request] = []
        if self._frontier or self._displaced:
            self._admit_frontier()
        if self._parked:
            self._resume_parked(force=not self.active)
        if not self.active:
            return finished
        if self._faults is not None:
            # chaos hook: fires only between jitted ticks, only when a
            # plan is armed (lint/fault-hook enforces this stays guarded)
            self._fire_faults(self._faults)
            self._fault_step += 1
        self.occ_ticks += 1
        self.occ_slot_ticks += len(self.active)
        stall_mark = self.stalls
        t0 = time.perf_counter()
        if self._jobs and not self._decoding_slots():
            # nobody decoding -> no fairness to protect: pack one job's
            # fragments up to the tick budget through the solo tick
            finished += self._solo_step()
        elif self.spec:
            if self._jobs:
                finished += self._spec_step()
            else:
                finished += self._spec_chunk_step()
        elif self._jobs:
            finished += self._mixed_step()
        else:
            finished += self._decode_step()
        # decode-phase wall clock: time spent inside serving ticks, i.e.
        # excluding admission prefill and host queueing — the
        # denominator of the bench's decode tokens/s (admission work is
        # identical across engine configs and, on CPU, dominated by
        # per-prompt-bucket XLA compiles that would drown the signal)
        dt = time.perf_counter() - t0
        self.decode_wall_s += dt
        self.last_tick_wall_s = dt
        if self.overcommit and (self._pressure or self.stalls > stall_mark):
            # the tick ran the block pool dry: claw chains back until a
            # block actually came free — a fully-shared victim relieves
            # nothing (evict_chain frees 0), so parking it alone would
            # spend a replay without moving the pressure
            self._pressure = False
            while True:
                free0 = int(np.sum(self._ref_host == 0))
                if self.preempt() is None:
                    break
                if int(np.sum(self._ref_host == 0)) > free0:
                    break
        return finished

    def _decode_step(self) -> list[Request]:
        """The multi-token decode chunk (no prefill fragments pending)."""
        finished: list[Request] = []
        if self.layout is None:
            self.dstate, self.cache, emitted, iters = self._chunk_fn(
                self.params, self.dstate, self.cache)
            em, active_mask, first, iters = jax.device_get(
                (emitted, self.dstate.active, self._first, iters))
        else:
            (self.dstate, self.cache, self.bstate, emitted, iters,
             stalls) = self._chunk_fn(self.params, self.dstate, self.cache,
                                      self.bstate)
            (em, active_mask, first, iters, stalls, tables_d,
             ref_d) = jax.device_get(
                (emitted, self.dstate.active, self._first, iters, stalls,
                 self.cache["block_tables"], self.bstate.refcount))
            # refresh the host mirrors with the chunk's on-device growth
            self._refresh_block_mirrors(tables_d, ref_d)
            self.stalls += int(stalls)
        self.host_syncs += 1
        self.device_ticks += int(iters)
        for slot, req in list(self.active.items()):
            if slot in self._need_first:
                req.out.append(int(first[slot]))
                self._need_first.discard(slot)
            row = self._checked_row(req, slot, em[slot])
            new_toks = [int(t) for t in row if t != NO_TOKEN]
            req.out.extend(new_toks)
            self.decode_tokens += len(new_toks)
            self.baseline_syncs += len(new_toks)
            if not active_mask[slot]:
                # hand off through _finished_instant and retire BEFORE
                # dropping from `active`: if a corrupt ledger makes the
                # release raise mid-loop, every request finished this
                # tick is still reachable — rescued or drained by the
                # fleet's quarantine, whose replay re-derives any tokens
                # the raise discarded
                self._finished_instant.append(req)
                self._retire_slot(slot, req)
                del self.active[slot]
        finished += self._finished_instant
        self._finished_instant = []
        return finished

    # -- preemption: evict under KV pressure, resume by replay --------------
    def _drop_chain_host(self, slot: int, evict: bool) -> None:
        """Drop `slot`'s block chain on device *and* in the host mirrors
        (prefix-map upkeep included) — the shared tail of retirement and
        eviction.  Refcount-aware on both sides: a shared prefix block
        another chain references survives."""
        plan = self._plans.pop(slot)
        chain = self._tables_host[slot]
        chain = chain[chain >= 0]
        self.kv_bytes_allocated += \
            (len(chain) - plan.n_shared) * self._block_bytes
        if evict:
            self.bstate, tables, _ = paging.evict_chain(
                self.bstate, self.cache["block_tables"], slot)
        else:
            self.bstate, tables = paging.release_chain(
                self.bstate, self.cache["block_tables"], slot)
        self.cache = dict(self.cache, block_tables=tables)
        for b in chain:
            self._ref_host[b] -= 1
            if self._ref_host[b] == 0:
                key = self._block_hash.pop(int(b), None)
                if key is not None and self._prefix_map.get(key) == int(b):
                    del self._prefix_map[key]
        self._tables_host[slot] = -1

    def _pick_victim(self) -> Optional[int]:
        """The eviction policy: throughput tier before latency tier
        (otherwise pressure eviction would immediately claw back the
        slot a latency arrival just displaced for — on an untiered
        stream the tier key is constant and the policy is unchanged),
        then fewest tokens generated, ties broken toward the latest
        admission (LIFO under equal progress).  The last running slot
        is never evicted — the maximal-progress request always retires
        and frees its chain, which is what makes over-commit terminate
        instead of thrash."""
        if len(self.active) <= 1:
            return None
        return min(self.active,
                   key=lambda s: (self.active[s].tier == "latency",
                                  len(self.active[s].out),
                                  -self._slot_seq.get(s, 0)))

    def preempt(self, slot: Optional[int] = None) -> Optional[int]:
        """Supervisor-initiated eviction: claw back a slot's rented KV
        and park its request (PHASE_PREEMPTED) with its full token
        history for a later recompute-based resume.  Call between steps
        (the host owns synced state there).  With ``slot=None`` the
        victim policy picks; returns the parked request's rid, or
        ``None`` when nothing is evictable."""
        if not self._can_preempt:
            raise RuntimeError(
                "preemption needs the chunked-prefill resume path "
                "(causal attention cache, no frontend)")
        if slot is None:
            slot = self._pick_victim()
            if slot is None:
                return None
        elif slot not in self.active:
            raise ValueError(f"slot {slot} has no active request")
        req = self.active.pop(slot)
        self._jobs.pop(slot, None)
        self._need_first.discard(slot)
        # device: the slot goes dark — exactly the shape a never-admitted
        # slot has, so the next tick cannot read or write through it
        self.dstate = self.dstate._replace(
            active=self.dstate.active.at[slot].set(False))
        self.cache["pos"] = self.cache["pos"].at[slot].set(0)
        if self.layout is not None:
            self._drop_chain_host(slot, evict=True)
        if self.spec:
            self.draft_state = draft_lib.evict_slot(self.draft_state, slot)
        self._parked[slot] = req
        self._park_order.append(slot)
        self.pool.set_phase(slot, pool_lib.PHASE_PREEMPTED)
        self.preemptions += 1
        self.preempted_tokens += len(req.out)
        self._evicted_recently = True
        return req.rid

    def _resume_stream(self, req: Request):
        """The replay stream for a parked request: prompt + everything
        generated *except* the pending last token (its KV row was never
        written — it is what the final replay fragment's argmax
        reproduces), plus the remaining device budget."""
        plen = len(req.prompt) + self._offset
        eff = self._max_new_eff(req, plen)
        if not req.out:
            return np.asarray(req.prompt, np.int32), eff, False
        stream = np.concatenate(
            [np.asarray(req.prompt, np.int32),
             np.asarray(req.out[:-1], np.int32)])
        # the device counts n_out from 1 at the PREFILL -> DECODE
        # transition, so the replayed budget is the *remaining* tokens
        # plus the replayed one
        return stream, eff - len(req.out) + 1, True

    def _resume_parked(self, force: bool = False) -> None:
        """Re-admit parked requests (oldest eviction first) through the
        chunked-prefill path.  A one-step damper after an eviction keeps
        a resume from stealing back the blocks the eviction just freed
        for the pressured runners; ``force`` overrides it when nothing
        else can run."""
        if self._evicted_recently and not force:
            self._evicted_recently = False
            return
        while self._park_order:
            slot = self._park_order[0]
            req = self._parked[slot]
            stream, max_new_eff, drop = self._resume_stream(req)
            job = _PrefillJob(req=req, max_new_eff=max_new_eff,
                              stream=stream, drop_first=drop)
            if self.layout is not None:
                plan = self._plan_chain(stream, len(stream) + self._offset,
                                        max_new_eff, rent_now=False)
                if plan is None:
                    break            # no capacity yet; FIFO order holds
                if self.overcommit and not force \
                        and plan.n_shared * self.layout.block_size \
                        < len(stream) \
                        and not np.any(self._ref_host == 0):
                    break            # replay would stall on its first
                    #                  unshared fragment: wait for blocks
                self._commit_plan_chunked(slot, plan)
                # a fully-shared replay prefix needs no recompute (but
                # keep >= 1 token for the final fragment's logits)
                job.cursor = min(plan.n_shared * self.layout.block_size,
                                 len(stream) - 1)
                job.registered = plan.n_shared
            self._park_order.pop(0)
            del self._parked[slot]
            self.cache["pos"] = self.cache["pos"].at[slot].set(job.cursor)
            self.active[slot] = req
            self._jobs[slot] = job
            self.pool.set_phase(slot, pool_lib.PHASE_PREFILL)
            self._admit_seq += 1
            self._slot_seq[slot] = self._admit_seq
            self.resumes += 1

    def _retire_slot(self, slot: int, req: Request) -> None:
        """Return the core — and, paged, the block chain — to the pool
        (§4.3 terminate)."""
        self.tokens_finished += len(req.prompt) + len(req.out)
        if self.layout is None:
            self.kv_bytes_allocated += self._slot_bytes
            self.pool.release(slot)
            return
        self._drop_chain_host(slot, evict=False)
        self.pool.release(slot)

    # -- chaos & health ------------------------------------------------------
    def arm_faults(self, faults) -> None:
        """Arm a :class:`runtime.faults.ReplicaFaults` schedule.  Until
        this is called the fault hooks in the tick path are dead code —
        ``self._faults`` stays ``None`` and every hook is behind that
        guard (the ``lint/fault-hook`` rule enforces it stays that way,
        and that no compiled tick ever branches on fault state)."""
        self._faults = faults

    def _fire_faults(self, faults) -> None:
        """Apply every due fault event (host-side, between ticks)."""
        for ev in faults.due(self._fault_step):
            if ev.kind == "tick_exception":
                raise faults_lib.InjectedFault(
                    f"injected tick exception at step {self._fault_step}")
            if ev.kind == "hang":
                time.sleep(ev.hang_s)
            elif ev.kind == "nan_poison":
                self.cache = faults_lib.poison_cache(self.cache)
                self._poison_pending = True
            elif ev.kind == "ledger_corruption":
                faults_lib.corrupt_pool_ledger(self.pool)

    def health_check(self) -> Optional[str]:
        """Sample the host-side slot-pool ledger invariants; returns a
        reason string when the replica should be quarantined, ``None``
        when healthy.  Reads only the host ledger mirror — no device
        sync — so the fleet can afford it every tick."""
        reason = pool_lib.invariant_violation(self.pool.state)
        if reason is not None:
            return f"slot-pool ledger: {reason}"
        return None

    def _replay_admit(self, req: Request, *, migrated: bool) -> bool:
        """Rent a *fresh* slot and replay ``req``'s prompt + generated
        history through the chunked-prefill resume path — the shared
        core of fleet migration (:meth:`adopt`) and tier-displacement
        re-admission.  Token-exact by greedy determinism; the replayed
        pending token is cross-checked in ``_emit_row`` (mismatches book
        into ``migrate_replay_mismatches`` or
        ``preempt_replay_mismatches`` by origin).  Returns False without
        side effects when there is no capacity right now."""
        slot = self.pool.rent()
        if slot is None:
            return False
        stream, max_new_eff, drop = self._resume_stream(req)
        job = _PrefillJob(req=req, max_new_eff=max_new_eff,
                          stream=stream, drop_first=drop, migrated=migrated)
        if self.layout is not None:
            plan = self._plan_chain(stream, len(stream) + self._offset,
                                    max_new_eff, rent_now=False)
            if plan is None:
                self.pool.release(slot)
                return False
            self._commit_plan_chunked(slot, plan)
            job.cursor = min(plan.n_shared * self.layout.block_size,
                             len(stream) - 1)
            job.registered = plan.n_shared
        self.cache["pos"] = self.cache["pos"].at[slot].set(job.cursor)
        req.slot = slot
        self.active[slot] = req
        self._jobs[slot] = job
        self.pool.set_phase(slot, pool_lib.PHASE_PREFILL)
        self._admit_seq += 1
        self._slot_seq[slot] = self._admit_seq
        self._admit_wall[req.rid] = time.perf_counter()
        return True

    def adopt(self, req: Request) -> bool:
        """Adopt an in-flight request drained from a quarantined sibling:
        replay prompt + generated-so-far through the chunked-prefill
        resume path (the same machinery preemption uses), token-exact by
        greedy determinism — the replayed pending token is cross-checked
        in ``_emit_row`` and any divergence counts in
        ``migrate_replay_mismatches``.  Returns False (without side
        effects) when this engine has no capacity right now."""
        if not self._can_preempt:
            raise RuntimeError(
                "migration needs the chunked-prefill resume path: "
                "construct the engine with chunked=True")
        if not self._replay_admit(req, migrated=True):
            return False
        self.migrations_in += 1
        return True

    # -- priority tiers: the async request frontier --------------------------
    @property
    def has_work(self) -> bool:
        """Anything left for an open-loop driver: queued arrivals,
        displaced victims awaiting re-admission, in-flight or parked
        requests, or finished-but-unreported ones."""
        return bool(self._frontier or self._displaced or self.active
                    or self._parked or self._finished_instant)

    def submit(self, req: Request, now: Optional[float] = None) -> None:
        """Async frontier entry: enqueue an arrival without blocking.
        Admission happens tier-aware at the next :meth:`step` (a
        latency-tier arrival jumps the queue and may displace
        throughput-tier victims); completions surface through
        :meth:`poll`.  Stamps the request into the per-tier SLO ledger
        (:class:`~repro.runtime.accounting.TierAccounting`) — pass
        ``now`` to replay a recorded arrival trace."""
        self.sla.arrive(req.rid, req.tier, now=now)
        self._frontier_rids.add(req.rid)
        self._frontier.append(req)

    def poll(self) -> list[Request]:
        """Drain finished frontier-submitted requests (non-blocking)."""
        done, self._completed = self._completed, []
        return done

    def _frontier_epilogue(self, finished: list[Request]) -> None:
        """Post-tick SLO stamping + completion routing for
        frontier-submitted requests.  Host lists and one
        ``perf_counter`` only — the tick's sync economy is untouched."""
        now = time.perf_counter()
        for req in self.active.values():
            if req.rid in self._frontier_rids:
                self.sla.observe(req.rid, len(req.out), now=now)
        for req in finished:
            if req.rid in self._frontier_rids:
                self.sla.observe(req.rid, len(req.out), now=now)
                self.sla.finish(req.rid)
                self._frontier_rids.discard(req.rid)
                self._completed.append(req)

    def _admit_frontier(self) -> None:
        """Drain the frontier tier-first (host side, between ticks):
        latency-tier arrivals admit ahead of queue order — displacing
        throughput-tier victims when the pools are full — then displaced
        victims re-enter before fresh throughput arrivals (they already
        hold generated tokens; replaying them promptly is what keeps
        their streams short), then throughput arrivals admit FIFO until
        one fails."""
        keep: list[Request] = []
        blocked = False
        for req in self._frontier:
            if req.tier != "latency":
                keep.append(req)
                continue
            if blocked or not self.admit_displacing(req):
                keep.append(req)
                blocked = True
        self._frontier = keep
        if blocked:
            return          # a latency head is starved: nothing jumps it
        while self._displaced:
            if not self._replay_admit(self._displaced[0], migrated=False):
                return
            self._displaced.pop(0)
            self.resumes += 1
        while self._frontier:
            if not self.admit(self._frontier[0]):
                return
            self._frontier.pop(0)

    def admit_displacing(self, req: Request) -> bool:
        """The tiered admission controller: try a plain admit; when a
        *latency-tier* arrival cannot rent a slot or blocks, displace
        throughput-tier victims through the public :meth:`preempt` hook
        (KV clawback) plus a full slot release, until the arrival fits
        or no throughput-tier victim remains.  A latency-tier arrival
        never displaces a latency-tier slot."""
        if self.admit(req):
            return True
        if req.tier != "latency" or not self._can_preempt:
            return False
        while True:
            victim = self._pick_displacement_victim()
            if victim is None:
                return False
            self._displace(victim)
            if self.admit(req):
                return True

    def _pick_displacement_victim(self) -> Optional[int]:
        """Displacement victim for a latency-tier arrival: throughput
        tier ONLY — by construction a latency arrival never evicts a
        latency slot (the property the conformance suite asserts).
        Parked throughput requests go first (they hold a slot but no
        KV, so displacing them frees a core without clawing back any
        chain); among active ones the over-commit victim policy applies
        (fewest tokens generated, ties to the latest admission)."""
        for slot in self._park_order:
            if self._parked[slot].tier != "latency":
                return slot
        cand = [s for s, r in self.active.items() if r.tier != "latency"]
        if not cand:
            return None
        return min(cand, key=lambda s: (len(self.active[s].out),
                                        -self._slot_seq.get(s, 0)))

    def _displace(self, slot: int) -> Request:
        """Fully evict ``slot``'s throughput-tier request — KV *and*
        core — so a latency-tier arrival can rent both.  An active
        victim goes through the public :meth:`preempt` hook first
        (chain clawback + park bookkeeping), then the parked request is
        pulled off its slot and queued for replay re-admission over the
        fleet-migration resume path."""
        if slot not in self._parked:
            self.preempt(slot)
        req = self._parked.pop(slot)
        self._park_order.remove(slot)
        self.pool.release(slot)
        req.slot = None
        self._displaced.append(req)
        self.displacements += 1
        return req

    def run_to_completion(self, requests: list[Request], max_ticks=10_000,
                          max_wall_s: Optional[float] = None):
        """Continuous batching: admit whenever slots free up, decode in
        device-resident chunks.  Returns (done, device decode ticks).

        Raises ``RuntimeError`` when ``max_ticks`` is exhausted with
        requests still pending or active — the pre-fix behavior silently
        returned only the finished subset, so a too-small budget looked
        like a successful (shorter) run.  Partial outputs stay on the
        undrained ``Request`` objects for inspection.  ``max_wall_s``
        bounds host wall clock the same way (a hung tick burns no device
        ticks, so ``max_ticks`` alone cannot catch it)."""
        pending = list(requests)
        done = []
        start_ticks = self.device_ticks
        t_start = time.perf_counter()
        while (pending or self.active or self._parked or self._displaced
               or self._finished_instant) and \
                self.device_ticks - start_ticks < max_ticks:
            n = self.admit_many(pending)
            del pending[:n]
            if not self.active and not self._parked \
                    and not self._displaced \
                    and not self._finished_instant:
                if pending:    # no capacity rentable and none draining
                    raise RuntimeError(self._stuck_report(pending))
                break
            done += self.step()
            if max_wall_s is not None \
                    and time.perf_counter() - t_start > max_wall_s:
                raise RuntimeError(self._stuck_report(
                    pending,
                    reason=f"max_wall_s={max_wall_s} exceeded with "
                           f"{len(self.active)} active, "
                           f"{len(self._parked)} preempted and "
                           f"{len(pending)} pending requests undrained"))
        if self._finished_instant:     # complete, just not yet reported
            done += self._finished_instant
            self._finished_instant = []
        if pending or self.active or self._parked or self._displaced:
            rids = sorted([r.rid for r in self.active.values()] +
                          [r.rid for r in self._parked.values()] +
                          [r.rid for r in self._displaced] +
                          [r.rid for r in pending])
            raise RuntimeError(
                f"max_ticks={max_ticks} exhausted with {len(self.active)} "
                f"active, {len(self._parked)} preempted and {len(pending)} "
                f"pending requests undrained (rids {rids}); partial "
                f"outputs remain on the Request objects")
        return done, self.device_ticks - start_ticks

    def _stuck_report(self, pending: list[Request],
                      reason: Optional[str] = None) -> str:
        """Per-request block demand vs pool capacity for the stuck-pool
        error — plus per-request in-flight ages and the replica's health
        state, so a wall-clock timeout or a quarantine is diagnosable
        from the message alone."""
        lines = [reason if reason is not None else
                 f"{len(pending)} requests stuck: pool has no rentable "
                 f"slot/blocks and no active request to drain"]
        lines.append(f"slot pool: {self.pool.n} slots, "
                     f"{self.pool.available} available")
        now = time.perf_counter()
        in_flight = (list(self.active.values()) +
                     list(self._parked.values()) + list(self._displaced))
        for r in in_flight[:8]:
            age = now - self._admit_wall.get(r.rid, now)
            lines.append(f"  in flight rid {r.rid}: {len(r.out)} tokens "
                         f"out, {age:.2f}s since admission")
        if len(in_flight) > 8:
            lines.append(f"  ... and {len(in_flight) - 8} more in flight")
        lines.append(f"health: {self.health_check() or 'ok'}; "
                     f"last tick {self.last_tick_wall_s * 1e3:.1f}ms")
        if self.layout is not None:
            bs = self.layout.block_size
            free = int(np.sum(self._ref_host == 0))
            lines.append(
                f"block pool: {self.layout.n_blocks} blocks of "
                f"{bs} positions, {free} free, "
                f"{self._reserved_blocks()} reserved "
                f"(admission={'overcommit' if self.overcommit else 'reserved'})")
            for r in pending[:8]:
                plen = len(r.prompt) + self._offset
                now = -(-plen // bs)
                worst = self._worst_blocks(plen, self._max_new_eff(r, plen))
                lines.append(
                    f"  rid {r.rid}: prompt {plen} tokens -> needs {now} "
                    f"blocks now, {worst} worst-case, vs "
                    f"{self.layout.n_blocks} total")
            if len(pending) > 8:
                lines.append(f"  ... and {len(pending) - 8} more")
        return "\n".join(lines)

    # -- accounting ---------------------------------------------------------
    def reset_stats(self) -> None:
        """Zero the accounting counters (pool/cache state untouched).
        Benches warm the jit caches on the engine they will time — each
        engine owns its own jitted closures, so warming a sibling engine
        warms nothing — then reset before the measured run."""
        self.host_syncs = self.baseline_syncs = 0
        self.device_ticks = self.decode_tokens = 0
        self.decode_wall_s = 0.0
        self.stalls = 0
        self.shared_block_hits = 0
        self.kv_bytes_allocated = 0
        self.tokens_finished = 0
        self.spec_forwards = self.spec_slot_forwards = 0
        self.spec_decode_tokens = 0
        self.spec_drafted = self.spec_accepted = 0
        self.preemptions = self.resumes = 0
        self.preempted_tokens = self.preempt_replay_mismatches = 0
        self.migrations_in = self.migrate_replay_mismatches = 0
        self.displacements = 0
        self.occ_ticks = self.occ_slot_ticks = 0
        if self.layout is not None:
            # the block high-water mark restarts from what is in use now
            pool = self.bstate.pool
            self.bstate = self.bstate._replace(
                pool=pool._replace(peak_used=pool_lib.used(pool)))

    def sync_stats(self) -> dict:
        """Host-sync economy vs a per-slot-per-tick engine (same run)."""
        tokens = max(1, self.decode_tokens)
        return {
            "host_syncs": self.host_syncs,
            "baseline_syncs": self.baseline_syncs,
            "device_ticks": self.device_ticks,
            "decode_tokens": self.decode_tokens,
            "host_syncs_per_100_tokens": 100.0 * self.host_syncs / tokens,
            "baseline_syncs_per_100_tokens":
                100.0 * self.baseline_syncs / tokens,
            "sync_reduction_x": self.baseline_syncs / max(1, self.host_syncs),
        }

    def spec_stats(self) -> dict:
        """Speculative decode economics.  ``tokens_per_forward`` is
        decode tokens emitted per *slot-forward* (one decoding slot in
        one verify tick) — exactly 1.0 for the non-speculative engine,
        ``1 + accepted drafts`` here, so it is the per-slot decode
        multiplier the drafter buys.  ``acceptance_rate`` is accepted /
        proposed draft tokens."""
        return {
            "spec_k": getattr(self, "_spec_k", 0),
            "spec_forwards": int(self.spec_forwards),
            "spec_slot_forwards": int(self.spec_slot_forwards),
            "spec_decode_tokens": int(self.spec_decode_tokens),
            "tokens_per_forward":
                self.spec_decode_tokens / max(1, self.spec_slot_forwards),
            "drafted": int(self.spec_drafted),
            "accepted": int(self.spec_accepted),
            "acceptance_rate":
                self.spec_accepted / max(1, self.spec_drafted),
        }

    def occupancy_stats(self) -> dict:
        """Over-commit economics: the mean fraction of slots actually
        running per tick (parked slots excluded — they hold no KV), the
        eviction/resume counts, and what the evictions cost in replayed
        tokens.  ``preempt_replay_mismatches`` must stay 0: greedy
        determinism makes every resume replay its history token-exactly,
        and the engine checks the replayed pending token against the one
        delivered before eviction."""
        return {
            "overcommit": bool(self.overcommit),
            "ticks": int(self.occ_ticks),
            "n_slots": int(self.pool.n),
            "slot_ticks": int(self.occ_slot_ticks),
            "occupancy": self.occ_slot_ticks
            / max(1, self.occ_ticks * self.pool.n),
            "preemptions": int(self.preemptions),
            "resumes": int(self.resumes),
            "preempted_tokens_recomputed": int(self.preempted_tokens),
            "preempt_replay_mismatches":
                int(self.preempt_replay_mismatches),
            "migrations_in": int(self.migrations_in),
            "migrate_replay_mismatches":
                int(self.migrate_replay_mismatches),
        }

    def kv_stats(self) -> dict:
        """KV-cache economics over the *finished* requests: bytes the
        engine actually allocated for them per token they produced.
        Contiguous slots pay `max_seq` rows per admission regardless of
        the sequence; paged chains pay per rented (non-shared) block.

        Byte totals are *global* (summed across the engine's model
        shards): the block/slot ledger is replicated-with-local-rent, so
        one rented block holds ``kv_shard_fraction`` of its bytes on each
        shard and the global figure is their sum.  ``*_per_shard`` fields
        give the single-shard view (what one device actually stores);
        fleet-wide aggregation across replicas is the
        ``FleetSupervisor.kv_stats`` sum over these per-engine ledgers.
        """
        out = {
            "layout": "paged" if self.layout is not None else "contiguous",
            "kv_bytes_allocated": int(self.kv_bytes_allocated),
            "tokens_finished": int(self.tokens_finished),
            "kv_bytes_per_token":
                self.kv_bytes_allocated / max(1, self.tokens_finished),
            "model_shards": int(self.model_shards),
            "kv_shard_fraction": float(self._kv_shard_frac),
            "kv_bytes_per_token_per_shard":
                self.kv_bytes_allocated * self._kv_shard_frac
                / max(1, self.tokens_finished),
        }
        if self.layout is not None:
            out.update(
                block_size=self.layout.block_size,
                n_blocks=self.layout.n_blocks,
                shared_block_hits=int(self.shared_block_hits),
                stalls=int(self.stalls),
                peak_blocks=int(self.bstate.pool.peak_used),
                blocks_in_use=int(np.sum(self._ref_host > 0)),
                block_bytes_per_shard=
                    int(self._block_bytes * self._kv_shard_frac),
            )
        else:
            out["slot_bytes"] = int(self._slot_bytes)
            out["slot_bytes_per_shard"] = \
                int(self._slot_bytes * self._kv_shard_frac)
        return out

    def load(self) -> dict:
        """Host-side routing signal for the fleet supervisor: rentable
        slots, rentable KV blocks net of the §5.1 reservation (what a new
        admission could actually claim — under over-commit nothing is
        reserved, so the raw free count stands), and the preemption
        pressure signals.  Parked requests hold a re-admission claim on
        blocks the ledger calls free; a pressure flag means the last tick
        ran the pool dry — a preemption-aware router sends new work
        elsewhere first.  Reads only host mirrors: routing never syncs
        the device."""
        free_blocks = None
        if self.layout is not None:
            free_blocks = int(np.sum(self._ref_host == 0))
            if not self.overcommit:
                free_blocks = max(0, free_blocks - self._reserved_blocks())
        return {
            "free_slots": int(self.pool.available),
            "free_blocks": free_blocks,
            "parked": len(self._parked),
            "pressure": bool(self._pressure),
        }
