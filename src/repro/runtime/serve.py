"""Serving runtime: device-resident continuous batching over the EMPA pool.

The KV-cache slot pool *is* the paper's core pool: a request is a QT, a
cache slot is a core — rented on admission, returned at EOS (§4.3's
rent/terminate cycle), preallocation reserves slots for a stream of
requests (§5.1).  The refactor pushed the supervisor onto the device:

* per-slot decode state (last token, emitted count, budget, active mask)
  lives on device as a :class:`DecodeState`;
* one jitted **decode chunk** (`build_decode_chunk`) advances every active
  slot up to ``chunk`` tokens inside a single ``lax.while_loop`` — greedy
  argmax, EOS/max-new retirement and the active mask are all computed on
  device, so the host syncs once per chunk instead of once per slot per
  tick;
* admission packs every rentable pending prompt into one right-padded
  batched prefill (`build_admit_step`) that scatters prompt caches into
  the rented slots — one compiled call per admission round, not one per
  request.

**Paged mode** (``ServingEngine(paged=True)``) applies the same rent /
release discipline one level down: the rented resource is a fixed-size
KV *block* (runtime/paging.py), so a slot's cache cost is proportional
to its actual sequence, not to ``max_seq``:

* admission rents ``ceil(len/block)`` blocks and *reserves* (the paper's
  §5.1 preallocation, as host accounting) the worst-case remainder, so
  decode growth can never starve mid-flight;
* identical prompt-prefix blocks are shared through a host-side hash
  map with device refcounts — rented once, referenced by many chains;
* inside the jitted chunk, slots crossing a block boundary rent one
  block each through a single vectorized ``pool.rent_many`` — no host
  sync;
* retirement releases the whole chain; refcount-zero blocks return to
  the pool.

**Chunked prefill** (``ServingEngine(chunked_prefill=True)``) applies the
paper's *fragment outsourcing* to prompts: a core never receives its
whole job at once — the supervisor feeds it fragments as capacity
appears (the companion EMPA paper's quasi-thread discipline).  Instead
of one monolithic admission prefill (which stalls every active decode
slot behind the longest prompt and compiles one variant per pow2 length
bucket), an admitted slot enters ``PHASE_PREFILL`` and the **unified
mixed tick** (`build_mixed_tick`) advances all slots together:

* a PREFILLING slot consumes one prompt fragment (≤ ``prefill_chunk_
  tokens``), written into its cache at its position offset;
* a DECODING slot advances one token — the *same* ``model.prefill_
  chunk`` forward treats it as a length-1 fragment;
* paged chains rent blocks chunk-granularly as fragments land
  (`paging.extend_chains`), never faster — the §5.1 worst-case
  reservation is still taken at admission, so lazy growth cannot
  starve; a fully-written shared prefix is skipped, not recomputed;
* one compile total, one host sync per tick, per-tick latency bounded
  by one fragment — no head-of-line blocking, and the outputs stay
  token-exact vs monolithic admission.

Host Python keeps only what must be host-side: the rent/return ledger
(`core/supervisor.CorePool`, itself a thin wrapper over the same jittable
`runtime/pool` transitions), the prefix-hash map, the per-slot fragment
cursors, and the request queue.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.core.supervisor import CorePool
from repro.models import model as model_lib
from repro.models.model import PagedLayout
from repro.runtime import paging
from repro.runtime import pool as pool_lib
from repro.runtime.sharding import ShardingRules, use_rules

NO_TOKEN = -1          # emitted-buffer sentinel: slot idle this iteration

# families whose prefill is exact under right-padding (causal attention);
# recurrent state (ssm/hybrid) would absorb pad tokens, so those admit
# one exact-length prompt per prefill call instead of a padded pack
PACKED_PREFILL_FAMILIES = ("dense", "moe", "vlm")


def build_prefill_step(cfg: ArchConfig, max_seq: int,
                       rules: Optional[ShardingRules] = None):
    def prefill_step(params, batch):
        with use_rules(rules):
            return model_lib.prefill(params, batch, cfg, max_seq)
    return prefill_step


def build_decode_step(cfg: ArchConfig,
                      rules: Optional[ShardingRules] = None):
    def decode_step(params, token, cache):
        with use_rules(rules):
            return model_lib.decode_step(params, token, cache, cfg)
    return decode_step


# ---------------------------------------------------------------------------
# Device-resident decode state + jitted transitions
# ---------------------------------------------------------------------------

class DecodeState(NamedTuple):
    """Per-slot decode supervisor state; every field is (n_slots,)."""

    tokens: jax.Array    # int32 — last emitted token (decode input)
    n_out: jax.Array     # int32 — tokens emitted so far (incl. prefill's)
    max_new: jax.Array   # int32 — per-request budget
    active: jax.Array    # bool — slot is decoding


def init_decode_state(n_slots: int) -> DecodeState:
    return DecodeState(tokens=jnp.zeros((n_slots,), jnp.int32),
                       n_out=jnp.zeros((n_slots,), jnp.int32),
                       max_new=jnp.zeros((n_slots,), jnp.int32),
                       active=jnp.zeros((n_slots,), bool))


def abstract_decode_state(n_slots: int) -> DecodeState:
    return jax.tree_util.tree_map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
        init_decode_state(n_slots))


def _merge_rows(new, old, keep_new):
    """Per-slot select between two cache leaves (batch axis 0 for `pos`,
    axis 1 for layer-stacked leaves — same convention as init_cache)."""
    if new.ndim == 1:
        return jnp.where(keep_new, new, old)
    shape = [1] * new.ndim
    shape[1] = -1
    return jnp.where(keep_new.reshape(shape), new, old)


def build_decode_chunk(cfg: ArchConfig, *, chunk: int, eos_id: int,
                       rules: Optional[ShardingRules] = None,
                       decode_fn: Optional[Callable] = None,
                       jit: bool = True,
                       paged: Optional[PagedLayout] = None):
    """Jitted multi-token decode tick: one host round-trip per `chunk`.

    Contiguous: fn(params, state, cache) -> (state, cache, emitted,
    iters).  Paged: fn(params, state, cache, bstate) -> (state, cache,
    bstate, emitted, iters, stalls) — each loop iteration first grows
    block chains on device (`paging.grow_for_decode`), then decodes.
    `emitted` is (n_slots, chunk) int32 (NO_TOKEN for idle cells),
    `iters` counts executed loop iterations (early exit when every slot
    retires) and `stalls` counts slots force-retired because the block
    pool ran dry (zero under the engine's admission-time reservation).
    The cache (and block state) is donated: the engine decodes in place.
    """
    decode = decode_fn or build_decode_step(cfg, rules)

    def advance(params, st: DecodeState, cache, active, i, emitted):
        """One decode step over every row + retirement bookkeeping."""
        pos0 = cache["pos"]
        logits, new_cache = decode(params, st.tokens, cache)
        nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        # a retired slot keeps its last token and frozen cache rows /
        # pages: it can never perturb an active one
        tok = jnp.where(active, nxt, st.tokens)
        n_out = st.n_out + active.astype(jnp.int32)
        if paged is None:
            cache = jax.tree_util.tree_map(
                lambda a, b: _merge_rows(a, b, active), new_cache, cache)
        else:
            # pages are disjoint per chain: an inactive row's write is
            # either dropped (released chain) or rewrites its own cell
            # with the identical value — only per-row leaves need merge
            cache = dict(new_cache,
                         pos=jnp.where(active, new_cache["pos"], pos0))
        emitted = emitted.at[:, i].set(jnp.where(active, tok, NO_TOKEN))
        retire = active & ((tok == eos_id) | (n_out >= st.max_new))
        return DecodeState(tok, n_out, st.max_new, active & ~retire), \
            cache, emitted

    if paged is None:
        def chunk_fn(params, state: DecodeState, cache):
            n = state.tokens.shape[0]
            emitted0 = jnp.full((n, chunk), NO_TOKEN, jnp.int32)

            def cond(carry):
                i, st, _, _ = carry
                return (i < chunk) & jnp.any(st.active)

            def body(carry):
                i, st, cache, emitted = carry
                st, cache, emitted = advance(params, st, cache, st.active,
                                             i, emitted)
                return i + jnp.int32(1), st, cache, emitted

            iters, state, cache, emitted = jax.lax.while_loop(
                cond, body, (jnp.int32(0), state, cache, emitted0))
            return state, cache, emitted, iters

        if not jit:    # the cluster supervisor jits with explicit shardings
            return chunk_fn
        return jax.jit(chunk_fn, donate_argnums=(2,))

    def chunk_fn_paged(params, state: DecodeState, cache, bstate):
        n = state.tokens.shape[0]
        emitted0 = jnp.full((n, chunk), NO_TOKEN, jnp.int32)

        def cond(carry):
            i, st, _, _, _, _ = carry
            return (i < chunk) & jnp.any(st.active)

        def body(carry):
            i, st, cache, bstate, emitted, stalls = carry
            # rent one block per slot crossing a block boundary — the
            # supervisor action happens on device, no host round-trip
            bstate, tables, stalled = paging.grow_for_decode(
                bstate, cache["block_tables"], cache["pos"], st.active,
                block_size=paged.block_size)
            active = st.active & ~stalled
            stalls = stalls + jnp.sum(stalled).astype(jnp.int32)
            cache = dict(cache, block_tables=tables)
            st, cache, emitted = advance(params, st, cache, active, i,
                                         emitted)
            return i + jnp.int32(1), st, cache, bstate, emitted, stalls

        iters, state, cache, bstate, emitted, stalls = jax.lax.while_loop(
            cond, body,
            (jnp.int32(0), state, cache, bstate, emitted0, jnp.int32(0)))
        return state, cache, bstate, emitted, iters, stalls

    if not jit:
        return chunk_fn_paged
    return jax.jit(chunk_fn_paged, donate_argnums=(2, 3))


def build_mixed_tick(cfg: ArchConfig, *, chunk_tokens: int, eos_id: int,
                     rules: Optional[ShardingRules] = None,
                     jit: bool = True,
                     paged: Optional[PagedLayout] = None):
    """Jitted unified prefill/decode tick (the fragment-outsourcing step).

    One call advances *every* rented slot exactly one quantum: a slot in
    ``PHASE_PREFILL`` consumes its next prompt fragment (up to
    ``chunk_tokens`` tokens, written into the cache at its position
    offset), a slot in ``PHASE_DECODE`` advances one token — both through
    the same ``model.prefill_chunk`` forward, where a decode step is just
    a length-1 fragment.  One compile (no per-prompt-length buckets), one
    host sync per tick, per-tick latency bounded by one fragment's cost.

    Contiguous: ``fn(params, state, cache, frag_tokens (n, C), frag_len
    (n,), frag_last (n,), frag_max_new (n,)) -> (state, cache, emitted
    (n, 1))``.  ``emitted`` carries the decode token per active slot and
    the *first* token for rows whose final fragment just ran (the prefill
    argmax), ``NO_TOKEN`` elsewhere.

    Paged: ``fn(params, state, cache, bstate, frag_tokens, frag_len,
    frag_last, frag_max_new, frag_skip, frag_cols, frag_rent) -> (state,
    cache, bstate, emitted, stalls)``.  ``frag_rent``/``frag_cols``
    commit this tick's chunk-granular block rents
    (:func:`paging.extend_chains` — host-picked, reservation-backed),
    ``frag_skip`` fences writes below it (shared prefix blocks an
    earlier chain already stored), and decode rows still grow their
    chains on device via :func:`paging.grow_for_decode`.

    The cache (and block state) is donated: the engine ticks in place.
    """

    def run(params, state: DecodeState, cache, decode_rows, frag_tokens,
            frag_len, frag_last, frag_max_new, frag_skip):
        """Shared tail: one prefill_chunk forward + QT bookkeeping."""
        # trace-time check: the compiled width IS the fragment width
        assert frag_tokens.shape[1] == chunk_tokens, \
            (frag_tokens.shape, chunk_tokens)
        # a decoding slot is a length-1 fragment whose token lives in
        # device state; a prefilling slot's fragment comes from the host
        first_col = jnp.where(decode_rows, state.tokens, frag_tokens[:, 0])
        tokens = jnp.concatenate([first_col[:, None], frag_tokens[:, 1:]],
                                 axis=1)
        lengths = jnp.where(decode_rows, 1, frag_len)
        with use_rules(rules):
            logits, cache = model_lib.prefill_chunk(
                params, tokens, lengths, cache, cfg, skip_until=frag_skip)
        nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        prefill_rows = frag_len > 0
        done_pref = prefill_rows & frag_last
        emit = decode_rows | done_pref
        tok = jnp.where(emit, nxt, state.tokens)
        n_out = jnp.where(done_pref, 1,
                          state.n_out + decode_rows.astype(jnp.int32))
        max_new = jnp.where(done_pref, frag_max_new, state.max_new)
        # same retirement rule as the decode chunk; like monolithic
        # admission, the first token is emitted without an EOS check and
        # a budget of 1 is already spent by it
        retire = decode_rows & ((tok == eos_id) | (n_out >= max_new))
        active = (decode_rows & ~retire) | (done_pref & (max_new > 1))
        emitted = jnp.where(emit, tok, NO_TOKEN)[:, None]
        return DecodeState(tok, n_out, max_new, active), cache, emitted

    if paged is None:
        def tick(params, state: DecodeState, cache, frag_tokens, frag_len,
                 frag_last, frag_max_new):
            frag_skip = jnp.zeros_like(frag_len)
            return run(params, state, cache, state.active, frag_tokens,
                       frag_len, frag_last, frag_max_new, frag_skip)

        if not jit:
            return tick
        return jax.jit(tick, donate_argnums=(2,))

    def tick_paged(params, state: DecodeState, cache, bstate, frag_tokens,
                   frag_len, frag_last, frag_max_new, frag_skip, frag_cols,
                   frag_rent):
        # 1. commit this tick's fragment blocks (host-picked, cannot
        #    stall under the §5.1 reservation)
        bstate, tables = paging.extend_chains(
            bstate, cache["block_tables"], frag_cols, frag_rent)
        # 2. decode rows crossing a block boundary rent on device
        bstate, tables, stalled = paging.grow_for_decode(
            bstate, tables, cache["pos"], state.active,
            block_size=paged.block_size)
        decode_rows = state.active & ~stalled
        stalls = jnp.sum(stalled).astype(jnp.int32)
        cache = dict(cache, block_tables=tables)
        state, cache, emitted = run(params, state, cache, decode_rows,
                                    frag_tokens, frag_len, frag_last,
                                    frag_max_new, frag_skip)
        return state, cache, bstate, emitted, stalls

    if not jit:
        return tick_paged
    return jax.jit(tick_paged, donate_argnums=(2, 3))


def build_admit_step(cfg: ArchConfig, max_seq: int,
                     rules: Optional[ShardingRules] = None):
    """Jitted packed admission: batched prefill + scatter into rented slots.

    fn(params, tokens (G,Sp), lengths (G,), max_new (G,), slots (G,),
       state, cache, first) -> (state, cache, first).

    Rows whose slot is out of range (the G-padding rows) are dropped by
    the scatter (`mode="drop"`), so the call compiles once per Sp bucket.
    A ``max_new`` of 1 admits inactive: the prefill argmax already is the
    whole budget, so the slot retires without a decode step.
    """

    def admit_fn(params, tokens, lengths, max_new, slots, state, cache,
                 first):
        logits, cache_g = _group_prefill(params, tokens, lengths, cfg,
                                         max_seq, rules)
        ftok = jnp.argmax(logits, axis=-1).astype(jnp.int32)

        def put(big, small):
            if big.ndim == 1:                  # pos: (n_slots,)
                return big.at[slots].set(small, mode="drop")
            return big.at[:, slots].set(
                small.astype(big.dtype), mode="drop")
        cache = jax.tree_util.tree_map(put, cache, cache_g)
        state = _admit_state(state, slots, ftok, max_new)
        first = first.at[slots].set(ftok, mode="drop")
        return state, cache, first

    return jax.jit(admit_fn, donate_argnums=(6,))


def _group_prefill(params, tokens, lengths, cfg, span, rules):
    """The shared packed-prefill call (span = group cache length)."""
    g = tokens.shape[0]
    batch = {"tokens": tokens}
    if cfg.frontend == "vision":
        batch["vision_embeds"] = jnp.zeros(
            (g, cfg.n_frontend_tokens, cfg.frontend_dim), jnp.float32)
    if cfg.family == "encdec":
        batch["enc_embeds"] = jnp.zeros(
            (g, tokens.shape[1], cfg.frontend_dim), jnp.float32)
    with use_rules(rules):
        return model_lib.prefill(params, batch, cfg, span, lengths=lengths)


def _admit_state(state: DecodeState, slots, ftok, max_new) -> DecodeState:
    return DecodeState(
        tokens=state.tokens.at[slots].set(ftok, mode="drop"),
        n_out=state.n_out.at[slots].set(1, mode="drop"),
        max_new=state.max_new.at[slots].set(max_new, mode="drop"),
        # budget 1 is already spent by the prefill argmax
        active=state.active.at[slots].set(max_new > 1, mode="drop"))


def build_admit_step_paged(cfg: ArchConfig, max_seq: int,
                           layout: PagedLayout,
                           rules: Optional[ShardingRules] = None):
    """Paged packed admission: prefill the group over its (block-rounded)
    span, then scatter K/V *blocks* into host-rented pages.

    fn(params, tokens (G,Sp), lengths, max_new, slots (G,),
       gtables (G,NB), wtargets (G,nb_span), state, cache, bstate, first)
    -> (state, cache, bstate, first).

    ``gtables`` rows are the full chains committed to the slots' block
    tables; ``wtargets`` names the physical block each span-block of the
    group prefill is stored into — shared prefix blocks carry the
    out-of-range sentinel (already stored by an earlier chain; the
    scatter drops them).  ``paging.admit_chains`` rents the written
    blocks and takes one reference per chain entry.
    """
    bs = layout.block_size

    def admit_fn(params, tokens, lengths, max_new, slots, gtables,
                 wtargets, state, cache, bstate, first):
        g = tokens.shape[0]
        span_total = tokens.shape[1] + \
            (cfg.n_frontend_tokens if cfg.frontend == "vision" else 0)
        logits, cache_g = _group_prefill(params, tokens, lengths, cfg,
                                         span_total, rules)
        ftok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        nb_span = span_total // bs
        wflat = wtargets.reshape(g * nb_span)
        for name in ("k", "v"):
            n_layers = cache_g[name].shape[0]
            blocks = cache_g[name].reshape(
                n_layers, g * nb_span, bs, *cache_g[name].shape[3:])
            cache[name] = cache[name].at[:, wflat].set(
                blocks.astype(cache[name].dtype), mode="drop")
        cache = dict(
            cache,
            pos=cache["pos"].at[slots].set(cache_g["pos"], mode="drop"),
            block_tables=cache["block_tables"].at[slots].set(
                gtables, mode="drop"))
        bstate = paging.admit_chains(bstate, gtables.reshape(-1), wflat)
        state = _admit_state(state, slots, ftok, max_new)
        first = first.at[slots].set(ftok, mode="drop")
        return state, cache, bstate, first

    return jax.jit(admit_fn, donate_argnums=(8, 9))


# ---------------------------------------------------------------------------
# Continuous-batching engine
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray            # (S,) int32
    max_new: int = 16
    out: list = dataclasses.field(default_factory=list)
    slot: Optional[int] = None


def _pow2_bucket(n: int, cap: int) -> int:
    """Next power of two ≥ n, clamped to cap — bounds recompiles.

    Over-cap lengths clamp to `cap` (admission rejects them before any
    compile); the pre-fix behavior returned raw `n`, which compiled a
    fresh prefill for every distinct over-cap prompt length.
    """
    b = 1
    while b < n:
        b <<= 1
    return min(b, cap)


@dataclasses.dataclass
class _ChainPlan:
    """Host-side admission plan for one request's block chain."""

    chain: list            # block ids covering the prompt (shared + new)
    new_blocks: list       # subset actually stored by this admission
    n_shared: int
    worst_total: int       # §5.1 reservation: blocks the chain may reach


@dataclasses.dataclass
class _PrefillJob:
    """Host cursor for one slot's incrementally outsourced prompt.

    The request's prompt is fed to the mixed tick fragment by fragment;
    ``cursor`` counts consumed tokens, ``registered`` the prefix-map
    blocks published so far (a block becomes shareable only once the
    fragment that writes it has been dispatched — a later chain must
    never attend to an unwritten shared block)."""

    req: Request
    max_new_eff: int
    cursor: int = 0
    registered: int = 0


class ServingEngine:
    """Batched greedy decoding with rent/return slot semantics.

    The host owns the pool ledger and the queue; everything per-tick —
    argmax, EOS / max-new retirement, the active mask, cache advancement,
    and (paged) block-chain growth — runs inside one jitted decode chunk
    with a donated cache.  The host syncs once per chunk (and reads
    nothing at admission), which is what turns sequential per-slot
    coordination into streaming throughput.

    With ``paged=True`` the KV cache is a pool of ``n_blocks`` blocks of
    ``block_size`` positions governed by the same rent/release discipline
    (runtime/paging.py): admission rents exactly what the prompt needs
    (sharing identical prefix blocks), reserves the worst-case decode
    remainder so growth can't starve, and retirement returns the chain.
    """

    def __init__(self, params, cfg: ArchConfig, *, n_slots: int,
                 max_seq: int, eos_id: int = 1,
                 decode_fn: Optional[Callable] = None,
                 chunk: int = 8,
                 rules: Optional[ShardingRules] = None,
                 paged: bool = False, block_size: int = 16,
                 n_blocks: Optional[int] = None,
                 prefix_sharing: bool = True,
                 chunked_prefill: bool = False,
                 prefill_chunk_tokens: int = 16,
                 max_prefill_tokens_per_tick: Optional[int] = None):
        self.params, self.cfg = params, cfg
        self.max_seq, self.eos_id, self.chunk = max_seq, eos_id, chunk
        self.pool = CorePool(n_slots)
        self.active: dict[int, Request] = {}
        self._offset = cfg.n_frontend_tokens if cfg.frontend == "vision" \
            else 0
        dtype = jax.tree_util.tree_leaves(params)[0].dtype
        self.layout: Optional[PagedLayout] = None
        if paged:
            if cfg.family not in model_lib.PAGED_FAMILIES:
                raise ValueError(
                    f"paged serving supports {model_lib.PAGED_FAMILIES}, "
                    f"not {cfg.family!r}")
            nb_full = -(-max_seq // block_size)
            if n_blocks is None:       # capacity-equivalent default
                n_blocks = n_slots * nb_full
            self.layout = PagedLayout(block_size, n_blocks)
        self.cache = model_lib.init_cache(cfg, n_slots, max_seq,
                                          dtype=dtype, layout=self.layout)
        self.dstate = init_decode_state(n_slots)
        self._first = jnp.zeros((n_slots,), jnp.int32)
        self._need_first: set[int] = set()
        self._chunk_fn = build_decode_chunk(cfg, chunk=chunk, eos_id=eos_id,
                                            rules=rules, decode_fn=decode_fn,
                                            paged=self.layout)
        if self.layout is None:
            self._admit_fn = build_admit_step(cfg, max_seq, rules=rules)
        else:
            self._admit_fn = build_admit_step_paged(cfg, max_seq,
                                                    self.layout, rules=rules)
            self.bstate = paging.init_blocks(n_blocks)
            self._prefix_sharing = prefix_sharing
            # host mirrors of the device block state (refreshed at every
            # chunk sync — admission never blocks on the device)
            self._ref_host = np.zeros((n_blocks,), np.int32)
            self._tables_host = np.full(
                (n_slots, self.layout.max_blocks(max_seq)), -1, np.int32)
            self._prefix_map: dict = {}      # prefix key -> block id
            self._block_hash: dict = {}      # block id -> prefix key
            self._plans: dict[int, _ChainPlan] = {}   # slot -> plan
        self._packed = cfg.family in PACKED_PREFILL_FAMILIES
        self.chunked = chunked_prefill
        if chunked_prefill:
            if cfg.family not in model_lib.PAGED_FAMILIES or cfg.frontend:
                raise ValueError(
                    f"chunked prefill supports causal attention caches "
                    f"{model_lib.PAGED_FAMILIES} without a frontend, not "
                    f"{cfg.family!r} (frontend={cfg.frontend!r})")
            if prefill_chunk_tokens < 1:
                raise ValueError("prefill_chunk_tokens must be >= 1")
            if max_prefill_tokens_per_tick is not None \
                    and max_prefill_tokens_per_tick < 1:
                raise ValueError(
                    "max_prefill_tokens_per_tick must be >= 1")
            self._pchunk = int(prefill_chunk_tokens)
            self._tick_budget = max_prefill_tokens_per_tick
            self._jobs: dict[int, _PrefillJob] = {}
            self._mixed_fn = build_mixed_tick(
                cfg, chunk_tokens=self._pchunk, eos_id=eos_id, rules=rules,
                paged=self.layout)
        self._finished_instant: list[Request] = []
        # accounting: host round-trips vs the one-sync-per-slot-per-tick
        # baseline an un-refactored engine would have paid
        self.host_syncs = 0
        self.baseline_syncs = 0
        self.device_ticks = 0
        self.decode_tokens = 0
        self.stalls = 0
        self.shared_block_hits = 0
        self.kv_bytes_allocated = 0
        self.tokens_finished = 0
        # per-slot / per-block KV footprint (all cache leaves that scale
        # with the slot or block count; `pos`/tables bookkeeping excluded)
        if self.layout is None:
            self._slot_bytes = sum(
                leaf.nbytes // n_slots for key, leaf in self.cache.items()
                if key != "pos")
        else:
            self._block_bytes = sum(
                self.cache[k].nbytes // n_blocks for k in ("k", "v"))

    # -- admission ---------------------------------------------------------
    def admit(self, req: Request) -> bool:
        return self.admit_many([req]) == 1

    def admit_many(self, requests: list[Request]) -> int:
        """Rent slots (and, paged, blocks) and prefill as many of
        `requests` as the pools allow; returns how many were consumed
        from the front of the list.

        Packed admission: one batched padded prefill per call (causal
        families); recurrent families fall back to one exact-length
        prefill per request through the same jitted path.

        With ``chunked_prefill`` the prompt is *not* prefilled at
        admission at all: the slot enters ``PHASE_PREFILL`` and the mixed
        tick feeds it one fragment per tick (paged blocks are rented
        chunk-granularly as fragments land, under the same §5.1
        worst-case reservation taken here).

        Edge cases (all host-side, before any compile):
        * an empty prompt raises ``ValueError`` (a packed prefill row of
          length 0 would gather its "last token" from row -1 — garbage
          as the first token);
        * a prompt longer than ``max_seq`` raises ``ValueError``;
        * a prompt of exactly ``max_seq`` is admitted with an effective
          budget of 1 (the prefill argmax) — no decode write can land
          past the cache;
        * ``max_new <= 0`` completes immediately with empty output.
        """
        # validate the whole batch before renting anything: a rejection
        # must never leave earlier requests granted-but-unprefilled
        for req in requests:
            if len(req.prompt) == 0:
                raise ValueError(
                    f"request {req.rid}: empty prompt; there is no last "
                    f"prompt token to gather first-token logits from — "
                    f"reject upstream")
            if len(req.prompt) + self._offset > self.max_seq:
                raise ValueError(
                    f"request {req.rid}: prompt length {len(req.prompt)}"
                    f"{f' (+{self._offset} frontend tokens)' if self._offset else ''}"
                    f" does not fit max_seq={self.max_seq}; reject or "
                    f"truncate upstream")
        granted: list[Request] = []
        consumed = 0
        for req in requests:
            plen = len(req.prompt) + self._offset
            if req.max_new <= 0:
                req.out = []
                self._finished_instant.append(req)
                consumed += 1
                continue
            slot = self.pool.rent()
            if slot is None:
                break                     # pool exhausted: queue upstream
            if self.layout is not None:
                plan = self._plan_chain(req, plen,
                                        rent_now=not self.chunked)
                if plan is None:          # block pool exhausted
                    self.pool.release(slot)
                    break
                if self.chunked:
                    self._commit_plan_chunked(slot, plan)
                else:
                    self._commit_plan(slot, plan, req)
            req.slot = slot
            granted.append(req)
            consumed += 1
        if not granted:
            return consumed
        if self.chunked:
            # no device prefill here: the slot's QT starts in the
            # fragment-feeding phase and the mixed tick does the rest
            for req in granted:
                slot, plen = req.slot, len(req.prompt)
                job = _PrefillJob(
                    req=req, max_new_eff=self._max_new_eff(req, plen))
                if self.layout is not None:
                    plan = self._plans[slot]
                    # a fully-shared prefix needs no recompute: fast-
                    # forward past it (but keep >= 1 token so the final
                    # fragment has a last position to take logits from)
                    job.cursor = min(plan.n_shared * self.layout.block_size,
                                     plen - 1)
                    job.registered = plan.n_shared
                self.cache["pos"] = self.cache["pos"].at[slot].set(
                    job.cursor)
                self.active[slot] = req
                self._jobs[slot] = job
                self.pool.set_phase(slot, pool_lib.PHASE_PREFILL)
            return consumed
        groups = [granted] if self._packed else [[r] for r in granted]
        for group in groups:
            self._prefill_group(group)
        for req in granted:
            self.active[req.slot] = req
            self._need_first.add(req.slot)
            self.pool.set_phase(req.slot, pool_lib.PHASE_DECODE)
        return consumed

    def _max_new_eff(self, req: Request, plen: int) -> int:
        """Budget clamp: emitted tokens 2..max_new write at positions
        plen..plen+max_new-2, which must stay inside max_seq."""
        return min(req.max_new, self.max_seq - plen + 1)

    def _plan_chain(self, req: Request, plen: int,
                    rent_now: bool = True) -> Optional[_ChainPlan]:
        """Pick the request's blocks from the host mirror: reuse shared
        prompt-prefix blocks, rent new ones, and check the §5.1
        reservation (worst-case chain) against the unreserved pool.

        With ``rent_now=False`` (chunked prefill) no new blocks are
        picked — the chain holds only the shared prefix and grows
        chunk-granularly as fragments are outsourced; the worst-case
        reservation is still taken here, so lazy growth can never
        starve."""
        lo = self.layout
        bs = lo.block_size
        n_full = plen // bs
        shared: list[int] = []
        if self._prefix_sharing:
            for j in range(n_full):
                blk = self._prefix_map.get(self._prefix_key(req.prompt, j))
                if blk is None:
                    break
                shared.append(blk)
        total_now = -(-plen // bs)
        worst_total = -(-(plen + self._max_new_eff(req, plen) - 1) // bs)
        used = int(np.sum(self._ref_host > 0))
        reserve = sum(
            max(0, p.worst_total - int(np.sum(self._tables_host[s] >= 0)))
            for s, p in self._plans.items())
        budget = lo.n_blocks - used - reserve
        if worst_total - len(shared) > budget:
            return None
        if not rent_now:
            return _ChainPlan(chain=list(shared), new_blocks=[],
                              n_shared=len(shared),
                              worst_total=worst_total)
        free_ids = np.flatnonzero(self._ref_host == 0)
        new_blocks = [int(b) for b in free_ids[:total_now - len(shared)]]
        return _ChainPlan(chain=shared + new_blocks, new_blocks=new_blocks,
                          n_shared=len(shared), worst_total=worst_total)

    def _commit_plan(self, slot: int, plan: _ChainPlan,
                     req: Request) -> None:
        """Host-mirror bookkeeping for a granted chain.  Prefix keys are
        registered here, *before* the group prefill, so later requests
        in the same admission round already share them (the group
        scatter stores each block exactly once)."""
        self._plans[slot] = plan
        self.shared_block_hits += plan.n_shared
        for b in plan.chain:
            self._ref_host[b] += 1
        row = self._tables_host[slot]
        row[:] = -1
        row[:len(plan.chain)] = plan.chain
        self._register_prefixes(req, plan)

    def _commit_plan_chunked(self, slot: int, plan: _ChainPlan) -> None:
        """Chunked admission commits only the *shared prefix*: reference
        it on the device immediately (a retiring source chain must never
        free blocks this request still needs) and seed the slot's block
        table with it; everything else is rented fragment by fragment
        inside the mixed tick (`paging.extend_chains`)."""
        self._plans[slot] = plan
        self.shared_block_hits += plan.n_shared
        row = self._tables_host[slot]
        row[:] = -1
        for b in plan.chain:
            self._ref_host[b] += 1
        row[:len(plan.chain)] = plan.chain
        if plan.chain:
            shared = jnp.asarray(plan.chain, jnp.int32)
            self.bstate = paging.admit_chains(
                self.bstate, shared, jnp.zeros((0,), jnp.int32))
            self.cache["block_tables"] = self.cache["block_tables"] \
                .at[slot, :len(plan.chain)].set(shared)

    def _prefix_key(self, prompt: np.ndarray, j: int):
        """Key for chain block j: its content is a pure function of the
        token prefix it covers (frontend stub tokens are constant)."""
        end = (j + 1) * self.layout.block_size - self._offset
        return (j, np.asarray(prompt[:max(0, end)], np.int32).tobytes())

    def _register_prefixes(self, req: Request, plan: _ChainPlan) -> None:
        if not self._prefix_sharing:
            return
        plen = len(req.prompt) + self._offset
        n_full = plen // self.layout.block_size
        for j in range(plan.n_shared, n_full):
            key = self._prefix_key(req.prompt, j)
            blk = plan.chain[j]
            self._prefix_map[key] = blk
            self._block_hash[blk] = key

    def _prefill_group(self, group: list[Request]) -> None:
        g = len(group)
        n = self.pool.n
        maxlen = max(len(r.prompt) for r in group)
        span = _pow2_bucket(maxlen, self.max_seq) if self._packed else maxlen
        if self.layout is not None:
            # the paged scatter stores whole blocks: pad the span so the
            # group cache divides into block_size rows
            bs = self.layout.block_size
            span += (-(span + self._offset)) % bs
        # pad the group to a pow2 row count: compiles stay bounded to
        # log2(n_slots) variants per span bucket, while a single trickle
        # admission doesn't pay a full n_slots-row prefill
        gpad = _pow2_bucket(g, n) if self._packed else g
        tokens = np.zeros((gpad, span), np.int32)
        lengths = np.ones((gpad,), np.int32)
        max_new = np.zeros((gpad,), np.int32)
        slots = np.full((gpad,), n, np.int32)   # n = out of range -> dropped
        for i, r in enumerate(group):
            tokens[i, :len(r.prompt)] = r.prompt
            lengths[i] = len(r.prompt)
            max_new[i] = self._max_new_eff(r, len(r.prompt) + self._offset)
            slots[i] = r.slot
        if self.layout is None:
            self.dstate, self.cache, self._first = self._admit_fn(
                self.params, jnp.asarray(tokens), jnp.asarray(lengths),
                jnp.asarray(max_new), jnp.asarray(slots), self.dstate,
                self.cache, self._first)
        else:
            lo = self.layout
            nb_span = (span + self._offset) // lo.block_size
            gtables = np.full((gpad, lo.max_blocks(self.max_seq)), -1,
                              np.int32)
            wtargets = np.full((gpad, nb_span), lo.n_blocks, np.int32)
            for i, r in enumerate(group):
                plan = self._plans[r.slot]
                gtables[i, :len(plan.chain)] = plan.chain
                for j, blk in enumerate(plan.chain):
                    if j >= plan.n_shared:
                        wtargets[i, j] = blk
            (self.dstate, self.cache, self.bstate,
             self._first) = self._admit_fn(
                self.params, jnp.asarray(tokens), jnp.asarray(lengths),
                jnp.asarray(max_new), jnp.asarray(slots),
                jnp.asarray(gtables), jnp.asarray(wtargets), self.dstate,
                self.cache, self.bstate, self._first)
        # un-refactored baseline: one argmax sync per admitted request
        self.baseline_syncs += g

    # -- chunked prefill: fragment scheduler + unified tick ------------------
    def _schedule_fragments(self):
        """Pick this tick's prompt fragments (host side): one fragment of
        up to ``prefill_chunk_tokens`` per PREFILLING slot, oldest job
        first, bounded by the per-tick token budget.  Paged jobs also get
        their fragment's blocks picked from the free mirror here — the
        §5.1 reservation taken at admission guarantees the pick succeeds,
        and the ids are committed on device by the tick itself
        (`paging.extend_chains`), so host and device free lists cannot
        race."""
        n = self.pool.n
        C = self._pchunk
        ft = np.zeros((n, C), np.int32)
        fl = np.zeros((n,), np.int32)
        flast = np.zeros((n,), bool)
        fmax = np.zeros((n,), np.int32)
        fskip = np.zeros((n,), np.int32)
        paged = self.layout is not None
        if paged:
            bs = self.layout.block_size
            frent = np.full((n, C // bs + 2), -1, np.int32)
            fcols = np.zeros((n, C // bs + 2), np.int32)
        budget = self._tick_budget if self._tick_budget is not None \
            else C * n
        finishing: list[int] = []
        for slot, job in list(self._jobs.items()):
            if budget <= 0:
                break                 # token budget spent: rest wait a tick
            prompt = job.req.prompt
            plen = len(prompt)
            take = min(C, plen - job.cursor, budget)
            if take <= 0:
                continue
            ft[slot, :take] = prompt[job.cursor:job.cursor + take]
            fl[slot] = take
            fmax[slot] = job.max_new_eff
            last = job.cursor + take >= plen
            flast[slot] = last
            if paged:
                plan = self._plans[slot]
                fskip[slot] = plan.n_shared * bs
                need = (job.cursor + take - 1) // bs + 1
                k_i = 0
                while len(plan.chain) < need:
                    blk = int(np.flatnonzero(self._ref_host == 0)[0])
                    col = len(plan.chain)
                    self._ref_host[blk] += 1
                    self._tables_host[slot, col] = blk
                    frent[slot, k_i] = blk
                    fcols[slot, k_i] = col
                    plan.chain.append(blk)
                    k_i += 1
                if self._prefix_sharing:
                    # publish prefix-map entries for the full blocks this
                    # fragment completes: a block becomes shareable only
                    # once its writing tick is dispatched
                    done_full = min((job.cursor + take) // bs, plen // bs)
                    for j in range(job.registered, done_full):
                        key = self._prefix_key(prompt, j)
                        self._prefix_map[key] = plan.chain[j]
                        self._block_hash[plan.chain[j]] = key
                    job.registered = max(job.registered, done_full)
            job.cursor += take
            budget -= take
            if last:
                finishing.append(slot)
        out = (ft, fl, flast, fmax, fskip)
        if paged:
            out = out + (fcols, frent)
        return out, finishing

    def _mixed_step(self) -> list[Request]:
        """One unified prefill/decode tick: every PREFILLING slot eats a
        fragment, every DECODING slot one token; one host sync."""
        sched, finishing = self._schedule_fragments()
        if self.layout is None:
            ft, fl, flast, fmax, _ = sched
            self.dstate, self.cache, emitted = self._mixed_fn(
                self.params, self.dstate, self.cache, jnp.asarray(ft),
                jnp.asarray(fl), jnp.asarray(flast), jnp.asarray(fmax))
            em, active_mask = jax.device_get((emitted, self.dstate.active))
        else:
            ft, fl, flast, fmax, fskip, fcols, frent = sched
            (self.dstate, self.cache, self.bstate, emitted,
             stalls) = self._mixed_fn(
                self.params, self.dstate, self.cache, self.bstate,
                jnp.asarray(ft), jnp.asarray(fl), jnp.asarray(flast),
                jnp.asarray(fmax), jnp.asarray(fskip), jnp.asarray(fcols),
                jnp.asarray(frent))
            em, active_mask, stalls, tables_d, ref_d = jax.device_get(
                (emitted, self.dstate.active, stalls,
                 self.cache["block_tables"], self.bstate.refcount))
            self._tables_host = np.asarray(tables_d).copy()
            self._ref_host = np.asarray(ref_d).copy()
            self.stalls += int(stalls)
        self.host_syncs += 1
        self.device_ticks += 1
        fin_set = set(finishing)
        for slot in finishing:
            # PREFILL -> DECODE: the final fragment's argmax is the first
            # token (what monolithic admission paid one sync for)
            del self._jobs[slot]
            self.pool.set_phase(slot, pool_lib.PHASE_DECODE)
            self.baseline_syncs += 1
        finished: list[Request] = []
        for slot, req in list(self.active.items()):
            if slot in self._jobs:
                continue               # mid-prefill: nothing emitted yet
            new_toks = [int(t) for t in em[slot] if t != NO_TOKEN]
            req.out.extend(new_toks)
            if slot not in fin_set:
                self.decode_tokens += len(new_toks)
                self.baseline_syncs += len(new_toks)
            if not active_mask[slot]:
                finished.append(req)
                del self.active[slot]
                self._retire_slot(slot, req)
        return finished

    # -- one decode chunk over all active slots -----------------------------
    def step(self) -> list[Request]:
        """Advance every active slot up to `chunk` tokens; one host sync.

        With chunked prefill, while any slot is still consuming prompt
        fragments the engine ticks the unified prefill/decode step
        instead (one token per decoding slot, one fragment per
        prefilling slot, bounded latency); once every prompt is absorbed
        it returns to multi-token decode chunks."""
        finished: list[Request] = []
        if self._finished_instant:
            finished, self._finished_instant = self._finished_instant, []
        if not self.active:
            return finished
        if self.chunked and self._jobs:
            return finished + self._mixed_step()
        if self.layout is None:
            self.dstate, self.cache, emitted, iters = self._chunk_fn(
                self.params, self.dstate, self.cache)
            em, active_mask, first, iters = jax.device_get(
                (emitted, self.dstate.active, self._first, iters))
        else:
            (self.dstate, self.cache, self.bstate, emitted, iters,
             stalls) = self._chunk_fn(self.params, self.dstate, self.cache,
                                      self.bstate)
            (em, active_mask, first, iters, stalls, tables_d,
             ref_d) = jax.device_get(
                (emitted, self.dstate.active, self._first, iters, stalls,
                 self.cache["block_tables"], self.bstate.refcount))
            # refresh the host mirrors with the chunk's on-device growth
            self._tables_host = np.asarray(tables_d).copy()
            self._ref_host = np.asarray(ref_d).copy()
            self.stalls += int(stalls)
        self.host_syncs += 1
        self.device_ticks += int(iters)
        for slot, req in list(self.active.items()):
            if slot in self._need_first:
                req.out.append(int(first[slot]))
                self._need_first.discard(slot)
            row = em[slot]
            new_toks = [int(t) for t in row if t != NO_TOKEN]
            req.out.extend(new_toks)
            self.decode_tokens += len(new_toks)
            self.baseline_syncs += len(new_toks)
            if not active_mask[slot]:
                finished.append(req)
                del self.active[slot]
                self._retire_slot(slot, req)
        return finished

    def _retire_slot(self, slot: int, req: Request) -> None:
        """Return the core — and, paged, the block chain — to the pool
        (§4.3 terminate)."""
        self.tokens_finished += len(req.prompt) + len(req.out)
        if self.layout is None:
            self.kv_bytes_allocated += self._slot_bytes
            self.pool.release(slot)
            return
        plan = self._plans.pop(slot)
        chain = self._tables_host[slot]
        chain = chain[chain >= 0]
        self.kv_bytes_allocated += \
            (len(chain) - plan.n_shared) * self._block_bytes
        # device: drop one reference per chain block, free refcount-zero
        # blocks, clear the table row
        self.bstate, tables = paging.release_chain(
            self.bstate, self.cache["block_tables"], slot)
        self.cache = dict(self.cache, block_tables=tables)
        # host mirror + prefix map upkeep
        for b in chain:
            self._ref_host[b] -= 1
            if self._ref_host[b] == 0:
                key = self._block_hash.pop(int(b), None)
                if key is not None and self._prefix_map.get(key) == int(b):
                    del self._prefix_map[key]
        self._tables_host[slot] = -1
        self.pool.release(slot)

    def run_to_completion(self, requests: list[Request], max_ticks=10_000):
        """Continuous batching: admit whenever slots free up, decode in
        device-resident chunks.  Returns (done, device decode ticks).

        Raises ``RuntimeError`` when ``max_ticks`` is exhausted with
        requests still pending or active — the pre-fix behavior silently
        returned only the finished subset, so a too-small budget looked
        like a successful (shorter) run.  Partial outputs stay on the
        undrained ``Request`` objects for inspection."""
        pending = list(requests)
        done = []
        start_ticks = self.device_ticks
        while (pending or self.active or self._finished_instant) and \
                self.device_ticks - start_ticks < max_ticks:
            n = self.admit_many(pending)
            del pending[:n]
            if not self.active and not self._finished_instant:
                if pending:    # no capacity rentable and none draining
                    raise RuntimeError(
                        f"{len(pending)} requests stuck: pool has no "
                        f"rentable slot/blocks and no active request to "
                        f"drain")
                break
            done += self.step()
        if self._finished_instant:     # complete, just not yet reported
            done += self._finished_instant
            self._finished_instant = []
        if pending or self.active:
            rids = sorted([r.rid for r in self.active.values()] +
                          [r.rid for r in pending])
            raise RuntimeError(
                f"max_ticks={max_ticks} exhausted with {len(self.active)} "
                f"active and {len(pending)} pending requests undrained "
                f"(rids {rids}); partial outputs remain on the Request "
                f"objects")
        return done, self.device_ticks - start_ticks

    # -- accounting ---------------------------------------------------------
    def reset_stats(self) -> None:
        """Zero the accounting counters (pool/cache state untouched).
        Benches warm the jit caches on the engine they will time — each
        engine owns its own jitted closures, so warming a sibling engine
        warms nothing — then reset before the measured run."""
        self.host_syncs = self.baseline_syncs = 0
        self.device_ticks = self.decode_tokens = 0
        self.stalls = 0
        self.shared_block_hits = 0
        self.kv_bytes_allocated = 0
        self.tokens_finished = 0
        if self.layout is not None:
            # the block high-water mark restarts from what is in use now
            pool = self.bstate.pool
            self.bstate = self.bstate._replace(
                pool=pool._replace(peak_used=pool_lib.used(pool)))

    def sync_stats(self) -> dict:
        """Host-sync economy vs a per-slot-per-tick engine (same run)."""
        tokens = max(1, self.decode_tokens)
        return {
            "host_syncs": self.host_syncs,
            "baseline_syncs": self.baseline_syncs,
            "device_ticks": self.device_ticks,
            "decode_tokens": self.decode_tokens,
            "host_syncs_per_100_tokens": 100.0 * self.host_syncs / tokens,
            "baseline_syncs_per_100_tokens":
                100.0 * self.baseline_syncs / tokens,
            "sync_reduction_x": self.baseline_syncs / max(1, self.host_syncs),
        }

    def kv_stats(self) -> dict:
        """KV-cache economics over the *finished* requests: bytes the
        engine actually allocated for them per token they produced.
        Contiguous slots pay `max_seq` rows per admission regardless of
        the sequence; paged chains pay per rented (non-shared) block."""
        out = {
            "layout": "paged" if self.layout is not None else "contiguous",
            "kv_bytes_allocated": int(self.kv_bytes_allocated),
            "tokens_finished": int(self.tokens_finished),
            "kv_bytes_per_token":
                self.kv_bytes_allocated / max(1, self.tokens_finished),
        }
        if self.layout is not None:
            out.update(
                block_size=self.layout.block_size,
                n_blocks=self.layout.n_blocks,
                shared_block_hits=int(self.shared_block_hits),
                stalls=int(self.stalls),
                peak_blocks=int(self.bstate.pool.peak_used),
                blocks_in_use=int(np.sum(self._ref_host > 0)),
            )
        else:
            out["slot_bytes"] = int(self._slot_bytes)
        return out
