"""Serving runtime: prefill/decode step builders + EMPA slot pool.

The KV-cache slot pool *is* the paper's core pool: a request is a QT, a
cache slot is a core — rented on admission, returned at EOS (§4.3's
rent/terminate cycle), preallocation reserves slots for a stream of
requests (§5.1).  `CorePool` from the paper's own supervisor module drives
admission — the same semantics property-tested at the machine level.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.core.supervisor import CorePool
from repro.models import model as model_lib
from repro.runtime.sharding import ShardingRules, use_rules


def build_prefill_step(cfg: ArchConfig, max_seq: int,
                       rules: Optional[ShardingRules] = None):
    def prefill_step(params, batch):
        with use_rules(rules):
            return model_lib.prefill(params, batch, cfg, max_seq)
    return prefill_step


def build_decode_step(cfg: ArchConfig,
                      rules: Optional[ShardingRules] = None):
    def decode_step(params, token, cache):
        with use_rules(rules):
            return model_lib.decode_step(params, token, cache, cfg)
    return decode_step


# ---------------------------------------------------------------------------
# Host-side continuous batching over the slot pool
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray            # (S,) int32
    max_new: int = 16
    out: list = dataclasses.field(default_factory=list)
    slot: Optional[int] = None


class ServingEngine:
    """Batched greedy decoding with rent/return slot semantics.

    Single-sequence prefill writes into the rented slot's cache rows;
    decode advances every active slot each step (inactive slots are
    masked by feeding pad tokens and ignoring their logits).
    """

    def __init__(self, params, cfg: ArchConfig, *, n_slots: int,
                 max_seq: int, eos_id: int = 1,
                 decode_fn: Optional[Callable] = None):
        self.params, self.cfg = params, cfg
        self.max_seq, self.eos_id = max_seq, eos_id
        self.pool = CorePool(n_slots)
        self.active: dict[int, Request] = {}
        dtype = jax.tree_util.tree_leaves(params)[0].dtype
        self.cache = model_lib.init_cache(cfg, n_slots, max_seq, dtype=dtype)
        self._decode = jax.jit(decode_fn or build_decode_step(cfg))
        self._prefill1 = jax.jit(
            lambda p, b: model_lib.prefill(p, b, cfg, max_seq))

    # -- admission ---------------------------------------------------------
    def admit(self, req: Request) -> bool:
        slot = self.pool.rent()
        if slot is None:
            return False                      # pool exhausted: queue upstream
        req.slot = slot
        batch = {"tokens": jnp.asarray(req.prompt)[None]}
        if self.cfg.frontend == "vision":
            batch["vision_embeds"] = jnp.zeros(
                (1, self.cfg.n_frontend_tokens, self.cfg.frontend_dim),
                jnp.float32)
        if self.cfg.family == "encdec":
            batch["enc_embeds"] = jnp.zeros(
                (1, len(req.prompt), self.cfg.frontend_dim), jnp.float32)
        logits, cache1 = self._prefill1(self.params, batch)
        self._write_slot(slot, cache1)
        req.out.append(int(jnp.argmax(logits[0])))
        self.active[slot] = req
        return True

    def _write_slot(self, slot: int, cache1):
        def put(big, small):
            if big.ndim == 1:                 # pos: (n_slots,)
                return big.at[slot].set(small[0])
            return big.at[:, slot].set(small[:, 0])
        self.cache = jax.tree_util.tree_map(put, self.cache, cache1)

    # -- one decode tick over all active slots ------------------------------
    def step(self) -> list[Request]:
        if not self.active:
            return []
        tokens = np.zeros((self.pool.n,), np.int32)
        for slot, req in self.active.items():
            tokens[slot] = req.out[-1]
        logits, self.cache = self._decode(self.params,
                                          jnp.asarray(tokens), self.cache)
        finished = []
        for slot, req in list(self.active.items()):
            tok = int(jnp.argmax(logits[slot]))
            req.out.append(tok)
            if tok == self.eos_id or len(req.out) >= req.max_new:
                finished.append(req)
                del self.active[slot]
                self.pool.release(slot)       # core back to the pool (§4.3)
        return finished

    def run_to_completion(self, requests: list[Request], max_ticks=10_000):
        pending = list(requests)
        done = []
        ticks = 0
        while (pending or self.active) and ticks < max_ticks:
            while pending and self.admit(pending[0]):
                pending.pop(0)
            done += self.step()
            ticks += 1
        return done, ticks
