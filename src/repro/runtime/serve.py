"""Serving runtime: device-resident continuous batching over the EMPA pool.

The KV-cache slot pool *is* the paper's core pool: a request is a QT, a
cache slot is a core — rented on admission, returned at EOS (§4.3's
rent/terminate cycle), preallocation reserves slots for a stream of
requests (§5.1).  The refactor pushed the supervisor onto the device:

* per-slot decode state (last token, emitted count, budget, active mask)
  lives on device as a :class:`DecodeState`;
* one jitted **decode chunk** (`build_decode_chunk`) advances every active
  slot up to ``chunk`` tokens inside a single ``lax.while_loop`` — greedy
  argmax, EOS/max-new retirement and the active mask are all computed on
  device, so the host syncs once per chunk instead of once per slot per
  tick;
* admission packs every rentable pending prompt into one right-padded
  batched prefill (`build_admit_step`) that scatters prompt caches into
  the rented slots — one compiled call per admission round, not one per
  request.

Host Python keeps only what must be host-side: the rent/return ledger
(`core/supervisor.CorePool`, itself a thin wrapper over the same jittable
`runtime/pool` transitions) and the request queue.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.core.supervisor import CorePool
from repro.models import model as model_lib
from repro.runtime.sharding import ShardingRules, use_rules

NO_TOKEN = -1          # emitted-buffer sentinel: slot idle this iteration

# families whose prefill is exact under right-padding (causal attention);
# recurrent state (ssm/hybrid) would absorb pad tokens, so those admit
# one exact-length prompt per prefill call instead of a padded pack
PACKED_PREFILL_FAMILIES = ("dense", "moe", "vlm")


def build_prefill_step(cfg: ArchConfig, max_seq: int,
                       rules: Optional[ShardingRules] = None):
    def prefill_step(params, batch):
        with use_rules(rules):
            return model_lib.prefill(params, batch, cfg, max_seq)
    return prefill_step


def build_decode_step(cfg: ArchConfig,
                      rules: Optional[ShardingRules] = None):
    def decode_step(params, token, cache):
        with use_rules(rules):
            return model_lib.decode_step(params, token, cache, cfg)
    return decode_step


# ---------------------------------------------------------------------------
# Device-resident decode state + jitted transitions
# ---------------------------------------------------------------------------

class DecodeState(NamedTuple):
    """Per-slot decode supervisor state; every field is (n_slots,)."""

    tokens: jax.Array    # int32 — last emitted token (decode input)
    n_out: jax.Array     # int32 — tokens emitted so far (incl. prefill's)
    max_new: jax.Array   # int32 — per-request budget
    active: jax.Array    # bool — slot is decoding


def init_decode_state(n_slots: int) -> DecodeState:
    return DecodeState(tokens=jnp.zeros((n_slots,), jnp.int32),
                       n_out=jnp.zeros((n_slots,), jnp.int32),
                       max_new=jnp.zeros((n_slots,), jnp.int32),
                       active=jnp.zeros((n_slots,), bool))


def abstract_decode_state(n_slots: int) -> DecodeState:
    return jax.tree_util.tree_map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
        init_decode_state(n_slots))


def _merge_rows(new, old, keep_new):
    """Per-slot select between two cache leaves (batch axis 0 for `pos`,
    axis 1 for layer-stacked leaves — same convention as init_cache)."""
    if new.ndim == 1:
        return jnp.where(keep_new, new, old)
    shape = [1] * new.ndim
    shape[1] = -1
    return jnp.where(keep_new.reshape(shape), new, old)


def build_decode_chunk(cfg: ArchConfig, *, chunk: int, eos_id: int,
                       rules: Optional[ShardingRules] = None,
                       decode_fn: Optional[Callable] = None,
                       jit: bool = True):
    """Jitted multi-token decode tick: one host round-trip per `chunk`.

    fn(params, state, cache) -> (state, cache, emitted, iters) where
    `emitted` is (n_slots, chunk) int32 (NO_TOKEN for idle cells) and
    `iters` counts executed loop iterations (early exit when every slot
    retires).  The cache is donated: the engine decodes in place.
    """
    decode = decode_fn or build_decode_step(cfg, rules)

    def chunk_fn(params, state: DecodeState, cache):
        n = state.tokens.shape[0]
        emitted0 = jnp.full((n, chunk), NO_TOKEN, jnp.int32)

        def cond(carry):
            i, st, _, _ = carry
            return (i < chunk) & jnp.any(st.active)

        def body(carry):
            i, st, cache, emitted = carry
            logits, new_cache = decode(params, st.tokens, cache)
            nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            # a retired slot keeps its last token and frozen cache rows:
            # it can never perturb an active one
            tok = jnp.where(st.active, nxt, st.tokens)
            n_out = st.n_out + st.active.astype(jnp.int32)
            cache = jax.tree_util.tree_map(
                lambda a, b: _merge_rows(a, b, st.active), new_cache, cache)
            emitted = emitted.at[:, i].set(
                jnp.where(st.active, tok, NO_TOKEN))
            retire = st.active & ((tok == eos_id) | (n_out >= st.max_new))
            st = DecodeState(tok, n_out, st.max_new, st.active & ~retire)
            return i + jnp.int32(1), st, cache, emitted

        iters, state, cache, emitted = jax.lax.while_loop(
            cond, body, (jnp.int32(0), state, cache, emitted0))
        return state, cache, emitted, iters

    if not jit:        # the cluster supervisor jits with explicit shardings
        return chunk_fn
    return jax.jit(chunk_fn, donate_argnums=(2,))


def build_admit_step(cfg: ArchConfig, max_seq: int,
                     rules: Optional[ShardingRules] = None):
    """Jitted packed admission: batched prefill + scatter into rented slots.

    fn(params, tokens (G,Sp), lengths (G,), max_new (G,), slots (G,),
       state, cache, first) -> (state, cache, first).

    Rows whose slot is out of range (the G-padding rows) are dropped by
    the scatter (`mode="drop"`), so the call compiles once per Sp bucket.
    """

    def admit_fn(params, tokens, lengths, max_new, slots, state, cache,
                 first):
        g = tokens.shape[0]
        batch = {"tokens": tokens}
        if cfg.frontend == "vision":
            batch["vision_embeds"] = jnp.zeros(
                (g, cfg.n_frontend_tokens, cfg.frontend_dim), jnp.float32)
        if cfg.family == "encdec":
            batch["enc_embeds"] = jnp.zeros(
                (g, tokens.shape[1], cfg.frontend_dim), jnp.float32)
        with use_rules(rules):
            logits, cache_g = model_lib.prefill(params, batch, cfg, max_seq,
                                                lengths=lengths)
        ftok = jnp.argmax(logits, axis=-1).astype(jnp.int32)

        def put(big, small):
            if big.ndim == 1:                  # pos: (n_slots,)
                return big.at[slots].set(small, mode="drop")
            return big.at[:, slots].set(
                small.astype(big.dtype), mode="drop")
        cache = jax.tree_util.tree_map(put, cache, cache_g)
        state = DecodeState(
            tokens=state.tokens.at[slots].set(ftok, mode="drop"),
            n_out=state.n_out.at[slots].set(1, mode="drop"),
            max_new=state.max_new.at[slots].set(max_new, mode="drop"),
            active=state.active.at[slots].set(True, mode="drop"))
        first = first.at[slots].set(ftok, mode="drop")
        return state, cache, first

    return jax.jit(admit_fn, donate_argnums=(6,))


# ---------------------------------------------------------------------------
# Continuous-batching engine
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray            # (S,) int32
    max_new: int = 16
    out: list = dataclasses.field(default_factory=list)
    slot: Optional[int] = None


def _pow2_bucket(n: int, cap: int) -> int:
    """Next power of two ≥ n, clipped to cap — bounds recompiles."""
    b = 1
    while b < n:
        b <<= 1
    return min(b, cap) if n <= cap else n


class ServingEngine:
    """Batched greedy decoding with rent/return slot semantics.

    The host owns the pool ledger and the queue; everything per-tick —
    argmax, EOS / max-new retirement, the active mask, cache advancement —
    runs inside one jitted decode chunk with a donated cache.  The host
    syncs once per chunk (and reads nothing at admission), which is what
    turns sequential per-slot coordination into streaming throughput.
    """

    def __init__(self, params, cfg: ArchConfig, *, n_slots: int,
                 max_seq: int, eos_id: int = 1,
                 decode_fn: Optional[Callable] = None,
                 chunk: int = 8,
                 rules: Optional[ShardingRules] = None):
        self.params, self.cfg = params, cfg
        self.max_seq, self.eos_id, self.chunk = max_seq, eos_id, chunk
        self.pool = CorePool(n_slots)
        self.active: dict[int, Request] = {}
        dtype = jax.tree_util.tree_leaves(params)[0].dtype
        self.cache = model_lib.init_cache(cfg, n_slots, max_seq, dtype=dtype)
        self.dstate = init_decode_state(n_slots)
        self._first = jnp.zeros((n_slots,), jnp.int32)
        self._need_first: set[int] = set()
        self._chunk_fn = build_decode_chunk(cfg, chunk=chunk, eos_id=eos_id,
                                            rules=rules, decode_fn=decode_fn)
        self._admit_fn = build_admit_step(cfg, max_seq, rules=rules)
        self._packed = cfg.family in PACKED_PREFILL_FAMILIES
        # accounting: host round-trips vs the one-sync-per-slot-per-tick
        # baseline an un-refactored engine would have paid
        self.host_syncs = 0
        self.baseline_syncs = 0
        self.device_ticks = 0
        self.decode_tokens = 0

    # -- admission ---------------------------------------------------------
    def admit(self, req: Request) -> bool:
        return self.admit_many([req]) == 1

    def admit_many(self, requests: list[Request]) -> int:
        """Rent slots and prefill as many of `requests` as the pool allows.

        Packed admission: one batched padded prefill per call (causal
        families); recurrent families fall back to one exact-length
        prefill per request through the same jitted path.
        """
        granted: list[Request] = []
        for req in requests:
            slot = self.pool.rent()
            if slot is None:
                break                     # pool exhausted: queue upstream
            req.slot = slot
            granted.append(req)
        if not granted:
            return 0
        groups = [granted] if self._packed else [[r] for r in granted]
        for group in groups:
            self._prefill_group(group)
        for req in granted:
            self.active[req.slot] = req
            self._need_first.add(req.slot)
        return len(granted)

    def _prefill_group(self, group: list[Request]) -> None:
        g = len(group)
        n = self.pool.n
        maxlen = max(len(r.prompt) for r in group)
        span = _pow2_bucket(maxlen, self.max_seq) if self._packed else maxlen
        # pad the group to a pow2 row count: compiles stay bounded to
        # log2(n_slots) variants per span bucket, while a single trickle
        # admission doesn't pay a full n_slots-row prefill
        gpad = _pow2_bucket(g, n) if self._packed else g
        tokens = np.zeros((gpad, span), np.int32)
        lengths = np.ones((gpad,), np.int32)
        max_new = np.zeros((gpad,), np.int32)
        slots = np.full((gpad,), n, np.int32)   # n = out of range -> dropped
        for i, r in enumerate(group):
            tokens[i, :len(r.prompt)] = r.prompt
            lengths[i] = len(r.prompt)
            max_new[i] = r.max_new
            slots[i] = r.slot
        self.dstate, self.cache, self._first = self._admit_fn(
            self.params, jnp.asarray(tokens), jnp.asarray(lengths),
            jnp.asarray(max_new), jnp.asarray(slots), self.dstate,
            self.cache, self._first)
        # un-refactored baseline: one argmax sync per admitted request
        self.baseline_syncs += g

    # -- one decode chunk over all active slots -----------------------------
    def step(self) -> list[Request]:
        """Advance every active slot up to `chunk` tokens; one host sync."""
        if not self.active:
            return []
        self.dstate, self.cache, emitted, iters = self._chunk_fn(
            self.params, self.dstate, self.cache)
        em, active_mask, first, iters = jax.device_get(
            (emitted, self.dstate.active, self._first, iters))
        self.host_syncs += 1
        self.device_ticks += int(iters)
        finished = []
        for slot, req in list(self.active.items()):
            if slot in self._need_first:
                req.out.append(int(first[slot]))
                self._need_first.discard(slot)
            row = em[slot]
            new_toks = [int(t) for t in row if t != NO_TOKEN]
            req.out.extend(new_toks)
            self.decode_tokens += len(new_toks)
            self.baseline_syncs += len(new_toks)
            if not active_mask[slot]:
                finished.append(req)
                del self.active[slot]
                self.pool.release(slot)   # core back to the pool (§4.3)
        return finished

    def run_to_completion(self, requests: list[Request], max_ticks=10_000):
        """Continuous batching: admit whenever slots free up, decode in
        device-resident chunks.  Returns (done, device decode ticks)."""
        pending = list(requests)
        done = []
        start_ticks = self.device_ticks
        while (pending or self.active) and \
                self.device_ticks - start_ticks < max_ticks:
            n = self.admit_many(pending)
            del pending[:n]
            if not self.active:
                if pending:    # no slots rentable and none draining
                    raise RuntimeError(
                        f"{len(pending)} requests stuck: pool has no "
                        f"rentable slot and no active request to drain")
                break
            done += self.step()
        return done, self.device_ticks - start_ticks

    # -- accounting ---------------------------------------------------------
    def sync_stats(self) -> dict:
        """Host-sync economy vs a per-slot-per-tick engine (same run)."""
        tokens = max(1, self.decode_tokens)
        return {
            "host_syncs": self.host_syncs,
            "baseline_syncs": self.baseline_syncs,
            "device_ticks": self.device_ticks,
            "decode_tokens": self.decode_tokens,
            "host_syncs_per_100_tokens": 100.0 * self.host_syncs / tokens,
            "baseline_syncs_per_100_tokens":
                100.0 * self.baseline_syncs / tokens,
            "sync_reduction_x": self.baseline_syncs / max(1, self.host_syncs),
        }
