"""Elastic capacity management: the device fleet as an EMPA core pool.

A pod/host is a core: it can be rented (join the mesh), disabled
("overheating", §4.1.2 — failed health check) and returned.  Because JAX
SPMD requires a rectangular mesh, elasticity is a LADDER of pre-validated
degraded meshes (launch/mesh.make_degraded_mesh): on capacity loss the
manager picks the largest level that fits the healthy host count,
re-lowers the already-validated plan, and training resumes from the last
durable checkpoint.  Data re-sharding is free: batches are a pure function
of (seed, step, host_id) — see data/pipeline.py.

Straggler mitigation = the paper's PREALLOCATION (§5.1): `spares` hosts
are kept out of the mesh and hot-swapped for persistently slow or failed
hosts, so the mesh shape (and the compiled program) never changes for a
single-host loss.  A swap is rent(spare) + disable(slow), not a recompile.

The pool discipline itself is the shared jittable transition set in
``runtime/pool.py`` (via the `CorePool` host wrapper) — the exact same
rent/release/disable semantics the serving engine runs on device, so the
fleet manager and the slot supervisor can never drift apart.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

from repro.core.supervisor import CorePool
from repro.runtime.pool import SlotPoolState

# (total chips required, mesh kwargs for launch/mesh.make_degraded_mesh)
LADDER = [
    (512, {"level": 0}),   # 2 × 16 × 16
    (256, {"level": 1}),   # 1 × 16 × 16
    (256, {"level": 2}),   # 16 × 16 (single-pod program)
    (128, {"level": 3}),   # 8 × 16
    (64, {"level": 4}),    # 4 × 16
]

CHIPS_PER_HOST = 4  # v5e host = 4 chips


# One shared health-event vocabulary for the *training* fleet (this
# module) and the *serving* fleet's replica quarantine
# (runtime/supervisor.FleetSupervisor).  Both fault paths append the same
# record type to their event logs, so the two cannot drift apart — the
# common health-event fixture in tests/runtime/conftest.py asserts every
# emitted event against this vocabulary for both managers.
EVENT_KINDS = frozenset({
    "fail", "slow", "swap", "relower", "recover",        # training hosts
    "quarantine", "migrate", "dead_letter", "readmit",   # serving replicas
})


@dataclasses.dataclass
class Event:
    kind: str          # one of EVENT_KINDS
    host: int          # host (training) / replica (serving); -1 fleet-wide
    detail: str = ""

    def __post_init__(self):
        if self.kind not in EVENT_KINDS:
            raise ValueError(f"unknown health-event kind {self.kind!r}; "
                             f"known: {sorted(EVENT_KINDS)}")


class ElasticManager:
    def __init__(self, n_hosts: int, *, spares: int = 2,
                 on_relower: Optional[Callable[[int], None]] = None):
        """`n_hosts` includes the spares (EMPA preallocation)."""
        self.pool = CorePool(n_hosts)
        self.spares = spares
        self.on_relower = on_relower
        self.level = 0
        self.events: list[Event] = []
        # rent the active fleet in ONE vectorized pool transition (the
        # same `rent_many` the paged serving chunk uses to grow block
        # chains on device); leave `spares` in the pool, preallocated
        self.active = self.pool.rent_many(n_hosts - spares)
        self.pool.preallocate(self.active[0], spares)

    # -- health signals ------------------------------------------------
    @property
    def pool_state(self) -> SlotPoolState:
        """The underlying jittable pool state (shared with serving)."""
        return self.pool.state

    @property
    def healthy_chips(self) -> int:
        return len(self.active) * CHIPS_PER_HOST

    def required_level(self) -> int:
        for i, (chips, _) in enumerate(LADDER):
            if self.healthy_chips >= chips:
                return i
        raise RuntimeError("fleet below minimum viable capacity")

    def fail(self, host: int) -> Event:
        """A host died.  Swap in a spare if available, else degrade."""
        assert host in self.active
        self.active.remove(host)
        self.pool.disable(host)
        self.events.append(Event("fail", host))
        spare = self.pool.rent()          # preallocated spares first
        if spare is not None:
            self.active.append(spare)
            ev = Event("swap", spare, f"replaced failed host {host}")
            self.events.append(ev)
            return ev                     # mesh unchanged: no recompile
        new_level = self.required_level()
        if new_level != self.level:
            self.level = new_level
            self.events.append(Event("relower", host,
                                     f"degraded to ladder level {new_level}"))
            if self.on_relower:
                self.on_relower(new_level)
        return self.events[-1]

    def straggler(self, host: int) -> Event:
        """Persistently slow host: treat as failed (swap, keep it benched)."""
        ev = self.fail(host)
        self.events.append(Event("slow", host, "benched as straggler"))
        return ev

    def recover(self, host: int) -> None:
        """A repaired host rejoins the pool as a spare.

        A failed host keeps its rent while benched (disable only flags
        it), so rejoining means enable *and* release — otherwise the
        "spare" could never be granted by the next `fail`'s rent."""
        self.pool.enable(host)
        if host not in self.active:
            self.pool.release(host)
        self.events.append(Event("recover", host))

    def check_invariants(self) -> None:
        self.pool.check_invariants()
        assert len(set(self.active)) == len(self.active)
