"""Donation coverage: every persistent-state buffer donated AND aliased.

Two failure modes, both silent at run time:

* **declared-but-unaliased** — an argnum is in ``donate_argnums`` but
  XLA could not alias some of its leaves to outputs (a dtype/shape
  drift between the state a tick takes and the state it returns), so
  the "in-place" tick quietly double-buffers.  The lowered StableHLO
  carries one ``tf.aliasing_output`` attribute per leaf that really
  aliases; we count them against the donated leaf count.
* **persistent-but-undonated** — the tick signature grew a new state
  buffer (cache-sized, flowing input -> output) that nobody added to
  ``donate_argnums``.  Detected structurally: a non-donated argnum
  whose leaf (shape, dtype) multiset is contained in the outputs' and
  whose byte size is within ``CANDIDATE_FRACTION`` of the largest
  donated buffer is state by any reasonable reading — params (argnum 0)
  are exempt (weights are shared across ticks, never donated).
"""
from __future__ import annotations

import re
from typing import List, Optional

import jax
import numpy as np

from repro.analysis.families import TickSpec, lower_spec
from repro.analysis.report import Finding, info, violation

ALIAS_ATTR = re.compile(r"tf\.aliasing_output")

# an undonated input this fraction of the largest donated buffer (or
# larger) that also round-trips to the outputs is persistent state
CANDIDATE_FRACTION = 0.25


def _leaves(tree):
    return jax.tree_util.tree_leaves(tree)


def _nbytes(leaf) -> int:
    return int(np.prod(leaf.shape, dtype=np.int64)) * \
        np.dtype(leaf.dtype).itemsize


def _sig(tree):
    """Leaf (shape, dtype) multiset of a pytree."""
    sig = {}
    for leaf in _leaves(tree):
        key = (tuple(leaf.shape), np.dtype(leaf.dtype).str)
        sig[key] = sig.get(key, 0) + 1
    return sig


def _contained(small: dict, big: dict) -> bool:
    return all(big.get(k, 0) >= n for k, n in small.items())


def audit_donation(spec: TickSpec, lowered=None) -> List[Finding]:
    findings: List[Finding] = []

    # -- aliasing: donated leaves must appear as tf.aliasing_output ------
    donated_leaves = sum(len(_leaves(spec.abstract_args[i]))
                         for i in spec.donate_argnums)
    if lowered is None:
        lowered = lower_spec(spec)
    aliased = len(ALIAS_ATTR.findall(lowered.as_text()))
    if aliased < donated_leaves:
        findings.append(violation(
            "donation", spec.name,
            f"{donated_leaves - aliased} of {donated_leaves} donated "
            f"leaves lowered without tf.aliasing_output — the donation "
            f"is declared but XLA could not alias them (shape/dtype "
            f"drift between state in and state out); the tick "
            f"double-buffers"))
    else:
        findings.append(info(
            "donation", spec.name,
            f"all {donated_leaves} donated leaves aliased in the "
            f"compiled output"))

    # -- coverage: no large persistent input left undonated --------------
    out_sig = _sig(jax.eval_shape(spec.step_fn, *spec.abstract_args))
    donated_bytes = [sum(_nbytes(leaf) for leaf in
                         _leaves(spec.abstract_args[i]))
                     for i in spec.donate_argnums]
    floor = CANDIDATE_FRACTION * max(donated_bytes) if donated_bytes else 0
    for argnum, arg in enumerate(spec.abstract_args):
        if argnum == 0 or argnum in spec.donate_argnums:
            continue
        arg_bytes = sum(_nbytes(leaf) for leaf in _leaves(arg))
        if arg_bytes >= floor and floor > 0 and \
                _contained(_sig(arg), out_sig):
            findings.append(violation(
                "donation", spec.name,
                f"argnum {argnum} ({arg_bytes} bytes) flows input -> "
                f"output like persistent state but is not in "
                f"donate_argnums={spec.donate_argnums} — donate it or "
                f"the tick copies it every call"))
    return findings
