"""Jit-site registry: the auditor's static meta-information manifest.

Every tick builder in ``runtime/serve.py`` finishes through one helper
(``serve._register_jit_site``) that records a :class:`JitSite` here —
the site's donation contract (which argnums carry persistent device
state) and its static-shape keys (the values that force a recompile
when they change).  The auditor cross-checks the registry against what
actually lowered: a tick whose signature grew a new state buffer that
nobody donated, or whose static key space silently became unbounded,
fails the audit instead of shipping.

This module must stay import-light (stdlib only): ``runtime/serve.py``
imports it at module load, so pulling jax or the analysis passes in
here would create a cycle.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Tuple


@dataclasses.dataclass(frozen=True)
class JitSite:
    """One ``jax.jit`` call site in the serving runtime.

    ``state_args`` maps donated argnum -> the name of the persistent
    device buffer it carries (``cache`` / ``bstate`` / ``dstate``);
    ``static_keys`` are the (name, value) pairs baked into this build's
    compiled shape — the retrace audit enumerates their reachable
    space.
    """

    name: str                       # e.g. "decode_chunk/paged"
    family: str                     # builder family, e.g. "decode_chunk"
    layout: str                     # "contiguous" | "paged"
    donate_argnums: Tuple[int, ...]
    state_args: Dict[int, str]
    static_keys: Tuple[Tuple[str, object], ...] = ()

    def to_json(self) -> dict:
        return {
            "name": self.name,
            "family": self.family,
            "layout": self.layout,
            "donate_argnums": list(self.donate_argnums),
            "state_args": {str(k): v for k, v in self.state_args.items()},
            "static_keys": [[k, v] for k, v in self.static_keys],
        }


_REGISTRY: Dict[str, JitSite] = {}


def register_site(site: JitSite) -> None:
    """Record (or refresh) a jit site.  Builders run many times per
    process with different static keys; latest build wins — the auditor
    builds its family matrix immediately before reading the registry."""
    _REGISTRY[site.name] = site


def sites() -> Dict[str, JitSite]:
    return dict(_REGISTRY)


def get(name: str) -> JitSite:
    return _REGISTRY[name]


def clear() -> None:
    _REGISTRY.clear()
