"""Retrace-key audit: every jit site's static key space is small and finite.

A jit site recompiles once per distinct static key (fragment width,
spec width, pow2 span/group bucket).  The repo's discipline is that
every such space is *bounded by construction* — PR 6's perf diagnosis
found the one that wasn't (``seed_slot`` keyed on raw prompt length,
one retrace per distinct length) and it silently erased the
speculation win.  This audit enumerates each site's reachable key
space by *evaluating the actual bucketing code* over the full input
range (``serve.retrace_key_spaces`` brute-forces ``_pow2_bucket`` over
every admissible length — a hand-kept list could rot exactly like the
donation lists this package exists to check) and fails if any space is
unbounded (``None``) or exceeds its declared budget.

Budgets: ``log2`` bucketing means the admission space is
``(log2(max_seq)+1) * (log2(n_slots)+1)`` keys; every tick family is a
singleton (its keys are fixed at engine construction).  A site may
declare a larger budget in ``BUDGETS``; anything undeclared gets
``DEFAULT_BUDGET``.
"""
from __future__ import annotations

from typing import Dict, List, Optional

from repro.analysis.report import Finding, info, violation

DEFAULT_BUDGET = 8          # singleton tick families, with headroom

# per-site-family overrides; admission compiles one variant per
# (span bucket, group bucket) pair
BUDGETS: Dict[str, int] = {}


def admission_budget(max_seq: int, n_slots: int) -> int:
    return (max_seq.bit_length() + 1) * (n_slots.bit_length() + 1)


def audit_retrace(spaces: Dict[str, Optional[list]], *,
                  max_seq: int, n_slots: int,
                  budgets: Optional[Dict[str, int]] = None) -> List[Finding]:
    """``spaces`` maps site name -> list of reachable static keys, or
    ``None`` for a site whose key space could not be bounded (always a
    violation — an unbounded site compiles per request)."""
    budgets = dict(BUDGETS, **(budgets or {}))
    findings: List[Finding] = []
    for name in sorted(spaces):
        space = spaces[name]
        budget = budgets.get(
            name, admission_budget(max_seq, n_slots)
            if name.startswith("admit_step") else DEFAULT_BUDGET)
        if space is None:
            findings.append(violation(
                "retrace", name,
                "static key space is unbounded — the site recompiles "
                "per distinct runtime value (the seed_slot failure "
                "mode)"))
        elif len(space) > budget:
            findings.append(violation(
                "retrace", name,
                f"{len(space)} reachable static keys exceed the "
                f"declared budget of {budget} — bucketing has rotted "
                f"(raw lengths reaching a jit boundary?)"))
        else:
            findings.append(info(
                "retrace", name,
                f"{len(space)} reachable static key(s) within budget "
                f"{budget}"))
    return findings


def serve_key_spaces(*, max_seq: int, n_slots: int,
                     block_size: Optional[int] = None,
                     offset: int = 0) -> Dict[str, list]:
    """The serving runtime's actual key spaces (after the tick builders
    have registered their sites — call via the families enumeration)."""
    from repro.runtime import serve as serve_lib
    return serve_lib.retrace_key_spaces(
        max_seq=max_seq, n_slots=n_slots, block_size=block_size,
        offset=offset)
