"""Findings and the machine-readable audit report (``AUDIT.json``)."""
from __future__ import annotations

import dataclasses
import json
from typing import List, Optional


@dataclasses.dataclass
class Finding:
    """One audit result.  ``severity`` is ``"violation"`` (always fails
    the run), ``"warning"`` (fails under ``--strict``) or ``"info"``
    (recorded, never fails — the before/after notes live here)."""

    analysis: str               # donation | transfers | retrace | ...
    subject: str                # family/site/module the finding is about
    severity: str               # violation | warning | info
    message: str

    def to_json(self) -> dict:
        return dataclasses.asdict(self)


def violation(analysis: str, subject: str, message: str) -> Finding:
    return Finding(analysis, subject, "violation", message)


def warning(analysis: str, subject: str, message: str) -> Finding:
    return Finding(analysis, subject, "warning", message)


def info(analysis: str, subject: str, message: str) -> Finding:
    return Finding(analysis, subject, "info", message)


@dataclasses.dataclass
class Report:
    """The full audit run: per-family analysis results plus repo lint."""

    findings: List[Finding] = dataclasses.field(default_factory=list)
    families: List[dict] = dataclasses.field(default_factory=list)
    sites: List[dict] = dataclasses.field(default_factory=list)
    meta: dict = dataclasses.field(default_factory=dict)

    def extend(self, findings) -> None:
        self.findings.extend(findings)

    def violations(self, strict: bool = False) -> List[Finding]:
        bad = {"violation", "warning"} if strict else {"violation"}
        return [f for f in self.findings if f.severity in bad]

    def ok(self, strict: bool = False) -> bool:
        return not self.violations(strict)

    def to_json(self) -> dict:
        counts = {"violation": 0, "warning": 0, "info": 0}
        for f in self.findings:
            counts[f.severity] = counts.get(f.severity, 0) + 1
        return {
            "version": 1,
            "meta": self.meta,
            "counts": counts,
            "clean": counts["violation"] == 0,
            "families": self.families,
            "jit_sites": self.sites,
            "findings": [f.to_json() for f in self.findings],
        }

    def write(self, path: str) -> None:
        with open(path, "w") as fh:
            json.dump(self.to_json(), fh, indent=2, sort_keys=False)
            fh.write("\n")


def summarize(report: Report, strict: bool = False) -> str:
    lines = []
    for f in report.findings:
        if f.severity == "info":
            continue
        lines.append(f"[{f.severity}] {f.analysis}: {f.subject}: "
                     f"{f.message}")
    n_bad = len(report.violations(strict))
    verdict = "CLEAN" if n_bad == 0 else f"{n_bad} FAILURE(S)"
    lines.append(f"audit: {len(report.families)} tick cells, "
                 f"{len(report.sites)} jit sites, "
                 f"{len(report.findings)} findings -> {verdict}")
    return "\n".join(lines)
