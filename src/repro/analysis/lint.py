"""AST-level repo lint: the rules a reviewer used to enforce by memory.

Five rules, all specific to this codebase's discipline:

* **L1 host-sync-in-transition** — the pure transition modules
  (``runtime/pool.py``, ``runtime/paging.py``, ``runtime/draft.py``)
  run *inside* jitted device programs; a ``int()`` / ``float()`` /
  ``bool()`` / ``.item()`` / ``np.asarray`` on a traced value there is
  either a trace error waiting to happen or a hidden host sync.  Each
  module's explicitly host-side helpers (invariant checkers, stats
  mergers, the host admission seeding) are allowlisted by name.
* **L2 kernel-oracle-pairing** — every ``kernels/<name>/`` package
  ships ``kernel.py`` + ``ops.py`` + ``ref.py`` and is named in
  ``repro.kernels.KERNEL_TESTS`` with an existing interpret-mode test
  under ``tests/kernels/`` that actually references the package.
* **L3 tracer-branch** — inside a tick builder (``serve.build_*``),
  the nested step functions close over *traced* parameters; a Python
  ``if``/``while`` on one is a silent trace-time constant fold (it
  branches on the tracer, not the value).  Static uses — ``.shape`` /
  ``.dtype`` / ``.ndim`` / ``.size`` attributes and ``is None``
  identity checks — are fine.
* **L4 fault-hook** — the chaos layer (``runtime/faults.py``) must be
  dead code unless a ``FaultPlan`` is armed, and must never reach
  traced code.  Two sub-rules: (a) tick builders (``build_*``) and
  everything nested in them may not reference any fault-named symbol —
  no chaos branches on traced values; (b) outside the arming allowlist
  (``__init__`` / ``arm_faults``), every ``_faults`` reference must sit
  lexically inside an ``if`` whose test mentions ``_faults``, so a
  never-armed engine takes exactly one pointer-is-None branch per tick
  and zero fault-layer calls.
* **L5 tier-host-side** — ``Request.tier`` is host-side scheduling
  metadata: any ``.tier`` attribute read inside a tick builder
  (``serve.build_*``) would bake the scheduling class into compiled
  code, breaking the tiered engine's token-exactness-by-construction
  guarantee (and adding a retrace axis).  The rule bans the attribute
  from builders outright.

Every rule takes source text, so the known-bad fixtures in
``tests/analysis`` feed synthetic modules straight in.
"""
from __future__ import annotations

import ast
import os
from typing import Dict, List, Optional, Set

from repro.analysis.report import Finding, info, violation

# L1: functions in the transition modules that are host-side *by
# design* — they take already-materialized state (invariant checking,
# cross-engine stats merging) or host data (admission-time prompt
# seeding), never traced values
HOST_ALLOWLIST: Dict[str, Set[str]] = {
    "pool.py": {"check_invariants", "merge_stats"},
    "paging.py": {"check_invariants", "merge_block_stats"},
    "draft.py": {"seed_slot"},
}

HOST_BUILTINS = {"int", "float", "bool"}
STATIC_ATTRS = {"shape", "dtype", "ndim", "size"}


def _host_call_label(node: ast.Call) -> Optional[str]:
    """Name of the host-sync call this node performs, if any."""
    fn = node.func
    if isinstance(fn, ast.Name) and fn.id in HOST_BUILTINS:
        return f"{fn.id}()"
    if isinstance(fn, ast.Attribute):
        if fn.attr == "item":
            return ".item()"
        if isinstance(fn.value, ast.Name):
            if fn.value.id == "np" and fn.attr in {"asarray", "array"}:
                return f"np.{fn.attr}()"
            if fn.value.id == "jax" and fn.attr == "device_get":
                return "jax.device_get()"
    return None


def lint_transition_source(src: str, module_name: str,
                           allowlist: Optional[Set[str]] = None
                           ) -> List[Finding]:
    """L1 over one module's source.  ``module_name`` is the bare file
    name (``pool.py``); the allowlist defaults to HOST_ALLOWLIST."""
    if allowlist is None:
        allowlist = HOST_ALLOWLIST.get(module_name, set())
    tree = ast.parse(src)
    findings: List[Finding] = []
    for node in ast.walk(tree):
        if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        if node.name in allowlist:
            continue
        for sub in ast.walk(node):
            if isinstance(sub, ast.Call):
                label = _host_call_label(sub)
                if label:
                    findings.append(violation(
                        "lint/host-sync", f"{module_name}:{node.name}",
                        f"{label} at line {sub.lineno} — a host sync "
                        f"inside a pure transition module (allowlist "
                        f"host-side helpers by name if intentional)"))
    return findings


def _traced_names(expr: ast.AST, params: Set[str]) -> Set[str]:
    """Parameter names whose *value* (not a static attribute) the
    expression depends on."""
    bad: Set[str] = set()

    def visit(node: ast.AST) -> None:
        if isinstance(node, ast.Attribute) and node.attr in STATIC_ATTRS:
            return                      # x.shape[...] etc — static
        if isinstance(node, ast.Compare) and \
                all(isinstance(op, (ast.Is, ast.IsNot)) for op in node.ops):
            return                      # `x is None` — host identity
        if isinstance(node, ast.Name) and node.id in params:
            bad.add(node.id)
            return
        for child in ast.iter_child_nodes(node):
            visit(child)

    visit(expr)
    return bad


def lint_tick_builder_source(src: str, module_name: str = "serve.py"
                             ) -> List[Finding]:
    """L3 over one module's source: no Python ``if``/``while`` on a
    traced parameter inside functions nested in a ``build_*`` builder
    (the builder's own arguments — ``chunk``, ``jit``, ``paged`` — are
    static config and branch freely)."""
    tree = ast.parse(src)
    findings: List[Finding] = []
    for node in ast.walk(tree):
        if not (isinstance(node, ast.FunctionDef)
                and node.name.startswith("build_")):
            continue
        for inner in ast.walk(node):
            if not isinstance(inner, ast.FunctionDef) or inner is node:
                continue
            params = {a.arg for a in
                      inner.args.args + inner.args.kwonlyargs
                      + inner.args.posonlyargs}
            for stmt in ast.walk(inner):
                if isinstance(stmt, (ast.If, ast.While)):
                    bad = _traced_names(stmt.test, params)
                    if bad:
                        kind = "if" if isinstance(stmt, ast.If) \
                            else "while"
                        findings.append(violation(
                            "lint/tracer-branch",
                            f"{module_name}:{node.name}.{inner.name}",
                            f"Python `{kind}` on traced parameter(s) "
                            f"{sorted(bad)} at line {stmt.lineno} — "
                            f"branches on the tracer, not the value "
                            f"(use jnp.where / lax.cond)"))
    return findings


# L4: the only functions allowed to touch `_faults` unguarded — the
# null initialization and the arming entry point itself
FAULT_HOOK_ALLOWLIST: Set[str] = {"__init__", "arm_faults"}


def _is_fault_name(name: str) -> bool:
    # "default" contains "fault": strip it before matching, or every
    # `default_mask=None` keyword would trip the rule
    return "fault" in name.lower().replace("default", "")


def _ref_label(node: ast.AST) -> Optional[str]:
    if isinstance(node, ast.Name):
        return node.id
    if isinstance(node, ast.Attribute):
        return node.attr
    return None


def _mentions_faults(expr: ast.AST) -> bool:
    return any(_ref_label(n) == "_faults" for n in ast.walk(expr))


def lint_fault_hooks_source(src: str, module_name: str = "serve.py",
                            allowlist: Optional[Set[str]] = None
                            ) -> List[Finding]:
    """L4 over one module's source.

    (a) ``build_*`` tick builders are traced: any fault-named reference
    inside one means chaos reached compiled code.  (b) everywhere else,
    a ``_faults`` reference outside the allowlist must be lexically
    inside an ``if`` testing ``_faults`` — fault hooks are dead code
    until :meth:`arm_faults` runs."""
    if allowlist is None:
        allowlist = FAULT_HOOK_ALLOWLIST
    tree = ast.parse(src)
    findings: List[Finding] = []

    # (a) no fault-named symbol anywhere under a tick builder
    for node in ast.walk(tree):
        if not (isinstance(node, ast.FunctionDef)
                and node.name.startswith("build_")):
            continue
        for sub in ast.walk(node):
            label = _ref_label(sub)
            if label and _is_fault_name(label):
                findings.append(violation(
                    "lint/fault-hook", f"{module_name}:{node.name}",
                    f"fault-injection symbol {label!r} at line "
                    f"{sub.lineno} inside a tick builder — chaos must "
                    f"never reach traced code"))

    # (b) `_faults` outside the allowlist only under an `if _faults` guard
    for fn in ast.walk(tree):
        if not isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        if fn.name in allowlist:
            continue

        def visit(node: ast.AST, guarded: bool) -> None:
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)) \
                    and node is not fn:
                return                      # walked on its own
            if isinstance(node, ast.If) and _mentions_faults(node.test):
                for child in node.body:     # the guard itself is the
                    visit(child, True)      # one allowed bare reference
                for child in node.orelse:
                    visit(child, guarded)
                return
            if not guarded and _ref_label(node) == "_faults":
                findings.append(violation(
                    "lint/fault-hook", f"{module_name}:{fn.name}",
                    f"unguarded `_faults` reference at line "
                    f"{node.lineno} — fault hooks must be dead code "
                    f"unless a FaultPlan is armed (wrap in "
                    f"`if self._faults is not None:` or allowlist the "
                    f"arming function)"))
            for child in ast.iter_child_nodes(node):
                visit(child, guarded)

        visit(fn, False)
    return findings


def lint_tier_reads_source(src: str, module_name: str = "serve.py"
                           ) -> List[Finding]:
    """L5 over one module's source: no ``.tier`` attribute access
    anywhere under a ``build_*`` tick builder.  The scheduling class is
    read only by the host-side admission controller / router — a traced
    tick that branched on it would compile the policy into the program
    (and silently fold it at trace time, exactly the L3 failure mode)."""
    tree = ast.parse(src)
    findings: List[Finding] = []
    for node in ast.walk(tree):
        if not (isinstance(node, ast.FunctionDef)
                and node.name.startswith("build_")):
            continue
        for sub in ast.walk(node):
            if isinstance(sub, ast.Attribute) and sub.attr == "tier":
                findings.append(violation(
                    "lint/tier-host-side", f"{module_name}:{node.name}",
                    f"`.tier` read at line {sub.lineno} inside a tick "
                    f"builder — Request.tier is host-side scheduling "
                    f"metadata and must never reach traced code (keep "
                    f"tier policy in the admission controller)"))
    return findings


def _repo_root() -> str:
    # src/repro/analysis/lint.py -> repo root is three dirs up from src
    here = os.path.dirname(os.path.abspath(__file__))
    return os.path.dirname(os.path.dirname(os.path.dirname(here)))


def lint_kernel_manifest(root: Optional[str] = None) -> List[Finding]:
    """L2: package tree <-> KERNEL_TESTS manifest <-> tests/kernels."""
    from repro.kernels import KERNEL_TESTS
    root = root or _repo_root()
    kdir = os.path.join(root, "src", "repro", "kernels")
    tdir = os.path.join(root, "tests", "kernels")
    findings: List[Finding] = []
    packages = sorted(
        name for name in os.listdir(kdir)
        if os.path.isfile(os.path.join(kdir, name, "kernel.py")))
    for name in packages:
        pkg = os.path.join(kdir, name)
        for required in ("ref.py", "ops.py"):
            if not os.path.isfile(os.path.join(pkg, required)):
                findings.append(violation(
                    "lint/kernel-oracle", f"kernels/{name}",
                    f"missing {required} — every kernel package ships "
                    f"a pure-jnp oracle and a jit'd wrapper"))
        test_file = KERNEL_TESTS.get(name)
        if test_file is None:
            findings.append(violation(
                "lint/kernel-oracle", f"kernels/{name}",
                "not listed in repro.kernels.KERNEL_TESTS — no "
                "interpret-mode test claims this kernel"))
            continue
        test_path = os.path.join(tdir, test_file)
        if not os.path.isfile(test_path):
            findings.append(violation(
                "lint/kernel-oracle", f"kernels/{name}",
                f"manifest names tests/kernels/{test_file}, which does "
                f"not exist"))
            continue
        with open(test_path) as fh:
            if name not in fh.read():
                findings.append(violation(
                    "lint/kernel-oracle", f"kernels/{name}",
                    f"tests/kernels/{test_file} never references "
                    f"'{name}' — the manifest pairing is dead"))
    for name in sorted(set(KERNEL_TESTS) - set(packages)):
        findings.append(violation(
            "lint/kernel-oracle", f"kernels/{name}",
            "listed in KERNEL_TESTS but no such package (stale manifest "
            "entry)"))
    if not findings:
        findings.append(info(
            "lint/kernel-oracle", "kernels",
            f"{len(packages)} packages, each with ref.py + ops.py + a "
            f"live interpret-mode test"))
    return findings


def lint_repo(root: Optional[str] = None) -> List[Finding]:
    """All five rules over the working tree."""
    root = root or _repo_root()
    rdir = os.path.join(root, "src", "repro", "runtime")
    findings: List[Finding] = []
    for module_name in ("pool.py", "paging.py", "draft.py"):
        with open(os.path.join(rdir, module_name)) as fh:
            findings.extend(lint_transition_source(fh.read(), module_name))
    with open(os.path.join(rdir, "serve.py")) as fh:
        serve_src = fh.read()
    findings.extend(lint_tick_builder_source(serve_src, "serve.py"))
    findings.extend(lint_fault_hooks_source(serve_src, "serve.py"))
    findings.extend(lint_tier_reads_source(serve_src, "serve.py"))
    with open(os.path.join(rdir, "supervisor.py")) as fh:
        findings.extend(lint_fault_hooks_source(fh.read(), "supervisor.py"))
    findings.extend(lint_kernel_manifest(root))
    if not any(f.severity == "violation" for f in findings):
        findings.append(info("lint", "repo", "all lint rules clean"))
    return findings


def main(argv=None) -> int:
    import argparse
    parser = argparse.ArgumentParser(
        description="repo AST lint (host-sync / kernel-oracle / "
                    "tracer-branch / fault-hook / tier-host-side rules)")
    parser.add_argument("--root", default=None,
                        help="repo root (default: derived from __file__)")
    args = parser.parse_args(argv)
    findings = lint_repo(args.root)
    bad = 0
    for f in findings:
        if f.severity == "violation":
            bad += 1
            print(f"[violation] {f.analysis}: {f.subject}: {f.message}")
        else:
            print(f"[{f.severity}] {f.analysis}: {f.subject}: "
                  f"{f.message}")
    print(f"lint: {bad} violation(s)")
    return 1 if bad else 0


if __name__ == "__main__":
    raise SystemExit(main())
