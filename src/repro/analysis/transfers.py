"""Host-transfer audit: nothing inside a tick talks to the host.

Static side: walk every tick family's jaxpr (recursing through
``pjit`` / ``while`` / ``cond`` / ``scan`` sub-jaxprs) and fail on any
callback or host-transfer primitive — a ``jax.debug.print`` or
``pure_callback`` smuggled into the serving tick reintroduces the
per-token host round-trip PR 1 removed.

Runtime side: drive a real (tiny) engine under
``jax.transfer_guard_device_to_host("disallow")`` — the engine's
``debug_transfers=True`` mode.  "disallow" blocks *implicit* transfers
only, so the budgeted per-tick ``jax.device_get`` sync and the pool
ledger's explicit pulls pass, while any stray ``int()`` / ``bool()`` /
``np.asarray`` on a device array raises.  One step budget, proven, not
promised: the harness also reports host syncs per tick from
``sync_stats``.
"""
from __future__ import annotations

from typing import List

import jax
import numpy as np

from repro.analysis.families import TickSpec
from repro.analysis.report import Finding, info, violation

# primitives that move data to (or run code on) the host from inside a
# compiled program; `infeed`/`outfeed` for completeness on TPU paths
FORBIDDEN_PRIMITIVES = frozenset({
    "pure_callback", "io_callback", "debug_callback", "callback",
    "infeed", "outfeed",
})


def _subjaxprs(params: dict):
    """Yield every Jaxpr / ClosedJaxpr nested in an eqn's params."""
    from jax.core import Jaxpr
    from jax.extend.core import ClosedJaxpr
    for val in params.values():
        vals = val if isinstance(val, (tuple, list)) else (val,)
        for v in vals:
            if isinstance(v, (Jaxpr, ClosedJaxpr)):
                yield v


def iter_primitives(jaxpr):
    """Every (primitive_name, eqn) in a jaxpr, sub-jaxprs included."""
    inner = getattr(jaxpr, "jaxpr", jaxpr)   # ClosedJaxpr -> Jaxpr
    for eqn in inner.eqns:
        yield eqn.primitive.name, eqn
        for sub in _subjaxprs(eqn.params):
            yield from iter_primitives(sub)


def audit_transfers(spec: TickSpec) -> List[Finding]:
    findings: List[Finding] = []
    closed = jax.make_jaxpr(spec.step_fn)(*spec.abstract_args)
    hits = {}
    for name, _ in iter_primitives(closed):
        if name in FORBIDDEN_PRIMITIVES:
            hits[name] = hits.get(name, 0) + 1
    for name, count in sorted(hits.items()):
        findings.append(violation(
            "transfers", spec.name,
            f"{count} `{name}` primitive(s) inside the tick jaxpr — "
            f"a host round-trip compiled into the serving hot path"))
    if not hits:
        findings.append(info(
            "transfers", spec.name,
            "no callback/host-transfer primitives in the tick jaxpr"))
    return findings


class TransferSpy:
    """Runtime enforcement of the one-budgeted-sync discipline that
    also has teeth on the CPU backend.

    ``jax.transfer_guard_device_to_host("disallow")`` (which
    ``ServingEngine(debug_transfers=True)`` arms around every tick) is
    the real guard on accelerators — but on the CPU backend host and
    device share memory, nothing "transfers", and the guard is inert.
    So the harness patches the concrete array type's conversion dunders
    for the duration of a drive loop: an ``int()`` / ``bool()`` /
    ``float()`` / ``__index__`` on a device array is an *implicit*
    device->host materialization and is recorded as a violation with
    the offending frame, unless it happens inside an explicit
    ``jax.device_get`` (the planned, budgeted syncs — ``jax.device_get``
    is wrapped to mark its extent).  This is exactly the transfer-guard
    semantics, reimplemented where XLA cannot see the copy.
    """

    _DUNDERS = ("__int__", "__bool__", "__float__", "__index__",
                "__array__")

    def __init__(self):
        self.violations: List[str] = []
        self._explicit = 0
        self._saved = {}
        self._saved_get = None

    def _frame(self) -> str:
        import traceback
        for fr in reversed(traceback.extract_stack()):
            fn = fr.filename.replace("\\", "/")
            if "/repro/" in fn and "/analysis/" not in fn:
                short = fn.split("/repro/", 1)[1]
                return f"repro/{short}:{fr.lineno} in {fr.name}"
        return "<outside repo frames>"

    def __enter__(self):
        import jax.numpy as jnp
        cls = type(jnp.zeros(()))
        self._cls = cls
        spy = self

        def wrap(name, orig):
            def guard(self_arr, *a, **kw):
                if spy._explicit == 0:
                    spy.violations.append(
                        f"implicit {name} on a device array at "
                        f"{spy._frame()}")
                return orig(self_arr, *a, **kw)
            return guard

        for name in self._DUNDERS:
            orig = cls.__dict__[name]
            self._saved[name] = orig
            setattr(cls, name, wrap(name, orig))

        self._saved_get = jax.device_get

        def explicit_get(tree):
            spy._explicit += 1
            try:
                return spy._saved_get(tree)
            finally:
                spy._explicit -= 1
        jax.device_get = explicit_get
        return self

    def __exit__(self, *exc):
        for name, orig in self._saved.items():
            setattr(self._cls, name, orig)
        jax.device_get = self._saved_get
        return False


def run_transfer_harness() -> List[Finding]:
    """Serve a real request stream with every implicit device->host
    transfer forbidden, on both layouts (the paged cell composes
    chunked prefill + speculation + over-commit, so the guard covers
    admission, fragment scheduling, eviction and resume).  The engine
    runs with ``debug_transfers=True`` (the accelerator-side guard) and
    the whole drive loop runs under :class:`TransferSpy` (the CPU-side
    equivalent)."""
    import jax.numpy as jnp
    from repro.analysis.families import (BLOCK_SIZE, FRAGMENT, MAX_SEQ,
                                         N_BLOCKS, N_SLOTS, SPEC_K,
                                         audit_config)
    from repro.models import model
    from repro.runtime.serve import Request, ServingEngine

    cfg, _ = audit_config()
    params = model.init(jax.random.PRNGKey(0), cfg, jnp.float32)

    cells = {
        "contiguous/decode": dict(),
        "paged/chunked+spec+overcommit": dict(
            paged=True, block_size=BLOCK_SIZE, n_blocks=N_BLOCKS,
            chunked_prefill=True, prefill_chunk_tokens=FRAGMENT,
            speculative=True, spec_k=SPEC_K, overcommit=True),
    }
    findings: List[Finding] = []
    for cell, kw in cells.items():
        rng = np.random.default_rng(7)
        reqs = [Request(i, rng.integers(2, 100,
                                        size=int(rng.integers(4, 12)))
                        .astype(np.int32),
                        max_new=int(rng.integers(4, 10)))
                for i in range(5)]
        eng = ServingEngine(params, cfg, n_slots=N_SLOTS, max_seq=MAX_SEQ,
                            chunk=4, debug_transfers=True, **kw)
        steps = 0
        spy = TransferSpy()
        try:
            with spy:
                pending = list(reqs)
                while pending or eng.active or eng._parked \
                        or eng._finished_instant:
                    n = eng.admit_many(pending)
                    del pending[:n]
                    eng.step()
                    steps += 1
                    assert steps < 500, \
                        "harness drive loop did not converge"
        except Exception as exc:                 # noqa: BLE001
            findings.append(violation(
                "transfers", f"harness/{cell}",
                f"engine step raised under transfer_guard_device_to_host"
                f"('disallow') after {steps} steps: "
                f"{type(exc).__name__}: {exc}"))
            continue
        if spy.violations:
            uniq = sorted(set(spy.violations))
            findings.append(violation(
                "transfers", f"harness/{cell}",
                f"{len(spy.violations)} implicit device->host "
                f"materialization(s) over {steps} steps: "
                + "; ".join(uniq[:5])
                + ("; ..." if len(uniq) > 5 else "")))
            continue
        stats = eng.sync_stats()
        findings.append(info(
            "transfers", f"harness/{cell}",
            f"{steps} guarded+spied steps, zero implicit device->host "
            f"transfers; {stats['host_syncs']} budgeted syncs over "
            f"{stats['device_ticks']} device ticks"))
    return findings
