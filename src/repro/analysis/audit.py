"""The tick auditor CLI: ``python -m repro.analysis.audit [--strict]``.

Lowers every serve tick cell the repo can build (five families x two
cache layouts, x mesh when >= 2 devices are visible) and runs the four
jaxpr/executable analyses on each — donation coverage, host-transfer
freedom, bounded retrace keys, constant hygiene — plus the AST lint
rules and a live transfer-guard harness.  Writes ``AUDIT.json`` and
exits nonzero on any violation (``--strict`` also fails warnings).

This is the EMPA stance applied to our own runtime: the supervisor
trusts *static* meta-information, so the properties the serving engine
relies on are proven by a tool before execution, not carried in
reviewers' heads.
"""
from __future__ import annotations

import argparse
import sys
from typing import Optional

import jax

from repro.analysis import constants as constants_lib
from repro.analysis import donation as donation_lib
from repro.analysis import lint as lint_lib
from repro.analysis import manifest
from repro.analysis import retrace as retrace_lib
from repro.analysis import transfers as transfers_lib
from repro.analysis.families import (BLOCK_SIZE, MAX_SEQ, N_SLOTS,
                                     audit_config, build_tick_specs,
                                     lower_spec)
from repro.analysis.report import Report, info, summarize

# satellite record: what the first audit run over the pre-audit tree
# surfaced, and what changed.  Kept in the report so the before/after
# does not live only in git archaeology.
BEFORE_AFTER = (
    "before: CorePool (core/supervisor.py) performed implicit "
    "device->host syncs — int()/bool() on device arrays in "
    "rent/release/set_phase/available — 16 implicit materializations "
    "over a 2-step contiguous stream and 23 over the 5-step paged "
    "overcommit stream, several per request retirement *inside* the "
    "serving step (caught by the harness's TransferSpy; XLA's own "
    "transfer guard is inert on the shared-memory CPU backend). "
    "after: the ledger is host-resident (one explicit jax.device_get "
    "per transition), queries are free host reads, and both harness "
    "cells drive their full streams with zero implicit transfers."
)


def register_admit_sites() -> None:
    """Admission jit sites register at engine construction; the audit
    builds them directly so the manifest is complete without one."""
    from repro.models.model import PagedLayout
    from repro.runtime import serve as serve_lib
    cfg, _ = audit_config()
    serve_lib.build_admit_step(cfg, MAX_SEQ)
    serve_lib.build_admit_step_paged(
        cfg, MAX_SEQ, PagedLayout(block_size=BLOCK_SIZE,
                                  n_blocks=N_SLOTS * MAX_SEQ // BLOCK_SIZE))


def collect_key_spaces() -> dict:
    """Reachable static-key spaces per jit site, both layouts."""
    from repro.runtime import serve as serve_lib
    spaces = {}
    for layout_name, bs in (("contiguous", None), ("paged", BLOCK_SIZE)):
        sp = serve_lib.retrace_key_spaces(
            max_seq=MAX_SEQ, n_slots=N_SLOTS, block_size=bs)
        for name, space in sp.items():
            if name == "admit_step":
                spaces[f"admit_step/{layout_name}"] = space
            elif name.endswith("/" + layout_name):
                spaces[name] = space
    return spaces


def run_audit(*, with_mesh: Optional[bool] = None, harness: bool = True,
              const_threshold: int = constants_lib.DEFAULT_THRESHOLD_BYTES
              ) -> Report:
    report = Report()
    cfg, shape = audit_config()
    report.meta = {
        "jax": jax.__version__,
        "backend": jax.default_backend(),
        "device_count": jax.device_count(),
        "config": {"arch": cfg.name, "n_slots": N_SLOTS,
                   "max_seq": MAX_SEQ, "block_size": BLOCK_SIZE},
        "before_after": BEFORE_AFTER,
    }

    specs = build_tick_specs(with_mesh=with_mesh)
    register_admit_sites()
    report.families = [s.to_json() for s in specs]
    report.sites = [site.to_json()
                    for _, site in sorted(manifest.sites().items())]

    for spec in specs:
        lowered = lower_spec(spec)
        report.extend(donation_lib.audit_donation(spec, lowered))
        report.extend(transfers_lib.audit_transfers(spec))
        report.extend(constants_lib.audit_constants(
            spec, threshold=const_threshold))

    report.extend(retrace_lib.audit_retrace(
        collect_key_spaces(), max_seq=MAX_SEQ, n_slots=N_SLOTS))
    report.extend(lint_lib.lint_repo())

    if harness:
        report.extend(transfers_lib.run_transfer_harness())
    else:
        report.extend([info("transfers", "harness",
                            "skipped (--no-harness)")])
    return report


def main(argv=None) -> int:
    parser = argparse.ArgumentParser(
        description="static audit over every lowered serve tick")
    parser.add_argument("--strict", action="store_true",
                        help="fail on warnings too")
    parser.add_argument("--out", default="AUDIT.json",
                        help="report path (default AUDIT.json)")
    parser.add_argument("--no-harness", action="store_true",
                        help="skip the live transfer-guard engine run")
    parser.add_argument("--mesh", choices=("auto", "on", "off"),
                        default="auto",
                        help="mesh cells: auto = when >= 2 devices")
    parser.add_argument("--const-threshold", type=int,
                        default=constants_lib.DEFAULT_THRESHOLD_BYTES,
                        help="constant-bloat threshold in bytes")
    args = parser.parse_args(argv)

    with_mesh = {"auto": None, "on": True, "off": False}[args.mesh]
    report = run_audit(with_mesh=with_mesh, harness=not args.no_harness,
                       const_threshold=args.const_threshold)
    report.write(args.out)
    print(summarize(report, strict=args.strict))
    print(f"report written to {args.out}")
    return 0 if report.ok(strict=args.strict) else 1


if __name__ == "__main__":
    sys.exit(main())
