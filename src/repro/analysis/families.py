"""Enumerate every serve tick cell the repo can build, as lowerable specs.

The audit matrix is {decode, chunked-prefill, solo-prefill, speculative,
over-commit resume} x {contiguous, paged} x {single-device, mesh}: the
five families come from ``ClusterSupervisor.plan_serve_families`` (one
entry point, explicit shardings, donated caches), the layouts from the
``paged`` kwarg, and the mesh axis from re-planning on a ``(1, 2)``
serve grid when the process has >= 2 devices (CI's multidevice job
forces 8 host devices, so the mesh cells run there).

Each cell is a :class:`TickSpec` — exactly the fields the four analyses
need, decoupled from the supervisor's ``Plan`` so the known-bad test
fixtures can hand-build specs without a model."""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class TickSpec:
    """One auditable jit cell: a step function plus its compile contract."""

    name: str                     # e.g. "speculative/paged/mesh2"
    family: str                   # plan family name
    layout: str                   # "contiguous" | "paged"
    mesh_devices: int             # 1 for the single-device cells
    step_fn: Any
    abstract_args: Tuple
    donate_argnums: Tuple[int, ...]
    in_shardings: Optional[Tuple] = None
    out_shardings: Optional[Any] = None

    def to_json(self) -> dict:
        return {"name": self.name, "family": self.family,
                "layout": self.layout, "mesh_devices": self.mesh_devices,
                "donate_argnums": list(self.donate_argnums)}


# the audit's tiny-but-real engine shape: one layer of the granite
# arch, the conformance matrix's serve geometry
N_SLOTS = 4
MAX_SEQ = 48
BLOCK_SIZE = 8
N_BLOCKS = 24
FRAGMENT = 8
SPEC_K = 3


def audit_config():
    """The reduced arch + serve shape every audit cell lowers with."""
    from repro.configs import ShapeConfig, get_arch, reduced
    cfg = reduced(get_arch("granite-3-2b"), n_layers=1, d_model=64,
                  vocab=128)
    shape = ShapeConfig("audit_tiny", MAX_SEQ, N_SLOTS, "serve")
    return cfg, shape


def _paged_layout():
    from repro.models.model import PagedLayout
    return PagedLayout(block_size=BLOCK_SIZE, n_blocks=N_BLOCKS)


def build_tick_specs(*, with_mesh: Optional[bool] = None) -> list:
    """The full audit matrix.  ``with_mesh=None`` auto-detects: mesh
    cells are added when the process has >= 2 devices."""
    from jax.sharding import Mesh
    from repro.runtime.sharding import serve_mesh
    from repro.runtime.supervisor import ClusterSupervisor

    cfg, shape = audit_config()
    base_mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1),
                     ("data", "model"))
    sup = ClusterSupervisor(base_mesh, cfg, shape, dtype=jnp.float32)
    if with_mesh is None:
        with_mesh = jax.device_count() >= 2

    meshes = [(1, None)]
    if with_mesh:
        meshes.append((2, serve_mesh(2)))

    specs = []
    for n_dev, mesh in meshes:
        for layout_name, layout in (("contiguous", None),
                                    ("paged", _paged_layout())):
            plans = sup.plan_serve_families(
                paged=layout, fragment=FRAGMENT, spec_k=SPEC_K, mesh=mesh)
            for family, plan in plans.items():
                suffix = f"/mesh{n_dev}" if n_dev > 1 else ""
                specs.append(TickSpec(
                    name=f"{family}/{layout_name}{suffix}",
                    family=family, layout=layout_name, mesh_devices=n_dev,
                    step_fn=plan.step_fn,
                    abstract_args=tuple(plan.abstract_args),
                    donate_argnums=tuple(plan.donate_argnums),
                    in_shardings=tuple(plan.in_shardings),
                    out_shardings=plan.out_shardings))
    return specs


def lower_spec(spec: TickSpec):
    """Lower a cell exactly the way the fleet does (explicit shardings
    and donation); returns the ``Lowered`` object the analyses walk."""
    kw = {}
    if spec.in_shardings is not None:
        kw["in_shardings"] = spec.in_shardings
    if spec.out_shardings is not None:
        kw["out_shardings"] = spec.out_shardings
    return jax.jit(spec.step_fn, donate_argnums=spec.donate_argnums,
                   **kw).lower(*spec.abstract_args)
