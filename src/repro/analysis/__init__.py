"""Static analysis over the serving runtime's compiled surface.

EMPA's contract is that parallelization meta-information is *static*:
the compiler proves properties ahead of time and the supervisor trusts
them at run time (PAPER.md; the programming companion makes the
ahead-of-time production of the meta-info explicit).  Seven PRs of this
repo accumulated exactly such hand-maintained static properties —
donation lists on every ``jax.jit`` tick, the one-sync-per-tick
discipline, bounded pow2 compile buckets, a ``ref.py`` oracle per
Pallas kernel — and PR 6 showed how silently one can rot.  This package
is the tool that re-proves them on every push:

* :mod:`repro.analysis.manifest` — the jit-site registry every tick
  builder reports into (name, donated state args, static keys);
* :mod:`repro.analysis.families` — enumerates every tick family the
  repo can build (decode / chunked / solo / speculative / over-commit
  resume, x contiguous/paged, x single-device/mesh) as lowerable specs;
* :mod:`repro.analysis.donation` — every persistent-state input is
  donated and actually aliased in the lowered module;
* :mod:`repro.analysis.transfers` — no callback / host-transfer
  primitive inside any tick jaxpr, plus a ``jax.transfer_guard``
  harness over a live engine step;
* :mod:`repro.analysis.retrace` — the static-argument key space per jit
  site is finite and within its declared budget;
* :mod:`repro.analysis.constants` — no large constants baked into a
  tick jaxpr;
* :mod:`repro.analysis.lint` — AST-level repo rules (no host syncs in
  pure transition modules, oracle/test pairing per kernel package, no
  Python branches on traced tick parameters);
* :mod:`repro.analysis.audit` — the CLI gluing it together
  (``python -m repro.analysis.audit --strict``), writing ``AUDIT.json``
  and exiting nonzero on any violation.

Import discipline: ``manifest`` must stay dependency-free — the runtime
imports it at module load, so anything heavier would be a cycle.
"""
