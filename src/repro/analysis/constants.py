"""Constant-bloat audit: no large arrays baked into a tick jaxpr.

A numpy array closed over at trace time becomes a jaxpr constant:
re-materialized per compile, resident per executable, and invisible in
any profile of the arguments — the classic silent memory and
compile-time regression.  Tick state must arrive through the
signature (where the donation audit sees it), so the audit walks every
tick family's consts (sub-jaxprs included) and flags anything over the
threshold.  Small iota/mask scalars are fine and expected.
"""
from __future__ import annotations

from typing import List

import jax
import numpy as np

from repro.analysis.families import TickSpec
from repro.analysis.report import Finding, info, violation

DEFAULT_THRESHOLD_BYTES = 1 << 16     # 64 KiB


def _subjaxprs(params: dict):
    from jax.core import Jaxpr
    from jax.extend.core import ClosedJaxpr
    for val in params.values():
        vals = val if isinstance(val, (tuple, list)) else (val,)
        for v in vals:
            if isinstance(v, (Jaxpr, ClosedJaxpr)):
                yield v


def iter_consts(jaxpr):
    """Every constant bound by a jaxpr, recursing into sub-jaxprs."""
    for const in getattr(jaxpr, "consts", ()) or ():
        yield const
    inner = getattr(jaxpr, "jaxpr", jaxpr)
    for eqn in inner.eqns:
        for sub in _subjaxprs(eqn.params):
            yield from iter_consts(sub)


def _nbytes(const) -> int:
    arr = np.asarray(const)
    return int(arr.size) * arr.dtype.itemsize


def audit_constants(spec: TickSpec, *,
                    threshold: int = DEFAULT_THRESHOLD_BYTES
                    ) -> List[Finding]:
    closed = jax.make_jaxpr(spec.step_fn)(*spec.abstract_args)
    findings: List[Finding] = []
    total = 0
    worst = 0
    for const in iter_consts(closed):
        size = _nbytes(const)
        total += size
        worst = max(worst, size)
        if size > threshold:
            arr = np.asarray(const)
            findings.append(violation(
                "constants", spec.name,
                f"{size}-byte constant ({arr.dtype}{list(arr.shape)}) "
                f"baked into the tick jaxpr (threshold {threshold}) — "
                f"state must arrive through the signature, not a "
                f"trace-time closure"))
    if not any(f.severity == "violation" for f in findings):
        findings.append(info(
            "constants", spec.name,
            f"{total} const bytes total, largest {worst} "
            f"(threshold {threshold})"))
    return findings
