"""granite-3-2b: 40L dense GQA.  [hf:ibm-granite/granite-3.0-2b-base]

vocab=49155 is odd (3×16385): the vocab dimension falls back to
replication; d_model keeps the FSDP shard.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="granite-3-2b", family="dense",
    n_layers=40, d_model=2048, n_heads=32, n_kv_heads=8,
    d_ff=8192, vocab=49155, head_dim=64,
    rope_theta=10_000.0,
)
