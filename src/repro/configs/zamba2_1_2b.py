"""zamba2-1.2b: 38 Mamba2 blocks + ONE shared attention+MLP block applied
every 6 layers.  [arXiv:2411.15242; hf]

The shared block is EMPA's rented core: one weight set, many QTs.  Shared
block simplification vs. the HF checkpoint: no per-application LoRA
deltas (noted in DESIGN.md §Arch-applicability).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="zamba2-1.2b", family="hybrid",
    n_layers=38, d_model=2048, n_heads=32, n_kv_heads=32,
    d_ff=8192, vocab=32000, head_dim=64,
    ssm_state=64, ssm_headdim=64, ssm_expand=2, ssm_conv=4, ssm_ngroups=1,
    shared_attn_every=6,
    subquadratic=True,
)
