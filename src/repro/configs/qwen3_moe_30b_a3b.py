"""qwen3-moe-30b-a3b: 48L MoE, 128 experts top-8.  [hf:Qwen/Qwen3-30B-A3B]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen3-moe-30b-a3b", family="moe",
    n_layers=48, d_model=2048, n_heads=32, n_kv_heads=4,
    d_ff=768, vocab=151936, head_dim=128,
    n_experts=128, top_k=8,
    rope_theta=1_000_000.0,
)
