"""starcoder2-3b: 30L dense GQA (24 heads kv=2), RoPE. [arXiv:2402.19173]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="starcoder2-3b", family="dense",
    n_layers=30, d_model=3072, n_heads=24, n_kv_heads=2,
    d_ff=12288, vocab=49152, head_dim=128,
    rope_theta=999_999.0,
    act="gelu",
)
