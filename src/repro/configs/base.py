"""Architecture + shape configuration system.

Every assigned architecture is an :class:`ArchConfig`; every assigned input
shape is a :class:`ShapeConfig`.  ``registry()`` exposes them to the
launcher (``--arch <id> --shape <id>``).
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Optional

ARCH_IDS = [
    "moonshot-v1-16b-a3b",
    "qwen3-moe-30b-a3b",
    "whisper-small",
    "granite-8b",
    "starcoder2-7b",
    "starcoder2-3b",
    "granite-3-2b",
    "pixtral-12b",
    "zamba2-1.2b",
    "mamba2-780m",
    # the paper's own workload (EMPA Y86 sumup) is a simulator config, not
    # an LM; see configs/empa_y86.py
]


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                    # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None
    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    n_shared_experts: int = 0      # DeepSeek/Moonlight-style always-on experts
    # --- SSM (Mamba2 / SSD) ---
    ssm_state: int = 0
    ssm_headdim: int = 64
    ssm_expand: int = 2
    ssm_conv: int = 4
    ssm_ngroups: int = 1
    # --- hybrid (zamba2): one shared attention+MLP block applied
    #     every `shared_attn_every` SSM blocks ---
    shared_attn_every: int = 0
    # --- enc-dec (whisper) ---
    enc_layers: int = 0
    dec_layers: int = 0
    # --- VLM / audio frontend stubs ---
    frontend: Optional[str] = None   # "vision" | "audio" | None
    frontend_dim: int = 1024         # precomputed patch/frame embedding width
    n_frontend_tokens: int = 256     # prepended stub tokens per sequence
    # --- common ---
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-5
    act: str = "silu"
    pos_embed: str = "rope"          # rope | learned
    max_position: int = 1 << 20
    tie_embeddings: bool = False
    dtype: str = "bfloat16"
    # sub-quadratic attention available? (drives long_500k applicability)
    subquadratic: bool = False

    def __post_init__(self):
        if self.head_dim is None:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)
        if self.n_heads and self.n_kv_heads:
            assert self.n_heads % self.n_kv_heads == 0, \
                f"{self.name}: GQA requires n_heads % n_kv_heads == 0"

    # ---- derived sizes -----------------------------------------------
    @property
    def vocab_padded(self) -> int:
        """Vocab padded to a TP-friendly multiple (Megatron-style): odd
        vocabs (51865/49155/50280) otherwise force replicated unembed
        tables, whose FSDP-sharded d-contraction all-reduces partial
        logits per loss chunk (see EXPERIMENTS.md §Perf)."""
        mult = 32
        return (self.vocab + mult - 1) // mult * mult

    @property
    def d_inner(self) -> int:
        """Mamba2 inner width."""
        return self.ssm_expand * self.d_model

    @property
    def ssm_nheads(self) -> int:
        return self.d_inner // self.ssm_headdim

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def has_decoder(self) -> bool:
        return True  # all assigned archs generate tokens (whisper decodes text)

    def param_count(self) -> int:
        """Exact parameter count from the definition table."""
        from repro.models import model as _m
        return sum(int(_prod(d.shape)) for d in _m.param_defs(self))

    def active_param_count(self) -> int:
        """Active-per-token parameters (MoE: only routed-in experts)."""
        from repro.models import model as _m
        total = 0
        for d in _m.param_defs(self):
            n = int(_prod(d.shape))
            if "experts" in (d.axes or ()) and self.n_experts:
                n = n * (self.top_k + self.n_shared_experts) // self.n_experts
            total += n
        return total


def _prod(xs):
    out = 1
    for x in xs:
        out *= x
    return out


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str     # train | prefill | decode


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


def shape_applicable(arch: "ArchConfig", shape: ShapeConfig) -> tuple[bool, str]:
    """long_500k runs only for sub-quadratic archs (assignment directive)."""
    if shape.name == "long_500k" and not arch.subquadratic:
        return False, ("skip: pure full-attention arch — 512k dense decode "
                       "excluded per assignment (see DESIGN.md §4)")
    return True, ""


def get_arch(arch_id: str) -> ArchConfig:
    mod = importlib.import_module(
        "repro.configs." + arch_id.replace("-", "_").replace(".", "_"))
    return mod.CONFIG


def registry() -> dict[str, ArchConfig]:
    return {a: get_arch(a) for a in ARCH_IDS}


def reduced(cfg: ArchConfig, **overrides) -> ArchConfig:
    """A tiny same-family config for CPU smoke tests."""
    small = dict(
        n_layers=min(cfg.n_layers, 2),
        d_model=128,
        n_heads=max(4, min(cfg.n_heads, 4)),
        n_kv_heads=min(cfg.n_kv_heads, 2) if cfg.n_kv_heads else 0,
        d_ff=256,
        vocab=512,
        head_dim=32,
        max_position=4096,
    )
    if cfg.n_experts:
        # capacity_factor == n_experts makes the reduced config dropless, so
        # decode-vs-forward consistency is exact (drop semantics are covered
        # by the dedicated MoE unit tests).
        small.update(n_experts=8, top_k=2, d_ff=64, capacity_factor=8.0)
    if cfg.ssm_state:
        small.update(ssm_state=16, ssm_headdim=32)
    if cfg.shared_attn_every:
        small.update(shared_attn_every=2, n_layers=4)
    if cfg.enc_layers:
        small.update(enc_layers=2, dec_layers=2)
    if cfg.frontend:
        small.update(frontend_dim=64, n_frontend_tokens=8)
    if cfg.n_kv_heads and cfg.n_heads % max(cfg.n_kv_heads, 1):
        small.update(n_kv_heads=2)
    small.update(overrides)
    return dataclasses.replace(cfg, **small)
