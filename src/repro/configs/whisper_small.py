"""whisper-small backbone: 12L enc + 12L dec, d=768.  [arXiv:2212.04356]

Conv audio frontend is a STUB per assignment: input_specs() provides
precomputed frame embeddings (B, S, frontend_dim).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="whisper-small", family="encdec",
    n_layers=24, d_model=768, n_heads=12, n_kv_heads=12,
    d_ff=3072, vocab=51865, head_dim=64,
    enc_layers=12, dec_layers=12,
    frontend="audio", frontend_dim=768,
    act="gelu", pos_embed="learned", max_position=65536,
)
