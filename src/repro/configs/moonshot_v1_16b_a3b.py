"""moonshot-v1-16b-a3b (Moonlight-16B-A3B): 48L MoE, 64 experts top-6.

[hf:moonshotai/Moonlight-16B-A3B; hf]  DeepSeek-style: 2 shared experts.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="moonshot-v1-16b-a3b", family="moe",
    n_layers=48, d_model=2048, n_heads=16, n_kv_heads=16,
    d_ff=1408, vocab=163840, head_dim=128,
    n_experts=64, top_k=6, n_shared_experts=2,
    rope_theta=50_000.0,
)
