from repro.configs.base import (  # noqa: F401
    ARCH_IDS, SHAPES, ArchConfig, ShapeConfig, get_arch, reduced, registry,
    shape_applicable)
