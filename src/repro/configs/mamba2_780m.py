"""mamba2-780m: 48L pure SSD (state-space duality), attention-free.
[arXiv:2405.21060]

d_ff=0 / attention-free: EMPA's attention-agnostic runtime applies
unchanged; the SSD chunk scan is the SUMUP-mode kernel (children=chunks,
parent=state carry).  O(1)-state decode makes long_500k runnable.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="mamba2-780m", family="ssm",
    n_layers=48, d_model=1536, n_heads=0, n_kv_heads=0,
    d_ff=0, vocab=50280, head_dim=1,
    ssm_state=128, ssm_headdim=64, ssm_expand=2, ssm_conv=4, ssm_ngroups=1,
    subquadratic=True,
    tie_embeddings=True,
)
