"""starcoder2-7b: 32L dense GQA (36 heads), RoPE.  [arXiv:2402.19173; hf]

36 heads / 4 KV heads are NOT divisible by the 16-wide model axis: the
sharding rules fall back to replicated attention weights (FSDP-only) while
the MLP keeps tensor parallelism on d_ff=18432 (divisible).  See
DESIGN.md §5.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="starcoder2-7b", family="dense",
    n_layers=32, d_model=4608, n_heads=36, n_kv_heads=4,
    d_ff=18432, vocab=49152, head_dim=128,
    rope_theta=1_000_000.0,
    act="gelu",
)
