"""pixtral-12b backbone: 40L decoder (mistral-nemo).  [hf:mistralai/Pixtral-12B-2409]

Pixtral-ViT frontend is a STUB per assignment: input_specs() provides
precomputed patch embeddings projected into the decoder.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="pixtral-12b", family="vlm",
    n_layers=40, d_model=5120, n_heads=32, n_kv_heads=8,
    d_ff=14336, vocab=131072, head_dim=128,
    frontend="vision", frontend_dim=1024, n_frontend_tokens=256,
    rope_theta=1_000_000_000.0,
)
