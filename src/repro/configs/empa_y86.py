"""The paper's own workload: EMPA Y86 `sumup` (Listing 1) on the clock-level
machine simulator — selectable alongside the LM architectures so the
benchmark harness treats the reproduction as a first-class config."""
import dataclasses


@dataclasses.dataclass(frozen=True)
class EmpaY86Config:
    name: str = "empa-y86"
    max_cores: int = 32
    modes: tuple = ("NO", "FOR", "SUMUP")
    vector_lengths: tuple = (1, 2, 4, 6)


CONFIG = EmpaY86Config()
