"""granite-8b (llama-arch, code): 36L dense GQA.  [arXiv:2405.04324; hf]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="granite-8b", family="dense",
    n_layers=36, d_model=4096, n_heads=32, n_kv_heads=8,
    d_ff=14336, vocab=49152, head_dim=128,
    rope_theta=10_000_000.0,
)
