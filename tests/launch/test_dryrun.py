"""Launch-layer tests: the dry-run really compiles at 512 devices.

Runs in a subprocess because the 512-device platform override must happen
before jax initializes (the main test process keeps 1 device).
"""
import json
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.dirname(__file__)))


def _run_cell(arch, shape, extra=()):
    out = os.path.join(REPO, "benchmarks", "artifacts",
                       f"test_{arch}_{shape}.json")
    if os.path.exists(out):
        os.unlink(out)
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
         "--shape", shape, "--out", out, *extra],
        capture_output=True, text=True, timeout=540, env=env, cwd=REPO)
    assert r.returncode == 0, r.stdout[-2000:] + r.stderr[-2000:]
    with open(out) as f:
        cells = json.load(f)
    os.unlink(out)
    return cells


@pytest.mark.slow
def test_dryrun_decode_cell_both_meshes():
    cells = _run_cell("mamba2-780m", "decode_32k")
    assert len(cells) == 2                       # single-pod + multi-pod
    for c in cells:
        assert c["status"] == "ok", c
        assert c["global_flops"] > 0
        assert c["memory"]["temp_size_in_bytes"] < 16e9   # fits v5e HBM
    assert {c["mesh"] for c in cells} == {"pod16x16", "pod2x16x16"}
    assert cells[0]["n_devices"] == 256
    assert cells[1]["n_devices"] == 512


@pytest.mark.slow
def test_dryrun_skips_long500k_for_full_attention():
    cells = _run_cell("granite-3-2b", "long_500k", ["--single-pod"])
    assert cells[0]["status"] == "skipped"
    assert "full-attention" in cells[0]["reason"]


def test_mesh_constructors_are_lazy():
    """Importing mesh.py must not touch jax device state."""
    import importlib
    import repro.launch.mesh as m
    importlib.reload(m)   # would explode if module-level jax.devices() ran
    assert callable(m.make_production_mesh)


def test_production_mesh_shapes():
    # shapes only (constructing 512-dev meshes needs the dryrun subprocess)
    import repro.launch.mesh as m
    import inspect
    src = inspect.getsource(m.make_production_mesh)
    assert "(2, 16, 16)" in src and "(16, 16)" in src
    assert '("pod", "data", "model")' in src
