"""Shared engine-vs-oracle harness for the serving test tree.

The token-exactness contract is the same across every serving feature
(paged KV, chunked prefill, speculative decode, preemptive over-commit):
run a request stream through a configured engine and compare it, token
for token, against a baseline.  The fixtures here hold the pieces that
used to be copy-pasted across test_serve.py, test_chunked_prefill.py
and test_spec_decode.py:

* ``serve_setup`` — the tiny session-scoped (cfg, params) every engine
  test decodes with;
* ``serve_harness`` — request generators (random / repetitive / mixed
  long+short), the copy-model transform (a real forward whose argmax
  copies its input token — the drafter-friendly regime), the drive loop
  (with optional forced preemptions), and the drained-pool assertions.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch, reduced
from repro.models import model
from repro.runtime import paging
from repro.runtime.serve import Request, ServingEngine


@pytest.fixture(scope="session")
def serve_setup():
    cfg = reduced(get_arch("granite-3-2b"), n_layers=1, d_model=64,
                  vocab=128)
    params = model.init(jax.random.PRNGKey(0), cfg, jnp.float32)
    return cfg, params


class ServeHarness:
    """Namespace of the shared engine-vs-oracle helpers (stateless)."""

    @staticmethod
    def copy_model(params, cfg):
        """Params whose forward copies its input token: every block's
        residual contribution is zeroed and the unembedding is tied, so
        argmax(logits(t)) == t.  Greedy decode becomes a constant
        stream — the perfectly repetitive regime where the n-gram
        drafter reaches full acceptance, through a real forward."""
        p = dict(params)
        p["layers"] = dict(p["layers"],
                           wo=jnp.zeros_like(p["layers"]["wo"]),
                           w_down=jnp.zeros_like(p["layers"]["w_down"]))
        if not cfg.tie_embeddings:
            p["unembed"] = p["embed"]["tok"]
        return p

    @staticmethod
    def random_requests(n=5, seed=5, min_new=4, max_new=12):
        rng = np.random.default_rng(seed)
        return [Request(i, rng.integers(2, 100,
                                        size=int(rng.integers(4, 12)))
                        .astype(np.int32),
                        max_new=int(rng.integers(min_new, max_new)))
                for i in range(n)]

    @staticmethod
    def repetitive_requests(n=5, seed=3):
        """Prompts ending in a constant run: the drafter's bread and
        butter once the model continues the repetition."""
        rng = np.random.default_rng(seed)
        out = []
        for i in range(n):
            head = rng.integers(2, 100,
                                size=int(rng.integers(3, 8))) \
                .astype(np.int32)
            tail = np.full(int(rng.integers(4, 9)),
                           int(rng.integers(2, 100)), np.int32)
            out.append(Request(i, np.concatenate([head, tail]),
                               max_new=int(rng.integers(8, 20))))
        return out

    @staticmethod
    def mixed_requests(n_short=4, long_len=30):
        """Short prompts plus one long one (the head-of-line blocker)."""
        rng = np.random.default_rng(5)
        reqs = [Request(i, rng.integers(1, 100,
                                        size=int(rng.integers(4, 12)))
                        .astype(np.int32),
                        max_new=int(rng.integers(4, 10)))
                for i in range(n_short)]
        reqs.append(Request(n_short,
                            rng.integers(1, 100, size=long_len)
                            .astype(np.int32), max_new=6))
        return reqs

    @staticmethod
    def pressure_requests(n=6, seed=5):
        """Medium prompts with real decode budgets: sized so a small
        block pool runs dry mid-flight under over-commit admission."""
        rng = np.random.default_rng(seed)
        return [Request(i, rng.integers(1, 100,
                                        size=int(rng.integers(6, 16)))
                        .astype(np.int32),
                        max_new=int(rng.integers(10, 18)))
                for i in range(n)]

    @staticmethod
    def drive(eng, requests, preempt_at=(), max_steps=2000):
        """Continuous-batching drive loop with optional supervisor
        preemptions forced at the given step numbers; returns
        {rid: tokens}."""
        pending = list(requests)
        done, steps = [], 0
        while pending or eng.active or eng._parked or eng._displaced \
                or eng._finished_instant:
            n = eng.admit_many(pending)
            del pending[:n]
            done += eng.step()
            steps += 1
            if steps in preempt_at:
                eng.preempt()
            assert steps < max_steps, "drive loop did not converge"
        return {r.rid: r.out for r in done}

    @classmethod
    def run(cls, params, cfg, requests, *, preempt_at=(), **engine_kw):
        """Build an engine, drive the stream, return (outputs, engine)."""
        eng = ServingEngine(params, cfg, **engine_kw)
        outputs = cls.drive(eng, requests, preempt_at=preempt_at)
        return outputs, eng

    @staticmethod
    def assert_drained(eng):
        """Every rent returned: slots free, chains released, refcounts /
        free mask / tables in agreement, replays token-exact."""
        assert eng.pool.used == 0
        assert not eng._parked and not eng._jobs
        assert not eng._displaced and not eng._frontier
        assert eng.preempt_replay_mismatches == 0
        assert eng.migrate_replay_mismatches == 0
        if eng.layout is not None:
            assert int(paging.blocks_in_use(eng.bstate)) == 0
            paging.check_invariants(eng.bstate, eng.cache["block_tables"])


@pytest.fixture(scope="session")
def serve_harness():
    return ServeHarness


@pytest.fixture
def assert_health_events():
    """The common health-event checker shared by the *training* fleet
    (runtime/elastic.ElasticManager) and the *serving* fleet
    (runtime/supervisor.FleetSupervisor): every emitted event must be
    an ``elastic.Event`` drawn from the single ``EVENT_KINDS``
    vocabulary — the two fault paths cannot drift apart.  Returns the
    kind sequence so tests can assert ordering."""
    from repro.runtime import elastic

    def check(events, expect_kinds=()):
        for ev in events:
            assert isinstance(ev, elastic.Event), ev
            assert ev.kind in elastic.EVENT_KINDS, ev
            assert isinstance(ev.host, int), ev
        kinds = [ev.kind for ev in events]
        for k in expect_kinds:
            assert k in kinds, (k, kinds)
        return kinds

    return check
