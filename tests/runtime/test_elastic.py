"""Elastic fleet manager: the fail/slow/swap/relower ladder.

The training fleet's fault path mirrors the serving fleet's quarantine
path (test_fleet.py) through one shared health-event vocabulary — both
suites validate their event logs with the common ``assert_health_events``
fixture, so the two managers cannot drift apart.
"""
from __future__ import annotations

import pytest

from repro.runtime import elastic
from repro.runtime.elastic import CHIPS_PER_HOST, ElasticManager, Event


def _mgr(n_hosts, spares=2, on_relower=None):
    m = ElasticManager(n_hosts, spares=spares, on_relower=on_relower)
    m.check_invariants()
    return m


def test_init_rents_active_fleet_and_preallocates_spares():
    m = _mgr(130, spares=2)
    assert len(m.active) == 128
    assert m.healthy_chips == 128 * CHIPS_PER_HOST == 512
    assert m.level == 0 and m.required_level() == 0
    assert m.events == []


def test_fail_with_spare_swaps_without_relower(assert_health_events):
    m = _mgr(130, spares=2)
    victim = m.active[0]
    ev = m.fail(victim)
    assert ev.kind == "swap"
    assert m.level == 0                      # mesh shape unchanged
    assert m.healthy_chips == 512            # spare restored capacity
    assert victim not in m.active
    kinds = assert_health_events(m.events, expect_kinds=("fail", "swap"))
    assert kinds == ["fail", "swap"]
    m.check_invariants()


def test_spares_exhausted_relowers_the_ladder(assert_health_events):
    levels = []
    m = _mgr(130, spares=2, on_relower=levels.append)
    for _ in range(2):                       # burn both spares
        m.fail(m.active[0])
    assert m.level == 0 and levels == []
    ev = m.fail(m.active[0])                 # 125 hosts = 500 chips
    assert ev.kind == "relower"
    assert m.level == 1 and levels == [1]
    assert_health_events(m.events,
                         expect_kinds=("fail", "swap", "relower"))
    m.check_invariants()


def test_straggler_is_benched_like_a_failure(assert_health_events):
    m = _mgr(130, spares=2)
    slow = m.active[3]
    m.straggler(slow)
    assert slow not in m.active
    assert m.healthy_chips == 512            # hot-swapped, no relower
    kinds = assert_health_events(m.events, expect_kinds=("slow",))
    assert kinds == ["fail", "swap", "slow"]
    m.check_invariants()


def test_recover_rejoins_as_spare(assert_health_events):
    m = _mgr(130, spares=2)
    victim = m.active[0]
    m.fail(victim)                           # burns spare 1
    m.fail(m.active[0])                      # burns spare 2
    m.recover(victim)                        # repaired host -> spare pool
    ev = m.fail(m.active[0])                 # next loss swaps it back in
    assert ev.kind == "swap"
    assert m.level == 0 and m.healthy_chips == 512
    assert_health_events(m.events, expect_kinds=("recover", "swap"))
    m.check_invariants()


def test_below_minimum_capacity_raises():
    m = _mgr(17, spares=1)                   # 16 active = 64 chips (L4)
    assert m.required_level() == len(elastic.LADDER) - 1
    m.fail(m.active[0])                      # spare keeps it at 64
    with pytest.raises(RuntimeError, match="below minimum"):
        m.fail(m.active[0])                  # 60 chips: off the ladder
    m.check_invariants()


def test_event_vocabulary_is_closed():
    with pytest.raises(ValueError, match="unknown health-event kind"):
        Event("meltdown", 0)
    # both fleets' kinds live in the one vocabulary
    assert {"fail", "swap", "relower"} < elastic.EVENT_KINDS
    assert {"quarantine", "migrate", "dead_letter",
            "readmit"} < elastic.EVENT_KINDS
