"""Runtime tests: sharding rules, checkpoint/restart, elastic pool, data."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")   # real lib or the conftest fallback
from hypothesis import given, settings, strategies as st  # noqa: E402
from jax.sharding import PartitionSpec as P

from repro.checkpoint.manager import CheckpointManager
from repro.configs import SHAPES, ShapeConfig, get_arch, reduced
from repro.data.pipeline import DataConfig, Prefetcher, synth_batch
from repro.launch.mesh import make_host_mesh
from repro.runtime.elastic import LADDER, ElasticManager
from repro.runtime.sharding import ShardingRules


# ---------------------------------------------------------------------------
# Sharding rules
# ---------------------------------------------------------------------------

class FakeMesh:
    def __init__(self, shape):
        self.shape = shape


def _rules(**mesh_shape):
    return ShardingRules(mesh=FakeMesh(mesh_shape))


def test_divisibility_fallback():
    r = _rules(data=16, model=16)
    # heads=36 not divisible by model=16 -> replicated; d divisible -> data
    spec = r.spec(("w_embed", "heads", None), (4608, 36, 128))
    assert spec == P("data", None, None)
    # heads=32 divisible -> model
    spec = r.spec(("w_embed", "heads", None), (4096, 32, 128))
    assert spec == P("data", "model", None)


def test_no_double_axis_use():
    r = _rules(data=16, model=16)
    # both dims want "model": only the first gets it
    spec = r.spec(("heads", "kv_heads"), (32, 16))
    assert spec == P("model", None)


def test_multi_axis_candidate():
    r = _rules(pod=2, data=16, model=16)
    spec = r.spec(("batch", None), (256, 4096))
    assert spec == P(("pod", "data"), None)
    # batch=8 doesn't divide 32 -> falls through to "data"? 8%16!=0 ->
    # "pod" (8%2==0)
    spec = r.spec(("batch", None), (8, 128))
    assert spec == P("pod", None)


def test_odd_vocab_replicates():
    r = _rules(data=16, model=16)
    spec = r.spec(("vocab", "w_embed"), (49155, 2048))
    assert spec == P(None, "data")
    assert "replicated" in r.report() or r.report()


def test_cache_head_dim_fallback():
    r = _rules(data=16, model=16)
    ax = ("layers", "cache_batch", None, "cache_kv_heads", "cache_head_dim")
    # whisper: kv=12 not divisible -> head_dim gets the model axis
    spec = r.spec(ax, (12, 128, 32768, 12, 64))
    assert spec == P(None, "data", None, None, "model")
    # zamba2: kv=32 divisible -> kv_heads wins, head_dim replicated
    spec = r.spec(ax, (6, 1, 524288, 32, 64))
    assert spec == P(None, None, None, "model", None)


@settings(max_examples=30, deadline=None)
@given(st.integers(1, 4096), st.integers(1, 4096))
def test_spec_always_divides(d1, d2):
    """Property: any assigned mesh axis divides its dimension."""
    r = _rules(data=16, model=16)
    spec = r.spec(("w_embed", "ffn"), (d1, d2))
    sizes = {"data": 16, "model": 16}
    for dim, entry in zip((d1, d2), spec):
        if entry is not None:
            assert dim % sizes[entry] == 0


# ---------------------------------------------------------------------------
# Checkpoint manager
# ---------------------------------------------------------------------------

def _tiny_state(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"params": {"w": jax.random.normal(k, (4, 4)),
                       "b": jnp.zeros((4,))},
            "opt": {"step": jnp.int32(3)}}


def test_checkpoint_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path), async_save=False, fingerprint="t")
    state = _tiny_state()
    mgr.save(7, state, block=True)
    like = jax.tree_util.tree_map(jnp.zeros_like, state)
    restored, step = mgr.restore(like)
    assert step == 7
    jax.tree_util.tree_map(
        lambda a, b: np.testing.assert_array_equal(np.array(a), np.array(b)),
        state, restored)


def test_checkpoint_gc_and_latest(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep_n=2, async_save=False)
    for s in (1, 2, 3, 4):
        mgr.save(s, _tiny_state(s), block=True)
    assert mgr.steps() == [3, 4]
    assert mgr.latest_step() == 4


def test_checkpoint_fingerprint_guard(tmp_path):
    mgr = CheckpointManager(str(tmp_path), async_save=False, fingerprint="a")
    mgr.save(1, _tiny_state(), block=True)
    mgr2 = CheckpointManager(str(tmp_path), async_save=False, fingerprint="b")
    with pytest.raises(ValueError):
        mgr2.restore(_tiny_state())


def test_checkpoint_crash_leaves_no_partial(tmp_path):
    """A .tmp dir (simulated crash) is never listed as a checkpoint."""
    mgr = CheckpointManager(str(tmp_path), async_save=False)
    mgr.save(5, _tiny_state(), block=True)
    os.makedirs(str(tmp_path / "step_00000009.tmp"))
    assert mgr.latest_step() == 5


def test_failure_injection_resume_identical(tmp_path):
    """Crash at step 6, restart, and the loss trajectory continues exactly
    as an uninterrupted run (checkpoint/restart fidelity)."""
    from repro.launch.train import train_loop
    cfg = reduced(get_arch("granite-3-2b"), n_layers=1, d_model=64, vocab=128)
    shape = ShapeConfig("t", 32, 4, "train")

    ref = train_loop(cfg, shape, steps=8, log_every=0)

    with pytest.raises(RuntimeError, match="injected failure"):
        train_loop(cfg, shape, steps=8, ckpt_dir=str(tmp_path / "c"),
                   ckpt_every=2, fail_at=6, log_every=0)
    resumed = train_loop(cfg, shape, steps=8, ckpt_dir=str(tmp_path / "c"),
                         ckpt_every=2, log_every=0)
    assert resumed.resumed_from == 6
    ref_tail = dict(ref.losses)
    for step, loss in resumed.losses:
        assert step >= 6
        np.testing.assert_allclose(loss, ref_tail[step], rtol=1e-5, atol=1e-6)


# ---------------------------------------------------------------------------
# Elastic manager
# ---------------------------------------------------------------------------

def test_spare_swap_no_relower():
    relowers = []
    em = ElasticManager(10, spares=2, on_relower=relowers.append)
    ev = em.fail(em.active[0])
    assert ev.kind == "swap" and not relowers   # mesh unchanged
    em.check_invariants()


def test_degrade_after_spares_exhausted():
    relowers = []
    # 130 hosts = 520 chips; 2 spares -> active 128 hosts = 512 chips
    em = ElasticManager(130, spares=2, on_relower=relowers.append)
    assert em.healthy_chips == 512
    em.fail(em.active[0])
    em.fail(em.active[0])         # spares consumed
    assert not relowers
    em.fail(em.active[0])         # 127 hosts = 508 chips < 512
    assert relowers == [1]        # degrade one ladder level
    em.check_invariants()


def test_ladder_monotone():
    chips = [c for c, _ in LADDER]
    assert chips == sorted(chips, reverse=True)


def test_recover_rejoins_pool():
    em = ElasticManager(6, spares=1)
    victim = em.active[0]
    em.fail(victim)
    em.recover(victim)
    em.check_invariants()


# ---------------------------------------------------------------------------
# Data pipeline
# ---------------------------------------------------------------------------

def test_data_deterministic_and_sharded():
    cfg = get_arch("granite-3-2b")
    shape = ShapeConfig("t", 64, 8, "train")
    b1 = synth_batch(cfg, shape, DataConfig(seed=1, host_id=0, n_hosts=2), 5)
    b2 = synth_batch(cfg, shape, DataConfig(seed=1, host_id=0, n_hosts=2), 5)
    b3 = synth_batch(cfg, shape, DataConfig(seed=1, host_id=1, n_hosts=2), 5)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])   # restartable
    assert b1["tokens"].shape == (4, 64)                        # host shard
    assert not np.array_equal(b1["tokens"], b3["tokens"])       # disjoint


def test_labels_are_shifted_tokens():
    cfg = get_arch("granite-3-2b")
    shape = ShapeConfig("t", 32, 2, "train")
    b = synth_batch(cfg, shape, DataConfig(), 0)
    np.testing.assert_array_equal(b["labels"][:, :-1], b["tokens"][:, 1:])
    assert np.all(b["labels"][:, -1] == -1)


def test_prefetcher_streams_in_order():
    cfg = get_arch("granite-3-2b")
    shape = ShapeConfig("t", 16, 2, "train")
    pf = Prefetcher(cfg, shape, DataConfig(seed=2), start_step=3)
    try:
        steps = [next(pf)[0] for _ in range(4)]
        assert steps == [3, 4, 5, 6]
    finally:
        pf.close()


def test_frontend_batches():
    for arch in ("pixtral-12b", "whisper-small"):
        cfg = reduced(get_arch(arch))
        shape = ShapeConfig("t", 32, 2, "train")
        b = synth_batch(cfg, shape, DataConfig(), 0)
        if cfg.frontend == "vision":
            assert b["vision_embeds"].shape == (2, cfg.n_frontend_tokens,
                                                cfg.frontend_dim)
            assert b["tokens"].shape == (2, 32 - cfg.n_frontend_tokens)
        if cfg.family == "encdec":
            assert b["enc_embeds"].shape == (2, 32, cfg.frontend_dim)
