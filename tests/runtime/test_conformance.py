"""Cross-config serving conformance matrix.

One contract, every configuration: the serving engine's token stream is
a pure function of (params, requests) — the cache layout ({contiguous,
paged}), the prefill strategy ({monolithic, chunked}), the decode mode
({greedy, speculative}) and the admission discipline ({reserved,
overcommit}) are implementation choices that must not change a single
token.  Each matrix cell runs the same request stream through its
engine **with supervisor preemptions forced mid-run** (and, for paged
over-commit cells, a pool small enough that natural evictions fire
too), then compares token-for-token against the uncontended oracle —
the plain contiguous/monolithic/greedy/reserved engine.

This is the acceptance gate for preemptive over-commit: a preempted
request resumes by replaying its history through chunked prefill, and
greedy determinism must make the recompute token-exact on every cell.
"""
import itertools

import numpy as np
import pytest

from repro.runtime import pool as pool_lib
from repro.runtime.serve import ServingEngine

N_SLOTS = 3
MAX_SEQ = 48
CHUNK = 2            # short sync chunks: many steps, real mid-run evictions
SMALL_POOL = 7       # over-commit cells: chains must contend for blocks
BIG_POOL = 20        # reserved cells: the §5.1 reservation always grantable

MATRIX = list(itertools.product(("contiguous", "paged"),
                                ("monolithic", "chunked"),
                                ("greedy", "speculative"),
                                ("reserved", "overcommit")))


def _engine_kw(layout, chunking, decode, admission):
    kw = dict(n_slots=N_SLOTS, max_seq=MAX_SEQ, chunk=CHUNK)
    if layout == "paged":
        kw.update(paged=True, block_size=8,
                  n_blocks=SMALL_POOL if admission == "overcommit"
                  else BIG_POOL)
    if chunking == "chunked":
        kw.update(chunked_prefill=True, prefill_chunk_tokens=4)
    if decode == "speculative":
        kw.update(speculative=True, spec_k=3)
    if admission == "overcommit":
        kw.update(overcommit=True)
    return kw


@pytest.fixture(scope="module")
def oracle(serve_setup, serve_harness):
    """The uncontended baseline: plain engine, no preemption, big pool."""
    cfg, params = serve_setup
    outputs, eng = serve_harness.run(
        params, cfg, serve_harness.pressure_requests(),
        n_slots=N_SLOTS, max_seq=MAX_SEQ, chunk=CHUNK)
    serve_harness.assert_drained(eng)
    return outputs


@pytest.mark.parametrize(
    "layout,chunking,decode,admission", MATRIX,
    ids=["-".join(cell) for cell in MATRIX])
def test_token_exact_across_configs(serve_setup, serve_harness, oracle,
                                    layout, chunking, decode, admission):
    cfg, params = serve_setup
    kw = _engine_kw(layout, chunking, decode, admission)
    outputs, eng = serve_harness.run(
        params, cfg, serve_harness.pressure_requests(),
        preempt_at=(2, 5), **kw)
    assert outputs == oracle, (layout, chunking, decode, admission)
    # the forced evictions really ran (plus natural ones on the
    # small-pool over-commit cells), and every resume replayed exactly
    assert eng.preemptions >= 1
    assert eng.resumes == eng.preemptions
    serve_harness.assert_drained(eng)
    if layout == "paged" and admission == "reserved":
        # forced eviction must not manufacture stalls under reservation
        assert eng.stalls == 0


def test_debug_transfers_cell_token_exact(serve_setup, serve_harness,
                                          oracle):
    """One matrix cell runs with ``debug_transfers=True``: every tick
    executes under ``jax.transfer_guard_device_to_host("disallow")``, so
    any *implicit* device->host sync smuggled into the hot path raises
    while the engine's explicit budgeted pulls pass — and the guarded
    stream must still be token-exact.  (`python -m repro.analysis.audit`
    drives the same guard plus the CPU-side TransferSpy over both
    layouts.)"""
    cfg, params = serve_setup
    kw = _engine_kw("paged", "chunked", "greedy", "overcommit")
    outputs, eng = serve_harness.run(
        params, cfg, serve_harness.pressure_requests(),
        preempt_at=(2, 5), debug_transfers=True, **kw)
    assert outputs == oracle
    serve_harness.assert_drained(eng)


def test_overcommit_small_pool_beats_reserved_occupancy(serve_setup,
                                                        serve_harness):
    """The tentpole's point: on a pool too small for every worst case,
    over-commit admission runs more slots concurrently than reserved
    admission — preempting and resuming instead of refusing entry — at
    identical tokens."""
    cfg, params = serve_setup
    kw = dict(n_slots=N_SLOTS, max_seq=MAX_SEQ, chunk=CHUNK, paged=True,
              block_size=8, n_blocks=SMALL_POOL, chunked_prefill=True,
              prefill_chunk_tokens=4)
    out_r, eng_r = serve_harness.run(
        params, cfg, serve_harness.pressure_requests(), **kw)
    out_o, eng_o = serve_harness.run(
        params, cfg, serve_harness.pressure_requests(),
        overcommit=True, **kw)
    assert out_o == out_r
    serve_harness.assert_drained(eng_o)
    st_r, st_o = eng_r.occupancy_stats(), eng_o.occupancy_stats()
    assert st_o["preemptions"] >= 1          # the pool really contended
    assert st_o["occupancy"] > st_r["occupancy"], (st_o, st_r)


def test_preempted_slot_parks_in_phase_preempted(serve_setup,
                                                 serve_harness):
    """The pool ledger tracks the parked lifecycle: PREEMPTED while the
    request holds no KV, PREFILL during the resume replay, DECODE after,
    IDLE at retirement."""
    cfg, params = serve_setup
    eng = ServingEngine(params, cfg, n_slots=2, max_seq=MAX_SEQ, chunk=CHUNK)
    reqs = serve_harness.pressure_requests(n=2)
    assert eng.admit_many(reqs) == 2
    eng.step()
    victim = eng._pick_victim()
    assert eng.preempt(victim) is not None
    assert eng.pool.phase_of(victim) == pool_lib.PHASE_PREEMPTED
    assert victim in eng._parked and victim not in eng.active
    pool_lib.check_invariants(eng.pool.state)
    eng.step()                   # damper tick
    eng.step()                   # resume lands
    assert eng.pool.phase_of(victim) in (pool_lib.PHASE_PREFILL,
                                         pool_lib.PHASE_DECODE)
    while eng.active or eng._parked:
        eng.step()
    assert eng.pool.phase_of(victim) == pool_lib.PHASE_IDLE
    assert eng.resumes == 1 and eng.preempt_replay_mismatches == 0
    serve_harness.assert_drained(eng)


def test_preempt_never_evicts_last_runner(serve_setup, serve_harness):
    """Progress guarantee: with one running slot the victim policy
    declines, so the maximal-progress request always retires."""
    cfg, params = serve_setup
    eng = ServingEngine(params, cfg, n_slots=2, max_seq=MAX_SEQ, chunk=2)
    reqs = serve_harness.pressure_requests(n=1)
    assert eng.admit_many(reqs) == 1
    eng.step()
    assert eng.preempt() is None
    assert eng.preemptions == 0 and not eng._parked


def test_victim_policy_fewest_tokens_then_latest_admission(serve_setup,
                                                           serve_harness):
    cfg, params = serve_setup
    eng = ServingEngine(params, cfg, n_slots=3, max_seq=MAX_SEQ, chunk=2)
    early = serve_harness.pressure_requests(n=2)
    assert eng.admit_many(early) == 2
    eng.step()                                   # both have tokens now
    late = serve_harness.pressure_requests(n=3)[2:]
    assert eng.admit_many(late) == 1
    # the late admission has fewest generated tokens -> the victim
    victim = eng._pick_victim()
    assert eng.active[victim].rid == late[0].rid
    # after its preemption, ties among the two earlier admissions break
    # toward the later one
    eng.preempt(victim)
    a, b = (s for s in eng.active)
    if len(eng.active[a].out) == len(eng.active[b].out):
        want = a if eng._slot_seq[a] > eng._slot_seq[b] else b
        assert eng._pick_victim() == want


def test_overcommit_rejects_unsupported_families(serve_harness):
    import jax
    import jax.numpy as jnp

    from repro.configs import get_arch, reduced
    from repro.models import model
    cfg_ssm = reduced(get_arch("mamba2-780m"))
    params = model.init(jax.random.PRNGKey(0), cfg_ssm, jnp.float32)
    with pytest.raises(ValueError, match="over-commit"):
        ServingEngine(params, cfg_ssm, n_slots=2, max_seq=32,
                      overcommit=True)


# -- chaos cells -------------------------------------------------------------
#
# The conformance contract under seeded faults: a FaultPlan injects a
# tick exception / NaN-poisoned cache / hung tick / forged pool-ledger
# bit into replica 0 of a 2-replica fleet mid-run, the fleet
# quarantines the replica and migrates its in-flight requests to the
# healthy one by replaying prompt + generated-so-far through chunked
# prefill — and every surviving request must stay bit-exact against the
# same uncontended single-engine oracle the fault-free cells use.
# Chaos engines are chunked (chunked_prefill=True), so a plain warmup
# compiles the solo/mixed/decode families — required before arming the
# hang cell's tick deadline, which must never fire on a compile.

CHAOS_MATRIX = [
    ("paged", "tick_exception"),
    ("paged", "nan_poison"),
    ("paged", "ledger_corruption"),
    ("paged", "hang"),
    ("contiguous", "tick_exception"),
]


@pytest.mark.parametrize(
    "layout,kind", CHAOS_MATRIX,
    ids=["chaos-" + "-".join(cell) for cell in CHAOS_MATRIX])
def test_chaos_cells_token_exact(serve_setup, serve_harness, oracle,
                                 layout, kind):
    import jax

    from repro.runtime import faults
    from repro.runtime.supervisor import FleetSupervisor
    cfg, params = serve_setup
    kw = dict(n_slots=N_SLOTS, max_seq=MAX_SEQ, chunk=CHUNK,
              chunked_prefill=True, prefill_chunk_tokens=4,
              validate_outputs=True)
    if layout == "paged":
        kw.update(paged=True, block_size=8, n_blocks=BIG_POOL)
    fleet = FleetSupervisor(params, cfg, n_replicas=2, model=1,
                            devices=jax.devices()[:1], **kw)
    if kind == "hang":
        for e in fleet.engines:     # compile every family, then arm
            e.run_to_completion(serve_harness.pressure_requests(3,
                                                               seed=99))
            e.reset_stats()
        fleet.tick_deadline_s = 0.5
        plan = faults.FaultPlan([faults.FaultEvent(
            kind="hang", tick=2, replica=0, hang_s=1.2)])
    else:
        plan = faults.FaultPlan([faults.FaultEvent(
            kind=kind, tick=3, replica=0)])
    fleet.arm_faults(plan)

    done, _ = fleet.run_to_completion(serve_harness.pressure_requests(),
                                      max_wall_s=120)
    got = {r.rid: r.out for r in done}
    assert got == oracle, (layout, kind)        # survivors bit-exact
    fh = fleet.fleet_health()
    assert fh["replicas"][0]["state"] == "quarantined", fh
    assert fh["healthy"] == 1
    assert fh["migrations"] >= 1                # work really moved
    assert fh["dead_letters"] == []             # nothing shed
    assert fh["migrate_replay_mismatches"] == 0
    if kind == "hang":
        assert "deadline" in fh["replicas"][0]["reason"]
    serve_harness.assert_drained(fleet.engines[1])


def test_chaos_tripwire_attributes_slot_and_tick(serve_setup,
                                                 serve_harness):
    """The `validate_outputs` tripwire reads only the already-synced
    emitted buffer (no new device pull) and names the slot/rid/tick in
    its raise, so a NaN'd cache is attributable, not a silent garbage
    stream."""
    from repro.runtime import faults
    from repro.runtime.serve import OutputValidationError
    cfg, params = serve_setup
    eng = ServingEngine(params, cfg, validate_outputs=True,
                        n_slots=N_SLOTS, max_seq=MAX_SEQ, chunk=CHUNK,
                        paged=True, block_size=8, n_blocks=BIG_POOL)
    eng.arm_faults(faults.FaultPlan([faults.FaultEvent(
        kind="nan_poison", tick=2)]).for_replica(0))
    with pytest.raises(OutputValidationError, match=r"slot \d+"):
        eng.run_to_completion(serve_harness.pressure_requests(3))


def test_chaos_max_wall_s_names_inflight_requests(serve_setup,
                                                  serve_harness):
    """`run_to_completion(max_wall_s=...)` bounds host wall clock (hung
    ticks burn no device ticks, so max_ticks alone cannot catch them)
    and the stuck report names each in-flight request with its age and
    the engine's health."""
    cfg, params = serve_setup
    eng = ServingEngine(params, cfg, n_slots=N_SLOTS, max_seq=MAX_SEQ,
                        chunk=CHUNK)
    with pytest.raises(RuntimeError, match="max_wall_s") as exc:
        eng.run_to_completion(serve_harness.pressure_requests(2),
                              max_wall_s=1e-4)
    assert "in flight rid" in str(exc.value)
    assert "health:" in str(exc.value)


# -- mesh-sharded cells ------------------------------------------------------
#
# The same contract one level up: a tensor-parallel engine (heads and KV
# sharded over the mesh's "model" axis) must emit the byte-identical
# token stream — sharding is a layout choice, not a numerical one.  The
# head-sharded contractions keep each head's reduction entirely on one
# shard (heads never mix in attention), so the float arithmetic per head
# is literally the same program as the single-device engine's.  Cells
# skip on a single-device host; CI runs them under
# ``XLA_FLAGS=--xla_force_host_platform_device_count=8``.

MESH_MATRIX = [cell for cell in MATRIX if cell[1] == "chunked"] + [
    ("contiguous", "monolithic", "greedy", "reserved"),
    ("paged", "monolithic", "greedy", "reserved"),
]


@pytest.mark.parametrize(
    "layout,chunking,decode,admission", MESH_MATRIX,
    ids=["mesh-" + "-".join(cell) for cell in MESH_MATRIX])
def test_token_exact_on_sharded_mesh(serve_setup, serve_harness, oracle,
                                     layout, chunking, decode, admission):
    import jax
    if jax.device_count() < 2:
        pytest.skip("needs >= 2 devices "
                    "(XLA_FLAGS=--xla_force_host_platform_device_count)")
    from repro.runtime.sharding import serve_mesh
    cfg, params = serve_setup
    kw = _engine_kw(layout, chunking, decode, admission)
    outputs, eng = serve_harness.run(
        params, cfg, serve_harness.pressure_requests(),
        preempt_at=(2, 5), mesh=serve_mesh(2), **kw)
    assert outputs == oracle, (layout, chunking, decode, admission)
    ks = eng.kv_stats()
    assert ks["model_shards"] == 2
    assert ks["kv_shard_fraction"] == 0.5       # KV really split, not
    assert eng.preemptions >= 1                 # replicated
    serve_harness.assert_drained(eng)


@pytest.mark.parametrize("paged", [False, True])
def test_plan_serve_overcommit_lowers_with_shardings(paged):
    """ClusterSupervisor lowers the eviction-aware mixed tick (the step
    the over-commit engine drives between evictions and resumes) with
    explicit shardings and donation on both layouts."""
    import jax
    import jax.numpy as jnp

    from jax.sharding import Mesh
    from repro.configs import ShapeConfig, get_arch, reduced
    from repro.models import model
    from repro.runtime.supervisor import ClusterSupervisor

    cfg = reduced(get_arch("granite-3-2b"), n_layers=1, d_model=64,
                  vocab=128)
    mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1),
                ("data", "model"))
    shape = ShapeConfig("serve_tiny", 48, 4, "serve")
    sup = ClusterSupervisor(mesh, cfg, shape, dtype=jnp.float32)
    layout = model.PagedLayout(block_size=8, n_blocks=24) if paged else None
    plan = sup.plan_serve(overcommit=8, paged=layout)
    assert plan.kind == "serve"
    assert plan.donate_argnums == ((2, 3) if paged else (2,))
    lowered = jax.jit(plan.step_fn, in_shardings=plan.in_shardings,
                      out_shardings=plan.out_shardings,
                      donate_argnums=plan.donate_argnums) \
        .lower(*plan.abstract_args)
    assert lowered.compile() is not None


@pytest.mark.parametrize("family,kw", [
    ("chunked", dict(chunked=8)),
    ("solo_prefill", dict(solo_prefill=8)),
    ("spec", dict(speculative=3)),
], ids=["chunked", "solo_prefill", "spec"])
@pytest.mark.parametrize("paged", [False, True], ids=["contig", "paged"])
def test_plan_serve_all_families_lower_on_serve_mesh(family, kw, paged):
    """Every tick family lowers through ``plan_serve(mesh=...)`` with
    explicit shardings and donated caches — the mesh kwarg rebuilds the
    supervisor on the serve grid, so one supervisor instance can plan
    for whatever mesh the fleet hands it."""
    import jax
    import jax.numpy as jnp

    from jax.sharding import Mesh
    from repro.configs import ShapeConfig, get_arch, reduced
    from repro.models import model
    from repro.runtime.sharding import serve_mesh
    from repro.runtime.supervisor import ClusterSupervisor

    cfg = reduced(get_arch("granite-3-2b"), n_layers=1, d_model=64,
                  vocab=128)
    train_mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1),
                      ("data", "model"))
    shape = ShapeConfig("serve_tiny", 48, 4, "serve")
    sup = ClusterSupervisor(train_mesh, cfg, shape, dtype=jnp.float32)
    layout = model.PagedLayout(block_size=8, n_blocks=24) if paged else None
    plan = sup.plan_serve(paged=layout, mesh=serve_mesh(1), **kw)
    assert plan.kind == "serve"
    assert dict(plan.rules.mesh.shape) == {"data": 1, "model": 1}
    lowered = jax.jit(plan.step_fn, in_shardings=plan.in_shardings,
                      out_shardings=plan.out_shardings,
                      donate_argnums=plan.donate_argnums) \
        .lower(*plan.abstract_args)
    assert lowered.compile() is not None
