"""Chunked prefill: the prompt as outsourced fragments.

The paper's cores never receive a whole job at once — fragments are
outsourced incrementally as capacity appears.  The contract under test:

* ``model.prefill_chunk`` is bit-exact against the monolithic prefill on
  both cache layouts, fragment size be damned (aligned or not with the
  block size), and a length-1 fragment is exactly a decode step;
* the chunked-prefill engine is token-exact against monolithic
  admission on mixed long/short workloads — including a long prompt
  admitted mid-decode, which must not perturb the tokens of
  already-active slots;
* paged chains grow chunk-granularly under the §5.1 worst-case
  reservation, and prefix-block sharing keeps working when the shared
  prefix spans a chunk boundary;
* the per-tick token budget bounds how much prefill one tick absorbs;
* slots move PHASE_PREFILL -> PHASE_DECODE -> PHASE_IDLE through the
  pool ledger.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch, reduced
from repro.models import model
from repro.runtime import paging
from repro.runtime import pool as pool_lib
from repro.runtime.serve import Request, ServingEngine


# the shared (cfg, params) fixture and the mixed long/short request
# generator live in tests/runtime/conftest.py: `serve_setup` /
# `serve_harness`


# ---------------------------------------------------------------------------
# model level: fragment-by-fragment == monolithic, bit for bit
# ---------------------------------------------------------------------------

def _drive_chunks(params, cfg, cache, toks, lengths, C):
    """Feed left-aligned fragments until every row consumed its prompt;
    returns the final-fragment logits per row."""
    bsz = toks.shape[0]
    cur = np.zeros(bsz, np.int32)
    last_logits = np.zeros((bsz, cfg.vocab_padded), np.float32)
    while np.any(cur < lengths):
        frag = np.zeros((bsz, C), np.int32)
        fl = np.zeros(bsz, np.int32)
        for b in range(bsz):
            take = min(C, int(lengths[b] - cur[b]))
            if take > 0:
                frag[b, :take] = toks[b, cur[b]:cur[b] + take]
                fl[b] = take
        lg, cache = model.prefill_chunk(params, jnp.asarray(frag),
                                        jnp.asarray(fl), cache, cfg)
        lg = np.asarray(lg)
        for b in range(bsz):
            if fl[b] and cur[b] + fl[b] >= lengths[b]:
                last_logits[b] = lg[b]
            cur[b] += fl[b]
    return last_logits, cache


@pytest.mark.parametrize("C", [4, 5])
def test_prefill_chunk_matches_monolithic_contiguous(serve_setup, C):
    cfg, params = serve_setup
    max_seq = 32
    toks = np.asarray(jax.random.randint(jax.random.PRNGKey(1), (3, 11),
                                         1, cfg.vocab), np.int32)
    lengths = np.asarray([11, 5, 8], np.int32)
    lm, cm = model.prefill(params, {"tokens": jnp.asarray(toks)}, cfg,
                           max_seq, lengths=jnp.asarray(lengths))
    cache = model.init_cache(cfg, 3, max_seq, dtype=jnp.float32)
    lc, cache = _drive_chunks(params, cfg, cache, toks, lengths, C)
    np.testing.assert_array_equal(np.asarray(lm), lc)
    np.testing.assert_array_equal(np.asarray(cm["pos"]),
                                  np.asarray(cache["pos"]))
    for b, s in enumerate(lengths):
        np.testing.assert_array_equal(np.asarray(cm["k"])[:, b, :s],
                                      np.asarray(cache["k"])[:, b, :s])
    # decode continuation from the chunk-built cache is a decode step
    tok = jnp.argmax(lm, -1).astype(jnp.int32)
    l1, _ = model.decode_step(params, tok, cm, cfg)
    l2, _ = model.decode_step(params, tok, cache, cfg)
    np.testing.assert_array_equal(np.asarray(l1), np.asarray(l2))


def test_prefill_chunk_matches_monolithic_paged(serve_setup):
    cfg, params = serve_setup
    max_seq, bs = 32, 8
    layout = model.PagedLayout(block_size=bs, n_blocks=16)
    toks = np.asarray(jax.random.randint(jax.random.PRNGKey(2), (2, 11),
                                         1, cfg.vocab), np.int32)
    lengths = np.asarray([11, 9], np.int32)
    lm, cm = model.prefill(params, {"tokens": jnp.asarray(toks)}, cfg,
                           max_seq, lengths=jnp.asarray(lengths))
    cache = model.init_cache(cfg, 2, max_seq, dtype=jnp.float32,
                             layout=layout)
    # identity chains, like the static paged prefill
    cache["block_tables"] = jnp.arange(8, dtype=jnp.int32).reshape(2, 4)
    lc, cache = _drive_chunks(params, cfg, cache, toks, lengths, C=4)
    np.testing.assert_array_equal(np.asarray(lm), lc)
    tok = jnp.argmax(lm, -1).astype(jnp.int32)
    for _ in range(10):        # crosses a block boundary
        l1, cm = model.decode_step(params, tok, cm, cfg)
        l2, cache = model.decode_step(params, tok, cache, cfg)
        np.testing.assert_array_equal(np.asarray(l1), np.asarray(l2))
        tok = jnp.argmax(l1, -1).astype(jnp.int32)


def test_prefill_chunk_rejects_unsupported_families(serve_setup):
    cfg_ssm = reduced(get_arch("mamba2-780m"))
    with pytest.raises(ValueError, match="chunked prefill"):
        model.prefill_chunk({}, jnp.zeros((1, 4), jnp.int32),
                            jnp.ones((1,), jnp.int32), {}, cfg_ssm)


# ---------------------------------------------------------------------------
# engine level: token-exact continuous batching, no head-of-line stalls
# ---------------------------------------------------------------------------

def test_chunked_engine_token_exact_vs_monolithic(serve_setup, serve_harness):
    cfg, params = serve_setup
    e_m = ServingEngine(params, cfg, n_slots=3, max_seq=48)
    done_m, _ = e_m.run_to_completion(serve_harness.mixed_requests())
    e_c = ServingEngine(params, cfg, n_slots=3, max_seq=48,
                        chunked_prefill=True, prefill_chunk_tokens=8)
    done_c, _ = e_c.run_to_completion(serve_harness.mixed_requests())
    assert {r.rid: r.out for r in done_m} == {r.rid: r.out for r in done_c}
    assert e_c.pool.used == 0
    # one compile for every prompt length (no pow2 span buckets), and the
    # engine returned to multi-token decode chunks once prompts drained
    assert not e_c._jobs


@pytest.mark.parametrize("C", [8, 5])
def test_chunked_engine_token_exact_paged(serve_setup, serve_harness, C):
    """Paged chunk-granular renting: exact tokens, clean pool, no stalls
    — with the fragment size aligned and unaligned to the block size."""
    cfg, params = serve_setup
    e_m = ServingEngine(params, cfg, n_slots=3, max_seq=48, paged=True,
                        block_size=8, n_blocks=20)
    done_m, _ = e_m.run_to_completion(serve_harness.mixed_requests())
    e_c = ServingEngine(params, cfg, n_slots=3, max_seq=48, paged=True,
                        block_size=8, n_blocks=20, chunked_prefill=True,
                        prefill_chunk_tokens=C)
    done_c, _ = e_c.run_to_completion(serve_harness.mixed_requests())
    assert {r.rid: r.out for r in done_m} == {r.rid: r.out for r in done_c}
    assert e_c.stalls == 0
    assert e_c.pool.used == 0
    assert int(paging.blocks_in_use(e_c.bstate)) == 0
    paging.check_invariants(e_c.bstate, e_c.cache["block_tables"])


def test_long_prompt_mid_decode_does_not_perturb_active_slots(serve_setup):
    """The mixed tick's whole point: outsourcing a long prompt fragment
    by fragment must leave already-active slots' token streams exactly
    as a decode-only run produces them."""
    cfg, params = serve_setup
    short = [Request(i, np.arange(1 + i, 9 + i, dtype=np.int32),
                     max_new=10) for i in range(2)]

    e_solo = ServingEngine(params, cfg, n_slots=4, max_seq=64,
                           chunked_prefill=True, prefill_chunk_tokens=8)
    done_solo, _ = e_solo.run_to_completion(
        [Request(r.rid, r.prompt, max_new=r.max_new) for r in short])
    solo = {r.rid: r.out for r in done_solo}

    eng = ServingEngine(params, cfg, n_slots=4, max_seq=64,
                        chunked_prefill=True, prefill_chunk_tokens=8)
    assert eng.admit_many(short) == 2
    eng.step()                       # both actives are decoding
    long_req = Request(9, np.arange(1, 41, dtype=np.int32), max_new=4)
    assert eng.admit(long_req)       # 40 tokens: 5 fragment ticks
    done = []
    while eng.active:
        done += eng.step()
    got = {r.rid: r.out for r in done}
    assert {0, 1, 9} == set(got)
    assert got[0] == solo[0] and got[1] == solo[1]


def test_prefix_sharing_across_chunk_boundary(serve_setup):
    """A chain becomes shareable only once written: admit the source,
    let its prefill finish, then admit a sharer whose 2-block shared
    prefix spans two fragments — the sharer skips the shared recompute
    and both streams stay exact vs the unshared engine."""
    cfg, params = serve_setup
    base = np.arange(1, 21, dtype=np.int32)      # 2 full 8-blocks + tail
    tail = np.concatenate([base, [77, 78]]).astype(np.int32)

    def run(sharing):
        eng = ServingEngine(params, cfg, n_slots=3, max_seq=48,
                            paged=True, block_size=8, n_blocks=20,
                            chunked_prefill=True, prefill_chunk_tokens=8,
                            prefix_sharing=sharing)
        r0 = Request(0, base, max_new=12)
        assert eng.admit(r0)
        # with nobody decoding, the cold-start solo tick packs r0's whole
        # prompt into one step; one more step starts decoding (r0 must
        # still be active when the sharer arrives, or its refcount-zero
        # prefix blocks would be dropped from the map at retirement)
        while eng._jobs:
            eng.step()
        eng.step()
        assert eng.active
        r1 = Request(1, tail, max_new=6)
        assert eng.admit(r1)
        done = []
        while eng.active:
            done += eng.step()
        paging.check_invariants(eng.bstate, eng.cache["block_tables"])
        assert int(paging.blocks_in_use(eng.bstate)) == 0
        return {r.rid: r.out for r in done}, eng

    out_s, eng_s = run(True)
    out_u, eng_u = run(False)
    assert out_s == out_u
    assert eng_s.shared_block_hits == 2          # both prefix blocks
    assert eng_u.shared_block_hits == 0
    assert eng_s.stalls == 0


def test_tick_token_budget_bounds_prefill_per_tick(serve_setup):
    """Two long prompts under a one-fragment budget: the scheduler
    serializes them (bounded per-tick latency) and outputs are still
    exact vs the unbudgeted engine."""
    cfg, params = serve_setup
    reqs = [Request(0, np.arange(1, 25, dtype=np.int32), max_new=4),
            Request(1, np.arange(2, 26, dtype=np.int32), max_new=4)]

    eng = ServingEngine(params, cfg, n_slots=2, max_seq=48,
                        chunked_prefill=True, prefill_chunk_tokens=8,
                        max_prefill_tokens_per_tick=8)
    assert eng.admit_many([Request(r.rid, r.prompt, max_new=r.max_new)
                           for r in reqs]) == 2
    eng.step()
    # one fragment granted, the other job starved this tick
    cursors = sorted(j.cursor for j in eng._jobs.values())
    assert cursors == [0, 8]
    done = []
    while eng.active:
        done += eng.step()

    free = ServingEngine(params, cfg, n_slots=2, max_seq=48,
                         chunked_prefill=True, prefill_chunk_tokens=8)
    done_f, _ = free.run_to_completion(reqs)
    assert {r.rid: r.out for r in done} == {r.rid: r.out for r in done_f}


def test_phase_ledger_tracks_fragment_lifecycle(serve_setup):
    """PHASE_PREFILL while fragments are outsourced, PHASE_DECODE once
    the prompt is absorbed, PHASE_IDLE after retirement."""
    cfg, params = serve_setup
    eng = ServingEngine(params, cfg, n_slots=2, max_seq=48,
                        chunked_prefill=True, prefill_chunk_tokens=8)
    req = Request(0, np.arange(1, 21, dtype=np.int32), max_new=3)
    assert eng.admit(req)
    slot = req.slot
    assert eng.pool.phase_of(slot) == pool_lib.PHASE_PREFILL
    eng.step()                                   # fragment 1 of 3
    assert eng.pool.phase_of(slot) == pool_lib.PHASE_PREFILL
    while eng._jobs:
        eng.step()
    assert eng.pool.phase_of(slot) == pool_lib.PHASE_DECODE
    while eng.active:
        eng.step()
    assert eng.pool.phase_of(slot) == pool_lib.PHASE_IDLE
    pool_lib.check_invariants(eng.pool.state)


def test_chunked_rejects_unsupported_families(serve_setup):
    cfg_ssm = reduced(get_arch("mamba2-780m"))
    params = model.init(jax.random.PRNGKey(0), cfg_ssm, jnp.float32)
    with pytest.raises(ValueError, match="chunked prefill"):
        ServingEngine(params, cfg_ssm, n_slots=2, max_seq=32,
                      chunked_prefill=True)
