"""Property tests of the jittable SlotPool transitions (runtime/pool.py).

The same §4.3 rent/terminate discipline drives the clock-level machine's
host pool (core/supervisor.CorePool), the serving slot supervisor (on
device) and the elastic fleet manager — so the invariants are tested once
over the shared pure transitions, plus parity between the consumers.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")   # real lib or the conftest fallback
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.supervisor import CorePool
from repro.runtime import pool as pool_lib

OPS = ["rent", "rent_child", "release", "prealloc", "disable", "enable"]


def _apply(state, rented, op):
    """Drive one op on the pure transitions, mirroring host-side legality
    checks (only release units without live children)."""
    if op == "rent":
        state, u = pool_lib.rent(state)
        if int(u) >= 0:
            rented.append(int(u))
    elif op == "rent_child" and rented:
        state, u = pool_lib.rent(state, parent=rented[0])
        if int(u) >= 0:
            rented.append(int(u))
    elif op == "release" and rented:
        u = rented[-1]
        if not np.any(np.asarray(pool_lib.children_mask(state, u))):
            state, status = pool_lib.release(state, u)
            assert int(status) == pool_lib.OK
            rented.remove(u)
    elif op == "prealloc" and rented:
        state, _ = pool_lib.preallocate(state, rented[0], 2)
    elif op == "disable":
        state = pool_lib.disable(state, state.n - 1)
    elif op == "enable":
        state = pool_lib.enable(state, state.n - 1)
    return state, rented


@settings(max_examples=25, deadline=None)
@given(st.lists(st.sampled_from(OPS), max_size=40), st.integers(2, 12))
def test_transition_invariants_random_walk(ops, n):
    """Conservation + parent/child consistency hold under arbitrary
    transition sequences on the pure jittable state."""
    state = pool_lib.init_pool(n)
    rented: list[int] = []
    for op in ops:
        state, rented = _apply(state, rented, op)
        pool_lib.check_invariants(state)
    assert int(pool_lib.used(state)) == len(rented)


@settings(max_examples=15, deadline=None)
@given(st.lists(st.sampled_from(OPS), max_size=30), st.integers(2, 8))
def test_host_wrapper_matches_pure_transitions(ops, n):
    """CorePool is a *thin* wrapper: same op sequence -> identical state."""
    pool = CorePool(n)
    state = pool_lib.init_pool(n)
    rented: list[int] = []
    for op in ops:
        if op == "rent":
            u = pool.rent()
            state, v = pool_lib.rent(state)
            assert (-1 if u is None else u) == int(v)
            if u is not None:
                rented.append(u)
        elif op == "rent_child" and rented:
            u = pool.rent(parent=rented[0])
            state, v = pool_lib.rent(state, parent=rented[0])
            assert (-1 if u is None else u) == int(v)
            if u is not None:
                rented.append(u)
        elif op == "release" and rented:
            u = rented[-1]
            if not pool.children_of(u):
                pool.release(u)
                state, status = pool_lib.release(state, u)
                assert int(status) == pool_lib.OK
                rented.remove(u)
        elif op == "prealloc" and rented:
            pool.preallocate(rented[0], 2)
            state, _ = pool_lib.preallocate(state, rented[0], 2)
        elif op == "disable":
            pool.disable(n - 1)
            state = pool_lib.disable(state, n - 1)
        elif op == "enable":
            pool.enable(n - 1)
            state = pool_lib.enable(state, n - 1)
        for a, b in zip(pool.state, state):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    pool.check_invariants()
    pool_lib.check_invariants(state)


def test_host_wrapper_raises_on_misuse():
    pool = CorePool(4)
    with pytest.raises(ValueError):
        pool.release(0)                  # not rented
    p = pool.rent()
    c = pool.rent(parent=p)
    with pytest.raises(RuntimeError):
        pool.release(p)                  # §4.3: live children block parent
    pool.release(c)
    pool.release(p)
    with pytest.raises(IndexError):
        pool.release(99)
    pool.check_invariants()


def test_transitions_compose_under_jit():
    """The whole rent->release cycle runs inside one jitted program —
    the property the device-resident serving supervisor relies on."""

    @jax.jit
    def cycle(state):
        state, u1 = pool_lib.rent(state)
        state, u2 = pool_lib.rent(state, parent=u1)
        state, s_blocked = pool_lib.release(state, u1)   # child alive
        state, s2 = pool_lib.release(state, u2)
        state, s1 = pool_lib.release(state, u1)
        return state, (u1, u2, s_blocked, s2, s1)

    state, (u1, u2, s_blocked, s2, s1) = cycle(pool_lib.init_pool(4))
    assert (int(u1), int(u2)) == (0, 1)
    assert int(s_blocked) == pool_lib.ERR_LIVE_CHILDREN
    assert int(s2) == pool_lib.OK and int(s1) == pool_lib.OK
    assert int(pool_lib.used(state)) == 0
    pool_lib.check_invariants(state)


def test_rent_exhaustion_and_disable_inside_scan():
    """Vectorized SV behavior: scan rents until exhaustion, -1 after."""
    def body(state, _):
        state, u = pool_lib.rent(state)
        return state, u

    state = pool_lib.disable(pool_lib.init_pool(3), 1)
    state, units = jax.lax.scan(body, state, None, length=4)
    assert [int(u) for u in units] == [0, 2, -1, -1]
    assert int(pool_lib.available(state)) == 0


def test_serving_and_elastic_observe_identical_pool_behavior():
    """The serving engine's slot pool and the elastic fleet pool are the
    same discipline: an identical op trace leaves identical state."""
    from repro.runtime.elastic import ElasticManager

    em = ElasticManager(6, spares=2)          # rents 4, preallocates 2
    # replay the exact same trace on a fresh CorePool (as the serving
    # engine would drive it: rent on admission, release on EOS)
    pool = CorePool(6)
    active = [pool.rent() for _ in range(4)]
    pool.preallocate(active[0], 2)
    for a, b in zip(em.pool.state, pool.state):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    # a failure in the fleet == a released+disabled slot in serving terms
    em.fail(em.active[0])                     # swap: disable + rent spare
    pool.disable(0)
    spare = pool.rent()
    assert spare is not None
    for a, b in zip(em.pool.state, pool.state):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    em.check_invariants()
    pool.check_invariants()


def test_serving_engine_pool_is_shared_discipline():
    """ServingEngine's ledger and ElasticManager's fleet pool expose the
    same SlotPoolState type — one property-tested implementation."""
    import jax.numpy as jnp_  # noqa: F401

    from repro.configs import get_arch, reduced
    from repro.models import model
    from repro.runtime.elastic import ElasticManager
    from repro.runtime.serve import Request, ServingEngine

    cfg = reduced(get_arch("granite-3-2b"), n_layers=1, d_model=64,
                  vocab=128)
    params = model.init(jax.random.PRNGKey(0), cfg, jnp.float32)
    eng = ServingEngine(params, cfg, n_slots=2, max_seq=32)
    em = ElasticManager(4, spares=1)
    assert isinstance(eng.pool.state, pool_lib.SlotPoolState)
    assert isinstance(em.pool_state, pool_lib.SlotPoolState)
    # rent-on-admission visible through the shared state
    assert eng.admit(Request(0, np.arange(1, 5, dtype=np.int32), max_new=2))
    assert not bool(eng.pool.state.free[0])
    assert int(pool_lib.used(eng.pool.state)) == 1
    done, _ = eng.run_to_completion([])
    assert len(done) == 1                      # the admitted request drains
    assert int(pool_lib.used(eng.pool.state)) == 0
    pool_lib.check_invariants(eng.pool.state)
    pool_lib.check_invariants(em.pool_state)


def test_set_phase_lifecycle_and_release_reset():
    """Phase follows the fragment lifecycle: IDLE on a free unit (by
    invariant), PREFILL/DECODE while rented, reset to IDLE by release."""
    state = pool_lib.init_pool(3)
    state, u = pool_lib.rent(state)
    u = int(u)
    assert int(state.phase[u]) == pool_lib.PHASE_IDLE
    state = pool_lib.set_phase(state, u, pool_lib.PHASE_PREFILL)
    assert int(state.phase[u]) == pool_lib.PHASE_PREFILL
    state = pool_lib.set_phase(state, u, pool_lib.PHASE_DECODE)
    assert int(state.phase[u]) == pool_lib.PHASE_DECODE
    pool_lib.check_invariants(state)
    state, status = pool_lib.release(state, u)
    assert int(status) == pool_lib.OK
    assert int(state.phase[u]) == pool_lib.PHASE_IDLE
    pool_lib.check_invariants(state)


def test_set_phase_total_on_free_or_bad_units():
    """set_phase is a total transition: free or out-of-range units leave
    the state unchanged (the host wrapper raises instead)."""
    state = pool_lib.init_pool(2)
    s2 = pool_lib.set_phase(state, 0, pool_lib.PHASE_DECODE)   # free unit
    assert int(s2.phase[0]) == pool_lib.PHASE_IDLE
    s3 = pool_lib.set_phase(state, 7, pool_lib.PHASE_DECODE)   # bad unit
    np.testing.assert_array_equal(np.asarray(s3.phase),
                                  np.asarray(state.phase))
    from repro.core.supervisor import CorePool
    pool = CorePool(2)
    with pytest.raises(ValueError, match="not rented"):
        pool.set_phase(0, pool_lib.PHASE_DECODE)
    u = pool.rent()
    pool.set_phase(u, pool_lib.PHASE_PREFILL)
    assert pool.phase_of(u) == pool_lib.PHASE_PREFILL
    pool.release(u)
    assert pool.phase_of(u) == pool_lib.PHASE_IDLE
    pool.check_invariants()


def test_release_many_resets_phase():
    state = pool_lib.init_pool(3)
    state, units = pool_lib.rent_many(state, jnp.ones((3,), bool))
    for u in units:
        state = pool_lib.set_phase(state, int(u), pool_lib.PHASE_DECODE)
    state = pool_lib.release_many(state, jnp.asarray([True, True, False]))
    assert [int(p) for p in state.phase] == \
        [pool_lib.PHASE_IDLE, pool_lib.PHASE_IDLE, pool_lib.PHASE_DECODE]
    pool_lib.check_invariants(state)
