"""Error-feedback int8 compression: numerics + convergence preservation."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")   # real lib or the conftest fallback
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.optim import compression


def test_quantize_roundtrip_small_error():
    key = jax.random.PRNGKey(0)
    g = jax.random.normal(key, (128,)) * 0.01
    q, scale, err = compression.quantize(g, jnp.zeros_like(g))
    deq = compression.dequantize(q, scale)
    # worst-case quantization error is scale/2 per element
    assert float(jnp.max(jnp.abs(deq - g))) <= float(scale) / 2 + 1e-9
    np.testing.assert_allclose(np.array(g - deq), np.array(err), atol=1e-7)


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 10_000))
def test_error_feedback_is_unbiased_over_time(seed):
    """Property: accumulated EF error stays bounded (doesn't drift)."""
    key = jax.random.PRNGKey(seed)
    err = jnp.zeros((64,))
    total_sent = jnp.zeros((64,))
    total_true = jnp.zeros((64,))
    for t in range(20):
        key, k = jax.random.split(key)
        g = jax.random.normal(k, (64,)) * 0.1
        q, scale, err = compression.quantize(g, err)
        total_sent += compression.dequantize(q, scale)
        total_true += g
    # sent + residual error == true sum exactly (EF invariant)
    np.testing.assert_allclose(np.array(total_sent + err),
                               np.array(total_true), rtol=1e-4, atol=1e-5)


def test_compress_grads_tree_and_ratio():
    grads = {"a": jnp.ones((100,)), "b": {"c": jnp.full((50,), -0.5)}}
    err = compression.init_error_state(grads)
    out, err2, metrics = compression.compress_grads(grads, err)
    assert jax.tree_util.tree_structure(out) == \
        jax.tree_util.tree_structure(grads)
    assert metrics["compression_ratio"] > 3.5   # ~4x for fp32 -> int8
    np.testing.assert_allclose(np.array(out["a"]), np.ones(100), rtol=2e-2)


def test_training_converges_with_compression():
    """Quadratic toy problem: EF-compressed gradient descent converges to
    (near) the same optimum as exact GD."""
    key = jax.random.PRNGKey(3)
    target = jax.random.normal(key, (16,))

    def loss(w):
        return jnp.sum((w - target) ** 2)

    w_exact = jnp.zeros((16,))
    w_comp = jnp.zeros((16,))
    err = jnp.zeros((16,))
    for _ in range(200):
        w_exact = w_exact - 0.05 * jax.grad(loss)(w_exact)
        g = jax.grad(loss)(w_comp)
        (gq, err, _) = compression.compress_grads(g, err)
        w_comp = w_comp - 0.05 * gq
    assert float(loss(w_exact)) < 1e-6
    assert float(loss(w_comp)) < 1e-4   # EF keeps convergence
