"""Serving engine: slot-pool admission, queueing, EOS release."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch, reduced
from repro.models import model
from repro.runtime.serve import Request, ServingEngine


def _engine(n_slots=2, max_seq=48):
    cfg = reduced(get_arch("granite-3-2b"), n_layers=1, d_model=64,
                  vocab=128)
    params = model.init(jax.random.PRNGKey(0), cfg, jnp.float32)
    return ServingEngine(params, cfg, n_slots=n_slots, max_seq=max_seq)


def test_admission_respects_pool():
    eng = _engine(n_slots=2)
    reqs = [Request(i, np.arange(1, 5, dtype=np.int32), max_new=4)
            for i in range(3)]
    assert eng.admit(reqs[0]) and eng.admit(reqs[1])
    assert not eng.admit(reqs[2])        # pool exhausted -> queue upstream
    assert eng.pool.used == 2


def test_eos_releases_slot_for_next_request():
    eng = _engine(n_slots=1)
    r1 = Request(0, np.arange(1, 5, dtype=np.int32), max_new=3)
    r2 = Request(1, np.arange(2, 6, dtype=np.int32), max_new=3)
    done, ticks = eng.run_to_completion([r1, r2])
    assert {r.rid for r in done} == {0, 1}
    assert eng.pool.created_total == 2   # slot rented twice (reuse)
    assert eng.pool.available == 1


def test_outputs_deterministic_wrt_batching():
    """A request decoded alone == decoded while sharing the batch."""
    eng1 = _engine(n_slots=4)
    prompt = np.arange(1, 9, dtype=np.int32)
    solo = Request(0, prompt, max_new=5)
    done, _ = eng1.run_to_completion([solo])
    solo_out = done[0].out

    eng2 = _engine(n_slots=4)
    rng = np.random.default_rng(1)
    others = [Request(i, rng.integers(1, 100, size=6).astype(np.int32),
                      max_new=5) for i in (1, 2)]
    together = Request(0, prompt, max_new=5)
    done2, _ = eng2.run_to_completion([together] + others)
    together_out = [r for r in done2 if r.rid == 0][0].out
    assert solo_out == together_out


def test_prefill_writes_correct_slot():
    eng = _engine(n_slots=3)
    r = Request(0, np.arange(1, 7, dtype=np.int32), max_new=2)
    assert eng.admit(r)
    slot = r.slot
    assert int(eng.cache["pos"][slot]) == 6      # prompt length
    other = [s for s in range(3) if s != slot]
    for s in other:
        assert int(eng.cache["pos"][s]) == 0     # untouched slots
