"""Serving engine: slot-pool admission, queueing, EOS release."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.runtime.serve import Request, ServingEngine

# (cfg, params) come from the shared session fixture in
# tests/runtime/conftest.py — the engine under test is built fresh per
# test, but the tiny model is initialized exactly once


def _engine(setup, n_slots=2, max_seq=48):
    cfg, params = setup
    return ServingEngine(params, cfg, n_slots=n_slots, max_seq=max_seq)


def test_admission_respects_pool(serve_setup):
    eng = _engine(serve_setup, n_slots=2)
    reqs = [Request(i, np.arange(1, 5, dtype=np.int32), max_new=4)
            for i in range(3)]
    assert eng.admit(reqs[0]) and eng.admit(reqs[1])
    assert not eng.admit(reqs[2])        # pool exhausted -> queue upstream
    assert eng.pool.used == 2


def test_eos_releases_slot_for_next_request(serve_setup):
    eng = _engine(serve_setup, n_slots=1)
    r1 = Request(0, np.arange(1, 5, dtype=np.int32), max_new=3)
    r2 = Request(1, np.arange(2, 6, dtype=np.int32), max_new=3)
    done, ticks = eng.run_to_completion([r1, r2])
    assert {r.rid for r in done} == {0, 1}
    assert eng.pool.created_total == 2   # slot rented twice (reuse)
    assert eng.pool.available == 1


def test_outputs_deterministic_wrt_batching(serve_setup):
    """A request decoded alone == decoded while sharing the batch, even
    when the neighbors retire mid-flight (shorter budgets)."""
    eng1 = _engine(serve_setup, n_slots=4)
    prompt = np.arange(1, 9, dtype=np.int32)
    solo = Request(0, prompt, max_new=5)
    done, _ = eng1.run_to_completion([solo])
    solo_out = done[0].out

    eng2 = _engine(serve_setup, n_slots=4)
    rng = np.random.default_rng(1)
    # staggered budgets: both neighbors retire while req 0 still decodes
    others = [Request(i, rng.integers(1, 100, size=6).astype(np.int32),
                      max_new=mn) for i, mn in ((1, 2), (2, 3))]
    together = Request(0, prompt, max_new=5)
    done2, _ = eng2.run_to_completion([together] + others)
    together_out = [r for r in done2 if r.rid == 0][0].out
    assert solo_out == together_out


def test_outputs_deterministic_wrt_retirement_churn(serve_setup):
    """Regression for the stale-token retirement bug class: slots retiring
    mid-chunk and being re-rented to fresh requests must never perturb a
    still-active slot's token stream."""
    prompt = np.arange(1, 9, dtype=np.int32)
    eng1 = _engine(serve_setup, n_slots=3, max_seq=64)
    done, _ = eng1.run_to_completion([Request(0, prompt, max_new=12)])
    solo_out = done[0].out
    assert len(solo_out) >= 2

    eng2 = _engine(serve_setup, n_slots=3, max_seq=64)
    rng = np.random.default_rng(7)
    churn = [Request(i, rng.integers(1, 100, size=4).astype(np.int32),
                     max_new=2) for i in range(1, 6)]
    target = Request(0, prompt, max_new=12)
    done2, _ = eng2.run_to_completion([target] + churn)
    assert {r.rid for r in done2} == set(range(6))
    assert [r for r in done2 if r.rid == 0][0].out == solo_out
    assert eng2.pool.created_total == 6      # recycled slots were re-rented
    assert eng2.pool.used == 0


def test_host_sync_economy(serve_setup):
    """The device-resident loop syncs ≥5× less than per-slot-per-tick."""
    eng = _engine(serve_setup, n_slots=4, max_seq=64)
    rng = np.random.default_rng(3)
    reqs = [Request(i, rng.integers(1, 100, size=6).astype(np.int32),
                    max_new=10) for i in range(6)]
    done, _ = eng.run_to_completion(reqs)
    assert len(done) == 6
    stats = eng.sync_stats()
    assert stats["sync_reduction_x"] >= 5.0, stats


def test_plan_serve_lowers_with_shardings(serve_setup):
    """ClusterSupervisor emits the jitted serve tick as a Plan."""
    from jax.sharding import Mesh
    from repro.configs import ShapeConfig
    from repro.runtime.supervisor import ClusterSupervisor

    cfg, _ = serve_setup
    mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1),
                ("data", "model"))
    shape = ShapeConfig("serve_tiny", 48, 4, "serve")
    plan = ClusterSupervisor(mesh, cfg, shape, dtype=jnp.float32).plan()
    assert plan.kind == "serve"
    assert plan.donate_argnums == (2,)       # the cache decodes in place
    lowered = jax.jit(plan.step_fn, in_shardings=plan.in_shardings,
                      out_shardings=plan.out_shardings,
                      donate_argnums=plan.donate_argnums) \
        .lower(*plan.abstract_args)
    assert lowered.compile() is not None


def test_pow2_bucket_clamps_over_cap_lengths():
    """Regression: over-cap lengths used to return raw `n`, compiling a
    fresh prefill per distinct over-cap prompt length."""
    from repro.runtime.serve import _pow2_bucket
    assert _pow2_bucket(5, 64) == 8
    assert _pow2_bucket(64, 64) == 64
    assert _pow2_bucket(65, 64) == 64      # clamped, not raw
    assert _pow2_bucket(1000, 64) == 64


def test_admit_rejects_prompt_longer_than_max_seq(serve_setup):
    eng = _engine(serve_setup, n_slots=2, max_seq=16)
    with pytest.raises(ValueError, match="does not fit max_seq"):
        eng.admit(Request(0, np.arange(1, 20, dtype=np.int32), max_new=4))
    assert eng.pool.used == 0              # nothing rented on the way out


def test_admit_prompt_exactly_max_seq(serve_setup):
    """A full-cache prompt is admissible: the budget clamps to the one
    token the prefill argmax already produced — no decode write can land
    past the cache."""
    eng = _engine(serve_setup, n_slots=2, max_seq=16)
    r = Request(0, np.arange(1, 17, dtype=np.int32), max_new=8)
    done, _ = eng.run_to_completion([r])
    assert len(done) == 1 and len(done[0].out) == 1
    assert eng.pool.used == 0


@pytest.mark.parametrize("paged", [False, True])
def test_admit_rejects_empty_prompt(serve_setup, paged):
    """Regression: lengths[i] = 0 in the packed prefill gathered the
    'last token' from row -1 — a garbage first token.  Both layouts
    reject up front, renting nothing."""
    cfg, params = serve_setup
    kw = dict(paged=True, block_size=8, n_blocks=12) if paged else {}
    eng = ServingEngine(params, cfg, n_slots=2, max_seq=48, **kw)
    with pytest.raises(ValueError, match="empty prompt"):
        eng.admit(Request(0, np.zeros((0,), np.int32), max_new=4))
    assert eng.pool.used == 0
    # a valid batch containing one empty prompt rejects wholesale,
    # before anything is rented or prefilled
    with pytest.raises(ValueError, match="empty prompt"):
        eng.admit_many([
            Request(1, np.arange(1, 5, dtype=np.int32), max_new=4),
            Request(2, np.zeros((0,), np.int32), max_new=4)])
    assert eng.pool.used == 0


def test_run_to_completion_max_ticks_raises_not_partial(serve_setup):
    """Regression: exhausting max_ticks used to silently return only the
    finished subset — pending/active requests vanished from the report."""
    eng = _engine(serve_setup, n_slots=1, max_seq=64)
    reqs = [Request(i, np.arange(1, 6, dtype=np.int32), max_new=20)
            for i in range(3)]
    with pytest.raises(RuntimeError, match="max_ticks=.* exhausted"):
        eng.run_to_completion(reqs, max_ticks=5)
    # partial outputs stay inspectable on the Request objects
    assert len(reqs[0].out) > 0
    # a sufficient budget still completes cleanly
    eng2 = _engine(serve_setup, n_slots=1, max_seq=64)
    done, _ = eng2.run_to_completion(
        [Request(i, np.arange(1, 6, dtype=np.int32), max_new=20)
         for i in range(3)])
    assert {r.rid for r in done} == {0, 1, 2}


def test_admit_max_new_zero_completes_instantly(serve_setup):
    eng = _engine(serve_setup, n_slots=1)
    r0 = Request(0, np.arange(1, 5, dtype=np.int32), max_new=0)
    r1 = Request(1, np.arange(1, 5, dtype=np.int32), max_new=3)
    done, _ = eng.run_to_completion([r0, r1])
    out = {r.rid: r.out for r in done}
    assert out[0] == []                    # no slot spent, no tokens
    assert len(out[1]) == 3
    assert eng.pool.created_total == 1     # only rid 1 rented the slot


def test_readmit_retired_rid_is_clean(serve_setup):
    eng = _engine(serve_setup, n_slots=1)
    done1, _ = eng.run_to_completion(
        [Request(7, np.arange(1, 6, dtype=np.int32), max_new=3)])
    done2, _ = eng.run_to_completion(
        [Request(7, np.arange(1, 6, dtype=np.int32), max_new=3)])
    assert done1[0].out == done2[0].out    # same rid, same slot, same tokens
    assert eng.pool.created_total == 2 and eng.pool.used == 0


def test_admission_when_pool_exhausted_defers_not_drops(serve_setup):
    eng = _engine(serve_setup, n_slots=2)
    reqs = [Request(i, np.arange(1, 5, dtype=np.int32), max_new=3)
            for i in range(5)]
    assert eng.admit_many(reqs) == 2       # slots gate the front of the queue
    # draining also finishes the two already-admitted requests
    done, _ = eng.run_to_completion(reqs[2:])
    assert eng.pool.used == 0
    assert {r.rid for r in done} == set(range(5))


def test_prefill_writes_correct_slot(serve_setup):
    eng = _engine(serve_setup, n_slots=3)
    r = Request(0, np.arange(1, 7, dtype=np.int32), max_new=2)
    assert eng.admit(r)
    slot = r.slot
    assert int(eng.cache["pos"][slot]) == 6      # prompt length
    other = [s for s in range(3) if s != slot]
    for s in other:
        assert int(eng.cache["pos"][s]) == 0     # untouched slots
