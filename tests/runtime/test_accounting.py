"""Loop-aware cost accounting tests (the roofline's foundations)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.runtime.accounting import hlo_collectives, jaxpr_cost


def test_dot_flops_exact():
    x = jax.ShapeDtypeStruct((64, 128), jnp.float32)
    w = jax.ShapeDtypeStruct((128, 32), jnp.float32)
    c = jaxpr_cost(lambda a, b: a @ b, x, w)
    assert c["matmul_flops"] == 2 * 64 * 128 * 32


def test_scan_multiplies_flops():
    x = jax.ShapeDtypeStruct((64, 64), jnp.float32)

    def f(a):
        def body(c, _):
            return c @ c, None
        y, _ = jax.lax.scan(body, a, None, length=7)
        return y
    c = jaxpr_cost(f, x)
    assert c["matmul_flops"] == 7 * 2 * 64**3


def test_nested_scan_multiplies():
    x = jax.ShapeDtypeStruct((16, 16), jnp.float32)

    def f(a):
        def outer(c, _):
            def inner(d, _):
                return d @ d, None
            d, _ = jax.lax.scan(inner, c, None, length=3)
            return d, None
        y, _ = jax.lax.scan(outer, a, None, length=5)
        return y
    c = jaxpr_cost(f, x)
    assert c["matmul_flops"] == 15 * 2 * 16**3


def test_remat_recompute_counted():
    """grad of a checkpointed matmul chain must count the recompute."""
    x = jax.ShapeDtypeStruct((32, 32), jnp.float32)

    def loss_plain(a):
        return jnp.sum((a @ a) @ a)

    def loss_remat(a):
        return jnp.sum(jax.checkpoint(lambda t: (t @ t) @ t)(a))

    plain = jaxpr_cost(jax.grad(loss_plain), x)["matmul_flops"]
    remat = jaxpr_cost(jax.grad(loss_remat), x)["matmul_flops"]
    assert remat > plain  # fwd replayed inside the backward


def test_cond_takes_max_branch():
    x = jax.ShapeDtypeStruct((32, 32), jnp.float32)

    def f(a):
        return jax.lax.cond(a[0, 0] > 0,
                            lambda t: (t @ t) @ t,   # 2 matmuls
                            lambda t: t + 1.0, a)
    c = jaxpr_cost(f, x)
    assert c["matmul_flops"] == 2 * 2 * 32**3


SYNTHETIC_HLO = """
%wrapped_compare_computation (p0: s32[], p1: s32[]) -> pred[] {
  ROOT %lt = pred[] compare(%p0, %p1), direction=LT
}
%cond.1 (arg: (s32[], f32[8,8])) -> pred[] {
  %c = s32[] constant(12)
  ROOT %r = pred[] fusion(%gte, %c), kind=kLoop, calls=%wrapped_compare_computation
}
%body.1 (arg: (s32[], f32[8,8])) -> (s32[], f32[8,8]) {
  %ar = f32[8,8]{1,0} all-reduce(%gte2), replica_groups={}
  %ag = bf16[4,16]{1,0} all-gather(%x), dimensions={0}
}
ENTRY %main (p: f32[8,8]) -> f32[8,8] {
  %w = (s32[], f32[8,8]{1,0}) while(%t), condition=%cond.1, body=%body.1
  %top = f32[2,2]{1,0} reduce-scatter(%p), replica_groups={}
  ROOT %r = f32[8,8]{1,0} get-tuple-element(%w), index=1
}
"""


def test_hlo_while_trip_count_multiplier():
    r = hlo_collectives(SYNTHETIC_HLO)
    # all-reduce f32[8,8]=256B and all-gather bf16[4,16]=128B, ×12 trips
    assert r["bytes"]["all-reduce"] == 256 * 12
    assert r["bytes"]["all-gather"] == 128 * 12
    # entry-level reduce-scatter f32[2,2]=16B, once
    assert r["bytes"]["reduce-scatter"] == 16
    assert r["total_bytes"] == 256 * 12 + 128 * 12 + 16


def test_hlo_real_compiled_scan():
    """End-to-end: compiled psum-in-scan counts length× the collective."""
    from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
    if len(jax.devices()) < 1:
        pytest.skip("no devices")
    mesh = jax.make_mesh((1,), ("d",))

    def f(x):
        def body(c, _):
            return jax.lax.with_sharding_constraint(
                c @ c, NamedSharding(mesh, P())), None
        y, _ = jax.lax.scan(body, x, None, length=4)
        return y
    with mesh:
        txt = jax.jit(f).lower(
            jax.ShapeDtypeStruct((8, 8), jnp.float32)).compile().as_text()
    r = hlo_collectives(txt)  # no collectives on 1 device — just no crash
    assert r["total_bytes"] >= 0.0
