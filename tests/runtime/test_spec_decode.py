"""Speculative decode: drafter cores run ahead, the supervisor verifies.

The contract under test mirrors the paper's outsourcing discipline:

* the n-gram drafter (`runtime/draft.py`) proposes continuations from a
  slot's own history and degrades to an empty draft (single greedy
  step) when nothing matches — acceptance can never fall below the
  status quo;
* the speculative engine is **token-exact** vs non-speculative greedy
  decode on both cache layouts, with and without chunked prefill, for
  accepting and never-accepting models alike (greedy argmax verify ⇒
  bit-exact);
* on a drafter-friendly (repetitive) stream it emits > 1 token per
  slot-forward — the decode multiplier the whole scheme exists for;
* rewinds leave the pools clean: every chain is released at retirement
  and the block invariants hold even though rejected speculative pages
  were written and abandoned;
* the cluster supervisor lowers the spec tick with shardings and
  donation.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch, reduced
from repro.models import model
from repro.runtime import draft as draft_lib
from repro.runtime import paging
from repro.runtime.serve import Request, ServingEngine

# the shared engine-vs-oracle pieces (request generators, the
# copy-model transform, drive loop) live in tests/runtime/conftest.py:
# `serve_setup` / `serve_harness` fixtures

ENGINE_CONFIGS = [
    {},
    dict(paged=True, block_size=8, n_blocks=24),
    dict(chunked_prefill=True, prefill_chunk_tokens=4),
    dict(paged=True, block_size=8, n_blocks=24, chunked_prefill=True,
         prefill_chunk_tokens=4),
]


# ---------------------------------------------------------------------------
# drafter unit behavior
# ---------------------------------------------------------------------------

def test_propose_continues_periodic_stream():
    st = draft_lib.init_draft_state(1, 32)
    st = draft_lib.seed_slot(st, 0, np.asarray([1, 2, 3, 4] * 3, np.int32))
    # stream ...3 4 1 2 3 4 | pending 1 -> bigram (4, 1) -> 2 3 4 ...
    draft, dlen = draft_lib.propose(st, jnp.asarray([1], jnp.int32), 4)
    assert int(dlen[0]) == 4
    assert [int(t) for t in draft[0]] == [2, 3, 4, 1]


def test_propose_prefers_match_with_longest_continuation():
    st = draft_lib.init_draft_state(1, 32)
    st = draft_lib.seed_slot(st, 0, np.asarray([9, 9, 7, 7, 7, 7, 7],
                                               np.int32))
    # pending 7: the LATEST (7,7) occurrence has no room after it — the
    # drafter must pick an earlier one and draft the full constant run
    draft, dlen = draft_lib.propose(st, jnp.asarray([7], jnp.int32), 4)
    assert int(dlen[0]) >= 3
    assert all(int(t) == 7 for t in draft[0][:int(dlen[0])])


def test_propose_no_match_falls_back_to_empty_draft():
    st = draft_lib.init_draft_state(2, 16)
    st = draft_lib.seed_slot(st, 0, np.asarray([1, 2, 3, 4, 5], np.int32))
    # slot 0: bigram (5, 99) never occurred; slot 1: no history at all
    _, dlen = draft_lib.propose(st, jnp.asarray([99, 5], jnp.int32), 4)
    assert [int(d) for d in dlen] == [0, 0]


def test_push_tokens_keeps_trailing_window():
    st = draft_lib.init_draft_state(2, 6)
    st = draft_lib.push_tokens(st, jnp.asarray([[1, 2, 3, 0],
                                                [7, 0, 0, 0]], jnp.int32),
                               jnp.asarray([3, 0], jnp.int32))
    assert [int(t) for t in st.hist[0][-3:]] == [1, 2, 3]
    assert int(st.count[0]) == 3 and int(st.count[1]) == 0
    # overflow: only the trailing window survives
    st = draft_lib.push_tokens(st, jnp.asarray([[4, 5, 6, 7],
                                                [0, 0, 0, 0]], jnp.int32),
                               jnp.asarray([4, 0], jnp.int32))
    assert [int(t) for t in st.hist[0]] == [2, 3, 4, 5, 6, 7]
    assert int(st.count[0]) == 6


def test_push_and_propose_equals_push_then_propose():
    """The fused transition (the spec-chunk loop's carry) must be
    exactly push_tokens followed by propose — same history, same draft,
    same lengths — for accepting, partially-accepting and idle rows."""
    rng = np.random.default_rng(11)
    st = draft_lib.init_draft_state(3, 12)
    st = draft_lib.seed_slot(st, 0, np.asarray([5, 5, 5, 5, 5], np.int32))
    st = draft_lib.seed_slot(st, 1, rng.integers(2, 9, 10).astype(np.int32))
    tokens = jnp.asarray(rng.integers(2, 9, (3, 5)), jnp.int32)
    counts = jnp.asarray([5, 2, 0], jnp.int32)
    pending = jnp.asarray([5, 3, 0], jnp.int32)
    want_st = draft_lib.push_tokens(st, tokens, counts)
    want_draft, want_dlen = draft_lib.propose(want_st, pending, 4)
    got_st, got_draft, got_dlen = draft_lib.push_and_propose(
        st, tokens, counts, pending, 4)
    np.testing.assert_array_equal(np.asarray(got_st.hist),
                                  np.asarray(want_st.hist))
    np.testing.assert_array_equal(np.asarray(got_st.count),
                                  np.asarray(want_st.count))
    np.testing.assert_array_equal(np.asarray(got_draft),
                                  np.asarray(want_draft))
    np.testing.assert_array_equal(np.asarray(got_dlen),
                                  np.asarray(want_dlen))


def test_seed_slot_pads_to_fixed_shape():
    """seed_slot's device update is shape-stable across prompt lengths
    (one XLA computation, not one per distinct tail length) and zeroes
    the invalid region."""
    st = draft_lib.init_draft_state(1, 8)
    st = draft_lib.DraftState(hist=jnp.full((1, 8), 9, jnp.int32),
                              count=st.count)
    st = draft_lib.seed_slot(st, 0, np.asarray([3, 4, 5], np.int32))
    assert [int(t) for t in st.hist[0]] == [0, 0, 0, 0, 0, 3, 4, 5]
    assert int(st.count[0]) == 3


def test_reset_slot_disables_matching():
    st = draft_lib.init_draft_state(1, 16)
    st = draft_lib.seed_slot(st, 0, np.asarray([5, 5, 5, 5, 5], np.int32))
    _, dlen = draft_lib.propose(st, jnp.asarray([5], jnp.int32), 4)
    assert int(dlen[0]) > 0
    st = draft_lib.reset_slot(st, 0)
    _, dlen = draft_lib.propose(st, jnp.asarray([5], jnp.int32), 4)
    assert int(dlen[0]) == 0


# ---------------------------------------------------------------------------
# engine: bit-exactness on every layout, accepting or not
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kw", ENGINE_CONFIGS)
def test_spec_token_exact_random_model(serve_setup, serve_harness, kw):
    """A random model never agrees with the drafter — speculation must
    degrade to the status quo with identical tokens."""
    cfg, params = serve_setup
    base = ServingEngine(params, cfg, n_slots=3, max_seq=64, **kw)
    done_b, _ = base.run_to_completion(serve_harness.random_requests())
    spec = ServingEngine(params, cfg, n_slots=3, max_seq=64,
                         speculative=True, spec_k=4, **kw)
    done_s, _ = spec.run_to_completion(serve_harness.random_requests())
    assert {r.rid: r.out for r in done_b} == {r.rid: r.out for r in done_s}
    st = spec.spec_stats()
    assert st["tokens_per_forward"] == pytest.approx(1.0)
    assert spec.pool.used == 0
    if spec.layout is not None:
        assert spec.stalls == 0
        assert int(paging.blocks_in_use(spec.bstate)) == 0
        paging.check_invariants(spec.bstate, spec.cache["block_tables"])


@pytest.mark.parametrize("kw", ENGINE_CONFIGS)
def test_spec_token_exact_and_accepting_copy_model(serve_setup, serve_harness, kw):
    """On a repetitive stream the drafter accepts — tokens stay exact
    and each verify forward emits > 1.3 tokens per decoding slot."""
    cfg, params = serve_setup
    cp = serve_harness.copy_model(params, cfg)
    base = ServingEngine(cp, cfg, n_slots=3, max_seq=64, **kw)
    done_b, _ = base.run_to_completion(serve_harness.repetitive_requests())
    spec = ServingEngine(cp, cfg, n_slots=3, max_seq=64,
                         speculative=True, spec_k=4, **kw)
    done_s, _ = spec.run_to_completion(serve_harness.repetitive_requests())
    assert {r.rid: r.out for r in done_b} == {r.rid: r.out for r in done_s}
    st = spec.spec_stats()
    assert st["tokens_per_forward"] > 1.3, st
    assert st["acceptance_rate"] > 0.5, st
    assert spec.pool.used == 0
    if spec.layout is not None:
        assert spec.stalls == 0
        assert int(paging.blocks_in_use(spec.bstate)) == 0
        paging.check_invariants(spec.bstate, spec.cache["block_tables"])


def test_spec_eos_inside_draft_truncates_exactly(serve_setup, serve_harness):
    """A draft running past EOS must emit only through the first EOS —
    the sequential engine's retirement point."""
    cfg, params = serve_setup
    cp = serve_harness.copy_model(params, cfg)
    eos = 1
    # the copy model repeats the last prompt token: EOS itself
    req = lambda: [Request(0, np.asarray([5, 9, 1, 1, 1, 1], np.int32),  # noqa: E731
                           max_new=10)]
    base = ServingEngine(cp, cfg, n_slots=1, max_seq=32, eos_id=eos)
    done_b, _ = base.run_to_completion(req())
    spec = ServingEngine(cp, cfg, n_slots=1, max_seq=32, eos_id=eos,
                         speculative=True, spec_k=4)
    done_s, _ = spec.run_to_completion(req())
    assert done_b[0].out == done_s[0].out
    assert done_s[0].out[-1] == eos
    assert spec.pool.used == 0


@pytest.mark.parametrize("max_new", [1, 2, 3])
def test_spec_budget_edges(serve_setup, serve_harness, max_new):
    """Tight budgets: the draft clamp keeps emission within max_new and
    the KV writes inside the admission-time reservation."""
    cfg, params = serve_setup
    cp = serve_harness.copy_model(params, cfg)
    mk = lambda: [Request(0, np.asarray([5, 7, 7, 7, 7], np.int32),  # noqa: E731
                          max_new=max_new)]
    base = ServingEngine(cp, cfg, n_slots=1, max_seq=32)
    done_b, _ = base.run_to_completion(mk())
    spec = ServingEngine(cp, cfg, n_slots=1, max_seq=32,
                         speculative=True, spec_k=4)
    done_s, _ = spec.run_to_completion(mk())
    assert done_b[0].out == done_s[0].out
    assert len(done_s[0].out) == max_new


def test_spec_prompt_exactly_max_seq(serve_setup):
    """A full-cache prompt admits with budget 1 — the spec tick must not
    write a single position past the cache."""
    cfg, params = serve_setup
    mk = lambda: [Request(0, np.arange(1, 17, dtype=np.int32),  # noqa: E731
                          max_new=8)]
    base = ServingEngine(params, cfg, n_slots=1, max_seq=16)
    done_b, _ = base.run_to_completion(mk())
    spec = ServingEngine(params, cfg, n_slots=1, max_seq=16,
                         speculative=True, spec_k=4)
    done_s, _ = spec.run_to_completion(mk())
    assert done_b[0].out == done_s[0].out and len(done_s[0].out) == 1
    assert spec.pool.used == 0


def test_spec_long_prompt_mid_decode_composes_with_chunked(serve_setup, serve_harness):
    """Chunked prefill keeps outsourcing fragments inside the spec tick:
    a long prompt admitted mid-decode perturbs nothing, speculation
    keeps running for the active slots."""
    cfg, params = serve_setup
    cp = serve_harness.copy_model(params, cfg)
    short = [Request(i, np.asarray([3 + i] * 8, np.int32), max_new=14)
             for i in range(2)]

    def run(spec):
        kw = dict(speculative=True, spec_k=3) if spec else {}
        eng = ServingEngine(cp, cfg, n_slots=4, max_seq=64,
                            chunked_prefill=True, prefill_chunk_tokens=8,
                            **kw)
        assert eng.admit_many([Request(r.rid, r.prompt, max_new=r.max_new)
                               for r in short]) == 2
        eng.step()
        long_req = Request(9, np.asarray([2] * 40, np.int32), max_new=4)
        assert eng.admit(long_req)
        done = []
        while eng.active:
            done += eng.step()
        return {r.rid: r.out for r in done}, eng

    got_b, _ = run(False)
    got_s, eng_s = run(True)
    assert got_b == got_s
    assert eng_s.spec_stats()["tokens_per_forward"] > 1.0


def test_spec_rejects_unsupported_families():
    cfg_ssm = reduced(get_arch("mamba2-780m"))
    params = model.init(jax.random.PRNGKey(0), cfg_ssm, jnp.float32)
    with pytest.raises(ValueError, match="speculative"):
        ServingEngine(params, cfg_ssm, n_slots=2, max_seq=32,
                      speculative=True)


def test_spec_slot_reuse_is_clean(serve_setup, serve_harness):
    """A retired slot's history must not leak drafts into the next
    request rented onto it (seed/reset discipline)."""
    cfg, params = serve_setup
    cp = serve_harness.copy_model(params, cfg)
    eng = ServingEngine(cp, cfg, n_slots=1, max_seq=48, speculative=True,
                        spec_k=4)
    done1, _ = eng.run_to_completion(
        [Request(0, np.asarray([5, 7, 7, 7, 7], np.int32), max_new=8)])
    done2, _ = eng.run_to_completion(
        [Request(1, np.asarray([9, 3, 3, 3, 3], np.int32), max_new=8)])
    solo = ServingEngine(cp, cfg, n_slots=1, max_seq=48, speculative=True,
                         spec_k=4)
    done_s, _ = solo.run_to_completion(
        [Request(1, np.asarray([9, 3, 3, 3, 3], np.int32), max_new=8)])
    assert done2[0].out == done_s[0].out
    assert done1[0].out != done2[0].out     # different streams, really


# ---------------------------------------------------------------------------
# supervisor lowering
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("paged", [False, True])
def test_plan_serve_speculative_lowers_with_shardings(paged):
    from jax.sharding import Mesh
    from repro.configs import ShapeConfig
    from repro.runtime.supervisor import ClusterSupervisor

    cfg = reduced(get_arch("granite-3-2b"), n_layers=1, d_model=64,
                  vocab=128)
    mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1),
                ("data", "model"))
    shape = ShapeConfig("serve_tiny", 48, 4, "serve")
    sup = ClusterSupervisor(mesh, cfg, shape, dtype=jnp.float32)
    layout = model.PagedLayout(block_size=8, n_blocks=24) if paged else None
    plan = sup.plan_serve(speculative=4, paged=layout)
    assert plan.kind == "serve"
    # drafter state + cache (+ block pool) stream in place
    assert plan.donate_argnums == ((2, 3, 4) if paged else (2, 3))
    lowered = jax.jit(plan.step_fn, in_shardings=plan.in_shardings,
                      out_shardings=plan.out_shardings,
                      donate_argnums=plan.donate_argnums) \
        .lower(*plan.abstract_args)
    assert lowered.compile() is not None
