"""Property tests for the logical-axis binding (`ShardingRules.spec`).

The binding is the compile-time half of the paper's metainstruction
story, and it carries two safety invariants the rest of the stack leans
on blindly:

* **divisibility fallback** — a mesh-axis candidate that does not divide
  the dimension is skipped (the dimension replicates); a spec must never
  ask GSPMD for a non-divisible shard;
* **no axis reuse** — one physical mesh axis appears at most once per
  spec; reusing it (e.g. ``cache_kv_heads`` and ``cache_head_dim`` both
  grabbing ``model``) is rejected by JAX at jit time, deep inside a
  serving tick where the error is undiagnosable.

Both are checked here over random mesh shapes x random logical-axis
rows drawn from the real rule table — `spec` only reads ``mesh.shape``,
so a duck-typed mesh keeps the property loop off the devices.
"""
from __future__ import annotations

import types

import pytest

pytest.importorskip("hypothesis")   # real lib or the conftest fallback
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.runtime.sharding import (  # noqa: E402
    DEFAULT_RULES, ShardingRules, fleet_submeshes, serve_mesh)

AXIS_NAMES = sorted(DEFAULT_RULES)

# mesh shapes the stack actually runs: serve meshes, train pods, odd sizes
MESH_SHAPES = [
    {"data": 1, "model": 1},
    {"data": 1, "model": 2},
    {"data": 2, "model": 2},
    {"data": 2, "model": 4},
    {"data": 8, "model": 1},
    {"model": 3},
    {"pod": 2, "data": 2, "model": 2},
    {"pod": 3, "data": 2, "model": 4},
]

# dimension sizes with real divisibility texture (primes, powers of two,
# the awkward head counts from the config registry: 36, 24, 12, 7)
DIM_CHOICES = [1, 2, 3, 4, 6, 7, 8, 12, 16, 24, 30, 36, 64, 100]


def fake_mesh(shape: dict):
    """`spec` reads only ``mesh.shape`` — a namespace stands in for a
    Mesh, so the property loop never touches devices."""
    return types.SimpleNamespace(shape=dict(shape))


def _axes_of(entry) -> tuple:
    return entry if isinstance(entry, tuple) else (entry,)


def _size(mesh_shape: dict, entry) -> int:
    out = 1
    for a in _axes_of(entry):
        out *= mesh_shape[a]
    return out


@given(st.sampled_from(MESH_SHAPES),
       st.lists(st.sampled_from(AXIS_NAMES + [None]),
                min_size=1, max_size=5),
       st.integers(min_value=0, max_value=10**6))
@settings(max_examples=200, deadline=None)
def test_spec_divisibility_and_no_reuse(mesh_shape, axes, dim_seed):
    dims = [DIM_CHOICES[(dim_seed + 7 * i) % len(DIM_CHOICES)]
            for i in range(len(axes))]
    rules = ShardingRules(fake_mesh(mesh_shape))
    spec = rules.spec(axes, dims)
    assert len(spec) == len(axes)
    used = []
    for name, entry, dim in zip(axes, spec, dims):
        if name is None:
            assert entry is None    # unnamed dims never shard
        if entry is None:
            continue
        assert dim % _size(mesh_shape, entry) == 0, (axes, dims, spec)
        used += list(_axes_of(entry))
    assert len(used) == len(set(used)), (axes, dims, spec)


@given(st.sampled_from(MESH_SHAPES),
       st.lists(st.sampled_from(AXIS_NAMES + [None]),
                min_size=1, max_size=5))
@settings(max_examples=100, deadline=None)
def test_spec_without_shape_never_reuses_axes(mesh_shape, axes):
    """No `shape` means no divisibility guard — the reuse invariant must
    hold on its own."""
    spec = ShardingRules(fake_mesh(mesh_shape)).spec(axes)
    used = [a for e in spec if e is not None for a in _axes_of(e)]
    assert len(used) == len(set(used)), (axes, spec)


def test_spec_priority_gives_model_to_kv_heads_not_head_dim():
    """Regression for the paged-cache spec: ``cache_kv_heads`` and its
    fallback ``cache_head_dim`` both list ``model``; the priority table
    must hand it to the head axis and leave head_dim replicated — never
    assign one mesh axis twice in one shape."""
    rules = ShardingRules(fake_mesh({"data": 2, "model": 2}))
    axes = ("layers", "cache_batch", None, "cache_kv_heads",
            "cache_head_dim")
    spec = rules.spec(axes, (2, 4, 64, 2, 32))
    assert spec[3] == "model"
    assert spec[4] is None
    # ... and when the head count does NOT divide, the fallback axis
    # inherits the mesh axis instead (whisper-style 12-head configs on
    # an 8-way model axis would hit this with head_dim 64)
    spec = rules.spec(axes, (2, 4, 64, 3, 32))
    assert spec[3] is None
    assert spec[4] == "model"
    used = [a for e in spec if e is not None for a in _axes_of(e)]
    assert len(used) == len(set(used))


def test_serve_mesh_shape_and_insufficient_devices():
    m = serve_mesh(1)
    assert dict(m.shape) == {"data": 1, "model": 1}
    with pytest.raises(ValueError, match="xla_force_host_platform"):
        serve_mesh(4096)


def test_fleet_submeshes_split_rows():
    import jax
    if jax.device_count() < 2:
        pytest.skip("needs >= 2 devices (XLA_FLAGS host device count)")
    m = serve_mesh(1, data=2)
    subs = fleet_submeshes(m)
    assert len(subs) == 2
    assert all(dict(s.shape) == {"data": 1, "model": 1} for s in subs)
    devs = [s.devices.reshape(-1)[0] for s in subs]
    assert devs[0] != devs[1]
