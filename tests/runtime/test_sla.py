"""Priority/SLA tiers behind the async request frontier.

Four contracts:

* **token exactness** — the tiered admission controller (latency-tier
  arrivals displacing throughput-tier victims mid-decode) must not
  change a single token vs the untiered oracle, on every
  {layout} x {decode} cell.  Tier is host-side scheduling metadata
  only (the ``lint/tier-host-side`` rule proves no traced tick reads
  it), so exactness holds by construction — these cells check the
  host-side replay machinery keeps its end of the bargain.
* **tier isolation** — a latency-tier arrival never displaces another
  latency-tier slot while any throughput-tier victim exists (property
  test over randomized slot states + a behavioral check).
* **open-loop semantics** — ``submit()`` / ``step()`` / ``poll()``
  deliver every request exactly once, and the engine drains clean.
* **SLO accounting** — ``TierAccounting`` stamps TTFT on the first
  output token and attributes inter-token gaps per token even when a
  chunk emits several at one sync.
"""
from __future__ import annotations

import numpy as np
import pytest

from repro.runtime import serve as serve_lib
from repro.runtime.accounting import TierAccounting
from repro.runtime.serve import Request

N_SLOTS = 3
MAX_SEQ = 48
CHUNK = 2


def _engine_kw(layout, decode):
    kw = dict(n_slots=N_SLOTS, max_seq=MAX_SEQ, chunk=CHUNK,
              chunked_prefill=True, prefill_chunk_tokens=4)
    if layout == "paged":
        kw.update(paged=True, block_size=8, n_blocks=12, overcommit=True)
    if decode == "speculative":
        kw.update(speculative=True, spec_k=3)
    return kw


def _tiered(requests, latency_rids):
    return [Request(r.rid, r.prompt, max_new=r.max_new,
                    tier="latency" if r.rid in latency_rids
                    else "throughput")
            for r in requests]


def _drive_frontier(eng, arrivals, max_steps=2000):
    """Open-loop drive: ``arrivals`` is (step, request) pairs; each
    request is submitted at its step index (0 = before the first tick),
    the engine ticks until it drains, and completions come back through
    poll().  Returns {rid: tokens}."""
    out = {}
    steps = 0
    pending = sorted(arrivals, key=lambda kv: (kv[0], kv[1].rid))
    while pending or eng.has_work:
        while pending and pending[0][0] <= steps:
            eng.submit(pending.pop(0)[1])
        eng.step()
        for req in eng.poll():
            assert req.rid not in out, f"rid {req.rid} delivered twice"
            out[req.rid] = req.out
        steps += 1
        assert steps < max_steps, "frontier drive did not converge"
    return out


# -- open-loop frontier semantics --------------------------------------------

def test_frontier_submit_poll_token_exact(serve_setup, serve_harness):
    """All-throughput open-loop run == the closed-loop batch run: the
    frontier changes *when* requests enter, never what they decode."""
    cfg, params = serve_setup
    reqs = serve_harness.pressure_requests()
    want, _ = serve_harness.run(params, cfg, reqs,
                                **_engine_kw("contiguous", "greedy"))
    eng = serve_lib.ServingEngine(params, cfg,
                                  **_engine_kw("contiguous", "greedy"))
    arrive = [(2 * i, r) for i, r in
              enumerate(serve_harness.pressure_requests())]
    got = _drive_frontier(eng, arrive)
    assert got == want
    serve_harness.assert_drained(eng)
    rep = eng.sla.report()
    assert rep["throughput"]["n"] == len(reqs)
    assert rep["throughput"]["finished"] == len(reqs)
    assert rep["throughput"]["ttft_p99"] > 0
    # no latency-tier traffic: the tier reports empty, not absent (the
    # bench JSON schema stays stable across traces)
    assert rep["latency"]["n"] == 0
    assert rep["latency"]["ttft_p99"] is None


def test_instant_finish_delivered_through_poll(serve_setup):
    """A submitted request with no decode budget still comes back out
    of poll() exactly once, with its SLO clock closed."""
    cfg, params = serve_setup
    eng = serve_lib.ServingEngine(params, cfg,
                                  **_engine_kw("contiguous", "greedy"))
    eng.submit(Request(0, np.array([3, 4, 5], np.int32), max_new=0))
    out = _drive_frontier(eng, [])
    assert out == {0: []}
    assert eng.sla.report()["throughput"]["finished"] == 1


# -- tiered conformance cells ------------------------------------------------

TIER_CELLS = [("contiguous", "greedy"), ("contiguous", "speculative"),
              ("paged", "greedy"), ("paged", "speculative")]


@pytest.mark.parametrize("layout,decode", TIER_CELLS,
                         ids=["-".join(c) for c in TIER_CELLS])
def test_tiered_admission_token_exact(serve_setup, serve_harness, layout,
                                      decode):
    """Latency-tier arrivals land mid-decode on saturated slots, the
    controller displaces throughput-tier victims, and every request
    still decodes the oracle's exact tokens."""
    cfg, params = serve_setup
    reqs = serve_harness.pressure_requests()
    # uncontended untiered oracle: plain engine, big pool
    want, oracle_eng = serve_harness.run(
        params, cfg, serve_harness.pressure_requests(),
        n_slots=N_SLOTS, max_seq=MAX_SEQ, chunk=CHUNK)
    serve_harness.assert_drained(oracle_eng)

    eng = serve_lib.ServingEngine(params, cfg, **_engine_kw(layout, decode))
    tiered = _tiered(serve_harness.pressure_requests(),
                     latency_rids={3, 5})
    # throughput burst up front saturates the slots; the latency pair
    # arrives mid-decode and must displace its way in
    arrive = [(0 if r.tier != "latency" else 4, r) for r in tiered]
    got = _drive_frontier(eng, arrive)

    assert got == want, (layout, decode)
    assert eng.displacements >= 1          # the controller really fired
    assert eng.preempt_replay_mismatches == 0
    serve_harness.assert_drained(eng)


# -- tier isolation property -------------------------------------------------

def _bare_engine(active_tiers, parked_tiers):
    """A victim-policy harness: just the four attrs the picker reads."""
    eng = object.__new__(serve_lib.ServingEngine)
    eng.active, eng._parked = {}, {}
    eng._park_order, eng._slot_seq = [], {}
    slot = 0
    for tier in active_tiers:
        eng.active[slot] = Request(slot, np.array([1], np.int32),
                                   tier=tier)
        eng._slot_seq[slot] = slot
        slot += 1
    for tier in parked_tiers:
        eng._parked[slot] = Request(slot, np.array([1], np.int32),
                                    tier=tier)
        eng._park_order.append(slot)
        slot += 1
    return eng


def test_latency_never_displaces_latency_property():
    """Over randomized slot states: the picked victim is never
    latency-tier, and None only when every candidate is latency-tier.
    Repeated displacement drains *all* throughput victims before the
    picker gives up."""
    rng = np.random.default_rng(11)
    for _ in range(200):
        tiers = lambda n: [("latency", "throughput")[rng.integers(2)]
                           for _ in range(n)]
        eng = _bare_engine(tiers(int(rng.integers(0, 4))),
                           tiers(int(rng.integers(0, 4))))
        victims = []
        while True:
            slot = eng._pick_displacement_victim()
            if slot is None:
                break
            victim = eng._parked.pop(slot) if slot in eng._parked \
                else eng.active.pop(slot)
            if slot in eng._park_order:
                eng._park_order.remove(slot)
            victims.append(victim)
        assert all(v.tier == "throughput" for v in victims)
        # nothing but latency-tier requests survive the drain
        left = list(eng.active.values()) + list(eng._parked.values())
        assert all(r.tier == "latency" for r in left)


def test_all_latency_slots_queue_instead_of_displacing(serve_setup,
                                                       serve_harness):
    """Behavioral check on a real engine: with every slot held by
    latency-tier requests, a new latency arrival waits its turn — no
    displacement, no preemption, and still token-exact."""
    cfg, params = serve_setup
    reqs = serve_harness.pressure_requests(4)
    want, _ = serve_harness.run(params, cfg,
                                serve_harness.pressure_requests(4),
                                n_slots=N_SLOTS, max_seq=MAX_SEQ,
                                chunk=CHUNK)
    eng = serve_lib.ServingEngine(params, cfg,
                                  **_engine_kw("contiguous", "greedy"))
    tiered = _tiered(reqs, latency_rids={r.rid for r in reqs})
    arrive = [(0 if r.rid < 3 else 2, r) for r in tiered]
    got = _drive_frontier(eng, arrive)
    assert got == want
    assert eng.displacements == 0
    assert eng.preemptions == 0
    serve_harness.assert_drained(eng)


# -- SLO accounting ----------------------------------------------------------

def test_tier_accounting_ttft_and_gap_attribution():
    acc = TierAccounting()
    acc.arrive(1, "latency", now=10.0)
    acc.arrive(2, "throughput", now=10.0)
    # rid 1: first token at t=10.5 -> TTFT 0.5; then 2 tokens in one
    # 1.0s chunk -> two 0.5s gaps
    acc.observe(1, 1, now=10.5)
    acc.observe(1, 3, now=11.5)
    acc.finish(1)
    # rid 2: 3 tokens all at the first sync — TTFT 2.0, the remaining
    # two tokens split the same instant (0.0 gaps)
    acc.observe(2, 3, now=12.0)
    rep = acc.report()
    assert rep["latency"]["ttft_p99"] == pytest.approx(0.5)
    assert rep["latency"]["inter_token_p50"] == pytest.approx(0.5)
    assert rep["latency"]["finished"] == 1
    assert rep["throughput"]["ttft_p99"] == pytest.approx(2.0)
    assert rep["throughput"]["inter_token_p99"] == pytest.approx(0.0)
    assert rep["throughput"]["finished"] == 0


def test_tier_accounting_rejects_unknown_tier():
    with pytest.raises(ValueError, match="tier"):
        TierAccounting().arrive(1, "platinum")


def test_no_growth_observation_is_free():
    acc = TierAccounting()
    acc.arrive(1, "latency", now=0.0)
    acc.observe(1, 0, now=5.0)          # no tokens yet: no TTFT stamp
    acc.observe(1, 1, now=7.0)
    acc.observe(1, 1, now=9.0)          # repeat n_out: no gap recorded
    rep = acc.report()
    assert rep["latency"]["ttft_p99"] == pytest.approx(7.0)
    assert rep["latency"]["inter_token_p99"] is None
