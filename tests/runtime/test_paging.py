"""Paged KV cache: pool generalization, block transitions, and the
token-exactness of paged serving vs the contiguous cache.

The block pool is the paper's rent/release discipline (§4.1.3, §4.3)
applied to KV blocks: the same pure `runtime/pool` transitions, one
level down from slots.  The contract under test:

* `rent_many`/`release_many` == a loop of single-unit transitions;
* chains grow exactly at block boundaries, on device, and release
  returns refcount-zero blocks only;
* paged decode is bit-exact vs the contiguous cache, at the model level
  and through the full continuous-batching engine (including shared
  prompt prefixes and admission deferral under block pressure).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch, reduced
from repro.models import model
from repro.runtime import paging
from repro.runtime import pool as pool_lib
from repro.runtime.serve import Request, ServingEngine


def _cfg(**kw):
    kw = {"n_layers": 1, "d_model": 64, "vocab": 128, **kw}
    return reduced(get_arch("granite-3-2b"), **kw)


def _params(cfg):
    return model.init(jax.random.PRNGKey(0), cfg, jnp.float32)


# ---------------------------------------------------------------------------
# pool generalization: vectorized transitions over arbitrary counts
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n,pattern", [
    (5, [True] * 3),
    (7, [True, False, True, True, False, True]),
    (3, [True] * 6),               # over-ask: pool runs dry mid-grant
])
def test_rent_many_matches_sequential_rents(n, pattern):
    state_v = pool_lib.init_pool(n)
    state_s = pool_lib.init_pool(n)
    state_v, units = pool_lib.rent_many(state_v, jnp.asarray(pattern))
    got = [int(u) for u in units]
    want = []
    for need in pattern:
        if not need:
            want.append(-1)
            continue
        state_s, u = pool_lib.rent(state_s)
        want.append(int(u))
    assert got == want
    for a, b in zip(state_v, state_s):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    pool_lib.check_invariants(state_v)


def test_rent_many_skips_disabled_units():
    state = pool_lib.disable(pool_lib.init_pool(4), 1)
    state, units = pool_lib.rent_many(state, jnp.ones((4,), bool))
    assert [int(u) for u in units] == [0, 2, 3, -1]


def test_release_many_blocks_parents_with_live_children():
    state = pool_lib.init_pool(4)
    state, p = pool_lib.rent(state)
    state, c = pool_lib.rent(state, parent=p)
    # parent alone: blocked (live child not in the release set)
    s2 = pool_lib.release_many(state, jnp.asarray([True, False, False,
                                                   False]))
    assert not bool(s2.free[int(p)])
    # parent + child together: both released
    s3 = pool_lib.release_many(state, jnp.asarray([True, True, False,
                                                   False]))
    assert bool(s3.free[int(p)]) and bool(s3.free[int(c)])
    pool_lib.check_invariants(s3)


def test_core_pool_rent_many_wrapper():
    from repro.core.supervisor import CorePool
    pool = CorePool(6)
    assert pool.rent_many(4) == [0, 1, 2, 3]
    assert pool.created_total == 4 and pool.used == 4
    assert pool.rent_many(5) == [4, 5]    # grants what the pool has


# ---------------------------------------------------------------------------
# block-pool transitions
# ---------------------------------------------------------------------------

def test_grow_rents_exactly_at_block_boundary():
    bs = 8
    bstate = paging.init_blocks(6)
    tables = paging.init_block_tables(2, 4)
    # slot 0 owns one block (positions 0..7); slot 1 inactive
    bstate = paging.admit_chains(bstate, jnp.asarray([0, -1]),
                                 jnp.asarray([0]))
    tables = tables.at[0, 0].set(0)
    active = jnp.asarray([True, False])
    # pos 7 still inside the block: no growth
    b2, t2, stalled = paging.grow_for_decode(
        bstate, tables, jnp.asarray([7, 0]), active, block_size=bs)
    np.testing.assert_array_equal(np.asarray(t2), np.asarray(tables))
    assert not bool(jnp.any(stalled))
    # pos 8 crosses: slot 0 rents exactly one block, refcount 1
    b3, t3, stalled = paging.grow_for_decode(
        bstate, tables, jnp.asarray([8, 0]), active, block_size=bs)
    assert int(t3[0, 1]) == 1 and int(t3[1, 0]) == -1
    assert int(b3.refcount[1]) == 1 and not bool(b3.pool.free[1])
    assert not bool(jnp.any(stalled))
    paging.check_invariants(b3, t3)


def test_grow_exhaustion_stalls_not_corrupts():
    bstate = paging.init_blocks(1)
    tables = paging.init_block_tables(1, 2)
    bstate = paging.admit_chains(bstate, jnp.asarray([0]), jnp.asarray([0]))
    tables = tables.at[0, 0].set(0)
    b2, t2, stalled = paging.grow_for_decode(
        bstate, tables, jnp.asarray([8]), jnp.asarray([True]), block_size=8)
    assert bool(stalled[0])
    assert int(t2[0, 1]) == -1            # chain unchanged: nothing granted


def test_grow_to_cover_rents_across_multiple_boundaries():
    """A speculative verify fragment can cross several block boundaries
    in one tick: grow_to_cover rents exactly the deficit, appended in
    chain order."""
    bs = 4
    bstate = paging.init_blocks(8)
    tables = paging.init_block_tables(2, 6)
    bstate = paging.admit_chains(bstate, jnp.asarray([0]), jnp.asarray([0]))
    tables = tables.at[0, 0].set(0)
    # slot 0 writes through position 10 (blocks 0..2): needs 2 more
    b2, t2, stalled = paging.grow_to_cover(
        bstate, tables, jnp.asarray([10, 0]), jnp.asarray([True, False]),
        block_size=bs, max_rounds=3)
    assert not bool(jnp.any(stalled))
    chain = [int(x) for x in t2[0] if int(x) >= 0]
    assert len(chain) == 3 and chain[0] == 0
    assert int(t2[1, 0]) == -1                  # inactive slot untouched
    paging.check_invariants(b2, t2)
    # insufficient rounds: target uncovered -> stalled, never corrupted
    _, _, stalled = paging.grow_to_cover(
        bstate, tables, jnp.asarray([10, 0]), jnp.asarray([True, False]),
        block_size=bs, max_rounds=1)
    assert bool(stalled[0])


pytest.importorskip("hypothesis")   # real lib or the conftest fallback
from hypothesis import given, settings, strategies as st  # noqa: E402


@settings(max_examples=20, deadline=None)
@given(st.lists(st.integers(0, 10**6), min_size=1, max_size=40),
       st.integers(0, 10**6))
def test_block_pool_invariants_across_spec_cycles(ops, seed):
    """Refcount/free-count invariants hold across speculative
    accept/reject/rewind + retire cycles.

    The speculative tick's life cycle against the block pool: admit a
    chain, overshoot it with grow_to_cover (the verify fragment's write
    span), *accept* a random prefix (pos advances part way — a rewind
    leaves the overshoot blocks rented but dead), decode-grow, retire.
    After every transition the device refcounts, the free mask and the
    tables must agree exactly, and the pool counters must stay
    monotone (`pool.check_invariants` runs inside
    `paging.check_invariants`)."""
    rng = np.random.default_rng(seed % (2**32))
    n_blocks, n_slots, bs, max_blocks = 10, 3, 4, 5
    bstate = paging.init_blocks(n_blocks)
    tables = paging.init_block_tables(n_slots, max_blocks)
    pos = np.zeros(n_slots, np.int64)        # next write position
    live = [False] * n_slots

    for v in ops:
        op = v % 4
        slot = v % n_slots
        if op == 0 and not live[slot]:            # admit a 1-block chain
            free = np.flatnonzero(np.asarray(bstate.pool.free))
            if len(free) == 0:
                continue
            blk = jnp.asarray([int(free[0])])
            bstate = paging.admit_chains(bstate, blk, blk)
            tables = tables.at[slot, 0].set(int(free[0]))
            pos[slot] = int(rng.integers(0, bs))
            live[slot] = True
        elif op == 1 and live[slot]:              # speculative overshoot
            overshoot = int(rng.integers(0, 6))
            target = min(pos[slot] + overshoot, max_blocks * bs - 1)
            bstate, tables, stalled = paging.grow_to_cover(
                bstate, tables, jnp.asarray([target if s == slot else 0
                                             for s in range(n_slots)]),
                jnp.asarray([s == slot for s in range(n_slots)]),
                block_size=bs, max_rounds=overshoot // bs + 1)
            if not bool(stalled[slot]):
                # accept a random prefix; the rest is the rewind — the
                # overshoot blocks stay rented (dead) until retirement
                pos[slot] = int(rng.integers(pos[slot], target + 1))
        elif op == 2 and live[slot]:              # retire: release chain
            bstate, tables = paging.release_chain(bstate, tables, slot)
            live[slot] = False
            pos[slot] = 0
        elif op == 3 and live[slot]:              # plain decode growth
            if pos[slot] < max_blocks * bs - 1:
                bstate, tables, stalled = paging.grow_for_decode(
                    bstate, tables, jnp.asarray([pos[slot]] * n_slots),
                    jnp.asarray([s == slot for s in range(n_slots)]),
                    block_size=bs)
                if not bool(stalled[slot]):
                    pos[slot] += 1
        paging.check_invariants(bstate, tables)
        # conservation: rented blocks == blocks referenced by tables
        t = np.asarray(tables)
        assert int(np.sum(~np.asarray(bstate.pool.free))) == \
            int(np.sum(t >= 0))

    # drain everything: the pool must come back whole
    for slot in range(n_slots):
        if live[slot]:
            bstate, tables = paging.release_chain(bstate, tables, slot)
    paging.check_invariants(bstate, tables)
    assert int(paging.blocks_in_use(bstate)) == 0


def test_evict_chain_shared_prefix_survives():
    """Preemption claws back only what no other chain references: the
    victim's private blocks free, the shared prefix block keeps its
    rent, and `n_freed` reports exactly the relieved pressure."""
    bstate = paging.init_blocks(4)
    # chains: slot0 = [0, 1], slot1 = [0, 2]; block 0 shared (ref 2)
    bstate = paging.admit_chains(bstate, jnp.asarray([0, 1, 0, 2]),
                                 jnp.asarray([0, 1, 2]))
    tables = jnp.asarray([[0, 1], [0, 2]], jnp.int32)
    bstate, tables, n_freed = paging.evict_chain(bstate, tables, 0)
    assert int(n_freed) == 1                     # block 1 only
    assert [int(x) for x in bstate.refcount] == [1, 0, 1, 0]
    free = np.asarray(bstate.pool.free)
    assert not free[0] and free[1] and not free[2]
    assert [int(x) for x in tables[0]] == [-1, -1]
    paging.check_invariants(bstate, tables)
    # evicting the survivor frees everything, shared block included
    bstate, tables, n_freed = paging.evict_chain(bstate, tables, 1)
    assert int(n_freed) == 2
    assert int(paging.blocks_in_use(bstate)) == 0
    paging.check_invariants(bstate, tables)


@settings(max_examples=15, deadline=None)
@given(st.lists(st.integers(0, 10**6), min_size=1, max_size=40),
       st.integers(0, 10**6))
def test_block_pool_invariants_across_evict_resume_cycles(ops, seed):
    """Random admit/evict/resume/grow/retire schedules preserve the
    BlockPoolState invariants (refcount/free-mask/table agreement,
    ``used <= peak_used <= created_total`` via the pool invariants) and
    never free a shared prefix block a live chain still references.

    Every admitted chain starts from one common prefix block — the
    over-commit engine's sharing shape — so evictions constantly race
    retirements for the last reference."""
    rng = np.random.default_rng(seed % (2**32))
    n_blocks, n_slots, bs, max_blocks = 10, 3, 4, 4
    bstate = paging.init_blocks(n_blocks)
    tables = paging.init_block_tables(n_slots, max_blocks)
    pos = np.zeros(n_slots, np.int64)
    state = ["idle"] * n_slots          # idle | live | parked
    shared_blk = None                   # the common prefix block

    def admit(slot):
        nonlocal bstate, tables, shared_blk
        if shared_blk is None or int(bstate.refcount[shared_blk]) == 0:
            free = np.flatnonzero(np.asarray(bstate.pool.free))
            if len(free) == 0:
                return False
            shared_blk = int(free[0])
            new = jnp.asarray([shared_blk], jnp.int32)
        else:
            new = jnp.zeros((0,), jnp.int32)
        bstate = paging.admit_chains(
            bstate, jnp.asarray([shared_blk], jnp.int32), new)
        tables = tables.at[slot, 0].set(shared_blk)
        pos[slot] = int(rng.integers(0, bs))
        return True

    for v in ops:
        op = v % 5
        slot = v % n_slots
        if op == 0 and state[slot] == "idle":
            if admit(slot):
                state[slot] = "live"
        elif op == 1 and state[slot] == "live":       # preempt: evict
            others = {s: [int(x) for x in np.asarray(tables[s]) if x >= 0]
                      for s in range(n_slots)
                      if s != slot and state[s] == "live"}
            used_before = int(paging.blocks_in_use(bstate))
            bstate, tables, n_freed = paging.evict_chain(bstate, tables,
                                                         slot)
            assert int(paging.blocks_in_use(bstate)) == \
                used_before - int(n_freed)
            for chain in others.values():             # no double-free
                for b in chain:
                    assert not bool(bstate.pool.free[b]), \
                        "evict freed a block a live chain references"
            state[slot] = "parked"
            pos[slot] = 0
        elif op == 2 and state[slot] == "parked":     # resume: re-admit
            if admit(slot):
                state[slot] = "live"
        elif op == 3 and state[slot] == "live":       # decode growth
            if pos[slot] < max_blocks * bs - 1:
                bstate, tables, stalled = paging.grow_for_decode(
                    bstate, tables, jnp.asarray([pos[slot]] * n_slots),
                    jnp.asarray([s == slot for s in range(n_slots)]),
                    block_size=bs)
                if not bool(stalled[slot]):
                    pos[slot] += 1
        elif op == 4 and state[slot] == "live":       # retire
            bstate, tables = paging.release_chain(bstate, tables, slot)
            state[slot] = "idle"
            pos[slot] = 0
        paging.check_invariants(bstate, tables)

    for slot in range(n_slots):
        if state[slot] == "live":
            bstate, tables = paging.release_chain(bstate, tables, slot)
    paging.check_invariants(bstate, tables)
    assert int(paging.blocks_in_use(bstate)) == 0


def test_release_chain_respects_shared_refcounts():
    bstate = paging.init_blocks(4)
    tables = paging.init_block_tables(2, 2)
    # chains: slot0 = [0, 1], slot1 = [0, 2]; block 0 shared (ref 2)
    bstate = paging.admit_chains(bstate, jnp.asarray([0, 1, 0, 2]),
                                 jnp.asarray([0, 1, 2]))
    tables = jnp.asarray([[0, 1], [0, 2]], jnp.int32)
    paging.check_invariants(bstate, tables)
    bstate, tables = paging.release_chain(bstate, tables, 0)
    assert [int(x) for x in bstate.refcount] == [1, 0, 1, 0]
    free = np.asarray(bstate.pool.free)
    assert not free[0] and free[1] and not free[2]   # shared block survives
    bstate, tables = paging.release_chain(bstate, tables, 1)
    assert int(paging.blocks_in_use(bstate)) == 0
    paging.check_invariants(bstate, tables)


# ---------------------------------------------------------------------------
# model-level parity: one cache API, two layouts, identical tokens
# ---------------------------------------------------------------------------

def test_paged_prefill_decode_matches_contiguous():
    cfg = _cfg(n_layers=2)
    params = _params(cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (3, 7), 1, cfg.vocab)
    lengths = jnp.asarray([7, 4, 6], jnp.int32)
    batch = {"tokens": toks}
    lc, cc = model.prefill(params, batch, cfg, 32, lengths=lengths)
    layout = model.PagedLayout(block_size=8, n_blocks=16)
    lp, pc = model.prefill(params, batch, cfg, 32, lengths=lengths,
                           layout=layout)
    np.testing.assert_array_equal(np.asarray(lc), np.asarray(lp))
    tok = jnp.argmax(lc, -1).astype(jnp.int32)
    for _ in range(12):    # crosses block boundaries at pos 8 and 16
        lc, cc = model.decode_step(params, tok, cc, cfg)
        lp, pc = model.decode_step(params, tok, pc, cfg)
        np.testing.assert_array_equal(np.asarray(lc), np.asarray(lp))
        tok = jnp.argmax(lc, -1).astype(jnp.int32)


def test_paged_layout_rejects_recurrent_families():
    cfg = reduced(get_arch("mamba2-780m"))
    with pytest.raises(ValueError):
        model.init_cache(cfg, 2, 32, layout=model.PagedLayout(8, 8))


# ---------------------------------------------------------------------------
# engine parity: full continuous batching, paged vs contiguous
# ---------------------------------------------------------------------------

def _requests(seed=0, n=8):
    rng = np.random.default_rng(seed)
    return [Request(i, rng.integers(1, 100,
                                    size=int(rng.integers(4, 12)))
                    .astype(np.int32),
                    max_new=int(rng.integers(4, 12))) for i in range(n)]


def test_paged_engine_token_exact_vs_contiguous():
    cfg = _cfg()
    params = _params(cfg)
    e_c = ServingEngine(params, cfg, n_slots=3, max_seq=48)
    done_c, _ = e_c.run_to_completion(_requests())
    e_p = ServingEngine(params, cfg, n_slots=3, max_seq=48, paged=True,
                        block_size=8, n_blocks=12)
    done_p, _ = e_p.run_to_completion(_requests())
    assert {r.rid: r.out for r in done_c} == {r.rid: r.out for r in done_p}
    assert e_p.stalls == 0
    # every chain returned, invariants hold, KV strictly cheaper
    assert e_p.pool.used == 0
    assert int(paging.blocks_in_use(e_p.bstate)) == 0
    paging.check_invariants(e_p.bstate, e_p.cache["block_tables"])
    assert e_p.kv_stats()["kv_bytes_per_token"] < \
        e_c.kv_stats()["kv_bytes_per_token"]


def test_shared_prefix_blocks_are_rented_once():
    cfg = _cfg()
    params = _params(cfg)
    base = np.arange(1, 17, dtype=np.int32)          # two full 8-blocks
    reqs = [Request(0, base, max_new=6),
            Request(1, base.copy(), max_new=6),
            Request(2, np.concatenate([base, [77, 78]]).astype(np.int32),
                    max_new=6)]
    eng = ServingEngine(params, cfg, n_slots=4, max_seq=48, paged=True,
                        block_size=8, n_blocks=16)
    done, _ = eng.run_to_completion(reqs)
    assert len(done) == 3
    assert eng.shared_block_hits == 4       # 2 blocks × 2 sharing chains
    # outputs must equal the unshared run
    solo = ServingEngine(params, cfg, n_slots=4, max_seq=48, paged=True,
                         block_size=8, n_blocks=16, prefix_sharing=False)
    done_s, _ = solo.run_to_completion(
        [Request(r.rid, r.prompt, max_new=6) for r in reqs])
    assert {r.rid: r.out for r in done} == {r.rid: r.out for r in done_s}
    assert solo.shared_block_hits == 0
    paging.check_invariants(eng.bstate, eng.cache["block_tables"])


def test_block_pressure_defers_admission():
    """Two 2-block requests over a 3-block pool: the §5.1 reservation
    serializes them instead of letting decode growth starve."""
    cfg = _cfg()
    params = _params(cfg)
    eng = ServingEngine(params, cfg, n_slots=2, max_seq=16, paged=True,
                        block_size=8, n_blocks=3, prefix_sharing=False)
    done, _ = eng.run_to_completion([
        Request(0, np.arange(1, 10, dtype=np.int32), max_new=3),
        Request(1, np.arange(2, 11, dtype=np.int32), max_new=3)])
    assert {r.rid for r in done} == {0, 1}
    assert eng.stalls == 0
    assert int(paging.blocks_in_use(eng.bstate)) == 0


def test_impossible_request_raises_instead_of_hanging():
    """The stuck-pool error reports per-request block demand vs pool
    capacity — a bare stuck-request count made over-commit failures
    (and any undersized pool) undiagnosable."""
    cfg = _cfg()
    params = _params(cfg)
    eng = ServingEngine(params, cfg, n_slots=2, max_seq=16, paged=True,
                        block_size=8, n_blocks=1)
    with pytest.raises(RuntimeError, match="stuck") as ei:
        eng.run_to_completion(
            [Request(0, np.arange(1, 11, dtype=np.int32), max_new=2)])
    msg = str(ei.value)
    assert "rid 0" in msg
    assert "needs 2 blocks now, 2 worst-case, vs 1 total" in msg
    assert "block pool: 1 blocks of 8 positions" in msg


def test_impossible_request_diagnosed_under_overcommit():
    """Over-commit defers (never thrash-admits) a request whose worst
    case exceeds the whole pool, and the stuck report names the
    admission mode and the demand."""
    cfg = _cfg()
    params = _params(cfg)
    eng = ServingEngine(params, cfg, n_slots=2, max_seq=48, paged=True,
                        block_size=8, n_blocks=2, overcommit=True)
    with pytest.raises(RuntimeError, match="stuck") as ei:
        eng.run_to_completion(
            [Request(0, np.arange(1, 20, dtype=np.int32), max_new=16)])
    msg = str(ei.value)
    assert "admission=overcommit" in msg
    assert "worst-case" in msg and "vs 2 total" in msg
    assert eng.pool.used == 0         # nothing left half-admitted


def test_plan_serve_paged_lowers_with_shardings():
    """ClusterSupervisor lowers the paged serve tick: pages + tables +
    donated block-pool state, all with explicit shardings."""
    from jax.sharding import Mesh
    from repro.configs import ShapeConfig
    from repro.runtime.supervisor import ClusterSupervisor

    cfg = _cfg()
    mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1),
                ("data", "model"))
    shape = ShapeConfig("serve_tiny", 48, 4, "serve")
    sup = ClusterSupervisor(mesh, cfg, shape, dtype=jnp.float32)
    plan = sup.plan_serve(paged=model.PagedLayout(block_size=8,
                                                  n_blocks=24))
    assert plan.kind == "serve"
    assert plan.donate_argnums == (2, 3)   # cache AND block pool donated
    lowered = jax.jit(plan.step_fn, in_shardings=plan.in_shardings,
                      out_shardings=plan.out_shardings,
                      donate_argnums=plan.donate_argnums) \
        .lower(*plan.abstract_args)
    assert lowered.compile() is not None
