"""FleetSupervisor: the data-parallel router over serving replicas.

The fleet is the paper's hierarchy applied one level up — each
`ServingEngine` is a supervisor over its slot/block cores; the fleet
owns the ``data`` axis and routes requests.  Three contracts:

* **token exactness** — which replica serves a request must not change
  a token (each replica runs the same greedy program, so this reduces
  to per-engine exactness — asserted against the single-engine oracle);
* **preemption-aware routing** — parked requests and pool pressure
  push new work to other replicas first; ties round-robin;
* **honest accounting** — fleet stats are sums over per-replica (and,
  inside a replica, per-shard) ledgers, never a mean of ratios.

Replicas here share one CPU device (model=1 submeshes may overlap when
there is nothing to shard) so the whole file runs in the tier-1 suite;
the tensor-parallel fleet cells skip below 4 devices and run in CI's
multi-device step.
"""
from __future__ import annotations

import jax
import pytest

from repro.runtime.supervisor import FleetSupervisor

N_SLOTS = 3
MAX_SEQ = 48
CHUNK = 4


def _kw(paged):
    kw = dict(n_slots=N_SLOTS, max_seq=MAX_SEQ, chunk=CHUNK)
    if paged:
        kw.update(paged=True, block_size=8, n_blocks=20)
    return kw


def _oracle(serve_setup, serve_harness, paged):
    cfg, params = serve_setup
    outputs, eng = serve_harness.run(
        params, cfg, serve_harness.pressure_requests(), **_kw(paged))
    return outputs, eng


def _run_fleet(fleet, requests):
    done, _ = fleet.run_to_completion(requests)
    return {r.rid: r.out for r in done}


@pytest.mark.parametrize("paged", [False, True], ids=["contig", "paged"])
def test_fleet_token_exact_vs_single_engine(serve_setup, serve_harness,
                                            paged):
    cfg, params = serve_setup
    want, _ = _oracle(serve_setup, serve_harness, paged)
    fleet = FleetSupervisor(params, cfg, n_replicas=2, model=1,
                            devices=jax.devices()[:1], **_kw(paged))
    got = _run_fleet(fleet, serve_harness.pressure_requests())
    assert got == want
    assert all(n > 0 for n in fleet.routed)     # both replicas served
    for e in fleet.engines:
        serve_harness.assert_drained(e)


def test_routing_is_preemption_aware_and_round_robin(serve_setup,
                                                     serve_harness):
    cfg, params = serve_setup
    fleet = FleetSupervisor(params, cfg, n_replicas=3, model=1,
                            devices=jax.devices()[:1], **_kw(True))
    # equal loads: stable tie-break -> replica 0, then least-routed
    assert fleet.route_order()[0] == 0
    fleet.routed[0] += 1
    assert fleet.route_order()[0] == 1
    # pool pressure demotes a replica even if it has the most blocks
    fleet.engines[1]._pressure = True
    assert fleet.route_order()[-1] == 1
    # a parked (preempted) request demotes too: its re-admission holds
    # a claim on blocks the ledger calls free
    fleet.engines[0]._parked[0] = object()
    order = fleet.route_order()
    assert order[0] == 2 and set(order[1:]) == {0, 1}
    # a precomputed ledger sweep routes identically to a fresh one (the
    # admit-drain fast path must not change any decision)
    loads = [e.load() for e in fleet.engines]
    assert fleet.route_order(loads=loads) == fleet.route_order()
    # latency-tier routing ignores the parked/pressure penalty: with
    # equal capacity everywhere only the round-robin count orders the
    # replicas (0 routed once already, so it goes last)
    assert fleet.route_order(tier="latency") == [1, 2, 0]
    fleet.engines[0]._parked.clear()
    fleet.engines[1]._pressure = False
    # no free slots demotes below a replica with capacity
    fleet.engines[2].pool.rent_many(N_SLOTS)
    assert fleet.route_order()[-1] == 2
    assert fleet.route_order(tier="latency")[-1] == 2


def test_admit_drain_sweeps_ledgers_once(serve_setup, serve_harness):
    """Satellite contract: one ``load()`` sweep per drain plus one
    refresh per admission — not a full sweep per admitted request —
    with the routing decisions unchanged (round-robin under equal
    load)."""
    cfg, params = serve_setup
    fleet = FleetSupervisor(params, cfg, n_replicas=2, model=1,
                            devices=jax.devices()[:1], **_kw(True))
    calls = {"n": 0}
    for e in fleet.engines:
        orig = e.load

        def counting(orig=orig):
            calls["n"] += 1
            return orig()

        e.load = counting
    pending = serve_harness.pressure_requests(4)
    n = fleet.admit_many(pending)
    assert n == 4
    # <= one sweep + one per-admission refresh (the pre-fix O(pending x
    # replicas) drain would have paid >= 8 here before the final sweep)
    assert calls["n"] <= len(fleet.engines) + n
    # equal loads round-robin across the replicas exactly as before
    assert fleet.routed == [2, 2]
    assert sum(len(e.active) for e in fleet.engines) == 4


def test_fleet_stats_sum_per_replica_ledgers(serve_setup, serve_harness):
    """Satellite contract: fleet-wide AND per-replica numbers, the
    fleet-wide ones sums over disjoint pools (slot AND block), byte
    totals conserved vs the single-engine run of the same stream."""
    cfg, params = serve_setup
    _, oracle_eng = _oracle(serve_setup, serve_harness, paged=True)
    fleet = FleetSupervisor(params, cfg, n_replicas=2, model=1,
                            devices=jax.devices()[:1], **_kw(True))
    _run_fleet(fleet, serve_harness.pressure_requests())

    ks = fleet.kv_stats()
    assert ks["fleet"]["n_replicas"] == 2
    assert len(ks["per_replica"]) == 2
    for key in ("kv_bytes_allocated", "tokens_finished"):
        assert ks["fleet"][key] == sum(p[key] for p in ks["per_replica"])
        # same requests, no evictions -> same chains, same totals as the
        # single engine (which replica rented the blocks cannot matter)
        assert ks["fleet"][key] == oracle_eng.kv_stats()[key]
    assert ks["fleet"]["n_blocks"] == 40        # 2 disjoint 20-block pools
    assert ks["fleet"]["in_use"] == 0           # drained
    assert ks["fleet"]["slot_pool"]["n_units"] == 2 * N_SLOTS
    assert ks["fleet"]["slot_pool"]["created_total"] == \
        sum(p_eng.pool.created_total for p_eng in fleet.engines)

    occ = fleet.occupancy_stats()
    # slot-tick weighted, not a mean of ratios
    num = sum(p["slot_ticks"] for p in occ["per_replica"])
    den = sum(p["ticks"] * p["n_slots"] for p in occ["per_replica"])
    assert occ["fleet"]["occupancy"] == pytest.approx(num / den)

    ss = fleet.sync_stats()
    assert ss["fleet"]["host_syncs"] == \
        sum(p["host_syncs"] for p in ss["per_replica"])
    assert ss["fleet"]["sync_reduction_x"] > 1


def test_engine_per_shard_kv_fields_unsharded():
    """On one shard the per-shard view IS the global view — the fields
    must agree exactly (the sharded case is covered by the mesh
    conformance cells)."""
    import jax.numpy as jnp

    from repro.configs import get_arch, reduced
    from repro.models import model
    from repro.runtime.serve import ServingEngine
    cfg = reduced(get_arch("granite-3-2b"), n_layers=1, d_model=64,
                  vocab=128)
    params = model.init(jax.random.PRNGKey(0), cfg, jnp.float32)
    eng = ServingEngine(params, cfg, n_slots=2, max_seq=32,
                        paged=True, block_size=8, n_blocks=12)
    ks = eng.kv_stats()
    assert ks["model_shards"] == 1
    assert ks["kv_shard_fraction"] == 1.0
    assert ks["block_bytes_per_shard"] > 0


def test_fleet_of_tensor_parallel_replicas_token_exact(serve_setup,
                                                       serve_harness):
    """The full (data, model) grid: 2 replicas x 2-way tensor parallel,
    still byte-identical to the single-device single-engine oracle."""
    if jax.device_count() < 4:
        pytest.skip("needs >= 4 devices "
                    "(XLA_FLAGS=--xla_force_host_platform_device_count)")
    cfg, params = serve_setup
    want, _ = _oracle(serve_setup, serve_harness, paged=True)
    fleet = FleetSupervisor(params, cfg, n_replicas=2, model=2,
                            **_kw(True))
    got = _run_fleet(fleet, serve_harness.pressure_requests())
    assert got == want
    ks = fleet.kv_stats()
    assert all(p["model_shards"] == 2 for p in ks["per_replica"])
    assert all(p["kv_shard_fraction"] == 0.5 for p in ks["per_replica"])


def test_fleet_insufficient_devices_for_model_parallel(serve_setup):
    cfg, params = serve_setup
    with pytest.raises(ValueError, match="devices"):
        FleetSupervisor(params, cfg, n_replicas=max(
            2, jax.device_count()), model=2, n_slots=2, max_seq=32)


# -- chaos: quarantine / migration / re-admission ---------------------------

def test_quarantine_heal_readmit_round_trip(serve_setup, serve_harness,
                                            assert_health_events):
    """The full fault lifecycle: a mid-run tick exception quarantines
    replica 0, its in-flight requests migrate token-exactly, `recover`
    re-admits the replica, and the router sends it work again."""
    from repro.runtime import faults

    cfg, params = serve_setup
    want, _ = _oracle(serve_setup, serve_harness, paged=True)
    fleet = FleetSupervisor(params, cfg, n_replicas=2, model=1,
                            devices=jax.devices()[:1],
                            validate_outputs=True, **_kw(True))
    fleet.arm_faults(faults.FaultPlan(
        [faults.FaultEvent(kind="tick_exception", tick=3, replica=0)]))
    got = _run_fleet(fleet, serve_harness.pressure_requests())

    assert got == want                       # survivors bit-exact
    fh = fleet.fleet_health()
    assert fh["replicas"][0]["state"] == "quarantined"
    assert fh["healthy"] == 1
    assert fh["migrations"] >= 1
    assert fh["dead_letters"] == []
    assert fh["migrate_replay_mismatches"] == 0
    assert_health_events(fleet.health_events,
                         expect_kinds=("quarantine", "migrate"))

    # heal: replica 0 is rebuilt, re-enabled, and routed to again
    fleet.recover(0)
    assert fleet.fleet_health()["replicas"][0]["state"] == "healthy"
    r0 = fleet.routed[0]
    got2 = _run_fleet(fleet, serve_harness.pressure_requests(4, seed=7))
    assert fleet.routed[0] > r0              # router trusts it again
    assert {rid: len(t) for rid, t in got2.items()}  # all served
    kinds = assert_health_events(
        fleet.health_events,
        expect_kinds=("quarantine", "migrate", "readmit"))
    assert kinds.index("readmit") > kinds.index("quarantine")
    serve_harness.assert_drained(fleet.engines[1])


def test_all_replicas_down_dead_letters_not_hangs(serve_setup,
                                                  serve_harness,
                                                  assert_health_events):
    """Graceful degradation: with every replica quarantined, queued
    migrations are dead-lettered (shed throughput) instead of spinning
    the drain loop forever (losing liveness) or fabricating tokens
    (losing correctness)."""
    from repro.runtime import faults

    cfg, params = serve_setup
    fleet = FleetSupervisor(params, cfg, n_replicas=1, model=1,
                            devices=jax.devices()[:1], **_kw(True))
    fleet.arm_faults(faults.FaultPlan(
        [faults.FaultEvent(kind="tick_exception", tick=3, replica=0)]))
    reqs = serve_harness.pressure_requests(3)   # all admit before tick 3
    done, _ = fleet.run_to_completion(reqs)

    fh = fleet.fleet_health()
    assert fh["healthy"] == 0
    assert len(done) + len(fh["dead_letters"]) == len(reqs)
    assert fh["dead_letters"]                   # something was shed
    assert_health_events(fleet.health_events,
                         expect_kinds=("quarantine", "dead_letter"))


# -- fleet diagnosis covers parked requests (satellite bugfix) ---------------

def test_fleet_stuck_report_names_parked_requests(serve_setup,
                                                  serve_harness):
    """Regression: preempted/parked requests used to be invisible in
    the fleet-level diagnosis — only ``e.active`` was counted.  Park
    one and assert both the max_ticks error and the stuck report name
    it."""
    import pytest

    cfg, params = serve_setup
    fleet = FleetSupervisor(params, cfg, n_replicas=1, model=1,
                            devices=jax.devices()[:1], **_kw(True))
    reqs = serve_harness.pressure_requests(3)
    assert fleet.admit_many(reqs) == 3
    parked_rid = fleet.engines[0].preempt()
    assert parked_rid is not None

    report = fleet._stuck_report([])
    assert f"preempted rids [{parked_rid}]" in report

    with pytest.raises(RuntimeError) as err:
        fleet.run_to_completion([], max_ticks=0)
    msg = str(err.value)
    assert "1 preempted" in msg
    assert f"preempted rids [{parked_rid}]" in msg


# -- same-tick quarantine/finish exactly-once (satellite bugfix) -------------

def test_deadline_quarantine_on_finishing_tick_delivers_once(
        serve_setup, serve_harness, assert_health_events):
    """A request that finishes on the exact tick its replica trips the
    deadline watchdog must be delivered exactly once: it exited the
    engine's in-flight state inside ``e.step()`` before the deadline
    check, so the quarantine drain has nothing to re-queue."""
    cfg, params = serve_setup
    fleet = FleetSupervisor(params, cfg, n_replicas=2, model=1,
                            devices=jax.devices()[:1], **_kw(True))
    req = serve_harness.pressure_requests(1)[0]
    req.max_new = 1                       # finishes on its first tick
    assert fleet.admit_many([req]) == 1
    fleet.tick_deadline_s = 0.0           # every tick now "exceeds"
    done = fleet.step()
    assert [r.rid for r in done] == [req.rid]
    assert fleet.health[0]["state"] == "quarantined"
    assert fleet._migration_queue == []   # nothing left to re-queue
    assert fleet.dead_letters == []
    assert fleet.step() == []             # and never delivered again
    assert_health_events(fleet.health_events,
                         expect_kinds=("quarantine",))


def test_instant_finish_survives_tick_exception(serve_setup,
                                                serve_harness):
    """Regression for the entry-drain race: ``_step`` drains
    ``_finished_instant`` before ticking, so a tick exception used to
    lose any instant finish drained that step — the quarantine rescue
    saw an empty list.  The drain now restores on raise: the rescue
    delivers it exactly once."""
    import numpy as np

    from repro.runtime import faults
    from repro.runtime.serve import Request

    cfg, params = serve_setup
    fleet = FleetSupervisor(params, cfg, n_replicas=1, model=1,
                            devices=jax.devices()[:1], **_kw(True))
    fleet.arm_faults(faults.FaultPlan(
        [faults.FaultEvent(kind="tick_exception", tick=0, replica=0)]))
    normal = serve_harness.pressure_requests(1)[0]
    instant = Request(99, np.array([3, 4], np.int32), max_new=0)
    done, _ = fleet.run_to_completion([normal, instant])
    # the instant finish is rescued through quarantine exactly once;
    # the in-flight request dead-letters (no second replica to adopt)
    assert [r.rid for r in done] == [instant.rid]
    assert sorted(r.rid for r in fleet.dead_letters) == [normal.rid]


# -- tier-aware fleet admission (tentpole) -----------------------------------

def test_latency_tier_skips_fleet_admit_barrier(serve_setup,
                                                serve_harness):
    """A latency-tier request behind a blocked throughput head jumps
    the queue-order admit barrier, displacing a throughput victim; the
    compaction keeps the caller's ``del pending[:n]`` contract."""
    from repro.runtime.serve import Request

    cfg, params = serve_setup
    fleet = FleetSupervisor(params, cfg, n_replicas=2, model=1,
                            devices=jax.devices()[:1], **_kw(True))
    fill = serve_harness.pressure_requests(6)      # 2 replicas x 3 slots
    assert fleet.admit_many(fill) == 6
    blocked = serve_harness.pressure_requests(2, seed=9)
    head, tail = blocked
    latency = Request(50, serve_harness.pressure_requests(1)[0].prompt,
                      max_new=6, tier="latency")
    pending = [head, latency, tail]
    n = fleet.admit_many(pending)
    assert n == 1
    assert pending[0] is latency           # compacted to the prefix
    assert pending[1:] == [head, tail]     # FIFO preserved behind it
    del pending[:n]                        # the caller's contract
    assert sum(e.displacements for e in fleet.engines) == 1
    displaced = [r for e in fleet.engines for r in e._displaced]
    assert all(r.tier == "throughput" for r in displaced)
    assert any(r.rid == latency.rid
               for e in fleet.engines for r in e.active.values())
