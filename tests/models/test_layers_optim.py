"""Layer + optimizer unit/property tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")   # real lib or the conftest fallback
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.models import attention, layers
from repro.optim import adamw


# ---------------------------------------------------------------------------
# layers
# ---------------------------------------------------------------------------

def test_rms_norm_unit_scale():
    x = jax.random.normal(jax.random.PRNGKey(0), (4, 64)) * 7.0
    y = layers.rms_norm(x, jnp.ones((64,)))
    rms = jnp.sqrt(jnp.mean(y.astype(jnp.float32) ** 2, -1))
    np.testing.assert_allclose(np.array(rms), 1.0, rtol=1e-3)


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 2**16), st.integers(1, 512))
def test_rope_preserves_norm_and_relative_phase(shift, dist):
    """RoPE is an orthogonal transform; scores depend on relative offset."""
    key = jax.random.PRNGKey(7)
    q = jax.random.normal(key, (1, 1, 1, 64))
    k = jax.random.normal(jax.random.PRNGKey(8), (1, 1, 1, 64))
    p0 = jnp.array([0]), jnp.array([dist])
    p1 = jnp.array([shift]), jnp.array([shift + dist])
    qr0 = layers.apply_rope(q, p0[0])
    kr0 = layers.apply_rope(k, p0[1])
    qr1 = layers.apply_rope(q, p1[0])
    kr1 = layers.apply_rope(k, p1[1])
    # norm preserved
    np.testing.assert_allclose(float(jnp.linalg.norm(qr0)),
                               float(jnp.linalg.norm(q)), rtol=1e-5)
    # dot product depends only on relative distance (f32 trig at large
    # absolute positions costs a few ulps — tolerance reflects that)
    np.testing.assert_allclose(float(jnp.vdot(qr0, kr0)),
                               float(jnp.vdot(qr1, kr1)), rtol=5e-3,
                               atol=5e-3)


def test_cross_entropy_matches_manual():
    logits = jnp.array([[[2.0, 1.0, 0.0], [0.0, 3.0, 0.0]]])
    labels = jnp.array([[0, 1]])
    got = layers.cross_entropy(logits, labels)
    p0 = np.exp(2.0) / (np.exp(2.0) + np.exp(1.0) + 1)
    p1 = np.exp(3.0) / (np.exp(3.0) + 2)
    want = -(np.log(p0) + np.log(p1)) / 2
    np.testing.assert_allclose(float(got), want, rtol=1e-6)


def test_cross_entropy_masks_negative_labels():
    logits = jnp.zeros((1, 3, 5))
    labels = jnp.array([[1, -1, -1]])
    got = layers.cross_entropy(logits, labels)
    np.testing.assert_allclose(float(got), np.log(5.0), rtol=1e-6)


def test_unembed_pad_masking():
    table = jnp.ones((8, 4))
    x = jnp.ones((2, 4))
    logits = layers.unembed_logits(x, table, true_vocab=5)
    assert np.all(np.array(logits[:, 5:]) < -1e29)
    assert np.all(np.isfinite(np.array(logits[:, :5])))


def test_blockwise_chunk_invariance():
    """Blockwise attention is exact for any chunk size (SUMUP property)."""
    key = jax.random.PRNGKey(1)
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (2, 64, 4, 16))
    k = jax.random.normal(ks[1], (2, 64, 2, 16))
    v = jax.random.normal(ks[2], (2, 64, 2, 16))
    want = attention.full_attention(q, k, v, causal=True)
    for chunk in (8, 16, 32, 64):
        got = attention.blockwise_attention(q, k, v, causal=True, chunk=chunk)
        np.testing.assert_allclose(np.array(got), np.array(want), rtol=2e-5,
                                   atol=2e-5)


# ---------------------------------------------------------------------------
# optimizer
# ---------------------------------------------------------------------------

def test_adamw_first_step_is_lr_sized():
    cfg = adamw.AdamWConfig(lr=1e-2, weight_decay=0.0, warmup_steps=0,
                            total_steps=10**9, grad_clip=1e9)
    params = {"w": jnp.zeros((4,))}
    state = adamw.init(params)
    grads = {"w": jnp.full((4,), 0.5)}
    new_p, state, m = adamw.update(grads, state, params, cfg)
    # bias-corrected Adam first step ≈ lr * sign(g)
    np.testing.assert_allclose(np.array(new_p["w"]), -1e-2, rtol=1e-3)
    assert int(state["step"]) == 1


def test_grad_clip_caps_norm():
    g = {"a": jnp.full((3,), 100.0)}
    clipped, norm = adamw.clip_by_global_norm(g, 1.0)
    assert float(norm) == pytest.approx(100.0 * np.sqrt(3), rel=1e-5)
    np.testing.assert_allclose(float(adamw.global_norm(clipped)), 1.0,
                               rtol=1e-5)


def test_schedule_warmup_and_decay():
    cfg = adamw.AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100,
                            min_lr_ratio=0.1)
    assert float(adamw.schedule(cfg, jnp.int32(5))) == pytest.approx(0.5)
    assert float(adamw.schedule(cfg, jnp.int32(10))) == pytest.approx(1.0)
    end = float(adamw.schedule(cfg, jnp.int32(100)))
    assert end == pytest.approx(0.1, rel=1e-3)


def test_quadratic_convergence():
    cfg = adamw.AdamWConfig(lr=0.1, weight_decay=0.0, warmup_steps=0,
                            total_steps=10**9)
    target = jnp.array([1.0, -2.0, 3.0])
    params = {"w": jnp.zeros((3,))}
    state = adamw.init(params)
    for _ in range(300):
        g = {"w": 2 * (params["w"] - target)}
        params, state, _ = adamw.update(g, state, params, cfg)
    np.testing.assert_allclose(np.array(params["w"]), np.array(target),
                               atol=1e-2)


# ---------------------------------------------------------------------------
# vmapped EMPA machines (many processors simulated in parallel)
# ---------------------------------------------------------------------------

def test_vmap_machine_over_memories():
    """One compiled machine, a batch of EMPA processors — vmap over the
    memory image (the paper's processor as a composable JAX module)."""
    import jax
    from repro.core import machine, programs

    n = 6
    prog = jnp.asarray(np.concatenate(
        [programs.sumup_sumup(n),
         np.zeros((0, 6), np.int32)]))
    vecs = np.arange(1, 4 * n + 1, dtype=np.int32).reshape(4, n)
    mems = []
    for v in vecs:
        m = np.zeros((machine.MEM_WORDS,), np.int32)
        img = programs.mem_image(v)
        m[:len(img)] = img
        mems.append(m)
    mems = jnp.asarray(np.stack(mems))

    batched = jax.vmap(lambda mem: machine._run(prog, mem, 1000))(mems)
    np.testing.assert_array_equal(np.array(batched.result),
                                  vecs.sum(axis=1))
    np.testing.assert_array_equal(np.array(batched.clocks),
                                  np.full(4, 32 + n))
