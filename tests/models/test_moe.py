"""MoE unit tests: routing, capacity, dispatch tables, oracle equivalence."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")   # real lib or the conftest fallback
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.configs import get_arch, reduced
from repro.models import layers, moe


def _cfg(**kw):
    base = reduced(get_arch("qwen3-moe-30b-a3b"))
    return dataclasses.replace(base, **kw)


def test_capacity_formula():
    assert moe.capacity(64, 2, 8, 1.0) == 16
    assert moe.capacity(64, 2, 8, 1.25) == 24     # ceil(20) -> pad to 8
    assert moe.capacity(1, 8, 128, 1.25) == 1     # never zero


def test_dispatch_tables_no_drop_roundtrip():
    g, t, k, e = 2, 16, 2, 4
    key = jax.random.PRNGKey(0)
    idx = jax.random.randint(key, (g, t, k), 0, e)
    gates = jnp.ones((g, t, k)) / k
    cap = t * k   # dropless
    buf_tok, buf_gate = moe.dispatch_tables(idx, gates, e, cap)
    # every (token, expert) assignment appears exactly once
    for gi in range(g):
        got = []
        for ei in range(e):
            for ci in range(cap):
                tok = int(buf_tok[gi, ei, ci])
                if tok < t:
                    got.append((tok, ei))
        want = [(ti, int(idx[gi, ti, kk])) for ti in range(t)
                for kk in range(k)]
        assert sorted(got) == sorted(want)


def test_dispatch_drops_over_capacity():
    g, t, k, e = 1, 8, 1, 2
    idx = jnp.zeros((g, t, k), jnp.int32)       # everyone wants expert 0
    gates = jnp.ones((g, t, k))
    cap = 3
    buf_tok, _ = moe.dispatch_tables(idx, gates, e, cap)
    kept = int(jnp.sum(buf_tok[0, 0] < t))
    assert kept == cap                           # exactly `cap` survive
    assert int(jnp.sum(buf_tok[0, 1] < t)) == 0  # expert 1 untouched


def test_moe_matches_dense_oracle():
    """Dropless MoE == explicit per-token expert sum."""
    cfg = _cfg(n_experts=4, top_k=2, capacity_factor=4.0, d_model=32, d_ff=16)
    key = jax.random.PRNGKey(1)
    ks = jax.random.split(key, 5)
    g, t, d, e, f = 2, 8, 32, 4, 16
    x = jax.random.normal(ks[0], (g, t, d), jnp.float32)
    p = {
        "router": jax.random.normal(ks[1], (d, e)) * 0.5,
        "w_gate": jax.random.normal(ks[2], (e, d, f)) * 0.2,
        "w_up": jax.random.normal(ks[3], (e, d, f)) * 0.2,
        "w_down": jax.random.normal(ks[4], (e, f, d)) * 0.2,
    }
    y, aux = moe.moe_ffn(x, p, cfg, "silu")

    gates, idx, _ = moe.route(x, p["router"], cfg.top_k)
    want = jnp.zeros_like(x)
    for gi in range(g):
        for ti in range(t):
            acc = jnp.zeros((d,))
            for kk in range(cfg.top_k):
                ei = int(idx[gi, ti, kk])
                xe = x[gi, ti]
                h = jax.nn.silu(xe @ p["w_gate"][ei]) * (xe @ p["w_up"][ei])
                acc = acc + gates[gi, ti, kk] * (h @ p["w_down"][ei])
            want = want.at[gi, ti].set(acc)
    np.testing.assert_allclose(np.array(y), np.array(want), rtol=2e-4,
                               atol=2e-4)
    assert np.isfinite(float(aux))


def test_load_balance_loss_uniform_is_one():
    g, t, e, k = 4, 64, 8, 2
    key = jax.random.PRNGKey(2)
    probs = jnp.ones((g, t, e)) / e
    # idx uniformly spread
    idx = jax.random.randint(key, (g, t, k), 0, e)
    loss = moe.load_balancing_loss(probs, idx, e)
    assert 0.9 < float(loss) < 1.1


@settings(max_examples=10, deadline=None)
@given(st.integers(2, 6), st.integers(4, 24), st.integers(1, 3))
def test_gates_normalized(e, t, k):
    """Property: combined top-k gates sum to 1 per token."""
    key = jax.random.PRNGKey(e * t + k)
    x = jax.random.normal(key, (1, t, 8))
    w = jax.random.normal(jax.random.PRNGKey(0), (8, e))
    gates, idx, probs = moe.route(x, w, min(k, e))
    np.testing.assert_allclose(np.array(jnp.sum(gates, -1)),
                               np.ones((1, t)), rtol=1e-5)
    assert int(jnp.max(idx)) < e
