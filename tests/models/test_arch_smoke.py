"""Per-architecture smoke tests: reduced config, one forward/train step on
CPU, asserting output shapes and absence of NaNs (assignment requirement),
plus prefill→decode consistency against the full-sequence forward."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_arch, reduced
from repro.models import model

B, S, MAX = 2, 16, 32


def _batch(cfg, key, with_labels=True):
    kt, kv = jax.random.split(key)
    s = S
    batch = {}
    if cfg.frontend == "vision":
        nv = cfg.n_frontend_tokens
        batch["vision_embeds"] = jax.random.normal(
            kv, (B, nv, cfg.frontend_dim), jnp.float32)
        s = S - nv
    if cfg.family == "encdec":
        batch["enc_embeds"] = jax.random.normal(
            kv, (B, S, cfg.frontend_dim), jnp.float32)
    batch["tokens"] = jax.random.randint(kt, (B, s), 0, cfg.vocab)
    if with_labels:
        batch["labels"] = jnp.roll(batch["tokens"], -1, axis=1)
    return batch


@pytest.fixture(scope="module")
def rng():
    return jax.random.PRNGKey(0)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_and_train_step(arch, rng):
    cfg = reduced(get_arch(arch))
    params = model.init(rng, cfg, jnp.float32)
    batch = _batch(cfg, rng)

    loss, metrics = model.loss_fn(params, batch, cfg, remat=False)
    assert loss.shape == ()
    assert np.isfinite(float(loss)), f"{arch}: loss not finite"

    # one SGD step via value_and_grad: gradients exist and are finite
    g = jax.grad(lambda p: model.loss_fn(p, batch, cfg, remat=True)[0])(params)
    leaves = jax.tree_util.tree_leaves(g)
    assert leaves, "no gradients"
    for leaf in leaves:
        assert np.all(np.isfinite(np.asarray(leaf, np.float32))), \
            f"{arch}: non-finite grad"
    # loss decreases after a small step (sanity, not convergence): grads
    # are a descent direction, so *some* small lr must help — backtrack
    # instead of hardwiring one lr for every family's loss landscape
    # (lr=0.1 marginally overshoots for the reduced MoE router)
    loss2 = np.inf
    for lr in (0.1, 0.03, 0.01):
        p2 = jax.tree_util.tree_map(lambda p, gg: p - lr * gg, params, g)
        loss2, _ = model.loss_fn(p2, batch, cfg, remat=False)
        if float(loss2) < float(loss) + 1e-3:
            break
    assert float(loss2) < float(loss) + 1e-3, f"{arch}: step did not help"


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_prefill_decode_consistency(arch, rng):
    """decode_step must continue exactly where prefill left off: logits for
    position S must match the full-sequence forward at position S."""
    cfg = reduced(get_arch(arch))
    params = model.init(rng, cfg, jnp.float32)
    batch = _batch(cfg, rng, with_labels=False)

    logits_p, cache = model.prefill(params, batch, cfg, MAX)
    next_tok = jnp.argmax(logits_p, -1).astype(jnp.int32)
    logits_d, cache = model.decode_step(params, next_tok, cache, cfg)
    assert logits_d.shape == (B, cfg.vocab)
    assert np.all(np.isfinite(np.asarray(logits_d, np.float32)))

    # oracle: full forward over tokens + [next_tok]
    batch2 = dict(batch)
    batch2["tokens"] = jnp.concatenate(
        [batch["tokens"], next_tok[:, None]], axis=1)
    x, _ = model.forward(params, batch2, cfg, remat=False)
    table = params["embed"]["tok"] if cfg.tie_embeddings else params["unembed"]
    ref = jnp.einsum("bd,vd->bv", x[:, -1], table)
    np.testing.assert_allclose(np.asarray(logits_d, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_abstract_params_match_init(arch, rng):
    """eval_shape of init == abstract_params (dry-run parity)."""
    cfg = reduced(get_arch(arch))
    abstract = model.abstract(cfg, jnp.float32)
    shaped = jax.eval_shape(lambda k: model.init(k, cfg, jnp.float32), rng)
    ta = jax.tree_util.tree_map(lambda a: (a.shape, str(a.dtype)), abstract)
    tb = jax.tree_util.tree_map(lambda a: (a.shape, str(a.dtype)), shaped)
    assert ta == tb


def test_param_counts_nominal():
    """Full-config parameter counts are in the architecture's nominal range."""
    expect = {
        "qwen3-moe-30b-a3b": (29e9, 32e9),
        "granite-8b": (7.5e9, 8.7e9),
        "starcoder2-7b": (6.8e9, 7.8e9),
        "starcoder2-3b": (2.8e9, 3.4e9),
        "granite-3-2b": (2.3e9, 2.9e9),
        "pixtral-12b": (11.5e9, 13e9),
        "zamba2-1.2b": (1.0e9, 1.4e9),
        "mamba2-780m": (0.72e9, 0.84e9),
        "whisper-small": (0.2e9, 0.4e9),
    }
    for arch, (lo, hi) in expect.items():
        n = get_arch(arch).param_count()
        assert lo <= n <= hi, f"{arch}: {n:,} outside [{lo:,.0f}, {hi:,.0f}]"
    # MoE active ≈ 3B for the a3b models
    for arch in ("qwen3-moe-30b-a3b", "moonshot-v1-16b-a3b"):
        a = get_arch(arch).active_param_count()
        assert 2e9 <= a <= 6.5e9, f"{arch} active {a:,}"
