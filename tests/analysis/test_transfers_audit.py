"""Transfer audit: callback primitives in jaxprs, and the TransferSpy."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis.transfers import (TransferSpy, audit_transfers,
                                      iter_primitives)

F32 = jnp.float32


def _violations(findings):
    return [f for f in findings if f.severity == "violation"]


def test_callback_smuggled_into_jaxpr_fires(make_spec):
    # jax.debug.print compiles to a debug_callback primitive — a host
    # round-trip inside the tick.
    def step(params, tok, cache):
        jax.debug.print("tok {}", tok)
        return tok + 1, cache

    spec = make_spec(
        step,
        (jax.ShapeDtypeStruct((8,), F32),
         jax.ShapeDtypeStruct((4,), jnp.int32),
         jax.ShapeDtypeStruct((4, 16), F32)),
        donate_argnums=(2,))
    bad = _violations(audit_transfers(spec))
    assert bad, "a callback primitive inside the tick must be a violation"
    assert any("debug_callback" in f.message for f in bad)


def test_pure_callback_in_nested_scope_fires(make_spec):
    # recursion check: the callback hides inside a lax.cond branch
    def step(params, tok, cache):
        def branch(t):
            return jax.pure_callback(
                lambda x: np.asarray(x), jax.ShapeDtypeStruct(t.shape,
                                                              t.dtype), t)
        tok = jax.lax.cond(tok[0] > 0, branch, lambda t: t, tok)
        return tok, cache

    spec = make_spec(
        step,
        (jax.ShapeDtypeStruct((8,), F32),
         jax.ShapeDtypeStruct((4,), jnp.int32),
         jax.ShapeDtypeStruct((4, 16), F32)))
    bad = _violations(audit_transfers(spec))
    assert any("pure_callback" in f.message for f in bad)


def test_clean_tick_has_no_forbidden_primitives(make_spec):
    def step(params, tok, cache):
        return tok + 1, cache * params[0]

    spec = make_spec(
        step,
        (jax.ShapeDtypeStruct((8,), F32),
         jax.ShapeDtypeStruct((4,), jnp.int32),
         jax.ShapeDtypeStruct((4, 16), F32)))
    findings = audit_transfers(spec)
    assert not _violations(findings)
    # the walker still saw real primitives
    closed = jax.make_jaxpr(spec.step_fn)(*spec.abstract_args)
    assert any(name for name, _ in iter_primitives(closed))


def test_transfer_spy_catches_implicit_int():
    x = jnp.ones(())
    spy = TransferSpy()
    with spy:
        assert int(x) == 1
    assert spy.violations
    assert "__int__" in spy.violations[0]


def test_transfer_spy_catches_implicit_bool_and_float():
    x = jnp.ones(())
    spy = TransferSpy()
    with spy:
        bool(x)
        float(x)
    kinds = "".join(spy.violations)
    assert "__bool__" in kinds and "__float__" in kinds


def test_transfer_spy_allows_explicit_device_get():
    x = jnp.arange(4)
    spy = TransferSpy()
    with spy:
        host = jax.device_get(x)
        assert int(host[2]) == 2          # numpy by now: not spied
    assert spy.violations == []


def test_transfer_spy_restores_dunders_on_exit():
    x = jnp.ones(())
    with TransferSpy():
        pass
    spy = TransferSpy()
    int(x)                                 # outside any spy: no record
    assert spy.violations == []
