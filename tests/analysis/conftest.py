"""Fixture helpers for the auditor's own tests.

The known-bad specs here are hand-built :class:`TickSpec` objects — no
model, no supervisor — exercising exactly the failure mode each
analysis exists to catch.  The clean-tree tests then run the same
analyses over the real serve plans and assert silence.
"""
from __future__ import annotations

import pytest

from repro.analysis.families import TickSpec


@pytest.fixture
def make_spec():
    """Hand-build a minimal auditable spec around a step function."""
    def _make(step_fn, abstract_args, donate_argnums=(),
              name="fixture/contiguous"):
        return TickSpec(
            name=name, family="fixture", layout="contiguous",
            mesh_devices=1, step_fn=step_fn,
            abstract_args=tuple(abstract_args),
            donate_argnums=tuple(donate_argnums))
    return _make
