"""The live harness: a real engine stream under guard + spy stays clean."""
from __future__ import annotations

import pytest

from repro.analysis.transfers import run_transfer_harness


@pytest.mark.slow
def test_harness_both_cells_clean():
    findings = run_transfer_harness()
    cells = {f.subject for f in findings}
    assert cells == {"harness/contiguous/decode",
                     "harness/paged/chunked+spec+overcommit"}
    bad = [f for f in findings if f.severity == "violation"]
    assert not bad, [f.message for f in bad]
    # the budgeted sync accounting made it into the messages
    assert all("budgeted syncs" in f.message for f in findings)
