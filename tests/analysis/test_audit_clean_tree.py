"""End-to-end: the audit passes --strict on the working tree.

This is the acceptance gate in test form: every family x layout cell
lowers, all four analyses run, the report serializes, and the tree is
clean.  The live transfer harness is exercised by its own test; here it
is skipped to keep the cell-lowering loop the only cost.
"""
from __future__ import annotations

import json

import pytest

from repro.analysis import manifest
from repro.analysis.audit import collect_key_spaces, run_audit
from repro.analysis.families import build_tick_specs

FAMILIES = {"decode", "chunked_prefill", "solo_prefill", "speculative",
            "overcommit_resume"}


@pytest.fixture(scope="module")
def report():
    return run_audit(with_mesh=False, harness=False)


def test_matrix_covers_all_families_both_layouts():
    specs = build_tick_specs(with_mesh=False)
    cells = {(s.family, s.layout) for s in specs}
    assert cells == {(f, lay) for f in FAMILIES
                     for lay in ("contiguous", "paged")}


def test_clean_tree_passes_strict(report):
    assert report.ok(strict=True), \
        [f.to_json() for f in report.violations(strict=True)]


def test_report_shape(report, tmp_path):
    assert len(report.families) == 10
    assert len(report.sites) >= 10
    assert {s["name"] for s in report.sites} >= \
        {"decode_chunk/contiguous", "spec_tick/paged", "admit_step/paged"}
    assert "before_after" in report.meta
    out = tmp_path / "AUDIT.json"
    report.write(str(out))
    data = json.loads(out.read_text())
    assert data["clean"] is True
    assert data["counts"]["violation"] == 0
    assert data["version"] == 1


def test_manifest_registers_every_tick_site(report):
    # build_tick_specs ran inside run_audit; the wrapper helper must
    # have registered each builder's jit site under both layouts
    # (decode -> decode_chunk, chunked prefill / over-commit ->
    # mixed_tick, speculation -> spec_tick + spec_chunk)
    names = set(manifest.sites())
    for builder in ("decode_chunk", "mixed_tick", "spec_tick",
                    "solo_prefill", "admit_step"):
        for layout in ("contiguous", "paged"):
            assert f"{builder}/{layout}" in names, (builder, names)


def test_collected_key_spaces_are_bounded(report):
    spaces = collect_key_spaces()
    assert "admit_step/contiguous" in spaces
    assert "admit_step/paged" in spaces
    assert all(space is not None for space in spaces.values())
    # paged admission rounds spans up to block multiples: still pow2-few
    assert len(spaces["admit_step/paged"]) <= \
        len(spaces["admit_step/contiguous"]) * 2
