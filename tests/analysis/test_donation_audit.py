"""Donation audit: fires on undonated state and unaliasable donation."""
from __future__ import annotations

import warnings

import jax
import jax.numpy as jnp

from repro.analysis.donation import audit_donation

F32 = jnp.float32
CACHE = jax.ShapeDtypeStruct((4, 64, 16), F32)      # 16 KiB of "state"


def _violations(findings):
    return [f for f in findings if f.severity == "violation"]


def test_undonated_state_buffer_fires(make_spec):
    # bstate (argnum 3) is cache-sized, flows input -> output, and is
    # missing from donate_argnums: the deliberately un-donated jit.
    def step(params, tok, cache, bstate):
        return tok + 1, cache + params[0], bstate * 2

    spec = make_spec(
        step,
        (jax.ShapeDtypeStruct((64,), F32),
         jax.ShapeDtypeStruct((4,), jnp.int32), CACHE, CACHE),
        donate_argnums=(2,))
    bad = _violations(audit_donation(spec))
    assert bad, "undonated persistent buffer must be a violation"
    assert any("argnum 3" in f.message for f in bad)


def test_declared_but_unaliasable_donation_fires(make_spec):
    # donated f32 cache comes back bf16: XLA cannot alias the buffers,
    # so the declared donation silently double-buffers.
    def step(params, tok, cache):
        return tok + 1, cache.astype(jnp.bfloat16)

    spec = make_spec(
        step,
        (jax.ShapeDtypeStruct((64,), F32),
         jax.ShapeDtypeStruct((4,), jnp.int32), CACHE),
        donate_argnums=(2,))
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")       # XLA's own donation gripe
        bad = _violations(audit_donation(spec))
    assert bad, "unaliased donated leaves must be a violation"
    assert any("tf.aliasing_output" in f.message for f in bad)


def test_fully_donated_spec_is_clean(make_spec):
    def step(params, tok, cache):
        return tok + 1, cache * params[0]

    spec = make_spec(
        step,
        (jax.ShapeDtypeStruct((64,), F32),
         jax.ShapeDtypeStruct((4,), jnp.int32), CACHE),
        donate_argnums=(2,))
    findings = audit_donation(spec)
    assert not _violations(findings)
    assert any(f.severity == "info" and "aliased" in f.message
               for f in findings)
