"""AST lint rules: host-sync, tracer-branch, kernel-oracle, fault-hook,
tier-host-side."""
from __future__ import annotations

import os
import textwrap

from repro.analysis.lint import (lint_fault_hooks_source,
                                 lint_kernel_manifest, lint_repo,
                                 lint_tick_builder_source,
                                 lint_tier_reads_source,
                                 lint_transition_source)


def _violations(findings):
    return [f for f in findings if f.severity == "violation"]


# ---------------------------------------------------------------- L1 --
BAD_TRANSITION = textwrap.dedent("""
    def release(state, unit):
        idx = int(unit)
        ok = state.free[idx].item()
        return bool(ok)
""")


def test_host_sync_in_transition_fires():
    bad = _violations(lint_transition_source(BAD_TRANSITION, "pool.py",
                                             allowlist=set()))
    labels = "".join(f.message for f in bad)
    assert "int()" in labels and ".item()" in labels and "bool()" in labels


def test_allowlisted_helper_is_exempt():
    assert not lint_transition_source(BAD_TRANSITION, "pool.py",
                                      allowlist={"release"})


def test_np_asarray_and_device_get_fire():
    src = textwrap.dedent("""
        def seed(state, prompt):
            buf = np.asarray(prompt)
            return jax.device_get(buf)
    """)
    bad = _violations(lint_transition_source(src, "draft.py",
                                             allowlist=set()))
    labels = "".join(f.message for f in bad)
    assert "np.asarray()" in labels and "jax.device_get()" in labels


# ---------------------------------------------------------------- L3 --
def test_tracer_branch_in_builder_fires():
    src = textwrap.dedent("""
        def build_decode_step(cfg, chunk):
            def step(params, tok, cache, frag_len):
                if frag_len > 0:
                    tok = tok + 1
                return tok, cache
            return step
    """)
    bad = _violations(lint_tick_builder_source(src))
    assert bad
    assert "frag_len" in bad[0].message


def test_while_on_traced_param_fires():
    src = textwrap.dedent("""
        def build_spec_tick(cfg):
            def step(params, accepted):
                while accepted:
                    accepted = accepted - 1
                return accepted
            return step
    """)
    assert _violations(lint_tick_builder_source(src))


def test_static_attr_and_none_checks_are_clean():
    src = textwrap.dedent("""
        def build_decode_step(cfg, chunk):
            def step(params, tok, cache, mask):
                if mask is None:
                    mask = tok * 0
                if tok.shape[0] > 1:
                    tok = tok[:1]
                if chunk > 2:
                    tok = tok + chunk
                return tok, cache
            return step
    """)
    assert not lint_tick_builder_source(src)


def test_branch_outside_builder_is_ignored():
    src = textwrap.dedent("""
        def helper(n):
            if n > 0:
                return n
            return 0
    """)
    assert not lint_tick_builder_source(src)


# ---------------------------------------------------------------- L4 --
def test_unguarded_fault_hook_fires():
    # the known-bad shape: a hook that calls into the fault plan every
    # tick regardless of whether one was armed
    src = textwrap.dedent("""
        class Engine:
            def __init__(self):
                self._faults = None
            def arm_faults(self, faults):
                self._faults = faults
            def _step(self):
                self._faults.due(0)
    """)
    bad = _violations(lint_fault_hooks_source(src))
    assert bad
    assert "_step" in bad[0].subject
    assert "unguarded" in bad[0].message


def test_guarded_fault_hook_is_clean():
    src = textwrap.dedent("""
        class Engine:
            def __init__(self):
                self._faults = None
            def arm_faults(self, faults):
                self._faults = faults
            def _step(self):
                if self._faults is not None:
                    self._fire_faults(self._faults)
    """)
    assert not lint_fault_hooks_source(src)


def test_fault_symbol_in_tick_builder_fires():
    # chaos leaking into traced code: a builder's nested step function
    # calling into the fault layer
    src = textwrap.dedent("""
        def build_decode_step(cfg):
            def step(params, tok, cache):
                tok = faults_lib.maybe_inject(tok)
                return tok, cache
            return step
    """)
    bad = _violations(lint_fault_hooks_source(src))
    assert bad
    assert "build_decode_step" in bad[0].subject
    assert "traced" in bad[0].message


def test_default_is_not_a_fault_name():
    # "default" contains "fault" — the matcher must not trip on it
    src = textwrap.dedent("""
        def build_decode_step(cfg, default_mask=None):
            def step(params, tok):
                if default_mask is None:
                    return tok
                return tok * default_mask
            return step
    """)
    assert not lint_fault_hooks_source(src)


# ---------------------------------------------------------------- L2 --
def test_kernel_manifest_clean_on_repo():
    assert not _violations(lint_kernel_manifest())


def test_kernel_missing_ref_and_stale_entry_fire(tmp_path):
    # a fake repo: one package with kernel.py but no ref.py/ops.py, and
    # none of the real KERNEL_TESTS packages present (all stale)
    kdir = tmp_path / "src" / "repro" / "kernels" / "ghost"
    os.makedirs(kdir)
    (kdir / "kernel.py").write_text("# stub\n")
    os.makedirs(tmp_path / "tests" / "kernels")
    bad = _violations(lint_kernel_manifest(str(tmp_path)))
    msgs = "".join(f.message for f in bad)
    assert "missing ref.py" in msgs
    assert "not listed" in msgs            # ghost has no manifest entry
    assert "stale manifest entry" in msgs  # real entries have no package


# ---------------------------------------------------------------- L5 --
def test_tier_read_in_builder_fires():
    # Request.tier is host-side scheduling metadata: a tick builder that
    # reads it would bake the scheduling class into compiled code
    src = textwrap.dedent("""
        def build_decode_step(cfg, req):
            def step(params, tok, cache):
                if req.tier == "latency":
                    tok = tok + 1
                return tok, cache
            return step
    """)
    bad = _violations(lint_tier_reads_source(src))
    assert bad
    assert "build_decode_step" in bad[0].subject
    assert "host-side" in bad[0].message


def test_tier_read_host_side_is_clean():
    # the admission controller reads .tier freely — only builders are
    # traced code
    src = textwrap.dedent("""
        def build_decode_step(cfg):
            def step(params, tok, cache):
                return tok, cache
            return step

        class Engine:
            def admit_displacing(self, req):
                if req.tier == "latency":
                    return self._displace_and_admit(req)
                return self.admit(req)
    """)
    assert not lint_tier_reads_source(src)


# ------------------------------------------------------------- repo --
def test_working_tree_is_lint_clean():
    assert not _violations(lint_repo())
