"""Retrace audit: unbounded key spaces and rotted bucketing both fire."""
from __future__ import annotations

from repro.analysis.retrace import admission_budget, audit_retrace
from repro.runtime.serve import (admit_group_buckets, admit_span_buckets,
                                 retrace_key_spaces)

MAX_SEQ, N_SLOTS = 48, 4


def _violations(findings):
    return [f for f in findings if f.severity == "violation"]


def test_unbounded_space_fires():
    bad = _violations(audit_retrace({"seed_slot/raw-length": None},
                                    max_seq=MAX_SEQ, n_slots=N_SLOTS))
    assert bad
    assert "unbounded" in bad[0].message


def test_rotted_bucketing_fires():
    # the known-bad enumerator: an identity "bucket" admits one compile
    # per raw span length — exactly the pre-PR-6 seed_slot failure
    spans = admit_span_buckets(MAX_SEQ, _bucket=lambda n, cap: n)
    assert len(spans) > admission_budget(MAX_SEQ, N_SLOTS)
    bad = _violations(audit_retrace({"admit_step/identity-bucket": spans},
                                    max_seq=MAX_SEQ, n_slots=N_SLOTS))
    assert bad
    assert "exceed" in bad[0].message


def test_over_budget_tick_site_fires():
    # a non-admit site gets the singleton budget; 9 keys blow it
    space = [("chunk", c) for c in range(9)]
    bad = _violations(audit_retrace({"decode/contiguous": space},
                                    max_seq=MAX_SEQ, n_slots=N_SLOTS))
    assert bad


def test_real_pow2_bucketing_is_within_budget():
    spans = admit_span_buckets(MAX_SEQ)
    groups = admit_group_buckets(N_SLOTS)
    # pow2 bucketing: log-many distinct spans/groups
    assert len(spans) <= MAX_SEQ.bit_length() + 1
    assert len(groups) <= N_SLOTS.bit_length() + 1
    spaces = retrace_key_spaces(max_seq=MAX_SEQ, n_slots=N_SLOTS)
    findings = audit_retrace(spaces, max_seq=MAX_SEQ, n_slots=N_SLOTS)
    assert not _violations(findings)


def test_paged_rounding_stays_bounded():
    spaces = retrace_key_spaces(max_seq=MAX_SEQ, n_slots=N_SLOTS,
                                block_size=8)
    findings = audit_retrace(spaces, max_seq=MAX_SEQ, n_slots=N_SLOTS)
    assert not _violations(findings)
