"""Constant-bloat audit: trace-time closures over big arrays fire."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis.constants import audit_constants

F32 = jnp.float32
BIG = np.ones((64, 1024), np.float32)        # 256 KiB > 64 KiB threshold


def _violations(findings):
    return [f for f in findings if f.severity == "violation"]


def test_big_closure_constant_fires(make_spec):
    def step(params, tok, cache):
        return tok + 1, cache + jnp.asarray(BIG)

    spec = make_spec(
        step,
        (jax.ShapeDtypeStruct((8,), F32),
         jax.ShapeDtypeStruct((4,), jnp.int32),
         jax.ShapeDtypeStruct((64, 1024), F32)))
    bad = _violations(audit_constants(spec))
    assert bad, "a 256 KiB baked-in constant must be a violation"
    assert any("262144" in f.message for f in bad)


def test_big_constant_in_subjaxpr_fires(make_spec):
    # recursion check: the constant is closed over inside a cond branch
    def step(params, tok, cache):
        cache = jax.lax.cond(tok[0] > 0,
                             lambda c: c + jnp.asarray(BIG),
                             lambda c: c, cache)
        return tok, cache

    spec = make_spec(
        step,
        (jax.ShapeDtypeStruct((8,), F32),
         jax.ShapeDtypeStruct((4,), jnp.int32),
         jax.ShapeDtypeStruct((64, 1024), F32)))
    assert _violations(audit_constants(spec))


def test_small_constants_are_clean(make_spec):
    small = np.arange(16, dtype=np.float32)

    def step(params, tok, cache):
        return tok + 1, cache + jnp.asarray(small)

    spec = make_spec(
        step,
        (jax.ShapeDtypeStruct((8,), F32),
         jax.ShapeDtypeStruct((4,), jnp.int32),
         jax.ShapeDtypeStruct((4, 16), F32)))
    findings = audit_constants(spec)
    assert not _violations(findings)
    assert any(f.severity == "info" for f in findings)


def test_threshold_is_configurable(make_spec):
    small = np.arange(16, dtype=np.float32)

    def step(params, tok, cache):
        return tok, cache + jnp.asarray(small)

    spec = make_spec(
        step,
        (jax.ShapeDtypeStruct((8,), F32),
         jax.ShapeDtypeStruct((4,), jnp.int32),
         jax.ShapeDtypeStruct((4, 16), F32)))
    assert _violations(audit_constants(spec, threshold=8))
