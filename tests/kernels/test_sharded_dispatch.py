"""Head-sharded kernel entries vs their unsharded twins — bit-exact.

GSPMD cannot partition a ``pallas_call``; under a head-sharded serving
mesh the kernels run per-shard on their local head slice via
``shard_map`` (kernels/*/ops.py ``*_sharded``).  Heads never mix in
attention, so each shard executes literally the same program the
unsharded kernel runs on that head slice — the outputs must match to
the bit, and the width-picks-the-schedule dispatch must be unchanged
(the fragment axis is unsharded).  Cells skip on a single-device host;
CI runs them under the forced multi-device step.
"""
from __future__ import annotations

import numpy as np
import pytest

import jax
import jax.numpy as jnp

pytestmark = pytest.mark.skipif(
    jax.device_count() < 2,
    reason="needs >= 2 devices "
           "(XLA_FLAGS=--xla_force_host_platform_device_count)")

B, H, HKV, D, SMAX = 3, 4, 2, 32, 64
BS = 8
NB = SMAX // BS
N_PAGES = 32


@pytest.fixture(scope="module")
def mesh():
    from repro.runtime.sharding import serve_mesh
    return serve_mesh(2)


@pytest.fixture(scope="module")
def data():
    rng = np.random.default_rng(0)
    kc = jnp.asarray(rng.normal(size=(B, SMAX, HKV, D)), jnp.float32)
    vc = jnp.asarray(rng.normal(size=(B, SMAX, HKV, D)), jnp.float32)
    kp = jnp.asarray(rng.normal(size=(N_PAGES, BS, HKV, D)), jnp.float32)
    vp = jnp.asarray(rng.normal(size=(N_PAGES, BS, HKV, D)), jnp.float32)
    bt = jnp.asarray(rng.permutation(N_PAGES)[:B * NB].reshape(B, NB),
                     jnp.int32)
    return rng, kc, vc, kp, vp, bt


def _q(rng, width):
    q = jnp.asarray(rng.normal(size=(B, width, H, D)), jnp.float32)
    q_pos = jnp.asarray(rng.integers(width, SMAX - 1, size=(B, width)),
                        jnp.int32)
    return q, q_pos


@pytest.mark.parametrize("width", [4, 16], ids=["narrow", "wide"])
def test_chunk_attention_sharded_bit_exact(mesh, data, width):
    from repro.kernels.chunk_attention import (
        chunk_attention_kernel, chunk_attention_kernel_sharded)
    rng, kc, vc, *_ = data
    q, q_pos = _q(rng, width)
    ref = chunk_attention_kernel(q, kc, vc, q_pos)
    out = chunk_attention_kernel_sharded(q, kc, vc, q_pos, mesh=mesh)
    assert jnp.array_equal(ref, out)


@pytest.mark.parametrize("width", [4, 16], ids=["narrow", "wide"])
def test_paged_chunk_attention_sharded_bit_exact(mesh, data, width):
    from repro.kernels.chunk_attention import (
        paged_chunk_attention_kernel, paged_chunk_attention_kernel_sharded)
    rng, _, _, kp, vp, bt = data
    q, q_pos = _q(rng, width)
    ref = paged_chunk_attention_kernel(q, kp, vp, bt, q_pos)
    out = paged_chunk_attention_kernel_sharded(q, kp, vp, bt, q_pos,
                                               mesh=mesh)
    assert jnp.array_equal(ref, out)


def test_paged_attention_sharded_bit_exact(mesh, data):
    from repro.kernels.paged_attention import (
        paged_attention, paged_attention_sharded)
    rng, _, _, kp, vp, bt = data
    q, _ = _q(rng, 1)
    q1 = q[:, 0]
    lengths = jnp.asarray(rng.integers(4, SMAX, size=(B,)), jnp.int32)
    ref = paged_attention(q1, kp, vp, bt, lengths)
    out = paged_attention_sharded(q1, kp, vp, bt, lengths, mesh=mesh)
    assert jnp.array_equal(ref, out)


def test_dispatcher_routes_sharded_under_rules(mesh, data):
    """`models/attention.py` picks the sharded entry exactly when the
    active rules' model axis divides both head counts; non-divisible
    head counts fall back to the unsharded kernel (the sharding-rules
    divisibility discipline)."""
    from repro.models import attention as attn
    from repro.runtime.sharding import ShardingRules, use_rules
    rng, kc, vc, kp, vp, bt = data
    q, q_pos = _q(rng, 4)
    want = attn.chunk_attention(q, kc, vc, q_pos, use_kernel=True)
    want_p = attn.paged_chunk_attention(q, kp, vp, bt, q_pos,
                                        use_kernel=True)
    with use_rules(ShardingRules(mesh)):
        assert attn._head_shard_mesh(H, HKV) is mesh
        assert attn._head_shard_mesh(6, 3) is None      # 2 divides neither
        got = attn.chunk_attention(q, kc, vc, q_pos, use_kernel=True)
        got_p = attn.paged_chunk_attention(q, kp, vp, bt, q_pos,
                                           use_kernel=True)
    assert jnp.array_equal(want, got)
    assert jnp.array_equal(want_p, got_p)
