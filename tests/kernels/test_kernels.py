"""Per-kernel allclose sweeps (interpret mode) against the ref.py oracles."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import flash_attention, flash_attention_ref
from repro.kernels.massmap import massmap, massmap_ref
from repro.kernels.ssd_scan import ssd_chunked_kernel, ssd_scan_ref
from repro.kernels.ssd_scan.kernel import ssd_scan_call
from repro.kernels.sumup import sumup, sumup_ref


def _rand(key, shape, dtype):
    x = jax.random.normal(key, shape, jnp.float32)
    return x.astype(dtype)


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 \
        else dict(rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# sumup
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("rows,n,block", [
    (1, 64, 16), (4, 256, 64), (8, 1024, 256), (2, 2048, 2048), (3, 96, 32),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_sumup_shapes(rows, n, block, dtype):
    x = _rand(jax.random.PRNGKey(rows * n), (rows, n), dtype)
    got = sumup(x, block=block)
    want = sumup_ref(x)
    np.testing.assert_allclose(np.array(got), np.array(want), rtol=1e-4,
                               atol=1e-3)


@pytest.mark.parametrize("op", ["sum", "max"])
def test_sumup_ops(op):
    x = _rand(jax.random.PRNGKey(7), (4, 512), jnp.float32)
    got = sumup(x, block=128, op=op)
    want = sumup_ref(x, op=op)
    np.testing.assert_allclose(np.array(got), np.array(want), rtol=1e-5,
                               atol=1e-5)


def test_sumup_matches_paper_semantics():
    """Same final sum as the EMPA machine's SUMUP mode (int vector)."""
    from repro.core import programs, run_program
    vec = np.array([13, 192, 2816, 40960, 5, 7, 11, 3], np.int32)
    r = run_program(programs.sumup_sumup(len(vec)), programs.mem_image(vec))
    got = sumup(jnp.asarray(vec, jnp.float32)[None], block=8)
    assert int(np.array(got)[0, 0]) == int(r.result)


# ---------------------------------------------------------------------------
# massmap
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("m,n,bm,bn", [
    (8, 64, 8, 32), (64, 256, 32, 128), (256, 512, 256, 512), (16, 128, 4, 64),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("act", ["silu", "gelu", "none"])
def test_massmap_shapes(m, n, bm, bn, dtype, act):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(m * n), 3)
    x = _rand(k1, (m, n), dtype)
    scale = _rand(k2, (n,), jnp.float32)
    bias = _rand(k3, (n,), jnp.float32)
    got = massmap(x, scale, bias, act=act, block_m=bm, block_n=bn)
    want = massmap_ref(x, scale, bias, act=act)
    np.testing.assert_allclose(np.array(got, np.float32),
                               np.array(want, np.float32), **_tol(dtype))


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b,h,hkv,sq,skv,d,bq,bk", [
    (1, 2, 2, 64, 64, 32, 32, 32),      # MHA
    (2, 4, 2, 128, 128, 64, 64, 64),    # GQA 2:1
    (1, 8, 2, 64, 128, 32, 32, 64),     # GQA 4:1, cross lengths
    (1, 2, 1, 256, 256, 64, 128, 128),  # MQA
])
@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(b, h, hkv, sq, skv, d, bq, bk, causal, dtype):
    if causal and sq != skv:
        pytest.skip("causal needs square layout in this sweep")
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(h * sq + d), 3)
    q = _rand(k1, (b, h, sq, d), dtype)
    k = _rand(k2, (b, hkv, skv, d), dtype)
    v = _rand(k3, (b, hkv, skv, d), dtype)
    got = flash_attention(q, k, v, causal=causal, block_q=bq, block_k=bk)
    want = flash_attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.array(got, np.float32),
                               np.array(want, np.float32), **_tol(dtype))


def test_flash_attention_matches_model_path():
    """Kernel == models/attention (both full and blockwise), layout-adjusted."""
    from repro.models import attention as A
    key = jax.random.PRNGKey(0)
    k1, k2, k3 = jax.random.split(key, 3)
    b, sq, h, hkv, d = 2, 128, 4, 2, 32
    q = jax.random.normal(k1, (b, sq, h, d), jnp.float32)
    k = jax.random.normal(k2, (b, sq, hkv, d), jnp.float32)
    v = jax.random.normal(k3, (b, sq, hkv, d), jnp.float32)
    want = A.full_attention(q, k, v, causal=True)
    want_bw = A.blockwise_attention(q, k, v, causal=True, chunk=32)
    got = flash_attention(q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                          v.transpose(0, 2, 1, 3), causal=True,
                          block_q=32, block_k=32).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.array(got), np.array(want), rtol=2e-5,
                               atol=2e-5)
    np.testing.assert_allclose(np.array(want_bw), np.array(want), rtol=2e-5,
                               atol=2e-5)


# ---------------------------------------------------------------------------
# ssd_scan
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b,h,nc,q,p,n", [
    (1, 2, 4, 16, 16, 8), (2, 3, 2, 32, 64, 16), (1, 1, 8, 64, 32, 32),
])
def test_ssd_scan_kernel_vs_ref(b, h, nc, q, p, n):
    key = jax.random.PRNGKey(b + h + q)
    k1, k2, k3, k4 = jax.random.split(key, 4)
    xdt = jax.random.normal(k1, (b, h, nc, q, p), jnp.float32)
    # realistic negative decays: cumsum of small negative increments
    da = -0.05 * jax.random.uniform(k2, (b, h, nc, q, 1))
    cum = jnp.cumsum(da, axis=3)
    bm = jax.random.normal(k3, (b, h, nc, q, n), jnp.float32) * 0.5
    cm = jax.random.normal(k4, (b, h, nc, q, n), jnp.float32) * 0.5
    y, st = ssd_scan_call(xdt, cum, bm, cm, interpret=True)
    y_ref, st_ref = ssd_scan_ref(xdt, cum, bm, cm)
    np.testing.assert_allclose(np.array(y), np.array(y_ref), rtol=1e-4,
                               atol=1e-4)
    np.testing.assert_allclose(np.array(st), np.array(st_ref), rtol=1e-4,
                               atol=1e-4)


@pytest.mark.parametrize("s,chunk", [(64, 16), (128, 64), (96, 32)])
def test_ssd_wrapper_vs_model_ssm(s, chunk):
    """Kernel-backed SSD == models/ssm.ssd_chunked (the model oracle)."""
    from repro.models import ssm
    key = jax.random.PRNGKey(s)
    ks = jax.random.split(key, 6)
    b, h, p, n, g = 2, 4, 16, 8, 1
    x = jax.random.normal(ks[0], (b, s, h, p), jnp.float32)
    dt = jax.random.normal(ks[1], (b, s, h), jnp.float32) * 0.5
    a_log = jax.random.normal(ks[2], (h,)) * 0.3
    bm = jax.random.normal(ks[3], (b, s, g, n)) * 0.5
    cm = jax.random.normal(ks[4], (b, s, g, n)) * 0.5
    d_skip = jax.random.normal(ks[5], (h,))
    dt_bias = jnp.zeros((h,))
    y_k, st_k = ssd_chunked_kernel(x, dt, a_log, bm, cm, d_skip, dt_bias,
                                   chunk=chunk)
    y_r, st_r = ssm.ssd_chunked(x, dt, a_log, bm, cm, d_skip, dt_bias,
                                chunk=chunk)
    np.testing.assert_allclose(np.array(y_k), np.array(y_r), rtol=2e-4,
                               atol=2e-4)
    np.testing.assert_allclose(np.array(st_k), np.array(st_r), rtol=2e-4,
                               atol=2e-4)


def test_ssd_decode_matches_chunked():
    """O(1) decode steps == chunked scan over the same tokens."""
    from repro.models import ssm
    key = jax.random.PRNGKey(3)
    ks = jax.random.split(key, 6)
    b, s, h, p, n, g = 1, 16, 2, 8, 4, 1
    x = jax.random.normal(ks[0], (b, s, h, p), jnp.float32)
    dt = jax.random.normal(ks[1], (b, s, h)) * 0.5
    a_log = jax.random.normal(ks[2], (h,)) * 0.3
    bm = jax.random.normal(ks[3], (b, s, g, n)) * 0.5
    cm = jax.random.normal(ks[4], (b, s, g, n)) * 0.5
    d_skip = jax.random.normal(ks[5], (h,))
    dt_bias = jnp.zeros((h,))
    y_ref, st_ref = ssm.ssd_chunked(x, dt, a_log, bm, cm, d_skip, dt_bias,
                                    chunk=8)
    state = jnp.zeros((b, h, p, n))
    ys = []
    for t in range(s):
        y_t, state = ssm.ssd_decode_step(x[:, t], dt[:, t], a_log, bm[:, t],
                                         cm[:, t], d_skip, dt_bias, state)
        ys.append(y_t)
    y_seq = jnp.stack(ys, axis=1)
    np.testing.assert_allclose(np.array(y_seq), np.array(y_ref), rtol=2e-4,
                               atol=2e-4)
    np.testing.assert_allclose(np.array(state), np.array(st_ref), rtol=2e-4,
                               atol=2e-4)
