"""Paged-attention kernel vs its oracles (interpret mode).

Three-way agreement: the Pallas kernel (scalar-prefetched block tables,
online softmax) == the pure-jnp ref.py gather == the model path
(`models/attention.paged_decode_attention`, which itself must match
contiguous `decode_attention` bit-for-bit on the same chains).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.paged_attention import paged_attention, paged_attention_ref


def _rand(key, shape, dtype):
    return jax.random.normal(key, shape, jnp.float32).astype(dtype)


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 \
        else dict(rtol=2e-5, atol=2e-5)


def _chains(rng, b, n_pages, nb, bs, lengths):
    """Random disjoint chains covering each row's length."""
    tables = np.full((b, nb), -1, np.int32)
    perm = rng.permutation(n_pages)
    i = 0
    for r in range(b):
        for j in range(-(-int(lengths[r]) // bs)):
            tables[r, j] = perm[i]
            i += 1
    return jnp.asarray(tables)


@pytest.mark.parametrize("b,h,hkv,d,n_pages,bs,nb", [
    (1, 2, 2, 32, 8, 8, 4),       # MHA
    (3, 4, 2, 32, 16, 8, 4),      # GQA 2:1
    (2, 8, 2, 64, 12, 16, 3),     # GQA 4:1
    (2, 2, 1, 64, 10, 8, 4),      # MQA
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_paged_attention_kernel_vs_ref(b, h, hkv, d, n_pages, bs, nb, dtype):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(b * h + d), 3)
    q = _rand(k1, (b, h, d), dtype)
    kp = _rand(k2, (n_pages, bs, hkv, d), dtype)
    vp = _rand(k3, (n_pages, bs, hkv, d), dtype)
    rng = np.random.default_rng(b + nb)
    lengths = jnp.asarray(rng.integers(1, nb * bs + 1, size=b), jnp.int32)
    tables = _chains(rng, b, n_pages, nb, bs, lengths)
    got = paged_attention(q, kp, vp, tables, lengths)
    want = paged_attention_ref(q, kp, vp, tables, lengths)
    np.testing.assert_allclose(np.array(got, np.float32),
                               np.array(want, np.float32), **_tol(dtype))


def test_paged_matches_contiguous_decode_attention():
    """Gathering the chain == attending the contiguous cache: the ref
    (and the kernel) must agree with `models/attention.decode_attention`
    on the same logical sequence."""
    from repro.models import attention as A
    key = jax.random.PRNGKey(0)
    k1, k2, k3 = jax.random.split(key, 3)
    b, h, hkv, d, bs, nb = 3, 4, 2, 32, 8, 4
    max_seq = bs * nb
    q = jax.random.normal(k1, (b, 1, h, d), jnp.float32)
    k_cont = jax.random.normal(k2, (b, max_seq, hkv, d), jnp.float32)
    v_cont = jax.random.normal(k3, (b, max_seq, hkv, d), jnp.float32)
    lengths = jnp.asarray([5, 17, 32], jnp.int32)
    # scatter the contiguous rows into shuffled pages
    rng = np.random.default_rng(7)
    tables = _chains(rng, b, b * nb, nb, bs, [max_seq] * b)
    kp = jnp.zeros((b * nb, bs, hkv, d), jnp.float32)
    vp = jnp.zeros((b * nb, bs, hkv, d), jnp.float32)
    for r in range(b):
        for j in range(nb):
            blk = int(tables[r, j])
            kp = kp.at[blk].set(k_cont[r, j * bs:(j + 1) * bs])
            vp = vp.at[blk].set(v_cont[r, j * bs:(j + 1) * bs])
    want = A.decode_attention(q, k_cont, v_cont, lengths)
    # model path (pure jnp): bit-exact vs contiguous
    got_model = A.paged_decode_attention(q, kp, vp, tables, lengths,
                                         use_kernel=False)
    np.testing.assert_array_equal(np.asarray(got_model), np.asarray(want))
    # kernel path (interpret): allclose (own accumulation schedule)
    got_kernel = A.paged_decode_attention(q, kp, vp, tables, lengths,
                                          use_kernel=True)
    np.testing.assert_allclose(np.asarray(got_kernel), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_paged_attention_empty_rows_are_finite():
    """Rows with length 0 (unadmitted slots riding in the batch) must
    produce finite output, never NaN (the engine discards them)."""
    q = jnp.ones((2, 4, 32), jnp.float32)
    kp = jnp.zeros((4, 8, 2, 32), jnp.float32)
    vp = jnp.zeros((4, 8, 2, 32), jnp.float32)
    tables = jnp.full((2, 2), -1, jnp.int32)
    lengths = jnp.asarray([0, 0], jnp.int32)
    out = paged_attention(q, kp, vp, tables, lengths)
    assert bool(jnp.all(jnp.isfinite(out)))
