"""Chunk-attention kernels vs their oracles (interpret mode).

Three independent sources of truth, all required to agree:

* ``ref.py`` — the naive full-cache-mask jnp schedule;
* ``models/attention.chunk_attention(use_kernel=False)`` — the
  span-clamped jnp ladder (must be BIT-exact vs the unclamped math:
  the pow2-slice append-zeros invariance every token-exactness
  guarantee in the serving tests leans on);
* ``full_attention`` over the logical prefix — an oracle that never
  saw the chunk/cache machinery at all.

Coverage per the shape-dispatch table: fragment widths {1, non-pow2,
spec k+1}, ``q_pos`` at 0 / a block boundary / ``max_seq - width``,
contiguous and paged layouts, wide and narrow kernel schedules.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.chunk_attention import (
    NARROW_MAX_WIDTH,
    chunk_attention_kernel,
    chunk_attention_ref,
    paged_chunk_attention_kernel,
    paged_chunk_attention_ref,
)
from repro.models import attention as A

TOL = dict(rtol=2e-5, atol=2e-5)


def _rand(key, shape, dtype=jnp.float32):
    return jax.random.normal(key, shape, jnp.float32).astype(dtype)


def _inputs(b, c, h, hkv, d, smax, pos0, seed=0):
    """Contiguous cache + fragment at per-row start positions pos0."""
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = _rand(k1, (b, c, h, d))
    kc = _rand(k2, (b, smax, hkv, d))
    vc = _rand(k3, (b, smax, hkv, d))
    q_pos = jnp.asarray(pos0, jnp.int32)[:, None] + jnp.arange(c)
    return q, kc, vc, q_pos


def _paged_from_contiguous(kc, vc, bs, seed=0):
    """Scatter each row's contiguous cache into shuffled pages."""
    b, smax, hkv, d = kc.shape
    nb = smax // bs
    rng = np.random.default_rng(seed)
    tables = np.full((b, nb), -1, np.int32)
    perm = rng.permutation(b * nb)
    kp = np.zeros((b * nb, bs, hkv, d), np.float32)
    vp = np.zeros((b * nb, bs, hkv, d), np.float32)
    i = 0
    for r in range(b):
        for j in range(nb):
            tables[r, j] = perm[i]
            kp[perm[i]] = np.asarray(kc[r, j * bs:(j + 1) * bs])
            vp[perm[i]] = np.asarray(vc[r, j * bs:(j + 1) * bs])
            i += 1
    return jnp.asarray(kp), jnp.asarray(vp), jnp.asarray(tables)


# -- widths {1, non-pow2, spec k+1} x q_pos {0, block boundary, smax-w} -----

WIDTHS = [1, 3, 5]          # 1 = decode-like, 3 = non-pow2, 5 = spec k+1
POS_CASES = ["zero", "block_boundary", "max"]


def _pos0(case, b, c, smax, bs=16):
    if case == "zero":
        return [0] * b
    if case == "block_boundary":
        return [bs, bs * 2, bs - 1, bs * 3][:b]
    return [smax - c] * b


@pytest.mark.parametrize("c", WIDTHS)
@pytest.mark.parametrize("case", POS_CASES)
def test_kernel_vs_ref_contiguous(c, case):
    b, h, hkv, d, smax = 4, 4, 2, 32, 64
    q, kc, vc, q_pos = _inputs(b, c, h, hkv, d, smax,
                               _pos0(case, b, c, smax), seed=c)
    got = chunk_attention_kernel(q, kc, vc, q_pos)
    want = chunk_attention_ref(q, kc, vc, q_pos)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), **TOL)


@pytest.mark.parametrize("c", WIDTHS)
@pytest.mark.parametrize("case", POS_CASES)
def test_kernel_vs_ref_paged(c, case):
    b, h, hkv, d, smax, bs = 4, 4, 2, 32, 64, 16
    q, kc, vc, q_pos = _inputs(b, c, h, hkv, d, smax,
                               _pos0(case, b, c, smax, bs), seed=10 + c)
    kp, vp, tables = _paged_from_contiguous(kc, vc, bs, seed=c)
    got = paged_chunk_attention_kernel(q, kp, vp, tables, q_pos)
    want = paged_chunk_attention_ref(q, kp, vp, tables, q_pos)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), **TOL)
    # gathering the chain back == the contiguous cache: one more oracle
    want_cont = chunk_attention_ref(q, kc, vc, q_pos)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want_cont),
                               **TOL)


def test_wide_schedule_vs_ref():
    """Fragments above NARROW_MAX_WIDTH dispatch to the wide kernel."""
    b, c, h, hkv, d, smax = 2, NARROW_MAX_WIDTH + 8, 8, 2, 64, 128
    q, kc, vc, q_pos = _inputs(b, c, h, hkv, d, smax, [0, 32], seed=3)
    got = chunk_attention_kernel(q, kc, vc, q_pos)
    want = chunk_attention_ref(q, kc, vc, q_pos)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), **TOL)


# -- full_attention as the independent oracle -------------------------------

@pytest.mark.parametrize("c", WIDTHS)
def test_against_full_attention_oracle(c):
    """A fragment continuing a prefix must produce exactly what one
    monolithic causal forward over [prefix; fragment] produces at the
    fragment's positions — checked against `full_attention`, which
    never saw the cache/chunk machinery (not just the ref)."""
    b, h, hkv, d, smax = 2, 4, 2, 32, 64
    plen = 21                                        # prefix length
    key = jax.random.PRNGKey(40 + c)
    k1, k2, k3 = jax.random.split(key, 3)
    total = plen + c
    q_all = _rand(k1, (b, total, h, d))
    k_all = _rand(k2, (b, total, hkv, d))
    v_all = _rand(k3, (b, total, hkv, d))
    want = A.full_attention(q_all, k_all, v_all, causal=True)[:, plen:]
    # the same math as a cached fragment: cache rows 0..plen+c hold K/V
    kc = jnp.zeros((b, smax, hkv, d)).at[:, :total].set(k_all)
    vc = jnp.zeros((b, smax, hkv, d)).at[:, :total].set(v_all)
    q = q_all[:, plen:]
    q_pos = plen + jnp.arange(c)[None, :] + jnp.zeros((b, 1), jnp.int32)
    for fn in (chunk_attention_kernel,
               lambda *a: A.chunk_attention(*a, use_kernel=False)):
        got = fn(q, kc, vc, q_pos)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   **TOL)


# -- the span clamp must be invisible: bit-exact vs unclamped ---------------

@pytest.mark.parametrize("c", WIDTHS)
@pytest.mark.parametrize("case", POS_CASES)
def test_clamped_jnp_bit_exact_vs_full_mask(c, case):
    """The ladder slice is the *same bits* as masking the whole cache —
    the invariance every serving token-exactness test leans on."""
    b, h, hkv, d, smax = 4, 4, 2, 32, 128
    q, kc, vc, q_pos = _inputs(b, c, h, hkv, d, smax,
                               _pos0(case, b, c, smax), seed=20 + c)
    clamped = A.chunk_attention(q, kc, vc, q_pos, use_kernel=False)
    full = A.chunk_attention(q, kc, vc, q_pos,
                             span_idx=jnp.int32(len(A.span_ladder(smax))
                                                - 1),
                             use_kernel=False)
    np.testing.assert_array_equal(np.asarray(clamped), np.asarray(full))


# -- satellite 2: short fragment over a long chain touches few blocks ------

def test_paged_clamp_touches_expected_block_count():
    b, h, hkv, d, smax, bs = 2, 4, 2, 32, 128, 16
    c = 4
    q, kc, vc, q_pos = _inputs(b, c, h, hkv, d, smax, [10, 17], seed=5)
    kp, vp, tables = _paged_from_contiguous(kc, vc, bs, seed=5)
    out, blocks = A.paged_chunk_attention(q, kp, vp, tables, q_pos,
                                          use_kernel=False,
                                          return_blocks=True)
    # limit = max(q_pos)+1 = 21 -> rung 32 -> ceil(32/16) = 2 of the
    # 8-block chain gathered
    assert int(blocks) == 2, int(blocks)
    want = chunk_attention_ref(q, kc, vc, q_pos)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), **TOL)
    # fragment at the chain's end -> the whole chain
    q_pos_end = jnp.asarray([smax - c, smax - c], jnp.int32)[:, None] \
        + jnp.arange(c)
    _, blocks_end = A.paged_chunk_attention(q, kp, vp, tables, q_pos_end,
                                            use_kernel=False,
                                            return_blocks=True)
    assert int(blocks_end) == smax // bs, int(blocks_end)


def test_span_ladder_shapes():
    assert A.span_ladder(128) == [16, 32, 64, 128]
    assert A.span_ladder(96) == [16, 32, 64, 96]
    assert A.span_ladder(16) == [16]
    assert A.span_ladder(8) == [8]
    assert A.span_ladder(1024) == [128, 256, 512, 1024]
    # attended_span picks the smallest covering rung
    qp = jnp.asarray([[20], [5]], jnp.int32)
    assert int(A.attended_span(qp, 128)) == 1          # rung 32
    assert int(A.attended_span(jnp.zeros((2, 1), jnp.int32), 128)) == 0
    assert int(A.attended_span(jnp.full((2, 1), 127, jnp.int32),
                               128)) == 3


def test_garbage_rows_are_finite():
    """Rows whose q_pos points at an empty cache region (unadmitted
    slots riding in the batch) must stay finite — the engine discards
    their outputs but NaNs would poison donated buffers."""
    b, c, h, hkv, d, smax = 2, 5, 4, 2, 32, 64
    q = jnp.ones((b, c, h, d), jnp.float32)
    kc = jnp.zeros((b, smax, hkv, d), jnp.float32)
    vc = jnp.zeros((b, smax, hkv, d), jnp.float32)
    q_pos = jnp.zeros((b, 1), jnp.int32) + jnp.arange(c)
    out = chunk_attention_kernel(q, kc, vc, q_pos)
    assert bool(jnp.all(jnp.isfinite(out)))
    out = A.chunk_attention(q, kc, vc, q_pos, use_kernel=False)
    assert bool(jnp.all(jnp.isfinite(out)))
