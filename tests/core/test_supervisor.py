"""Property tests of the SV pool semantics (supervisor.CorePool, qt.QTGraph)."""
import pytest

pytest.importorskip("hypothesis")   # real lib or the conftest fallback
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.qt import QT, MassMode, QTGraph
from repro.core.supervisor import CorePool


def test_rent_release_roundtrip():
    pool = CorePool(8)
    u = pool.rent()
    assert u == 0 and pool.used == 1
    pool.release(u)
    assert pool.used == 0 and pool.available == 8
    pool.check_invariants()


def test_parent_child_masks():
    pool = CorePool(8)
    p = pool.rent()
    c1, c2 = pool.rent(parent=p), pool.rent(parent=p)
    assert pool.children_of(p) == [c1, c2]
    assert pool.parent_of(c1) == p
    with pytest.raises(RuntimeError):
        pool.release(p)  # §4.3: parent termination blocked
    pool.release(c1)
    pool.release(c2)
    pool.release(p)      # now allowed
    pool.check_invariants()


def test_prealloc_preference():
    pool = CorePool(8)
    p = pool.rent()
    got = pool.preallocate(p, 2)
    assert len(got) == 2
    c = pool.rent(parent=p)
    assert c in got  # preallocated units are preferred (§5.1)
    pool.check_invariants()


def test_disable_excludes_from_pool():
    pool = CorePool(4)
    pool.disable(0)
    assert pool.rent() == 1   # 'overheated' unit skipped (§4.1.2)
    assert pool.available == 2
    pool.check_invariants()


def test_exhaustion_returns_none():
    pool = CorePool(2)
    assert pool.rent() is not None and pool.rent() is not None
    assert pool.rent() is None
    assert not pool.ready()


@settings(max_examples=40, deadline=None)
@given(st.lists(st.sampled_from(["rent", "rent_child", "release", "disable",
                                 "enable"]), max_size=60),
       st.integers(2, 16))
def test_pool_invariants_random_walk(ops, n):
    """Invariants hold under arbitrary operation sequences."""
    pool = CorePool(n)
    rented: list[int] = []
    for op in ops:
        if op == "rent":
            u = pool.rent()
            if u is not None:
                rented.append(u)
        elif op == "rent_child" and rented:
            u = pool.rent(parent=rented[0])
            if u is not None:
                rented.append(u)
        elif op == "release" and rented:
            u = rented[-1]
            if not pool.children_of(u):
                pool.release(u)
                rented.remove(u)
        elif op == "disable":
            pool.disable(n - 1)
        elif op == "enable":
            pool.enable(n - 1)
        pool.check_invariants()
    assert pool.used == len(rented)


def test_qt_graph_basics():
    g = QTGraph()
    g.add(QT("train_step", flops=1e12))
    g.add(QT("embed", flops=1e9, shard_axis="data"), parent="train_step",
          glue_bytes=1e6)
    g.add(QT("layers", flops=9e11, mode=MassMode.FOR), parent="train_step",
          glue_bytes=2e6)
    g.add(QT("grad_reduce", mode=MassMode.SUMUP), parent="train_step")
    assert g.roots() == ["train_step"]
    assert set(g.children("train_step")) == {"embed", "layers", "grad_reduce"}
    assert g.parent("embed") == "train_step"
    assert g.total_flops() == pytest.approx(1e12 + 1e9 + 9e11)
    assert g.total_glue_bytes() == pytest.approx(3e6)
    g.check_invariants()


def test_qt_graph_rejects_duplicates_and_unknown_parent():
    g = QTGraph()
    g.add(QT("a"))
    with pytest.raises(ValueError):
        g.add(QT("a"))
    with pytest.raises(ValueError):
        g.add(QT("b"), parent="nope")
