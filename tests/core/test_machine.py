"""Machine semantics: results, timing model, nested QTs, properties."""
import numpy as np
import pytest

pytest.importorskip("hypothesis")   # real lib or the conftest fallback
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import exec_clocks, isa, machine, programs, run_program

MODES = ["NO", "FOR", "SUMUP"]


@settings(max_examples=12, deadline=None)
@given(st.lists(st.integers(-2**20, 2**20), min_size=1, max_size=48),
       st.sampled_from(MODES))
def test_sum_matches_numpy(vec, mode):
    """Property: all three codings compute exactly sum(vec)."""
    n = len(vec)
    r = run_program(programs.PROGRAMS[mode](n), programs.mem_image(vec))
    assert bool(r.halted)
    # int32 wrap-around semantics on both sides
    assert int(r.result) == int(np.asarray(vec, np.int32).sum(dtype=np.int32))


@settings(max_examples=12, deadline=None)
@given(st.integers(1, 120), st.sampled_from(MODES))
def test_clocks_match_analytic(n, mode):
    """Property: machine clock count equals the analytic timing model."""
    vec = np.arange(1, n + 1, dtype=np.int32)
    r = run_program(programs.PROGRAMS[mode](n), programs.mem_image(vec))
    assert int(r.clocks) == int(exec_clocks(n, mode))


@pytest.mark.parametrize("aluop,npop", [
    (isa.ALU_ADD, lambda v: np.int32(v.sum(dtype=np.int32))),
    (isa.ALU_AND, lambda v: np.bitwise_and.reduce(v)),
    (isa.ALU_XOR, lambda v: np.bitwise_xor.reduce(v)),
])
def test_sumup_alu_ops(aluop, npop):
    """The SUMUP combining unit supports add/and/xor (mass modes, §4.6)."""
    vec = np.array([0b1100, 0b1010, 0b0111, 0b11110, 5], np.int32)
    src = [
        ("irmovl", len(vec), "%edx"),
        ("irmovl", programs.ARRAY_BASE, "%ecx"),
        ("irmovl", -1 if aluop == isa.ALU_AND else 0, "%eax"),
        ("andl", "%edx", "%edx"),
        ("qprealloc", 30),
        ("qsumup", "%ecx", "%edx", "Payload", 4, aluop),
        ("halt",),
        ("label", "Payload"),
        ("mrmovl", 0, "%ecx", "%esi"),
        ("paddl", "%esi"),
        ("qterm",),
    ]
    r = run_program(isa.assemble(src), programs.mem_image(vec))
    expected = npop(vec)
    if aluop == isa.ALU_AND:
        expected = np.bitwise_and(np.int32(-1), expected)
    assert int(r.result) == int(expected)


@pytest.mark.parametrize("depth,fanout", [(1, 2), (2, 3), (3, 2)])
def test_nested_qt_tree(depth, fanout):
    """§3: 'QTs can be embedded into each other' — count leaves of a tree."""
    r = run_program(programs.qt_tree(depth, fanout), ())
    assert bool(r.halted)
    assert int(r.result) == fanout ** depth
    assert int(r.created_total) == sum(fanout ** d for d in range(1, depth + 1))


def test_parent_termination_blocked_until_children_done():
    """§4.3: the SV blocks termination of a parent until children clear."""
    src = [
        ("qcreate", "Child"),
        ("halt",),                     # parent tries to halt immediately
        ("label", "Child"),
        ("irmovl", 7, "%eax"),
        ("irmovl", 1, "%ebx"),         # busy-work so the child outlives
        ("irmovl", 2, "%ebx"),         # the parent's halt attempt
        ("qterm",),
    ]
    r = run_program(isa.assemble(src), ())
    assert bool(r.halted)  # halts *eventually*, after the child terminated


def test_qwait_clone_back():
    """§4.6: the latched link register is written back on (implied) Wait."""
    src = [
        ("irmovl", 100, "%eax"),
        ("qcreate", "Child"),
        ("qwait",),
        ("halt",),                    # %eax must hold the child's clone-back
        ("label", "Child"),
        ("irmovl", 41, "%ebx"),
        ("irmovl", 1, "%ecx"),
        ("addl", "%ecx", "%ebx"),
        ("rrmovl", "%ebx", "%eax"),
        ("qterm",),
    ]
    r = run_program(isa.assemble(src), ())
    assert int(r.result) == 42


def test_child_inherits_glue():
    """§3.5: the parent's 'glue' (registers) is cloned to the child."""
    src = [
        ("irmovl", 1000, "%esi"),
        ("xorl", "%eax", "%eax"),
        ("qcreate", "Child"),
        ("qwait",),
        ("halt",),
        ("label", "Child"),
        ("rrmovl", "%esi", "%eax"),   # child sees parent's %esi
        ("qterm",),
    ]
    r = run_program(isa.assemble(src), ())
    assert int(r.result) == 1000


def test_out_of_cores_blocks_not_crashes():
    """When the pool is exhausted, QCREATE retries until a core frees
    (§4.5: 'the SV simply disables the core, until the condition
    fulfilled')."""
    fanout = machine.MAX_CORES + 4   # more QTs than cores
    src = [("xorl", "%ebx", "%ebx")]
    for _ in range(fanout):
        src += [("qcreate", "Child"), ("qwait",), ("addl", "%eax", "%ebx")]
    src += [("rrmovl", "%ebx", "%eax"), ("halt",),
            ("label", "Child"), ("irmovl", 1, "%eax"), ("qterm",)]
    r = run_program(isa.assemble(src), ())
    assert int(r.result) == fanout


def test_memory_store_load_roundtrip():
    src = [
        ("irmovl", 0x200, "%ecx"),
        ("irmovl", 1234, "%eax"),
        ("rmmovl", "%eax", 0, "%ecx"),
        ("irmovl", 0, "%eax"),
        ("mrmovl", 0, "%ecx", "%eax"),
        ("halt",),
    ]
    r = run_program(isa.assemble(src), ())
    assert int(r.result) == 1234


def test_conditional_jumps():
    # compute |x| via jge
    for x, expect in [(5, 5), (-5, 5), (0, 0)]:
        src = [
            ("irmovl", x, "%eax"),
            ("andl", "%eax", "%eax"),
            ("jge", "Done"),
            ("irmovl", 0, "%ebx"),
            ("subl", "%eax", "%ebx"),
            ("rrmovl", "%ebx", "%eax"),
            ("label", "Done"),
            ("halt",),
        ]
        r = run_program(isa.assemble(src), ())
        assert int(r.result) == expect, x


def test_peak_cores_accounting_for_mode():
    vec = np.arange(1, 9, dtype=np.int32)
    r = run_program(programs.sumup_for(8), programs.mem_image(vec))
    assert int(r.peak_cores) == 2       # 1 parent + 1 reused child
    assert int(r.created_total) == 8    # the child was rented 8 times
