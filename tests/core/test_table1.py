"""Exact reproduction of the paper's Table 1 (and §6.1/§6.2 claims)."""
import numpy as np
import pytest

from repro.core import TABLE1, alpha_eff, cores_used, exec_clocks, programs, \
    run_program, speedup, timing

VEC = [0xD, 0xC0, 0xB00, 0xA000, 5, 7]  # paper's 4-element array, extended


@pytest.mark.parametrize("n,mode,t_exp,k_exp,s_exp,sk_exp,aeff_exp", TABLE1)
def test_table1_machine(n, mode, t_exp, k_exp, s_exp, sk_exp, aeff_exp):
    r = run_program(programs.PROGRAMS[mode](n), programs.mem_image(VEC[:n]))
    assert bool(r.halted), "machine did not halt cleanly"
    assert int(r.clocks) == t_exp, f"clocks {int(r.clocks)} != Table1 {t_exp}"
    assert int(r.peak_cores) == k_exp
    assert int(r.result) == sum(VEC[:n])


@pytest.mark.parametrize("n,mode,t_exp,k_exp,s_exp,sk_exp,aeff_exp", TABLE1)
def test_table1_analytic(n, mode, t_exp, k_exp, s_exp, sk_exp, aeff_exp):
    """Clock/core counts must be exact; the paper's derived float columns
    mix round-half-up and truncation in the last printed digit (e.g. the
    n=2 FOR α_eff prints 0.97 although k/(k−1)·(S−1)/S = 0.9756), so the
    float columns are checked to ±0.015 — one unit in the last place."""
    assert int(exec_clocks(n, mode)) == t_exp
    assert int(cores_used(n, mode)) == k_exp
    s = speedup(n, mode)
    assert float(s) == pytest.approx(s_exp, abs=0.015)
    assert float(s / cores_used(n, mode)) == pytest.approx(sk_exp, abs=0.015)
    assert float(alpha_eff(k_exp, s)) == pytest.approx(aeff_exp, abs=0.015)


def test_speedup_saturation():
    """§6.1: speedups saturate at 30/11 (FOR) and 30 (SUMUP)."""
    n = 10**7
    assert speedup(n, "FOR") == pytest.approx(30 / 11, rel=1e-4)
    assert speedup(n, "SUMUP") == pytest.approx(30.0, rel=1e-4)


def test_core_cap():
    """§6.2: max 31 cores (1 parent + 30 children) in SUMUP mode."""
    for n in (1, 5, 30, 31, 64, 200):
        assert int(cores_used(n, "SUMUP")) == min(n, 30) + 1
    vec = np.arange(1, 65)
    r = run_program(programs.sumup_sumup(64), programs.mem_image(vec))
    assert int(r.peak_cores) == 31
    assert int(r.clocks) == 32 + 64


def test_alpha_eff_limits():
    """α_eff → 1 for long vectors (Fig 6); S/k falls then re-approaches 1."""
    a = timing.alpha_eff_mode(np.array([1, 10, 100, 10000]), "SUMUP")
    assert np.all(np.diff(a) > 0) and a[-1] > 0.99
    sk = timing.s_over_k(np.array([10, 30, 40, 100]), "SUMUP")
    assert sk[1] <= sk[0] or sk[0] < 1  # falls while k grows with n
    assert float(timing.s_over_k(10**6, "SUMUP")) == pytest.approx(30 / 31, rel=1e-3)
